//! XLA runtime demo: run the AOT Pallas/JAX artifacts (component labels,
//! BFS reachability, triangle census) through PJRT and cross-check every
//! result against the native CPU implementations.
//!
//! ```bash
//! make artifacts && cargo run --release --example accel_components
//! ```

use cavc::ensure;
use cavc::graph::{components, generators, metrics, Graph};
use cavc::runtime::{Accelerator, ArtifactSet};
use cavc::util::error::Result;
use std::time::Instant;

fn main() -> Result<()> {
    let set = ArtifactSet::default_location();
    ensure!(
        set.complete(),
        "artifacts missing under {} — run `make artifacts` first",
        set.dir().display()
    );
    let acc = Accelerator::with_artifacts(set)?;
    println!("PJRT CPU client up; size classes up to {} vertices\n", acc.max_vertices());

    // 1. Component labels on a graph that splits into many parts.
    let g = generators::union_of_random(25, 8, 20, 0.25, 42);
    let t = Instant::now();
    let labels = acc.connected_components(&g)?;
    let xla_ms = t.elapsed().as_secs_f64() * 1e3;
    let t = Instant::now();
    let (_, native_count) = components::labels(&g);
    let cpu_ms = t.elapsed().as_secs_f64() * 1e3;
    let distinct: std::collections::HashSet<_> = labels.iter().collect();
    println!(
        "components: xla {} labels in {:.2} ms | native {} in {:.3} ms",
        distinct.len(),
        xla_ms,
        native_count,
        cpu_ms
    );
    assert_eq!(distinct.len(), native_count);

    // 2. BFS reachability from several sources.
    let g2 = Graph::disjoint_union(&[
        generators::random_tree(300, 1),
        generators::cycle(200),
        generators::clique(24),
    ]);
    for src in [0u32, 300, 510] {
        let t = Instant::now();
        let mask = acc.bfs_reach(&g2, src)?;
        let reached = mask.iter().filter(|&&b| b).count();
        let native = components::bfs_reach(&g2, src).count();
        println!(
            "bfs_reach(src={src}): {} vertices in {:.2} ms (native agrees: {})",
            reached,
            t.elapsed().as_secs_f64() * 1e3,
            reached == native
        );
        assert_eq!(reached, native);
    }

    // 3. Triangle census (the degree-2 triangle rule's statistics).
    let g3 = generators::geometric(400, 0.08, 9);
    let t = Instant::now();
    let tri = acc.triangle_census(&g3)?;
    let total: u64 = tri.iter().map(|&x| x as u64).sum();
    println!(
        "triangle census: {} triangle-memberships in {:.2} ms (native: {})",
        total,
        t.elapsed().as_secs_f64() * 1e3,
        metrics::triangles_per_vertex(&g3).iter().map(|&x| x as u64).sum::<u64>()
    );
    assert_eq!(tri, metrics::triangles_per_vertex(&g3));

    println!("\naccel_components OK — all XLA results match native");
    Ok(())
}
