//! End-to-end driver (the repo's headline validation run): exercises the
//! FULL three-layer stack on a real workload and reports the paper's
//! headline metric.
//!
//! Pipeline per dataset:
//!   1. build the analog graph (L3);
//!   2. root reduce + crown + induce (L3, paper §IV-B);
//!   3. split the residual into components with the **AOT-compiled
//!      XLA artifact** via PJRT when it fits a size class (L1/L2 via the
//!      runtime; native fallback otherwise) — proving the layers compose;
//!   4. solve every component with the proposed parallel engine and the
//!      three baselines;
//!   5. report the Table-I-shaped rows plus the tree-node reduction.
//!
//! Results land in `EXPERIMENTS.md` §End-to-end. Run with:
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use cavc::harness::{datasets, tables};
use cavc::prep::{prepare, PrepConfig};
use cavc::runtime::{Accelerator, ArtifactSet};
use cavc::util::error::Result;
use std::time::Instant;

fn main() -> Result<()> {
    let budget = tables::cell_timeout();
    println!(
        "== CAVC end-to-end driver (budget {}s/solve, scheduler {}) ==\n",
        budget.as_secs_f64(),
        tables::cell_scheduler().name()
    );

    // Layer check: PJRT + artifacts.
    let accel = match ArtifactSet::default_location() {
        set if set.complete() => match Accelerator::with_artifacts(set) {
            Ok(a) => {
                println!("[runtime] PJRT CPU client up; artifacts complete");
                Some(a)
            }
            Err(e) => {
                println!("[runtime] PJRT unavailable ({e}); native fallback");
                None
            }
        },
        _ => {
            println!("[runtime] artifacts missing (run `make artifacts`); native fallback");
            None
        }
    };

    let suite = if std::env::var("CAVC_SUITE").as_deref() == Ok("full") {
        datasets::suite()
    } else {
        datasets::smoke_suite()
    };

    let mut rows = Vec::new();
    for d in &suite {
        let g = d.build();
        println!("\n-- {} ({} analog, |V|={}, |E|={})", d.name, d.family, g.num_vertices(), g.num_edges());

        // §IV-B preprocessing
        let t0 = Instant::now();
        let p = prepare(&g, &PrepConfig::default(), None);
        println!(
            "[prep] {:.1} ms: greedy ub {}, forced {}, residual |V| {} (dtype {}, {} blocks)",
            t0.elapsed().as_secs_f64() * 1e3,
            p.greedy_ub,
            p.forced_cover.len(),
            p.residual.graph.num_vertices(),
            p.dtype.name(),
            p.occupancy.blocks
        );

        // §III-B root component split — through the XLA artifact when it fits
        if let Some(acc) = &accel {
            let t1 = Instant::now();
            match acc.component_split(&p.residual.graph) {
                Ok(sets) => {
                    let nontrivial = sets.iter().filter(|s| s.len() > 1).count();
                    println!(
                        "[xla ] root split via PJRT in {:.1} ms: {} components ({} non-trivial)",
                        t1.elapsed().as_secs_f64() * 1e3,
                        sets.len(),
                        nontrivial
                    );
                    // cross-check against native
                    let native = cavc::graph::components::count(&p.residual.graph);
                    assert_eq!(sets.len(), native, "XLA and native split disagree");
                }
                Err(e) => println!("[xla ] split skipped: {e}"),
            }
        }

        // Table-I row: the four variants
        let row = tables::table1_row(d);
        println!(
            "[mvc ] proposed {} ({}) | sequential {} | no-lb {} | yamout {}",
            tables::cell(&row.proposed),
            row.proposed.best,
            tables::cell(&row.sequential),
            tables::cell(&row.no_lb),
            tables::cell(&row.yamout),
        );

        // Tree-node reduction (Table III's shape)
        let t3 = tables::table3_row(d);
        println!(
            "[tree] nodes {}{} -> {} with component branching ({} splits)",
            t3.nodes_disabled,
            if t3.disabled_timed_out { "+" } else { "" },
            t3.nodes_enabled,
            t3.component_branches
        );
        rows.push(row);
    }

    println!("\n== Table I (this run) ==");
    tables::print_table1(&rows, std::io::stdout().lock())?;

    // headline check: the proposed solver beats or matches every baseline
    // on the splitting datasets
    let mut wins = 0;
    for r in &rows {
        let base = r.no_lb.secs.min(r.sequential.secs);
        if r.proposed.secs <= base || r.proposed.best <= r.no_lb.best {
            wins += 1;
        }
    }
    println!("\nproposed wins/ties vs best baseline on {}/{} datasets", wins, rows.len());
    println!("end_to_end OK");
    Ok(())
}
