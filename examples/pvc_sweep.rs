//! PVC sweep: the paper's Table-V workload shape — for a dataset, sweep
//! k across the minimum and watch the early-termination behaviour
//! (k ≥ min returns quickly; k = min−1 must exhaust the search).
//!
//! ```bash
//! cargo run --release --example pvc_sweep [dataset] [--variant ...]
//! ```

use cavc::harness::datasets;
use cavc::solver::{solve_mvc, solve_pvc, SolverConfig};

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "power-eris1176".into());
    let d = datasets::dataset(&name).unwrap_or_else(|| {
        eprintln!("unknown dataset {name}; try `cavc datasets`");
        std::process::exit(1);
    });
    let g = d.build();
    println!("dataset {} (|V|={}, |E|={})", d.name, g.num_vertices(), g.num_edges());

    let mvc = solve_mvc(&g, &SolverConfig::proposed());
    println!("minimum vertex cover: {} ({:.3}s)\n", mvc.best, mvc.elapsed.as_secs_f64());

    println!("{:>8} {:>8} {:>10} {:>12} {:>12}", "k", "found", "size", "time (s)", "tree nodes");
    for dk in -2i64..=2 {
        let k = (mvc.best as i64 + dk).max(0) as u32;
        let r = solve_pvc(&g, k, &SolverConfig::proposed());
        println!(
            "{:>8} {:>8} {:>10} {:>12.4} {:>12}",
            k,
            if r.found { "yes" } else { "no" },
            r.size.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
            r.elapsed.as_secs_f64(),
            r.stats.tree_nodes
        );
        // consistency with the exhaustive MVC
        assert_eq!(r.found, k >= mvc.best, "PVC inconsistent with MVC at k={k}");
    }
    println!("\npvc_sweep OK (k >= {} found, k < {} exhausted)", mvc.best, mvc.best);
}
