//! Quickstart: build a graph, solve MVC with the proposed solver, check
//! the answer against the sequential witness extractor.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use cavc::graph::{generators, Graph};
use cavc::solver::{solve_mvc, solve_pvc, SolverConfig};

fn main() {
    // 1. A graph from an edge list…
    let g = Graph::from_edges(9, &[
        (0, 1), (0, 4), (1, 2), (1, 4), (2, 5), (3, 4), (4, 5), (4, 7),
        (5, 8), (6, 7), (7, 8),
    ]);
    let r = solve_mvc(&g, &SolverConfig::proposed());
    println!("paper Figure-1 example: MVC size = {} (expected 4)", r.best);
    assert_eq!(r.best, 4);

    // 2. …or from a generator. This one splits into components while
    // branching — the paper's sweet spot.
    let g = generators::union_of_random(40, 6, 12, 0.2, 7);
    let r = solve_mvc(&g, &SolverConfig::proposed());
    println!(
        "union-of-40-parts: MVC = {}, tree nodes = {}, component splits = {}",
        r.best, r.stats.tree_nodes, r.stats.component_branches
    );

    // 3. Witness extraction runs on the sequential variant.
    let mut seq = SolverConfig::sequential();
    seq.extract_cover = true;
    let rs = solve_mvc(&g, &seq);
    assert_eq!(rs.best, r.best, "variants must agree");
    if let Some(cover) = &rs.cover {
        assert!(g.is_vertex_cover(cover));
        println!("witness cover of size {} verified", cover.len());
    }

    // 4. Parameterized variant: is there a cover of size ≤ k?
    for k in [r.best - 1, r.best, r.best + 1] {
        let p = solve_pvc(&g, k, &SolverConfig::proposed());
        println!("PVC k={k}: {}", if p.found { "found" } else { "none" });
    }
    println!("quickstart OK");
}
