//! Registry walkthrough: replays the paper's Figure-3 scenario against
//! the real component branch registry, printing every entry transition —
//! the clearest way to see how non-tail-recursive post-processing is
//! delegated to last descendants.
//!
//! ```bash
//! cargo run --release --example registry_trace
//! ```

use cavc::solver::registry::{Registry, NONE};

fn dump(reg: &Registry, label: &str, ids: &[(u32, &str)]) {
    println!("-- {label}");
    for &(idx, name) in ids {
        let (val, live, link, aux) = reg.snapshot(idx);
        println!(
            "   {name:<12} val={val:<4} live={live:<3} link={:<6} aux={aux}",
            if link == NONE { "ROOT".into() } else { format!("#{link}") }
        );
    }
}

fn main() {
    let reg = Registry::new(false);
    let mut root_report = |t: u32| println!(">>> ROOT receives achievable total {t}");

    // Figure 3: node 1 splits into components 2 and 3.
    println!("node 1 finds two components -> registers parent + children\n");
    let p1 = reg.new_parent(0, NONE);
    let c2 = reg.new_child(p1, 5, 5); // component of 6 vertices
    let c3 = reg.new_child(p1, 9, 9); // component of 10 vertices
    reg.finish_scan(p1, &mut root_report);
    let ids = [(p1, "parent n1"), (c2, "child n2"), (c3, "child n3")];
    dump(&reg, "after registration", &ids);

    // Node 12 (descendant of 3) splits again into 13 and 14.
    println!("\nnode 12 (inside component 3, with 1 vertex committed) splits\n");
    reg.on_branch(c3); // node 12 branched from node 3's subtree
    let p12 = reg.new_parent(1, c3);
    let c13 = reg.new_child(p12, 3, 3);
    let c14 = reg.new_child(p12, 2, 2);
    reg.finish_scan(p12, &mut root_report);
    let ids2 = [
        (p1, "parent n1"),
        (c2, "child n2"),
        (c3, "child n3"),
        (p12, "parent n12"),
        (c13, "child n13"),
        (c14, "child n14"),
    ];
    dump(&reg, "after nested registration", &ids2);

    // Node 20, the last descendant of 13, finds a cover of size 2.
    println!("\nnode 20 (last descendant of 13) reports best 2 and completes\n");
    reg.report_solution(c13, 2, &mut root_report);
    reg.complete_node(c13, &mut root_report);
    dump(&reg, "after n13 completes (n12.sum += 2, liveComps -= 1)", &ids2);

    // Component 14 completes with its initial bound.
    println!("\nlast descendant of 14 completes (best stays 2)\n");
    reg.complete_node(c14, &mut root_report);
    dump(
        &reg,
        "after n14 completes -> split n12 finished, total 1+2+2=5 improves n3",
        &ids2,
    );

    // Node 3's remaining descendant finishes; then node 2's.
    println!("\nremaining descendant of component 3 completes\n");
    reg.complete_node(c3, &mut root_report);
    println!("\ncomponent 2 completes with best 4\n");
    reg.report_solution(c2, 4, &mut root_report);
    reg.complete_node(c2, &mut root_report);
    dump(&reg, "final state (all live counters drained)", &ids2);

    reg.assert_drained();
    println!("\nregistry_trace OK — root total = parent sum 0 + best(c2)=4 + best(c3)=5 = 9");
}
