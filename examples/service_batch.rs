//! Quickstart for the resident solver service ([`cavc::solver::service`]).
//!
//! Builds one [`VcService`] (a persistent worker pool), then shows the
//! whole job lifecycle: fire-and-wait, a concurrent mixed MVC/PVC batch
//! on the shared pool, a per-job deadline, and cancellation.
//!
//! Run with: `cargo run --release --example service_batch`

use cavc::graph::generators;
use cavc::solver::{JobOptions, Problem, SolverConfig, Termination, VcService};
use std::time::Duration;

fn main() {
    // One pool for the whole process: construct once, submit forever.
    let svc = VcService::builder()
        .workers(4)
        .config(SolverConfig::proposed())
        .build();
    println!("service up: {} resident workers", svc.workers());

    // 1) Fire-and-wait.
    let sol = svc.solve(Problem::mvc(generators::petersen()));
    println!(
        "petersen: mvc = {} ({:?}, {} tree nodes)",
        sol.objective, sol.termination, sol.stats.tree_nodes
    );

    // 2) A concurrent batch of mixed problems: every submit returns
    //    immediately with a JobHandle; the jobs share the pool.
    let handles: Vec<_> = (0..8u64)
        .map(|seed| {
            let g = generators::erdos_renyi(18, 0.2, seed);
            if seed % 2 == 0 {
                svc.submit(Problem::mvc(g))
            } else {
                svc.submit(Problem::pvc(g, 12))
            }
        })
        .collect();
    for h in &handles {
        let sol = h.wait();
        println!(
            "job {:>2}: {:?} -> objective {} (feasible: {})",
            h.id(),
            sol.problem,
            sol.objective,
            sol.feasible
        );
    }

    // 3) Per-job deadline: a dense graph under a 50ms budget returns an
    //    upper bound with DeadlineExpired.
    let dense = generators::p_hat(120, 0.3, 0.8, 7);
    let bounded = svc.submit_with(
        Problem::mvc(dense.clone()),
        JobOptions { timeout: Some(Duration::from_millis(50)), ..JobOptions::default() },
    );
    let sol = bounded.wait();
    println!("deadline job: mvc <= {} ({:?})", sol.objective, sol.termination);

    // 4) Cancellation: queued nodes of the job are dropped as they
    //    surface; other jobs are untouched.
    let doomed = svc.submit(Problem::mvc(dense));
    doomed.cancel();
    let sol = doomed.wait();
    assert_eq!(sol.termination, Termination::Cancelled);
    println!("cancelled job: mvc <= {} ({:?})", sol.objective, sol.termination);

    // Dropping the service drains outstanding jobs and joins the pool.
}
