//! Internal: probe solve times of the full dataset suite (used while
//! calibrating the analogs; kept as a maintenance tool).
use cavc::harness::datasets;
use cavc::solver::{solve_mvc, SolverConfig};
fn main() {
    let budget = std::time::Duration::from_secs(12);
    for d in datasets::suite().iter().chain(datasets::table6_suite().iter()) {
        let g = d.build();
        let t = std::time::Instant::now();
        let r = solve_mvc(&g, &SolverConfig::proposed().with_timeout(budget));
        println!("{:<22} n={:<5} m={:<6} mvc={:<5} {:>8.3}s nodes={:<9} splits={:<7} to={}",
            d.name, g.num_vertices(), g.num_edges(), r.best, t.elapsed().as_secs_f64(),
            r.stats.tree_nodes, r.stats.component_branches, r.timed_out);
    }
}
