"""AOT compile path: lower every Layer-2 program to HLO **text**.

Run once at build time (`make artifacts`); the Rust runtime loads the
text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client. Python never runs on the request path.

HLO *text* — not ``lowered.compile()`` or a serialized ``HloModuleProto``
— is the interchange format: jax ≥ 0.5 emits protos with 64-bit
instruction ids which the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. ``return_tuple=True`` so the Rust side unpacks a
tuple uniformly. See /opt/xla-example/README.md.
"""

import argparse
import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import PROGRAMS, SIZE_CLASSES


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_program(name: str, n: int) -> str:
    """Lower one program at one size class to HLO text."""
    fn, spec_builder = PROGRAMS[name]
    lowered = jax.jit(fn).lower(*spec_builder(n))
    return to_hlo_text(lowered)


def build_all(out_dir: pathlib.Path, sizes=SIZE_CLASSES, programs=None) -> list[pathlib.Path]:
    """Write every artifact; returns the paths written."""
    out_dir.mkdir(parents=True, exist_ok=True)
    written = []
    for name in programs or PROGRAMS:
        for n in sizes:
            text = lower_program(name, n)
            path = out_dir / f"{name}_{n}.hlo.txt"
            path.write_text(text)
            written.append(path)
            print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--sizes", default=None, help="comma-separated size classes")
    ap.add_argument("--programs", default=None, help="comma-separated program names")
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(",")) if args.sizes else SIZE_CLASSES
    programs = args.programs.split(",") if args.programs else None
    build_all(pathlib.Path(args.out), sizes, programs)


if __name__ == "__main__":
    main()
