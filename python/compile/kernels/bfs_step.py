"""Pallas kernel: tiled BFS frontier expansion (Layer 1).

Frontier expansion is a masked mat-vec: ``reach = A @ f``. On the GPU the
paper does this pull-based with one thread block per frontier sweep; on
TPU the natural mapping is a tiled dot that feeds the MXU — adjacency
tiles are (128, 128) f32 blocks, the frontier is a 128-lane vector, and
the contraction accumulates across column tiles with the output row tile
stationary in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _bfs_expand_kernel(a_ref, f_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (TILE, TILE) @ (TILE,) → (TILE,) partial reach counts on the MXU.
    o_ref[...] += a_ref[...] @ f_ref[...]


@functools.partial(jax.jit, static_argnames=("tile",))
def bfs_expand(a, frontier, *, tile=TILE):
    """Raw expansion counts ``A @ f`` (callers threshold / mask).

    Matches ``ref.bfs_expand_ref``.
    """
    n = a.shape[0]
    assert a.shape == (n, n) and frontier.shape == (n,)
    assert n % tile == 0, f"n={n} must be a multiple of the {tile} tile"
    grid = (n // tile, n // tile)
    return pl.pallas_call(
        _bfs_expand_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
            pl.BlockSpec((tile,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(a, frontier)


def bfs_step(a, frontier, visited, *, tile=TILE):
    """One BFS step over the kernel: next frontier + updated visited.

    Matches ``ref.bfs_step_ref``.
    """
    reached = bfs_expand(a, frontier, tile=tile) > 0
    new_frontier = jnp.logical_and(reached, jnp.logical_not(visited > 0))
    new_frontier = new_frontier.astype(jnp.float32)
    return new_frontier, jnp.clip(visited + new_frontier, 0.0, 1.0)
