"""Pallas kernel: tiled min-label propagation step (Layer 1).

The paper's component finding is a block-collaborative pull-based BFS on
the GPU (§III-B): every thread block sweeps the adjacency of the frontier
and each vertex pulls the minimum label of its neighborhood. On TPU the
same insight maps to a dense tiled reduction over the adjacency matrix:

* the HBM↔VMEM schedule that CUDA expressed with thread blocks becomes a
  ``BlockSpec`` grid of (row-tile, col-tile) steps;
* the per-block shared-memory staging becomes the VMEM-resident
  ``(TILE, TILE)`` blocks;
* the warp-level min-reduction becomes an 8×128-lane vectorized
  ``min`` over the tile columns.

Grid iteration order is row-major with the column dimension innermost, so
each output row tile stays resident while the column tiles stream
through — the classic output-stationary schedule.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU performance is *estimated* in DESIGN.md from the
VMEM footprint (3 tiles × 64 KiB ≪ 16 MiB) instead of measured.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Tile edge. 128 matches both the TPU lane width and the MXU systolic
#: array edge; every AOT size class (128..1024) divides evenly.
TILE = 128

#: Label sentinel as a Python float: a `jnp` constant would be captured
#: by the kernel closure, which pallas_call rejects.
INF = float(2**30)


def _label_prop_kernel(a_ref, lab_col_ref, lab_row_ref, o_ref):
    """One (row-tile, col-tile) grid step.

    o[i] accumulates min(own label, min over neighbor labels in this
    column tile). The first column step seeds the accumulator with the
    row's own labels, making the outer ``minimum`` in the model a no-op.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _seed():
        o_ref[...] = lab_row_ref[...]

    a = a_ref[...]  # (TILE, TILE) adjacency block
    lab = lab_col_ref[...]  # (TILE,) labels of this column tile
    cand = jnp.where(a > 0, lab[None, :], INF).min(axis=1)
    o_ref[...] = jnp.minimum(o_ref[...], cand)


@functools.partial(jax.jit, static_argnames=("tile",))
def label_prop_step(a, labels, *, tile=TILE):
    """One min-label propagation step over the dense adjacency ``a``.

    Exactly ``ref.label_prop_step_ref`` (including the self minimum).
    """
    n = a.shape[0]
    assert a.shape == (n, n) and labels.shape == (n,)
    assert n % tile == 0, f"n={n} must be a multiple of the {tile} tile"
    grid = (n // tile, n // tile)
    return pl.pallas_call(
        _label_prop_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j: (i, j)),  # A block
            pl.BlockSpec((tile,), lambda i, j: (j,)),  # labels (col)
            pl.BlockSpec((tile,), lambda i, j: (i,)),  # labels (row)
        ],
        out_specs=pl.BlockSpec((tile,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(a, labels, labels)
