"""Pure-jnp reference oracles for the Pallas kernels (Layer 1).

Every kernel in this package is validated against these references by
``python/tests/test_kernels.py`` (hypothesis sweeps over shapes and random
adjacency matrices). The references are deliberately written in the most
obvious vectorized form — no tiling, no tricks — so a disagreement always
points at the kernel.

All functions take a dense symmetric 0/1 adjacency matrix ``a`` of shape
(n, n) with a zero diagonal, in float32.
"""

import jax.numpy as jnp

#: Sentinel larger than any vertex label.
INF = jnp.float32(2**30)


def label_prop_step_ref(a, labels):
    """One min-label propagation step.

    new[i] = min(labels[i], min_{j : a[i,j]=1} labels[j])
    """
    neighbor = jnp.where(a > 0, labels[None, :], INF).min(axis=1)
    return jnp.minimum(labels, neighbor)


def bfs_expand_ref(a, frontier):
    """Raw frontier expansion counts: (A @ f). Callers threshold."""
    return a @ frontier


def bfs_step_ref(a, frontier, visited):
    """One BFS step: the next frontier and the updated visited mask."""
    reached = (a @ frontier) > 0
    new_frontier = jnp.logical_and(reached, jnp.logical_not(visited > 0))
    new_frontier = new_frontier.astype(jnp.float32)
    return new_frontier, jnp.clip(visited + new_frontier, 0.0, 1.0)


def triangle_rowsum_ref(a):
    """Row sums of (A @ A) ⊙ A. Equals 2 × (triangles through vertex i)."""
    return ((a @ a) * a).sum(axis=1)


def connected_components_ref(a):
    """Component labels: smallest vertex index in each component."""
    n = a.shape[0]
    labels = jnp.arange(n, dtype=jnp.float32)
    # n iterations always suffice (longest shortest path < n)
    for _ in range(n):
        labels = label_prop_step_ref(a, labels)
    return labels


def bfs_reach_ref(a, seed):
    """Reachability mask from a 0/1 seed vector."""
    visited = seed.astype(jnp.float32)
    frontier = visited
    for _ in range(a.shape[0]):
        frontier, visited = bfs_step_ref(a, frontier, visited)
    return visited
