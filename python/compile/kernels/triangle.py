"""Pallas kernel: fused triangle census (Layer 1).

Per-vertex triangle membership is ``rowsum((A @ A) ⊙ A) / 2``. A naive
XLA lowering materializes the (n, n) product; this kernel fuses the
product, the elementwise mask, and the row reduction inside one grid
step, so the (TILE, TILE) product block never leaves VMEM:

    t[i] += Σ_j  ( Σ_k A[i,k]·A[k,j] ) · A[i,j]      for j in tile J

The contraction feeds the MXU with (TILE, n)·(n, TILE) panels — the K
dimension is kept unblocked (n ≤ 1024 ⇒ 128×1024 f32 panel = 512 KiB,
comfortably VMEM-resident next to its transpose panel).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 128


def _triangle_kernel(a_rows_ref, a_cols_ref, a_ij_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    # (TILE, n) @ (n, TILE) on the MXU, masked and row-reduced in VMEM.
    c = a_rows_ref[...] @ a_cols_ref[...]
    o_ref[...] += (c * a_ij_ref[...]).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("tile",))
def triangle_rowsum(a, *, tile=TILE):
    """Row sums of ``(A @ A) ⊙ A`` (= 2 × triangles per vertex).

    Matches ``ref.triangle_rowsum_ref``.
    """
    n = a.shape[0]
    assert a.shape == (n, n)
    assert n % tile == 0, f"n={n} must be a multiple of the {tile} tile"
    grid = (n // tile, n // tile)
    return pl.pallas_call(
        _triangle_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, n), lambda i, j: (i, 0)),  # A row panel
            pl.BlockSpec((n, tile), lambda i, j: (0, j)),  # A col panel
            pl.BlockSpec((tile, tile), lambda i, j: (i, j)),  # A mask block
        ],
        out_specs=pl.BlockSpec((tile,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(a, a, a)
