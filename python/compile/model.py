"""Layer 2: JAX fixpoint programs over the Pallas kernels.

These are the compute graphs that get AOT-lowered to HLO by ``aot.py``
and executed from the Rust runtime via PJRT. Each is a
``lax.while_loop`` fixpoint with an early-exit condition, so the lowered
module contains a genuine HLO while loop (no per-iteration host sync, no
unrolling blowup) around the Layer-1 kernels.

Inputs are dense symmetric 0/1 f32 adjacency matrices padded to the AOT
size class; padding vertices are isolated, which every fixpoint here
treats as its own trivial component, so padding never changes results
for the real vertices.
"""

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.bfs_step import bfs_expand
from .kernels.label_prop import label_prop_step
from .kernels.triangle import triangle_rowsum


def connected_components(a):
    """Component labels: the smallest vertex index in each component.

    Min-label propagation to a fixpoint. Converges in at most
    diameter+1 steps; the while loop exits as soon as a step changes
    nothing.
    """
    n = a.shape[0]
    init_labels = jnp.arange(n, dtype=jnp.float32)

    def cond(state):
        _, changed = state
        return changed

    def body(state):
        labels, _ = state
        new = label_prop_step(a, labels)
        return new, jnp.any(new != labels)

    labels, _ = lax.while_loop(cond, body, (init_labels, jnp.bool_(True)))
    return (labels,)


def bfs_reach(a, seed):
    """Reachability mask (0/1 f32) from a 0/1 seed vector."""

    def cond(state):
        frontier, _ = state
        return jnp.sum(frontier) > 0

    def body(state):
        frontier, visited = state
        reached = bfs_expand(a, frontier) > 0
        new_frontier = jnp.logical_and(reached, visited == 0).astype(jnp.float32)
        return new_frontier, jnp.clip(visited + new_frontier, 0.0, 1.0)

    seed = seed.astype(jnp.float32)
    _, visited = lax.while_loop(cond, body, (seed, seed))
    return (visited,)


def triangle_census(a):
    """Row sums of (A@A)⊙A — 2 × per-vertex triangle counts."""
    return (triangle_rowsum(a),)


#: Program registry: artifact stem → (fn, input_spec_builder).
#: Must stay in sync with `rust/src/runtime/artifacts.rs`.
def _adj_spec(n):
    return (jax.ShapeDtypeStruct((n, n), jnp.float32),)


def _adj_seed_spec(n):
    return (
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.float32),
    )


PROGRAMS = {
    "components": (connected_components, _adj_spec),
    "bfs_reach": (bfs_reach, _adj_seed_spec),
    "triangle_census": (triangle_census, _adj_spec),
}

#: AOT size classes (must match `rust/src/runtime/artifacts.rs`).
SIZE_CLASSES = (128, 256, 512, 1024)
