"""AOT path: every program lowers to parseable HLO text with the
structure the Rust runtime expects (tuple root, while loop present)."""

import pathlib

import pytest

from compile import aot, model


@pytest.mark.parametrize("name", sorted(model.PROGRAMS))
def test_lowering_produces_hlo_text(name):
    text = aot.lower_program(name, 128)
    assert "HloModule" in text
    assert "ROOT" in text
    # lowered with return_tuple=True → root is a tuple
    assert "tuple(" in text or "tuple " in text


@pytest.mark.parametrize("name", ["components", "bfs_reach"])
def test_fixpoints_lower_to_while_loops(name):
    # early-exit fixpoints must be genuine HLO while loops, not unrolled
    text = aot.lower_program(name, 128)
    assert "while(" in text or "while " in text


def test_build_all_writes_every_artifact(tmp_path: pathlib.Path):
    written = aot.build_all(tmp_path, sizes=(128,))
    names = {p.name for p in written}
    assert names == {
        "components_128.hlo.txt",
        "bfs_reach_128.hlo.txt",
        "triangle_census_128.hlo.txt",
    }
    for p in written:
        assert p.stat().st_size > 200


def test_size_classes_match_rust_runtime():
    # keep in sync with rust/src/runtime/artifacts.rs::SIZE_CLASSES
    rust = pathlib.Path(__file__).resolve().parents[2] / "rust/src/runtime/artifacts.rs"
    src = rust.read_text()
    assert "[128, 256, 512, 1024]" in src
    assert model.SIZE_CLASSES == (128, 256, 512, 1024)
    # artifact stems too
    for stem in model.PROGRAMS:
        assert f'"{stem}"' in src, f"stem {stem} missing from artifacts.rs"
