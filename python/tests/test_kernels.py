"""Layer-1 correctness: Pallas kernels vs the pure-jnp references.

Hypothesis sweeps random adjacency matrices (several densities, all AOT
tile multiples) and asserts exact agreement — these kernels are integer
computations carried in f32, so there is no tolerance to hide behind.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bfs_step import bfs_expand, bfs_step
from compile.kernels.label_prop import label_prop_step
from compile.kernels.triangle import triangle_rowsum

# Small tile so hypothesis can sweep multiple grid shapes quickly; the
# AOT path uses TILE=128 and is covered by test_aot/test_model.
TILE = 8


def random_adjacency(n: int, density: float, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < density).astype(np.float32)
    a = np.triu(a, 1)
    return a + a.T


adj_params = st.tuples(
    st.integers(1, 6),  # grid multiplier → n = TILE * m
    st.floats(0.0, 0.6),  # density
    st.integers(0, 2**32 - 1),  # seed
)


@settings(max_examples=40, deadline=None)
@given(adj_params)
def test_label_prop_matches_ref(params):
    m, density, seed = params
    n = TILE * m
    a = jnp.asarray(random_adjacency(n, density, seed))
    labels = jnp.asarray(np.random.default_rng(seed ^ 1).permutation(n).astype(np.float32))
    got = label_prop_step(a, labels, tile=TILE)
    want = ref.label_prop_step_ref(a, labels)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=40, deadline=None)
@given(adj_params)
def test_bfs_expand_matches_ref(params):
    m, density, seed = params
    n = TILE * m
    a = jnp.asarray(random_adjacency(n, density, seed))
    f = jnp.asarray((np.random.default_rng(seed ^ 2).random(n) < 0.3).astype(np.float32))
    got = bfs_expand(a, f, tile=TILE)
    want = ref.bfs_expand_ref(a, f)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=30, deadline=None)
@given(adj_params)
def test_triangle_matches_ref(params):
    m, density, seed = params
    n = TILE * m
    a = jnp.asarray(random_adjacency(n, density, seed))
    got = triangle_rowsum(a, tile=TILE)
    want = ref.triangle_rowsum_ref(a)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bfs_step_composes():
    a = jnp.asarray(random_adjacency(TILE, 0.1, 7))
    seed_vec = np.zeros(TILE, dtype=np.float32)
    seed_vec[0] = 1.0
    f = jnp.asarray(seed_vec)
    v = jnp.asarray(seed_vec)
    for _ in range(3):
        # interpret-mode kernel path vs reference step
        (f1, v1) = bfs_step(a, f, v, tile=TILE)
        (f2, v2) = ref.bfs_step_ref(a, f, v)
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        f, v = f1, v1


def test_label_prop_converges_to_components():
    # two cliques: labels converge to the min id of each clique
    n = TILE
    a = np.zeros((n, n), dtype=np.float32)
    half = n // 2
    a[:half, :half] = 1.0
    a[half:, half:] = 1.0
    np.fill_diagonal(a, 0.0)
    labels = jnp.arange(n, dtype=jnp.float32)
    a = jnp.asarray(a)
    for _ in range(3):
        labels = label_prop_step(a, labels, tile=TILE)
    got = np.asarray(labels)
    assert (got[:half] == 0).all()
    assert (got[half:] == half).all()


def test_triangle_on_known_graph():
    # K4 embedded in a padded tile: every K4 vertex is in 3 triangles
    n = TILE
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(4):
        for j in range(4):
            if i != j:
                a[i, j] = 1.0
    got = np.asarray(triangle_rowsum(jnp.asarray(a), tile=TILE))
    np.testing.assert_array_equal(got[:4], np.full(4, 6.0))  # 2 × 3
    np.testing.assert_array_equal(got[4:], np.zeros(n - 4))


def test_tile_divisibility_enforced():
    a = jnp.zeros((TILE + 1, TILE + 1), dtype=jnp.float32)
    with pytest.raises(AssertionError):
        label_prop_step(a, jnp.zeros(TILE + 1), tile=TILE)


def test_default_tile_is_128():
    from compile.kernels import bfs_step as m1, label_prop as m2, triangle as m3

    assert m1.TILE == m2.TILE == m3.TILE == 128
