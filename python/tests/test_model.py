"""Layer-2 correctness: the while-loop fixpoints vs references and
against networkx-free hand-built graphs."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

N = 128  # smallest AOT size class


def adjacency_from_edges(n, edges):
    a = np.zeros((n, n), dtype=np.float32)
    for u, v in edges:
        a[u, v] = 1.0
        a[v, u] = 1.0
    return jnp.asarray(a)


def cc_labels_numpy(n, edges):
    """Union-find ground truth: smallest vertex id per component."""
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in edges:
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[max(ru, rv)] = min(ru, rv)
    return np.array([find(v) for v in range(n)], dtype=np.float32)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1), st.floats(0.0, 0.06))
def test_connected_components_matches_union_find(seed, density):
    rng = np.random.default_rng(seed)
    mask = np.triu(rng.random((N, N)) < density, 1)
    edges = [(int(u), int(v)) for u, v in zip(*np.nonzero(mask))]
    a = adjacency_from_edges(N, edges)
    (labels,) = model.connected_components(a)
    np.testing.assert_array_equal(np.asarray(labels), cc_labels_numpy(N, edges))


def test_components_on_path_and_cliques():
    edges = [(i, i + 1) for i in range(9)]  # path on 0..9
    edges += [(20 + i, 20 + j) for i in range(5) for j in range(i + 1, 5)]  # K5
    a = adjacency_from_edges(N, edges)
    (labels,) = model.connected_components(a)
    got = np.asarray(labels)
    assert (got[:10] == 0).all()
    assert (got[20:25] == 20).all()
    # isolated padding vertices keep their own ids
    assert got[50] == 50


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_bfs_reach_matches_ref(seed):
    rng = np.random.default_rng(seed)
    mask = np.triu(rng.random((N, N)) < 0.03, 1)
    a = jnp.asarray((mask + mask.T).astype(np.float32))
    seed_vec = np.zeros(N, dtype=np.float32)
    seed_vec[int(rng.integers(N))] = 1.0
    (visited,) = model.bfs_reach(a, jnp.asarray(seed_vec))
    want = ref.bfs_reach_ref(a, jnp.asarray(seed_vec))
    np.testing.assert_array_equal(np.asarray(visited), np.asarray(want))


def test_bfs_reach_two_components():
    edges = [(0, 1), (1, 2), (5, 6)]
    a = adjacency_from_edges(N, edges)
    s = np.zeros(N, dtype=np.float32)
    s[0] = 1.0
    (visited,) = model.bfs_reach(a, jnp.asarray(s))
    got = np.asarray(visited)
    assert got[0] == got[1] == got[2] == 1.0
    assert got[5] == got[6] == 0.0


def test_triangle_census_known():
    edges = [(0, 1), (1, 2), (0, 2), (2, 3)]  # one triangle + a tail
    a = adjacency_from_edges(N, edges)
    (t,) = model.triangle_census(a)
    got = np.asarray(t)
    assert got[0] == got[1] == got[2] == 2.0  # 2 × 1 triangle
    assert got[3] == 0.0


def test_program_registry_is_complete():
    assert set(model.PROGRAMS) == {"components", "bfs_reach", "triangle_census"}
    assert model.SIZE_CLASSES == (128, 256, 512, 1024)
    for _, (fn, spec) in model.PROGRAMS.items():
        out = fn(*(jnp.zeros(s.shape, s.dtype) for s in spec(N)))
        assert isinstance(out, tuple) and len(out) == 1
