//! Self-tuning controller convergence smoke: a mixed batch (component-
//! rich unions plus denser single components) run once per fixed knob
//! setting — owned frames, and delta frames across a pin-depth ×
//! induction grid, all with the controller pinned off — and then on a
//! service with the controller live and every knob at its default.
//!
//! Every configuration must produce identical objectives (the knobs are
//! performance levers, never correctness levers); the controller row
//! additionally reports its convergence trajectory (epochs, flips,
//! converged-at epoch, final pin depth and delta-bucket mask). Results
//! go to stdout and `bench_out/autotune.csv`. `CAVC_SMOKE=1` shrinks
//! the batch and the grid for the CI smoke job — trajectory only, no
//! wall-clock threshold: these graphs are small enough that ratios are
//! noisy in shared CI runners.

use cavc::graph::{generators, Graph};
use cavc::solver::{NodeRepr, Problem, SolverConfig, VcService};
use std::time::Instant;

/// Mixed deterministic batch: component-rich unions (many small induced
/// components per job — induction and memo traffic) interleaved with
/// denser single components (genuine branching — repr and pin-depth
/// traffic).
fn batch(n: usize) -> Vec<Graph> {
    (0..n)
        .map(|i| {
            let seed = 0xA070_0000 + (i % 8) as u64;
            if i % 3 == 0 {
                generators::erdos_renyi(20, 0.25, seed)
            } else {
                generators::union_of_random(4, 4, 9, 0.35, seed)
            }
        })
        .collect()
}

fn run_pass(svc: &VcService, graphs: &[Graph]) -> (Vec<u32>, f64, u64) {
    let t = Instant::now();
    let handles: Vec<_> = graphs.iter().map(|g| svc.submit(Problem::mvc(g.clone()))).collect();
    let mut answers = Vec::with_capacity(handles.len());
    let mut nodes = 0u64;
    for h in handles {
        let sol = h.wait();
        nodes += sol.stats.tree_nodes;
        answers.push(sol.objective);
    }
    (answers, t.elapsed().as_secs_f64(), nodes)
}

struct Fixed {
    label: &'static str,
    repr: NodeRepr,
    pin: u32,
    induce: f64,
}

const GRID: &[Fixed] = &[
    Fixed { label: "owned", repr: NodeRepr::Owned, pin: 24, induce: 0.5 },
    Fixed { label: "delta-pin8", repr: NodeRepr::Delta, pin: 8, induce: 0.5 },
    Fixed { label: "delta-pin24", repr: NodeRepr::Delta, pin: 24, induce: 0.5 },
    Fixed { label: "delta-pin64", repr: NodeRepr::Delta, pin: 64, induce: 0.5 },
    Fixed { label: "delta-noinduce", repr: NodeRepr::Delta, pin: 24, induce: 0.0 },
    Fixed { label: "delta-induce1", repr: NodeRepr::Delta, pin: 24, induce: 1.0 },
];
const SMOKE_GRID: &[Fixed] = &[
    Fixed { label: "owned", repr: NodeRepr::Owned, pin: 24, induce: 0.5 },
    Fixed { label: "delta-pin24", repr: NodeRepr::Delta, pin: 24, induce: 0.5 },
];

fn main() {
    let smoke = std::env::var("CAVC_SMOKE").is_ok();
    let n = if smoke { 24 } else { 96 };
    let passes = if smoke { 2 } else { 4 };
    let grid = if smoke { SMOKE_GRID } else { GRID };
    let graphs = batch(n);
    let workers = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4);
    println!(
        "# autotune convergence — {n} mixed graphs x {passes} passes, {workers} workers, \
         {} fixed settings vs controller",
        grid.len()
    );
    println!(
        "{:<16} {:>10} {:>10} {:>12} {:>8} {:>6} {:>10}",
        "config", "secs", "jobs/s", "tree nodes", "epochs", "flips", "converged"
    );

    let mut rows: Vec<String> = Vec::new();
    let mut reference: Option<Vec<u32>> = None;
    fn check(reference: &mut Option<Vec<u32>>, label: &str, answers: &[u32]) {
        match reference {
            Some(r) => {
                assert_eq!(r.as_slice(), answers, "{label}: objectives diverge from the reference")
            }
            None => *reference = Some(answers.to_vec()),
        }
    }

    for f in grid {
        let cfg = SolverConfig::proposed()
            .with_node_repr(f.repr)
            .with_max_pin_depth(f.pin)
            .with_induce_threshold(f.induce);
        let svc = VcService::builder().config(cfg).workers(workers).autotune(false).build();
        let mut secs = 0.0;
        let mut nodes = 0u64;
        for _ in 0..passes {
            let (answers, s, tn) = run_pass(&svc, &graphs);
            check(&mut reference, f.label, &answers);
            secs += s;
            nodes += tn;
        }
        let rate = (n * passes) as f64 / secs.max(1e-9);
        println!(
            "{:<16} {:>10.4} {:>10.1} {:>12} {:>8} {:>6} {:>10}",
            f.label, secs, rate, nodes, "-", "-", "-"
        );
        rows.push(format!("{},{n},{passes},{workers},{secs},{rate},{nodes},0,0,0", f.label));
    }

    // The controller: every knob at its default, decisions live. Passes
    // after the first run against whatever it has learned so far.
    let svc = VcService::builder().workers(workers).autotune(true).build();
    let mut secs = 0.0;
    let mut nodes = 0u64;
    for _ in 0..passes {
        let (answers, s, tn) = run_pass(&svc, &graphs);
        check(&mut reference, "controller", &answers);
        secs += s;
        nodes += tn;
    }
    let a = svc.stats().autotune;
    assert!(a.enabled, "controller service must report the tuner enabled");
    let rate = (n * passes) as f64 / secs.max(1e-9);
    println!(
        "{:<16} {:>10.4} {:>10.1} {:>12} {:>8} {:>6} {:>10}",
        "controller", secs, rate, nodes, a.epochs, a.flips, a.converged_epoch
    );
    println!(
        "controller state: pin-depth {}, delta-buckets {:#010b}, steal {} ppm, \
         repr decisions {} owned / {} delta, induce {} pass / {} block",
        a.pin_depth,
        a.delta_buckets,
        a.steal_rate_ppm,
        a.decisions_owned,
        a.decisions_delta,
        a.induce_pass,
        a.induce_block
    );
    rows.push(format!(
        "controller,{n},{passes},{workers},{secs},{rate},{nodes},{},{},{}",
        a.epochs, a.flips, a.converged_epoch
    ));

    let header =
        "config,jobs,passes,workers,secs,jobs_per_s,tree_nodes,epochs,flips,converged_epoch";
    match cavc::harness::tables::write_csv("autotune", header, &rows) {
        Ok(path) => println!("csv: {}", path.display()),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
