//! Graceful degradation: throughput and small-job latency of one
//! resident service driving an over-budget job mix, memory watchdog on
//! vs off.
//!
//! The mix is a batch of medium throughput-lane MVC jobs (the ledger
//! pressure) plus a serial stream of small latency-lane jobs (the
//! latency probes). Two modes on identical traffic:
//!
//! * `watchdog-off` — the default limits (far above what the mix ever
//!   charges): every job dispatches immediately and runs concurrently;
//! * `watchdog-on`  — a 1-byte soft limit, so the service is over
//!   budget whenever any job is live: throughput-lane dispatch is held
//!   until the ledger drains (jobs serialize) and new jobs are forced
//!   onto the delta node representation. Latency-lane probes bypass the
//!   gate by design.
//!
//! Degradation must change *when* work runs, never what it computes:
//! both modes are asserted to produce identical, oracle-exact answers.
//! Results go to stdout and `bench_out/degradation.csv`. `CAVC_SMOKE=1`
//! shrinks the mix for the CI smoke job (trajectory only, no
//! thresholds).

use cavc::graph::{generators, Graph};
use cavc::solver::{oracle, JobOptions, Lane, Problem, Termination, VcService};
use std::time::Instant;

/// Medium jobs: enough search to keep the ledger charged.
fn medium_mix(n: usize) -> Vec<Graph> {
    (0..n).map(|i| generators::erdos_renyi(36, 0.15, 0xD15C_0000 + i as u64)).collect()
}

/// Small latency probes (oracle-checkable).
fn probe_mix(n: usize) -> Vec<Graph> {
    (0..n).map(|i| generators::erdos_renyi(15, 0.22, 0xBEEF_0000 + i as u64)).collect()
}

fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    let idx = ((p / 100.0) * (sorted_ms.len() - 1) as f64).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

/// One mode: submit the medium batch up front, then stream the probes,
/// then wait out the batch. Returns (wall seconds, probe latencies in
/// ms, medium answers, probe answers).
fn run_mode(
    medium: &[Graph],
    probes: &[Graph],
    workers: usize,
    watchdog: bool,
) -> (f64, Vec<f64>, Vec<u32>, Vec<u32>) {
    let mut b = VcService::builder().workers(workers);
    if watchdog {
        // 1 byte: over the soft limit whenever anything is live, so the
        // run exercises the held-dispatch + forced-delta degraded mode.
        b = b.mem_soft(1);
    }
    let svc = b.build();
    let t0 = Instant::now();
    let handles: Vec<_> = medium
        .iter()
        .map(|g| {
            svc.submit_with(
                Problem::mvc(g.clone()),
                JobOptions { priority: Some(Lane::Throughput), ..JobOptions::default() },
            )
        })
        .collect();
    let mut lat_ms = Vec::with_capacity(probes.len());
    let mut probe_ans = Vec::with_capacity(probes.len());
    for g in probes {
        let t = Instant::now();
        let h = svc.submit_with(
            Problem::mvc(g.clone()),
            JobOptions { priority: Some(Lane::Latency), ..JobOptions::default() },
        );
        let sol = h.wait();
        assert_eq!(sol.termination, Termination::Complete);
        lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
        probe_ans.push(sol.objective);
    }
    let medium_ans: Vec<u32> = handles
        .iter()
        .map(|h| {
            let sol = h.wait();
            assert_eq!(sol.termination, Termination::Complete);
            sol.objective
        })
        .collect();
    (t0.elapsed().as_secs_f64(), lat_ms, medium_ans, probe_ans)
}

fn main() {
    let smoke = std::env::var("CAVC_SMOKE").is_ok();
    let (n_medium, n_probe) = if smoke { (6, 10) } else { (24, 60) };
    let workers = 3;
    let medium = medium_mix(n_medium);
    let probes = probe_mix(n_probe);
    let probe_expect: Vec<u32> = probes.iter().map(oracle::mvc_size).collect();
    println!(
        "# degradation — {n_medium} medium + {n_probe} probe jobs, {workers} workers, watchdog on vs off"
    );

    let mut rows = Vec::new();
    let mut answers: Vec<(Vec<u32>, Vec<u32>)> = Vec::new();
    println!(
        "{:<14} {:>9} {:>10} {:>10} {:>10}",
        "mode", "wall s", "jobs/s", "p50 ms", "p99 ms"
    );
    for (mode, watchdog) in [("watchdog-off", false), ("watchdog-on", true)] {
        let (wall, lat_ms, med_ans, probe_ans) = run_mode(&medium, &probes, workers, watchdog);
        assert_eq!(probe_ans, probe_expect, "{mode}: probe answers must be oracle-exact");
        let mut s = lat_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = percentile(&s, 50.0);
        let p99 = percentile(&s, 99.0);
        let jobs_s = (n_medium + n_probe) as f64 / wall.max(1e-9);
        println!("{mode:<14} {wall:>9.3} {jobs_s:>10.1} {p50:>10.3} {p99:>10.3}");
        rows.push(format!("{mode},{},{workers},{wall},{jobs_s},{p50},{p99}", n_medium + n_probe));
        answers.push((med_ans, probe_ans));
    }
    assert_eq!(
        answers[0], answers[1],
        "degradation changed an answer — it may only change scheduling"
    );

    let header = "mode,jobs,workers,wall_s,jobs_per_s,p50_ms,p99_ms";
    match cavc::harness::tables::write_csv("degradation", header, &rows) {
        Ok(path) => println!("csv: {}", path.display()),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
