//! Figure 4: breakdown of the proposed solver's execution time across
//! activities (reduction rules, component search, branching,
//! stack/worklist, stopping/leaf), normalized per worker as the paper
//! normalizes per thread block.

use cavc::harness::{datasets, tables};
use cavc::util::timer::{Activity, ALL_ACTIVITIES};

fn main() {
    let suite = if std::env::var("CAVC_SUITE").as_deref() == Ok("smoke") {
        datasets::smoke_suite()
    } else {
        datasets::suite()
    };
    println!(
        "# Figure 4 — activity breakdown (% of busy time), budget {}s/run",
        tables::cell_timeout().as_secs_f64()
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for d in &suite {
        eprintln!("[fig4] {} ...", d.name);
        let row = tables::fig4_row(d);
        let vals: Vec<String> = ALL_ACTIVITIES
            .iter()
            .filter(|a| **a != Activity::Idle)
            .map(|a| format!("{:.4}", row.fractions[*a as usize]))
            .collect();
        csv.push(format!("{},{}", row.name, vals.join(",")));
        rows.push(row);
    }
    tables::print_fig4(&rows, std::io::stdout().lock()).unwrap();
    println!("\n# per-worker scheduler counters (push/pop/steal/retry)");
    tables::print_fig4_sched(&rows, std::io::stdout().lock()).unwrap();
    let path = tables::write_csv(
        "fig4_breakdown",
        "graph,reduce,component_search,branch,queue,leaf",
        &csv,
    )
    .unwrap();
    let sched_csv: Vec<String> = rows
        .iter()
        .flat_map(|r| {
            r.sched_workers.iter().enumerate().map(move |(w, c)| {
                format!(
                    "{},{},{w},{},{},{},{},{},{}",
                    r.name,
                    r.scheduler.name(),
                    c.pushes,
                    c.pops,
                    c.shared_pops,
                    c.steals,
                    c.steal_retries,
                    c.max_depth
                )
            })
        })
        .collect();
    let sched_path = tables::write_csv(
        "fig4_sched_counters",
        "graph,scheduler,worker,pushes,pops,shared_pops,steals,steal_retries,max_depth",
        &sched_csv,
    )
    .unwrap();

    // Witness-cost companion pass: the same suite with extraction on,
    // so the choice-log memory cost sits next to the bytes-per-node
    // telemetry of the breakdown runs.
    println!("\n# witness extraction cost (choice logs vs node payloads)");
    let mut wrows = Vec::new();
    for d in &suite {
        eprintln!("[fig4:witness] {} ...", d.name);
        wrows.push(tables::witness_cost_row(d));
    }
    tables::print_witness_cost(&wrows, std::io::stdout().lock()).unwrap();
    let witness_csv: Vec<String> = wrows
        .iter()
        .map(|r| {
            format!(
                "{},{},{},{},{},{},{}",
                r.name,
                r.best,
                r.verified,
                r.witness_log_bytes,
                r.logs_recycled,
                r.payload_bytes,
                r.payload_nodes
            )
        })
        .collect();
    let witness_path = tables::write_csv(
        "fig4_witness_cost",
        "graph,mvc,verified,witness_log_bytes,logs_recycled,payload_bytes,payload_nodes",
        &witness_csv,
    )
    .unwrap();

    println!("\ncsv: {}", path.display());
    println!("csv: {}", sched_path.display());
    println!("csv: {}", witness_path.display());
}
