//! §III-A effective-branching-factor model: β_e ≈ β^(1−ρη).
//!
//! Measures ρ (fraction of internal nodes that split) and the node-count
//! reduction on the suite, and compares against the paper's analytic
//! model — the reproduction of the paper's worked example
//! (β=1.5, ρ=0.02, η=0.5, n=200 → ≈2.25× fewer nodes).

use cavc::harness::{datasets, tables};
use cavc::solver::{solve_mvc, SolverConfig};

fn main() {
    println!("# §III-A — effective branching factor model vs measurement");
    // the paper's worked example
    let beta: f64 = 1.5;
    let rho = 0.02;
    let eta = 0.5;
    let beta_e = beta.powf(1.0 - rho * eta);
    let n = 200.0;
    println!(
        "paper example: beta={beta}, rho={rho}, eta={eta} -> beta_e={beta_e:.4}, \
         node ratio at n=200: {:.2}x (paper: ~2.25x)",
        (beta / beta_e).powf(n)
    );
    println!();
    println!(
        "| {:<22} | {:>9} | {:>12} | {:>12} | {:>9} |",
        "Graph", "rho", "nodes w/o", "nodes w/", "reduction"
    );
    println!("|{}|", "-".repeat(78));
    let mut csv = Vec::new();
    for d in datasets::smoke_suite() {
        let g = d.build();
        let mut prop = SolverConfig::proposed();
        prop.timeout = Some(tables::cell_timeout());
        let with = solve_mvc(&g, &prop);
        let mut off = SolverConfig::proposed();
        off.component_aware = false;
        off.timeout = Some(tables::cell_timeout());
        let without = solve_mvc(&g, &off);
        let rho_measured =
            with.stats.component_branches as f64 / with.stats.tree_nodes.max(1) as f64;
        let reduction = without.stats.tree_nodes as f64 / with.stats.tree_nodes.max(1) as f64;
        println!(
            "| {:<22} | {:>8.4} | {:>11}{} | {:>12} | {:>8.2}x |",
            d.name,
            rho_measured,
            without.stats.tree_nodes,
            if without.timed_out { "+" } else { " " },
            with.stats.tree_nodes,
            reduction,
        );
        csv.push(format!(
            "{},{:.6},{},{},{},{:.4}",
            d.name,
            rho_measured,
            without.stats.tree_nodes,
            without.timed_out,
            with.stats.tree_nodes,
            reduction
        ));
    }
    let path = tables::write_csv(
        "fig_beta_model",
        "graph,rho,nodes_without,without_timed_out,nodes_with,reduction",
        &csv,
    )
    .unwrap();
    println!("\ncsv: {}", path.display());
}
