//! Memo-cache throughput: a component-rich batch run cold (empty cache)
//! and warm (identical resubmission on the same resident service).
//!
//! The workload is a deterministic set of union-of-random graphs — many
//! small induced components per job, repeated across jobs — which is
//! exactly the traffic shape the cross-job memo cache targets: the warm
//! pass should answer most component dispatches from the cache instead
//! of re-searching their subtrees. Both passes must produce identical
//! objectives; the warm pass must actually hit. Results go to stdout
//! and `bench_out/memo_throughput.csv`. `CAVC_SMOKE=1` shrinks the
//! batch for the CI smoke job (trajectory only, no speedup threshold —
//! these graphs are small enough that wall-clock ratios are noisy in
//! shared CI runners; the hit-rate column is the load-bearing signal).

use cavc::graph::{generators, Graph};
use cavc::solver::{Problem, VcService};
use std::time::Instant;

/// Component-rich deterministic batch: unions of small random parts,
/// with seeds reused across the batch so distinct jobs share component
/// structure even before resubmission.
fn batch(n: usize) -> Vec<Graph> {
    (0..n)
        .map(|i| {
            let seed = 0x5EED_0000 + (i % 8) as u64;
            generators::union_of_random(4, 4, 9, 0.35, seed)
        })
        .collect()
}

fn run_pass(svc: &VcService, graphs: &[Graph]) -> (Vec<u32>, f64) {
    let t = Instant::now();
    let handles: Vec<_> = graphs.iter().map(|g| svc.submit(Problem::mvc(g.clone()))).collect();
    let answers: Vec<u32> = handles.iter().map(|h| h.wait().objective).collect();
    (answers, t.elapsed().as_secs_f64())
}

fn main() {
    let smoke = std::env::var("CAVC_SMOKE").is_ok();
    let n = if smoke { 24 } else { 120 };
    let graphs = batch(n);
    let workers = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4);
    println!("# memo throughput — {n} component-rich graphs, {workers} workers");

    let svc = VcService::builder().workers(workers).build();
    let (cold, cold_s) = run_pass(&svc, &graphs);
    let cold_stats = svc.stats().memo;
    let (warm, warm_s) = run_pass(&svc, &graphs);
    let warm_stats = svc.stats().memo;

    assert_eq!(cold, warm, "warm pass must reproduce the cold answers");
    let warm_hits = warm_stats.hits - cold_stats.hits;
    let warm_lookups = warm_stats.lookups - cold_stats.lookups;
    assert!(warm_hits > 0, "warm resubmission must hit the cache");

    let rate = |h: u64, l: u64| h as f64 / (l as f64).max(1.0);
    println!("{:<12} {:>10} {:>10} {:>10} {:>10}", "pass", "secs", "lookups", "hits", "hit_rate");
    println!(
        "{:<12} {:>10.4} {:>10} {:>10} {:>10.3}",
        "cold",
        cold_s,
        cold_stats.lookups,
        cold_stats.hits,
        rate(cold_stats.hits, cold_stats.lookups)
    );
    println!(
        "{:<12} {:>10.4} {:>10} {:>10} {:>10.3}",
        "warm",
        warm_s,
        warm_lookups,
        warm_hits,
        rate(warm_hits, warm_lookups)
    );
    println!(
        "warm vs cold: {:.2}x wall, {} subtree nodes saved, {} bytes held",
        cold_s / warm_s.max(1e-12),
        warm_stats.saved_nodes,
        warm_stats.bytes
    );

    let rows = vec![
        format!(
            "cold,{n},{workers},{cold_s},{},{},{}",
            cold_stats.lookups,
            cold_stats.hits,
            rate(cold_stats.hits, cold_stats.lookups)
        ),
        format!(
            "warm,{n},{workers},{warm_s},{warm_lookups},{warm_hits},{}",
            rate(warm_hits, warm_lookups)
        ),
    ];
    let header = "pass,jobs,workers,secs,lookups,hits,hit_rate";
    match cavc::harness::tables::write_csv("memo_throughput", header, &rows) {
        Ok(path) => println!("csv: {}", path.display()),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
