//! Micro-benchmarks of the engine hot paths (used by the §Perf pass):
//! the scheduler queues (Chase–Lev deque, injector, sharded worklist),
//! the registry cascade, component induction on a fixed split-heavy
//! seed, and end-to-end solves — including the scheduler-vs-scheduler
//! race on an imbalanced-tree workload that the work-stealing runtime
//! exists to win.
//!
//! Every measurement is also appended to `bench_out/micro_hotpaths.csv`
//! (metric,value,unit) so CI can archive the trajectory. Set
//! `CAVC_SMOKE=1` to run only the fixed split-heavy seed section — the
//! CI smoke-bench configuration (no thresholds, trajectory only).

use cavc::graph::{generators, Graph};
use cavc::solver::registry::{Registry, NONE};
use cavc::solver::sched::deque::{ChaseLev, Steal};
use cavc::solver::sched::injector::Injector;
use cavc::solver::worklist::Worklist;
use cavc::solver::{solve_mvc, SchedulerKind, SolverConfig};
use std::time::Instant;

struct Csv(Vec<String>);

impl Csv {
    fn push(&mut self, metric: &str, value: f64, unit: &str) {
        // metric labels may contain commas (e.g. "c_fat(110,8)")
        let metric = metric.replace(',', ";");
        self.0.push(format!("{metric},{value},{unit}"));
    }

    fn write(&self) {
        match cavc::harness::tables::write_csv("micro_hotpaths", "metric,value,unit", &self.0) {
            Ok(path) => println!("\ncsv: {}", path.display()),
            Err(e) => eprintln!("csv write failed: {e}"),
        }
    }
}

fn bench<F: FnMut()>(name: &str, iters: usize, csv: &mut Csv, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters.div_ceil(10) {
        f();
    }
    let mut samples = Vec::with_capacity(5);
    for _ in 0..5 {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[2];
    println!("{name:<40} {med:>12.0} ns/op");
    csv.push(name, med, "ns/op");
    med
}

/// Time one solve of `g` with the given scheduler and worker count.
fn timed_solve(g: &Graph, sched: SchedulerKind, workers: usize) -> (f64, u32, bool) {
    let cfg = SolverConfig::proposed()
        .with_scheduler(sched)
        .with_workers(workers)
        .with_timeout(std::time::Duration::from_secs(60));
    let t = Instant::now();
    let r = solve_mvc(g, &cfg);
    (t.elapsed().as_secs_f64(), r.best, r.timed_out)
}

/// The fixed split-heavy seed section: component induction on vs off on
/// the nested split gadget (CI smoke-bench target).
fn split_heavy_section(csv: &mut Csv) {
    println!("\n# component induction on the fixed split-heavy seed (s/solve)");
    let gadget = generators::split_gadget(3); // 87 vertices, nested splits
    println!("{:<40} {:>10} {:>10}", "workload", "induce=0", "induce=1");
    for workers in [1usize, 4] {
        let mut times = [0.0f64; 2];
        let mut bests = [0u32; 2];
        for (i, threshold) in [0.0, 1.0].into_iter().enumerate() {
            let cfg = SolverConfig::proposed()
                .with_workers(workers)
                .with_induce_threshold(threshold)
                .with_timeout(std::time::Duration::from_secs(60));
            let t = Instant::now();
            let r = solve_mvc(&gadget, &cfg);
            times[i] = t.elapsed().as_secs_f64();
            bests[i] = r.best;
            assert!(!r.timed_out, "split gadget must finish");
        }
        assert_eq!(bests[0], bests[1], "induction changed the answer on split_gadget(3)");
        println!(
            "split_gadget(3) @ {workers:>2} workers    {:>10.4} {:>10.4}",
            times[0], times[1]
        );
        csv.push(&format!("split_gadget3_w{workers}_induce_off"), times[0], "s");
        csv.push(&format!("split_gadget3_w{workers}_induce_on"), times[1], "s");
    }

    // single-component guard: induction must not slow down a graph that
    // never splits (the gate only fires on splits)
    let single = generators::generalized_petersen(36, 2);
    for (label, threshold) in [("off", 0.0), ("on", 1.0)] {
        let cfg = SolverConfig::proposed()
            .with_workers(2)
            .with_induce_threshold(threshold)
            .with_timeout(std::time::Duration::from_secs(60));
        let t = Instant::now();
        let r = solve_mvc(&single, &cfg);
        let el = t.elapsed().as_secs_f64();
        println!("gp(36,2) single-comp induce={label:<4} {el:>10.4} s (mvc={})", r.best);
        csv.push(&format!("gp36_single_comp_induce_{label}"), el, "s");
    }
}

fn main() {
    let smoke = std::env::var("CAVC_SMOKE").as_deref() == Ok("1");
    let mut csv = Csv(Vec::new());
    println!("# micro hot paths (medians of 5 runs)");

    if smoke {
        split_heavy_section(&mut csv);
        csv.write();
        return;
    }

    // sharded worklist push+pop round trip under no contention
    let wl: Worklist<u64> = Worklist::new(8);
    bench("worklist push+pop (sharded)", 100_000, &mut csv, || {
        wl.push(3, 42);
        let _ = wl.pop(3);
    });

    // Chase-Lev owner push+pop round trip (the work stealer's fast path)
    let dq: ChaseLev<u64> = ChaseLev::with_capacity(256);
    bench("deque push+pop (chase-lev owner)", 100_000, &mut csv, || unsafe {
        dq.push(42);
        let _ = dq.pop();
    });

    // Chase-Lev push+steal (owner enqueues, consumer takes from the top)
    let dq2: ChaseLev<u64> = ChaseLev::with_capacity(256);
    bench("deque push+steal (chase-lev)", 100_000, &mut csv, || {
        unsafe { dq2.push(42) };
        let _ = matches!(dq2.steal(), Steal::Taken(_));
    });

    // injector round trip (root/restart queue; cold path in real runs)
    let inj: Injector<u64> = Injector::new();
    bench("injector push+pop (michael-scott)", 100_000, &mut csv, || {
        inj.push(42);
        let _ = inj.pop();
    });

    // registry split + cascade (2 components)
    let reg = Registry::new(false);
    bench("registry split+cascade (2 comps)", 50_000, &mut csv, || {
        let p = reg.new_parent(0, NONE);
        let c1 = reg.new_child(p, 3, 3);
        let c2 = reg.new_child(p, 4, 4);
        let mut sink = |_t: u32| {};
        reg.finish_scan(p, &mut sink);
        reg.complete_node(c1, &mut sink);
        reg.complete_node(c2, &mut sink);
    });

    split_heavy_section(&mut csv);

    // Scheduler head-to-head on an imbalanced search tree: a banded
    // graph fragments into wildly different sub-tree sizes, so static
    // partitions starve and load balancing decides the wall clock.
    println!("\n# scheduler comparison (imbalanced-tree workload, s/solve)");
    let imbalanced = generators::banded(320, 2, 0.28, 90, 0xCA0B);
    println!("{:<28} {:>10} {:>10}", "workload", "sharded", "steal");
    for workers in [1usize, 2, 4, 8] {
        let (sharded_s, a, a_to) = timed_solve(&imbalanced, SchedulerKind::Sharded, workers);
        let (steal_s, b, b_to) = timed_solve(&imbalanced, SchedulerKind::WorkSteal, workers);
        if !a_to && !b_to {
            assert_eq!(a, b, "schedulers disagree on banded(320)");
        }
        println!("banded(320,2) @ {workers:>2} workers   {sharded_s:>10.4} {steal_s:>10.4}");
        csv.push(&format!("banded320_w{workers}_sharded"), sharded_s, "s");
        csv.push(&format!("banded320_w{workers}_steal"), steal_s, "s");
    }

    // end-to-end solves of reference workloads (the real hot path)
    let workloads: Vec<(&str, Graph)> = vec![
        ("solve c_fat(110,8)", generators::c_fat(110, 8, 0xCA09)),
        ("solve grid(12x16)", generators::grid(12, 16, 0.08, 0xCA02)),
        ("solve banded(320,2)", generators::banded(320, 2, 0.28, 90, 0xCA0B)),
        ("solve gp(40,2)", generators::generalized_petersen(40, 2)),
    ];
    println!();
    for (name, g) in &workloads {
        let cfg = SolverConfig::proposed().with_timeout(std::time::Duration::from_secs(30));
        let t = Instant::now();
        let r = solve_mvc(g, &cfg);
        let el = t.elapsed().as_secs_f64();
        println!(
            "{name:<40} {el:>10.4} s   (mvc={}, nodes={}, splits={})",
            r.best, r.stats.tree_nodes, r.stats.component_branches
        );
        csv.push(name, el, "s");
    }

    // per-node throughput proxy: nodes/sec on a branching-heavy instance
    let g = generators::generalized_petersen(36, 2);
    let t = Instant::now();
    let r = solve_mvc(&g, &SolverConfig::proposed());
    let el = t.elapsed().as_secs_f64();
    println!(
        "{:<40} {:>10.0} nodes/s ({} nodes in {:.3}s)",
        "engine node throughput gp(36,2)",
        r.stats.tree_nodes as f64 / el,
        r.stats.tree_nodes,
        el
    );
    csv.push("engine_node_throughput_gp36", r.stats.tree_nodes as f64 / el, "nodes/s");

    csv.write();
}
