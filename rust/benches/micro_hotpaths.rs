//! Micro-benchmarks of the engine hot paths (used by the §Perf pass):
//! per-node reduction sweep, component BFS, child materialization, the
//! worklist, and the registry cascade. Reports ns/op medians.

use cavc::graph::{generators, Graph};
use cavc::solver::registry::{Registry, NONE};
use cavc::solver::worklist::Worklist;
use cavc::solver::{solve_mvc, SolverConfig};
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters.div_ceil(10) {
        f();
    }
    let mut samples = Vec::with_capacity(5);
    for _ in 0..5 {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = samples[2];
    println!("{name:<40} {med:>12.0} ns/op");
    med
}

fn main() {
    println!("# micro hot paths (medians of 5 runs)");

    // worklist push+pop round trip under no contention
    let wl: Worklist<u64> = Worklist::new(8);
    bench("worklist push+pop", 100_000, || {
        wl.push(3, 42);
        let _ = wl.pop(3);
    });

    // registry split + cascade (2 components)
    let reg = Registry::new(false);
    bench("registry split+cascade (2 comps)", 50_000, || {
        let p = reg.new_parent(0, NONE);
        let c1 = reg.new_child(p, 3, 3);
        let c2 = reg.new_child(p, 4, 4);
        let mut sink = |_t: u32| {};
        reg.finish_scan(p, &mut sink);
        reg.complete_node(c1, &mut sink);
        reg.complete_node(c2, &mut sink);
    });

    // end-to-end solves of reference workloads (the real hot path)
    let workloads: Vec<(&str, Graph)> = vec![
        ("solve c_fat(110,8)", generators::c_fat(110, 8, 0xCA09)),
        ("solve grid(12x16)", generators::grid(12, 16, 0.08, 0xCA02)),
        ("solve banded(320,2)", generators::banded(320, 2, 0.28, 90, 0xCA0B)),
        ("solve gp(40,2)", generators::generalized_petersen(40, 2)),
    ];
    for (name, g) in &workloads {
        let cfg = SolverConfig::proposed().with_timeout(std::time::Duration::from_secs(30));
        let t = Instant::now();
        let r = solve_mvc(g, &cfg);
        let el = t.elapsed().as_secs_f64();
        println!(
            "{name:<40} {el:>10.4} s   (mvc={}, nodes={}, splits={})",
            r.best, r.stats.tree_nodes, r.stats.component_branches
        );
    }

    // per-node throughput proxy: nodes/sec on a branching-heavy instance
    let g = generators::generalized_petersen(36, 2);
    let t = Instant::now();
    let r = solve_mvc(&g, &SolverConfig::proposed());
    let el = t.elapsed().as_secs_f64();
    println!(
        "{:<40} {:>10.0} nodes/s ({} nodes in {:.3}s)",
        "engine node throughput gp(36,2)",
        r.stats.tree_nodes as f64 / el,
        r.stats.tree_nodes,
        el
    );
}
