//! QoS latency: small-job submit→result latency while a large
//! throughput job saturates the same resident pool, lanes on vs off.
//!
//! One dense p_hat hog is submitted to a small pool and left branching;
//! a stream of small MVC jobs then flows through the same service and
//! each job's wall-clock latency (submission to `wait` return) is
//! measured. Two modes on identical traffic:
//!
//! * `lanes-off` — the small jobs ride the throughput lane like the
//!   hog: weight-1 dispatch, roots land behind the hog's queued nodes,
//!   pickup waits on the 64-pop fairness poll;
//! * `lanes-on`  — the small jobs are pinned to the latency lane: 4×
//!   deficit-round-robin weight and urgent injection (every worker
//!   polls the shared queue on every pop until pickup).
//!
//! Both modes must produce identical (oracle-exact) answers — lanes may
//! only move *when* work is picked up. Results go to stdout and
//! `bench_out/qos_latency.csv`. `CAVC_SMOKE=1` shrinks the stream for
//! the CI smoke job (trajectory only, no thresholds).

use cavc::graph::{generators, Graph};
use cavc::solver::{oracle, JobOptions, Lane, Problem, Termination, VcService};
use std::time::{Duration, Instant};

/// The measured traffic: a deterministic stream of small mixed graphs.
fn stream(n: usize) -> Vec<Graph> {
    (0..n)
        .map(|i| {
            let seed = 0x0A75_0000 + i as u64;
            match i % 3 {
                0 => generators::erdos_renyi(14 + i % 6, 0.2, seed),
                1 => generators::union_of_random(3, 3, 6, 0.3, seed),
                _ => generators::random_tree(20 + i % 12, seed),
            }
        })
        .collect()
}

/// The dense hog: far more search than the measured window consumes.
fn hog_graph() -> Graph {
    generators::p_hat(180, 0.35, 0.85, 11)
}

fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    let idx = ((p / 100.0) * (sorted_ms.len() - 1) as f64).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

/// Run one mode: hog branching in the throughput lane, the small-job
/// stream submitted serially in `lane`, each job's latency recorded.
/// Returns (per-job latencies in ms, answers).
fn run_mode(graphs: &[Graph], workers: usize, lane: Lane) -> (Vec<f64>, Vec<u32>) {
    let svc = VcService::builder().workers(workers).build();
    let hog = svc.submit_with(
        Problem::mvc(hog_graph()),
        JobOptions { priority: Some(Lane::Throughput), ..JobOptions::default() },
    );
    // let the hog get past setup and fill the deques
    std::thread::sleep(Duration::from_millis(50));
    assert!(hog.try_result().is_none(), "hog must still be branching");

    let mut lat_ms = Vec::with_capacity(graphs.len());
    let mut answers = Vec::with_capacity(graphs.len());
    for g in graphs {
        let t = Instant::now();
        let h = svc.submit_with(
            Problem::mvc(g.clone()),
            JobOptions { priority: Some(lane), ..JobOptions::default() },
        );
        let sol = h.wait();
        lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
        answers.push(sol.objective);
    }
    assert!(hog.try_result().is_none(), "hog outlived the measured window");
    hog.cancel();
    assert_eq!(hog.wait().termination, Termination::Cancelled);
    (lat_ms, answers)
}

fn main() {
    let smoke = std::env::var("CAVC_SMOKE").is_ok();
    let n = if smoke { 20 } else { 100 };
    // A small fixed pool keeps the hog genuinely saturating: on a wide
    // machine idle workers would absorb the small jobs in either mode.
    let workers = 2;
    let graphs = stream(n);
    let expect: Vec<u32> = graphs.iter().map(oracle::mvc_size).collect();
    println!("# qos latency — {n} small jobs racing one dense hog, {workers} workers");

    let (off_ms, off_ans) = run_mode(&graphs, workers, Lane::Throughput);
    let (on_ms, on_ans) = run_mode(&graphs, workers, Lane::Latency);
    assert_eq!(off_ans, expect, "lanes-off answers must be oracle-exact");
    assert_eq!(on_ans, expect, "lanes-on answers must be oracle-exact");

    let mut rows = Vec::new();
    println!("{:<10} {:>10} {:>10} {:>10}", "mode", "p50 ms", "p99 ms", "mean ms");
    for (mode, ms) in [("lanes-off", &off_ms), ("lanes-on", &on_ms)] {
        let mut s = ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = percentile(&s, 50.0);
        let p99 = percentile(&s, 99.0);
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        println!("{mode:<10} {p50:>10.3} {p99:>10.3} {mean:>10.3}");
        rows.push(format!("{mode},{n},{workers},{p50},{p99},{mean}"));
    }
    let mut off_sorted = off_ms.clone();
    off_sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut on_sorted = on_ms.clone();
    on_sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "p99 lanes-on vs lanes-off: {:.2}x",
        percentile(&off_sorted, 99.0) / percentile(&on_sorted, 99.0).max(1e-9)
    );

    let header = "mode,jobs,workers,p50_ms,p99_ms,mean_ms";
    match cavc::harness::tables::write_csv("qos_latency", header, &rows) {
        Ok(path) => println!("csv: {}", path.display()),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
