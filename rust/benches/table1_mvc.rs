//! Table I: MVC execution time of the proposed solver vs the prior-work
//! GPU baseline (Yamout et al.), the optimized sequential baseline, and
//! the no-load-balance variant, over the 17-dataset analog suite.
//!
//! `CAVC_TIMEOUT_S` bounds each cell (the paper's ">6hrs" stand-in;
//! default 5 s). `CAVC_SUITE=smoke` runs the fast subset.

use cavc::harness::{datasets, tables};
use std::io::Write;

fn main() {
    let suite = if std::env::var("CAVC_SUITE").as_deref() == Ok("smoke") {
        datasets::smoke_suite()
    } else {
        datasets::suite()
    };
    println!(
        "# Table I — MVC time (s), budget {}s/cell, {} datasets, scheduler {} (CAVC_SCHED=steal|sharded)",
        tables::cell_timeout().as_secs_f64(),
        suite.len(),
        tables::cell_scheduler().name()
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for d in &suite {
        eprintln!("[table1] {} ...", d.name);
        let row = tables::table1_row(d);
        csv.push(format!(
            "{},{},{},{:.6},{},{:.6},{},{:.6},{},{:.6},{}",
            row.name,
            row.n,
            row.m,
            row.yamout.secs,
            row.yamout.timed_out,
            row.sequential.secs,
            row.sequential.timed_out,
            row.no_lb.secs,
            row.no_lb.timed_out,
            row.proposed.secs,
            row.proposed.timed_out,
        ));
        rows.push(row);
    }
    tables::print_table1(&rows, std::io::stdout().lock()).unwrap();
    let path = tables::write_csv(
        "table1_mvc",
        "graph,n,m,yamout_s,yamout_to,seq_s,seq_to,nolb_s,nolb_to,proposed_s,proposed_to",
        &csv,
    )
    .unwrap();
    writeln!(std::io::stdout(), "\ncsv: {}", path.display()).unwrap();
}
