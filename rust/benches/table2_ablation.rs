//! Table II: incremental impact of each optimization — the proposed
//! solver with (a) component branching disabled, (b) root reduce+induce
//! disabled, (c) tree induction disabled (`--induce-threshold 0`:
//! full-width split children), (d) non-zero bounds disabled, vs the
//! full system — plus the full system on a resident service with the
//! self-tuning controller retuning its knobs online.

use cavc::harness::{datasets, tables};

fn main() {
    let suite = if std::env::var("CAVC_SUITE").as_deref() == Ok("smoke") {
        datasets::smoke_suite()
    } else {
        datasets::suite()
    };
    println!(
        "# Table II — ablations (s), budget {}s/cell",
        tables::cell_timeout().as_secs_f64()
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for d in &suite {
        eprintln!("[table2] {} ...", d.name);
        let row = tables::table2_row(d);
        csv.push(format!(
            "{},{:.6},{},{:.6},{},{:.6},{},{:.6},{},{:.6},{},{:.6},{}",
            row.name,
            row.no_components.secs,
            row.no_components.timed_out,
            row.no_induce.secs,
            row.no_induce.timed_out,
            row.no_tree_induce.secs,
            row.no_tree_induce.timed_out,
            row.no_bounds.secs,
            row.no_bounds.timed_out,
            row.proposed.secs,
            row.proposed.timed_out,
            row.controller.secs,
            row.controller.timed_out,
        ));
        rows.push(row);
    }
    tables::print_table2(&rows, std::io::stdout().lock()).unwrap();
    let path = tables::write_csv(
        "table2_ablation",
        "graph,no_components_s,no_components_to,no_induce_s,no_induce_to,no_tree_induce_s,no_tree_induce_to,no_bounds_s,no_bounds_to,proposed_s,proposed_to,controller_s,controller_to",
        &csv,
    )
    .unwrap();
    println!("\ncsv: {}", path.display());
}
