//! Table III: search-tree nodes visited without vs with component
//! branching, plus the components-per-branch histogram of the proposed
//! solver.

use cavc::harness::{datasets, tables};

fn main() {
    let suite = if std::env::var("CAVC_SUITE").as_deref() == Ok("smoke") {
        datasets::smoke_suite()
    } else {
        datasets::suite()
    };
    println!(
        "# Table III — tree nodes, budget {}s/cell",
        tables::cell_timeout().as_secs_f64()
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for d in &suite {
        eprintln!("[table3] {} ...", d.name);
        let row = tables::table3_row(d);
        let hist: Vec<String> = row.histogram.iter().map(|(k, v)| format!("{k}:{v}")).collect();
        csv.push(format!(
            "{},{},{},{},{},{}",
            row.name,
            row.nodes_disabled,
            row.disabled_timed_out,
            row.nodes_enabled,
            row.component_branches,
            hist.join(";")
        ));
        rows.push(row);
    }
    tables::print_table3(&rows, std::io::stdout().lock()).unwrap();
    let path = tables::write_csv(
        "table3_nodes",
        "graph,nodes_disabled,disabled_timed_out,nodes_enabled,component_branches,histogram",
        &csv,
    )
    .unwrap();
    println!("\ncsv: {}", path.display());
}
