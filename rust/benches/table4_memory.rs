//! Table IV: effect of reducing the graph and inducing a subgraph on the
//! degree array size, modeled thread-block occupancy, shared-memory fit,
//! and degree dtype. Pure preprocessing — no search, so no budget needed.

use cavc::harness::{datasets, tables};

fn main() {
    println!("# Table IV — degree array / occupancy effects of reduce+induce");
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for d in datasets::suite() {
        let row = tables::table4_row(&d);
        csv.push(format!(
            "{},{},{},{},{},{},{},{},{}",
            row.name,
            row.n_before,
            row.n_after,
            row.blocks_before,
            row.blocks_after,
            row.fits_before,
            row.fits_after,
            row.short_before,
            row.short_after,
        ));
        rows.push(row);
    }
    tables::print_table4(&rows, std::io::stdout().lock()).unwrap();
    let path = tables::write_csv(
        "table4_memory",
        "graph,n_before,n_after,blocks_before,blocks_after,fits_before,fits_after,short_before,short_after",
        &csv,
    )
    .unwrap();
    println!("\ncsv: {}", path.display());
}
