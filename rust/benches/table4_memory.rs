//! Table IV: effect of reducing the graph and inducing a subgraph on the
//! degree array size, modeled thread-block occupancy, shared-memory fit,
//! and degree dtype — plus the tree-induction extension: live per-node
//! payload telemetry (peak live bytes, bytes/node, pool traffic) on
//! seeded split-heavy workloads with component induction toggled, which
//! shows post-split payloads tracking component size instead of root n —
//! plus the delta-representation extension: owned vs delta bytes/node
//! and the undo-replay cost (covers reverted on backtrack, covers
//! replayed at steal-time materialization) the delta trade pays.

use cavc::graph::generators;
use cavc::harness::{datasets, tables};
use cavc::solver::NodeRepr;

fn main() {
    println!("# Table IV — degree array / occupancy effects of reduce+induce");
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for d in datasets::suite() {
        let row = tables::table4_row(&d);
        csv.push(format!(
            "{},{},{},{},{},{},{},{},{}",
            row.name,
            row.n_before,
            row.n_after,
            row.blocks_before,
            row.blocks_after,
            row.fits_before,
            row.fits_after,
            row.short_before,
            row.short_after,
        ));
        rows.push(row);
    }
    tables::print_table4(&rows, std::io::stdout().lock()).unwrap();
    let path = tables::write_csv(
        "table4_memory",
        "graph,n_before,n_after,blocks_before,blocks_after,fits_before,fits_after,short_before,short_after",
        &csv,
    )
    .unwrap();
    println!("\ncsv: {}", path.display());

    // ---- tree-induction extension: per-node payload bytes ----
    println!("\n# Table IV ext — per-node payload bytes, induction off vs on");
    let workloads: Vec<(String, cavc::graph::Graph)> = vec![
        ("split_gadget(2)".into(), generators::split_gadget(2)),
        ("split_gadget(3)".into(), generators::split_gadget(3)),
        ("union_of_random(8,6,10)".into(), generators::union_of_random(8, 6, 10, 0.3, 21)),
    ];
    let mut nrows = Vec::new();
    let mut ncsv = Vec::new();
    for (name, g) in &workloads {
        for induce in [false, true] {
            let r = tables::node_bytes_row(name, g, induce);
            ncsv.push(format!(
                "{},{},{},{:.1},{},{},{},{},{:.6}",
                r.name,
                r.induce,
                r.peak_live_bytes,
                r.bytes_per_node,
                r.pool_hits,
                r.pool_misses,
                r.induced_subproblems,
                r.tree_nodes,
                r.secs,
            ));
            nrows.push(r);
        }
    }
    tables::print_node_bytes(&nrows, std::io::stdout().lock()).unwrap();
    let npath = tables::write_csv(
        "table4_node_bytes",
        "workload,induce,peak_live_bytes,bytes_per_node,pool_hits,pool_misses,induced_subproblems,tree_nodes,secs",
        &ncsv,
    )
    .unwrap();
    println!("\ncsv: {}", npath.display());

    // ---- delta-representation extension: owned vs delta bytes/node ----
    println!("\n# Table IV ext — node representation: owned copies vs delta/undo frames");
    let dworkloads: Vec<(String, cavc::graph::Graph)> = vec![
        ("split_gadget(2)".into(), generators::split_gadget(2)),
        ("split_gadget(3)".into(), generators::split_gadget(3)),
        ("er(36,0.15)".into(), generators::erdos_renyi(36, 0.15, 3)),
        ("union_of_random(8,6,10)".into(), generators::union_of_random(8, 6, 10, 0.3, 21)),
    ];
    let mut drows = Vec::new();
    let mut dcsv = Vec::new();
    for (name, g) in &dworkloads {
        for induce in [false, true] {
            for repr in [NodeRepr::Owned, NodeRepr::Delta] {
                let r = tables::delta_bytes_row(name, g, induce, repr);
                dcsv.push(format!(
                    "{},{},{},{:.1},{},{},{},{},{},{},{},{:.6}",
                    r.name,
                    r.induce,
                    r.repr.name(),
                    r.bytes_per_node,
                    r.peak_live_bytes,
                    r.delta_children,
                    r.undo_pops,
                    r.undo_covers,
                    r.materializations,
                    r.replayed_covers,
                    r.tree_nodes,
                    r.secs,
                ));
                drows.push(r);
            }
        }
    }
    tables::print_delta_bytes(&drows, std::io::stdout().lock()).unwrap();
    let dpath = tables::write_csv(
        "table4_delta_nodes",
        "workload,induce,repr,bytes_per_node,peak_live_bytes,delta_children,undo_pops,undo_covers,materializations,replayed_covers,tree_nodes,secs",
        &dcsv,
    )
    .unwrap();
    println!("\ncsv: {}", dpath.display());
}
