//! Table V: PVC execution time at k ∈ {min−1, min, min+1} for the
//! proposed solver vs the three baselines. Requires the MVC minimum per
//! dataset, computed first with the proposed solver (rows are skipped if
//! that times out, as the paper cannot define min±1 either).

use cavc::harness::{datasets, tables};

fn main() {
    let suite = if std::env::var("CAVC_SUITE").as_deref() == Ok("smoke") {
        datasets::smoke_suite()
    } else {
        datasets::suite()
    };
    println!(
        "# Table V — PVC time (s) at k = min-1 / min / min+1, budget {}s/cell, scheduler {}",
        tables::cell_timeout().as_secs_f64(),
        tables::cell_scheduler().name()
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for d in &suite {
        eprintln!("[table5] {} ...", d.name);
        for row in tables::table5_rows(d) {
            csv.push(format!(
                "{},{},{},{},{:.6},{},{:.6},{},{:.6},{},{:.6},{}",
                row.name,
                row.instance,
                row.k,
                row.found,
                row.yamout.secs,
                row.yamout.timed_out,
                row.sequential.secs,
                row.sequential.timed_out,
                row.no_lb.secs,
                row.no_lb.timed_out,
                row.proposed.secs,
                row.proposed.timed_out,
            ));
            rows.push(row);
        }
    }
    tables::print_table5(&rows, std::io::stdout().lock()).unwrap();
    let path = tables::write_csv(
        "table5_pvc",
        "graph,instance,k,found,yamout_s,yamout_to,seq_s,seq_to,nolb_s,nolb_to,proposed_s,proposed_to",
        &csv,
    )
    .unwrap();
    println!("\ncsv: {}", path.display());
}
