//! Table VI: proposed vs prior work on prior work's own datasets — the
//! low-degree graphs the proposed solution wins on and the dense
//! p_hat-style family it loses on, with the paper's ~10%-density
//! predictor reported per row.

use cavc::harness::{datasets, tables};

fn main() {
    println!(
        "# Table VI — prior work's datasets, budget {}s/cell",
        tables::cell_timeout().as_secs_f64()
    );
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for d in datasets::table6_suite() {
        eprintln!("[table6] {} ...", d.name);
        let row = tables::table6_row(&d);
        csv.push(format!(
            "{},{:.4},{:.6},{},{:.6},{}",
            row.name,
            row.density,
            row.yamout.secs,
            row.yamout.timed_out,
            row.proposed.secs,
            row.proposed.timed_out,
        ));
        rows.push(row);
    }
    tables::print_table6(&rows, std::io::stdout().lock()).unwrap();

    // the paper's empirical predictor: wins cluster below ~10% density
    let mut wins_low = 0;
    let mut losses_high = 0;
    let (mut wins, mut losses) = (0, 0);
    for r in &rows {
        let base = if r.yamout.timed_out {
            tables::cell_timeout().as_secs_f64()
        } else {
            r.yamout.secs
        };
        if base > r.proposed.secs {
            wins += 1;
            if r.density < 0.10 {
                wins_low += 1;
            }
        } else {
            losses += 1;
            if r.density >= 0.10 {
                losses_high += 1;
            }
        }
    }
    println!("\npredictor: {wins_low}/{wins} wins below 10% density; {losses_high}/{losses} losses at ≥10%");
    let path = tables::write_csv(
        "table6_prior",
        "graph,density,yamout_s,yamout_to,proposed_s,proposed_to",
        &csv,
    )
    .unwrap();
    println!("csv: {}", path.display());
}
