//! Service throughput: jobs/sec on a batch of small graphs, resident
//! pool vs per-call spawn.
//!
//! The resident [`VcService`] pays thread spawn + pool warm-up once and
//! runs every job on shared workers with recycled per-worker scratch;
//! the per-call baseline is the one-shot `solve_mvc` engine, which
//! spawns and joins a full `thread::scope` worker set for every graph
//! (forced here via an explicit `with_workers`, which bypasses the
//! default-service shim). Three modes are timed on an identical batch:
//!
//! * `per-call spawn`   — a `solve_mvc` loop, one pool per call;
//! * `resident serial`  — one service, submit → wait one job at a time
//!   (isolates the spawn savings);
//! * `resident batch`   — one service, all jobs in flight concurrently
//!   (adds cross-job parallelism on the shared pool).
//!
//! Every mode must produce identical answers. Results go to stdout and
//! `bench_out/throughput.csv`. `CAVC_SMOKE=1` shrinks the batch for the
//! CI smoke job (trajectory only, no thresholds).

use cavc::graph::{generators, Graph};
use cavc::solver::{solve_mvc, Problem, SolverConfig, VcService};
use std::time::Instant;

/// A deterministic batch of small mixed-family graphs (the "many small
/// requests" traffic shape the service exists for).
fn batch(n: usize) -> Vec<Graph> {
    (0..n)
        .map(|i| {
            let seed = 0xBEE5_0000 + i as u64;
            match i % 4 {
                0 => generators::erdos_renyi(14 + i % 10, 0.2, seed),
                1 => generators::union_of_random(3, 3, 6, 0.3, seed),
                2 => generators::random_tree(24 + i % 16, seed),
                _ => generators::erdos_renyi(18, 0.15, seed),
            }
        })
        .collect()
}

fn main() {
    let smoke = std::env::var("CAVC_SMOKE").is_ok();
    let n = if smoke { 40 } else { 200 };
    let graphs = batch(n);
    let workers = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4);
    println!("# service throughput — {n} small graphs, {workers} workers");

    // Baseline: per-call spawn. The explicit worker count forces the
    // one-shot engine (a fresh thread::scope pool per call).
    let oneshot = SolverConfig::proposed().with_workers(workers);
    let t = Instant::now();
    let base: Vec<u32> = graphs.iter().map(|g| solve_mvc(g, &oneshot).best).collect();
    let per_call_s = t.elapsed().as_secs_f64();

    // Resident pool, serial submission: spawn savings only.
    let svc = VcService::builder().workers(workers).build();
    let t = Instant::now();
    let serial: Vec<u32> =
        graphs.iter().map(|g| svc.solve(Problem::mvc(g.clone())).objective).collect();
    let serial_s = t.elapsed().as_secs_f64();

    // Resident pool, everything in flight: spawn savings + cross-job
    // parallelism.
    let t = Instant::now();
    let handles: Vec<_> = graphs.iter().map(|g| svc.submit(Problem::mvc(g.clone()))).collect();
    let conc: Vec<u32> = handles.iter().map(|h| h.wait().objective).collect();
    let conc_s = t.elapsed().as_secs_f64();

    assert_eq!(base, serial, "resident serial must reproduce the one-shot answers");
    assert_eq!(base, conc, "resident batch must reproduce the one-shot answers");

    let jps = |s: f64| n as f64 / s.max(1e-12);
    println!("{:<18} {:>10} {:>12}", "mode", "secs", "jobs/s");
    println!("{:<18} {:>10.4} {:>12.1}", "per-call spawn", per_call_s, jps(per_call_s));
    println!("{:<18} {:>10.4} {:>12.1}", "resident serial", serial_s, jps(serial_s));
    println!("{:<18} {:>10.4} {:>12.1}", "resident batch", conc_s, jps(conc_s));
    println!(
        "resident batch vs per-call spawn: {:.2}x",
        per_call_s / conc_s.max(1e-12)
    );

    let rows = vec![
        format!("per-call-spawn,{n},{workers},{per_call_s},{}", jps(per_call_s)),
        format!("resident-serial,{n},{workers},{serial_s},{}", jps(serial_s)),
        format!("resident-batch,{n},{workers},{conc_s},{}", jps(conc_s)),
    ];
    let header = "mode,jobs,workers,secs,jobs_per_sec";
    match cavc::harness::tables::write_csv("throughput", header, &rows) {
        Ok(path) => println!("csv: {}", path.display()),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
