//! Wire-protocol overhead: the same deterministic batch solved on one
//! resident service three ways — in-process submits, remote serial
//! (one `solve` round trip per job), and remote pipelined (submit all,
//! then collect) — over a loopback TCP connection.
//!
//! All three modes must produce identical objectives (the framing layer
//! is not allowed to change answers); the interesting columns are the
//! per-job overhead of a serial round trip versus pipelining. Results
//! go to stdout and `bench_out/wire_throughput.csv`. `CAVC_SMOKE=1`
//! shrinks the batch for the CI smoke job.

use cavc::graph::{generators, Graph};
use cavc::solver::{Problem, ServerConfig, ServerReply, VcClient, VcServer, VcService, WireOptions};
use std::collections::HashMap;
use std::time::Instant;

/// Deterministic small-graph batch: cheap individual solves, so the
/// measurement is dominated by dispatch + framing, not search.
fn batch(n: usize) -> Vec<Graph> {
    (0..n).map(|i| generators::erdos_renyi(18, 0.22, 0xA11CE + i as u64)).collect()
}

fn in_process(svc: &VcService, graphs: &[Graph]) -> (Vec<u32>, f64) {
    let t = Instant::now();
    let handles: Vec<_> = graphs.iter().map(|g| svc.submit(Problem::mvc(g.clone()))).collect();
    let answers: Vec<u32> = handles.iter().map(|h| h.wait().objective).collect();
    (answers, t.elapsed().as_secs_f64())
}

fn remote_serial(client: &mut VcClient, graphs: &[Graph]) -> (Vec<u32>, f64) {
    let t = Instant::now();
    let answers: Vec<u32> = graphs
        .iter()
        .map(|g| {
            client
                .solve(&Problem::mvc(g.clone()), WireOptions::default())
                .expect("remote solve")
                .objective
        })
        .collect();
    (answers, t.elapsed().as_secs_f64())
}

fn remote_pipelined(client: &mut VcClient, graphs: &[Graph]) -> (Vec<u32>, f64) {
    let t = Instant::now();
    let ids: Vec<u64> = graphs
        .iter()
        .map(|g| client.submit(&Problem::mvc(g.clone()), WireOptions::default()).expect("submit"))
        .collect();
    let mut by_id: HashMap<u64, u32> = HashMap::with_capacity(ids.len());
    while by_id.len() < ids.len() {
        match client.recv().expect("reply") {
            ServerReply::Solution(sol) => {
                by_id.insert(sol.req_id, sol.objective);
            }
            ServerReply::Error(e) => panic!("remote rejection: {e:?}"),
            ServerReply::Stats(_) => {}
        }
    }
    let answers = ids.iter().map(|id| by_id[id]).collect();
    (answers, t.elapsed().as_secs_f64())
}

fn main() {
    let smoke = std::env::var("CAVC_SMOKE").is_ok();
    let n = if smoke { 40 } else { 200 };
    let graphs = batch(n);
    let workers = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4);
    println!("# wire throughput — {n} small graphs, {workers} workers, loopback TCP");

    let svc = VcService::builder().workers(workers).build();
    let server =
        VcServer::bind("127.0.0.1:0", svc, ServerConfig::default()).expect("bind loopback");
    let addr = server.local_addr().to_string();

    let (local, local_s) = in_process(server.service(), &graphs);
    let mut client = VcClient::connect(&addr).expect("connect");
    let (serial, serial_s) = remote_serial(&mut client, &graphs);
    let (piped, piped_s) = remote_pipelined(&mut client, &graphs);

    assert_eq!(local, serial, "serial wire answers must match in-process");
    assert_eq!(local, piped, "pipelined wire answers must match in-process");

    let per_job_us = |secs: f64| 1e6 * secs / n as f64;
    println!("{:<16} {:>10} {:>12} {:>12}", "mode", "secs", "jobs/s", "us/job");
    for (mode, secs) in
        [("in-process", local_s), ("remote-serial", serial_s), ("remote-pipelined", piped_s)]
    {
        println!(
            "{:<16} {:>10.4} {:>12.1} {:>12.1}",
            mode,
            secs,
            n as f64 / secs.max(1e-12),
            per_job_us(secs)
        );
    }
    println!(
        "framing overhead: serial {:.2}x, pipelined {:.2}x of in-process wall",
        serial_s / local_s.max(1e-12),
        piped_s / local_s.max(1e-12)
    );

    let rows = vec![
        format!("in-process,{n},{workers},{local_s},{}", per_job_us(local_s)),
        format!("remote-serial,{n},{workers},{serial_s},{}", per_job_us(serial_s)),
        format!("remote-pipelined,{n},{workers},{piped_s},{}", per_job_us(piped_s)),
    ];
    let header = "mode,jobs,workers,secs,us_per_job";
    match cavc::harness::tables::write_csv("wire_throughput", header, &rows) {
        Ok(path) => println!("csv: {}", path.display()),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    server.shutdown();
}
