//! Non-zero bounds on the degree array (paper §IV-C).
//!
//! Deep in the search tree most degree entries are zero; the paper keeps
//! two indices — the first and last vertex with non-zero degree — and
//! restricts all reduction sweeps to that window. The window is cheap to
//! maintain (shrink-only between copies; recomputed from the parent's
//! window when a child is materialized) and costs 8 bytes, versus a full
//! compaction pass for a sparse list.

use super::DegElem;

/// Inclusive `[lo, hi]` window that contains every non-zero entry.
/// An empty window is represented as `lo > hi` (`EMPTY`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NonZeroBounds {
    /// First possibly-nonzero index.
    pub lo: u32,
    /// Last possibly-nonzero index.
    pub hi: u32,
}

impl NonZeroBounds {
    /// The empty window.
    pub const EMPTY: NonZeroBounds = NonZeroBounds { lo: 1, hi: 0 };

    /// Window covering all of `0..n`.
    pub fn full(n: usize) -> NonZeroBounds {
        if n == 0 {
            NonZeroBounds::EMPTY
        } else {
            NonZeroBounds { lo: 0, hi: (n - 1) as u32 }
        }
    }

    /// True if the window contains no indices.
    #[inline]
    pub fn is_empty(self) -> bool {
        self.lo > self.hi
    }

    /// Number of indices in the window.
    #[inline]
    pub fn width(self) -> usize {
        if self.is_empty() {
            0
        } else {
            (self.hi - self.lo + 1) as usize
        }
    }

    /// Iterate indices in the window.
    #[inline]
    pub fn iter(self) -> impl Iterator<Item = u32> {
        self.lo..=if self.is_empty() { 0 } else { self.hi }
    }

    /// Tighten the window against the actual array contents: advance `lo`
    /// past leading zeros and retreat `hi` past trailing zeros.
    pub fn tighten<T: DegElem>(self, deg: &[T]) -> NonZeroBounds {
        if self.is_empty() {
            return NonZeroBounds::EMPTY;
        }
        let mut lo = self.lo;
        let mut hi = self.hi;
        let zero = T::default();
        while lo <= hi && deg[lo as usize] == zero {
            lo += 1;
        }
        if lo > hi {
            return NonZeroBounds::EMPTY;
        }
        while hi > lo && deg[hi as usize] == zero {
            hi -= 1;
        }
        NonZeroBounds { lo, hi }
    }

    /// Exact bounds computed from scratch (used when bounds maintenance
    /// is disabled we still need a full window, and in tests).
    pub fn exact<T: DegElem>(deg: &[T]) -> NonZeroBounds {
        NonZeroBounds::full(deg.len()).tighten(deg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_and_empty() {
        assert!(NonZeroBounds::full(0).is_empty());
        let b = NonZeroBounds::full(5);
        assert_eq!((b.lo, b.hi), (0, 4));
        assert_eq!(b.width(), 5);
        assert!(NonZeroBounds::EMPTY.is_empty());
        assert_eq!(NonZeroBounds::EMPTY.width(), 0);
    }

    #[test]
    fn tighten_shrinks_both_ends() {
        let deg: Vec<u8> = vec![0, 0, 3, 0, 1, 0, 0];
        let b = NonZeroBounds::full(7).tighten(&deg);
        assert_eq!((b.lo, b.hi), (2, 4));
    }

    #[test]
    fn tighten_all_zero() {
        let deg: Vec<u16> = vec![0; 8];
        assert!(NonZeroBounds::full(8).tighten(&deg).is_empty());
    }

    #[test]
    fn tighten_is_shrink_only() {
        // window that already excludes nonzeros outside it stays put
        let deg: Vec<u8> = vec![9, 0, 1, 0, 9];
        let b = NonZeroBounds { lo: 1, hi: 3 }.tighten(&deg);
        assert_eq!((b.lo, b.hi), (2, 2));
    }

    #[test]
    fn exact_matches_manual() {
        let deg: Vec<u32> = vec![0, 5, 0, 0, 7, 0];
        let b = NonZeroBounds::exact(&deg);
        assert_eq!((b.lo, b.hi), (1, 4));
    }

    #[test]
    fn iter_covers_window() {
        let b = NonZeroBounds { lo: 2, hi: 4 };
        assert_eq!(b.iter().collect::<Vec<_>>(), vec![2, 3, 4]);
        assert_eq!(NonZeroBounds::EMPTY.iter().count(), 0);
    }
}
