//! Degree-array intermediate representation (paper §IV).
//!
//! The branch-and-reduce engine never mutates the CSR graph; the entire
//! intermediate state of a search-tree node is a *degree array*: one
//! counter per vertex of the (root-induced) subgraph. A vertex is present
//! iff its entry is nonzero; an edge `uv` exists iff both endpoints are
//! present (edges only disappear when an endpoint is removed, so the
//! static CSR plus the degree array fully determines the residual graph).
//!
//! Three footprint optimizations from the paper are implemented here:
//! * arrays sized to the **root-induced subgraph** (§IV-B) — callers
//!   induce first, see [`crate::prep`];
//! * **non-zero bounds** `[lo, hi]` maintained per node so reduction
//!   sweeps skip the all-zero prefix/suffix (§IV-C);
//! * **small integer dtypes** selected from the post-reduction maximum
//!   degree (§IV-D): `u8` / `u16` / `u32` element types via [`DegElem`].

pub mod bounds;

pub use bounds::NonZeroBounds;

/// Element type of a degree array. The engine is generic over this, so
/// dtype selection changes the real memory footprint of every stack
/// entry, as on the GPU.
pub trait DegElem:
    Copy + Send + Sync + Default + PartialEq + std::fmt::Debug + 'static
{
    /// Bytes per entry.
    const BYTES: usize;
    /// Largest representable degree.
    const MAX_DEG: u32;
    /// Widen to u32.
    fn to_u32(self) -> u32;
    /// Narrow from u32 (caller guarantees it fits).
    fn from_u32(x: u32) -> Self;
}

impl DegElem for u8 {
    const BYTES: usize = 1;
    const MAX_DEG: u32 = u8::MAX as u32;
    #[inline]
    fn to_u32(self) -> u32 {
        self as u32
    }
    #[inline]
    fn from_u32(x: u32) -> Self {
        debug_assert!(x <= Self::MAX_DEG);
        x as u8
    }
}

impl DegElem for u16 {
    const BYTES: usize = 2;
    const MAX_DEG: u32 = u16::MAX as u32;
    #[inline]
    fn to_u32(self) -> u32 {
        self as u32
    }
    #[inline]
    fn from_u32(x: u32) -> Self {
        debug_assert!(x <= Self::MAX_DEG);
        x as u16
    }
}

impl DegElem for u32 {
    const BYTES: usize = 4;
    const MAX_DEG: u32 = u32::MAX;
    #[inline]
    fn to_u32(self) -> u32 {
        self
    }
    #[inline]
    fn from_u32(x: u32) -> Self {
        x
    }
}

/// Runtime dtype tag (for occupancy reporting and engine dispatch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// 1-byte entries (Δ ≤ 255).
    U8,
    /// 2-byte entries (Δ ≤ 65535).
    U16,
    /// 4-byte entries.
    U32,
}

impl Dtype {
    /// Smallest dtype that can hold `max_degree`.
    pub fn for_max_degree(max_degree: u32) -> Dtype {
        if max_degree <= u8::MAX_DEG {
            Dtype::U8
        } else if max_degree <= u16::MAX_DEG {
            Dtype::U16
        } else {
            Dtype::U32
        }
    }

    /// Bytes per entry.
    pub fn bytes(self) -> usize {
        match self {
            Dtype::U8 => 1,
            Dtype::U16 => 2,
            Dtype::U32 => 4,
        }
    }

    /// Short display name ("u8"/"u16"/"u32").
    pub fn name(self) -> &'static str {
        match self {
            Dtype::U8 => "u8",
            Dtype::U16 => "u16",
            Dtype::U32 => "u32",
        }
    }

    /// Whether this counts as a "short datatype" in Table IV.
    pub fn is_short(self) -> bool {
        !matches!(self, Dtype::U32)
    }
}

/// Build the initial degree array for a graph.
pub fn initial_degrees<T: DegElem>(g: &crate::graph::Graph) -> Vec<T> {
    (0..g.num_vertices() as u32).map(|v| T::from_u32(g.degree(v))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn dtype_selection() {
        assert_eq!(Dtype::for_max_degree(0), Dtype::U8);
        assert_eq!(Dtype::for_max_degree(255), Dtype::U8);
        assert_eq!(Dtype::for_max_degree(256), Dtype::U16);
        assert_eq!(Dtype::for_max_degree(65535), Dtype::U16);
        assert_eq!(Dtype::for_max_degree(65536), Dtype::U32);
    }

    #[test]
    fn dtype_bytes_and_short() {
        assert_eq!(Dtype::U8.bytes(), 1);
        assert_eq!(Dtype::U16.bytes(), 2);
        assert_eq!(Dtype::U32.bytes(), 4);
        assert!(Dtype::U8.is_short() && Dtype::U16.is_short());
        assert!(!Dtype::U32.is_short());
    }

    #[test]
    fn elem_roundtrip() {
        assert_eq!(u8::from_u32(200).to_u32(), 200);
        assert_eq!(u16::from_u32(60000).to_u32(), 60000);
        assert_eq!(u32::from_u32(1 << 20).to_u32(), 1 << 20);
    }

    #[test]
    fn initial_degrees_match_graph() {
        let g = generators::star(10);
        let d: Vec<u16> = initial_degrees(&g);
        assert_eq!(d[0], 9);
        assert!(d[1..].iter().all(|&x| x == 1));
    }
}
