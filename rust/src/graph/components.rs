//! Connected components on the static graph (CPU reference paths).
//!
//! The solver's per-node component detection works over the *dynamic*
//! degree array (see `solver::engine`); these routines operate on the
//! whole static graph and are used at the root split, in tests, and as
//! the CPU fallback for the XLA-accelerated path in `runtime::accel`.

use super::Graph;
use crate::util::BitSet;

/// Component label per vertex (labels are `0..count`, in discovery order).
pub fn labels(g: &Graph) -> (Vec<u32>, usize) {
    let n = g.num_vertices();
    let mut label = vec![u32::MAX; n];
    let mut next = 0u32;
    let mut queue = std::collections::VecDeque::new();
    for s in 0..n as u32 {
        if label[s as usize] != u32::MAX {
            continue;
        }
        label[s as usize] = next;
        queue.push_back(s);
        while let Some(u) = queue.pop_front() {
            for &v in g.neighbors(u) {
                if label[v as usize] == u32::MAX {
                    label[v as usize] = next;
                    queue.push_back(v);
                }
            }
        }
        next += 1;
    }
    (label, next as usize)
}

/// Number of connected components (isolated vertices count).
pub fn count(g: &Graph) -> usize {
    labels(g).1
}

/// Vertex sets of each component, in discovery order.
pub fn vertex_sets(g: &Graph) -> Vec<Vec<u32>> {
    let (label, k) = labels(g);
    let mut sets = vec![Vec::new(); k];
    for (v, &l) in label.iter().enumerate() {
        sets[l as usize].push(v as u32);
    }
    sets
}

/// BFS reachability from `source`: the set of reached vertices.
pub fn bfs_reach(g: &Graph, source: u32) -> BitSet {
    let mut seen = BitSet::new(g.num_vertices());
    let mut queue = std::collections::VecDeque::new();
    seen.set(source as usize);
    queue.push_back(source);
    while let Some(u) = queue.pop_front() {
        for &v in g.neighbors(u) {
            if seen.insert(v as usize) {
                queue.push_back(v);
            }
        }
    }
    seen
}

/// Union-find structure (used by tests to cross-check BFS labeling and
/// by the crown reduction for auxiliary bookkeeping).
#[derive(Debug, Clone)]
pub struct UnionFind {
    parent: Vec<u32>,
    rank: Vec<u8>,
    /// Disjoint sets remaining; decremented by every merging `union` so
    /// [`UnionFind::num_sets`] is O(1) instead of n× `find`.
    sets: usize,
}

impl UnionFind {
    /// `n` singleton sets.
    pub fn new(n: usize) -> Self {
        Self { parent: (0..n as u32).collect(), rank: vec![0; n], sets: n }
    }

    /// Representative of `x`'s set (path halving).
    pub fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            let gp = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
        x
    }

    /// Merge the sets of `a` and `b`; returns true if they were disjoint.
    pub fn union(&mut self, a: u32, b: u32) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        let (hi, lo) = if self.rank[ra as usize] >= self.rank[rb as usize] {
            (ra, rb)
        } else {
            (rb, ra)
        };
        self.parent[lo as usize] = hi;
        if self.rank[hi as usize] == self.rank[lo as usize] {
            self.rank[hi as usize] += 1;
        }
        self.sets -= 1;
        true
    }

    /// Number of disjoint sets remaining (O(1): a counter maintained by
    /// [`UnionFind::union`]).
    pub fn num_sets(&self) -> usize {
        self.sets
    }
}

/// Components via union-find (cross-check for [`labels`]).
pub fn count_union_find(g: &Graph) -> usize {
    let mut uf = UnionFind::new(g.num_vertices());
    for (u, v) in g.edges() {
        uf.union(u, v);
    }
    uf.num_sets()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn single_component_path() {
        let g = generators::path(6);
        assert_eq!(count(&g), 1);
    }

    #[test]
    fn isolated_vertices_counted() {
        let g = Graph::from_edges(5, &[(0, 1)]);
        assert_eq!(count(&g), 4);
    }

    #[test]
    fn labels_partition() {
        let g = Graph::disjoint_union(&[generators::cycle(4), generators::path(3)]);
        let (label, k) = labels(&g);
        assert_eq!(k, 2);
        assert!(label[..4].iter().all(|&l| l == label[0]));
        assert!(label[4..].iter().all(|&l| l == label[4]));
        assert_ne!(label[0], label[4]);
    }

    #[test]
    fn vertex_sets_cover_all() {
        let g = generators::union_of_random(8, 3, 6, 0.3, 5);
        let sets = vertex_sets(&g);
        assert_eq!(sets.len(), 8);
        let total: usize = sets.iter().map(|s| s.len()).sum();
        assert_eq!(total, g.num_vertices());
    }

    #[test]
    fn bfs_reach_component_only() {
        let g = Graph::disjoint_union(&[generators::path(4), generators::path(3)]);
        let r = bfs_reach(&g, 0);
        assert_eq!(r.count(), 4);
        assert!(!r.get(4));
    }

    #[test]
    fn union_find_agrees_with_bfs() {
        for seed in 0..10 {
            let g = generators::erdos_renyi(80, 0.02, seed);
            assert_eq!(count(&g), count_union_find(&g), "seed {seed}");
        }
    }

    #[test]
    fn union_find_basics() {
        let mut uf = UnionFind::new(4);
        assert!(uf.union(0, 1));
        assert!(!uf.union(1, 0));
        assert_eq!(uf.num_sets(), 3);
        assert_eq!(uf.find(0), uf.find(1));
    }

    #[test]
    fn num_sets_counter_tracks_every_union() {
        let mut uf = UnionFind::new(6);
        assert_eq!(uf.num_sets(), 6);
        uf.union(0, 1);
        uf.union(2, 3);
        assert_eq!(uf.num_sets(), 4);
        uf.union(1, 2); // merges the two pairs
        assert_eq!(uf.num_sets(), 3);
        uf.union(0, 3); // already joined: no change
        assert_eq!(uf.num_sets(), 3);
        uf.union(4, 5);
        uf.union(0, 5);
        assert_eq!(uf.num_sets(), 1);
        // cross-check against an explicit root census
        let roots = (0..6u32).filter(|&x| uf.find(x) == x).count();
        assert_eq!(roots, uf.num_sets());
    }
}
