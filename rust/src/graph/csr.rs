//! Compressed Sparse Row graph representation.
//!
//! Undirected simple graphs with `u32` vertex ids. Each undirected edge is
//! stored twice (once per direction); adjacency lists are sorted, which
//! lets edge queries run in `O(log d)` and lets the degree-two triangle
//! rule check adjacency cheaply.

/// An immutable undirected simple graph in CSR form.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    row_ptr: Vec<u32>,
    adj: Vec<u32>,
}

impl Graph {
    /// Build from an edge list over vertices `0..n`. Self-loops are
    /// dropped (the paper removes them to keep graphs simple) and
    /// duplicate edges are deduplicated.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Graph {
        let mut deg = vec![0u32; n];
        let mut clean: Vec<(u32, u32)> = Vec::with_capacity(edges.len());
        for &(a, b) in edges {
            assert!((a as usize) < n && (b as usize) < n, "edge ({a},{b}) out of range (n={n})");
            if a != b {
                clean.push((a.min(b), a.max(b)));
            }
        }
        clean.sort_unstable();
        clean.dedup();
        for &(a, b) in &clean {
            deg[a as usize] += 1;
            deg[b as usize] += 1;
        }
        let mut row_ptr = vec![0u32; n + 1];
        for v in 0..n {
            row_ptr[v + 1] = row_ptr[v] + deg[v];
        }
        let mut cursor: Vec<u32> = row_ptr[..n].to_vec();
        let mut adj = vec![0u32; row_ptr[n] as usize];
        for &(a, b) in &clean {
            adj[cursor[a as usize] as usize] = b;
            cursor[a as usize] += 1;
            adj[cursor[b as usize] as usize] = a;
            cursor[b as usize] += 1;
        }
        // Edges were sorted by (min,max) so per-row lists may be unsorted
        // for the higher endpoint; sort each row.
        for v in 0..n {
            let (s, e) = (row_ptr[v] as usize, row_ptr[v + 1] as usize);
            adj[s..e].sort_unstable();
        }
        Graph { row_ptr, adj }
    }

    /// Assemble a graph directly from raw CSR arrays.
    ///
    /// `row_ptr` must have `n + 1` entries with `row_ptr[n] == adj.len()`,
    /// every row strictly sorted ascending, and the adjacency symmetric —
    /// exactly what the engine's component induction produces (it walks
    /// sorted neighbor lists through a monotonic renumbering map). Debug
    /// builds validate the row structure; release builds trust the caller
    /// so the hot split path stays allocation-and-scan only.
    pub fn from_csr_parts(row_ptr: Vec<u32>, adj: Vec<u32>) -> Graph {
        debug_assert!(!row_ptr.is_empty(), "row_ptr needs the trailing sentinel");
        debug_assert_eq!(*row_ptr.last().unwrap() as usize, adj.len());
        #[cfg(debug_assertions)]
        for v in 0..row_ptr.len() - 1 {
            let (s, e) = (row_ptr[v] as usize, row_ptr[v + 1] as usize);
            debug_assert!(s <= e, "row {v} has negative extent");
            for i in s + 1..e {
                debug_assert!(adj[i - 1] < adj[i], "row {v} not strictly sorted");
            }
        }
        Graph { row_ptr, adj }
    }

    /// Decompose into the raw `(row_ptr, adj)` CSR arrays, e.g. so a
    /// retired component view can return its buffers to a recycling pool.
    pub fn into_parts(self) -> (Vec<u32>, Vec<u32>) {
        (self.row_ptr, self.adj)
    }

    /// Number of vertices.
    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.row_ptr.len() - 1
    }

    /// Number of undirected edges.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.adj.len() / 2
    }

    /// Static degree of `v` in the full graph.
    #[inline]
    pub fn degree(&self, v: u32) -> u32 {
        self.row_ptr[v as usize + 1] - self.row_ptr[v as usize]
    }

    /// Neighbors of `v`, sorted ascending.
    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        &self.adj[self.row_ptr[v as usize] as usize..self.row_ptr[v as usize + 1] as usize]
    }

    /// True if edge `uv` exists (binary search on the sorted row).
    #[inline]
    pub fn has_edge(&self, u: u32, v: u32) -> bool {
        self.neighbors(u).binary_search(&v).is_ok()
    }

    /// Maximum static degree Δ(G).
    pub fn max_degree(&self) -> u32 {
        (0..self.num_vertices() as u32).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    /// Edge density `2m / (n(n-1))` in `[0,1]`.
    pub fn density(&self) -> f64 {
        let n = self.num_vertices() as f64;
        if n < 2.0 {
            return 0.0;
        }
        2.0 * self.num_edges() as f64 / (n * (n - 1.0))
    }

    /// Iterate over undirected edges `(u, v)` with `u < v`.
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.num_vertices() as u32).flat_map(move |u| {
            self.neighbors(u).iter().copied().filter(move |&v| u < v).map(move |v| (u, v))
        })
    }

    /// Check that a vertex set covers every edge.
    pub fn is_vertex_cover(&self, cover: &[u32]) -> bool {
        let mut inset = vec![false; self.num_vertices()];
        for &v in cover {
            inset[v as usize] = true;
        }
        self.edges().all(|(u, v)| inset[u as usize] || inset[v as usize])
    }

    /// Disjoint union of graphs (vertex ids shifted).
    pub fn disjoint_union(parts: &[Graph]) -> Graph {
        let total: usize = parts.iter().map(|g| g.num_vertices()).sum();
        let mut edges = Vec::new();
        let mut off = 0u32;
        for g in parts {
            for (u, v) in g.edges() {
                edges.push((u + off, v + off));
            }
            off += g.num_vertices() as u32;
        }
        Graph::from_edges(total, &edges)
    }

    /// Degree histogram (index = degree).
    pub fn degree_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.max_degree() as usize + 1];
        for v in 0..self.num_vertices() as u32 {
            h[self.degree(v) as usize] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path5() -> Graph {
        Graph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)])
    }

    #[test]
    fn basic_counts() {
        let g = path5();
        assert_eq!(g.num_vertices(), 5);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(2), 2);
        assert_eq!(g.max_degree(), 2);
    }

    #[test]
    fn neighbors_sorted() {
        let g = Graph::from_edges(4, &[(3, 0), (1, 0), (2, 0)]);
        assert_eq!(g.neighbors(0), &[1, 2, 3]);
    }

    #[test]
    fn dedup_and_self_loops() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 0), (0, 0), (1, 2)]);
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.degree(0), 1);
    }

    #[test]
    fn has_edge() {
        let g = path5();
        assert!(g.has_edge(1, 2));
        assert!(g.has_edge(2, 1));
        assert!(!g.has_edge(0, 4));
    }

    #[test]
    fn edges_iter_each_once() {
        let g = path5();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es, vec![(0, 1), (1, 2), (2, 3), (3, 4)]);
    }

    #[test]
    fn vertex_cover_check() {
        let g = path5();
        assert!(g.is_vertex_cover(&[1, 3]));
        assert!(!g.is_vertex_cover(&[1]));
        assert!(g.is_vertex_cover(&[0, 1, 2, 3, 4]));
    }

    #[test]
    fn disjoint_union_shifts() {
        let g = Graph::disjoint_union(&[path5(), path5()]);
        assert_eq!(g.num_vertices(), 10);
        assert_eq!(g.num_edges(), 8);
        assert!(g.has_edge(5, 6));
        assert!(!g.has_edge(4, 5));
    }

    #[test]
    fn density_triangle() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2), (0, 2)]);
        assert!((g.density() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn degree_histogram_path() {
        let g = path5();
        assert_eq!(g.degree_histogram(), vec![0, 2, 3]);
    }

    #[test]
    fn csr_parts_roundtrip() {
        let g = path5();
        let (row_ptr, adj) = g.clone().into_parts();
        assert_eq!(row_ptr.len(), 6);
        assert_eq!(adj.len(), 8); // 4 undirected edges, stored twice
        let g2 = Graph::from_csr_parts(row_ptr, adj);
        assert_eq!(g2, g);
    }

    #[test]
    fn from_csr_parts_matches_from_edges() {
        let g = Graph::from_edges(4, &[(0, 1), (0, 2), (1, 2), (2, 3)]);
        let (rp, adj) = g.clone().into_parts();
        let rebuilt = Graph::from_csr_parts(rp, adj);
        assert_eq!(rebuilt.neighbors(2), &[0, 1, 3]);
        assert_eq!(rebuilt.num_edges(), 4);
        assert!(rebuilt.has_edge(0, 2));
        assert!(!rebuilt.has_edge(0, 3));
    }

    #[test]
    fn empty_graph() {
        let g = Graph::from_edges(0, &[]);
        assert_eq!(g.num_vertices(), 0);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.max_degree(), 0);
    }
}
