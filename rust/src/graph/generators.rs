//! Synthetic graph generators.
//!
//! The paper's evaluation graphs (Network Data Repository + PACE 2019)
//! are not redistributable inside this offline environment, so the
//! benchmark harness builds deterministic synthetic analogs from these
//! families. Each generator is seeded; equal seeds give equal graphs.

use super::Graph;
use crate::util::SplitMix64;

/// Erdős–Rényi G(n, p).
pub fn erdos_renyi(n: usize, p: f64, seed: u64) -> Graph {
    let mut rng = SplitMix64::new(seed);
    let mut edges = Vec::new();
    for u in 0..n as u32 {
        for v in (u + 1)..n as u32 {
            if rng.chance(p) {
                edges.push((u, v));
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// Erdős–Rényi with an exact edge count G(n, m).
pub fn gnm(n: usize, m: usize, seed: u64) -> Graph {
    let mut rng = SplitMix64::new(seed);
    let max = n * (n - 1) / 2;
    let m = m.min(max);
    let mut set = std::collections::HashSet::with_capacity(m * 2);
    let mut edges = Vec::with_capacity(m);
    while edges.len() < m {
        let u = rng.index(n) as u32;
        let v = rng.index(n) as u32;
        if u == v {
            continue;
        }
        let key = (u.min(v), u.max(v));
        if set.insert(key) {
            edges.push(key);
        }
    }
    Graph::from_edges(n, &edges)
}

/// Barabási–Albert preferential attachment: power-law degree
/// distribution, the web-crawl family (web-webbase, web-spam, wikipedia).
pub fn barabasi_albert(n: usize, m_per_node: usize, seed: u64) -> Graph {
    assert!(m_per_node >= 1 && n > m_per_node);
    let mut rng = SplitMix64::new(seed);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * m_per_node);
    // Repeated-endpoint list implements preferential attachment.
    let mut targets: Vec<u32> = (0..m_per_node as u32).collect();
    for v in m_per_node as u32..n as u32 {
        // Vec + contains keeps iteration order deterministic (a HashSet
        // here would make the stream depend on hash iteration order).
        let mut picked: Vec<u32> = Vec::with_capacity(m_per_node);
        while picked.len() < m_per_node {
            let t = targets[rng.index(targets.len())];
            if !picked.contains(&t) {
                picked.push(t);
            }
        }
        for &t in &picked {
            edges.push((v, t));
            targets.push(t);
            targets.push(v);
        }
    }
    Graph::from_edges(n, &edges)
}

/// 2D grid with optional random rewiring — the power-grid family
/// (power-eris1176, US power grid): sparse, low degree, splits readily.
pub fn grid(rows: usize, cols: usize, rewire_p: f64, seed: u64) -> Graph {
    let n = rows * cols;
    let mut rng = SplitMix64::new(seed);
    let id = |r: usize, c: usize| (r * cols + c) as u32;
    let mut edges = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                edges.push((id(r, c), id(r, c + 1)));
            }
            if r + 1 < rows {
                edges.push((id(r, c), id(r + 1, c)));
            }
        }
    }
    for e in edges.iter_mut() {
        if rng.chance(rewire_p) {
            let w = rng.index(n) as u32;
            if w != e.0 {
                e.1 = w;
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// DIMACS `c-fat` family analog: vertices on a ring, each connected to
/// the `band` nearest on each side — quasi-cliques chained in a circle.
/// Splits into exactly two components on nearly every branch.
pub fn c_fat(n: usize, band: usize, seed: u64) -> Graph {
    let mut rng = SplitMix64::new(seed);
    let mut edges = Vec::new();
    for u in 0..n {
        for d in 1..=band {
            let v = (u + d) % n;
            edges.push((u as u32, v as u32));
        }
    }
    // a sprinkle of chords, as in the DIMACS instances
    for _ in 0..n / 10 {
        let u = rng.index(n) as u32;
        let v = rng.index(n) as u32;
        if u != v {
            edges.push((u, v));
        }
    }
    Graph::from_edges(n, &edges)
}

/// DIMACS `p_hat` family analog: random graph with a *wide degree
/// spread* (each vertex gets its own edge probability drawn from
/// `[lo, hi]`). Dense, does not split — the family where the paper's
/// method loses to prior work (Table VI).
pub fn p_hat(n: usize, lo: f64, hi: f64, seed: u64) -> Graph {
    let mut rng = SplitMix64::new(seed);
    let probs: Vec<f64> = (0..n).map(|_| lo + (hi - lo) * rng.next_f64()).collect();
    let mut edges = Vec::new();
    for u in 0..n {
        for v in (u + 1)..n {
            let p = 0.5 * (probs[u] + probs[v]);
            if rng.chance(p) {
                edges.push((u as u32, v as u32));
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// Banded sparse-matrix graph — the `rajat` circuit-simulation family:
/// a diagonal band plus sparse random fill-in. Long thin structure that
/// fragments into many components during the search.
pub fn banded(n: usize, band: usize, fill_p: f64, fill_span: usize, seed: u64) -> Graph {
    let mut rng = SplitMix64::new(seed);
    let mut edges = Vec::new();
    for u in 0..n {
        for d in 1..=band {
            if u + d < n {
                edges.push((u as u32, (u + d) as u32));
            }
        }
        if rng.chance(fill_p) {
            let span = fill_span.min(n - 1).max(1);
            let v = (u + 1 + rng.index(span)) % n;
            if v != u {
                edges.push((u as u32, v as u32));
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// Random geometric graph on the unit square — the face-to-face contact
/// network family (scc-infect-dublin): local clustering, moderate density.
pub fn geometric(n: usize, radius: f64, seed: u64) -> Graph {
    let mut rng = SplitMix64::new(seed);
    let pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();
    let r2 = radius * radius;
    let mut edges = Vec::new();
    for u in 0..n {
        for v in (u + 1)..n {
            let dx = pts[u].0 - pts[v].0;
            let dy = pts[u].1 - pts[v].1;
            if dx * dx + dy * dy <= r2 {
                edges.push((u as u32, v as u32));
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// Bipartite rating-style graph (movielens analog): `left` users ×
/// `right` items, each user rates a geometric-ish number of items.
pub fn bipartite(left: usize, right: usize, avg_deg: f64, seed: u64) -> Graph {
    let mut rng = SplitMix64::new(seed);
    let n = left + right;
    let mut edges = Vec::new();
    for u in 0..left {
        // degree ~ 1 + Poisson-ish around avg_deg, via repeated bernoulli
        let mut d = 1 + rng.index((2.0 * avg_deg) as usize + 1);
        d = d.min(right);
        for it in rng.sample_distinct(right, d) {
            edges.push((u as u32, (left + it) as u32));
        }
    }
    Graph::from_edges(n, &edges)
}

/// Simple cycle C_n.
pub fn cycle(n: usize) -> Graph {
    let edges: Vec<(u32, u32)> =
        (0..n).map(|i| (i as u32, ((i + 1) % n) as u32)).collect();
    Graph::from_edges(n, &edges)
}

/// Complete graph K_n.
pub fn clique(n: usize) -> Graph {
    let mut edges = Vec::new();
    for u in 0..n as u32 {
        for v in (u + 1)..n as u32 {
            edges.push((u, v));
        }
    }
    Graph::from_edges(n, &edges)
}

/// Path P_n.
pub fn path(n: usize) -> Graph {
    let edges: Vec<(u32, u32)> = (1..n).map(|i| ((i - 1) as u32, i as u32)).collect();
    Graph::from_edges(n, &edges)
}

/// Star S_n (one hub, n-1 leaves).
pub fn star(n: usize) -> Graph {
    let edges: Vec<(u32, u32)> = (1..n as u32).map(|v| (0, v)).collect();
    Graph::from_edges(n, &edges)
}

/// Generalized Petersen graph GP(n, k): outer cycle, inner star polygon,
/// spokes. 3-regular and (for k=2, n≥5) triangle-free — immune to the
/// degree-1 / degree-2-triangle / special-component rules, so it keeps
/// the branch-and-reduce engine honest in tests.
pub fn generalized_petersen(n: usize, k: usize) -> Graph {
    assert!(n >= 3 && k >= 1 && k < n);
    let mut edges = Vec::with_capacity(3 * n);
    for i in 0..n {
        edges.push((i as u32, ((i + 1) % n) as u32)); // outer cycle
        edges.push(((n + i) as u32, (n + (i + k) % n) as u32)); // inner polygon
        edges.push((i as u32, (n + i) as u32)); // spoke
    }
    Graph::from_edges(2 * n, &edges)
}

/// The Petersen graph GP(5, 2).
pub fn petersen() -> Graph {
    generalized_petersen(5, 2)
}

/// Uniform random tree (random Prüfer-like attachment).
pub fn random_tree(n: usize, seed: u64) -> Graph {
    let mut rng = SplitMix64::new(seed);
    let mut edges = Vec::with_capacity(n.saturating_sub(1));
    for v in 1..n {
        edges.push((rng.index(v) as u32, v as u32));
    }
    Graph::from_edges(n, &edges)
}

/// Union of many small random components — the PROTEINS / SYNTHETIC
/// family: a dataset that is *already* a disjoint union of hundreds of
/// small graphs, the best case for component-aware branching.
pub fn union_of_random(
    num_parts: usize,
    part_lo: usize,
    part_hi: usize,
    p: f64,
    seed: u64,
) -> Graph {
    let mut rng = SplitMix64::new(seed);
    let parts: Vec<Graph> = (0..num_parts)
        .map(|_| {
            let n = rng.range(part_lo, part_hi);
            let mut sub = rng.split();
            // keep each part connected-ish: a random tree plus extra edges
            let tree = random_tree(n, sub.next_u64());
            let extra = erdos_renyi(n, p, sub.next_u64());
            let mut edges: Vec<(u32, u32)> = tree.edges().collect();
            edges.extend(extra.edges());
            Graph::from_edges(n, &edges)
        })
        .collect();
    Graph::disjoint_union(&parts)
}

/// Nested split gadget (paper §III): `depth = 0` is the Petersen graph
/// (3-regular, triangle-free — immune to every reduction rule and not a
/// special component); depth `d` joins a fresh hub to the first `5 + d`
/// vertices of each of two depth-`d−1` copies. The per-level attachment
/// count makes each hub degree `2·(5 + d)` — strictly above the inner
/// hubs (`2·(5 + d − 1)`) and every Petersen vertex (≤ `3 + d`), so the
/// hub is the *unique* maximum-degree vertex at every nesting level:
/// the engine branches hub-first, each covered hub disconnects its
/// gadget into the two sub-gadgets, and the search cascades through `d`
/// nested splits — the worst case for per-node payload memory and the
/// split-registry machinery. `|V| = 11·2^depth − 1`.
pub fn split_gadget(depth: usize) -> Graph {
    if depth == 0 {
        return petersen();
    }
    let part = split_gadget(depth - 1);
    let pn = part.num_vertices() as u32;
    let two = Graph::disjoint_union(&[part.clone(), part]);
    let hub = 2 * pn;
    let mut edges: Vec<(u32, u32)> = two.edges().collect();
    for i in 0..(5 + depth as u32) {
        edges.push((hub, i)); // first 5+d vertices of copy 1
        edges.push((hub, pn + i)); // and of copy 2
    }
    Graph::from_edges(2 * pn as usize + 1, &edges)
}

/// Web-crawl analog with pendant-tree fringe: a BA core with extra
/// degree-1/2 tendrils hanging off it (web-webbase-2001 reduces almost
/// entirely at the root thanks to these).
pub fn web_crawl(core_n: usize, fringe_n: usize, seed: u64) -> Graph {
    let mut rng = SplitMix64::new(seed);
    let core = barabasi_albert(core_n, 2, rng.next_u64());
    let n = core_n + fringe_n;
    let mut edges: Vec<(u32, u32)> = core.edges().collect();
    for v in core_n..n {
        // attach each fringe vertex under a random earlier vertex
        edges.push((rng.index(v) as u32, v as u32));
    }
    Graph::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::components;

    #[test]
    fn er_determinism() {
        let a = erdos_renyi(60, 0.1, 7);
        let b = erdos_renyi(60, 0.1, 7);
        assert_eq!(a, b);
        let c = erdos_renyi(60, 0.1, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn gnm_exact_edges() {
        let g = gnm(50, 100, 3);
        assert_eq!(g.num_edges(), 100);
    }

    #[test]
    fn ba_is_connected_and_powerlawish() {
        let g = barabasi_albert(300, 2, 5);
        assert_eq!(components::count(&g), 1);
        // hub exists: max degree well above the mean
        let mean = 2.0 * g.num_edges() as f64 / g.num_vertices() as f64;
        assert!(g.max_degree() as f64 > 3.0 * mean);
    }

    #[test]
    fn grid_shape() {
        let g = grid(4, 5, 0.0, 0);
        assert_eq!(g.num_vertices(), 20);
        assert_eq!(g.num_edges(), 4 * 4 + 3 * 5);
        assert_eq!(components::count(&g), 1);
    }

    #[test]
    fn cfat_ring_band() {
        let g = c_fat(60, 4, 1);
        assert!(g.num_edges() >= 60 * 4);
        assert_eq!(components::count(&g), 1);
    }

    #[test]
    fn p_hat_degree_spread() {
        let g = p_hat(80, 0.1, 0.7, 2);
        let h = g.degree_histogram();
        let lo = h.iter().take(h.len() / 3).sum::<usize>();
        assert!(lo < g.num_vertices(), "expected spread: {h:?}");
        assert!(g.density() > 0.2);
    }

    #[test]
    fn banded_sparse() {
        let g = banded(500, 2, 0.2, 50, 4);
        assert!(g.density() < 0.05);
    }

    #[test]
    fn geometric_local() {
        let g = geometric(120, 0.12, 9);
        assert!(g.num_edges() > 0);
    }

    #[test]
    fn bipartite_no_intra_side_edges() {
        let g = bipartite(30, 50, 3.0, 11);
        for (u, v) in g.edges() {
            let u_left = (u as usize) < 30;
            let v_left = (v as usize) < 30;
            assert_ne!(u_left, v_left, "edge within one side: {u}-{v}");
        }
    }

    #[test]
    fn basic_shapes() {
        assert_eq!(cycle(5).num_edges(), 5);
        assert_eq!(clique(6).num_edges(), 15);
        assert_eq!(path(7).num_edges(), 6);
        assert_eq!(star(8).num_edges(), 7);
        assert_eq!(random_tree(40, 1).num_edges(), 39);
        assert_eq!(components::count(&random_tree(40, 1)), 1);
    }

    #[test]
    fn union_has_many_components() {
        let g = union_of_random(25, 4, 9, 0.2, 13);
        assert_eq!(components::count(&g), 25);
    }

    #[test]
    fn web_crawl_connected() {
        let g = web_crawl(100, 300, 17);
        assert_eq!(g.num_vertices(), 400);
        assert_eq!(components::count(&g), 1);
    }

    #[test]
    fn split_gadget_shape() {
        assert_eq!(split_gadget(0), petersen());
        for depth in 1..=3usize {
            let g = split_gadget(depth);
            assert_eq!(g.num_vertices(), 11 * (1 << depth) - 1, "depth {depth}");
            assert_eq!(components::count(&g), 1, "depth {depth}: must start connected");
            let hub = (g.num_vertices() - 1) as u32;
            assert_eq!(g.degree(hub), 2 * (5 + depth as u32), "depth {depth}");
            // the hub strictly dominates every other degree — including
            // the inner hubs — so the engine's branch vertex is unique
            let snd = (0..hub).map(|v| g.degree(v)).max().unwrap();
            assert!(g.degree(hub) > snd, "depth {depth}: hub must be the unique branch vertex");
        }
    }
}
