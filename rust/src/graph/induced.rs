//! Induced subgraphs with vertex remapping.
//!
//! The paper's key memory optimization (§IV-B) runs reduction rules
//! exhaustively at the root, then *induces a subgraph* on the surviving
//! vertices so that degree arrays are sized to the reduced graph, not the
//! original. [`InducedSubgraph`] keeps the old→new and new→old maps so
//! solutions can be translated back to original vertex ids.
//!
//! [`induce_residual_into`] is the allocation-free sibling used *inside*
//! the search tree: when the engine splits on components it re-induces
//! each component as a compact CSR over caller-supplied (recycled)
//! buffers, so per-node state deep in the tree is sized to the component
//! rather than the root graph.

use super::Graph;
use crate::util::BitSet;

/// Build the CSR rows of the residual subgraph induced on `vertices`
/// into caller-supplied buffers (cleared first; no allocation beyond
/// their growth).
///
/// `vertices` must be sorted ascending and `map[v]` must hold the
/// compact id of every `v` in `vertices` (entries for other vertices are
/// ignored). `deg_of(v)` is the *residual* degree: nonzero means
/// present, and for present vertices it must equal the number of present
/// static neighbors — the count lets each row stop scanning early.
/// `vertices` must be closed under residual adjacency (a residual
/// component, or a union of them), so every present neighbor has a map
/// entry. Because `vertices` is sorted, the renumbering is monotonic and
/// the produced rows stay sorted, as [`Graph::from_csr_parts`] requires.
pub fn induce_residual_into(
    g: &Graph,
    vertices: &[u32],
    map: &[u32],
    deg_of: impl Fn(u32) -> u32,
    row_ptr: &mut Vec<u32>,
    adj: &mut Vec<u32>,
) {
    row_ptr.clear();
    adj.clear();
    row_ptr.push(0);
    for &v in vertices {
        let mut remaining = deg_of(v);
        for &w in g.neighbors(v) {
            if remaining == 0 {
                break;
            }
            if deg_of(w) > 0 {
                adj.push(map[w as usize]);
                remaining -= 1;
            }
        }
        debug_assert_eq!(remaining, 0, "residual degree of {v} out of sync");
        row_ptr.push(adj.len() as u32);
    }
}

/// Canonical fingerprint of an induced-component CSR, used as the key
/// of the cross-job memo cache (`solver::memo`).
///
/// [`induce_residual_into`] renumbers a component's vertices `0..k` in
/// ascending parent-id order and emits sorted rows, so structurally
/// identical components produce bit-identical `(row_ptr, adj)` arrays —
/// the fingerprint hashes exactly those words (plus the dimensions;
/// `row_ptr` already encodes the full degree profile). FNV-1a over the
/// words with a splitmix64-style avalanche finisher: cheap, word-at-a-
/// time, and well mixed in the high bits (the cache shards on them).
/// Collisions are harmless — the cache verifies every lookup against
/// the retained arrays byte-for-byte.
pub fn fingerprint_csr(row_ptr: &[u32], adj: &[u32]) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |w: u64| {
        h ^= w;
        h = h.wrapping_mul(PRIME);
    };
    mix(row_ptr.len() as u64);
    mix(adj.len() as u64);
    for &w in row_ptr {
        mix(w as u64);
    }
    for &w in adj {
        mix(w as u64);
    }
    // splitmix64 finisher: avalanche so shard selection on high bits
    // and bucket selection on low bits are both uniform.
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58476d1ce4e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d049bb133111eb);
    h ^ (h >> 31)
}

/// A subgraph induced on a vertex subset, with id translation maps.
#[derive(Debug, Clone)]
pub struct InducedSubgraph {
    /// The induced graph over compacted ids `0..keep.len()`.
    pub graph: Graph,
    /// new id → original id.
    pub to_original: Vec<u32>,
    /// original id → new id (`u32::MAX` if dropped).
    pub from_original: Vec<u32>,
}

impl InducedSubgraph {
    /// Induce on the vertices whose bit is set in `keep`.
    pub fn new(g: &Graph, keep: &BitSet) -> InducedSubgraph {
        assert_eq!(keep.len(), g.num_vertices());
        let to_original: Vec<u32> = keep.iter_ones().map(|v| v as u32).collect();
        let mut from_original = vec![u32::MAX; g.num_vertices()];
        for (new, &orig) in to_original.iter().enumerate() {
            from_original[orig as usize] = new as u32;
        }
        let mut edges = Vec::new();
        for &orig in &to_original {
            let nu = from_original[orig as usize];
            for &w in g.neighbors(orig) {
                let nw = from_original[w as usize];
                if nw != u32::MAX && nu < nw {
                    edges.push((nu, nw));
                }
            }
        }
        let graph = Graph::from_edges(to_original.len(), &edges);
        InducedSubgraph { graph, to_original, from_original }
    }

    /// Induce on an explicit vertex list (order preserved, must be unique).
    pub fn from_vertices(g: &Graph, vertices: &[u32]) -> InducedSubgraph {
        let mut keep = BitSet::new(g.num_vertices());
        for &v in vertices {
            keep.set(v as usize);
        }
        assert_eq!(keep.count(), vertices.len(), "duplicate vertices");
        InducedSubgraph::new(g, &keep)
    }

    /// Translate a cover over the induced graph back to original ids.
    pub fn translate_cover(&self, cover: &[u32]) -> Vec<u32> {
        cover.iter().map(|&v| self.to_original[v as usize]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn induce_middle_of_path() {
        let g = generators::path(5); // 0-1-2-3-4
        let ind = InducedSubgraph::from_vertices(&g, &[1, 2, 3]);
        assert_eq!(ind.graph.num_vertices(), 3);
        assert_eq!(ind.graph.num_edges(), 2);
        assert_eq!(ind.to_original, vec![1, 2, 3]);
        assert_eq!(ind.from_original[0], u32::MAX);
        assert_eq!(ind.from_original[2], 1);
    }

    #[test]
    fn translate_cover_roundtrip() {
        let g = generators::cycle(6);
        let ind = InducedSubgraph::from_vertices(&g, &[2, 3, 4]);
        // induced graph is the path 2-3-4 → cover {3} (new id 1)
        let cover = ind.translate_cover(&[1]);
        assert_eq!(cover, vec![3]);
    }

    #[test]
    fn induced_edges_only_within_subset() {
        let g = generators::clique(6);
        let ind = InducedSubgraph::from_vertices(&g, &[0, 2, 4]);
        assert_eq!(ind.graph.num_edges(), 3); // K3
    }

    #[test]
    fn empty_induce() {
        let g = generators::path(4);
        let keep = BitSet::new(4);
        let ind = InducedSubgraph::new(&g, &keep);
        assert_eq!(ind.graph.num_vertices(), 0);
    }

    #[test]
    fn induce_residual_component_of_cycle() {
        // cycle 0-1-2-3-4-5; remove 0 and 3 from the residual: two path
        // components {1,2} and {4,5}
        let g = generators::cycle(6);
        let deg = [0u32, 1, 1, 0, 1, 1];
        let mut map = vec![u32::MAX; 6];
        let comp = [4u32, 5];
        for (i, &v) in comp.iter().enumerate() {
            map[v as usize] = i as u32;
        }
        let mut row_ptr = Vec::new();
        let mut adj = Vec::new();
        induce_residual_into(&g, &comp, &map, |v| deg[v as usize], &mut row_ptr, &mut adj);
        let sub = Graph::from_csr_parts(row_ptr, adj);
        assert_eq!(sub.num_vertices(), 2);
        assert_eq!(sub.num_edges(), 1);
        assert!(sub.has_edge(0, 1));
    }

    #[test]
    fn induce_residual_matches_induced_subgraph() {
        // With every vertex present, the residual induction over a
        // component must agree with the set-based InducedSubgraph.
        let g = Graph::disjoint_union(&[generators::clique(4), generators::path(3)]);
        let deg: Vec<u32> = (0..g.num_vertices() as u32).map(|v| g.degree(v)).collect();
        let comp: Vec<u32> = vec![0, 1, 2, 3];
        let mut map = vec![u32::MAX; g.num_vertices()];
        for (i, &v) in comp.iter().enumerate() {
            map[v as usize] = i as u32;
        }
        let (mut row_ptr, mut adj) = (Vec::new(), Vec::new());
        induce_residual_into(&g, &comp, &map, |v| deg[v as usize], &mut row_ptr, &mut adj);
        let sub = Graph::from_csr_parts(row_ptr, adj);
        let reference = InducedSubgraph::from_vertices(&g, &comp);
        assert_eq!(sub, reference.graph);
    }

    #[test]
    fn induce_residual_reuses_buffers() {
        let g = generators::path(4); // 0-1-2-3, all present
        let deg: Vec<u32> = (0..4u32).map(|v| g.degree(v)).collect();
        let comp: Vec<u32> = vec![0, 1, 2, 3];
        let mut map = vec![u32::MAX; 4];
        for (i, &v) in comp.iter().enumerate() {
            map[v as usize] = i as u32;
        }
        // dirty buffers must be cleared, not appended to
        let mut row_ptr = vec![9, 9, 9];
        let mut adj = vec![7; 10];
        induce_residual_into(&g, &comp, &map, |v| deg[v as usize], &mut row_ptr, &mut adj);
        assert_eq!(row_ptr.len(), 5);
        assert_eq!(adj.len(), 6);
        let sub = Graph::from_csr_parts(row_ptr, adj);
        assert_eq!(sub.num_edges(), 3);
    }

    #[test]
    fn fingerprint_distinguishes_structure_not_origin() {
        // The same structure induced from different host graphs (and
        // different original ids) fingerprints identically...
        let g1 = generators::cycle(8);
        let g2 = Graph::disjoint_union(&[generators::clique(3), generators::cycle(8)]);
        let build = |g: &Graph, comp: &[u32]| {
            let mut map = vec![u32::MAX; g.num_vertices()];
            for (i, &v) in comp.iter().enumerate() {
                map[v as usize] = i as u32;
            }
            let deg: Vec<u32> = (0..g.num_vertices() as u32).map(|v| g.degree(v)).collect();
            let (mut rp, mut aj) = (Vec::new(), Vec::new());
            induce_residual_into(g, comp, &map, |v| deg[v as usize], &mut rp, &mut aj);
            (rp, aj)
        };
        let (rp1, aj1) = build(&g1, &[0, 1, 2, 3, 4, 5, 6, 7]);
        let (rp2, aj2) = build(&g2, &[3, 4, 5, 6, 7, 8, 9, 10]);
        assert_eq!((&rp1, &aj1), (&rp2, &aj2), "canonical CSR must be id-independent");
        assert_eq!(fingerprint_csr(&rp1, &aj1), fingerprint_csr(&rp2, &aj2));
        // ...while different structures differ.
        let g3 = generators::path(8);
        let (rp3, aj3) = build(&g3, &[0, 1, 2, 3, 4, 5, 6, 7]);
        assert_ne!(fingerprint_csr(&rp1, &aj1), fingerprint_csr(&rp3, &aj3));
        // Degenerate shapes don't alias: empty vs singleton vs edgeless pair.
        assert_ne!(fingerprint_csr(&[0], &[]), fingerprint_csr(&[0, 0], &[]));
        assert_ne!(fingerprint_csr(&[0, 0], &[]), fingerprint_csr(&[0, 0, 0], &[]));
    }

    #[test]
    fn full_induce_is_identity() {
        let g = generators::erdos_renyi(40, 0.1, 3);
        let mut keep = BitSet::new(40);
        for i in 0..40 {
            keep.set(i);
        }
        let ind = InducedSubgraph::new(&g, &keep);
        assert_eq!(ind.graph, g);
        assert_eq!(ind.to_original, (0..40).collect::<Vec<u32>>());
    }
}
