//! Induced subgraphs with vertex remapping.
//!
//! The paper's key memory optimization (§IV-B) runs reduction rules
//! exhaustively at the root, then *induces a subgraph* on the surviving
//! vertices so that degree arrays are sized to the reduced graph, not the
//! original. [`InducedSubgraph`] keeps the old→new and new→old maps so
//! solutions can be translated back to original vertex ids.

use super::Graph;
use crate::util::BitSet;

/// A subgraph induced on a vertex subset, with id translation maps.
#[derive(Debug, Clone)]
pub struct InducedSubgraph {
    /// The induced graph over compacted ids `0..keep.len()`.
    pub graph: Graph,
    /// new id → original id.
    pub to_original: Vec<u32>,
    /// original id → new id (`u32::MAX` if dropped).
    pub from_original: Vec<u32>,
}

impl InducedSubgraph {
    /// Induce on the vertices whose bit is set in `keep`.
    pub fn new(g: &Graph, keep: &BitSet) -> InducedSubgraph {
        assert_eq!(keep.len(), g.num_vertices());
        let to_original: Vec<u32> = keep.iter_ones().map(|v| v as u32).collect();
        let mut from_original = vec![u32::MAX; g.num_vertices()];
        for (new, &orig) in to_original.iter().enumerate() {
            from_original[orig as usize] = new as u32;
        }
        let mut edges = Vec::new();
        for &orig in &to_original {
            let nu = from_original[orig as usize];
            for &w in g.neighbors(orig) {
                let nw = from_original[w as usize];
                if nw != u32::MAX && nu < nw {
                    edges.push((nu, nw));
                }
            }
        }
        let graph = Graph::from_edges(to_original.len(), &edges);
        InducedSubgraph { graph, to_original, from_original }
    }

    /// Induce on an explicit vertex list (order preserved, must be unique).
    pub fn from_vertices(g: &Graph, vertices: &[u32]) -> InducedSubgraph {
        let mut keep = BitSet::new(g.num_vertices());
        for &v in vertices {
            keep.set(v as usize);
        }
        assert_eq!(keep.count(), vertices.len(), "duplicate vertices");
        InducedSubgraph::new(g, &keep)
    }

    /// Translate a cover over the induced graph back to original ids.
    pub fn translate_cover(&self, cover: &[u32]) -> Vec<u32> {
        cover.iter().map(|&v| self.to_original[v as usize]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn induce_middle_of_path() {
        let g = generators::path(5); // 0-1-2-3-4
        let ind = InducedSubgraph::from_vertices(&g, &[1, 2, 3]);
        assert_eq!(ind.graph.num_vertices(), 3);
        assert_eq!(ind.graph.num_edges(), 2);
        assert_eq!(ind.to_original, vec![1, 2, 3]);
        assert_eq!(ind.from_original[0], u32::MAX);
        assert_eq!(ind.from_original[2], 1);
    }

    #[test]
    fn translate_cover_roundtrip() {
        let g = generators::cycle(6);
        let ind = InducedSubgraph::from_vertices(&g, &[2, 3, 4]);
        // induced graph is the path 2-3-4 → cover {3} (new id 1)
        let cover = ind.translate_cover(&[1]);
        assert_eq!(cover, vec![3]);
    }

    #[test]
    fn induced_edges_only_within_subset() {
        let g = generators::clique(6);
        let ind = InducedSubgraph::from_vertices(&g, &[0, 2, 4]);
        assert_eq!(ind.graph.num_edges(), 3); // K3
    }

    #[test]
    fn empty_induce() {
        let g = generators::path(4);
        let keep = BitSet::new(4);
        let ind = InducedSubgraph::new(&g, &keep);
        assert_eq!(ind.graph.num_vertices(), 0);
    }

    #[test]
    fn full_induce_is_identity() {
        let g = generators::erdos_renyi(40, 0.1, 3);
        let mut keep = BitSet::new(40);
        for i in 0..40 {
            keep.set(i);
        }
        let ind = InducedSubgraph::new(&g, &keep);
        assert_eq!(ind.graph, g);
        assert_eq!(ind.to_original, (0..40).collect::<Vec<u32>>());
    }
}
