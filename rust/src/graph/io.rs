//! Graph readers and writers.
//!
//! Formats: whitespace edge lists (SNAP / Network Data Repository style),
//! MatrixMarket `.mtx` pattern matrices, DIMACS clique/coloring files
//! (`p edge n m`, `e u v`), and PACE 2019 `.gr` vertex-cover instances
//! (`p td n m`). All formats use the detected parser through
//! [`read_graph`]; vertices are normalized to `0..n`.

use super::Graph;
use crate::bail;
use crate::util::error::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Supported on-disk formats.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    /// `u v` per line, `#`/`%` comments, ids 0- or 1-based (auto).
    EdgeList,
    /// MatrixMarket coordinate pattern (1-based).
    MatrixMarket,
    /// DIMACS: `p edge n m`, edges as `e u v` (1-based).
    Dimacs,
    /// PACE 2019 `.gr`: `p td n m`, edges `u v` (1-based), `c` comments.
    Pace,
}

impl Format {
    /// Infer from a file extension, defaulting to edge list.
    pub fn from_path(path: &Path) -> Format {
        match path.extension().and_then(|e| e.to_str()).unwrap_or("") {
            "mtx" => Format::MatrixMarket,
            "dimacs" | "col" | "clq" => Format::Dimacs,
            "gr" => Format::Pace,
            _ => Format::EdgeList,
        }
    }
}

/// Read a graph from `path`, inferring the format from the extension.
pub fn read_graph(path: &Path) -> Result<Graph> {
    let file = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    read_graph_from(BufReader::new(file), Format::from_path(path))
}

/// Read a graph in a specific format from any reader.
pub fn read_graph_from<R: BufRead>(reader: R, format: Format) -> Result<Graph> {
    match format {
        Format::EdgeList => read_edge_list(reader),
        Format::MatrixMarket => read_mtx(reader),
        Format::Dimacs => read_dimacs(reader),
        Format::Pace => read_pace(reader),
    }
}

fn parse_two(line: &str) -> Option<(u64, u64)> {
    let mut it = line.split_whitespace();
    let a = it.next()?.parse().ok()?;
    let b = it.next()?.parse().ok()?;
    Some((a, b))
}

fn normalize(pairs: Vec<(u64, u64)>, declared_n: Option<u64>, one_based: bool) -> Graph {
    let shift = u64::from(one_based);
    let edges: Vec<(u32, u32)> = pairs
        .iter()
        .map(|&(a, b)| ((a - shift) as u32, (b - shift) as u32))
        .collect();
    let max_seen = edges.iter().map(|&(a, b)| a.max(b) as u64 + 1).max().unwrap_or(0);
    let n = declared_n.unwrap_or(max_seen).max(max_seen) as usize;
    Graph::from_edges(n, &edges)
}

fn read_edge_list<R: BufRead>(reader: R) -> Result<Graph> {
    let mut pairs = Vec::new();
    let mut min_id = u64::MAX;
    for line in reader.lines() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let (a, b) = parse_two(t).with_context(|| format!("bad edge line: {t:?}"))?;
        min_id = min_id.min(a).min(b);
        pairs.push((a, b));
    }
    // Heuristic: a file that never mentions vertex 0 is 1-based.
    let one_based = min_id != u64::MAX && min_id >= 1;
    Ok(normalize(pairs, None, one_based))
}

fn read_mtx<R: BufRead>(reader: R) -> Result<Graph> {
    let mut lines = reader.lines();
    let header = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                let t = l.trim().to_string();
                if !t.is_empty() && !t.starts_with('%') {
                    break t;
                }
            }
            None => bail!("mtx: missing size header"),
        }
    };
    let mut it = header.split_whitespace();
    let rows: u64 = it.next().context("mtx rows")?.parse()?;
    let cols: u64 = it.next().context("mtx cols")?.parse()?;
    let n = rows.max(cols);
    let mut pairs = Vec::new();
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let (a, b) = parse_two(t).with_context(|| format!("bad mtx line: {t:?}"))?;
        pairs.push((a, b));
    }
    Ok(normalize(pairs, Some(n), true))
}

fn read_dimacs<R: BufRead>(reader: R) -> Result<Graph> {
    let mut n: Option<u64> = None;
    let mut pairs = Vec::new();
    for line in reader.lines() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('c') {
            continue;
        }
        if let Some(rest) = t.strip_prefix("p ") {
            let mut it = rest.split_whitespace();
            let _kind = it.next();
            n = Some(it.next().context("dimacs: p line n")?.parse()?);
        } else if let Some(rest) = t.strip_prefix("e ") {
            let (a, b) = parse_two(rest).with_context(|| format!("bad dimacs edge: {t:?}"))?;
            pairs.push((a, b));
        }
    }
    if n.is_none() {
        bail!("dimacs: missing p line");
    }
    Ok(normalize(pairs, n, true))
}

fn read_pace<R: BufRead>(reader: R) -> Result<Graph> {
    let mut n: Option<u64> = None;
    let mut pairs = Vec::new();
    for line in reader.lines() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('c') {
            continue;
        }
        if let Some(rest) = t.strip_prefix("p ") {
            let mut it = rest.split_whitespace();
            let _td = it.next();
            n = Some(it.next().context("pace: p line n")?.parse()?);
        } else {
            let (a, b) = parse_two(t).with_context(|| format!("bad pace edge: {t:?}"))?;
            pairs.push((a, b));
        }
    }
    if n.is_none() {
        bail!("pace: missing `p td n m` line");
    }
    Ok(normalize(pairs, n, true))
}

/// Write a graph as a PACE `.gr` instance.
pub fn write_pace<W: Write>(g: &Graph, mut w: W) -> Result<()> {
    writeln!(w, "p td {} {}", g.num_vertices(), g.num_edges())?;
    for (u, v) in g.edges() {
        writeln!(w, "{} {}", u + 1, v + 1)?;
    }
    Ok(())
}

/// Write a graph as a 0-based edge list.
pub fn write_edge_list<W: Write>(g: &Graph, mut w: W) -> Result<()> {
    writeln!(w, "# cavc edge list: n={} m={}", g.num_vertices(), g.num_edges())?;
    for (u, v) in g.edges() {
        writeln!(w, "{u} {v}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn edge_list_zero_based() {
        let g = read_graph_from(Cursor::new("# c\n0 1\n1 2\n"), Format::EdgeList).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 2);
    }

    #[test]
    fn edge_list_one_based_autodetect() {
        let g = read_graph_from(Cursor::new("1 2\n2 3\n"), Format::EdgeList).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert!(g.has_edge(0, 1) && g.has_edge(1, 2));
    }

    #[test]
    fn mtx_roundtrip() {
        let src = "%%MatrixMarket matrix coordinate pattern symmetric\n% c\n4 4 3\n1 2\n2 3\n4 1\n";
        let g = read_graph_from(Cursor::new(src), Format::MatrixMarket).unwrap();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 3);
        assert!(g.has_edge(0, 3));
    }

    #[test]
    fn dimacs_parse() {
        let src = "c comment\np edge 5 3\ne 1 2\ne 2 3\ne 4 5\n";
        let g = read_graph_from(Cursor::new(src), Format::Dimacs).unwrap();
        assert_eq!(g.num_vertices(), 5);
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn pace_roundtrip() {
        let src = "c x\np td 4 2\n1 2\n3 4\n";
        let g = read_graph_from(Cursor::new(src), Format::Pace).unwrap();
        assert_eq!(g.num_vertices(), 4);
        let mut buf = Vec::new();
        write_pace(&g, &mut buf).unwrap();
        let g2 = read_graph_from(Cursor::new(buf), Format::Pace).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn edge_list_write_read() {
        let g = Graph::from_edges(4, &[(0, 1), (2, 3), (1, 2)]);
        let mut buf = Vec::new();
        write_edge_list(&g, &mut buf).unwrap();
        let g2 = read_graph_from(Cursor::new(buf), Format::EdgeList).unwrap();
        assert_eq!(g, g2);
    }

    #[test]
    fn format_from_extension() {
        assert_eq!(Format::from_path(Path::new("a.mtx")), Format::MatrixMarket);
        assert_eq!(Format::from_path(Path::new("a.gr")), Format::Pace);
        assert_eq!(Format::from_path(Path::new("a.clq")), Format::Dimacs);
        assert_eq!(Format::from_path(Path::new("a.txt")), Format::EdgeList);
    }

    #[test]
    fn dimacs_missing_p_line_errors() {
        assert!(read_graph_from(Cursor::new("e 1 2\n"), Format::Dimacs).is_err());
    }
}
