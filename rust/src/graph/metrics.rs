//! Structural metrics used by the harness tables and the `info` CLI verb.

use super::{components, Graph};

/// Summary statistics for a graph.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphMetrics {
    /// |V|.
    pub n: usize,
    /// |E|.
    pub m: usize,
    /// Δ(G).
    pub max_degree: u32,
    /// Mean degree 2m/n.
    pub avg_degree: f64,
    /// Edge density in [0,1].
    pub density: f64,
    /// Connected components.
    pub components: usize,
    /// Vertices with degree 0.
    pub isolated: usize,
    /// Vertices with degree 1 (prime degree-one-rule targets).
    pub degree_one: usize,
    /// Triangle count (sum over edges of common neighbors / 3).
    pub triangles: u64,
}

/// Compute all metrics. Triangle counting is `O(Σ d(v)^2)` via sorted
/// adjacency intersection — fine at harness scale.
pub fn compute(g: &Graph) -> GraphMetrics {
    let n = g.num_vertices();
    let m = g.num_edges();
    let mut isolated = 0;
    let mut degree_one = 0;
    for v in 0..n as u32 {
        match g.degree(v) {
            0 => isolated += 1,
            1 => degree_one += 1,
            _ => {}
        }
    }
    GraphMetrics {
        n,
        m,
        max_degree: g.max_degree(),
        avg_degree: if n == 0 { 0.0 } else { 2.0 * m as f64 / n as f64 },
        density: g.density(),
        components: components::count(g),
        isolated,
        degree_one,
        triangles: triangle_count(g),
    }
}

/// Total number of triangles in the graph.
pub fn triangle_count(g: &Graph) -> u64 {
    let mut total = 0u64;
    for (u, v) in g.edges() {
        total += sorted_intersection_size(g.neighbors(u), g.neighbors(v)) as u64;
    }
    total / 3
}

/// Per-vertex triangle membership counts (cross-checked against the
/// XLA triangle-census artifact in `runtime::accel`).
pub fn triangles_per_vertex(g: &Graph) -> Vec<u32> {
    let n = g.num_vertices();
    let mut t = vec![0u32; n];
    for (u, v) in g.edges() {
        let (mut i, mut j) = (0, 0);
        let (nu, nv) = (g.neighbors(u), g.neighbors(v));
        while i < nu.len() && j < nv.len() {
            match nu[i].cmp(&nv[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    let w = nu[i];
                    if w > v {
                        // count each triangle once at its smallest edge
                        t[u as usize] += 1;
                        t[v as usize] += 1;
                        t[w as usize] += 1;
                    }
                    i += 1;
                    j += 1;
                }
            }
        }
    }
    t
}

fn sorted_intersection_size(a: &[u32], b: &[u32]) -> usize {
    let (mut i, mut j, mut k) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                k += 1;
                i += 1;
                j += 1;
            }
        }
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn triangle_count_known() {
        assert_eq!(triangle_count(&generators::clique(4)), 4);
        assert_eq!(triangle_count(&generators::clique(5)), 10);
        assert_eq!(triangle_count(&generators::cycle(5)), 0);
        assert_eq!(triangle_count(&generators::cycle(3)), 1);
    }

    #[test]
    fn per_vertex_sums_to_three_times_total() {
        let g = generators::erdos_renyi(60, 0.1, 5);
        let per = triangles_per_vertex(&g);
        let total: u64 = per.iter().map(|&x| x as u64).sum();
        assert_eq!(total, 3 * triangle_count(&g));
    }

    #[test]
    fn metrics_path() {
        let m = compute(&generators::path(5));
        assert_eq!(m.n, 5);
        assert_eq!(m.m, 4);
        assert_eq!(m.components, 1);
        assert_eq!(m.degree_one, 2);
        assert_eq!(m.isolated, 0);
        assert_eq!(m.triangles, 0);
    }

    #[test]
    fn metrics_counts_isolated() {
        let g = Graph::from_edges(4, &[(0, 1)]);
        let m = compute(&g);
        assert_eq!(m.isolated, 2);
        assert_eq!(m.components, 3);
    }
}
