//! Graph substrate: static CSR representation, IO, synthetic generators,
//! connected components, induced subgraphs, and structural metrics.
//!
//! The solver treats the graph as immutable; all intermediate state during
//! branch-and-reduce lives in the degree array (see [`crate::degree`]),
//! exactly as in the paper's CSR + degree-array representation.

pub mod components;
pub mod csr;
pub mod generators;
pub mod induced;
pub mod io;
pub mod metrics;

pub use csr::Graph;
pub use induced::InducedSubgraph;
