//! The benchmark dataset suite.
//!
//! The paper evaluates on Network Data Repository and PACE 2019 graphs
//! which cannot be redistributed or downloaded in this offline
//! environment. Each evaluation graph is therefore replaced by a
//! *deterministic synthetic analog* from the same structural family,
//! scaled so the whole suite runs in minutes on a CPU (see DESIGN.md
//! §Dataset-substitution). The analog preserves the property that drives
//! the paper's result for that row: density regime, degree distribution,
//! reducibility at the root, and the tendency to split into components.

use crate::graph::{generators, Graph};

/// One dataset of the evaluation suite.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Analog name: the paper's dataset it stands in for.
    pub name: &'static str,
    /// Structural family of the analog.
    pub family: &'static str,
    /// |V|/|E| of the paper's original (for the table header).
    pub paper_nv: usize,
    /// |E| of the paper's original.
    pub paper_ne: usize,
    /// Generator.
    build: fn() -> Graph,
}

impl Dataset {
    /// Build the graph (deterministic).
    pub fn build(&self) -> Graph {
        (self.build)()
    }
}

/// The Table I/II/III/IV/V suite: one analog per paper dataset, ordered
/// as in the paper.
pub fn suite() -> Vec<Dataset> {
    vec![
        Dataset {
            name: "web-webbase-2001",
            family: "web crawl (BA core + pendant fringe)",
            paper_nv: 16_062,
            paper_ne: 25_593,
            build: || generators::web_crawl(260, 1_340, 0xCA01),
        },
        Dataset {
            name: "power-eris1176",
            family: "power grid (grid + rewire)",
            paper_nv: 1_176,
            paper_ne: 8_688,
            build: || generators::grid(12, 16, 0.08, 0xCA02),
        },
        Dataset {
            name: "movielens-100k",
            family: "bipartite ratings",
            paper_nv: 2_625,
            paper_ne: 94_834,
            build: || generators::bipartite(90, 260, 7.0, 0xCA03),
        },
        Dataset {
            name: "qc324",
            family: "dense quantum-chemistry matrix",
            paper_nv: 324,
            paper_ne: 13_203,
            build: || generators::gnm(90, 1_010, 0xCA04),
        },
        Dataset {
            name: "SYNTHETIC",
            family: "300 disjoint random parts",
            paper_nv: 30_000,
            paper_ne: 58_800,
            build: || generators::union_of_random(300, 6, 12, 0.20, 0xCA05),
        },
        Dataset {
            name: "SYNTHETICnew",
            family: "300 disjoint random parts (alt seed)",
            paper_nv: 30_000,
            paper_ne: 58_875,
            build: || generators::union_of_random(300, 6, 12, 0.21, 0xCA06),
        },
        Dataset {
            name: "vc-exact-017",
            family: "PACE: sparse tree/cycle mix",
            paper_nv: 23_541,
            paper_ne: 34_233,
            build: || generators::banded(380, 1, 0.35, 60, 0xCA07),
        },
        Dataset {
            name: "vc-exact-029",
            family: "PACE: sparse near-tree",
            paper_nv: 13_431,
            paper_ne: 16_234,
            build: || generators::banded(420, 1, 0.25, 200, 0xCA08),
        },
        Dataset {
            name: "c-fat500-5",
            family: "ring of quasi-cliques",
            paper_nv: 500,
            paper_ne: 23_191,
            build: || generators::c_fat(110, 8, 0xCA09),
        },
        Dataset {
            name: "scc-infect-dublin",
            family: "face-to-face contact (geometric)",
            paper_nv: 10_972,
            paper_ne: 175_573,
            build: || generators::geometric(280, 0.08, 0xCA0A),
        },
        Dataset {
            name: "rajat28",
            family: "banded circuit matrix",
            paper_nv: 87_190,
            paper_ne: 263_606,
            build: || generators::banded(320, 2, 0.28, 90, 0xCA0B),
        },
        Dataset {
            name: "rajat20",
            family: "banded circuit matrix",
            paper_nv: 86_916,
            paper_ne: 262_648,
            build: || generators::banded(310, 2, 0.28, 90, 0xCA0C),
        },
        Dataset {
            name: "mhda416",
            family: "small dense MHD matrix",
            paper_nv: 416,
            paper_ne: 5_177,
            build: || generators::gnm(110, 760, 0xCA0D),
        },
        Dataset {
            name: "rajat17",
            family: "banded circuit matrix",
            paper_nv: 94_294,
            paper_ne: 277_444,
            build: || generators::banded(300, 2, 0.30, 100, 0xCA0E),
        },
        Dataset {
            name: "rajat18",
            family: "banded circuit matrix",
            paper_nv: 94_294,
            paper_ne: 270_253,
            build: || generators::banded(300, 2, 0.28, 100, 0xCA0F),
        },
        Dataset {
            name: "web-spam",
            family: "web host graph (dense BA)",
            paper_nv: 4_767,
            paper_ne: 37_375,
            build: || generators::barabasi_albert(170, 5, 0xCA10),
        },
        Dataset {
            name: "PROTEINS-full",
            family: "union of many protein graphs",
            paper_nv: 43_471,
            paper_ne: 81_044,
            build: || {
                // unions of rewired grids: reduction-resistant parts that
                // force genuine branching inside every component
                let mut parts: Vec<Graph> = (0..3)
                    .map(|i| generators::grid(12, 16, 0.08, 0xCA11 + i))
                    .collect();
                parts.push(generators::union_of_random(60, 8, 16, 0.2, 0xCA12));
                Graph::disjoint_union(&parts)
            },
        },
    ]
}

/// The Table VI suite (prior work's own datasets): low-degree graphs the
/// proposed solution wins on, plus the dense `p_hat` family it loses on.
pub fn table6_suite() -> Vec<Dataset> {
    vec![
        Dataset {
            name: "US-power-grid",
            family: "power grid",
            paper_nv: 4_941,
            paper_ne: 6_594,
            build: || generators::grid(12, 16, 0.08, 0xC601),
        },
        Dataset {
            name: "Sister-Cities",
            family: "sparse social",
            paper_nv: 14_274,
            paper_ne: 20_573,
            build: || generators::union_of_random(90, 5, 14, 0.12, 0xC602),
        },
        Dataset {
            name: "LastFM-Asia",
            family: "social (BA)",
            paper_nv: 7_624,
            paper_ne: 27_806,
            build: || generators::barabasi_albert(700, 2, 0xC603),
        },
        Dataset {
            name: "movielens-100k",
            family: "bipartite ratings",
            paper_nv: 2_625,
            paper_ne: 94_834,
            build: || generators::bipartite(90, 260, 7.0, 0xCA03),
        },
        Dataset {
            name: "wikipedia_link_lo",
            family: "web crawl",
            paper_nv: 3_811,
            paper_ne: 102_746,
            build: || generators::web_crawl(220, 900, 0xC604),
        },
        Dataset {
            name: "p_hat300-1",
            family: "dense, wide degree spread",
            paper_nv: 300,
            paper_ne: 10_933,
            build: || generators::p_hat(72, 0.10, 0.40, 0xC605),
        },
        Dataset {
            name: "p_hat300-2",
            family: "dense, wide degree spread",
            paper_nv: 300,
            paper_ne: 21_928,
            build: || generators::p_hat(72, 0.25, 0.70, 0xC606),
        },
        Dataset {
            name: "p_hat500-1",
            family: "dense, wide degree spread",
            paper_nv: 500,
            paper_ne: 31_569,
            build: || generators::p_hat(84, 0.10, 0.40, 0xC607),
        },
        Dataset {
            name: "p_hat700-1",
            family: "dense, wide degree spread",
            paper_nv: 700,
            paper_ne: 60_999,
            build: || generators::p_hat(92, 0.10, 0.40, 0xC608),
        },
    ]
}

/// Look up a dataset by name across both suites.
pub fn dataset(name: &str) -> Option<Dataset> {
    suite().into_iter().chain(table6_suite()).find(|d| d.name == name)
}

/// Small, fast subset for smoke tests and the quickstart example.
pub fn smoke_suite() -> Vec<Dataset> {
    suite()
        .into_iter()
        .filter(|d| {
            matches!(d.name, "power-eris1176" | "qc324" | "c-fat500-5" | "SYNTHETIC")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::components;

    #[test]
    fn suites_are_deterministic() {
        for d in suite().iter().chain(table6_suite().iter()) {
            assert_eq!(d.build(), d.build(), "{} not deterministic", d.name);
        }
    }

    #[test]
    fn suite_covers_all_paper_rows() {
        assert_eq!(suite().len(), 17);
        assert!(table6_suite().len() >= 9);
    }

    #[test]
    fn synthetic_splits_into_many_components() {
        let g = dataset("SYNTHETIC").unwrap().build();
        assert_eq!(components::count(&g), 300);
    }

    #[test]
    fn p_hat_is_dense_and_whole() {
        let g = dataset("p_hat300-1").unwrap().build();
        assert!(g.density() > 0.1, "density {}", g.density());
        assert_eq!(components::count(&g), 1);
    }

    #[test]
    fn low_degree_families_are_sparse() {
        for name in ["US-power-grid", "vc-exact-029", "rajat28"] {
            let g = dataset(name).unwrap().build();
            assert!(g.density() < 0.02, "{name} density {}", g.density());
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(dataset("qc324").is_some());
        assert!(dataset("nope").is_none());
    }
}
