//! Experiment harness: the dataset suite (synthetic analogs of the
//! paper's evaluation graphs) and the drivers that regenerate every table
//! and figure of the paper's evaluation section.

pub mod datasets;
pub mod tables;

pub use datasets::{dataset, suite, table6_suite, Dataset};
