//! Table/figure drivers: run the solver variants over the dataset suite
//! and print rows shaped like the paper's evaluation tables. Shared by
//! the `benches/` binaries and the `cavc tables` CLI verb.

use super::datasets::Dataset;
use crate::graph::Graph;
use crate::solver::sched::WorkerCounters;
use crate::solver::{self, NodeRepr, Problem, SchedulerKind, SolverConfig, Termination, VcService};
use crate::util::{fmt_secs, fmt_speedup};
use std::io::Write;
use std::time::Duration;

/// One timed run.
#[derive(Debug, Clone)]
pub struct Timed {
    /// Seconds elapsed.
    pub secs: f64,
    /// Whether the run hit its budget (the ">6hrs" stand-in).
    pub timed_out: bool,
    /// Cover size reported (upper bound when timed out).
    pub best: u32,
    /// Tree nodes visited.
    pub tree_nodes: u64,
}

/// Wall-clock budget per table cell, configurable via `CAVC_TIMEOUT_S`.
pub fn cell_timeout() -> Duration {
    let secs = std::env::var("CAVC_TIMEOUT_S")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(5.0);
    Duration::from_secs_f64(secs)
}

/// Scheduler used by every table cell, configurable via `CAVC_SCHED`
/// (`steal` | `sharded`) so scheduler runs can be compared head-to-head
/// without recompiling.
pub fn cell_scheduler() -> SchedulerKind {
    std::env::var("CAVC_SCHED")
        .ok()
        .and_then(|s| SchedulerKind::parse(&s))
        .unwrap_or_default()
}

/// Run MVC with a variant preset + budget.
pub fn run_mvc(g: &Graph, mut cfg: SolverConfig) -> Timed {
    cfg.timeout = Some(cell_timeout());
    cfg.scheduler = cell_scheduler();
    // Paper tables compare engine variants, so every column must share
    // the one-shot shape (per-call pool, occupancy-model worker sizing)
    // rather than mixing warm resident-service runs with cold ones.
    cfg.one_shot = true;
    let r = solver::solve_mvc(g, &cfg);
    Timed {
        secs: r.elapsed.as_secs_f64(),
        timed_out: r.timed_out,
        best: r.best,
        tree_nodes: r.stats.tree_nodes,
    }
}

/// Run MVC through a resident service with the self-tuning controller
/// live: the "controller" ablation column. Unlike the variant columns
/// (which share the one-shot shape), this cell *is* the resident
/// deployment the controller targets — the job runs under whatever
/// repr/pin/induction decisions the tuner has reached, and must still
/// land the same answer inside the same budget.
pub fn run_mvc_controller(g: &Graph, mut cfg: SolverConfig) -> Timed {
    cfg.timeout = Some(cell_timeout());
    cfg.scheduler = cell_scheduler();
    let svc = VcService::builder()
        .config(cfg.clone())
        .scheduler(cfg.scheduler)
        .autotune(true)
        .build();
    let sol = svc.submit(Problem::mvc(g.clone())).wait();
    Timed {
        secs: sol.elapsed.as_secs_f64(),
        timed_out: sol.termination == Termination::DeadlineExpired,
        best: sol.objective,
        tree_nodes: sol.stats.tree_nodes,
    }
}

/// Run PVC with a variant preset + budget.
pub fn run_pvc(g: &Graph, k: u32, mut cfg: SolverConfig) -> (Timed, bool) {
    cfg.timeout = Some(cell_timeout());
    cfg.scheduler = cell_scheduler();
    cfg.one_shot = true; // variant columns share the one-shot shape
    let r = solver::solve_pvc(g, k, &cfg);
    (
        Timed {
            secs: r.elapsed.as_secs_f64(),
            timed_out: r.timed_out,
            best: r.size.unwrap_or(0),
            tree_nodes: r.stats.tree_nodes,
        },
        r.found,
    )
}

/// Format a timed cell the way the paper prints it.
pub fn cell(t: &Timed) -> String {
    fmt_secs(t.secs, t.timed_out, cell_timeout().as_secs_f64())
}

/// Format a speedup cell (baseline vs ours).
pub fn speedup_cell(baseline: &Timed, ours: &Timed) -> String {
    let base = if baseline.timed_out { cell_timeout().as_secs_f64() } else { baseline.secs };
    fmt_speedup(base, ours.secs, baseline.timed_out)
}

/// Table I row: four variants on one dataset.
#[derive(Debug, Clone)]
pub struct Table1Row {
    /// Dataset analog.
    pub name: &'static str,
    /// Analog |V|.
    pub n: usize,
    /// Analog |E|.
    pub m: usize,
    /// Prior-work GPU baseline (Yamout et al.).
    pub yamout: Timed,
    /// Sequential optimized baseline.
    pub sequential: Timed,
    /// Component-aware without load balancing.
    pub no_lb: Timed,
    /// The proposed solver.
    pub proposed: Timed,
}

/// Run one Table I row.
pub fn table1_row(d: &Dataset) -> Table1Row {
    let g = d.build();
    let proposed = run_mvc(&g, SolverConfig::proposed());
    let yamout = run_mvc(&g, SolverConfig::prior_work());
    let sequential = run_mvc(&g, SolverConfig::sequential());
    let no_lb = run_mvc(&g, SolverConfig::no_load_balance());
    // correctness cross-check between all finished variants
    let finished: Vec<u32> = [&proposed, &yamout, &sequential, &no_lb]
        .iter()
        .filter(|t| !t.timed_out)
        .map(|t| t.best)
        .collect();
    if let Some(&first) = finished.first() {
        assert!(
            finished.iter().all(|&b| b == first),
            "{}: variants disagree: {:?}",
            d.name,
            finished
        );
    }
    Table1Row {
        name: d.name,
        n: g.num_vertices(),
        m: g.num_edges(),
        yamout,
        sequential,
        no_lb,
        proposed,
    }
}

/// Print a Table I header + rows to `w` (markdown-ish pipe table).
pub fn print_table1(rows: &[Table1Row], mut w: impl Write) -> std::io::Result<()> {
    writeln!(
        w,
        "| {:<22} | {:>6} | {:>7} | {:>10} | {:>10} | {:>10} | {:>10} | {:>12} | {:>10} | {:>10} |",
        "Graph", "|V|", "|E|", "Yamout[5]", "Sequential", "No-LB", "Proposed",
        "vs Yamout", "vs Seq", "vs No-LB"
    )?;
    writeln!(w, "|{}|", "-".repeat(136))?;
    for r in rows {
        writeln!(
            w,
            "| {:<22} | {:>6} | {:>7} | {:>10} | {:>10} | {:>10} | {:>10} | {:>12} | {:>10} | {:>10} |",
            r.name,
            r.n,
            r.m,
            cell(&r.yamout),
            cell(&r.sequential),
            cell(&r.no_lb),
            cell(&r.proposed),
            speedup_cell(&r.yamout, &r.proposed),
            speedup_cell(&r.sequential, &r.proposed),
            speedup_cell(&r.no_lb, &r.proposed),
        )?;
    }
    Ok(())
}

/// Table II row: disable one optimization at a time.
#[derive(Debug, Clone)]
pub struct Table2Row {
    /// Dataset analog.
    pub name: &'static str,
    /// Proposed minus component branching.
    pub no_components: Timed,
    /// Proposed minus root reduce+induce.
    pub no_induce: Timed,
    /// Proposed minus *tree* induction (`--induce-threshold 0`: split
    /// children stay full-width over the parent view).
    pub no_tree_induce: Timed,
    /// Proposed minus non-zero bounds.
    pub no_bounds: Timed,
    /// Full proposed.
    pub proposed: Timed,
    /// Full proposed on a resident service with the self-tuning
    /// controller retuning repr/pin/induction/pool-shape online.
    pub controller: Timed,
}

/// Run one Table II row.
pub fn table2_row(d: &Dataset) -> Table2Row {
    let g = d.build();
    let mut no_comp = SolverConfig::proposed();
    no_comp.component_aware = false;
    let mut no_induce = SolverConfig::proposed();
    no_induce.reduce_root = false;
    no_induce.use_crown = false;
    let no_tree_induce = SolverConfig::proposed().with_induce_threshold(0.0);
    let mut no_bounds = SolverConfig::proposed();
    no_bounds.use_bounds = false;
    Table2Row {
        name: d.name,
        no_components: run_mvc(&g, no_comp),
        no_induce: run_mvc(&g, no_induce),
        no_tree_induce: run_mvc(&g, no_tree_induce),
        no_bounds: run_mvc(&g, no_bounds),
        proposed: run_mvc(&g, SolverConfig::proposed()),
        controller: run_mvc_controller(&g, SolverConfig::proposed()),
    }
}

/// Print Table II.
pub fn print_table2(rows: &[Table2Row], mut w: impl Write) -> std::io::Result<()> {
    writeln!(
        w,
        "| {:<22} | {:>12} | {:>12} | {:>13} | {:>12} | {:>10} | {:>10} |",
        "Graph", "-components", "-induce", "-tree-induce", "-bounds", "Proposed", "Controller"
    )?;
    writeln!(w, "|{}|", "-".repeat(111))?;
    for r in rows {
        writeln!(
            w,
            "| {:<22} | {:>12} | {:>12} | {:>13} | {:>12} | {:>10} | {:>10} |",
            r.name,
            cell(&r.no_components),
            cell(&r.no_induce),
            cell(&r.no_tree_induce),
            cell(&r.no_bounds),
            cell(&r.proposed),
            cell(&r.controller)
        )?;
    }
    Ok(())
}

/// Table III row: tree nodes without/with component branching plus the
/// components-per-branch histogram.
#[derive(Debug, Clone)]
pub struct Table3Row {
    /// Dataset analog.
    pub name: &'static str,
    /// Nodes visited with component branching disabled (lower bound when
    /// the run timed out, as in the paper).
    pub nodes_disabled: u64,
    /// Whether the disabled run timed out.
    pub disabled_timed_out: bool,
    /// Nodes visited by the proposed solver.
    pub nodes_enabled: u64,
    /// Branches on components.
    pub component_branches: u64,
    /// Histogram {components per branch → count}.
    pub histogram: std::collections::BTreeMap<u32, u64>,
}

/// Run one Table III row.
pub fn table3_row(d: &Dataset) -> Table3Row {
    let g = d.build();
    let mut no_comp = SolverConfig::proposed();
    no_comp.component_aware = false;
    no_comp.timeout = Some(cell_timeout());
    let disabled = solver::solve_mvc(&g, &no_comp);
    let mut prop = SolverConfig::proposed();
    prop.timeout = Some(cell_timeout());
    let enabled = solver::solve_mvc(&g, &prop);
    Table3Row {
        name: d.name,
        nodes_disabled: disabled.stats.tree_nodes,
        disabled_timed_out: disabled.timed_out,
        nodes_enabled: enabled.stats.tree_nodes,
        component_branches: enabled.stats.component_branches,
        histogram: enabled.stats.comp_histogram,
    }
}

/// Print Table III.
pub fn print_table3(rows: &[Table3Row], mut w: impl Write) -> std::io::Result<()> {
    writeln!(
        w,
        "| {:<22} | {:>16} | {:>12} | {:>10} | histogram |",
        "Graph", "nodes (disabled)", "nodes (prop)", "splits"
    )?;
    writeln!(w, "|{}|", "-".repeat(100))?;
    for r in rows {
        let hist: Vec<String> =
            r.histogram.iter().map(|(k, v)| format!("{k}: {v}")).collect();
        let disabled = if r.disabled_timed_out {
            format!(">{}", r.nodes_disabled)
        } else {
            r.nodes_disabled.to_string()
        };
        writeln!(
            w,
            "| {:<22} | {:>16} | {:>12} | {:>10} | {{{}}} |",
            r.name,
            disabled,
            r.nodes_enabled,
            r.component_branches,
            hist.join("; ")
        )?;
    }
    Ok(())
}

/// Table IV row: degree-array / occupancy effect of reduce+induce.
#[derive(Debug, Clone)]
pub struct Table4Row {
    /// Dataset analog.
    pub name: &'static str,
    /// Degree-array vertices before (original |V|).
    pub n_before: usize,
    /// Degree-array vertices after root reduce+induce.
    pub n_after: usize,
    /// Modeled blocks before.
    pub blocks_before: usize,
    /// Modeled blocks after.
    pub blocks_after: usize,
    /// Shared-memory fit before/after.
    pub fits_before: bool,
    /// Shared-memory fit after.
    pub fits_after: bool,
    /// Short dtype before/after.
    pub short_before: bool,
    /// Short dtype after.
    pub short_after: bool,
}

/// Run one Table IV row (pure preprocessing, no search).
pub fn table4_row(d: &Dataset) -> Table4Row {
    use crate::degree::Dtype;
    use crate::prep::{prepare, PrepConfig};
    use crate::solver::occupancy::OccupancyModel;
    let g = d.build();
    let model = OccupancyModel::default();
    // before: full graph, 32-bit entries (prior work)
    let before = model.plan(g.num_vertices(), Dtype::U32);
    // after: reduce + induce + small dtype
    let p = prepare(&g, &PrepConfig::default(), None);
    let after = model.plan(p.residual.graph.num_vertices(), p.dtype);
    Table4Row {
        name: d.name,
        n_before: g.num_vertices(),
        n_after: p.residual.graph.num_vertices(),
        blocks_before: before.blocks,
        blocks_after: after.blocks,
        fits_before: before.fits_shared_mem,
        fits_after: after.fits_shared_mem,
        short_before: Dtype::U32.is_short(),
        short_after: p.dtype.is_short(),
    }
}

/// Print Table IV.
pub fn print_table4(rows: &[Table4Row], mut w: impl Write) -> std::io::Result<()> {
    writeln!(
        w,
        "| {:<22} | {:>8} | {:>8} | {:>6} | {:>7} | {:>7} | {:>8} | {:>9} | {:>9} | {:>9} | {:>9} |",
        "Graph", "n before", "n after", "ratio", "blk bef", "blk aft", "increase",
        "shm bef", "shm aft", "short bef", "short aft"
    )?;
    writeln!(w, "|{}|", "-".repeat(132))?;
    for r in rows {
        writeln!(
            w,
            "| {:<22} | {:>8} | {:>8} | {:>5.2}x | {:>7} | {:>7} | {:>7.2}x | {:>9} | {:>9} | {:>9} | {:>9} |",
            r.name,
            r.n_before,
            r.n_after,
            r.n_after as f64 / r.n_before.max(1) as f64,
            r.blocks_before,
            r.blocks_after,
            r.blocks_after as f64 / r.blocks_before.max(1) as f64,
            yn(r.fits_before),
            yn(r.fits_after),
            yn(r.short_before),
            yn(r.short_after),
        )?;
    }
    Ok(())
}

fn yn(b: bool) -> &'static str {
    if b {
        "Yes"
    } else {
        "No"
    }
}

/// Table IV extension row: live per-node payload telemetry from an
/// instrumented search, with component induction toggled. On split-heavy
/// graphs the induced run's bytes-per-node tracks component size while
/// the full-width run's tracks root n.
#[derive(Debug, Clone)]
pub struct NodeBytesRow {
    /// Workload label.
    pub name: String,
    /// Whether tree induction was enabled.
    pub induce: bool,
    /// Peak simultaneously-live node-state bytes (degree arrays plus
    /// live induced-view CSR buffers, so off-vs-on is unbiased).
    pub peak_live_bytes: u64,
    /// Average payload bytes per created node.
    pub bytes_per_node: f64,
    /// Buffer-pool hits (recycled payloads/CSRs).
    pub pool_hits: u64,
    /// Buffer-pool misses (fresh allocations).
    pub pool_misses: u64,
    /// Components materialized as induced subproblems.
    pub induced_subproblems: u64,
    /// Search-tree nodes visited.
    pub tree_nodes: u64,
    /// Seconds elapsed.
    pub secs: f64,
}

/// Run one instrumented solve of `g` and report its payload telemetry.
pub fn node_bytes_row(name: &str, g: &Graph, induce: bool) -> NodeBytesRow {
    let mut cfg = SolverConfig::proposed()
        .with_induce_threshold(if induce { 1.0 } else { 0.0 });
    cfg.instrument = true;
    cfg.timeout = Some(cell_timeout());
    let r = solver::solve_mvc(g, &cfg);
    NodeBytesRow {
        name: name.to_string(),
        induce,
        peak_live_bytes: r.stats.peak_live_bytes,
        bytes_per_node: r.stats.payload_bytes as f64 / r.stats.payload_nodes.max(1) as f64,
        pool_hits: r.stats.pool_hits,
        pool_misses: r.stats.pool_misses,
        induced_subproblems: r.stats.induced_subproblems,
        tree_nodes: r.stats.tree_nodes,
        secs: r.elapsed.as_secs_f64(),
    }
}

/// Print the Table IV node-bytes extension.
pub fn print_node_bytes(rows: &[NodeBytesRow], mut w: impl Write) -> std::io::Result<()> {
    writeln!(
        w,
        "| {:<26} | {:>7} | {:>12} | {:>10} | {:>9} | {:>9} | {:>8} | {:>10} |",
        "Workload", "induce", "peak live B", "B/node", "pool hit", "pool miss", "induced", "nodes"
    )?;
    writeln!(w, "|{}|", "-".repeat(114))?;
    for r in rows {
        writeln!(
            w,
            "| {:<26} | {:>7} | {:>12} | {:>10.1} | {:>9} | {:>9} | {:>8} | {:>10} |",
            r.name,
            yn(r.induce),
            r.peak_live_bytes,
            r.bytes_per_node,
            r.pool_hits,
            r.pool_misses,
            r.induced_subproblems,
            r.tree_nodes,
        )?;
    }
    Ok(())
}

/// Table IV delta extension row: owned vs delta node representation on
/// one workload — resident bytes/node against the undo-replay cost the
/// delta trade buys them with (covers reverted on local backtracks,
/// covers replayed at steal-time materialization).
#[derive(Debug, Clone)]
pub struct DeltaBytesRow {
    /// Workload label.
    pub name: String,
    /// Whether tree induction was enabled.
    pub induce: bool,
    /// Node representation measured.
    pub repr: NodeRepr,
    /// Average resident payload bytes per created node (owned degree
    /// arrays vs pinned suffix/base shares).
    pub bytes_per_node: f64,
    /// Peak simultaneously-live node-state bytes.
    pub peak_live_bytes: u64,
    /// Delta right children pushed.
    pub delta_children: u64,
    /// Delta children consumed by in-place undo.
    pub undo_pops: u64,
    /// Covers reverted by undo replay (the backtrack cost).
    pub undo_covers: u64,
    /// Delta children materialized (stolen/foreign).
    pub materializations: u64,
    /// Covers replayed forward during materialization (the steal cost).
    pub replayed_covers: u64,
    /// Search-tree nodes visited.
    pub tree_nodes: u64,
    /// Seconds elapsed.
    pub secs: f64,
}

/// Run one instrumented solve of `g` under `repr` and report the
/// bytes/node + undo-replay-cost telemetry.
pub fn delta_bytes_row(name: &str, g: &Graph, induce: bool, repr: NodeRepr) -> DeltaBytesRow {
    let mut cfg = SolverConfig::proposed()
        .with_induce_threshold(if induce { 1.0 } else { 0.0 })
        .with_node_repr(repr);
    cfg.instrument = true;
    cfg.timeout = Some(cell_timeout());
    let r = solver::solve_mvc(g, &cfg);
    DeltaBytesRow {
        name: name.to_string(),
        induce,
        repr,
        bytes_per_node: r.stats.payload_bytes as f64 / r.stats.payload_nodes.max(1) as f64,
        peak_live_bytes: r.stats.peak_live_bytes,
        delta_children: r.stats.delta_children,
        undo_pops: r.stats.undo_pops,
        undo_covers: r.stats.undo_covers,
        materializations: r.stats.materializations,
        replayed_covers: r.stats.replayed_covers,
        tree_nodes: r.stats.tree_nodes,
        secs: r.elapsed.as_secs_f64(),
    }
}

/// Print the Table IV owned-vs-delta extension.
pub fn print_delta_bytes(rows: &[DeltaBytesRow], mut w: impl Write) -> std::io::Result<()> {
    writeln!(
        w,
        "| {:<22} | {:>6} | {:>5} | {:>10} | {:>12} | {:>8} | {:>9} | {:>9} | {:>7} | {:>9} |",
        "Workload",
        "induce",
        "repr",
        "B/node",
        "peak live B",
        "deltas",
        "undo pop",
        "undo cov",
        "mat.",
        "replayed"
    )?;
    writeln!(w, "|{}|", "-".repeat(130))?;
    for r in rows {
        writeln!(
            w,
            "| {:<22} | {:>6} | {:>5} | {:>10.1} | {:>12} | {:>8} | {:>9} | {:>9} | {:>7} | {:>9} |",
            r.name,
            yn(r.induce),
            r.repr.name(),
            r.bytes_per_node,
            r.peak_live_bytes,
            r.delta_children,
            r.undo_pops,
            r.undo_covers,
            r.materializations,
            r.replayed_covers,
        )?;
    }
    Ok(())
}

/// Table V row: PVC at k ∈ {min−1, min, min+1} for one variant set.
#[derive(Debug, Clone)]
pub struct Table5Row {
    /// Dataset analog.
    pub name: &'static str,
    /// Which instance: "min-1" | "min" | "min+1".
    pub instance: &'static str,
    /// k value used.
    pub k: u32,
    /// Prior-work baseline.
    pub yamout: Timed,
    /// Sequential baseline.
    pub sequential: Timed,
    /// No load balance.
    pub no_lb: Timed,
    /// Proposed.
    pub proposed: Timed,
    /// Found flags (proposed) — must be false for k=min−1, true otherwise
    /// unless timed out.
    pub found: bool,
}

/// Run the three Table V instances for one dataset. Needs the MVC size,
/// which is computed with the proposed solver first (and reused).
pub fn table5_rows(d: &Dataset) -> Vec<Table5Row> {
    let g = d.build();
    let mvc = run_mvc(&g, SolverConfig::proposed());
    if mvc.timed_out {
        return Vec::new(); // cannot derive k = min±1 without the minimum
    }
    let min = mvc.best;
    let mut out = Vec::new();
    for (instance, k) in [
        ("min-1", min.saturating_sub(1)),
        ("min", min),
        ("min+1", min + 1),
    ] {
        let (proposed, found) = run_pvc(&g, k, SolverConfig::proposed());
        let (yamout, _) = run_pvc(&g, k, SolverConfig::prior_work());
        let (sequential, _) = run_pvc(&g, k, SolverConfig::sequential());
        let (no_lb, _) = run_pvc(&g, k, SolverConfig::no_load_balance());
        out.push(Table5Row {
            name: d.name,
            instance,
            k,
            yamout,
            sequential,
            no_lb,
            proposed,
            found,
        });
    }
    out
}

/// Print Table V.
pub fn print_table5(rows: &[Table5Row], mut w: impl Write) -> std::io::Result<()> {
    writeln!(
        w,
        "| {:<22} | {:<6} | {:>5} | {:>10} | {:>10} | {:>10} | {:>10} | {:>12} | {:>10} | {:>10} |",
        "Graph", "k", "found", "Yamout[5]", "Sequential", "No-LB", "Proposed",
        "vs Yamout", "vs Seq", "vs No-LB"
    )?;
    writeln!(w, "|{}|", "-".repeat(132))?;
    for r in rows {
        writeln!(
            w,
            "| {:<22} | {:<6} | {:>5} | {:>10} | {:>10} | {:>10} | {:>10} | {:>12} | {:>10} | {:>10} |",
            r.name,
            r.instance,
            yn(r.found),
            cell(&r.yamout),
            cell(&r.sequential),
            cell(&r.no_lb),
            cell(&r.proposed),
            speedup_cell(&r.yamout, &r.proposed),
            speedup_cell(&r.sequential, &r.proposed),
            speedup_cell(&r.no_lb, &r.proposed),
        )?;
    }
    Ok(())
}

/// Table VI row: proposed vs prior work on prior work's datasets.
#[derive(Debug, Clone)]
pub struct Table6Row {
    /// Dataset analog.
    pub name: &'static str,
    /// Density of the analog (the paper's 10% predictor).
    pub density: f64,
    /// Prior work.
    pub yamout: Timed,
    /// Proposed.
    pub proposed: Timed,
}

/// Run one Table VI row.
pub fn table6_row(d: &Dataset) -> Table6Row {
    let g = d.build();
    Table6Row {
        name: d.name,
        density: g.density(),
        yamout: run_mvc(&g, SolverConfig::prior_work()),
        proposed: run_mvc(&g, SolverConfig::proposed()),
    }
}

/// Print Table VI.
pub fn print_table6(rows: &[Table6Row], mut w: impl Write) -> std::io::Result<()> {
    writeln!(
        w,
        "| {:<22} | {:>8} | {:>10} | {:>10} | {:>10} |",
        "Graph", "density", "Yamout[5]", "Proposed", "Speedup"
    )?;
    writeln!(w, "|{}|", "-".repeat(74))?;
    for r in rows {
        writeln!(
            w,
            "| {:<22} | {:>7.1}% | {:>10} | {:>10} | {:>10} |",
            r.name,
            100.0 * r.density,
            cell(&r.yamout),
            cell(&r.proposed),
            speedup_cell(&r.yamout, &r.proposed),
        )?;
    }
    Ok(())
}

/// Figure 4 row: normalized activity breakdown for the proposed solver.
#[derive(Debug, Clone)]
pub struct Fig4Row {
    /// Dataset analog.
    pub name: &'static str,
    /// Busy-time fractions in `ALL_ACTIVITIES` order.
    pub fractions: [f64; crate::util::timer::NUM_ACTIVITIES],
    /// Scheduler used for the run.
    pub scheduler: SchedulerKind,
    /// Per-worker scheduler traffic (push/pop/steal/retry) behind the
    /// `stack/worklist` activity bar.
    pub sched_workers: Vec<WorkerCounters>,
}

/// Run one Figure 4 row.
pub fn fig4_row(d: &Dataset) -> Fig4Row {
    use crate::util::timer::NUM_ACTIVITIES;
    let g = d.build();
    let mut cfg = SolverConfig::proposed();
    cfg.instrument = true;
    cfg.timeout = Some(cell_timeout());
    cfg.scheduler = cell_scheduler();
    let r = solver::solve_mvc(&g, &cfg);
    let mut totals = [0u64; NUM_ACTIVITIES];
    totals.copy_from_slice(&r.stats.activity);
    let busy: u64 = totals
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != crate::util::timer::Activity::Idle as usize)
        .map(|(_, v)| *v)
        .sum();
    let mut fractions = [0.0; NUM_ACTIVITIES];
    if busy > 0 {
        for (i, &v) in totals.iter().enumerate() {
            if i != crate::util::timer::Activity::Idle as usize {
                fractions[i] = v as f64 / busy as f64;
            }
        }
    }
    Fig4Row {
        name: d.name,
        fractions,
        scheduler: cfg.scheduler,
        sched_workers: r.stats.sched_workers,
    }
}

/// Witness-extraction memory cost for one dataset (the Figure 4
/// companion table): choice-log bytes and recycled-log counts next to
/// the PR 2 payload bytes-per-node telemetry, so the price of carrying
/// witnesses is visible in the same units.
#[derive(Debug, Clone)]
pub struct WitnessCostRow {
    /// Dataset analog.
    pub name: &'static str,
    /// Cover size of the extracting run.
    pub best: u32,
    /// Whether the extracted witness verified against the input.
    pub verified: bool,
    /// Total choice-log bytes retired over the run.
    pub witness_log_bytes: u64,
    /// Log buffers recycled through the worker pools.
    pub logs_recycled: u64,
    /// Node payload bytes (the baseline the log cost compares against).
    pub payload_bytes: u64,
    /// Node payloads created.
    pub payload_nodes: u64,
}

/// Run one witness-cost row: the proposed solver with extraction on.
pub fn witness_cost_row(d: &Dataset) -> WitnessCostRow {
    let g = d.build();
    let mut cfg = SolverConfig::proposed();
    cfg.timeout = Some(cell_timeout());
    cfg.scheduler = cell_scheduler();
    cfg.extract_cover = true;
    cfg.one_shot = true;
    let r = solver::solve_mvc(&g, &cfg);
    let verified = r
        .cover
        .as_ref()
        .is_some_and(|c| crate::solver::witness::verify_cover(&g, c).is_ok());
    WitnessCostRow {
        name: d.name,
        best: r.best,
        verified,
        witness_log_bytes: r.stats.witness_log_bytes,
        logs_recycled: r.stats.logs_recycled,
        payload_bytes: r.stats.payload_bytes,
        payload_nodes: r.stats.payload_nodes,
    }
}

/// Print the witness-cost companion table.
pub fn print_witness_cost(rows: &[WitnessCostRow], mut w: impl Write) -> std::io::Result<()> {
    let header = format!(
        "| {:<22} | {:>8} | {:>8} | {:>14} | {:>13} | {:>12} | {:>11} |",
        "Graph", "mvc", "verified", "log bytes", "logs recycled", "payload B", "log/payload"
    );
    writeln!(w, "{header}")?;
    writeln!(w, "|{}|", "-".repeat(header.len() - 2))?;
    for r in rows {
        let ratio = if r.payload_bytes > 0 {
            r.witness_log_bytes as f64 / r.payload_bytes as f64
        } else {
            0.0
        };
        writeln!(
            w,
            "| {:<22} | {:>8} | {:>8} | {:>14} | {:>13} | {:>12} | {:>10.3}% |",
            r.name,
            r.best,
            r.verified,
            r.witness_log_bytes,
            r.logs_recycled,
            r.payload_bytes,
            100.0 * ratio
        )?;
    }
    Ok(())
}

/// Print Figure 4 as a percentage table.
pub fn print_fig4(rows: &[Fig4Row], mut w: impl Write) -> std::io::Result<()> {
    use crate::util::timer::{Activity, ALL_ACTIVITIES};
    write!(w, "| {:<22} |", "Graph")?;
    for a in ALL_ACTIVITIES {
        if a != Activity::Idle {
            write!(w, " {:>18} |", a.label())?;
        }
    }
    writeln!(w)?;
    writeln!(w, "|{}|", "-".repeat(24 + 21 * 5))?;
    for r in rows {
        write!(w, "| {:<22} |", r.name)?;
        for a in ALL_ACTIVITIES {
            if a != Activity::Idle {
                write!(w, " {:>17.1}% |", 100.0 * r.fractions[a as usize])?;
            }
        }
        writeln!(w)?;
    }
    Ok(())
}

/// Print the per-worker scheduler counters behind each Figure 4 row
/// (push/pop/steal/retry — the worklist-traffic half of the breakdown).
pub fn print_fig4_sched(rows: &[Fig4Row], mut w: impl Write) -> std::io::Result<()> {
    for r in rows {
        let total: u64 = r.sched_workers.iter().map(|c| c.acquired()).sum();
        writeln!(
            w,
            "{} [{}]: {} workers, {} nodes through queues",
            r.name,
            r.scheduler.name(),
            r.sched_workers.len(),
            total
        )?;
        for (i, c) in r.sched_workers.iter().enumerate() {
            writeln!(
                w,
                "  w{i:<3} push {:>9}  pop {:>9}  shared {:>7}  steal {:>7}  retry {:>6}  depth {:>5}",
                c.pushes, c.pops, c.shared_pops, c.steals, c.steal_retries, c.max_depth
            )?;
        }
    }
    Ok(())
}

/// Write rows as CSV under `bench_out/`.
pub fn write_csv(name: &str, header: &str, lines: &[String]) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("bench_out");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.csv"));
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{header}")?;
    for l in lines {
        writeln!(f, "{l}")?;
    }
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::datasets;

    #[test]
    fn table1_row_smoke() {
        std::env::set_var("CAVC_TIMEOUT_S", "5");
        let d = datasets::dataset("qc324").unwrap();
        let r = table1_row(&d);
        assert!(!r.proposed.timed_out, "qc324 analog must finish fast");
        assert!(r.proposed.best > 0);
    }

    #[test]
    fn table4_row_shows_reduction() {
        let d = datasets::dataset("web-webbase-2001").unwrap();
        let r = table4_row(&d);
        assert!(r.n_after < r.n_before);
        assert!(r.blocks_after >= r.blocks_before);
        assert!(r.short_after);
        assert!(!r.short_before);
    }

    #[test]
    fn printers_do_not_panic() {
        std::env::set_var("CAVC_TIMEOUT_S", "5");
        let d = datasets::dataset("qc324").unwrap();
        let mut buf = Vec::new();
        print_table1(&[table1_row(&d)], &mut buf).unwrap();
        print_table2(&[table2_row(&d)], &mut buf).unwrap();
        print_table4(&[table4_row(&d)], &mut buf).unwrap();
        assert!(!buf.is_empty());
    }
}
