//! # CAVC — Component-Aware Vertex Cover
//!
//! A reproduction of *"Faster Vertex Cover Algorithms on GPUs with
//! Component-Aware Parallel Branching"* (TPDS 2025) as a three-layer
//! Rust + JAX + Pallas stack. The GPU execution model (thread blocks with
//! private stacks, a shared load-balancing worklist, and a component
//! branch registry in global memory) is reproduced with worker threads,
//! sharded MPMC deques, and an atomic registry arena; the paper's
//! block-level BFS/analytics kernels are AOT-compiled from Pallas/JAX to
//! HLO and executed via PJRT from the Rust runtime.
//!
//! ## Quickstart
//!
//! ```no_run
//! use cavc::graph::Graph;
//! use cavc::solver::{solve_mvc, SolverConfig};
//!
//! let g = Graph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
//! let res = solve_mvc(&g, &SolverConfig::proposed());
//! assert_eq!(res.best, 2);
//! ```
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index.

pub mod degree;
pub mod graph;
pub mod harness;
pub mod prep;
pub mod reduce;
pub mod runtime;
pub mod solver;
pub mod util;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
