//! # CAVC — Component-Aware Vertex Cover
//!
//! A reproduction of *"Faster Vertex Cover Algorithms on GPUs with
//! Component-Aware Parallel Branching"* (TPDS 2025) as a three-layer
//! Rust + JAX + Pallas stack. The GPU execution model is reproduced on
//! worker threads through a pluggable scheduler abstraction
//! ([`solver::sched`]):
//!
//! | GPU concept (paper)            | CPU reproduction                            |
//! |--------------------------------|---------------------------------------------|
//! | thread block w/ private stack  | worker thread owning a Chase–Lev deque      |
//! | broker worklist (§II-C)        | global injector + the stealable deque tops  |
//! | "is the worklist hungry?"      | thief-pull stealing (no donation heuristic) |
//! | grid-wide quiescence           | epoch-validated idle-count termination      |
//! | component branch registry      | lock-free atomic registry arena (§III-C)    |
//! | subgraph induction (§IV-B)     | root induce **and** per-split component     |
//! |                                | re-induction (`induce_threshold` gate)      |
//! | preallocated stack slots       | per-worker size-classed buffer pools        |
//!
//! ## Memory model: root-induce → tree-induce
//!
//! The paper reduces at the root and *induces a subgraph* so degree
//! arrays are sized to the residual graph — its answer to prior GPU
//! solvers whose "high memory footprint limits the number of workers
//! that can execute concurrently". This reproduction carries the same
//! optimization into the search tree: when a node splits on components,
//! each component becomes a compact renumbered subproblem (component-
//! local CSR + `|C|`-sized degree array), so descendants pay O(|C|) per
//! clone instead of O(n), and retired payloads are recycled through
//! per-worker pools. See [`solver::engine`] for the mechanism and
//! `Occupancy::plan_induced` for how the shrinking-payload path feeds
//! back into the occupancy model and scheduler queue sizing.
//!
//! The previous mutex-sharded worklist survives as a second [`solver::sched::Scheduler`]
//! implementation, selectable from `SolverConfig`, so the paper's
//! variants stay expressible as scheduler + config choices and benches
//! can race runtimes on identical searches. The paper's block-level
//! BFS/analytics kernels are AOT-compiled from Pallas/JAX to HLO and
//! executed via PJRT from the Rust runtime (behind the `xla` feature;
//! native fallbacks otherwise).
//!
//! ## Quickstart
//!
//! ```no_run
//! use cavc::graph::Graph;
//! use cavc::solver::{solve_mvc, SolverConfig};
//!
//! let g = Graph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
//! let res = solve_mvc(&g, &SolverConfig::proposed());
//! assert_eq!(res.best, 2);
//! ```
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index.

pub mod degree;
pub mod graph;
pub mod harness;
pub mod prep;
pub mod reduce;
pub mod runtime;
pub mod solver;
pub mod util;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
