//! # CAVC — Component-Aware Vertex Cover
//!
//! A reproduction of *"Faster Vertex Cover Algorithms on GPUs with
//! Component-Aware Parallel Branching"* (TPDS 2025) as a three-layer
//! Rust + JAX + Pallas stack. The GPU execution model is reproduced on
//! worker threads through a pluggable scheduler abstraction
//! ([`solver::sched`]):
//!
//! | GPU concept (paper)            | CPU reproduction                            |
//! |--------------------------------|---------------------------------------------|
//! | thread block w/ private stack  | worker thread owning a Chase–Lev deque      |
//! | broker worklist (§II-C)        | global injector + the stealable deque tops  |
//! | "is the worklist hungry?"      | thief-pull stealing (no donation heuristic) |
//! | grid-wide quiescence           | epoch-validated idle-count termination      |
//! | component branch registry      | lock-free atomic registry arena (§III-C)    |
//!
//! The previous mutex-sharded worklist survives as a second [`solver::sched::Scheduler`]
//! implementation, selectable from `SolverConfig`, so the paper's
//! variants stay expressible as scheduler + config choices and benches
//! can race runtimes on identical searches. The paper's block-level
//! BFS/analytics kernels are AOT-compiled from Pallas/JAX to HLO and
//! executed via PJRT from the Rust runtime (behind the `xla` feature;
//! native fallbacks otherwise).
//!
//! ## Quickstart
//!
//! ```no_run
//! use cavc::graph::Graph;
//! use cavc::solver::{solve_mvc, SolverConfig};
//!
//! let g = Graph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
//! let res = solve_mvc(&g, &SolverConfig::proposed());
//! assert_eq!(res.best, 2);
//! ```
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index.

pub mod degree;
pub mod graph;
pub mod harness;
pub mod prep;
pub mod reduce;
pub mod runtime;
pub mod solver;
pub mod util;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
