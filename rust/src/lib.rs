//! # CAVC — Component-Aware Vertex Cover
//!
//! A reproduction of *"Faster Vertex Cover Algorithms on GPUs with
//! Component-Aware Parallel Branching"* (TPDS 2025) as a three-layer
//! Rust + JAX + Pallas stack. The GPU execution model is reproduced on
//! worker threads through a pluggable scheduler abstraction
//! ([`solver::sched`]):
//!
//! | GPU concept (paper)            | CPU reproduction                            |
//! |--------------------------------|---------------------------------------------|
//! | thread block w/ private stack  | worker thread owning a Chase–Lev deque      |
//! | broker worklist (§II-C)        | global injector + the stealable deque tops  |
//! | "is the worklist hungry?"      | thief-pull stealing (no donation heuristic) |
//! | grid-wide quiescence           | epoch-validated idle-count termination      |
//! | component branch registry      | lock-free atomic registry arena (§III-C)    |
//! | subgraph induction (§IV-B)     | root induce **and** per-split component     |
//! |                                | re-induction (`induce_threshold` gate)      |
//! | preallocated stack slots       | per-worker size-classed buffer pools        |
//!
//! ## Memory model: root-induce → tree-induce → delta/undo
//!
//! The paper reduces at the root and *induces a subgraph* so degree
//! arrays are sized to the residual graph — its answer to prior GPU
//! solvers whose "high memory footprint limits the number of workers
//! that can execute concurrently". This reproduction carries the same
//! optimization into the search tree: when a node splits on components,
//! each component becomes a compact renumbered subproblem (component-
//! local CSR + `|C|`-sized degree array), so descendants pay O(|C|) per
//! clone instead of O(n), and retired payloads are recycled through
//! per-worker pools.
//!
//! The third stage stops copying altogether
//! ([`solver::NodeRepr::Delta`], `--node-repr delta`): a worker
//! branches *speculatively in place* — the left child mutates the live
//! frame under a reversible cover journal, the right child queued for
//! later is only a pinned parent frame plus its branch vertex, undone
//! by reverse journal replay when it surfaces locally and materialized
//! into an owned payload by the thief when stolen. Resident bytes per
//! node drop from O(view) to O(delta); the price is bounded
//! recomputation, capped by a max-pin-depth knob that periodically
//! freezes full snapshots. GPU analogy: a thread block descending in
//! shared memory without writing its stack slot back to global memory
//! until another block actually claims the right sub-tree — the
//! copy-vs-recompute trade GPU branch-and-bound (van der Zanden &
//! Bodlaender's treewidth solver) showed wins on memory-bound search.
//! See [`solver::engine`] for the mechanism and
//! `Occupancy::plan_induced`/`Occupancy::plan_delta` for how the
//! shrinking-payload path feeds back into the occupancy model and
//! scheduler queue sizing.
//!
//! The previous mutex-sharded worklist survives as a second [`solver::sched::Scheduler`]
//! implementation, selectable from `SolverConfig`, so the paper's
//! variants stay expressible as scheduler + config choices and benches
//! can race runtimes on identical searches. The paper's block-level
//! BFS/analytics kernels are AOT-compiled from Pallas/JAX to HLO and
//! executed via PJRT from the Rust runtime (behind the `xla` feature;
//! native fallbacks otherwise).
//!
//! ## Service layer: resident pool, unified Problem/Solution API
//!
//! On top of the engine sits [`solver::service`]: a
//! [`solver::VcService`] is built once and owns a *resident* worker
//! pool — the GPU analogy is the grid itself, which is launched once
//! and fed work, not re-launched per request. The entry API is a
//! unified [`solver::Problem`] (`Mvc`/`Pvc`/`Mis`) and
//! [`solver::Solution`] (objective, optional witness, stats, prep
//! summary, termination reason); [`solver::VcService::submit`] returns
//! a [`solver::JobHandle`] with `wait`/`try_result`/`cancel` and a
//! per-job deadline.
//!
//! **Job lifecycle.** `submit` injects a *setup* item; a worker runs
//! the preparation pipeline (the "job setup" half of the engine) and
//! pushes the job's root search node; branch-and-reduce node processing
//! then fans out across the pool. Every worklist item carries an `Arc`
//! to its job's state — registry, global best, stop flags, stats sink —
//! which is the job-id scoping that keeps the component-branch
//! registry's completion/pruning/last-descendant aggregation job-local
//! while nodes of different jobs interleave on the same deques
//! (context ids in a node index that job's private registry arena).
//! A per-job outstanding-item count detects completion: whoever
//! decrements it to zero finalizes the `Solution` and wakes waiters.
//! Scheduler-side, resident pools park on quiescence instead of
//! terminating (condvar park/unpark + shutdown drain in
//! `solver::sched`), so many small jobs run concurrently while one
//! large job is still branching.
//!
//! The classic free functions survive as thin shims: service-compatible
//! configurations of [`solver::solve_mvc`]/[`solver::solve_pvc`] route
//! through a lazily-built process-wide default service (no per-call
//! thread spawn); sequential, no-load-balance, instrumented, and
//! explicit pool-shape calls keep the one-shot engine.
//!
//! **Admission & QoS.** Multi-tenant submission pressure is absorbed by
//! a bounded, QoS-aware admission layer in front of the pool (see
//! [`solver::service`], "Admission & QoS"): a bounded two-lane queue
//! with explicit backpressure ([`solver::VcService::try_submit`]
//! returns [`solver::SubmitError::QueueFull`];
//! [`solver::VcService::submit_within`] bounds the blocking wait),
//! per-job [`solver::Lane`] classes — small jobs ride a latency lane
//! with 4× weighted-deficit-round-robin dispatch and *urgent* injection
//! that preempts the schedulers' 64-pop fairness cadence, large jobs
//! ride the throughput lane — and per-tenant admission quotas
//! ([`solver::TenantQuota`]: concurrent jobs + outstanding live nodes).
//! Lane scheduling moves only *when* work is picked up, never what is
//! computed (`tests/qos_admission.rs` asserts objectives and witnesses
//! are lane-invariant); `benches/qos_latency.rs` measures the small-job
//! p50/p99 latency win against a concurrently branching hog.
//!
//! **Failure model & degradation ladder.** The service degrades in
//! rungs rather than failing whole (full treatment in
//! [`solver::service`], "Failure model & degradation ladder"):
//! a job that runs out of deadline or is cancelled still returns an
//! *anytime* result — the best objective bound seen plus, for MVC/MIS
//! with witness extraction, a feasible verified cover of exactly that
//! size (the registry's shortest-wins root witness slot, re-anchored at
//! finalization, with the greedy cover as the floor);
//! [`solver::JobHandle::progress`] exposes the live bound / nodes
//! expanded / elapsed while the job runs. A worker panic marks the job
//! [`solver::Termination::Failed`] with the captured panic message on
//! the `Solution`, and under an opt-in [`solver::RetryPolicy`] the
//! service reruns it on the sequential solver — same prep pipeline, no
//! shared-state machinery — surfacing
//! [`solver::Termination::Recovered`] with a trusted answer; jobs that
//! exhaust their attempts are quarantined and counted. A pool-level
//! memory watchdog meters queued payload + pinned bytes against
//! soft/hard limits: past soft it parks throughput-lane dispatch and
//! forces the delta node representation, past hard it sheds new
//! submissions with [`solver::SubmitError::MemoryPressure`]. All of it
//! is exercised by a deterministic, seeded fault-injection harness
//! ([`solver::FaultPlan`], `tests/chaos.rs`) and measured by
//! `benches/degradation.rs`.
//!
//! ## Witnesses: every engine path hands back a verifiable cover
//!
//! All solver paths — sequential, one-shot parallel, and service jobs
//! ([`solver::service::JobOptions::extract_witness`]) — can return the
//! actual solution vertex set, not just its size. The parallel engine
//! carries a per-node **choice log** (the covered-vertex delta since the
//! node's component context, in root-residual ids via each induced
//! view's back map); the component registry reassembles component-local
//! winning logs at last-descendant aggregation, exactly where it folds
//! sizes; and [`solver::witness`] lifts the winning cover back through
//! the two translation layers — the §IV-B induction renumbering and the
//! root-reduction unwind ([`reduce::UnwindLog`]) — to original vertex
//! ids, then verifies it edge-by-edge. This upgrades the repo's
//! strongest invariant from "parallel == sequential == oracle
//! objective" to "…and the parallel cover itself verifies": see
//! `tests/witness_fuzz.rs` and the CLI's `--check` flag.
//!
//! ## Cross-job component memoization
//!
//! The resident service owns a sharded component → solution cache
//! ([`solver::memo`]) consulted at every component dispatch: the
//! canonical §IV-B induced form (renumbered CSR) is fingerprinted, and
//! a verified hit skips the component's entire branch-and-bound subtree
//! — the cached exact cover feeds straight through the registry's fold
//! algebra via `add_solved_component`, exactly like a kernelized
//! special component. Only *exact* component covers are ever published
//! (bound-pruned PVC subtrees and deadline-truncated searches never
//! reach the cache; publication is arranged at last-descendant
//! finalization and poisoned on any early stop), so a warm service
//! returns bit-identical verified witnesses to a cold one. Cache bytes
//! are charged to the admission ledger and shed *first* under memory
//! pressure — cached results are a luxury, live jobs are not. Batch
//! mode exposes `--memo on|off` / `--memo-bytes N` (`CAVC_MEMO`,
//! `CAVC_MEMO_BYTES`); differential coverage lives in
//! `tests/memo_cache.rs` and `benches/memo_throughput.rs` measures the
//! warm/cold resubmission ratio.
//!
//! ## Self-tuning controller
//!
//! The resident service closes its measurement → decision loop online
//! ([`solver::autotune`]): a controller thread samples the counters the
//! engine already maintains — per-width-bucket delta bytes, undo vs
//! materialize traffic, steal rates, CSR-rebuild amortization, the live
//! admission ledger — and retunes the knobs that were previously fixed
//! at build time: owned-vs-delta node representation per width bucket,
//! `max_pin_depth`, per-bucket induction gating, and the pool shape
//! (admission capacity + memo budget replanned through the occupancy
//! model). Every knob it turns is a performance lever, never a
//! correctness lever — answers and verified witnesses are bit-identical
//! with the controller on or off (`tests/autotune_invariance.rs`), and
//! the watchdog's soft-pressure forced-delta override always outranks
//! it. Explicit static knobs (`--node-repr`, `--max-pin-depth`,
//! `--induce-threshold`, `--max-queued`, `--memo-bytes`) pin their
//! dimension so ablation runs stay exact; `--autotune on|off`
//! (`CAVC_AUTOTUNE`) switches the whole controller, and
//! `benches/autotune.rs` races it against the fixed-knob grid.
//!
//! ## Serving over the network
//!
//! The resident service is network-reachable: [`solver::wire`] defines
//! a zero-dependency length-prefixed binary protocol (magic + version
//! handshake, CSR-validated graph transport, typed admission errors)
//! and [`solver::VcServer`] exposes one [`solver::VcService`] over TCP
//! — per-connection reader threads decode frames into a single bounded
//! ingress channel drained by one coordinator (the sole admission
//! caller), replies fan back out through per-connection writers, and a
//! dropped connection cancels its outstanding jobs. Backpressure maps
//! onto the wire: a shed submit travels back as a typed
//! queue-full/quota/memory error frame the client can turn back into a
//! [`solver::SubmitError`]. [`solver::VcClient`] is the blocking,
//! pipelining client behind `cavc solve --remote HOST:PORT` and
//! `cavc serve`; `tests/wire_protocol.rs` holds the loopback
//! differential (remote answers bit-identical to in-process), the
//! malformed-frame fuzzer, and the disconnect-cancellation coverage,
//! and `benches/wire_throughput.rs` prices the framing overhead.
//!
//! ## Quickstart
//!
//! ```no_run
//! use cavc::graph::Graph;
//! use cavc::solver::{solve_mvc, Problem, SolverConfig, VcService};
//!
//! let g = Graph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
//! let res = solve_mvc(&g, &SolverConfig::proposed());
//! assert_eq!(res.best, 2);
//!
//! // The same solve as a service job (resident pool, concurrent jobs):
//! let svc = VcService::builder().workers(4).build();
//! let sol = svc.solve(Problem::mvc(g));
//! assert_eq!(sol.objective, 2);
//! ```
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `examples/service_batch.rs` for the full job lifecycle.

pub mod degree;
pub mod graph;
pub mod harness;
pub mod prep;
pub mod reduce;
pub mod runtime;
pub mod solver;
pub mod util;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
