//! `cavc` — command-line launcher for the component-aware vertex cover
//! system.
//!
//! Verbs:
//!   solve <graph|dataset>      minimum vertex cover
//!   pvc <graph|dataset> --k K  parameterized vertex cover
//!   info <graph|dataset>       structural metrics + preprocessing report
//!   components <graph>         component split (XLA-accelerated if
//!                              artifacts are built, CPU fallback)
//!   gen <family> --out F       write a synthetic graph
//!   datasets                   list the benchmark suite
//!   tables <1..6|fig4>         regenerate a paper table/figure
//!
//! Options: --variant proposed|yamout|no-lb|sequential, --workers N,
//! --timeout SECS, --k K, --out FILE, --no-accel, --seed S. Batch mode
//! (`--jobs`) additionally takes the admission/QoS flags --lane
//! latency|throughput, --max-queued N, --submit-timeout SECS, plus the
//! degradation flags --retry N, --mem-soft BYTES, --mem-hard BYTES and
//! the cross-job memo-cache flags --memo on|off, --memo-bytes N, and
//! the self-tuning controller switch --autotune on|off; it exits
//! non-zero if any job ends `Termination::Failed`.

use cavc::bail;
use cavc::graph::{generators, io, Graph};
use cavc::util::error::{Context, Error, Result};
use cavc::harness::{datasets, tables};
use cavc::solver::engine::EngineStats;
use cavc::solver::{
    self, witness, JobHandle, Lane, Problem, ProblemKind, RetryPolicy, SchedulerKind, ServerConfig,
    ServerReply, SolverConfig, Termination, VcClient, VcServer, VcService, Variant, WireOptions,
    WireSolution,
};

use cavc::util::cli::Args;
use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

const VALUED: &[&str] = &[
    "variant", "workers", "timeout", "k", "out", "seed", "n", "p", "m", "family", "rows", "cols",
    "sched", "induce-threshold", "jobs", "node-repr", "max-pin-depth", "lane", "submit-timeout",
    "max-queued", "retry", "mem-soft", "mem-hard", "memo", "memo-bytes", "autotune", "addr",
    "remote", "max-conns", "tenant",
];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(raw: Vec<String>) -> Result<()> {
    let args = Args::parse(raw, VALUED).map_err(Error::msg)?;
    match args.pos(0) {
        Some("solve") => cmd_solve(&args),
        Some("pvc") => cmd_pvc(&args),
        Some("mis") => cmd_mis(&args),
        Some("info") => cmd_info(&args),
        Some("components") => cmd_components(&args),
        Some("gen") => cmd_gen(&args),
        Some("serve") => cmd_serve(&args),
        Some("datasets") => cmd_datasets(),
        Some("tables") => cmd_tables(&args),
        Some("version") => {
            println!("cavc {}", cavc::VERSION);
            Ok(())
        }
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "cavc {} — component-aware vertex cover (TPDS'25 reproduction)\n\n\
         usage: cavc <solve|pvc|mis|serve|info|components|gen|datasets|tables> [args]\n\
         \n\
         solve <graph|dataset> [--variant proposed|yamout|no-lb|sequential]\n\
        \x20                   [--workers N] [--timeout SECS] [--sched steal|sharded]\n\
        \x20                   [--induce-threshold A]  (induce split components when |C| <= A*view; 0 = off)\n\
        \x20                   [--node-repr owned|delta] (delta: speculative in-place branching — right\n\
        \x20                                            children pin their parent frame + covered-vertex\n\
        \x20                                            delta, undone on backtrack, materialized when\n\
        \x20                                            stolen; owned copies are the ablation baseline.\n\
        \x20                                            CAVC_NODE_REPR sets the process default)\n\
        \x20                   [--max-pin-depth D]     (delta: chain length before a forced owned\n\
        \x20                                            snapshot bounds undo/replay cost)\n\
        \x20                   [--check]               (extract a witness cover on any variant and\n\
        \x20                                            verify it edge-by-edge against the input)\n\
        \x20                   [--jobs LIST]           (batch mode: one resident service solves every\n\
        \x20                                            graph in LIST — one spec per line, '#' comments —\n\
        \x20                                            plus any extra positional specs, concurrently)\n\
        \x20                   [--lane latency|tput]   (batch: pin every submitted job to a QoS lane;\n\
        \x20                                            default classifies by reduced-graph size)\n\
        \x20                   [--max-queued N]        (batch: admission-queue bound — submits past it\n\
        \x20                                            block, exerting backpressure on the driver)\n\
        \x20                   [--submit-timeout SECS] (batch: give up on a submit stuck behind\n\
        \x20                                            admission backpressure after SECS)\n\
        \x20                   [--retry N]             (batch: rerun a worker-panicked job on the\n\
        \x20                                            sequential solver up to N times before\n\
        \x20                                            surfacing it as failed)\n\
        \x20                   [--mem-soft BYTES]      (batch: memory-watchdog soft limit — past it the\n\
        \x20                                            service holds throughput-lane dispatch and\n\
        \x20                                            forces the delta node representation)\n\
        \x20                   [--mem-hard BYTES]      (batch: memory-watchdog hard limit — submits\n\
        \x20                                            past it shed with a MemoryPressure error)\n\
        \x20                   [--memo on|off]         (batch: cross-job component memo cache — exact\n\
        \x20                                            component covers are reused across jobs on the\n\
        \x20                                            resident service; CAVC_MEMO sets the default)\n\
        \x20                   [--memo-bytes N]        (batch: memo-cache byte budget; default is a\n\
        \x20                                            quarter of the watchdog stack budget, and\n\
        \x20                                            CAVC_MEMO_BYTES overrides)\n\
        \x20                   [--autotune on|off]     (batch/serve: online controller retunes node\n\
        \x20                                            repr, pin depth, induction gating, and pool\n\
        \x20                                            shape from live counters; default on, CAVC_AUTOTUNE\n\
        \x20                                            overrides. Explicit --node-repr/--max-pin-depth/\n\
        \x20                                            --induce-threshold/--max-queued/--memo-bytes pin\n\
        \x20                                            that knob; the batch summary prints the\n\
        \x20                                            converged settings)\n\
        \x20                   [--remote HOST:PORT]    (run the job on a `cavc serve` instance over the\n\
        \x20                                            length-prefixed wire protocol instead of in\n\
        \x20                                            process; works with --jobs batch mode too, and\n\
        \x20                                            --check re-verifies the witness locally.\n\
        \x20                                            --lane/--timeout/--tenant/--memo travel with\n\
        \x20                                            each job; a backpressured server answers with\n\
        \x20                                            typed queue-full/quota/memory errors)\n\
         pvc <graph|dataset> --k K [--variant ...] [--jobs LIST] [--check] [--remote HOST:PORT]\n         mis <graph|dataset> [--variant ...] [--check] [--remote HOST:PORT]\n\
         serve --addr HOST:PORT [--max-conns N] [--workers N] [--sched steal|sharded]\n\
        \x20      [--max-queued N] [--submit-timeout SECS] [--retry N] [--mem-soft BYTES]\n\
        \x20      [--mem-hard BYTES] [--memo on|off] [--memo-bytes N] [--autotune on|off]\n\
        \x20                  (expose one resident VcService over TCP: per-connection readers feed a\n\
        \x20                   single admission coordinator; --submit-timeout > 0 lets a submit wait\n\
        \x20                   out backpressure server-side instead of bouncing immediately; stats\n\
        \x20                   are scrapeable as a wire frame)\n\
         info <graph|dataset>\n\
         components <graph|dataset> [--no-accel]\n\
         gen <er|ba|grid|cfat|phat|banded|union> --out FILE [--n N] [--p P] [--seed S]\n\
         datasets\n\
         tables <1|2|3|4|5|6|fig4>   (CAVC_TIMEOUT_S bounds each cell)",
        cavc::VERSION
    );
}

/// Load a graph argument: a dataset name from the suite, or a file path.
fn load_graph(spec: &str) -> Result<Graph> {
    if let Some(d) = datasets::dataset(spec) {
        return Ok(d.build());
    }
    let p = Path::new(spec);
    if p.exists() {
        return io::read_graph(p);
    }
    bail!("{spec}: not a dataset name or readable file (try `cavc datasets`)")
}

fn parse_config(args: &Args) -> Result<SolverConfig> {
    let mut cfg = match args.get("variant").unwrap_or("proposed") {
        "proposed" => SolverConfig::proposed(),
        "yamout" | "prior" => SolverConfig::prior_work(),
        "no-lb" | "nolb" => SolverConfig::no_load_balance(),
        "sequential" | "seq" => SolverConfig::sequential(),
        v => bail!("unknown variant {v:?}"),
    };
    if let Some(w) = args.get("workers") {
        cfg.workers = Some(w.parse().context("--workers")?);
    }
    if let Some(s) = args.get("sched") {
        cfg.scheduler = SchedulerKind::parse(s)
            .with_context(|| format!("unknown scheduler {s:?} (use steal|sharded)"))?;
    }
    if let Some(t) = args.get("induce-threshold") {
        let t: f64 = t.parse().context("--induce-threshold")?;
        if !(0.0..=1.0).contains(&t) {
            bail!("--induce-threshold must be in [0, 1] (0 disables tree induction)");
        }
        cfg.induce_threshold = t;
    }
    if let Some(r) = args.get("node-repr") {
        cfg.node_repr = solver::NodeRepr::parse(r)
            .with_context(|| format!("unknown node representation {r:?} (use owned|delta)"))?;
    }
    if let Some(d) = args.get("max-pin-depth") {
        cfg.max_pin_depth = d.parse().context("--max-pin-depth")?;
    }
    if let Some(m) = args.get("memo") {
        cfg.memo = Some(match m {
            "on" => true,
            "off" => false,
            v => bail!("--memo takes on|off, got {v:?}"),
        });
    }
    if let Some(a) = args.get("autotune") {
        cfg.autotune = Some(match a {
            "on" => true,
            "off" => false,
            v => bail!("--autotune takes on|off, got {v:?}"),
        });
    }
    let t: f64 = args.get_parse("timeout", 0.0).map_err(Error::msg)?;
    if t > 0.0 {
        cfg.timeout = Some(Duration::from_secs_f64(t));
    }
    Ok(cfg)
}

/// Resolve the batch job list: the lines of `--jobs LIST` (one graph
/// spec per line, `#` comments) plus any extra positional specs.
fn batch_specs(args: &Args, list: &str) -> Result<Vec<String>> {
    let text = std::fs::read_to_string(list).with_context(|| format!("reading {list}"))?;
    let mut specs: Vec<String> = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(String::from)
        .collect();
    specs.extend(args.pos_rest(1).iter().cloned());
    if specs.is_empty() {
        bail!("--jobs {list}: no graph specs (one per line; '#' starts a comment)");
    }
    Ok(specs)
}

/// One resident service shaped by the CLI flags (workers / scheduler /
/// per-job solver knobs all come in through the parsed config; the
/// admission-queue bound, retry policy, and memory-watchdog limits come
/// in separately from `--max-queued` / `--retry` / `--mem-soft` /
/// `--mem-hard`).
fn build_service(args: &Args, cfg: &SolverConfig, max_queued: Option<usize>) -> Result<VcService> {
    let mut b = VcService::builder().config(cfg.clone()).scheduler(cfg.scheduler);
    if let Some(w) = cfg.workers {
        b = b.workers(w);
    }
    if let Some(q) = max_queued {
        b = b.max_queued(q);
    }
    if let Some(n) = args.get("retry") {
        let attempts: u32 = n.parse().context("--retry")?;
        if attempts == 0 {
            bail!("--retry must be >= 1 (omit the flag to disable failure recovery)");
        }
        b = b.retry(RetryPolicy { attempts, ..RetryPolicy::default() });
    }
    if let Some(s) = args.get("mem-soft") {
        b = b.mem_soft(s.parse().context("--mem-soft")?);
    }
    if let Some(s) = args.get("mem-hard") {
        b = b.mem_hard(s.parse().context("--mem-hard")?);
    }
    if let Some(s) = args.get("memo-bytes") {
        b = b.memo_bytes(s.parse().context("--memo-bytes")?);
    }
    Ok(b.build())
}

/// Batch mode: feed every graph spec through one resident service as
/// concurrent jobs and print a per-job table plus aggregate throughput.
/// With `--check`, every job extracts its witness and the run fails if
/// any witness is missing or does not verify.
fn cmd_batch(args: &Args, list: &str, k: Option<u32>) -> Result<()> {
    if let Some(addr) = args.get("remote") {
        return cmd_batch_remote(args, list, k, addr);
    }
    let specs = batch_specs(args, list)?;
    let check = args.flag("check");
    let cfg = parse_config(args)?;
    if cfg.variant == Variant::Sequential || cfg.variant == Variant::NoLoadBalance {
        bail!("--jobs batch mode needs a load-balanced parallel variant (proposed|yamout)");
    }
    let lane = match args.get("lane") {
        Some(s) => Some(
            Lane::parse(s).with_context(|| format!("unknown lane {s:?} (use latency|throughput)"))?,
        ),
        None => None,
    };
    let submit_timeout: f64 = args.get_parse("submit-timeout", 0.0).map_err(Error::msg)?;
    let max_queued: Option<usize> =
        args.get("max-queued").map(str::parse).transpose().context("--max-queued")?;
    let svc = build_service(args, &cfg, max_queued)?;
    let t0 = Instant::now();
    let mut jobs: Vec<(String, JobHandle)> = Vec::with_capacity(specs.len());
    for spec in &specs {
        let g = load_graph(spec)?;
        let problem = match k {
            Some(k) => Problem::pvc(g, k),
            None => Problem::mvc(g),
        };
        let opts = cavc::solver::JobOptions {
            extract_witness: check,
            priority: lane,
            ..Default::default()
        };
        // A submit can block on admission backpressure (bounded queue);
        // --submit-timeout turns a stuck submit into a clean error
        // instead of an indefinitely wedged driver.
        let handle = if submit_timeout > 0.0 {
            match svc.submit_within(problem, opts, Duration::from_secs_f64(submit_timeout)) {
                Ok(h) => h,
                Err(e) => bail!("submit {spec}: {e} (waited {submit_timeout}s)"),
            }
        } else {
            svc.submit_with(problem, opts)
        };
        jobs.push((spec.clone(), handle));
    }
    let submitted = t0.elapsed().as_secs_f64();

    let mut agg = EngineStats::default();
    let mut check_failures: Vec<String> = Vec::new();
    let mut failed_jobs: Vec<String> = Vec::new();
    println!(
        "{:<28} {:>10} {:>12} {:>10}  {}",
        "graph", "answer", "tree nodes", "elapsed", "status"
    );
    for (spec, job) in &jobs {
        let sol = job.wait();
        agg.merge(&sol.stats);
        let answer = match k {
            Some(_) if sol.feasible => format!("<= {}", sol.objective),
            Some(k) => format!("> {k}"),
            None => sol.objective.to_string(),
        };
        let status = match sol.termination {
            Termination::Complete => "ok",
            Termination::DeadlineExpired => "timeout",
            Termination::Cancelled => "cancelled",
            Termination::Recovered => "recovered",
            Termination::Failed => "failed",
        };
        if sol.termination == Termination::Failed {
            failed_jobs.push(match &sol.failure {
                Some(msg) => format!("{spec} ({msg})"),
                None => spec.clone(),
            });
        }
        // Witness verdict: a feasible PVC / any MVC answer must carry a
        // verified witness under --check; infeasible PVC has nothing to
        // witness.
        let checked = if !check {
            ""
        } else if sol.witness_verified == Some(true) {
            " witness=ok"
        } else if k.is_some() && !sol.feasible {
            " witness=n/a"
        } else {
            check_failures.push(spec.clone());
            " witness=FAILED"
        };
        println!(
            "{:<28} {:>10} {:>12} {:>9.3}s  {}{}",
            spec,
            answer,
            sol.stats.tree_nodes,
            sol.elapsed.as_secs_f64(),
            status,
            checked
        );
    }
    // A Failed job produced no trusted answer (it exhausted any retry
    // budget): the batch as a whole must exit non-zero so drivers see it.
    if !failed_jobs.is_empty() {
        bail!("{} job(s) failed: {}", failed_jobs.len(), failed_jobs.join(", "));
    }
    if !check_failures.is_empty() {
        bail!(
            "--check: {} job(s) without a verified witness: {}",
            check_failures.len(),
            check_failures.join(", ")
        );
    }
    let total = t0.elapsed().as_secs_f64();
    println!(
        "-- {} jobs on {} resident workers: {:.3}s total ({:.1} jobs/s; submit {:.3}s), {} tree nodes",
        jobs.len(),
        svc.workers(),
        total,
        jobs.len() as f64 / total.max(1e-9),
        submitted,
        agg.tree_nodes
    );
    let m = svc.stats().memo;
    if m.lookups > 0 || m.inserts > 0 {
        println!(
            "-- memo: {} hits / {} lookups ({} inserts, {} evictions, {} bytes held, ~{} nodes saved)",
            m.hits, m.lookups, m.inserts, m.evictions, m.bytes, m.saved_nodes
        );
    }
    let a = svc.stats().autotune;
    if a.enabled {
        let converged = if a.converged_epoch > 0 {
            format!("converged@{}", a.converged_epoch)
        } else {
            "not converged".to_string()
        };
        println!(
            "-- autotune: {} epochs / {} flips ({}), pin-depth {}, delta-buckets {:#010b}, \
             steal {} ppm, admission {} / queue {}",
            a.epochs,
            a.flips,
            converged,
            a.pin_depth,
            a.delta_buckets,
            a.steal_rate_ppm,
            a.admission_capacity,
            a.queue_capacity
        );
    }
    Ok(())
}

/// Report a witness verification outcome on one line; errors name the
/// first offending edge. Returns an `Err` so `--check` failures exit
/// non-zero.
fn report_check(kind: &str, ok: std::result::Result<(), witness::WitnessError>) -> Result<()> {
    match ok {
        Ok(()) => {
            println!("witness check   : ok ({kind} verified edge-by-edge)");
            Ok(())
        }
        Err(e) => {
            println!("witness check   : FAILED — {e}");
            bail!("witness verification failed: {e}")
        }
    }
}

fn cmd_solve(args: &Args) -> Result<()> {
    if let Some(list) = args.get("jobs") {
        return cmd_batch(args, list, None);
    }
    if let Some(addr) = args.get("remote") {
        return cmd_remote(args, addr, ProblemKind::Mvc);
    }
    let spec = args.pos(1).context("solve: missing <graph|dataset>")?;
    let g = load_graph(spec)?;
    let check = args.flag("check");
    let mut cfg = parse_config(args)?;
    if cfg.variant == Variant::Sequential || check {
        cfg.extract_cover = true;
    }
    let r = solver::solve_mvc(&g, &cfg);
    println!("graph           : {spec} (|V|={}, |E|={})", g.num_vertices(), g.num_edges());
    println!("variant         : {}", cfg.variant.name());
    println!("scheduler       : {}", cfg.scheduler.name());
    println!("mvc             : {}{}", r.best, if r.timed_out { " (timeout: upper bound)" } else { "" });
    println!("elapsed         : {:.3}s", r.elapsed.as_secs_f64());
    println!("tree nodes      : {}", r.stats.tree_nodes);
    println!("component splits: {}", r.stats.component_branches);
    println!(
        "prep            : n {} -> {}, forced {}, dtype {}, blocks {}, workers {}",
        r.prep.n_original,
        r.prep.n_residual,
        r.prep.forced,
        r.prep.dtype.name(),
        r.prep.blocks,
        r.prep.workers
    );
    match &r.cover {
        Some(c) => {
            println!("cover           : {} vertices extracted", c.len());
            report_check("cover", witness::verify_cover(&g, c))?;
        }
        None if check => bail!("--check: no witness extracted (timeout?)"),
        None => {}
    }
    Ok(())
}

fn cmd_pvc(args: &Args) -> Result<()> {
    let k: u32 = args
        .get("k")
        .context("pvc: missing --k")?
        .parse()
        .context("--k")?;
    if let Some(list) = args.get("jobs") {
        return cmd_batch(args, list, Some(k));
    }
    if let Some(addr) = args.get("remote") {
        return cmd_remote(args, addr, ProblemKind::Pvc);
    }
    let spec = args.pos(1).context("pvc: missing <graph|dataset>")?;
    let g = load_graph(spec)?;
    let check = args.flag("check");
    let mut cfg = parse_config(args)?;
    if check {
        cfg.extract_cover = true;
    }
    let r = solver::solve_pvc(&g, k, &cfg);
    println!("graph   : {spec} (|V|={}, |E|={})", g.num_vertices(), g.num_edges());
    println!("variant : {}", cfg.variant.name());
    match (r.found, r.timed_out) {
        (true, _) => println!("found   : yes (size {})", r.size.unwrap()),
        (false, true) => println!("found   : unknown (timeout)"),
        (false, false) => println!("found   : no (no cover of size <= {k})"),
    }
    println!("elapsed : {:.3}s", r.elapsed.as_secs_f64());
    println!("nodes   : {}", r.stats.tree_nodes);
    if let Some(c) = &r.cover {
        println!("cover   : {} vertices (budget {k})", c.len());
        report_check("cover", witness::verify_cover(&g, c))?;
    } else if check && r.found {
        bail!("--check: feasible answer carried no witness");
    }
    Ok(())
}

fn cmd_mis(args: &Args) -> Result<()> {
    if let Some(addr) = args.get("remote") {
        return cmd_remote(args, addr, ProblemKind::Mis);
    }
    let spec = args.pos(1).context("mis: missing <graph|dataset>")?;
    let g = load_graph(spec)?;
    let check = args.flag("check");
    let mut cfg = parse_config(args)?;
    if cfg.variant == Variant::Sequential || check {
        cfg.extract_cover = true;
    }
    let r = cavc::solver::mis::solve_mis(&g, &cfg);
    println!("graph   : {spec} (|V|={}, |E|={})", g.num_vertices(), g.num_edges());
    println!("alpha   : {}{}", r.alpha, if r.mvc.timed_out { " (timeout: lower bound)" } else { "" });
    println!("elapsed : {:.3}s", r.mvc.elapsed.as_secs_f64());
    match &r.set {
        Some(set) => {
            println!("witness : {} vertices", set.len());
            report_check("independent set", witness::verify_independent_set(&g, set))?;
        }
        None if check => bail!("--check: no witness extracted (timeout?)"),
        None => {}
    }
    Ok(())
}

/// The per-job options that travel with a remote submit. The solver
/// knobs in `--variant`/`--sched`/… stay server-side (the resident
/// service was built with its own config); only the wire-visible
/// subset crosses: lane, deadline, tenant, witness extraction, memo.
fn remote_options(args: &Args, cfg: &SolverConfig, check: bool) -> Result<WireOptions> {
    let lane = match args.get("lane") {
        Some(s) => Some(
            Lane::parse(s).with_context(|| format!("unknown lane {s:?} (use latency|throughput)"))?,
        ),
        None => None,
    };
    Ok(WireOptions {
        lane,
        timeout: cfg.timeout,
        tenant: args.get("tenant").map(String::from),
        extract_witness: check,
        memo: cfg.memo,
    })
}

fn connect_remote(addr: &str) -> Result<VcClient> {
    VcClient::connect(addr).with_context(|| format!("connecting to {addr}"))
}

/// Run one problem on a `cavc serve` instance instead of in process.
/// The answer comes back over the wire; with `--check` the witness is
/// re-verified *locally* edge-by-edge against the input graph, so a
/// buggy or hostile server cannot hand back an unvouched answer.
fn cmd_remote(args: &Args, addr: &str, kind: ProblemKind) -> Result<()> {
    let spec = args.pos(1).context("missing <graph|dataset>")?;
    let g = Arc::new(load_graph(spec)?);
    let check = args.flag("check");
    let cfg = parse_config(args)?;
    let (problem, k) = match kind {
        ProblemKind::Mvc => (Problem::mvc(Arc::clone(&g)), None),
        ProblemKind::Pvc => {
            let k: u32 = args.get("k").context("pvc: missing --k")?.parse().context("--k")?;
            (Problem::pvc(Arc::clone(&g), k), Some(k))
        }
        ProblemKind::Mis => (Problem::mis(Arc::clone(&g)), None),
    };
    let opts = remote_options(args, &cfg, check)?;
    let mut client = connect_remote(addr)?;
    let t0 = Instant::now();
    let sol = client.solve(&problem, opts).with_context(|| format!("remote solve on {addr}"))?;
    let round_trip = t0.elapsed();

    println!("graph           : {spec} (|V|={}, |E|={})", g.num_vertices(), g.num_edges());
    println!("server          : {addr} (protocol v{})", client.version());
    let answer = match (kind, sol.feasible) {
        (ProblemKind::Mvc, _) => format!("mvc {}", sol.objective),
        (ProblemKind::Pvc, true) => format!("pvc yes (size {})", sol.objective),
        (ProblemKind::Pvc, false) => format!("pvc no (no cover of size <= {})", k.unwrap_or(0)),
        (ProblemKind::Mis, _) => format!("alpha {}", sol.objective),
    };
    println!(
        "answer          : {}{}",
        answer,
        if sol.timed_out() { " (timeout: bound only)" } else { "" }
    );
    println!(
        "elapsed         : {:.3}s on server ({:.3}s round trip)",
        sol.elapsed.as_secs_f64(),
        round_trip.as_secs_f64()
    );
    println!("tree nodes      : {}", sol.tree_nodes);
    println!(
        "prep            : n {} -> {}, forced {}, greedy ub {}",
        g.num_vertices(),
        sol.n_residual,
        sol.forced,
        sol.greedy_ub
    );
    if sol.memo_lookups > 0 {
        println!("memo            : {} hits / {} lookups", sol.memo_hits, sol.memo_lookups);
    }
    if sol.termination == Termination::Failed {
        bail!(
            "remote job failed: {}",
            sol.failure.as_deref().unwrap_or("no failure detail")
        );
    }
    match &sol.witness {
        Some(w) => {
            println!("witness         : {} vertices returned over the wire", w.len());
            match kind {
                ProblemKind::Mis => {
                    report_check("independent set", witness::verify_independent_set(&g, w))?
                }
                _ => report_check("cover", witness::verify_cover(&g, w))?,
            }
        }
        // Infeasible PVC has nothing to witness; any other checked
        // answer without one is a failure (timeout or server fault).
        None if check && !(kind == ProblemKind::Pvc && !sol.feasible) => {
            bail!("--check: no witness came back (timeout?)")
        }
        None => {}
    }
    Ok(())
}

/// Batch mode against a remote server: submit every spec pipelined on
/// one connection, then collect replies by request id and print the
/// same per-job table as the in-process batch path.
fn cmd_batch_remote(args: &Args, list: &str, k: Option<u32>, addr: &str) -> Result<()> {
    let specs = batch_specs(args, list)?;
    let check = args.flag("check");
    let cfg = parse_config(args)?;
    let opts = remote_options(args, &cfg, check)?;
    let mut client = connect_remote(addr)?;
    println!("server: {addr} (protocol v{})", client.version());

    let t0 = Instant::now();
    // Keep every input graph alive for local witness re-verification.
    let mut graphs: Vec<Arc<Graph>> = Vec::with_capacity(specs.len());
    let mut ids: Vec<u64> = Vec::with_capacity(specs.len());
    for spec in &specs {
        let g = Arc::new(load_graph(spec)?);
        let problem = match k {
            Some(k) => Problem::pvc(Arc::clone(&g), k),
            None => Problem::mvc(Arc::clone(&g)),
        };
        let id = client
            .submit(&problem, opts.clone())
            .with_context(|| format!("submit {spec} to {addr}"))?;
        graphs.push(g);
        ids.push(id);
    }
    let submitted = t0.elapsed().as_secs_f64();

    // Replies arrive in completion order; bucket them by request id.
    // A typed error frame with a request id is that job's rejection; a
    // connection-scoped error (id 0) sinks the whole batch.
    let mut replies: HashMap<u64, std::result::Result<WireSolution, String>> = HashMap::new();
    while replies.len() < ids.len() {
        match client.recv().with_context(|| format!("receiving from {addr}"))? {
            ServerReply::Solution(s) => {
                replies.insert(s.req_id, Ok(s));
            }
            ServerReply::Error(e) if e.req_id != 0 => {
                replies.insert(e.req_id, Err(e.detail));
            }
            ServerReply::Error(e) => bail!("server rejected the connection: {}", e.detail),
            ServerReply::Stats(_) => {}
        }
    }

    let mut total_nodes: u64 = 0;
    let mut check_failures: Vec<String> = Vec::new();
    let mut failed_jobs: Vec<String> = Vec::new();
    println!(
        "{:<28} {:>10} {:>12} {:>10}  {}",
        "graph", "answer", "tree nodes", "elapsed", "status"
    );
    for ((spec, id), g) in specs.iter().zip(&ids).zip(&graphs) {
        let sol = match replies.get(id) {
            Some(Ok(s)) => s,
            Some(Err(detail)) => {
                println!("{:<28} {:>10} {:>12} {:>10}  rejected: {}", spec, "-", "-", "-", detail);
                failed_jobs.push(format!("{spec} ({detail})"));
                continue;
            }
            None => {
                failed_jobs.push(format!("{spec} (no reply)"));
                continue;
            }
        };
        total_nodes += sol.tree_nodes;
        let answer = match k {
            Some(_) if sol.feasible => format!("<= {}", sol.objective),
            Some(k) => format!("> {k}"),
            None => sol.objective.to_string(),
        };
        let status = match sol.termination {
            Termination::Complete => "ok",
            Termination::DeadlineExpired => "timeout",
            Termination::Cancelled => "cancelled",
            Termination::Recovered => "recovered",
            Termination::Failed => "failed",
        };
        if sol.termination == Termination::Failed {
            failed_jobs.push(match &sol.failure {
                Some(msg) => format!("{spec} ({msg})"),
                None => spec.clone(),
            });
        }
        // Re-verify the wire witness locally — the server's own
        // verified bit is reported but not trusted for --check.
        let checked = if !check {
            ""
        } else if sol
            .witness
            .as_deref()
            .is_some_and(|w| witness::verify_cover(g, w).is_ok())
        {
            " witness=ok"
        } else if k.is_some() && !sol.feasible {
            " witness=n/a"
        } else {
            check_failures.push(spec.clone());
            " witness=FAILED"
        };
        println!(
            "{:<28} {:>10} {:>12} {:>9.3}s  {}{}",
            spec,
            answer,
            sol.tree_nodes,
            sol.elapsed.as_secs_f64(),
            status,
            checked
        );
    }
    if !failed_jobs.is_empty() {
        bail!("{} job(s) failed: {}", failed_jobs.len(), failed_jobs.join(", "));
    }
    if !check_failures.is_empty() {
        bail!(
            "--check: {} job(s) without a locally verified witness: {}",
            check_failures.len(),
            check_failures.join(", ")
        );
    }
    let total = t0.elapsed().as_secs_f64();
    println!(
        "-- {} remote jobs: {:.3}s total ({:.1} jobs/s; submit {:.3}s), {} tree nodes",
        ids.len(),
        total,
        ids.len() as f64 / total.max(1e-9),
        submitted,
        total_nodes
    );
    // Scrape the server-side admission/memo ledger over the wire.
    if let Ok(stats) = client.stats() {
        let a = &stats.admission;
        println!(
            "-- server: {} latency + {} throughput dispatched, {} shed ({} quota, {} memory)",
            a.dispatched_latency,
            a.dispatched_throughput,
            a.rejected,
            a.quota_rejected,
            a.mem_rejected
        );
        let m = &stats.memo;
        if m.lookups > 0 || m.inserts > 0 {
            println!(
                "-- server memo: {} hits / {} lookups ({} inserts, {} bytes held)",
                m.hits, m.lookups, m.inserts, m.bytes
            );
        }
    }
    Ok(())
}

/// `cavc serve`: expose one resident [`VcService`] over TCP until the
/// process is killed. All the batch-mode service flags apply; the
/// wire-protocol knobs are `--addr` and `--max-conns`.
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = parse_config(args)?;
    let addr = args.get("addr").unwrap_or("127.0.0.1:9717");
    let max_queued: Option<usize> =
        args.get("max-queued").map(str::parse).transpose().context("--max-queued")?;
    let svc = build_service(args, &cfg, max_queued)?;
    let submit_timeout: f64 = args.get_parse("submit-timeout", 0.0).map_err(Error::msg)?;
    let server_cfg = ServerConfig {
        max_conns: args.get_parse("max-conns", 64).map_err(Error::msg)?,
        submit_wait: Duration::from_secs_f64(submit_timeout.max(0.0)),
        ..ServerConfig::default()
    };
    let server = VcServer::bind(addr, svc, server_cfg)
        .with_context(|| format!("binding {addr}"))?;
    println!(
        "cavc serve: listening on {} (protocol v{}, {} workers, scheduler {})",
        server.local_addr(),
        solver::PROTOCOL_VERSION,
        server.service().workers(),
        cfg.scheduler.name()
    );
    // Serve until killed; the accept loop, readers, and coordinator all
    // live on background threads.
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let spec = args.pos(1).context("info: missing <graph|dataset>")?;
    let g = load_graph(spec)?;
    let m = cavc::graph::metrics::compute(&g);
    println!("graph      : {spec}");
    println!("|V|        : {}", m.n);
    println!("|E|        : {}", m.m);
    println!("max degree : {}", m.max_degree);
    println!("avg degree : {:.2}", m.avg_degree);
    println!("density    : {:.3}%", 100.0 * m.density);
    println!("components : {}", m.components);
    println!("isolated   : {}", m.isolated);
    println!("degree-1   : {}", m.degree_one);
    println!("triangles  : {}", m.triangles);
    let p = cavc::prep::prepare(&g, &cavc::prep::PrepConfig::default(), None);
    println!("-- preprocessing (paper §IV-B) --");
    println!("greedy ub  : {}", p.greedy_ub);
    println!("forced     : {}", p.forced_cover.len());
    println!("residual |V|: {}", p.residual.graph.num_vertices());
    println!("dtype      : {}", p.dtype.name());
    println!(
        "occupancy  : {} blocks, degree array {} B, shared-mem fit: {}",
        p.occupancy.blocks,
        p.occupancy.degree_array_bytes,
        p.occupancy.fits_shared_mem
    );
    Ok(())
}

fn cmd_components(args: &Args) -> Result<()> {
    let spec = args.pos(1).context("components: missing <graph|dataset>")?;
    let g = load_graph(spec)?;
    let use_accel = !args.flag("no-accel");
    let sets = if use_accel {
        match cavc::runtime::Accelerator::new() {
            Ok(acc) => match acc.component_split(&g) {
                Ok(sets) => {
                    println!("backend: xla/pjrt ({} artifacts)", "hlo-text");
                    sets
                }
                Err(e) => {
                    println!("backend: cpu (accelerator unavailable: {e})");
                    cavc::graph::components::vertex_sets(&g)
                }
            },
            Err(e) => {
                println!("backend: cpu (no pjrt: {e})");
                cavc::graph::components::vertex_sets(&g)
            }
        }
    } else {
        println!("backend: cpu (--no-accel)");
        cavc::graph::components::vertex_sets(&g)
    };
    println!("components: {}", sets.len());
    let mut sizes: Vec<usize> = sets.iter().map(|s| s.len()).collect();
    sizes.sort_unstable_by(|a, b| b.cmp(a));
    println!("largest   : {:?}", &sizes[..sizes.len().min(10)]);
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let family = args.pos(1).context("gen: missing family")?;
    let out = args.get("out").context("gen: missing --out")?;
    let n: usize = args.get_parse("n", 200).map_err(Error::msg)?;
    let p: f64 = args.get_parse("p", 0.1).map_err(Error::msg)?;
    let seed: u64 = args.get_parse("seed", 42).map_err(Error::msg)?;
    let g = match family {
        "er" => generators::erdos_renyi(n, p, seed),
        "ba" => generators::barabasi_albert(n, 2, seed),
        "grid" => {
            let rows: usize = args.get_parse("rows", 16).map_err(Error::msg)?;
            let cols: usize = args.get_parse("cols", n.div_ceil(16)).map_err(Error::msg)?;
            generators::grid(rows, cols, p, seed)
        }
        "cfat" => {
            let band: usize = args.get_parse("m", 6).map_err(Error::msg)?;
            generators::c_fat(n, band, seed)
        }
        "phat" => generators::p_hat(n, 0.1, 0.5, seed),
        "banded" => {
            let band: usize = args.get_parse("m", 2).map_err(Error::msg)?;
            generators::banded(n, band, p, 50, seed)
        }
        "geo" => generators::geometric(n, p.max(0.01), seed),
        "union" => {
            let lo: usize = args.get_parse("rows", 5).map_err(Error::msg)?;
            let hi: usize = args.get_parse("cols", 12).map_err(Error::msg)?;
            generators::union_of_random(n / 10, lo, hi, p.max(0.15), seed)
        }
        f => bail!("unknown family {f:?}"),
    };
    let path = Path::new(out);
    let file = std::fs::File::create(path).with_context(|| format!("creating {out}"))?;
    match path.extension().and_then(|e| e.to_str()) {
        Some("gr") => io::write_pace(&g, file)?,
        _ => io::write_edge_list(&g, file)?,
    }
    println!("wrote {} (|V|={}, |E|={})", out, g.num_vertices(), g.num_edges());
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    println!("{:<24} {:<40} {:>10} {:>10}", "name", "family", "paper |V|", "paper |E|");
    for d in datasets::suite() {
        println!("{:<24} {:<40} {:>10} {:>10}", d.name, d.family, d.paper_nv, d.paper_ne);
    }
    println!("-- table VI suite --");
    for d in datasets::table6_suite() {
        println!("{:<24} {:<40} {:>10} {:>10}", d.name, d.family, d.paper_nv, d.paper_ne);
    }
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let which = args.pos(1).unwrap_or("1");
    let stdout = std::io::stdout();
    let suite = datasets::suite();
    match which {
        "1" => {
            let rows: Vec<_> = suite.iter().map(tables::table1_row).collect();
            tables::print_table1(&rows, stdout.lock())?;
        }
        "2" => {
            let rows: Vec<_> = suite.iter().map(tables::table2_row).collect();
            tables::print_table2(&rows, stdout.lock())?;
        }
        "3" => {
            let rows: Vec<_> = suite.iter().map(tables::table3_row).collect();
            tables::print_table3(&rows, stdout.lock())?;
        }
        "4" => {
            let rows: Vec<_> = suite.iter().map(tables::table4_row).collect();
            tables::print_table4(&rows, stdout.lock())?;
        }
        "5" => {
            let rows: Vec<_> = suite.iter().flat_map(|d| tables::table5_rows(d)).collect();
            tables::print_table5(&rows, stdout.lock())?;
        }
        "6" => {
            let rows: Vec<_> =
                datasets::table6_suite().iter().map(tables::table6_row).collect();
            tables::print_table6(&rows, stdout.lock())?;
        }
        "fig4" => {
            let rows: Vec<_> = suite.iter().map(tables::fig4_row).collect();
            tables::print_fig4(&rows, stdout.lock())?;
        }
        t => bail!("unknown table {t:?} (use 1..6 or fig4)"),
    }
    Ok(())
}
