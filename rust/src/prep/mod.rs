//! Root preparation pipeline (paper §IV-B): greedy upper bound →
//! exhaustive root reduction (incl. crown) → induced subgraph → degree
//! dtype selection → occupancy plan.
//!
//! Shared by every solver variant and by the Table IV harness (which
//! reports the before/after effect of exactly this stage).

use crate::degree::Dtype;
use crate::graph::{Graph, InducedSubgraph};
use crate::reduce::{self, RootReduceStats, UnwindLog};
use crate::solver::greedy;
use crate::solver::occupancy::{Occupancy, OccupancyModel};
use crate::solver::witness::CoverLift;
use crate::util::BitSet;

/// Knobs for the preparation stage.
#[derive(Debug, Clone)]
pub struct PrepConfig {
    /// Run root reductions and induce a subgraph (§IV-B). When false the
    /// search runs over the original graph (prior-work behaviour).
    pub reduce_root: bool,
    /// Apply the crown rule at the root (§IV-B).
    pub use_crown: bool,
    /// Select the smallest degree dtype that fits Δ (§IV-D).
    pub small_dtypes: bool,
}

impl Default for PrepConfig {
    fn default() -> Self {
        PrepConfig { reduce_root: true, use_crown: true, small_dtypes: true }
    }
}

/// Prepared instance, ready for the search engine.
#[derive(Debug)]
pub struct Prepared {
    /// The (possibly induced) residual graph the engine runs on.
    pub residual: InducedSubgraph,
    /// Vertices (original ids) forced into the cover at the root.
    pub forced_cover: Vec<u32>,
    /// Greedy upper bound on the *original* graph.
    pub greedy_ub: u32,
    /// Upper bound for the residual search: `greedy_ub − |forced|`
    /// (clamped to the residual's trivial bound).
    pub residual_ub: u32,
    /// Degree dtype selected for the residual.
    pub dtype: Dtype,
    /// Occupancy plan for the residual (Table IV "after" columns).
    pub occupancy: Occupancy,
    /// Root-reduction statistics.
    pub reduce_stats: RootReduceStats,
    /// Root-reduction decision log: replayed in reverse, it lifts any
    /// residual cover to a full-graph cover (see
    /// [`Prepared::lift_residual_cover`]).
    pub unwind: UnwindLog,
}

impl Prepared {
    /// Translate a residual-relative optimal size to the original graph.
    pub fn total_size(&self, residual_best: u32) -> u32 {
        self.forced_cover.len() as u32 + residual_best
    }

    /// Lift a cover over the residual graph to a cover of the original
    /// graph: translate residual ids through the induction map, then
    /// unwind the root reductions (restoring every forced vertex's cover
    /// decision; crown-independent vertices stay excluded).
    pub fn lift_residual_cover(&self, residual_cover: &[u32]) -> Vec<u32> {
        let mut cover = self.residual.translate_cover(residual_cover);
        self.unwind.unwind(&mut cover);
        cover
    }

    /// An owned [`CoverLift`] (induction map + unwind log) that outlives
    /// this preparation — the resident service keeps one per witness-
    /// extracting job after the prep graphs are dropped.
    pub fn cover_lift(&self) -> CoverLift {
        CoverLift::new(self.residual.to_original.clone(), self.unwind.clone())
    }
}

/// Run the preparation stage.
///
/// `ub_for_rules` lets PVC pass `k + 1` so the high-degree rule preserves
/// every cover of size ≤ k; MVC passes the greedy bound.
pub fn prepare(g: &Graph, cfg: &PrepConfig, ub_override: Option<u32>) -> Prepared {
    let greedy_ub = greedy::greedy_bound(g);
    let ub_for_rules = ub_override.unwrap_or(greedy_ub);

    let (residual, forced_cover, reduce_stats, unwind) = if cfg.reduce_root {
        let red = reduce::reduce_root(g, ub_for_rules, cfg.use_crown, true);
        (InducedSubgraph::new(g, &red.kept), red.in_cover, red.stats, red.log)
    } else {
        // identity induction: degree arrays sized to the original graph
        let mut keep = BitSet::new(g.num_vertices());
        for v in 0..g.num_vertices() {
            keep.set(v);
        }
        (
            InducedSubgraph::new(g, &keep),
            Vec::new(),
            RootReduceStats::default(),
            UnwindLog::default(),
        )
    };

    let max_deg = residual.graph.max_degree();
    let dtype = if cfg.small_dtypes { Dtype::for_max_degree(max_deg) } else { Dtype::U32 };
    let occupancy = OccupancyModel::default().plan(residual.graph.num_vertices(), dtype);

    let forced = forced_cover.len() as u32;
    // Residual search bound: improving on greedy means finding a residual
    // cover strictly below greedy_ub − forced; also the trivial |V|
    // bound.
    let ub = ub_for_rules.saturating_sub(forced).min(residual.graph.num_vertices() as u32 + 1);

    Prepared {
        residual,
        forced_cover,
        greedy_ub,
        residual_ub: ub,
        dtype,
        occupancy,
        reduce_stats,
        unwind,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::solver::oracle;

    #[test]
    fn reduction_shrinks_residual() {
        let g = generators::web_crawl(60, 240, 5);
        let p = prepare(&g, &PrepConfig::default(), None);
        assert!(p.residual.graph.num_vertices() < g.num_vertices() / 2);
    }

    #[test]
    fn identity_when_disabled() {
        let g = generators::erdos_renyi(40, 0.1, 2);
        let cfg = PrepConfig { reduce_root: false, use_crown: false, small_dtypes: false };
        let p = prepare(&g, &cfg, None);
        assert_eq!(p.residual.graph.num_vertices(), 40);
        assert!(p.forced_cover.is_empty());
        assert_eq!(p.dtype, Dtype::U32);
    }

    #[test]
    fn preparation_preserves_optimum() {
        for seed in 0..10 {
            let g = generators::erdos_renyi(16, 0.2, seed);
            let opt = oracle::mvc_size(&g);
            let p = prepare(&g, &PrepConfig::default(), None);
            let residual_opt = oracle::mvc_size(&p.residual.graph);
            let total = p.total_size(residual_opt);
            // total is optimal when strictly better than greedy, else the
            // greedy bound is optimal
            assert_eq!(total.min(p.greedy_ub), opt, "seed {seed}");
        }
    }

    #[test]
    fn lift_residual_cover_is_valid_and_optimal() {
        for seed in 0..8 {
            let g = generators::erdos_renyi(16, 0.2, seed);
            let opt = oracle::mvc_size(&g);
            let p = prepare(&g, &PrepConfig::default(), None);
            let sub = if p.residual.graph.num_vertices() == 0 {
                Vec::new()
            } else {
                oracle::mvc_cover(&p.residual.graph)
            };
            let cover = p.lift_residual_cover(&sub);
            assert!(g.is_vertex_cover(&cover), "seed {seed}");
            assert_eq!(cover.len(), sub.len() + p.forced_cover.len(), "seed {seed}");
            // total ≥ opt always; strictly beating greedy implies optimal
            // (prep soundness: min(total, greedy) == opt)
            assert!(cover.len() as u32 >= opt, "seed {seed}");
            if (cover.len() as u32) < p.greedy_ub {
                assert_eq!(cover.len() as u32, opt, "seed {seed}");
            }
        }
    }

    #[test]
    fn small_dtype_selected() {
        let g = generators::grid(10, 10, 0.0, 0); // Δ = 4 after anything
        let p = prepare(&g, &PrepConfig { reduce_root: false, ..Default::default() }, None);
        assert_eq!(p.dtype, Dtype::U8);
    }
}
