//! Crown reduction (Chlebík & Chlebíková), applied exhaustively at the
//! root node only (paper §IV-B: "sophisticated and heavyweight … applying
//! it just at the root node contributes to further reducing the graph").
//!
//! A *crown* is a pair `(I, H)` where `I` is a non-empty independent set,
//! `H = N(I)`, and there is a matching of `H` into `I` saturating `H`.
//! Every minimum vertex cover contains all of `H` and none of `I`, so we
//! add `H` to the cover and delete `I ∪ H`.
//!
//! Construction (standard):
//! 1. greedy maximal matching `M1`; `O` = unmatched vertices (independent);
//! 2. maximum bipartite matching `M2` between `O` and `N(O)`;
//! 3. `I0` = vertices of `O` unmatched by `M2`; iterate
//!    `I_{k+1} = I_k ∪ {M2-partners in O of N(I_k)}` to a fixpoint;
//!    `H = N(I)`. If `I0 = ∅` there is no crown.

use crate::graph::Graph;
use crate::util::BitSet;

use super::matching;

/// Result of one crown extraction on the residual graph.
#[derive(Debug, Clone)]
pub struct Crown {
    /// Head: vertices forced into the cover.
    pub head: Vec<u32>,
    /// Crown: independent vertices excluded from the cover.
    pub independent: Vec<u32>,
}

/// Find a crown in the residual graph (`alive[v] && deg[v] > 0`).
/// Returns `None` if no crown exists for the chosen maximal matching.
pub fn find_crown(g: &Graph, deg: &[u32]) -> Option<Crown> {
    let n = g.num_vertices();
    let present = |v: u32| deg[v as usize] > 0;

    // 1. Greedy maximal matching over residual edges.
    let residual_edges = g
        .edges()
        .filter(|&(u, v)| present(u) && present(v));
    let matched = matching::greedy_maximal_matching(n, residual_edges);

    // O = present, unmatched (independent by maximality of M1).
    let outsiders: Vec<u32> = (0..n as u32)
        .filter(|&v| present(v) && !matched[v as usize])
        .collect();
    if outsiders.is_empty() {
        return None;
    }

    // N(O): the matched neighbors of outsiders.
    let mut in_outsiders = BitSet::new(n);
    for &v in &outsiders {
        in_outsiders.set(v as usize);
    }
    let mut boundary_ids = vec![u32::MAX; n]; // graph id -> right id
    let mut boundary: Vec<u32> = Vec::new();
    for &o in &outsiders {
        for &w in g.neighbors(o) {
            if present(w) && boundary_ids[w as usize] == u32::MAX {
                boundary_ids[w as usize] = boundary.len() as u32;
                boundary.push(w);
            }
        }
    }
    if boundary.is_empty() {
        return None; // outsiders are isolated; nothing to do here
    }

    // 2. Maximum bipartite matching O ↔ N(O).
    let adj: Vec<Vec<u32>> = outsiders
        .iter()
        .map(|&o| {
            g.neighbors(o)
                .iter()
                .filter(|&&w| present(w))
                .map(|&w| boundary_ids[w as usize])
                .collect()
        })
        .collect();
    let m2 = matching::hopcroft_karp(outsiders.len(), boundary.len(), &adj);

    // 3. Grow I from the M2-unmatched outsiders.
    let mut in_i = vec![false; outsiders.len()];
    let mut stack: Vec<usize> = (0..outsiders.len())
        .filter(|&i| m2.left_match[i] == u32::MAX)
        .collect();
    if stack.is_empty() {
        return None; // M2 saturates O: no crown from this matching
    }
    for &i in &stack {
        in_i[i] = true;
    }
    let mut in_h = vec![false; boundary.len()];
    while let Some(i) = stack.pop() {
        for &r in &adj[i] {
            if !in_h[r as usize] {
                in_h[r as usize] = true;
                // r is matched (otherwise it would have been matched to an
                // unmatched outsider — impossible for a maximum matching).
                let partner = m2.right_match[r as usize];
                debug_assert_ne!(partner, u32::MAX, "boundary of I must be matched");
                if !in_i[partner as usize] {
                    in_i[partner as usize] = true;
                    stack.push(partner as usize);
                }
            }
        }
    }

    let independent: Vec<u32> = outsiders
        .iter()
        .enumerate()
        .filter(|&(i, _)| in_i[i])
        .map(|(_, &v)| v)
        .collect();
    let head: Vec<u32> = boundary
        .iter()
        .enumerate()
        .filter(|&(r, _)| in_h[r])
        .map(|(_, &v)| v)
        .collect();
    if independent.is_empty() {
        return None;
    }
    Some(Crown { head, independent })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    fn full_deg(g: &Graph) -> Vec<u32> {
        (0..g.num_vertices() as u32).map(|v| g.degree(v)).collect()
    }

    #[test]
    fn star_yields_crown() {
        // Star: leaves form I, hub forms H.
        let g = generators::star(6);
        let c = find_crown(&g, &full_deg(&g)).expect("star has a crown");
        assert_eq!(c.head, vec![0]);
        assert!(c.independent.len() >= 4);
    }

    #[test]
    fn crown_properties_hold() {
        for seed in 0..20 {
            let g = generators::erdos_renyi(40, 0.05, seed);
            let deg = full_deg(&g);
            if let Some(c) = find_crown(&g, &deg) {
                // I independent
                for (i, &u) in c.independent.iter().enumerate() {
                    for &v in &c.independent[i + 1..] {
                        assert!(!g.has_edge(u, v), "I not independent (seed {seed})");
                    }
                }
                // N(I) ⊆ H (over residual = whole graph here)
                let hset: std::collections::HashSet<u32> =
                    c.head.iter().copied().collect();
                for &u in &c.independent {
                    for &w in g.neighbors(u) {
                        if deg[w as usize] > 0 {
                            assert!(hset.contains(&w), "N(I) ⊄ H (seed {seed})");
                        }
                    }
                }
                // |H| ≤ |I| (H is matched into I)
                assert!(c.head.len() <= c.independent.len(), "seed {seed}");
            }
        }
    }

    #[test]
    fn clique_has_no_crown() {
        let g = generators::clique(6);
        // Greedy matching leaves possibly 0 outsiders on even cliques;
        // on odd cliques the single outsider is saturated by M2.
        let g7 = generators::clique(7);
        assert!(find_crown(&g, &full_deg(&g)).is_none());
        assert!(find_crown(&g7, &full_deg(&g7)).is_none());
    }

    #[test]
    fn respects_residual_degrees() {
        // Vertex 0 "removed" (deg 0) — crown must not touch it.
        let g = generators::star(5);
        let mut deg = full_deg(&g);
        deg[0] = 0; // hub gone → leaves isolated, no crown
        assert!(find_crown(&g, &deg).is_none());
    }
}
