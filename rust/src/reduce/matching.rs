//! Hopcroft–Karp maximum bipartite matching.
//!
//! Substrate for the crown reduction (paper §IV-B applies the crown rule
//! exhaustively at the root). Left vertices are `0..nl`, right vertices
//! `0..nr`, adjacency given per left vertex.

const NIL: u32 = u32::MAX;

/// Maximum matching result.
#[derive(Debug, Clone)]
pub struct Matching {
    /// For each left vertex, its matched right vertex or `u32::MAX`.
    pub left_match: Vec<u32>,
    /// For each right vertex, its matched left vertex or `u32::MAX`.
    pub right_match: Vec<u32>,
    /// Number of matched pairs.
    pub size: usize,
}

/// Compute a maximum matching of the bipartite graph `adj` (adjacency of
/// each left vertex, right ids). Runs in `O(E sqrt(V))`.
pub fn hopcroft_karp(nl: usize, nr: usize, adj: &[Vec<u32>]) -> Matching {
    assert_eq!(adj.len(), nl);
    let mut left_match = vec![NIL; nl];
    let mut right_match = vec![NIL; nr];
    let mut dist = vec![u32::MAX; nl];
    let mut queue = std::collections::VecDeque::new();

    loop {
        // BFS layering from free left vertices.
        queue.clear();
        for u in 0..nl {
            if left_match[u] == NIL {
                dist[u] = 0;
                queue.push_back(u as u32);
            } else {
                dist[u] = u32::MAX;
            }
        }
        let mut found_augmenting = false;
        while let Some(u) = queue.pop_front() {
            for &v in &adj[u as usize] {
                let w = right_match[v as usize];
                if w == NIL {
                    found_augmenting = true;
                } else if dist[w as usize] == u32::MAX {
                    dist[w as usize] = dist[u as usize] + 1;
                    queue.push_back(w);
                }
            }
        }
        if !found_augmenting {
            break;
        }
        // DFS augmentation along the layering.
        let mut size_grew = false;
        for u in 0..nl as u32 {
            if left_match[u as usize] == NIL
                && dfs(u, adj, &mut left_match, &mut right_match, &mut dist)
            {
                size_grew = true;
            }
        }
        if !size_grew {
            break;
        }
    }

    let size = left_match.iter().filter(|&&m| m != NIL).count();
    Matching { left_match, right_match, size }
}

fn dfs(
    u: u32,
    adj: &[Vec<u32>],
    left_match: &mut [u32],
    right_match: &mut [u32],
    dist: &mut [u32],
) -> bool {
    for &v in &adj[u as usize] {
        let w = right_match[v as usize];
        let ok = w == NIL
            || (dist[w as usize] == dist[u as usize] + 1
                && dfs(w, adj, left_match, right_match, dist));
        if ok {
            left_match[u as usize] = v;
            right_match[v as usize] = u;
            return true;
        }
    }
    dist[u as usize] = u32::MAX;
    false
}

/// Greedy maximal matching on a general graph (edge list), used to seed
/// the crown decomposition: returns a vertex-disjoint edge set such that
/// every remaining edge touches a matched vertex.
pub fn greedy_maximal_matching(
    n: usize,
    edges: impl Iterator<Item = (u32, u32)>,
) -> Vec<bool> {
    let mut matched = vec![false; n];
    for (u, v) in edges {
        if !matched[u as usize] && !matched[v as usize] {
            matched[u as usize] = true;
            matched[v as usize] = true;
        }
    }
    matched
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_matching_on_k33() {
        let adj = vec![vec![0, 1, 2], vec![0, 1, 2], vec![0, 1, 2]];
        let m = hopcroft_karp(3, 3, &adj);
        assert_eq!(m.size, 3);
        // consistency
        for (u, &v) in m.left_match.iter().enumerate() {
            assert_eq!(m.right_match[v as usize], u as u32);
        }
    }

    #[test]
    fn path_matching() {
        // L0-R0, R0-L1, L1-R1 → max matching 2
        let adj = vec![vec![0], vec![0, 1]];
        let m = hopcroft_karp(2, 2, &adj);
        assert_eq!(m.size, 2);
    }

    #[test]
    fn star_matches_one() {
        let adj = vec![vec![0], vec![0], vec![0]];
        let m = hopcroft_karp(3, 1, &adj);
        assert_eq!(m.size, 1);
    }

    #[test]
    fn empty_graph() {
        let m = hopcroft_karp(3, 3, &[vec![], vec![], vec![]]);
        assert_eq!(m.size, 0);
        assert!(m.left_match.iter().all(|&x| x == u32::MAX));
    }

    #[test]
    fn augmenting_path_needed() {
        // L0:{R0,R1} L1:{R0} — greedy could match L0-R0 blocking L1;
        // max matching must find size 2.
        let adj = vec![vec![0, 1], vec![0]];
        let m = hopcroft_karp(2, 2, &adj);
        assert_eq!(m.size, 2);
    }

    #[test]
    fn maximal_matching_covers_edges() {
        let edges = vec![(0u32, 1u32), (1, 2), (2, 3), (3, 4)];
        let matched = greedy_maximal_matching(5, edges.iter().copied());
        for (u, v) in edges {
            assert!(matched[u as usize] || matched[v as usize]);
        }
    }
}
