//! Reduction rules (paper §II-B, §III-D, §IV-B).
//!
//! Two deployment contexts:
//! * **root** ([`reduce_root`]): run exhaustively on the CPU over the
//!   original graph before the search — degree-one, degree-two triangle,
//!   high-degree, and the crown rule — then the caller induces a subgraph
//!   on the survivors (paper §IV-B);
//! * **in-engine**: the same cheap rules applied per search-tree node over
//!   the degree array; that variant lives in `solver::engine` because it
//!   is generic over the degree dtype, and is cross-checked against this
//!   one in tests.

pub mod crown;
pub mod matching;
pub mod special;

use crate::graph::Graph;
use crate::util::BitSet;

pub use special::{classify, SpecialComponent};

/// Statistics from the exhaustive root reduction.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RootReduceStats {
    /// Vertices forced into the cover by the degree-one rule.
    pub degree_one: usize,
    /// Vertices forced by the degree-two triangle rule.
    pub degree_two_triangle: usize,
    /// Vertices forced by the high-degree rule.
    pub high_degree: usize,
    /// Vertices forced by crown heads (over all crown iterations).
    pub crown_head: usize,
    /// Crown independent-set vertices excluded from the cover.
    pub crown_independent: usize,
    /// Number of crown iterations that found a crown.
    pub crown_rounds: usize,
    /// Vertices solved via special components (cliques/cycles) at the root.
    pub special_cover: usize,
}

/// One recorded root-reduction decision. Each rule application is logged
/// with enough structure that [`UnwindLog::unwind`] can replay it in
/// reverse and restore the removed vertices' cover decisions on top of a
/// residual-graph cover — the witness counterpart of the size bookkeeping
/// in [`RootReduction::in_cover`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum UnwindStep {
    /// Degree-one rule: the pendant's neighbor entered the cover.
    DegreeOne {
        /// The covered neighbor.
        covered: u32,
    },
    /// Degree-two triangle rule: both neighbors of the apex entered.
    Triangle {
        /// First covered neighbor.
        a: u32,
        /// Second covered neighbor.
        b: u32,
    },
    /// High-degree rule: the vertex itself entered the cover.
    HighDegree {
        /// The covered vertex.
        covered: u32,
    },
    /// Crown head vertex forced into the cover.
    CrownHead {
        /// The covered head vertex.
        covered: u32,
    },
    /// Crown independent vertex removed *without* covering it (all its
    /// edges are covered by crown heads).
    CrownExcluded {
        /// The excluded vertex.
        excluded: u32,
    },
    /// Closed-form special component (clique / chordless cycle) solved
    /// at the root: its canonical minimum cover.
    Special {
        /// The covered vertices.
        covered: Vec<u32>,
    },
}

/// Ordered log of every root-reduction decision, replayable in reverse
/// to lift a residual cover to a full-graph cover (`unwind`). All the
/// root rules commit *unconditional* decisions (the forced vertices are
/// in every improving cover regardless of how the residual is solved),
/// so the lift is a pure append — but the reverse replay and the
/// per-rule structure keep the log honest if a future rule (e.g. vertex
/// folding) needs residual-dependent unwinding.
#[derive(Debug, Clone, Default)]
pub struct UnwindLog {
    steps: Vec<UnwindStep>,
}

impl UnwindLog {
    fn push(&mut self, step: UnwindStep) {
        self.steps.push(step);
    }

    /// Recorded steps, in application order.
    pub fn steps(&self) -> &[UnwindStep] {
        &self.steps
    }

    /// True when no rule fired.
    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Number of vertices the log forces into the cover.
    pub fn covered_count(&self) -> usize {
        self.steps
            .iter()
            .map(|s| match s {
                UnwindStep::DegreeOne { .. }
                | UnwindStep::HighDegree { .. }
                | UnwindStep::CrownHead { .. } => 1,
                UnwindStep::Triangle { .. } => 2,
                UnwindStep::CrownExcluded { .. } => 0,
                UnwindStep::Special { covered } => covered.len(),
            })
            .sum()
    }

    /// Replay the log in reverse over `cover` (a valid cover of the
    /// residual graph, original ids): every rule-covered vertex is
    /// appended; crown-excluded vertices stay out. The result covers
    /// the full graph with exactly `covered_count()` extra vertices.
    pub fn unwind(&self, cover: &mut Vec<u32>) {
        for step in self.steps.iter().rev() {
            match step {
                UnwindStep::DegreeOne { covered }
                | UnwindStep::HighDegree { covered }
                | UnwindStep::CrownHead { covered } => cover.push(*covered),
                UnwindStep::Triangle { a, b } => {
                    cover.push(*a);
                    cover.push(*b);
                }
                UnwindStep::CrownExcluded { .. } => {}
                UnwindStep::Special { covered } => cover.extend_from_slice(covered),
            }
        }
    }
}

/// Result of the exhaustive root reduction.
#[derive(Debug, Clone)]
pub struct RootReduction {
    /// Original-id vertices forced into every (improving) cover.
    pub in_cover: Vec<u32>,
    /// Residual degree of every original vertex (0 = removed/isolated).
    pub residual_deg: Vec<u32>,
    /// Vertices that survive with non-zero degree (the set to induce on).
    pub kept: BitSet,
    /// Rule application counts.
    pub stats: RootReduceStats,
    /// Per-rule decision log for witness unwinding (forces exactly the
    /// `in_cover` vertices; additionally records crown exclusions).
    pub log: UnwindLog,
}

impl RootReduction {
    /// Number of surviving vertices.
    pub fn kept_count(&self) -> usize {
        self.kept.count()
    }
}

struct RootCtx<'g> {
    g: &'g Graph,
    deg: Vec<u32>,
    in_cover: Vec<u32>,
    queue: std::collections::VecDeque<u32>,
    queued: BitSet,
    stats: RootReduceStats,
    log: UnwindLog,
}

impl<'g> RootCtx<'g> {
    #[inline]
    fn present(&self, v: u32) -> bool {
        self.deg[v as usize] > 0
    }

    fn enqueue(&mut self, v: u32) {
        if self.queued.insert(v as usize) {
            self.queue.push_back(v);
        }
    }

    /// Remove `v` into the cover; neighbors lose a degree and re-enter
    /// the rule queue.
    fn cover(&mut self, v: u32) {
        debug_assert!(self.present(v));
        self.in_cover.push(v);
        self.deg[v as usize] = 0;
        for &w in self.g.neighbors(v) {
            if self.present(w) {
                self.deg[w as usize] -= 1;
                self.enqueue(w);
            }
        }
    }

    /// Remove `v` from the graph *without* covering it (crown independent
    /// vertices). All its edges must already be covered by its neighbors
    /// — covering the crown head usually zeroes `v`'s degree already, so
    /// this is defensive cleanup.
    fn discard(&mut self, v: u32) {
        if !self.present(v) {
            return;
        }
        self.deg[v as usize] = 0;
        for &w in self.g.neighbors(v) {
            if self.present(w) {
                self.deg[w as usize] -= 1;
                self.enqueue(w);
            }
        }
    }

    /// First present neighbor of `v`.
    fn first_neighbor(&self, v: u32) -> Option<u32> {
        self.g.neighbors(v).iter().copied().find(|&w| self.present(w))
    }

    /// Two present neighbors of a degree-2 vertex.
    fn two_neighbors(&self, v: u32) -> (u32, u32) {
        let mut it = self.g.neighbors(v).iter().copied().filter(|&w| self.present(w));
        let a = it.next().expect("degree-2 vertex has a neighbor");
        let b = it.next().expect("degree-2 vertex has two neighbors");
        (a, b)
    }

    /// Fixpoint of the cheap rules. `threshold(sol_len)` is the
    /// high-degree cutoff, or `u32::MAX` to disable.
    fn cheap_rules(&mut self, ub: u32, use_high_degree: bool) {
        while let Some(v) = self.queue.pop_front() {
            self.queued.clear(v as usize);
            if !self.present(v) {
                continue;
            }
            match self.deg[v as usize] {
                1 => {
                    let u = self.first_neighbor(v).expect("deg-1 neighbor");
                    self.cover(u);
                    self.log.push(UnwindStep::DegreeOne { covered: u });
                    self.stats.degree_one += 1;
                }
                2 => {
                    let (a, b) = self.two_neighbors(v);
                    if self.g.has_edge(a, b) {
                        self.cover(a);
                        self.cover(b);
                        self.log.push(UnwindStep::Triangle { a, b });
                        self.stats.degree_two_triangle += 1;
                    }
                }
                d => {
                    if use_high_degree {
                        let budget =
                            ub.saturating_sub(self.in_cover.len() as u32).saturating_sub(1);
                        if d > budget {
                            self.cover(v);
                            self.log.push(UnwindStep::HighDegree { covered: v });
                            self.stats.high_degree += 1;
                        }
                    }
                }
            }
        }
    }
}

/// Run the exhaustive root reduction (paper §IV-B).
///
/// `ub` is the current best cover size (e.g. from the greedy bound); the
/// high-degree rule preserves every cover *strictly smaller* than `ub`.
/// With `use_crown`, crown decompositions are extracted between fixpoints
/// of the cheap rules until none remains.
pub fn reduce_root(g: &Graph, ub: u32, use_crown: bool, use_high_degree: bool) -> RootReduction {
    let n = g.num_vertices();
    let mut ctx = RootCtx {
        g,
        deg: (0..n as u32).map(|v| g.degree(v)).collect(),
        in_cover: Vec::new(),
        queue: std::collections::VecDeque::new(),
        queued: BitSet::new(n),
        stats: RootReduceStats::default(),
        log: UnwindLog::default(),
    };
    for v in 0..n as u32 {
        ctx.enqueue(v);
    }
    loop {
        ctx.cheap_rules(ub, use_high_degree);

        // Special components (cliques / chordless cycles) solvable in
        // closed form at the root: cover size is forced, so commit the
        // canonical optimal cover directly.
        if solve_special_components(&mut ctx) {
            continue;
        }

        if !use_crown {
            break;
        }
        match crown::find_crown(g, &ctx.deg) {
            None => break,
            Some(c) => {
                ctx.stats.crown_rounds += 1;
                ctx.stats.crown_head += c.head.len();
                ctx.stats.crown_independent += c.independent.len();
                for &h in &c.head {
                    if ctx.present(h) {
                        ctx.cover(h);
                        ctx.log.push(UnwindStep::CrownHead { covered: h });
                    }
                }
                for &i in &c.independent {
                    ctx.discard(i);
                    // the exclusion is a *decision* (i is in no improving
                    // cover), recorded even though covering the heads
                    // already removed i's edges
                    ctx.log.push(UnwindStep::CrownExcluded { excluded: i });
                }
            }
        }
    }

    let mut kept = BitSet::new(n);
    for v in 0..n {
        if ctx.deg[v] > 0 {
            kept.set(v);
        }
    }
    debug_assert_eq!(
        ctx.log.covered_count(),
        ctx.in_cover.len(),
        "unwind log out of sync with the forced cover"
    );
    RootReduction {
        in_cover: ctx.in_cover,
        residual_deg: ctx.deg,
        kept,
        stats: ctx.stats,
        log: ctx.log,
    }
}

/// Detect and solve residual components that are cliques or chordless
/// cycles (paper §III-D applied at the root). Returns true if anything
/// was removed (so the cheap-rule fixpoint must be re-run).
fn solve_special_components(ctx: &mut RootCtx<'_>) -> bool {
    let n = ctx.g.num_vertices();
    let mut visited = BitSet::new(n);
    let mut changed = false;
    for s in 0..n as u32 {
        if !ctx.present(s) || visited.get(s as usize) {
            continue;
        }
        // BFS to collect the component.
        let mut comp = vec![s];
        visited.set(s as usize);
        let mut head = 0;
        while head < comp.len() {
            let u = comp[head];
            head += 1;
            for &w in ctx.g.neighbors(u) {
                if ctx.present(w) && visited.insert(w as usize) {
                    comp.push(w);
                }
            }
        }
        let size = comp.len() as u32;
        if let Some(sp) = classify(size, comp.iter().map(|&v| ctx.deg[v as usize])) {
            // canonical minimum cover shared with the sequential and
            // parallel extractors (SpecialComponent::cover_into)
            let g = ctx.g;
            let deg = &ctx.deg;
            let mut cover = Vec::with_capacity(sp.mvc_size() as usize);
            sp.cover_into(g, &comp, |v| deg[v as usize] > 0, &mut cover);
            let mut covered = Vec::with_capacity(cover.len());
            for &v in &cover {
                if ctx.present(v) {
                    ctx.cover(v);
                    covered.push(v);
                }
            }
            ctx.stats.special_cover += covered.len();
            ctx.log.push(UnwindStep::Special { covered });
            changed = true;
        }
    }
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    /// Reduction soundness: forced cover + optimum of the residual ==
    /// optimum of the original (when an optimum < ub exists).
    fn check_sound(g: &Graph, use_crown: bool) {
        let opt = crate::solver::oracle::mvc_size(g);
        let ub = g.num_vertices() as u32; // trivial, never prunes optimum
        let red = reduce_root(g, ub, use_crown, true);
        let ind = crate::graph::InducedSubgraph::new(g, &red.kept);
        let residual_opt = crate::solver::oracle::mvc_size(&ind.graph);
        assert_eq!(
            red.in_cover.len() as u32 + residual_opt,
            opt,
            "root reduction changed the optimum (crown={use_crown})"
        );
    }

    #[test]
    fn path_fully_reduced() {
        // P5 reduces completely via degree-one cascades.
        let g = generators::path(5);
        let red = reduce_root(&g, 5, false, true);
        assert_eq!(red.kept_count(), 0);
        assert_eq!(red.in_cover.len(), 2);
        assert!(g.is_vertex_cover(&red.in_cover));
    }

    #[test]
    fn triangle_rule_fires() {
        // A triangle with a pendant: pendant forces its neighbor, rest
        // collapses; final cover must be optimal (=2).
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        let red = reduce_root(&g, 4, false, true);
        assert_eq!(red.kept_count(), 0);
        assert_eq!(red.in_cover.len() as u32, crate::solver::oracle::mvc_size(&g));
    }

    #[test]
    fn clique_component_solved() {
        let g = generators::clique(6);
        let red = reduce_root(&g, 6, false, false);
        assert_eq!(red.kept_count(), 0);
        assert_eq!(red.in_cover.len(), 5);
        assert!(g.is_vertex_cover(&red.in_cover));
    }

    #[test]
    fn cycle_component_solved() {
        for n in [4usize, 5, 6, 7, 9] {
            let g = generators::cycle(n);
            let red = reduce_root(&g, n as u32, false, false);
            assert_eq!(red.kept_count(), 0, "C{n}");
            assert_eq!(red.in_cover.len(), n.div_ceil(2), "C{n}");
            assert!(g.is_vertex_cover(&red.in_cover), "C{n}");
        }
    }

    #[test]
    fn crown_reduces_star_forest() {
        let g = Graph::disjoint_union(&[generators::star(8), generators::star(5)]);
        let red = reduce_root(&g, 13, true, false);
        assert_eq!(red.kept_count(), 0);
        assert_eq!(red.in_cover.len(), 2);
    }

    #[test]
    fn sound_on_random_graphs() {
        for seed in 0..12 {
            let g = generators::erdos_renyi(14, 0.18, seed);
            check_sound(&g, false);
            check_sound(&g, true);
        }
    }

    #[test]
    fn sound_on_structured_graphs() {
        check_sound(&generators::grid(3, 4, 0.0, 0), true);
        check_sound(&generators::c_fat(12, 2, 1), true);
        check_sound(&generators::union_of_random(3, 3, 5, 0.3, 7), true);
    }

    #[test]
    fn high_degree_rule_preserves_improving_covers() {
        // hub-heavy graph; ub from greedy; optimum must be reachable
        for seed in 0..8 {
            let g = generators::barabasi_albert(16, 2, seed);
            let opt = crate::solver::oracle::mvc_size(&g);
            let ub = crate::solver::greedy::greedy_cover(&g).len() as u32;
            let red = reduce_root(&g, ub, true, true);
            let ind = crate::graph::InducedSubgraph::new(&g, &red.kept);
            let residual = crate::solver::oracle::mvc_size(&ind.graph);
            let total = red.in_cover.len() as u32 + residual;
            // the reduced answer can only be wrong if it claims better
            // than optimal; and if opt < ub it must equal opt
            assert!(total >= opt, "seed {seed}");
            if opt < ub {
                assert_eq!(total, opt, "seed {seed}");
            }
        }
    }

    #[test]
    fn stats_populated() {
        let g = generators::path(9);
        let red = reduce_root(&g, 9, false, true);
        assert!(red.stats.degree_one > 0);
    }

    /// Round-trip: reduce, solve the residual exactly, unwind — the
    /// lifted cover must be valid on the full graph and have exactly
    /// `|residual cover| + covered_count()` vertices (== the optimum
    /// whenever an optimum strictly below `ub` exists).
    fn check_unwind_roundtrip(g: &Graph, use_crown: bool, use_high_degree: bool, ub: u32) {
        let red = reduce_root(g, ub, use_crown, use_high_degree);
        assert_eq!(red.log.covered_count(), red.in_cover.len(), "log/in_cover drift");
        let ind = crate::graph::InducedSubgraph::new(g, &red.kept);
        let sub_cover = if ind.graph.num_vertices() == 0 {
            Vec::new()
        } else {
            crate::solver::oracle::mvc_cover(&ind.graph)
        };
        let mut cover = ind.translate_cover(&sub_cover);
        red.log.unwind(&mut cover);
        assert!(g.is_vertex_cover(&cover), "unwound cover invalid");
        assert_eq!(cover.len(), sub_cover.len() + red.in_cover.len(), "unwound size drift");
        let opt = crate::solver::oracle::mvc_size(g);
        if opt < ub {
            assert_eq!(cover.len() as u32, opt, "unwound cover not optimal");
        }
        // crown-excluded vertices must never re-enter the cover
        for step in red.log.steps() {
            if let UnwindStep::CrownExcluded { excluded } = step {
                assert!(!cover.contains(excluded), "excluded vertex {excluded} in cover");
            }
        }
    }

    #[test]
    fn unwind_degree_one_rule() {
        // paths reduce entirely through degree-one cascades
        for n in [3usize, 5, 8, 11] {
            check_unwind_roundtrip(&generators::path(n), false, false, n as u32 + 1);
        }
    }

    #[test]
    fn unwind_triangle_rule() {
        // triangle with a pendant: degree-one forces the pendant's
        // neighbor, the triangle rule takes the rest
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        check_unwind_roundtrip(&g, false, false, 5);
        let red = reduce_root(&g, 5, false, false);
        assert!(red.stats.degree_one + red.stats.degree_two_triangle > 0);
    }

    #[test]
    fn unwind_high_degree_rule() {
        // hub-heavy graphs with a tight greedy ub make the rule fire
        for seed in 0..6 {
            let g = generators::barabasi_albert(16, 2, seed);
            let ub = crate::solver::greedy::greedy_bound(&g);
            let red = reduce_root(&g, ub, false, true);
            // the lift must stay sound whether or not the rule fired
            let ind = crate::graph::InducedSubgraph::new(&g, &red.kept);
            let sub = if ind.graph.num_vertices() == 0 {
                Vec::new()
            } else {
                crate::solver::oracle::mvc_cover(&ind.graph)
            };
            let mut cover = ind.translate_cover(&sub);
            red.log.unwind(&mut cover);
            assert!(g.is_vertex_cover(&cover), "seed {seed}");
            let opt = crate::solver::oracle::mvc_size(&g);
            assert!(cover.len() as u32 >= opt, "seed {seed}");
            if opt < ub {
                assert_eq!(cover.len() as u32, opt, "seed {seed}");
            }
        }
    }

    #[test]
    fn unwind_crown_rule() {
        // K2,5: right vertices have degree 2 with non-adjacent
        // neighbors, so no cheap rule fires and only the crown
        // decomposition (greedy + Hopcroft–Karp matchings) can reduce
        // it — heads {0,1} covered, the independent side excluded.
        let g = Graph::from_edges(
            7,
            &[
                (0, 2),
                (0, 3),
                (0, 4),
                (0, 5),
                (0, 6),
                (1, 2),
                (1, 3),
                (1, 4),
                (1, 5),
                (1, 6),
            ],
        );
        check_unwind_roundtrip(&g, true, false, g.num_vertices() as u32);
        let red = reduce_root(&g, g.num_vertices() as u32, true, false);
        assert!(red.stats.crown_rounds > 0, "crown must fire on K2,5");
        assert!(red.log.steps().iter().any(|s| matches!(s, UnwindStep::CrownHead { .. })));
        assert!(red.log.steps().iter().any(|s| matches!(s, UnwindStep::CrownExcluded { .. })));
        // a crown-reduced K2,5 must land on the optimal cover {0, 1}
        let mut cover = Vec::new();
        red.log.unwind(&mut cover);
        cover.sort_unstable();
        assert_eq!(cover, vec![0, 1]);
    }

    #[test]
    fn unwind_special_components() {
        // cliques and chordless cycles solved in closed form at the root
        let g = Graph::disjoint_union(&[
            generators::clique(5),
            generators::cycle(7),
            generators::cycle(6),
        ]);
        check_unwind_roundtrip(&g, false, false, g.num_vertices() as u32);
        let red = reduce_root(&g, g.num_vertices() as u32, false, false);
        assert!(red.log.steps().iter().any(|s| matches!(s, UnwindStep::Special { .. })));
    }

    #[test]
    fn unwind_mixed_rules_random_graphs() {
        for seed in 0..10 {
            let g = generators::erdos_renyi(15, 0.18, seed);
            check_unwind_roundtrip(&g, true, false, g.num_vertices() as u32 + 1);
        }
        for seed in 0..6 {
            let g = generators::union_of_random(3, 3, 6, 0.3, seed);
            check_unwind_roundtrip(&g, true, false, g.num_vertices() as u32 + 1);
        }
    }
}
