//! Component-targeting reduction rules (paper §III-D): cliques and
//! chordless cycles are solved in closed form the moment component
//! detection identifies them, instead of being branched on.
//!
//! These helpers are representation-agnostic (they take a degree lookup)
//! so both the root reducer (u32 degrees over the original graph) and the
//! generic engine (u8/u16/u32 degree arrays over the induced subgraph)
//! share them. [`SpecialComponent::cover_into`] produces the canonical
//! witness cover of a classified component, shared by the root reducer,
//! the sequential extractor, and the parallel engine's choice logs.

use crate::graph::Graph;

/// Closed-form classification of a connected component.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpecialComponent {
    /// Complete graph on `size` vertices → MVC = size − 1.
    Clique {
        /// Component vertex count.
        size: u32,
    },
    /// Chordless cycle on `size` vertices → MVC = ⌈size/2⌉.
    ChordlessCycle {
        /// Component vertex count.
        size: u32,
    },
}

impl SpecialComponent {
    /// Minimum vertex cover size of the special component.
    pub fn mvc_size(self) -> u32 {
        match self {
            SpecialComponent::Clique { size } => size - 1,
            SpecialComponent::ChordlessCycle { size } => size.div_ceil(2),
        }
    }

    /// Append the canonical minimum cover of this component to `out`:
    /// all-but-one vertex of a clique; alternating vertices of a cycle
    /// (plus one extra when odd). `comp` is the component's vertex list
    /// and `present(v)` the residual membership test (`deg > 0`), so the
    /// walk works over any degree representation. Exactly
    /// [`SpecialComponent::mvc_size`] vertices are appended.
    pub fn cover_into(
        self,
        g: &Graph,
        comp: &[u32],
        present: impl Fn(u32) -> bool,
        out: &mut Vec<u32>,
    ) {
        match self {
            SpecialComponent::Clique { .. } => out.extend(comp.iter().skip(1).copied()),
            SpecialComponent::ChordlessCycle { .. } => {
                // walk the cycle, take every second vertex (+1 when odd)
                let start = comp[0];
                let mut order = vec![start];
                let mut prev = start;
                let mut cur = g
                    .neighbors(start)
                    .iter()
                    .copied()
                    .find(|&w| present(w))
                    .expect("cycle vertex has a present neighbor");
                while cur != start {
                    order.push(cur);
                    let next = g
                        .neighbors(cur)
                        .iter()
                        .copied()
                        .find(|&w| present(w) && w != prev)
                        .expect("cycle vertex has two present neighbors");
                    prev = cur;
                    cur = next;
                }
                debug_assert_eq!(order.len(), comp.len(), "cycle walk must visit all vertices");
                out.extend(order.iter().skip(1).step_by(2).copied());
                if order.len() % 2 == 1 {
                    out.push(order[order.len() - 1]);
                }
            }
        }
    }
}

/// Classify a *connected* component given its vertex list and a residual
/// degree lookup.
///
/// * all degrees == `size − 1` → clique (every vertex adjacent to every
///   other, since degrees are counted within the residual graph);
/// * all degrees == 2 → chordless cycle (a connected 2-regular graph is a
///   cycle; a chord would raise two degrees to 3).
///
/// Components of size ≤ 2 are handled by the degree rules, but classifying
/// them here is still correct: an edge is K2 (cover 1).
pub fn classify(size: u32, mut degrees: impl Iterator<Item = u32>) -> Option<SpecialComponent> {
    if size < 2 {
        return None;
    }
    let first = degrees.next()?;
    let uniform = degrees.all(|d| d == first);
    if !uniform {
        return None;
    }
    if first == size - 1 {
        Some(SpecialComponent::Clique { size })
    } else if first == 2 && size >= 3 {
        Some(SpecialComponent::ChordlessCycle { size })
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clique_classified() {
        let c = classify(5, [4u32, 4, 4, 4, 4].into_iter()).unwrap();
        assert_eq!(c, SpecialComponent::Clique { size: 5 });
        assert_eq!(c.mvc_size(), 4);
    }

    #[test]
    fn cycle_classified() {
        let c = classify(6, [2u32; 6].into_iter()).unwrap();
        assert_eq!(c, SpecialComponent::ChordlessCycle { size: 6 });
        assert_eq!(c.mvc_size(), 3);
        let odd = classify(7, [2u32; 7].into_iter()).unwrap();
        assert_eq!(odd.mvc_size(), 4); // ceil(7/2)
    }

    #[test]
    fn triangle_is_both_but_clique_wins() {
        // K3: all degrees 2 and size-1 == 2; clique branch must win
        // (same answer either way: 2 = ceil(3/2) = 3-1).
        let c = classify(3, [2u32, 2, 2].into_iter()).unwrap();
        assert_eq!(c, SpecialComponent::Clique { size: 3 });
        assert_eq!(c.mvc_size(), 2);
    }

    #[test]
    fn edge_is_k2() {
        let c = classify(2, [1u32, 1].into_iter()).unwrap();
        assert_eq!(c, SpecialComponent::Clique { size: 2 });
        assert_eq!(c.mvc_size(), 1);
    }

    #[test]
    fn non_uniform_rejected() {
        assert!(classify(4, [1u32, 2, 2, 1].into_iter()).is_none());
    }

    #[test]
    fn cover_into_produces_valid_minimum_covers() {
        use crate::graph::generators;
        for n in [3usize, 4, 5, 6, 7, 9] {
            let g = generators::cycle(n);
            let comp: Vec<u32> = (0..n as u32).collect();
            let sp = classify(n as u32, comp.iter().map(|&v| g.degree(v))).unwrap();
            let mut cover = Vec::new();
            sp.cover_into(&g, &comp, |_| true, &mut cover);
            assert_eq!(cover.len() as u32, sp.mvc_size(), "C{n}");
            assert!(g.is_vertex_cover(&cover), "C{n}");
        }
        for n in [2usize, 4, 6] {
            let g = generators::clique(n);
            let comp: Vec<u32> = (0..n as u32).collect();
            let sp = classify(n as u32, comp.iter().map(|&v| g.degree(v))).unwrap();
            let mut cover = Vec::new();
            sp.cover_into(&g, &comp, |_| true, &mut cover);
            assert_eq!(cover.len() as u32, sp.mvc_size(), "K{n}");
            assert!(g.is_vertex_cover(&cover), "K{n}");
        }
    }

    #[test]
    fn path_rejected() {
        // P3 has degrees 1,2,1 — not special.
        assert!(classify(3, [1u32, 2, 1].into_iter()).is_none());
        // 3-regular on 6 vertices (prism) — not special.
        assert!(classify(6, [3u32; 6].into_iter()).is_none());
    }
}
