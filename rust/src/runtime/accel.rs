//! XLA-accelerated graph analytics on the solve pipeline.
//!
//! The paper's component finding is a block-collaborative pull-based BFS
//! on the GPU (§III-B). That data-parallel primitive is what we author as
//! Pallas kernels (L1), wrap into fixpoint programs in JAX (L2), and AOT
//! to HLO. This module executes those artifacts via PJRT from the Rust
//! request path:
//!
//! * root-level component split of the reduced/induced graph before the
//!   search launches (graphs ≤ 1024 vertices after padding);
//! * triangle census for the preprocessing report and the degree-2
//!   triangle rule statistics.
//!
//! Per-*node* component detection inside the engine stays native: a PJRT
//! dispatch per search-tree node would measure IPC overhead, not the
//! algorithm (see DESIGN.md §Hardware-Adaptation). Every accelerated
//! routine has a native fallback and is cross-checked against it in
//! integration tests.

use crate::util::error::{Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;

use super::artifacts::{ArtifactKind, ArtifactSet};
use super::client::{Executable, Runtime, TensorF32};
use crate::graph::Graph;

/// PJRT-backed analytics with lazy per-(kind, size-class) compilation.
pub struct Accelerator {
    rt: Runtime,
    artifacts: ArtifactSet,
    cache: Mutex<HashMap<(ArtifactKind, usize), std::sync::Arc<Executable>>>,
}

impl std::fmt::Debug for Accelerator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Accelerator").field("artifacts", &self.artifacts.dir()).finish()
    }
}

impl Accelerator {
    /// Create an accelerator over the default artifact location.
    pub fn new() -> Result<Accelerator> {
        Self::with_artifacts(ArtifactSet::default_location())
    }

    /// Create an accelerator over a specific artifact set.
    pub fn with_artifacts(artifacts: ArtifactSet) -> Result<Accelerator> {
        Ok(Accelerator { rt: Runtime::cpu()?, artifacts, cache: Mutex::new(HashMap::new()) })
    }

    /// Largest graph the compiled artifacts can handle.
    pub fn max_vertices(&self) -> usize {
        super::artifacts::SIZE_CLASSES[super::artifacts::SIZE_CLASSES.len() - 1]
    }

    fn executable(
        &self,
        kind: ArtifactKind,
        n: usize,
    ) -> Result<(std::sync::Arc<Executable>, usize)> {
        let (path, class) = self.artifacts.path_for(kind, n)?;
        let mut cache = self.cache.lock().unwrap();
        if let Some(e) = cache.get(&(kind, class)) {
            return Ok((e.clone(), class));
        }
        let exe = std::sync::Arc::new(self.rt.load_hlo_text(&path)?);
        cache.insert((kind, class), exe.clone());
        Ok((exe, class))
    }

    /// Dense 0/1 adjacency padded to `class × class` (padding vertices
    /// are isolated so they never affect the fixpoints).
    fn dense_adjacency(g: &Graph, class: usize) -> Vec<f32> {
        let mut a = vec![0f32; class * class];
        for (u, v) in g.edges() {
            a[u as usize * class + v as usize] = 1.0;
            a[v as usize * class + u as usize] = 1.0;
        }
        a
    }

    /// Connected-component labels via the AOT min-label-propagation
    /// program. Labels are the smallest vertex id in each component
    /// (canonical), matching `graph::components::labels` up to renaming.
    pub fn connected_components(&self, g: &Graph) -> Result<Vec<u32>> {
        let n = g.num_vertices();
        let (exe, class) = self.executable(ArtifactKind::ConnectedComponents, n)?;
        let a = Self::dense_adjacency(g, class);
        let dims = [class as i64, class as i64];
        let out = exe
            .run_f32(&[TensorF32 { data: &a, dims: &dims }])
            .context("components artifact")?;
        let labels = &out[0];
        Ok((0..n).map(|v| labels[v] as u32).collect())
    }

    /// BFS reachability mask from `source` via the AOT frontier-expansion
    /// program.
    pub fn bfs_reach(&self, g: &Graph, source: u32) -> Result<Vec<bool>> {
        let n = g.num_vertices();
        let (exe, class) = self.executable(ArtifactKind::BfsReach, n)?;
        let a = Self::dense_adjacency(g, class);
        let mut seed = vec![0f32; class];
        seed[source as usize] = 1.0;
        let adims = [class as i64, class as i64];
        let sdims = [class as i64];
        let out = exe
            .run_f32(&[
                TensorF32 { data: &a, dims: &adims },
                TensorF32 { data: &seed, dims: &sdims },
            ])
            .context("bfs artifact")?;
        Ok(out[0][..n].iter().map(|&x| x > 0.5).collect())
    }

    /// Per-vertex triangle counts via the AOT (A·A)⊙A row-sum program.
    pub fn triangle_census(&self, g: &Graph) -> Result<Vec<u32>> {
        let n = g.num_vertices();
        let (exe, class) = self.executable(ArtifactKind::TriangleCensus, n)?;
        let a = Self::dense_adjacency(g, class);
        let dims = [class as i64, class as i64];
        let out = exe
            .run_f32(&[TensorF32 { data: &a, dims: &dims }])
            .context("triangle artifact")?;
        // program returns row sums of (A@A)⊙A = 2 × triangles per vertex
        Ok(out[0][..n].iter().map(|&x| (x / 2.0).round() as u32).collect())
    }

    /// Component vertex sets via the accelerated labels, with native
    /// fallback for graphs beyond the largest size class.
    pub fn component_split(&self, g: &Graph) -> Result<Vec<Vec<u32>>> {
        if g.num_vertices() > self.max_vertices() {
            return Ok(crate::graph::components::vertex_sets(g));
        }
        let labels = self.connected_components(g)?;
        let mut by_label: HashMap<u32, Vec<u32>> = HashMap::new();
        for (v, &l) in labels.iter().enumerate() {
            by_label.entry(l).or_default().push(v as u32);
        }
        let mut sets: Vec<Vec<u32>> = by_label.into_values().collect();
        sets.sort_by_key(|s| s[0]);
        Ok(sets)
    }
}
