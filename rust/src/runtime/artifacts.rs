//! Artifact registry: discovers `artifacts/*.hlo.txt` produced by
//! `make artifacts` (`python/compile/aot.py`) and picks the right size
//! class for a given graph.
//!
//! Each program is AOT-compiled at fixed padded sizes (XLA requires
//! static shapes); the runtime pads inputs up to the nearest class.

use crate::bail;
use crate::util::error::Result;
use std::path::{Path, PathBuf};

/// The AOT-compiled programs (must match `python/compile/aot.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// Connected-component labels via min-label propagation fixpoint.
    ConnectedComponents,
    /// BFS reachability mask from a seed vector.
    BfsReach,
    /// Per-vertex triangle counts ((A·A)⊙A row sums).
    TriangleCensus,
}

impl ArtifactKind {
    /// File-name stem used by the AOT script.
    pub fn stem(self) -> &'static str {
        match self {
            ArtifactKind::ConnectedComponents => "components",
            ArtifactKind::BfsReach => "bfs_reach",
            ArtifactKind::TriangleCensus => "triangle_census",
        }
    }

    /// All kinds.
    pub const ALL: [ArtifactKind; 3] = [
        ArtifactKind::ConnectedComponents,
        ArtifactKind::BfsReach,
        ArtifactKind::TriangleCensus,
    ];
}

/// Size classes compiled by the AOT script (must stay in sync).
pub const SIZE_CLASSES: [usize; 4] = [128, 256, 512, 1024];

/// Locates artifacts on disk.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    dir: PathBuf,
}

impl ArtifactSet {
    /// Use artifacts from `dir`.
    pub fn new(dir: impl Into<PathBuf>) -> ArtifactSet {
        ArtifactSet { dir: dir.into() }
    }

    /// Default location: `$CAVC_ARTIFACTS` or `./artifacts`.
    pub fn default_location() -> ArtifactSet {
        let dir = std::env::var_os("CAVC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"));
        ArtifactSet::new(dir)
    }

    /// Directory root.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Smallest compiled size class that fits `n` vertices.
    pub fn size_class(n: usize) -> Option<usize> {
        SIZE_CLASSES.iter().copied().find(|&c| c >= n)
    }

    /// Path of an artifact for `kind` at size class `class`.
    pub fn path(&self, kind: ArtifactKind, class: usize) -> PathBuf {
        self.dir.join(format!("{}_{}.hlo.txt", kind.stem(), class))
    }

    /// Path of the artifact that fits a graph of `n` vertices.
    pub fn path_for(&self, kind: ArtifactKind, n: usize) -> Result<(PathBuf, usize)> {
        let Some(class) = Self::size_class(n) else {
            bail!("no size class fits n={n} (max {})", SIZE_CLASSES[SIZE_CLASSES.len() - 1]);
        };
        let p = self.path(kind, class);
        if !p.exists() {
            bail!("artifact missing: {} (run `make artifacts`)", p.display());
        }
        Ok((p, class))
    }

    /// True if every artifact exists (all kinds × all classes).
    pub fn complete(&self) -> bool {
        ArtifactKind::ALL
            .iter()
            .all(|k| SIZE_CLASSES.iter().all(|&c| self.path(*k, c).exists()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_class_selection() {
        assert_eq!(ArtifactSet::size_class(1), Some(128));
        assert_eq!(ArtifactSet::size_class(128), Some(128));
        assert_eq!(ArtifactSet::size_class(129), Some(256));
        assert_eq!(ArtifactSet::size_class(1024), Some(1024));
        assert_eq!(ArtifactSet::size_class(1025), None);
    }

    #[test]
    fn path_shape() {
        let a = ArtifactSet::new("/tmp/x");
        assert_eq!(
            a.path(ArtifactKind::ConnectedComponents, 256),
            PathBuf::from("/tmp/x/components_256.hlo.txt")
        );
        assert_eq!(
            a.path(ArtifactKind::TriangleCensus, 1024),
            PathBuf::from("/tmp/x/triangle_census_1024.hlo.txt")
        );
    }

    #[test]
    fn missing_artifact_errors() {
        let a = ArtifactSet::new("/nonexistent");
        assert!(a.path_for(ArtifactKind::BfsReach, 100).is_err());
        assert!(!a.complete());
    }
}
