//! PJRT client wrapper: load HLO-text artifacts and execute them.
//!
//! Interchange is HLO **text**, not serialized `HloModuleProto`: jax ≥0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids (see
//! `/opt/xla-example/README.md` and `python/compile/aot.py`).
//!
//! The PJRT path requires the vendored `xla` crate and is compiled only
//! with the `xla` cargo feature. Without it this module is a stub whose
//! constructors return an error, so every caller (CLI `components` verb,
//! `end_to_end` example, accel integration tests) takes its native CPU
//! fallback — the offline build stays dependency-free.

/// A dense f32 input tensor.
#[derive(Debug, Clone)]
pub struct TensorF32<'a> {
    /// Row-major data.
    pub data: &'a [f32],
    /// Dimensions.
    pub dims: &'a [i64],
}

#[cfg(feature = "xla")]
mod imp {
    use super::TensorF32;
    use crate::util::error::{Context, Error, Result};
    use std::path::Path;

    /// A PJRT CPU client plus compiled-executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<Runtime> {
            let client =
                xla::PjRtClient::cpu().map_err(Error::msg).context("creating PJRT CPU client")?;
            Ok(Runtime { client })
        }

        /// Platform name (e.g. "cpu").
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact and compile it for this client.
        pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path must be utf-8")?,
            )
            .map_err(Error::msg)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(Error::msg)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(Executable { exe })
        }
    }

    /// A compiled HLO program. All our artifacts are lowered with
    /// `return_tuple=True`, so outputs are unpacked from a tuple literal.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
    }

    impl Executable {
        /// Execute with f32 inputs; returns each tuple output flattened to
        /// `Vec<f32>` (converting from whatever dtype the program produced).
        pub fn run_f32(&self, inputs: &[TensorF32<'_>]) -> Result<Vec<Vec<f32>>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|t| {
                    let expected: i64 = t.dims.iter().product();
                    crate::ensure!(
                        expected as usize == t.data.len(),
                        "dims {:?} do not match data length {}",
                        t.dims,
                        t.data.len()
                    );
                    xla::Literal::vec1(t.data).reshape(t.dims).map_err(Error::msg)
                })
                .collect::<Result<_>>()?;
            let result = self.exe.execute::<xla::Literal>(&literals).map_err(Error::msg)?[0][0]
                .to_literal_sync()
                .map_err(Error::msg)
                .context("fetching result literal")?;
            let outputs =
                result.to_tuple().map_err(Error::msg).context("unpacking output tuple")?;
            outputs
                .into_iter()
                .map(|lit| {
                    let lit = lit
                        .convert(xla::ElementType::F32.primitive_type())
                        .map_err(Error::msg)
                        .context("converting output to f32")?;
                    lit.to_vec::<f32>().map_err(Error::msg)
                })
                .collect()
        }
    }
}

#[cfg(not(feature = "xla"))]
mod imp {
    use super::TensorF32;
    use crate::bail;
    use crate::util::error::Result;
    use std::path::Path;

    const UNAVAILABLE: &str =
        "PJRT unavailable: cavc was built without the `xla` feature (native CPU paths remain)";

    /// Stub runtime: construction fails so callers use CPU fallbacks.
    pub struct Runtime {
        never: std::convert::Infallible,
    }

    impl Runtime {
        /// Always errors in stub builds.
        pub fn cpu() -> Result<Runtime> {
            bail!("{UNAVAILABLE}")
        }

        /// Unreachable in stub builds (no value can be constructed).
        pub fn platform(&self) -> String {
            match self.never {}
        }

        /// Unreachable in stub builds.
        pub fn load_hlo_text(&self, _path: &Path) -> Result<Executable> {
            match self.never {}
        }
    }

    /// Stub executable (never constructed).
    pub struct Executable {
        never: std::convert::Infallible,
    }

    impl Executable {
        /// Unreachable in stub builds.
        pub fn run_f32(&self, _inputs: &[TensorF32<'_>]) -> Result<Vec<Vec<f32>>> {
            match self.never {}
        }
    }
}

pub use imp::{Executable, Runtime};

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime").finish()
    }
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable").finish()
    }
}

/// True if this build can execute PJRT artifacts at all.
pub fn pjrt_compiled_in() -> bool {
    cfg!(feature = "xla")
}

#[cfg(test)]
mod tests {
    #[test]
    fn stub_reports_unavailable() {
        if !super::pjrt_compiled_in() {
            let err = super::Runtime::cpu().err().expect("stub must fail");
            assert!(err.to_string().contains("xla"), "{err}");
        }
    }
}
