//! PJRT client wrapper: load HLO-text artifacts and execute them.
//!
//! Interchange is HLO **text**, not serialized `HloModuleProto`: jax ≥0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids (see
//! `/opt/xla-example/README.md` and `python/compile/aot.py`).

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT CPU client plus compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime").field("platform", &self.platform()).finish()
    }
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it for this client.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path must be utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }
}

/// A compiled HLO program. All our artifacts are lowered with
/// `return_tuple=True`, so outputs are unpacked from a tuple literal.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Executable").finish()
    }
}

/// A dense f32 input tensor.
#[derive(Debug, Clone)]
pub struct TensorF32<'a> {
    /// Row-major data.
    pub data: &'a [f32],
    /// Dimensions.
    pub dims: &'a [i64],
}

impl Executable {
    /// Execute with f32 inputs; returns each tuple output flattened to
    /// `Vec<f32>` (converting from whatever dtype the program produced).
    pub fn run_f32(&self, inputs: &[TensorF32<'_>]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let expected: i64 = t.dims.iter().product();
                anyhow::ensure!(
                    expected as usize == t.data.len(),
                    "dims {:?} do not match data length {}",
                    t.dims,
                    t.data.len()
                );
                Ok(xla::Literal::vec1(t.data).reshape(t.dims)?)
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let outputs = result.to_tuple().context("unpacking output tuple")?;
        outputs
            .into_iter()
            .map(|lit| {
                let lit = lit
                    .convert(xla::ElementType::F32.primitive_type())
                    .context("converting output to f32")?;
                Ok(lit.to_vec::<f32>()?)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // Runtime tests that need real artifacts live in `rust/tests/`
    // (integration) and run only when `artifacts/` has been built.
}
