//! PJRT runtime: load AOT-compiled HLO artifacts (built once by
//! `python/compile/aot.py`) and execute them from the Rust request path.
//! Python is never on the request path.

pub mod accel;
pub mod artifacts;
pub mod client;

pub use accel::Accelerator;
pub use artifacts::{ArtifactKind, ArtifactSet};
