//! Online self-tuning controller for the engine's memory/scheduling
//! knobs (the ROADMAP "self-tuning engine controller" item).
//!
//! Every major engine lever — node representation, `max_pin_depth`, the
//! §IV-B induction gate, admission/queue capacity — ships as a static
//! knob, yet the engine already measures exactly what is needed to set
//! them: bytes/node, undo-vs-materialize traffic, steal rates, induced
//! subproblem counts, live ledger bytes. This module closes the loop
//! for the resident service:
//!
//! * [`TuneShared`] is the controller's blackboard: lock-free atomic
//!   decision cells (a per-width-bucket owned/delta mask, the tuned pin
//!   depth, per-bucket induction thresholds, replanned pool shape) plus
//!   the cumulative observation counters workers drain into it.
//! * [`JobTune`] is the per-job consultation handle the engine reads on
//!   the hot path (`JobCtl::repr_for` / `max_pin_depth` /
//!   `induce_gate`). Explicitly-set static knobs pin the corresponding
//!   decision off per job (ablation overrides stay exact), and the
//!   memory watchdog's soft-pressure `forced_delta` override outranks
//!   every controller decision — the degradation ladder wins.
//! * [`Tuner`] is the decision procedure, run periodically by the
//!   service's `cavc-svc-tune` thread: EWMA bytes/node per width
//!   bucket decides owned-vs-delta, the observed steal rate
//!   lengthens/shortens pin chains, induced-subproblem amortization
//!   gates tree induction per bucket, and live ledger bytes re-plan
//!   admission capacity and the memo budget through the occupancy
//!   model. It is deliberately free of threads and clocks so unit
//!   tests can drive epochs synthetically.
//!
//! Decisions never change *what* is computed — only how node state is
//! represented and where induction pays — so answers and witnesses are
//! bit-identical with the controller on or off
//! (`tests/autotune_invariance.rs`).

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

use super::engine::{EngineStats, NodeRepr, DEFAULT_MAX_PIN_DEPTH};

/// Width buckets for per-view-size decisions: bucket `b` covers view
/// widths in `[2^(3b-2), 2^(3b+1))` (roughly one decision per 8× width
/// change), clamped to [`TUNE_BUCKETS`] classes.
pub(crate) const TUNE_BUCKETS: usize = 8;

/// Buckets strictly below this never take the delta representation:
/// a pinned-chain link plus eventual materialization replay does not
/// amortize against copying a few dozen bytes.
const MIN_DELTA_BUCKET: usize = 2;

/// Bootstrap prior before any observations: buckets at or above this
/// (view width ≥ ~256) start on the delta representation — wide views
/// are exactly where O(delta) resident bytes beat O(view) copies.
const PRIOR_DELTA_BUCKET: usize = 3;

/// Pin-depth controller bounds and step.
const MIN_PIN_DEPTH: u32 = 4;
const MAX_PIN_DEPTH_CAP: u32 = 96;
const PIN_STEP: u32 = 4;

/// Steal-rate thresholds (parts per million of acquired nodes): below
/// `LOW` the undo fast path dominates and chains may lengthen; above
/// `HIGH` thieves pay materialization replay and chains shorten.
const STEAL_LOW_PPM: u64 = 20_000;
const STEAL_HIGH_PPM: u64 = 100_000;

/// Induction-gate controller: a bucket needs this many induced
/// subproblems before its amortization estimate is trusted, and the
/// tuned threshold moves by powers of two within [MIN, 1000] milli.
const INDUCE_MIN_SAMPLES: u64 = 16;
const INDUCE_MIN_ALPHA_MILLI: u32 = 100;
const INDUCE_LOW_AMORT: u64 = 4;
const INDUCE_HIGH_AMORT: u64 = 32;

/// Ticks with traffic and no knob movement before the controller
/// declares convergence.
const STABLE_TICKS: u32 = 3;

/// Width bucket of a view of `width` vertices.
#[inline]
pub(crate) fn bucket_of(width: usize) -> usize {
    let bits = usize::BITS - width.leading_zeros();
    ((bits as usize) / 3).min(TUNE_BUCKETS - 1)
}

fn zeros() -> [AtomicU64; TUNE_BUCKETS] {
    std::array::from_fn(|_| AtomicU64::new(0))
}

/// The controller blackboard shared between the tuner thread, the
/// admission layer, and every job's [`JobTune`] handle. All cells are
/// relaxed atomics: decisions are hints consumed on the engine hot
/// path, and observation counters are drained by workers at stats-flush
/// time — neither side ever blocks on the other.
pub struct TuneShared {
    // ---- decisions (written by the tuner, read by the engine) ----
    /// Bit `b` set ⇒ owned nodes opening a descent in width bucket `b`
    /// branch with delta right children.
    delta_mask: AtomicU32,
    /// Tuned delta-chain length bound.
    pin_depth: AtomicU32,
    /// Tuned per-bucket induction gate, in milli (1000 = induce every
    /// component, the static default).
    alpha_milli: [AtomicU32; TUNE_BUCKETS],
    /// Last replanned admission capacity (also applied to
    /// `Admission::max_queued` by the tuner thread).
    admission_capacity: AtomicU64,
    /// Last replanned per-worker queue capacity (published telemetry;
    /// resident deques grow on demand, so this is the plan, not a cap).
    queue_capacity: AtomicU64,

    // ---- decision traffic (written by JobTune on consultation) ----
    decisions_owned: AtomicU64,
    decisions_delta: AtomicU64,
    induce_pass: AtomicU64,
    induce_block: AtomicU64,

    // ---- engine observations (drained from worker scratch) ----
    owned_nodes: [AtomicU64; TUNE_BUCKETS],
    owned_bytes: [AtomicU64; TUNE_BUCKETS],
    delta_nodes: [AtomicU64; TUNE_BUCKETS],
    delta_bytes: [AtomicU64; TUNE_BUCKETS],
    tree_nodes: [AtomicU64; TUNE_BUCKETS],
    induced: [AtomicU64; TUNE_BUCKETS],
    undo_pops: AtomicU64,
    undo_covers: AtomicU64,
    materializations: AtomicU64,
    replayed_covers: AtomicU64,

    // ---- controller surface ----
    epochs: AtomicU64,
    flips: AtomicU64,
    /// First epoch after which [`STABLE_TICKS`] consecutive ticks saw
    /// traffic but no knob movement (0 = not converged yet).
    converged_epoch: AtomicU64,
    steal_rate_ppm: AtomicU64,
}

impl Default for TuneShared {
    fn default() -> Self {
        Self::new()
    }
}

impl TuneShared {
    pub(crate) fn new() -> TuneShared {
        let mut mask = 0u32;
        for b in PRIOR_DELTA_BUCKET..TUNE_BUCKETS {
            mask |= 1 << b;
        }
        TuneShared {
            delta_mask: AtomicU32::new(mask),
            pin_depth: AtomicU32::new(DEFAULT_MAX_PIN_DEPTH),
            alpha_milli: std::array::from_fn(|_| AtomicU32::new(1000)),
            admission_capacity: AtomicU64::new(0),
            queue_capacity: AtomicU64::new(0),
            decisions_owned: AtomicU64::new(0),
            decisions_delta: AtomicU64::new(0),
            induce_pass: AtomicU64::new(0),
            induce_block: AtomicU64::new(0),
            owned_nodes: zeros(),
            owned_bytes: zeros(),
            delta_nodes: zeros(),
            delta_bytes: zeros(),
            tree_nodes: zeros(),
            induced: zeros(),
            undo_pops: AtomicU64::new(0),
            undo_covers: AtomicU64::new(0),
            materializations: AtomicU64::new(0),
            replayed_covers: AtomicU64::new(0),
            epochs: AtomicU64::new(0),
            flips: AtomicU64::new(0),
            converged_epoch: AtomicU64::new(0),
            steal_rate_ppm: AtomicU64::new(0),
        }
    }

    /// Absorb a worker's per-item observation scratch plus the
    /// stats-delta globals (the caller flushes and resets `stats`
    /// immediately after, so its counters are per-item deltas). Only
    /// non-zero cells touch the shared atomics.
    pub(crate) fn absorb(&self, obs: &mut TuneObs, stats: &EngineStats) {
        if obs.any {
            for b in 0..TUNE_BUCKETS {
                macro_rules! add {
                    ($field:ident) => {
                        if obs.$field[b] != 0 {
                            self.$field[b].fetch_add(obs.$field[b], Ordering::Relaxed);
                        }
                    };
                }
                add!(owned_nodes);
                add!(owned_bytes);
                add!(delta_nodes);
                add!(delta_bytes);
                add!(tree_nodes);
                add!(induced);
            }
            *obs = TuneObs::default();
        }
        macro_rules! addg {
            ($field:ident) => {
                if stats.$field != 0 {
                    self.$field.fetch_add(stats.$field, Ordering::Relaxed);
                }
            };
        }
        addg!(undo_pops);
        addg!(undo_covers);
        addg!(materializations);
        addg!(replayed_covers);
    }

    fn snapshot(&self) -> ObsSnapshot {
        macro_rules! arr {
            ($field:ident) => {
                std::array::from_fn(|b| self.$field[b].load(Ordering::Relaxed))
            };
        }
        ObsSnapshot {
            owned_nodes: arr!(owned_nodes),
            owned_bytes: arr!(owned_bytes),
            delta_nodes: arr!(delta_nodes),
            delta_bytes: arr!(delta_bytes),
            tree_nodes: arr!(tree_nodes),
            induced: arr!(induced),
            materializations: self.materializations.load(Ordering::Relaxed),
            replayed_covers: self.replayed_covers.load(Ordering::Relaxed),
        }
    }

    /// Current controller state as a stats block (`enabled` is supplied
    /// by the service, which knows whether a tuner thread is running).
    pub(crate) fn stats(&self, enabled: bool) -> AutotuneStats {
        AutotuneStats {
            enabled,
            epochs: self.epochs.load(Ordering::Relaxed),
            flips: self.flips.load(Ordering::Relaxed),
            converged_epoch: self.converged_epoch.load(Ordering::Relaxed),
            pin_depth: self.pin_depth.load(Ordering::Relaxed) as u64,
            delta_buckets: self.delta_mask.load(Ordering::Relaxed) as u64,
            decisions_owned: self.decisions_owned.load(Ordering::Relaxed),
            decisions_delta: self.decisions_delta.load(Ordering::Relaxed),
            induce_pass: self.induce_pass.load(Ordering::Relaxed),
            induce_block: self.induce_block.load(Ordering::Relaxed),
            steal_rate_ppm: self.steal_rate_ppm.load(Ordering::Relaxed),
            admission_capacity: self.admission_capacity.load(Ordering::Relaxed),
            queue_capacity: self.queue_capacity.load(Ordering::Relaxed),
        }
    }
}

/// Per-worker observation scratch, drained into [`TuneShared`] at
/// stats-flush time so the engine hot path pays plain-integer adds, not
/// shared atomics.
#[derive(Default)]
pub(crate) struct TuneObs {
    pub(crate) owned_nodes: [u64; TUNE_BUCKETS],
    pub(crate) owned_bytes: [u64; TUNE_BUCKETS],
    pub(crate) delta_nodes: [u64; TUNE_BUCKETS],
    pub(crate) delta_bytes: [u64; TUNE_BUCKETS],
    pub(crate) tree_nodes: [u64; TUNE_BUCKETS],
    pub(crate) induced: [u64; TUNE_BUCKETS],
    /// Whether any cell was written since the last drain.
    pub(crate) any: bool,
}

impl TuneObs {
    #[inline]
    pub(crate) fn note_owned(&mut self, width: usize, bytes: u64) {
        let b = bucket_of(width);
        self.owned_nodes[b] += 1;
        self.owned_bytes[b] += bytes;
        self.any = true;
    }

    #[inline]
    pub(crate) fn note_delta_node(&mut self, width: usize) {
        self.delta_nodes[bucket_of(width)] += 1;
        self.any = true;
    }

    #[inline]
    pub(crate) fn note_delta_bytes(&mut self, width: usize, bytes: u64) {
        self.delta_bytes[bucket_of(width)] += bytes;
        self.any = true;
    }

    #[inline]
    pub(crate) fn note_tree_node(&mut self, width: usize) {
        self.tree_nodes[bucket_of(width)] += 1;
        self.any = true;
    }

    #[inline]
    pub(crate) fn note_induced(&mut self, size: usize) {
        self.induced[bucket_of(size)] += 1;
        self.any = true;
    }
}

/// The per-job consultation handle carried on `JobCfg`. Knobs the
/// submitter (or an env override) set explicitly are *pinned*: the
/// corresponding `tune_*` flag is false and the static value wins, so
/// ablation runs stay exact while default-configured jobs float with
/// the controller.
pub struct JobTune {
    pub(crate) shared: Arc<TuneShared>,
    pub(crate) tune_repr: bool,
    pub(crate) tune_pin: bool,
    pub(crate) tune_induce: bool,
}

impl std::fmt::Debug for JobTune {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobTune")
            .field("tune_repr", &self.tune_repr)
            .field("tune_pin", &self.tune_pin)
            .field("tune_induce", &self.tune_induce)
            .finish()
    }
}

impl JobTune {
    /// Effective node representation for an owned node of `width`
    /// opening a descent. The caller (`JobCtl::repr_for`) has already
    /// applied the watchdog's `forced_delta` override — the degradation
    /// ladder outranks the controller.
    #[inline]
    pub(crate) fn repr_for(&self, width: usize, cfg_repr: NodeRepr) -> NodeRepr {
        if !self.tune_repr {
            return cfg_repr;
        }
        let b = bucket_of(width);
        if self.shared.delta_mask.load(Ordering::Relaxed) & (1 << b) != 0 {
            self.shared.decisions_delta.fetch_add(1, Ordering::Relaxed);
            NodeRepr::Delta
        } else {
            self.shared.decisions_owned.fetch_add(1, Ordering::Relaxed);
            NodeRepr::Owned
        }
    }

    /// Effective delta-chain length bound.
    #[inline]
    pub(crate) fn pin_depth(&self, cfg: u32) -> u32 {
        if self.tune_pin {
            self.shared.pin_depth.load(Ordering::Relaxed)
        } else {
            cfg
        }
    }

    /// Effective §IV-B induction gate for a component of `size` inside
    /// a view of `view_n` vertices.
    #[inline]
    pub(crate) fn induce_gate(&self, size: u32, view_n: usize, cfg_alpha: f64) -> bool {
        let alpha = if self.tune_induce {
            self.shared.alpha_milli[bucket_of(size as usize)].load(Ordering::Relaxed) as f64
                / 1000.0
        } else {
            cfg_alpha
        };
        let pass = alpha > 0.0 && (size as f64) <= alpha * view_n as f64;
        if pass {
            self.shared.induce_pass.fetch_add(1, Ordering::Relaxed);
        } else {
            self.shared.induce_block.fetch_add(1, Ordering::Relaxed);
        }
        pass
    }
}

/// Plain snapshot of the cumulative observation counters.
#[derive(Default, Clone)]
struct ObsSnapshot {
    owned_nodes: [u64; TUNE_BUCKETS],
    owned_bytes: [u64; TUNE_BUCKETS],
    delta_nodes: [u64; TUNE_BUCKETS],
    delta_bytes: [u64; TUNE_BUCKETS],
    tree_nodes: [u64; TUNE_BUCKETS],
    induced: [u64; TUNE_BUCKETS],
    materializations: u64,
    replayed_covers: u64,
}

impl ObsSnapshot {
    fn activity(&self) -> u64 {
        self.tree_nodes.iter().sum::<u64>()
            + self.owned_nodes.iter().sum::<u64>()
            + self.delta_nodes.iter().sum::<u64>()
    }
}

/// The decision procedure: one `tick` per controller epoch. Owns the
/// EWMA state and the previous snapshot; free of threads and clocks so
/// tests can drive it synthetically. The service thread supplies the
/// scheduler-side inputs (steal counters) and the occupancy replans
/// (admission/queue capacity from live ledger bytes) each tick.
pub(crate) struct Tuner {
    shared: Arc<TuneShared>,
    prev: ObsSnapshot,
    prev_steals: u64,
    prev_acquired: u64,
    /// EWMA bytes/node per bucket, in milli-bytes (0 = no data yet).
    ewma_owned_bpn: [u64; TUNE_BUCKETS],
    ewma_delta_bpn: [u64; TUNE_BUCKETS],
    stable: u32,
}

impl Tuner {
    pub(crate) fn new(shared: Arc<TuneShared>) -> Tuner {
        Tuner {
            shared,
            prev: ObsSnapshot::default(),
            prev_steals: 0,
            prev_acquired: 0,
            ewma_owned_bpn: [0; TUNE_BUCKETS],
            ewma_delta_bpn: [0; TUNE_BUCKETS],
            stable: 0,
        }
    }

    /// Run one controller epoch. `steals`/`acquired` are cumulative
    /// pool-wide scheduler counters; `admission_capacity` and
    /// `queue_capacity` are the occupancy model's replans from live
    /// ledger bytes (the caller applies the admission value to the
    /// admission layer; this records them and charges flips).
    pub(crate) fn tick(
        &mut self,
        steals: u64,
        acquired: u64,
        admission_capacity: u64,
        queue_capacity: u64,
    ) {
        let sh = &self.shared;
        let epoch = sh.epochs.fetch_add(1, Ordering::Relaxed) + 1;
        let cur = sh.snapshot();
        let traffic = cur.activity() > self.prev.activity();
        let mut flips = 0u64;

        // ---- steal rate (per-tick, falling back to the last value on
        // idle ticks) ----
        let d_steals = steals.saturating_sub(self.prev_steals);
        let d_acquired = acquired.saturating_sub(self.prev_acquired);
        self.prev_steals = steals;
        self.prev_acquired = acquired;
        if d_acquired > 0 {
            sh.steal_rate_ppm.store(d_steals * 1_000_000 / d_acquired, Ordering::Relaxed);
        }
        let rate = sh.steal_rate_ppm.load(Ordering::Relaxed);

        // ---- (b) steal-rate-driven pin depth ----
        if d_acquired >= 64 {
            let pin = sh.pin_depth.load(Ordering::Relaxed);
            let new_pin = if rate < STEAL_LOW_PPM {
                (pin + PIN_STEP).min(MAX_PIN_DEPTH_CAP)
            } else if rate > STEAL_HIGH_PPM {
                pin.saturating_sub(PIN_STEP).max(MIN_PIN_DEPTH)
            } else {
                pin
            };
            if new_pin != pin {
                sh.pin_depth.store(new_pin, Ordering::Relaxed);
                flips += 1;
            }
        }

        // ---- (a) per-width repr choice: EWMA bytes/node ----
        for b in 0..TUNE_BUCKETS {
            let dn = cur.owned_nodes[b] - self.prev.owned_nodes[b];
            if dn > 0 {
                let sample = (cur.owned_bytes[b] - self.prev.owned_bytes[b]) * 1000 / dn;
                self.ewma_owned_bpn[b] = ewma(self.ewma_owned_bpn[b], sample);
            }
            let dn = cur.delta_nodes[b] - self.prev.delta_nodes[b];
            if dn > 0 {
                let sample = (cur.delta_bytes[b] - self.prev.delta_bytes[b]) * 1000 / dn;
                self.ewma_delta_bpn[b] = ewma(self.ewma_delta_bpn[b], sample);
            }
        }
        // Materialization replay cost a thief pays per stolen delta, in
        // milli-bytes of cover entries (4 bytes each).
        let replay_milli = if cur.materializations > 0 {
            cur.replayed_covers * 4_000 / cur.materializations
        } else {
            64_000 // prior: ~16 replayed covers per materialization
        };
        let mut mask = sh.delta_mask.load(Ordering::Relaxed);
        for b in MIN_DELTA_BUCKET..TUNE_BUCKETS {
            let (owned, delta) = (self.ewma_owned_bpn[b], self.ewma_delta_bpn[b]);
            if owned == 0 || delta == 0 {
                continue; // keep prior/current choice until both sides have data
            }
            // Expected delta cost: resident chain bytes plus the
            // steal-rate-weighted materialization replay.
            let delta_cost = delta + rate * replay_milli / 1_000_000;
            let bit = 1u32 << b;
            // 2× hysteresis on both edges so the mask doesn't chatter.
            if mask & bit == 0 && owned > delta_cost * 2 {
                mask |= bit;
                flips += 1;
            } else if mask & bit != 0 && owned * 2 < delta_cost {
                mask &= !bit;
                flips += 1;
            }
        }
        sh.delta_mask.store(mask, Ordering::Relaxed);

        // ---- (c) per-bucket induction gating from amortization ----
        for b in 0..TUNE_BUCKETS {
            if cur.induced[b] < INDUCE_MIN_SAMPLES {
                continue;
            }
            // Tree nodes processed at this width per induced CSR
            // rebuild: the §IV-B rebuild amortizes when descendants
            // sweep the compact view many times.
            let amort = cur.tree_nodes[b] / cur.induced[b];
            let alpha = sh.alpha_milli[b].load(Ordering::Relaxed);
            let new_alpha = if amort < INDUCE_LOW_AMORT {
                (alpha / 2).max(INDUCE_MIN_ALPHA_MILLI)
            } else if amort > INDUCE_HIGH_AMORT {
                (alpha * 2).min(1000)
            } else {
                alpha
            };
            if new_alpha != alpha {
                sh.alpha_milli[b].store(new_alpha, Ordering::Relaxed);
                flips += 1;
            }
        }

        // ---- (d) pool-shape convergence ----
        if sh.admission_capacity.swap(admission_capacity, Ordering::Relaxed)
            != admission_capacity
        {
            flips += 1;
        }
        if sh.queue_capacity.swap(queue_capacity, Ordering::Relaxed) != queue_capacity {
            flips += 1;
        }

        // ---- convergence bookkeeping ----
        if flips > 0 {
            sh.flips.fetch_add(flips, Ordering::Relaxed);
            self.stable = 0;
        } else if traffic {
            self.stable += 1;
            if self.stable >= STABLE_TICKS
                && sh.converged_epoch.load(Ordering::Relaxed) == 0
            {
                sh.converged_epoch.store(epoch, Ordering::Relaxed);
            }
        }
        self.prev = cur;
    }
}

#[inline]
fn ewma(prev: u64, sample: u64) -> u64 {
    if prev == 0 {
        sample
    } else {
        (3 * prev + sample) / 4
    }
}

/// Controller counters surfaced through `ServiceStats` (and the wire
/// stats frame): what the controller decided, how often it moved, and
/// when it converged.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AutotuneStats {
    /// Whether a controller thread is running on this service.
    pub enabled: bool,
    /// Controller epochs (ticks) elapsed.
    pub epochs: u64,
    /// Knob movements across all epochs.
    pub flips: u64,
    /// First epoch after which the knobs held still for several ticks
    /// of live traffic (0 = not converged yet).
    pub converged_epoch: u64,
    /// Current tuned delta-chain length bound.
    pub pin_depth: u64,
    /// Bitmask of width buckets currently taking the delta
    /// representation (bit `b` ⇔ bucket `b`).
    pub delta_buckets: u64,
    /// Per-dispatch repr decisions resolved to owned / delta.
    pub decisions_owned: u64,
    pub decisions_delta: u64,
    /// Induction-gate consultations that passed / were blocked.
    pub induce_pass: u64,
    pub induce_block: u64,
    /// Last observed steal rate (parts per million of acquired nodes).
    pub steal_rate_ppm: u64,
    /// Last replanned admission capacity (0 until the first replan).
    pub admission_capacity: u64,
    /// Last replanned per-worker queue capacity plan.
    pub queue_capacity: u64,
}

/// The `CAVC_AUTOTUNE` process default: `Some(true)`/`Some(false)` when
/// the variable is set to an on/off word, `None` otherwise (callers
/// fall through to the built-in default — on for the resident service).
pub fn env_autotune_default() -> Option<bool> {
    let v = std::env::var("CAVC_AUTOTUNE").ok()?;
    match v.trim().to_ascii_lowercase().as_str() {
        "on" | "1" | "true" | "yes" => Some(true),
        "off" | "0" | "false" | "no" => Some(false),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_monotonic_and_clamped() {
        let mut last = 0;
        for w in 1..100_000usize {
            let b = bucket_of(w);
            assert!(b >= last || b == last, "bucket regressed at width {w}");
            assert!(b < TUNE_BUCKETS);
            last = b;
        }
        assert!(bucket_of(1) < MIN_DELTA_BUCKET, "tiny views sit below the delta floor");
        assert!(bucket_of(16) < MIN_DELTA_BUCKET);
        assert!(bucket_of(1 << 30) == TUNE_BUCKETS - 1);
    }

    #[test]
    fn pin_depth_follows_steal_rate() {
        let sh = Arc::new(TuneShared::new());
        let mut t = Tuner::new(Arc::clone(&sh));
        // Low steal rate: chains lengthen toward the cap.
        let mut acquired = 0;
        for _ in 0..64 {
            acquired += 1000;
            t.tick(0, acquired, 0, 0);
        }
        assert_eq!(sh.pin_depth.load(Ordering::Relaxed), MAX_PIN_DEPTH_CAP);
        // High steal rate: chains shorten toward the floor.
        let mut steals = 0;
        for _ in 0..64 {
            acquired += 1000;
            steals += 500;
            t.tick(steals, acquired, 0, 0);
        }
        assert_eq!(sh.pin_depth.load(Ordering::Relaxed), MIN_PIN_DEPTH);
    }

    #[test]
    fn repr_mask_moves_with_observed_bytes_per_node() {
        let sh = Arc::new(TuneShared::new());
        let mut t = Tuner::new(Arc::clone(&sh));
        // Bucket 2 (width ~64): owned copies cost 256 B/node while delta
        // children freeze ~8 B/node — the controller should flip the
        // bucket to delta.
        let mut obs = TuneObs::default();
        for _ in 0..4 {
            obs.note_owned(64, 256);
            obs.note_delta_bytes(64, 8);
            obs.note_delta_node(64);
            sh.absorb(&mut obs, &EngineStats::default());
            t.tick(0, 0, 0, 0);
        }
        assert_ne!(
            sh.delta_mask.load(Ordering::Relaxed) & (1 << 2),
            0,
            "cheap deltas should win bucket 2"
        );
        // Now make deltas expensive (wide frozen bases) and owned cheap:
        // the bit must clear again.
        for _ in 0..16 {
            obs.note_owned(64, 16);
            obs.note_delta_bytes(64, 4096);
            obs.note_delta_node(64);
            sh.absorb(&mut obs, &EngineStats::default());
            t.tick(0, 0, 0, 0);
        }
        assert_eq!(
            sh.delta_mask.load(Ordering::Relaxed) & (1 << 2),
            0,
            "expensive deltas should lose bucket 2"
        );
    }

    #[test]
    fn induction_gate_halves_when_rebuilds_do_not_amortize() {
        let sh = Arc::new(TuneShared::new());
        let mut t = Tuner::new(Arc::clone(&sh));
        let b = bucket_of(100);
        let mut obs = TuneObs::default();
        // 32 induced rebuilds but only ~2 tree nodes each: no
        // amortization, alpha should halve (repeatedly, to the floor).
        for _ in 0..32 {
            obs.note_induced(100);
            obs.note_tree_node(100);
            obs.note_tree_node(100);
        }
        sh.absorb(&mut obs, &EngineStats::default());
        for _ in 0..8 {
            t.tick(0, 0, 0, 0);
        }
        assert_eq!(
            sh.alpha_milli[b].load(Ordering::Relaxed),
            INDUCE_MIN_ALPHA_MILLI,
            "non-amortizing bucket should bottom out"
        );
    }

    #[test]
    fn converges_after_stable_ticks_with_traffic() {
        let sh = Arc::new(TuneShared::new());
        let mut t = Tuner::new(Arc::clone(&sh));
        let mut obs = TuneObs::default();
        for i in 0..8u64 {
            obs.note_tree_node(50);
            sh.absorb(&mut obs, &EngineStats::default());
            t.tick(0, 0, 128, 256);
            if i == 0 {
                // The first replan publishes the pool shape (one flip
                // each) — convergence counting starts after.
                assert!(sh.flips.load(Ordering::Relaxed) > 0);
            }
        }
        let converged = sh.converged_epoch.load(Ordering::Relaxed);
        assert!(converged > 0, "controller should converge under steady obs");
        assert!(sh.epochs.load(Ordering::Relaxed) >= converged);
    }

    #[test]
    fn pinned_knobs_ignore_the_controller() {
        let sh = Arc::new(TuneShared::new());
        sh.pin_depth.store(7, Ordering::Relaxed);
        sh.delta_mask.store(u32::MAX, Ordering::Relaxed);
        let jt = JobTune {
            shared: Arc::clone(&sh),
            tune_repr: false,
            tune_pin: false,
            tune_induce: false,
        };
        assert_eq!(jt.repr_for(10_000, NodeRepr::Owned), NodeRepr::Owned);
        assert_eq!(jt.pin_depth(24), 24);
        // Pinned induce gate uses the static threshold verbatim.
        assert!(jt.induce_gate(10, 100, 1.0));
        assert!(!jt.induce_gate(10, 100, 0.0));
        let floats = JobTune {
            shared: Arc::clone(&sh),
            tune_repr: true,
            tune_pin: true,
            tune_induce: true,
        };
        assert_eq!(floats.repr_for(10_000, NodeRepr::Owned), NodeRepr::Delta);
        assert_eq!(floats.pin_depth(24), 7);
    }

    #[test]
    fn env_parse_matches_the_memo_idiom() {
        // No env manipulation here (tests run in parallel); the parse
        // table itself is exercised through a local copy of the match.
        let parse = |v: &str| match v {
            "on" | "1" | "true" | "yes" => Some(true),
            "off" | "0" | "false" | "no" => Some(false),
            _ => None,
        };
        assert_eq!(parse("on"), Some(true));
        assert_eq!(parse("off"), Some(false));
        assert_eq!(parse("banana"), None);
    }
}
