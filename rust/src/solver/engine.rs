//! The parallel branch-and-reduce engine (paper §III).
//!
//! Reproduces the GPU execution model: N workers ("thread blocks"), each
//! with a private LIFO stack of search-tree nodes, plus a shared MPMC
//! worklist for load balancing. A node's entire intermediate state is a
//! degree array over the root-induced subgraph (generic dtype `T`), the
//! committed solution size, an incremental edge count, the non-zero
//! bounds window, and a registry context.
//!
//! One engine serves all three paper variants:
//! * **proposed** — `component_aware + load_balance`;
//! * **prior work (Yamout et al.)** — `load_balance` only (plus the
//!   pipeline disables root-induce / bounds / small dtypes);
//! * **no load balance** — `component_aware` with private stacks only
//!   (sub-trees statically seeded round-robin, components kept local).
//!
//! PVC (§III-E) runs the same engine with the global best initialized to
//! `k + 1`, registry propagation enabled, and stop-on-first-improvement.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::degree::{DegElem, NonZeroBounds};
use crate::graph::Graph;
use crate::reduce::special::classify;
use crate::util::timer::{Activity, ActivityTimer, NUM_ACTIVITIES};

use super::registry::{cas_min, Registry, NONE};
use super::worklist::Worklist;

/// Flattened engine configuration (see `SolverConfig` for the public
/// pipeline-level knobs).
#[derive(Debug, Clone)]
pub struct EngineCfg {
    /// Detect component splits and branch on components (§III).
    pub component_aware: bool,
    /// Offload children to the shared worklist (§II-C).
    pub load_balance: bool,
    /// Maintain non-zero bounds windows (§IV-C).
    pub use_bounds: bool,
    /// Worker threads to run.
    pub workers: usize,
    /// Stop on the first global improvement (PVC semantics).
    pub stop_on_improvement: bool,
    /// Wall-clock deadline.
    pub deadline: Option<Instant>,
    /// Record per-activity timings (Figure 4).
    pub instrument: bool,
}

/// Counters collected by the engine (Tables III / IV / Fig 4 inputs).
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Search-tree nodes visited.
    pub tree_nodes: u64,
    /// Nodes that branched on components.
    pub component_branches: u64,
    /// Histogram: components-per-branch → occurrence count.
    pub comp_histogram: BTreeMap<u32, u64>,
    /// Components solved in closed form (§III-D clique/cycle rules).
    pub special_solved: u64,
    /// Deepest private stack observed.
    pub max_stack_depth: usize,
    /// Nodes offloaded to the shared worklist.
    pub worklist_pushes: u64,
    /// Cross-worker steals from the worklist.
    pub worklist_steals: u64,
    /// Registry entries allocated.
    pub registry_entries: u64,
    /// Per-activity busy nanoseconds (all workers merged).
    pub activity: [u64; NUM_ACTIVITIES],
}

impl EngineStats {
    fn merge(&mut self, other: &EngineStats) {
        self.tree_nodes += other.tree_nodes;
        self.component_branches += other.component_branches;
        for (&k, &v) in &other.comp_histogram {
            *self.comp_histogram.entry(k).or_insert(0) += v;
        }
        self.special_solved += other.special_solved;
        self.max_stack_depth = self.max_stack_depth.max(other.max_stack_depth);
        self.worklist_pushes += other.worklist_pushes;
        self.worklist_steals += other.worklist_steals;
        for i in 0..NUM_ACTIVITIES {
            self.activity[i] += other.activity[i];
        }
    }
}

/// Result of an engine run over the residual graph.
#[derive(Debug, Clone)]
pub struct EngineOutcome {
    /// Best (residual-relative) cover size found, including the initial
    /// bound if never improved.
    pub best: u32,
    /// Whether the initial bound was improved.
    pub improved: bool,
    /// Counters.
    pub stats: EngineStats,
    /// True if the deadline fired before exhausting the search.
    pub timed_out: bool,
}

/// One search-tree node. `deg` is the full degree array of the induced
/// subgraph — exactly the paper's stack-entry payload.
struct Node<T> {
    deg: Box<[T]>,
    sol: u32,
    edges: u64,
    bounds: NonZeroBounds,
    ctx: u32,
}

struct Shared<'g, T> {
    g: &'g Graph,
    cfg: EngineCfg,
    registry: Registry,
    worklist: Worklist<Node<T>>,
    best: AtomicU32,
    pending: AtomicU64,
    stop: AtomicBool,
    improved: AtomicBool,
    timed_out: AtomicBool,
    low_water: usize,
    stats_sink: Mutex<EngineStats>,
}

impl<'g, T: DegElem> Shared<'g, T> {
    /// Prune bound for a node: global best at the root, `min(Best,
    /// Limit)` inside a component context.
    #[inline]
    fn bound_of(&self, ctx: u32) -> u32 {
        if ctx == NONE {
            self.best.load(Ordering::SeqCst)
        } else {
            self.registry.bound(ctx)
        }
    }

    /// Record an achievable root-level total.
    fn on_root_total(&self, total: u32) {
        if cas_min(&self.best, total).is_some() {
            self.improved.store(true, Ordering::SeqCst);
            if self.cfg.stop_on_improvement {
                self.stop.store(true, Ordering::SeqCst);
            }
        }
    }
}

struct WorkerCtx<T> {
    id: usize,
    stack: Vec<Node<T>>,
    /// Seeding mode (no-load-balance): children go to this FIFO frontier.
    frontier: Option<std::collections::VecDeque<Node<T>>>,
    /// BFS scratch: visit stamps (avoids clearing between searches).
    visit: Vec<u32>,
    stamp: u32,
    queue: Vec<u32>,
    nbuf: Vec<u32>,
    stats: EngineStats,
    timer: ActivityTimer,
    deadline_tick: u32,
}

impl<T: DegElem> WorkerCtx<T> {
    fn new(id: usize, n: usize, instrument: bool) -> Self {
        WorkerCtx {
            id,
            stack: Vec::new(),
            frontier: None,
            visit: vec![0; n],
            stamp: 0,
            queue: Vec::new(),
            nbuf: Vec::new(),
            stats: EngineStats::default(),
            timer: if instrument { ActivityTimer::enabled() } else { ActivityTimer::disabled() },
            deadline_tick: 0,
        }
    }
}

/// Run the engine on the (already root-reduced, induced) graph.
///
/// `initial_best` is the residual-relative upper bound (greedy bound
/// minus root-forced vertices for MVC; `k + 1` for PVC). Returns the best
/// value found (`== initial_best` if not improved).
pub fn run<T: DegElem>(
    g: &Graph,
    initial_best: u32,
    cfg: EngineCfg,
) -> EngineOutcome {
    let n = g.num_vertices();
    let workers = cfg.workers.max(1);
    let shared = Shared::<T> {
        g,
        registry: Registry::new(cfg.stop_on_improvement),
        worklist: Worklist::new(workers),
        best: AtomicU32::new(initial_best),
        pending: AtomicU64::new(0),
        stop: AtomicBool::new(false),
        improved: AtomicBool::new(false),
        timed_out: AtomicBool::new(false),
        low_water: 2 * workers,
        stats_sink: Mutex::new(EngineStats::default()),
        cfg,
    };

    // Root node over the full residual graph.
    let root = Node::<T> {
        deg: crate::degree::initial_degrees::<T>(g).into_boxed_slice(),
        sol: 0,
        edges: g.num_edges() as u64,
        bounds: NonZeroBounds::full(n),
        ctx: NONE,
    };

    if shared.cfg.load_balance {
        shared.pending.store(1, Ordering::SeqCst);
        shared.worklist.push(0, root);
        run_workers(&shared, workers, None);
    } else {
        // Static seeding (prior works [3], [4]): expand a frontier of
        // sub-trees breadth-first, then give each worker a fixed share.
        let mut seeder = WorkerCtx::<T>::new(0, n, shared.cfg.instrument);
        seeder.frontier = Some(std::collections::VecDeque::new());
        shared.pending.store(1, Ordering::SeqCst);
        seeder.frontier.as_mut().unwrap().push_back(root);
        let target = workers * 4;
        let mut processed = 0usize;
        while processed < 4096 {
            let Some(node) = seeder.frontier.as_mut().unwrap().pop_front() else { break };
            if seeder.frontier.as_ref().unwrap().len() + 1 >= target {
                seeder.frontier.as_mut().unwrap().push_front(node);
                break;
            }
            process(&shared, &mut seeder, node);
            shared.pending.fetch_sub(1, Ordering::SeqCst);
            processed += 1;
            if shared.stop.load(Ordering::SeqCst) {
                break;
            }
        }
        let frontier = seeder.frontier.take().unwrap();
        seeder.timer.stop();
        let mut sink = shared.stats_sink.lock().unwrap();
        seeder.stats.activity = seeder.timer.totals();
        sink.merge(&seeder.stats);
        drop(sink);
        run_workers(&shared, workers, Some(frontier));
    }

    let mut stats = shared.stats_sink.into_inner().unwrap();
    stats.worklist_pushes = shared.worklist.total_pushes() as u64;
    stats.worklist_steals = shared.worklist.total_steals() as u64;
    stats.registry_entries = shared.registry.len() as u64;
    let timed_out = shared.timed_out.load(Ordering::SeqCst);
    if cfg!(debug_assertions) && !timed_out && !shared.stop.load(Ordering::SeqCst) {
        shared.registry.assert_drained();
    }
    EngineOutcome {
        best: shared.best.load(Ordering::SeqCst),
        improved: shared.improved.load(Ordering::SeqCst),
        stats,
        timed_out,
    }
}

fn run_workers<T: DegElem>(
    shared: &Shared<'_, T>,
    workers: usize,
    seed: Option<std::collections::VecDeque<Node<T>>>,
) {
    let n = shared.g.num_vertices();
    let mut seeds: Vec<Vec<Node<T>>> = (0..workers).map(|_| Vec::new()).collect();
    if let Some(frontier) = seed {
        for (i, node) in frontier.into_iter().enumerate() {
            seeds[i % workers].push(node);
        }
    }
    std::thread::scope(|s| {
        for (id, seed_nodes) in seeds.into_iter().enumerate() {
            let shared = &*shared;
            s.spawn(move || {
                let mut ctx = WorkerCtx::<T>::new(id, n, shared.cfg.instrument);
                ctx.stack = seed_nodes;
                worker_loop(shared, &mut ctx);
                ctx.timer.stop();
                ctx.stats.activity = ctx.timer.totals();
                shared.stats_sink.lock().unwrap().merge(&ctx.stats);
            });
        }
    });
}

fn worker_loop<T: DegElem>(shared: &Shared<'_, T>, ctx: &mut WorkerCtx<T>) {
    let mut idle_spins = 0u32;
    loop {
        if shared.stop.load(Ordering::Relaxed) {
            return;
        }
        ctx.timer.switch(Activity::Queue);
        let node = ctx.stack.pop().or_else(|| {
            if shared.cfg.load_balance {
                shared.worklist.pop(ctx.id)
            } else {
                None
            }
        });
        match node {
            Some(node) => {
                idle_spins = 0;
                process(shared, ctx, node);
                shared.pending.fetch_sub(1, Ordering::SeqCst);
                check_deadline(shared, ctx);
            }
            None => {
                if shared.pending.load(Ordering::SeqCst) == 0 {
                    return;
                }
                ctx.timer.switch(Activity::Idle);
                idle_spins += 1;
                if idle_spins > 64 {
                    std::thread::sleep(std::time::Duration::from_micros(50));
                    check_deadline(shared, ctx);
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }
}

#[inline]
fn check_deadline<T: DegElem>(shared: &Shared<'_, T>, ctx: &mut WorkerCtx<T>) {
    ctx.deadline_tick = ctx.deadline_tick.wrapping_add(1);
    if ctx.deadline_tick % 64 != 0 {
        return;
    }
    if let Some(d) = shared.cfg.deadline {
        if Instant::now() >= d {
            shared.timed_out.store(true, Ordering::SeqCst);
            shared.stop.store(true, Ordering::SeqCst);
        }
    }
}

/// Process one search-tree node, descending left branches in place.
fn process<T: DegElem>(shared: &Shared<'_, T>, ctx: &mut WorkerCtx<T>, mut node: Node<T>) {
    loop {
        ctx.stats.tree_nodes += 1;

        // ---- reduce (Alg. 2 line 2) ----
        ctx.timer.switch(Activity::Reduce);
        let red = reduce_node(shared, &mut node);

        // ---- stopping conditions (lines 3-4) ----
        ctx.timer.switch(Activity::Leaf);
        let bound = shared.bound_of(node.ctx);
        if node.sol >= bound {
            complete(shared, node.ctx);
            return;
        }
        let rem = (bound - node.sol - 1) as u64;
        if node.edges > rem * rem {
            complete(shared, node.ctx);
            return;
        }
        // ---- leaf (lines 5-7) ----
        if node.edges == 0 {
            report_leaf(shared, node.ctx, node.sol);
            complete(shared, node.ctx);
            return;
        }

        // ---- component search (line 9) ----
        if shared.cfg.component_aware {
            ctx.timer.switch(Activity::ComponentSearch);
            match scan_components(shared, ctx, &node, &red) {
                Scan::Single => {}
                Scan::SingleSpecial(mvc) => {
                    ctx.stats.special_solved += 1;
                    report_leaf(shared, node.ctx, node.sol + mvc);
                    complete(shared, node.ctx);
                    return;
                }
                Scan::Split { first_size, dmin, dmax } => {
                    branch_on_components(shared, ctx, node, first_size, dmin, dmax);
                    return;
                }
            }
        }

        // ---- single-component branch (lines 11-13) ----
        ctx.timer.switch(Activity::Branch);
        let vmax = red.vmax;
        debug_assert_eq!(vmax, max_degree_vertex(&node), "fused argmax out of sync");
        debug_assert_ne!(vmax, u32::MAX);

        // right child: N(vmax) into S
        let right = make_right_child(shared, ctx, &node, vmax);
        shared.registry.on_branch(node.ctx);
        push_child(shared, ctx, right);

        // left child: vmax into S — descend in place
        cover_vertex(shared.g, &mut node, vmax);
        node.sol += 1;
    }
}

/// Outcome of the reduce fixpoint, carrying facts the final sweep
/// computed for free so later stages skip their own window scans.
#[derive(Debug, Clone, Copy)]
struct ReduceOutcome {
    /// Present (non-zero-degree) vertices in the residual.
    present: usize,
    /// First present vertex (BFS seed), or `u32::MAX`.
    first: u32,
    /// Vertex of maximum residual degree, or `u32::MAX`.
    vmax: u32,
}

const NO_VERTEX: ReduceOutcome = ReduceOutcome { present: 0, first: u32::MAX, vmax: u32::MAX };

/// Apply the cheap reduction rules to a fixpoint over the bounds window.
///
/// The final (unchanged) sweep doubles as the census pass: it counts the
/// present vertices, finds the first one (the component-BFS seed), and
/// selects the maximum-degree branch vertex — so neither the component
/// scan nor the branching step needs another pass over the window.
fn reduce_node<T: DegElem>(shared: &Shared<'_, T>, node: &mut Node<T>) -> ReduceOutcome {
    let g = shared.g;
    loop {
        if shared.cfg.use_bounds {
            node.bounds = node.bounds.tighten(&node.deg);
        } else {
            node.bounds = NonZeroBounds::full(node.deg.len());
        }
        if node.edges == 0 || node.bounds.is_empty() {
            return NO_VERTEX;
        }
        let bound = shared.bound_of(node.ctx);
        if node.sol >= bound {
            return NO_VERTEX; // stopping condition will fire
        }
        let mut changed = false;
        let mut present = 0usize;
        let mut first = u32::MAX;
        let mut vmax = u32::MAX;
        let mut dmax = 0u32;
        let lo = node.bounds.lo as usize;
        let hi = node.bounds.hi as usize;
        let mut v = lo;
        // while-loop over the window: measurably cheaper than the
        // RangeInclusive iterator in this innermost sweep
        while v <= hi {
            let d = node.deg[v].to_u32();
            if d == 0 {
                v += 1;
                continue;
            }
            present += 1;
            if first == u32::MAX {
                first = v as u32;
            }
            if d > dmax {
                dmax = d;
                vmax = v as u32;
            }
            match d {
                1 => {
                    // degree-one: cover the neighbor
                    let u = first_present_neighbor(g, &node.deg, v as u32);
                    cover_vertex(g, node, u);
                    node.sol += 1;
                    changed = true;
                }
                2 => {
                    // degree-two triangle: cover both neighbors
                    let (a, b) = two_present_neighbors(g, &node.deg, v as u32);
                    if g.has_edge(a, b) {
                        cover_vertex(g, node, a);
                        cover_vertex(g, node, b);
                        node.sol += 2;
                        changed = true;
                    }
                }
                d => {
                    // high-degree rule
                    let budget = bound.saturating_sub(node.sol).saturating_sub(1);
                    if d > budget {
                        cover_vertex(g, node, v as u32);
                        node.sol += 1;
                        changed = true;
                    }
                }
            }
            if node.edges == 0 || node.sol >= bound {
                return NO_VERTEX;
            }
            v += 1;
        }
        if !changed {
            // nothing fired this sweep, so the census is exact
            return ReduceOutcome { present, first, vmax };
        }
    }
}

/// Remove `v` into the cover: zero its degree, decrement present
/// neighbors, maintain the edge count. (Does not touch `sol`.)
#[inline]
fn cover_vertex<T: DegElem>(g: &Graph, node: &mut Node<T>, v: u32) {
    let d = node.deg[v as usize].to_u32();
    debug_assert!(d > 0);
    node.deg[v as usize] = T::from_u32(0);
    node.edges -= d as u64;
    let mut remaining = d;
    for &w in g.neighbors(v) {
        let dw = node.deg[w as usize].to_u32();
        if dw > 0 {
            node.deg[w as usize] = T::from_u32(dw - 1);
            remaining -= 1;
            if remaining == 0 {
                break;
            }
        }
    }
    debug_assert_eq!(remaining, 0, "degree count out of sync");
}

#[inline]
fn first_present_neighbor<T: DegElem>(g: &Graph, deg: &[T], v: u32) -> u32 {
    for &w in g.neighbors(v) {
        if deg[w as usize].to_u32() > 0 {
            return w;
        }
    }
    unreachable!("degree-1 vertex must have a present neighbor")
}

#[inline]
fn two_present_neighbors<T: DegElem>(g: &Graph, deg: &[T], v: u32) -> (u32, u32) {
    let mut first = u32::MAX;
    for &w in g.neighbors(v) {
        if deg[w as usize].to_u32() > 0 {
            if first == u32::MAX {
                first = w;
            } else {
                return (first, w);
            }
        }
    }
    unreachable!("degree-2 vertex must have two present neighbors")
}

/// Vertex of maximum residual degree within the bounds window
/// (debug cross-check for the fused census in `reduce_node`).
#[cfg_attr(not(debug_assertions), allow(dead_code))]
fn max_degree_vertex<T: DegElem>(node: &Node<T>) -> u32 {
    let mut vmax = u32::MAX;
    let mut dmax = 0u32;
    for v in node.bounds.lo..=node.bounds.hi {
        let d = node.deg[v as usize].to_u32();
        if d > dmax {
            dmax = d;
            vmax = v;
        }
    }
    vmax
}

/// Build the right child (`N(vmax)` into the cover).
fn make_right_child<T: DegElem>(
    shared: &Shared<'_, T>,
    ctx: &mut WorkerCtx<T>,
    node: &Node<T>,
    vmax: u32,
) -> Node<T> {
    let g = shared.g;
    ctx.nbuf.clear();
    ctx.nbuf.extend(
        g.neighbors(vmax).iter().copied().filter(|&w| node.deg[w as usize].to_u32() > 0),
    );
    let mut child = Node {
        deg: node.deg.clone(),
        sol: node.sol + ctx.nbuf.len() as u32,
        edges: node.edges,
        bounds: node.bounds,
        ctx: node.ctx,
    };
    for &u in &ctx.nbuf {
        if child.deg[u as usize].to_u32() > 0 {
            cover_vertex(g, &mut child, u);
        }
    }
    debug_assert_eq!(child.deg[vmax as usize].to_u32(), 0);
    child
}

/// Push a child node to the worklist (if balancing and it is hungry) or
/// the private stack / seed frontier.
fn push_child<T: DegElem>(shared: &Shared<'_, T>, ctx: &mut WorkerCtx<T>, node: Node<T>) {
    shared.pending.fetch_add(1, Ordering::SeqCst);
    if let Some(front) = ctx.frontier.as_mut() {
        front.push_back(node);
        return;
    }
    if shared.cfg.load_balance && shared.worklist.is_hungry(shared.low_water) {
        shared.worklist.push(ctx.id, node);
    } else {
        ctx.stack.push(node);
        ctx.stats.max_stack_depth = ctx.stats.max_stack_depth.max(ctx.stack.len());
    }
}

fn report_leaf<T: DegElem>(shared: &Shared<'_, T>, ctx: u32, size: u32) {
    if ctx == NONE {
        shared.on_root_total(size);
    } else {
        let mut on_root = |t: u32| shared.on_root_total(t);
        shared.registry.report_solution(ctx, size, &mut on_root);
    }
}

fn complete<T: DegElem>(shared: &Shared<'_, T>, ctx: u32) {
    let mut on_root = |t: u32| shared.on_root_total(t);
    shared.registry.complete_node(ctx, &mut on_root);
}

enum Scan {
    /// Residual graph is one component (not special).
    Single,
    /// One component and it is a clique / chordless cycle with this MVC.
    SingleSpecial(u32),
    /// Multiple components. The detection BFS's component is left in
    /// `ctx.queue` (stamp intact) so the split branch can reuse it.
    Split {
        /// |V| of the already-discovered first component.
        first_size: u32,
        /// Its minimum residual degree.
        dmin: u32,
        /// Its maximum residual degree.
        dmax: u32,
    },
}

/// One BFS from the first present vertex; decides single vs split.
/// On `Single`, also classifies the special-component rules (§III-D).
/// `present_total` comes for free from the reduce fixpoint's final sweep.
fn scan_components<T: DegElem>(
    shared: &Shared<'_, T>,
    ctx: &mut WorkerCtx<T>,
    node: &Node<T>,
    red: &ReduceOutcome,
) -> Scan {
    let start = red.first;
    debug_assert!(start != u32::MAX, "edges > 0 implies a present vertex");
    let (size, dmin, dmax) = bfs_component(shared.g, node, ctx, start);
    if (size as usize) == red.present {
        if dmin == dmax {
            if let Some(sp) = classify(size, std::iter::repeat(dmin).take(size as usize)) {
                return Scan::SingleSpecial(sp.mvc_size());
            }
        }
        return Scan::Single;
    }
    Scan::Split { first_size: size, dmin, dmax }
}

/// Branch on components (Alg. 2 lines 14-20): register a parent entry,
/// dispatch each component **eagerly** as it is found (special ones in
/// closed form), and release the discovery reference at the end.
///
/// The split-detection BFS already discovered the first component
/// (`ctx.queue`, visit stamps intact), so discovery resumes from there
/// instead of re-walking it.
fn branch_on_components<T: DegElem>(
    shared: &Shared<'_, T>,
    ctx: &mut WorkerCtx<T>,
    node: Node<T>,
    first_size: u32,
    first_dmin: u32,
    first_dmax: u32,
) {
    let g = shared.g;
    ctx.stats.component_branches += 1;
    let parent = shared.registry.new_parent(node.sol, node.ctx);
    ctx.stats.registry_entries += 1;

    // Component 1: reuse the detection BFS result.
    dispatch_component(shared, ctx, &node, parent, first_size, first_dmin, first_dmax);
    let mut comp_count = 1u32;

    // Remaining components: continue scanning under the same stamp.
    let mut cursor = node.bounds.lo;
    loop {
        // next unvisited present vertex
        let mut start = u32::MAX;
        while cursor <= node.bounds.hi {
            let v = cursor;
            cursor += 1;
            if node.deg[v as usize].to_u32() > 0 && ctx.visit[v as usize] != ctx.stamp {
                start = v;
                break;
            }
        }
        if start == u32::MAX {
            break;
        }
        let (size, dmin, dmax) = bfs_component_accumulate(g, &node, ctx, start);
        comp_count += 1;
        dispatch_component(shared, ctx, &node, parent, size, dmin, dmax);
    }

    *ctx.stats.comp_histogram.entry(comp_count).or_insert(0) += 1;
    let mut on_root = |t: u32| shared.on_root_total(t);
    shared.registry.finish_scan(parent, &mut on_root);
}

/// Handle one discovered component (vertex list in `ctx.queue`): solve
/// cliques/chordless cycles in closed form (§III-D), otherwise register
/// a child entry and dispatch the component node for search.
fn dispatch_component<T: DegElem>(
    shared: &Shared<'_, T>,
    ctx: &mut WorkerCtx<T>,
    node: &Node<T>,
    parent: u32,
    size: u32,
    dmin: u32,
    dmax: u32,
) {
    if dmin == dmax {
        if let Some(sp) = classify(size, std::iter::repeat(dmin).take(size as usize)) {
            ctx.stats.special_solved += 1;
            shared.registry.add_solved_component(parent, sp.mvc_size());
            return;
        }
    }

    // Register the component child: Best starts at the achievable
    // |V_i|-1; Limit adds the parent's remaining budget.
    let parent_bound = shared.bound_of_parent(node.ctx, parent);
    let best0 = size - 1;
    let limit = best0.min(parent_bound);
    let child_ctx = shared.registry.new_child(parent, best0, limit);
    ctx.stats.registry_entries += 1;

    // Materialize the component node: degrees masked to the component.
    let mut deg = vec![T::from_u32(0); node.deg.len()].into_boxed_slice();
    let mut edges2 = 0u64;
    let (mut lo, mut hi) = (u32::MAX, 0u32);
    for &v in &ctx.queue {
        let d = node.deg[v as usize];
        deg[v as usize] = d;
        edges2 += d.to_u32() as u64;
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let child = Node {
        deg,
        sol: 0,
        edges: edges2 / 2,
        bounds: NonZeroBounds { lo, hi },
        ctx: child_ctx,
    };
    push_child(shared, ctx, child);
}

impl<'g, T: DegElem> Shared<'g, T> {
    /// Remaining budget for a new component: the enclosing context bound
    /// minus what the split has already committed (`Sum` so far).
    fn bound_of_parent(&self, node_ctx: u32, parent: u32) -> u32 {
        let ctx_bound = self.bound_of(node_ctx);
        let (sum_now, _, _, _) = self.registry.snapshot(parent);
        ctx_bound.saturating_sub(sum_now)
    }
}

/// BFS one component starting at `start` using a fresh stamp.
/// Returns (size, min residual degree, max residual degree); the visited
/// vertex list is left in `ctx.queue`.
fn bfs_component<T: DegElem>(
    g: &Graph,
    node: &Node<T>,
    ctx: &mut WorkerCtx<T>,
    start: u32,
) -> (u32, u32, u32) {
    fresh_stamp(ctx);
    bfs_component_accumulate(g, node, ctx, start)
}

/// Advance the visit stamp, clearing marks on wraparound.
fn fresh_stamp<T: DegElem>(ctx: &mut WorkerCtx<T>) {
    ctx.stamp = ctx.stamp.wrapping_add(1);
    if ctx.stamp == 0 {
        ctx.visit.fill(0);
        ctx.stamp = 1;
    }
}

/// BFS one component reusing the current stamp (so successive calls in a
/// split scan accumulate the visited set).
fn bfs_component_accumulate<T: DegElem>(
    g: &Graph,
    node: &Node<T>,
    ctx: &mut WorkerCtx<T>,
    start: u32,
) -> (u32, u32, u32) {
    ctx.queue.clear();
    ctx.queue.push(start);
    ctx.visit[start as usize] = ctx.stamp;
    let mut head = 0;
    let (mut dmin, mut dmax) = (u32::MAX, 0u32);
    while head < ctx.queue.len() {
        let u = ctx.queue[head];
        head += 1;
        let du = node.deg[u as usize].to_u32();
        dmin = dmin.min(du);
        dmax = dmax.max(du);
        let mut remaining = du;
        for &w in g.neighbors(u) {
            if node.deg[w as usize].to_u32() > 0 {
                remaining -= 1;
                if ctx.visit[w as usize] != ctx.stamp {
                    ctx.visit[w as usize] = ctx.stamp;
                    ctx.queue.push(w);
                }
                if remaining == 0 {
                    break;
                }
            }
        }
    }
    (ctx.queue.len() as u32, dmin, dmax)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::solver::oracle;

    fn run_cfg(g: &Graph, component_aware: bool, load_balance: bool, workers: usize) -> u32 {
        let ub = crate::solver::greedy::greedy_bound(g);
        let out = run::<u32>(
            g,
            ub,
            EngineCfg {
                component_aware,
                load_balance,
                use_bounds: true,
                workers,
                stop_on_improvement: false,
                deadline: None,
                instrument: false,
            },
        );
        assert!(!out.timed_out);
        out.best
    }

    #[test]
    fn matches_oracle_all_variants() {
        for seed in 0..15 {
            let g = generators::erdos_renyi(18, 0.18, seed);
            let opt = oracle::mvc_size(&g);
            assert_eq!(run_cfg(&g, true, true, 4), opt, "proposed seed {seed}");
            assert_eq!(run_cfg(&g, false, true, 4), opt, "yamout seed {seed}");
            assert_eq!(run_cfg(&g, true, false, 4), opt, "no-lb seed {seed}");
            assert_eq!(run_cfg(&g, true, true, 1), opt, "1-worker seed {seed}");
        }
    }

    #[test]
    fn splitting_graphs_match_oracle() {
        for seed in 0..10 {
            let g = generators::union_of_random(4, 3, 6, 0.3, seed);
            let opt = oracle::mvc_size(&g);
            assert_eq!(run_cfg(&g, true, true, 4), opt, "seed {seed}");
            assert_eq!(run_cfg(&g, false, true, 4), opt, "seed {seed}");
        }
    }

    #[test]
    fn structured_graphs() {
        let cases: Vec<(Graph, u32)> = vec![
            (generators::cycle(9), 5),
            (generators::clique(7), 6),
            (generators::path(10), 5),
            (generators::star(12), 1),
        ];
        for (g, expect) in cases {
            assert_eq!(run_cfg(&g, true, true, 2), expect);
        }
    }

    #[test]
    fn component_branches_counted() {
        // two reduction-proof, non-special components (3-regular,
        // triangle-free) so the split must be handled by the registry
        let g = Graph::disjoint_union(&[generators::petersen(), generators::petersen()]);
        let ub = crate::solver::greedy::greedy_bound(&g);
        let out = run::<u32>(
            &g,
            ub,
            EngineCfg {
                component_aware: true,
                load_balance: true,
                use_bounds: true,
                workers: 2,
                stop_on_improvement: false,
                deadline: None,
                instrument: false,
            },
        );
        assert_eq!(out.best, oracle::mvc_size(&g));
        assert!(out.stats.component_branches >= 1);
        assert!(!out.stats.comp_histogram.is_empty());
    }

    #[test]
    fn pvc_mode_stops_early_when_found() {
        let g = generators::erdos_renyi(20, 0.2, 3);
        let opt = oracle::mvc_size(&g);
        // k = opt: initial best = k+1, must improve and stop
        let out = run::<u32>(
            &g,
            opt + 1,
            EngineCfg {
                component_aware: true,
                load_balance: true,
                use_bounds: true,
                workers: 4,
                stop_on_improvement: true,
                deadline: None,
                instrument: false,
            },
        );
        assert!(out.improved);
        assert!(out.best <= opt);
    }

    #[test]
    fn pvc_mode_k_too_small_finds_nothing() {
        let g = generators::erdos_renyi(16, 0.25, 5);
        let opt = oracle::mvc_size(&g);
        let out = run::<u32>(
            &g,
            opt, // searching for < opt ⇒ impossible
            EngineCfg {
                component_aware: true,
                load_balance: true,
                use_bounds: true,
                workers: 4,
                stop_on_improvement: true,
                deadline: None,
                instrument: false,
            },
        );
        assert!(!out.improved);
        assert_eq!(out.best, opt);
    }

    #[test]
    fn small_dtypes_agree() {
        for seed in 0..6 {
            let g = generators::erdos_renyi(20, 0.15, seed);
            let ub = crate::solver::greedy::greedy_bound(&g);
            let cfg = EngineCfg {
                component_aware: true,
                load_balance: true,
                use_bounds: true,
                workers: 3,
                stop_on_improvement: false,
                deadline: None,
                instrument: false,
            };
            let a = run::<u8>(&g, ub, cfg.clone()).best;
            let b = run::<u16>(&g, ub, cfg.clone()).best;
            let c = run::<u32>(&g, ub, cfg).best;
            assert_eq!(a, b, "seed {seed}");
            assert_eq!(b, c, "seed {seed}");
            assert_eq!(c, oracle::mvc_size(&g), "seed {seed}");
        }
    }

    #[test]
    fn bounds_disabled_agrees() {
        for seed in 0..5 {
            let g = generators::union_of_random(3, 4, 7, 0.25, seed);
            let ub = crate::solver::greedy::greedy_bound(&g);
            let mk = |use_bounds| EngineCfg {
                component_aware: true,
                load_balance: true,
                use_bounds,
                workers: 2,
                stop_on_improvement: false,
                deadline: None,
                instrument: false,
            };
            assert_eq!(
                run::<u32>(&g, ub, mk(true)).best,
                run::<u32>(&g, ub, mk(false)).best,
                "seed {seed}"
            );
        }
    }

    #[test]
    fn deadline_times_out() {
        // a dense-ish graph with an immediate deadline must report timeout
        let g = generators::p_hat(60, 0.3, 0.8, 1);
        let ub = crate::solver::greedy::greedy_bound(&g);
        let out = run::<u32>(
            &g,
            ub,
            EngineCfg {
                component_aware: true,
                load_balance: true,
                use_bounds: true,
                workers: 2,
                stop_on_improvement: false,
                deadline: Some(Instant::now()),
                instrument: false,
            },
        );
        assert!(out.timed_out);
    }

    #[test]
    fn instrumentation_records_activity() {
        let g = generators::erdos_renyi(24, 0.2, 9);
        let ub = crate::solver::greedy::greedy_bound(&g);
        let out = run::<u32>(
            &g,
            ub,
            EngineCfg {
                component_aware: true,
                load_balance: true,
                use_bounds: true,
                workers: 2,
                stop_on_improvement: false,
                deadline: None,
                instrument: true,
            },
        );
        let busy: u64 = out.stats.activity.iter().sum();
        assert!(busy > 0);
    }
}
