//! The parallel branch-and-reduce engine (paper §III).
//!
//! Reproduces the GPU execution model: N workers ("thread blocks"), each
//! with a private LIFO queue of search-tree nodes, load-balanced through
//! a pluggable [`Scheduler`] (see [`crate::solver::sched`]). A node's
//! entire intermediate state is a degree array over its *graph view*
//! (generic dtype `T`), the committed solution size, an incremental edge
//! count, the non-zero bounds window, and a registry context.
//!
//! ## Job setup vs. run loop
//!
//! The engine is split into two halves so the same node-processing code
//! serves both entry points:
//!
//! * **Job state** — [`JobCfg`] (the per-search knobs) and the crate-
//!   internal `JobCtl` (registry, global best, stop/improved/timed-out
//!   flags, live-byte accounting, stats sink). Everything a search
//!   needs that is independent of *which* threads run it.
//! * **Run loop** — `process`/`descend` drive one node at a time against
//!   a `JobCtl` through a [`WorkerHandle`]. The one-shot [`run`] entry
//!   spawns a `thread::scope` pool per call (the paper's benchmark
//!   shape); the resident [`crate::solver::service::VcService`] feeds
//!   nodes from many jobs through one persistent pool, each node
//!   carrying its job's `JobCtl` so completion, pruning, and
//!   last-descendant aggregation stay job-local.
//!
//! ## Memory model: root-induce → tree-induce
//!
//! The paper induces a subgraph once, at the root (§IV-B), so degree
//! arrays are sized to the reduced graph. This engine carries the same
//! idea *into the tree*: when a node splits on components, each
//! component is re-induced as a compact, renumbered subproblem — a
//! component-local CSR ([`crate::graph::induced::induce_residual_into`])
//! plus a `|C|`-sized degree array — so every descendant pays O(|C|) per
//! clone instead of O(n). A `Node`'s `view` points at its component's
//! CSR (`None` ⇒ the shared root graph); the [`crate::solver::registry`]
//! aggregates solution *sizes* on every run, and under
//! [`EngineCfg::extract_witness`] also the covers behind them — each
//! node carries a choice log of covered vertices in root-residual ids
//! (induced views keep a pre-composed `back` map, so renumbering is
//! undone at log-append time), and the last-descendant cascade
//! concatenates component-local winning logs exactly where it folds
//! sizes. GPU analogy: on the device this is the difference between
//! every thread block's stack slot being a full-width degree array in
//! global memory and post-split blocks working on small arrays that fit
//! shared memory — the occupancy lever of the paper's Table IV, applied
//! at every split (`Occupancy::plan_induced` models exactly this).
//!
//! Under node creation sits a per-worker size-classed `BufferPool`:
//! payloads of completed nodes (and the CSR arrays of fully-retired
//! component views) are recycled instead of returned to the allocator,
//! so the `make_right_child` clone on the hot path is a pool pop +
//! memcpy. Induction is gated by [`EngineCfg::induce_threshold`]
//! (`|C| ≤ α·view`) for ablation.
//!
//! ## Memory model, stage 3: delta/undo nodes ([`NodeRepr::Delta`])
//!
//! Tree induction made payloads O(|C|); the next lever is not copying
//! at all. Under the delta representation a worker branches
//! *speculatively in place*: the left child mutates the live frame
//! (every cover journaled reversibly), and the right child pushed to
//! the queue is just `(pinned parent frame, branch vertex)` — an `Arc`
//! chain of covered-vertex suffixes ending in an owned base snapshot,
//! the PR-4 choice-log format reused as a state delta. When the worker
//! pops its own delta back (the overwhelmingly common deep local-pop
//! case — steals are rare by design), it *undoes* the journal back to
//! the pinned branch point instead of restoring from a copy; when a
//! thief steals one, it materializes an owned payload at steal time by
//! replaying the chain onto a pooled copy of the base, so stolen work
//! owns its state outright and the Chase–Lev deque contract is
//! untouched. [`EngineCfg::max_pin_depth`] forces a fresh base every so
//! many links so undo/replay chains stay bounded — copy bandwidth is
//! traded for bounded recomputation, the trade GPU branch-and-bound
//! solvers (van der Zanden & Bodlaender's treewidth solver) showed wins
//! on memory-bound searches. GPU analogy: the left child descending in
//! shared memory without writing its stack slot back to global memory,
//! with the global-memory copy deferred until another thread block
//! actually claims the right sub-tree.
//!
//! Scheduling is split out of branching: the engine decides *what* to
//! explore (reduce, bound, branch, split on components) and the
//! scheduler decides *where* child nodes run. Two runtimes implement the
//! trait — the lock-free Chase–Lev work stealer (default) and the
//! mutex-sharded worklist baseline — selected by
//! [`EngineCfg::scheduler`], so schedulers can be compared head-to-head
//! on identical searches.
//!
//! One engine serves all three paper variants:
//! * **proposed** — `component_aware + load_balance`;
//! * **prior work (Yamout et al.)** — `load_balance` only (plus the
//!   pipeline disables root-induce / bounds / small dtypes);
//! * **no load balance** — `component_aware` with private queues only
//!   (sub-trees statically seeded round-robin, components kept local).
//!
//! PVC (§III-E) runs the same engine with the global best initialized to
//! `k + 1`, registry propagation enabled, and stop-on-first-improvement.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::degree::{DegElem, NonZeroBounds};
use crate::graph::induced::{fingerprint_csr, induce_residual_into};
use crate::graph::Graph;
use crate::reduce::special::{classify, SpecialComponent};
use crate::util::timer::{Activity, ActivityTimer, NUM_ACTIVITIES};

use super::registry::{cas_min, Registry, NONE};
use super::sched::{
    IdleOutcome, PopSource, Scheduler, SchedulerKind, ShardedScheduler, WorkStealScheduler,
    WorkerCounters, WorkerHandle,
};

/// Default per-worker queue capacity when no occupancy plan is supplied.
pub const DEFAULT_QUEUE_CAPACITY: usize = 256;

/// Default component-induction gate: re-induce every split component
/// (`|C| ≤ 1.0 × view` always holds — components are strict subsets).
pub const DEFAULT_INDUCE_THRESHOLD: f64 = 1.0;

/// Default bound on the delta-frame chain length before the engine
/// forces a fresh owned base snapshot (see [`NodeRepr::Delta`]): long
/// chains make steal-time materialization replay long cover suffixes,
/// so periodic materialization trades one full-width copy for bounded
/// replay cost — the same copy-vs-recompute dial the GPU treewidth
/// literature turns.
pub const DEFAULT_MAX_PIN_DEPTH: u32 = 24;

/// How search-tree nodes are physically represented in the queues.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum NodeRepr {
    /// Every right child owns a full pooled copy of its degree array
    /// (the ablation baseline — PR-2 behavior).
    #[default]
    Owned,
    /// Speculative in-place branching: the left child mutates the live
    /// frame, right children are (pinned parent frame + covered-vertex
    /// delta) and cost O(delta) resident bytes. A locally popped delta
    /// is *undone* onto the live frame by replaying the worker's choice
    /// journal in reverse; a stolen delta is materialized into an owned
    /// payload by the thief at steal time.
    Delta,
}

impl NodeRepr {
    /// Short display name used by tables and the CLI.
    pub fn name(self) -> &'static str {
        match self {
            NodeRepr::Owned => "owned",
            NodeRepr::Delta => "delta",
        }
    }

    /// Parse a name as accepted by `--node-repr` / `CAVC_NODE_REPR`.
    pub fn parse(s: &str) -> Option<NodeRepr> {
        match s {
            "owned" | "copy" => Some(NodeRepr::Owned),
            "delta" | "undo" => Some(NodeRepr::Delta),
            _ => None,
        }
    }

    /// The process default: `CAVC_NODE_REPR` when set (so test suites
    /// and CI matrix legs can flip every solver config at once),
    /// otherwise [`NodeRepr::Owned`].
    pub fn from_env() -> NodeRepr {
        std::env::var("CAVC_NODE_REPR")
            .ok()
            .and_then(|s| NodeRepr::parse(&s))
            .unwrap_or_default()
    }
}

/// Flattened engine configuration (see `SolverConfig` for the public
/// pipeline-level knobs). Combines the per-job search semantics
/// ([`JobCfg`]) with the pool shape (workers / scheduler / queue sizing)
/// for the one-shot [`run`] entry point.
#[derive(Debug, Clone)]
pub struct EngineCfg {
    /// Detect component splits and branch on components (§III).
    pub component_aware: bool,
    /// Let idle workers take other workers' nodes (§II-C).
    pub load_balance: bool,
    /// Maintain non-zero bounds windows (§IV-C).
    pub use_bounds: bool,
    /// Worker threads to run.
    pub workers: usize,
    /// Stop on the first global improvement (PVC semantics).
    pub stop_on_improvement: bool,
    /// Wall-clock deadline.
    pub deadline: Option<Instant>,
    /// Record per-activity timings (Figure 4).
    pub instrument: bool,
    /// Scheduling runtime to move nodes between workers.
    pub scheduler: SchedulerKind,
    /// Initial per-worker queue capacity (the occupancy model's
    /// stack-depth bound; queues grow beyond it as needed).
    pub queue_capacity: usize,
    /// Component-local subproblem induction gate: a split component is
    /// re-induced as a compact renumbered subproblem when
    /// `|C| ≤ induce_threshold × view_size`. `0.0` disables tree
    /// induction (children stay full-width over the parent's view);
    /// `1.0` (default) induces every component.
    pub induce_threshold: f64,
    /// Carry per-node choice logs and reassemble a witness cover at the
    /// registry's last-descendant aggregation (residual-graph ids; lift
    /// to original ids via `Prepared::lift_residual_cover`).
    pub extract_witness: bool,
    /// Physical node representation (owned payload copies vs delta/undo
    /// frames — see [`NodeRepr`]).
    pub node_repr: NodeRepr,
    /// Delta mode: maximum delta-frame chain length before a branch
    /// freezes a fresh owned base snapshot (bounds undo-replay cost).
    pub max_pin_depth: u32,
}

impl Default for EngineCfg {
    fn default() -> Self {
        EngineCfg {
            component_aware: true,
            load_balance: true,
            use_bounds: true,
            workers: 1,
            stop_on_improvement: false,
            deadline: None,
            instrument: false,
            scheduler: SchedulerKind::default(),
            queue_capacity: DEFAULT_QUEUE_CAPACITY,
            induce_threshold: DEFAULT_INDUCE_THRESHOLD,
            extract_witness: false,
            node_repr: NodeRepr::from_env(),
            max_pin_depth: DEFAULT_MAX_PIN_DEPTH,
        }
    }
}

impl EngineCfg {
    /// The per-job half of this configuration (everything that describes
    /// *one search*, none of the pool shape).
    pub fn job_cfg(&self) -> JobCfg {
        JobCfg {
            component_aware: self.component_aware,
            use_bounds: self.use_bounds,
            stop_on_improvement: self.stop_on_improvement,
            deadline: self.deadline,
            instrument: self.instrument,
            induce_threshold: self.induce_threshold,
            extract_witness: self.extract_witness,
            node_repr: self.node_repr,
            max_pin_depth: self.max_pin_depth,
            fault: None,
            memo: None,
            tune: None,
        }
    }
}

/// Per-job search configuration: the subset of [`EngineCfg`] that
/// describes one search's semantics, independent of which worker pool
/// executes it. The resident service attaches one `JobCfg` to every
/// submitted job; the one-shot [`run`] derives it from its `EngineCfg`.
#[derive(Debug, Clone)]
pub struct JobCfg {
    /// Detect component splits and branch on components (§III).
    pub component_aware: bool,
    /// Maintain non-zero bounds windows (§IV-C).
    pub use_bounds: bool,
    /// Stop on the first global improvement (PVC semantics).
    pub stop_on_improvement: bool,
    /// Wall-clock deadline for this job.
    pub deadline: Option<Instant>,
    /// Record per-activity timings and live-byte peaks.
    pub instrument: bool,
    /// Component-local subproblem induction gate (see
    /// [`EngineCfg::induce_threshold`]).
    pub induce_threshold: f64,
    /// Carry choice logs and reassemble a witness cover (see
    /// [`EngineCfg::extract_witness`]). Under PVC semantics this also
    /// gates early stopping on *assembled* root witnesses, so the
    /// returned cover always respects the proven bound.
    pub extract_witness: bool,
    /// Physical node representation (see [`NodeRepr`]).
    pub node_repr: NodeRepr,
    /// Delta mode: chain-length bound forcing periodic materialization.
    pub max_pin_depth: u32,
    /// Deterministic fault injector for chaos testing (see
    /// [`crate::solver::faults`]). `None` on every production path; when
    /// set, the engine consults it at node-processing, split, and
    /// allocation points.
    pub fault: Option<Arc<crate::solver::faults::FaultInjector>>,
    /// Cross-job component memoization handle (see
    /// [`crate::solver::memo`]). `None` on one-shot engines and when the
    /// service runs with the cache disabled; when set, component
    /// dispatch consults the cache and exactly-solved components are
    /// published back at last-view-drop time.
    pub memo: Option<Arc<crate::solver::memo::JobMemo>>,
    /// Self-tuning controller handle (see [`crate::solver::autotune`]).
    /// `None` on one-shot engines and when the service runs with the
    /// controller off; when set, the engine consults it for the
    /// per-width node representation, the delta pin depth, and the
    /// per-bucket induction gate — unless the corresponding static
    /// knob was set explicitly, which pins that decision. The memory
    /// watchdog's `forced_delta` override outranks every controller
    /// decision.
    pub tune: Option<Arc<crate::solver::autotune::JobTune>>,
}

impl Default for JobCfg {
    fn default() -> Self {
        EngineCfg::default().job_cfg()
    }
}

/// Counters collected by the engine (Tables III / IV / Fig 4 inputs).
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Search-tree nodes visited.
    pub tree_nodes: u64,
    /// Nodes that branched on components.
    pub component_branches: u64,
    /// Histogram: components-per-branch → occurrence count.
    pub comp_histogram: BTreeMap<u32, u64>,
    /// Components solved in closed form (§III-D clique/cycle rules).
    pub special_solved: u64,
    /// Deepest per-worker queue observed.
    pub max_stack_depth: usize,
    /// Nodes made visible to other workers (shared-queue/deque pushes).
    pub worklist_pushes: u64,
    /// Nodes taken from another worker.
    pub worklist_steals: u64,
    /// Registry entries allocated.
    pub registry_entries: u64,
    /// Split components materialized as compact induced subproblems
    /// (vs full-width masked children).
    pub induced_subproblems: u64,
    /// Node payloads (and CSR buffers) served from a worker's recycling
    /// pool instead of the allocator.
    pub pool_hits: u64,
    /// Pool requests that fell through to a fresh allocation.
    pub pool_misses: u64,
    /// Search-tree node payloads created (root + right children +
    /// component children; left descents mutate in place).
    pub payload_nodes: u64,
    /// Total bytes of those payloads — `payload_bytes / payload_nodes`
    /// is the engine's bytes-per-node figure (Table IV extension).
    pub payload_bytes: u64,
    /// Peak simultaneously-live node-state bytes: degree arrays plus the
    /// CSR buffers of live induced component views (tracked only when
    /// `EngineCfg::instrument` is set; 0 otherwise).
    pub peak_live_bytes: u64,
    /// Delta-representation right children pushed (parent-frame pin +
    /// branch vertex instead of an owned payload copy).
    pub delta_children: u64,
    /// Delta nodes consumed on the in-place undo fast path (the worker's
    /// live frame was rewound by reverse journal replay — no copy).
    pub undo_pops: u64,
    /// Covered vertices reverted by undo replay.
    pub undo_covers: u64,
    /// Delta nodes materialized into owned payloads (stolen or foreign
    /// nodes whose pinned frame is not the worker's live descent).
    pub materializations: u64,
    /// Covered vertices replayed forward while materializing delta
    /// nodes (the recompute cost paid for not copying).
    pub replayed_covers: u64,
    /// Owned base snapshots frozen for delta chains (first branch of a
    /// descent + periodic `max_pin_depth` materialization points).
    pub frame_bases: u64,
    /// Bytes frozen into pinned delta frames (base snapshots + cover
    /// suffixes) over the run.
    pub pinned_frame_bytes: u64,
    /// Bytes of witness choice-log entries retired over the run (each
    /// log's high-water length at node retirement) — the memory cost of
    /// witness extraction against the bytes-per-node telemetry.
    pub witness_log_bytes: u64,
    /// Witness log buffers recycled through the worker pools instead of
    /// freed.
    pub logs_recycled: u64,
    /// Worker panics contained while processing this job's nodes (the
    /// service's per-job panic containment; includes injected faults).
    pub panics: u64,
    /// Component dispatches that consulted the cross-job memo cache.
    pub memo_lookups: u64,
    /// Memo lookups that skipped the component's subtree entirely.
    pub memo_hits: u64,
    /// Coarse lower-bound estimate of tree nodes not expanded thanks to
    /// memo hits (component size per hit).
    pub memo_saved_nodes: u64,
    /// Per-activity busy nanoseconds (all workers merged).
    pub activity: [u64; NUM_ACTIVITIES],
    /// Per-worker scheduler counters, indexed by worker id (Figure-4
    /// instrumentation: push/pop/steal/retry traffic behind the
    /// `stack/worklist` bar).
    pub sched_workers: Vec<WorkerCounters>,
}

impl EngineStats {
    /// Accumulate `other` into `self`: sums for counters, max for
    /// high-water marks, elementwise merge for histograms and per-worker
    /// scheduler counters. Used by the workers to drain into a job's
    /// stats sink and by the service/batch layers to aggregate per-job
    /// stats into a fleet total.
    pub fn merge(&mut self, other: &EngineStats) {
        self.tree_nodes += other.tree_nodes;
        self.component_branches += other.component_branches;
        for (&k, &v) in &other.comp_histogram {
            *self.comp_histogram.entry(k).or_insert(0) += v;
        }
        self.special_solved += other.special_solved;
        self.max_stack_depth = self.max_stack_depth.max(other.max_stack_depth);
        self.worklist_pushes += other.worklist_pushes;
        self.worklist_steals += other.worklist_steals;
        self.registry_entries += other.registry_entries;
        self.induced_subproblems += other.induced_subproblems;
        self.pool_hits += other.pool_hits;
        self.pool_misses += other.pool_misses;
        self.payload_nodes += other.payload_nodes;
        self.payload_bytes += other.payload_bytes;
        self.peak_live_bytes = self.peak_live_bytes.max(other.peak_live_bytes);
        self.delta_children += other.delta_children;
        self.undo_pops += other.undo_pops;
        self.undo_covers += other.undo_covers;
        self.materializations += other.materializations;
        self.replayed_covers += other.replayed_covers;
        self.frame_bases += other.frame_bases;
        self.pinned_frame_bytes += other.pinned_frame_bytes;
        self.witness_log_bytes += other.witness_log_bytes;
        self.logs_recycled += other.logs_recycled;
        self.panics += other.panics;
        self.memo_lookups += other.memo_lookups;
        self.memo_hits += other.memo_hits;
        self.memo_saved_nodes += other.memo_saved_nodes;
        for i in 0..NUM_ACTIVITIES {
            self.activity[i] += other.activity[i];
        }
        if other.sched_workers.len() > self.sched_workers.len() {
            self.sched_workers.resize(other.sched_workers.len(), WorkerCounters::default());
        }
        for (i, c) in other.sched_workers.iter().enumerate() {
            self.sched_workers[i].accumulate(c);
        }
    }
}

/// Result of an engine run over the residual graph.
#[derive(Debug, Clone)]
pub struct EngineOutcome {
    /// Best (residual-relative) cover size found, including the initial
    /// bound if never improved.
    pub best: u32,
    /// Whether the initial bound was improved.
    pub improved: bool,
    /// The assembled witness cover behind `best` (residual-graph ids),
    /// when [`EngineCfg::extract_witness`] was set and an improvement
    /// was found. Its length equals `best` except under PVC early stop,
    /// where it is a valid cover within the proven bound.
    pub witness: Option<Vec<u32>>,
    /// Counters.
    pub stats: EngineStats,
    /// True if the deadline fired before exhausting the search.
    pub timed_out: bool,
}

/// A component-local graph view: the induced CSR plus (when witness
/// extraction is on) the inverse of the induction's renumbering chain —
/// `back[local] = root-residual id`, pre-composed through every
/// enclosing view so a choice log can be written in root ids with one
/// lookup per covered vertex.
pub(crate) struct GraphView {
    pub(crate) graph: Graph,
    /// local id → root-residual id; empty when logging is off.
    back: Vec<u32>,
    /// Memo-cache tag: set when this view's component missed the cache
    /// and was registered for publication — the last view drop then
    /// offers the CSR buffers to the cache instead of the pool.
    memo: Option<ViewMemo>,
}

/// The memo registration riding on a [`GraphView`]: the component's
/// canonical fingerprint plus the owning job's cache handle.
pub(crate) struct ViewMemo {
    fp: u64,
    job: Arc<crate::solver::memo::JobMemo>,
}

/// One *owned* search-tree node. `deg` is the degree array of the node's
/// graph view — exactly the paper's stack-entry payload, sized to the
/// view (the root residual graph, or a component-local induced
/// subgraph). Under [`NodeRepr::Delta`] this is also the live frame a
/// worker descends with in place.
pub(crate) struct Node<T> {
    deg: Vec<T>,
    sol: u32,
    edges: u64,
    bounds: NonZeroBounds,
    ctx: u32,
    /// Component-local view this node's indices refer to; `None` ⇒ the
    /// shared root graph. Shared by every node descended from the same
    /// split component; the last one to retire recycles its buffers.
    view: Option<Arc<GraphView>>,
    /// Witness choice log: the vertices (root-residual ids) this node's
    /// lineage covered since its context root. Empty when extraction is
    /// off. Owned by the node, so it survives steals with it.
    log: Vec<u32>,
}

impl<T: DegElem> Node<T> {
    /// Payload bytes of this node's degree array.
    #[inline]
    pub(crate) fn payload_bytes(&self) -> u64 {
        (self.deg.len() * T::BYTES) as u64
    }
}

/// A queued search-tree node: either a self-contained owned payload, or
/// — under [`NodeRepr::Delta`] — a speculative right child represented
/// as a pinned parent frame plus its branch vertex.
pub(crate) enum NodePayload<T> {
    /// Full owned payload (always used for roots and split-component
    /// children; the only representation under [`NodeRepr::Owned`]).
    Owned(Node<T>),
    /// Delta right child: "on the pinned parent state, move `N(branch)`
    /// into the cover". Costs O(1) + its share of the pinned chain
    /// instead of an O(view) payload copy.
    Delta(DeltaNode<T>),
}

impl<T: DegElem> NodePayload<T> {
    /// Payload bytes of the queued representation (owned degree array,
    /// or the delta node's constant footprint).
    pub(crate) fn payload_bytes(&self) -> u64 {
        match self {
            NodePayload::Owned(n) => n.payload_bytes(),
            NodePayload::Delta(_) => std::mem::size_of::<DeltaNode<T>>() as u64,
        }
    }
}

/// A delta right child (see [`NodePayload::Delta`]).
pub(crate) struct DeltaNode<T> {
    /// The pinned parent frame: an immutable snapshot chain ending in an
    /// owned base. Shared with the producing worker's anchor stack, so a
    /// locally popped delta can be *undone* onto the live frame instead
    /// of materialized.
    parent: Arc<FrameState<T>>,
    /// Branch vertex: the child covers every present neighbor of it.
    branch: u32,
    /// Cover size after applying the branch — lets a popper prune
    /// against the current bound *before* paying for materialization.
    sol_after: u32,
    /// Registry context (same as the parent frame's spine).
    ctx: u32,
    /// Graph view of the parent frame.
    view: Option<Arc<GraphView>>,
}

/// An immutable pinned frame: either a full owned snapshot of a branch
/// point (`Base`), or a chain link recording the covered-vertex delta
/// from its parent frame (`Link`). Thieves materialize a delta node by
/// copying the base onto a pooled buffer and replaying every suffix
/// outward; `depth` bounds that replay (see
/// [`EngineCfg::max_pin_depth`]). Buffers are recycled through the
/// worker pools when the last `Arc` holder drops a chain — the frame
/// refcount is what decides recycle eligibility.
pub(crate) struct FrameState<T> {
    /// Chain length to the owned base (`Base` = 0).
    depth: u32,
    link: FrameLink<T>,
}

enum FrameLink<T> {
    /// Owned snapshot of the frame at a branch point. `log` is the
    /// witness choice-log prefix (root-residual ids; empty when
    /// extraction is off) — delta descendants share it instead of each
    /// owning a copy.
    Base { deg: Vec<T>, sol: u32, edges: u64, bounds: NonZeroBounds, log: Vec<u32> },
    /// Covered-vertex delta from `parent` (view-local ids, in cover
    /// order) — exactly the PR-4 choice-log format, replayable forward.
    Link { parent: Arc<FrameState<T>>, suffix: Vec<u32> },
}

/// Tag bit marking an undo-journal entry as "neighbor zeroed by this
/// cover" (vs the covered vertex itself, which ends each op). View-local
/// vertex ids stay below this bit for any graph the engine can hold.
const UNDO_TAG: u32 = 1 << 31;

/// One worker-local descent: the live in-place frame, the reversible
/// cover journal, and the anchor stack of frozen branch points. The
/// journal records every cover applied to the frame (tagged entries
/// remember neighbors that hit degree zero, which backward replay could
/// not otherwise distinguish from already-covered ones); anchors pin the
/// `Arc` frame chain so a locally popped delta child can be matched by
/// pointer identity and undone instead of materialized.
pub(crate) struct Descent<T> {
    node: Node<T>,
    journal: Vec<u32>,
    anchors: Vec<Anchor<T>>,
    /// Whether covers on this frame are journaled (delta mode).
    track: bool,
}

/// A frozen branch point of a descent.
struct Anchor<T> {
    state: Arc<FrameState<T>>,
    /// Journal length at the freeze — undo target position.
    jpos: usize,
    sol: u32,
    edges: u64,
    bounds: NonZeroBounds,
    /// Witness-log length at the freeze.
    log_len: usize,
}

impl<T: DegElem> Descent<T> {
    fn new(node: Node<T>, track: bool) -> Descent<T> {
        Descent { node, journal: Vec::new(), anchors: Vec::new(), track }
    }
}

/// The root node over a (residual) graph: full-width degree array, no
/// registry context, no component view. Shared by the one-shot runner
/// and the resident service's job-setup stage.
pub(crate) fn make_root<T: DegElem>(g: &Graph) -> Node<T> {
    Node {
        deg: crate::degree::initial_degrees::<T>(g),
        sol: 0,
        edges: g.num_edges() as u64,
        bounds: NonZeroBounds::full(g.num_vertices()),
        ctx: NONE,
        view: None,
        log: Vec::new(),
    }
}

/// Dtype-independent state of one search job: the registry, the global
/// best, the control flags, and the stats sink. Outlives any particular
/// worker; nodes of the job reference it while they execute. This is the
/// "job half" of the old monolithic engine state — the resident service
/// keeps one per submitted job, the one-shot runner keeps one per call.
pub(crate) struct JobCtl {
    pub(crate) cfg: JobCfg,
    pub(crate) registry: Registry,
    pub(crate) best: AtomicU32,
    /// The initial (exclusive) bound the search started from — the
    /// reference for `improved` and for the witnessed-stop gate.
    pub(crate) initial: AtomicU32,
    pub(crate) stop: AtomicBool,
    pub(crate) improved: AtomicBool,
    pub(crate) timed_out: AtomicBool,
    /// Live payload bytes across all workers (instrumented runs only).
    pub(crate) live_bytes: AtomicU64,
    /// High-water mark of `live_bytes` (instrumented runs only).
    pub(crate) peak_live_bytes: AtomicU64,
    /// Search-tree nodes expanded so far, published every 64 nodes by
    /// the inner descent loop — feeds `JobHandle::progress()` without a
    /// stats-sink lock on the hot path.
    pub(crate) nodes_expanded: AtomicU64,
    /// Memory-watchdog override: when set (soft-limit pressure), new
    /// right children use [`NodeRepr::Delta`] regardless of `cfg`.
    pub(crate) forced_delta: AtomicBool,
    pub(crate) stats_sink: Mutex<EngineStats>,
}

impl JobCtl {
    pub(crate) fn new(cfg: JobCfg, initial_best: u32) -> JobCtl {
        let mut registry = Registry::new(cfg.stop_on_improvement);
        if cfg.extract_witness {
            registry = registry.with_witnesses();
        }
        if let Some(m) = &cfg.memo {
            if m.publishes() {
                // Observe every child-slot fold: the memo decides
                // whether the folded value is the component's *exact*
                // MVC and queues it for publication (solver::memo docs).
                let m = Arc::clone(m);
                registry = registry.with_fold_observer(Box::new(
                    move |ctx, best, limit, cover| m.on_fold(ctx, best, limit, cover),
                ));
            }
        }
        JobCtl {
            registry,
            best: AtomicU32::new(initial_best),
            initial: AtomicU32::new(initial_best),
            stop: AtomicBool::new(false),
            improved: AtomicBool::new(false),
            timed_out: AtomicBool::new(false),
            live_bytes: AtomicU64::new(0),
            peak_live_bytes: AtomicU64::new(0),
            nodes_expanded: AtomicU64::new(0),
            forced_delta: AtomicBool::new(false),
            stats_sink: Mutex::new(EngineStats::default()),
            cfg,
        }
    }

    /// Effective node representation for a descent opening on an owned
    /// node of `width` vertices. Precedence, highest first: the memory
    /// watchdog's soft-pressure `forced_delta` override (the
    /// degradation ladder outranks autotuning), then the self-tuning
    /// controller's per-width-bucket choice (when the job carries a
    /// tune handle and the repr knob floats), then the configured repr.
    #[inline]
    pub(crate) fn repr_for(&self, width: usize) -> NodeRepr {
        if self.forced_delta.load(Ordering::Relaxed) {
            return NodeRepr::Delta;
        }
        match &self.cfg.tune {
            Some(t) => t.repr_for(width, self.cfg.node_repr),
            None => self.cfg.node_repr,
        }
    }

    /// Effective delta-chain length bound: the controller's tuned value
    /// when the knob floats, the configured one otherwise.
    #[inline]
    pub(crate) fn max_pin_depth(&self) -> u32 {
        match &self.cfg.tune {
            Some(t) => t.pin_depth(self.cfg.max_pin_depth),
            None => self.cfg.max_pin_depth,
        }
    }

    /// §IV-B induction gate for a component of `size` inside a view of
    /// `view_n` vertices: the controller's per-bucket threshold when
    /// the knob floats, the configured `induce_threshold` otherwise.
    #[inline]
    pub(crate) fn induce_gate(&self, size: u32, view_n: usize) -> bool {
        match &self.cfg.tune {
            Some(t) => t.induce_gate(size, view_n, self.cfg.induce_threshold),
            None => {
                self.cfg.induce_threshold > 0.0
                    && (size as f64) <= self.cfg.induce_threshold * view_n as f64
            }
        }
    }

    /// Prune bound for a node: global best at the root, `min(Best,
    /// Limit)` inside a component context.
    #[inline]
    pub(crate) fn bound_of(&self, ctx: u32) -> u32 {
        if ctx == NONE {
            self.best.load(Ordering::SeqCst)
        } else {
            self.registry.bound(ctx)
        }
    }

    /// Record an achievable root-level total. Under PVC semantics this
    /// latches the stop flag on improvement; with witness extraction on,
    /// the stop additionally waits for an *assembled* root witness
    /// within the bound (est-propagated totals tighten `best` but carry
    /// no cover — see the registry module docs), so a stopped search can
    /// always hand back a verifiable cover.
    pub(crate) fn on_root_total(&self, total: u32) {
        if cas_min(&self.best, total).is_some() {
            self.improved.store(true, Ordering::SeqCst);
        }
        if self.cfg.stop_on_improvement
            && self.best.load(Ordering::SeqCst) < self.initial.load(Ordering::SeqCst)
        {
            let witnessed = !self.cfg.extract_witness
                || self
                    .registry
                    .root_witness_len()
                    .is_some_and(|l| (l as u32) < self.initial.load(Ordering::SeqCst));
            if witnessed {
                self.stop.store(true, Ordering::SeqCst);
            }
        }
    }

    /// Remaining budget for a new component: the enclosing context bound
    /// minus what the split has already committed (`Sum` so far).
    fn bound_of_parent(&self, node_ctx: u32, parent: u32) -> u32 {
        let ctx_bound = self.bound_of(node_ctx);
        let (sum_now, _, _, _) = self.registry.snapshot(parent);
        ctx_bound.saturating_sub(sum_now)
    }

    /// Check this job's deadline; on expiry latch `timed_out` and `stop`.
    /// Returns true if the job is past its deadline.
    pub(crate) fn check_deadline(&self) -> bool {
        if let Some(d) = self.cfg.deadline {
            if Instant::now() >= d {
                // Poison the memo before raising stop: workers that see
                // the stop mid-descent complete truncated subtrees whose
                // folds must not be published (solver::memo docs).
                if let Some(m) = &self.cfg.memo {
                    m.poison();
                }
                self.timed_out.store(true, Ordering::SeqCst);
                self.stop.store(true, Ordering::SeqCst);
                return true;
            }
        }
        false
    }
}

/// A worker's borrowed view of one job: the job's root graph plus its
/// control block. Cheap to construct per node, so the resident pool can
/// interleave nodes of different jobs on the same worker.
#[derive(Clone, Copy)]
pub(crate) struct JobView<'g> {
    pub(crate) g: &'g Graph,
    pub(crate) ctl: &'g JobCtl,
}

/// Number of size classes in a [`BufferPool`] (capacities up to 2^27
/// elements; anything larger falls into the last class).
const POOL_CLASSES: usize = 28;
/// Retained buffers per size class — bounds worst-case pool memory.
const POOL_PER_CLASS: usize = 32;
/// Delta mode: suspended descents kept per worker (each holds one
/// view-sized live frame + journal so its queued delta children can
/// still take the undo fast path after e.g. a component split).
const MAX_SUSPENDED_DESCENTS: usize = 6;

/// Per-worker size-classed free list of node payload buffers.
///
/// Class `c` holds buffers with capacity in `[2^c, 2^{c+1})`, so an
/// acquire for `len` entries (served from the ceil class of `len`)
/// always pops a buffer that fits. Returned buffers are *cleared*, never
/// zero-filled wholesale: callers rebuild contents (`extend_from_slice`
/// / `resize`), which is both the safety argument (no stale degrees can
/// leak between nodes) and the perf win (no redundant memset before a
/// full overwrite).
struct BufferPool<T> {
    classes: Vec<Vec<Vec<T>>>,
    hits: u64,
    misses: u64,
}

impl<T> BufferPool<T> {
    fn new() -> Self {
        BufferPool {
            classes: (0..POOL_CLASSES).map(|_| Vec::new()).collect(),
            hits: 0,
            misses: 0,
        }
    }

    /// Ceil size class serving requests of `len`.
    #[inline]
    fn class_for_len(len: usize) -> usize {
        (len.max(1).next_power_of_two().trailing_zeros() as usize).min(POOL_CLASSES - 1)
    }

    /// An empty buffer with capacity ≥ `len`, recycled when possible.
    fn acquire(&mut self, len: usize) -> Vec<T> {
        let c = Self::class_for_len(len);
        // In the (clamped) last class capacities vary; scan for a fit.
        // Every buffer in an unclamped class fits, so this is index 0.
        if let Some(pos) = self.classes[c].iter().position(|b| b.capacity() >= len) {
            let mut buf = self.classes[c].swap_remove(pos);
            buf.clear();
            self.hits += 1;
            return buf;
        }
        self.misses += 1;
        Vec::with_capacity(len.max(1).next_power_of_two())
    }

    /// Return a no-longer-needed buffer to its (floor) size class.
    fn release(&mut self, buf: Vec<T>) {
        let cap = buf.capacity();
        if cap == 0 {
            return;
        }
        let c = ((usize::BITS - 1 - cap.leading_zeros()) as usize).min(POOL_CLASSES - 1);
        if self.classes[c].len() < POOL_PER_CLASS {
            self.classes[c].push(buf);
        }
    }
}

/// Per-worker scratch: BFS stamps, induction maps, recycling pools, and
/// locally-accumulated stats. One-shot runs keep one per spawned thread;
/// the resident pool keeps one per worker per dtype and grows the
/// graph-sized scratch ([`WorkerCtx::ensure_graph`]) to the largest job
/// seen.
pub(crate) struct WorkerCtx<T> {
    worker: usize,
    /// Seeding mode (no-load-balance): children go to this FIFO frontier
    /// instead of the scheduler.
    frontier: Option<std::collections::VecDeque<NodePayload<T>>>,
    /// Delta mode: the current descent (last entry) plus suspended ones
    /// whose queued delta children may still surface locally. Capped at
    /// [`MAX_SUSPENDED_DESCENTS`]; an evicted descent only costs later
    /// deltas a materialization, never correctness.
    descents: Vec<Descent<T>>,
    /// BFS scratch: visit stamps (avoids clearing between searches).
    visit: Vec<u32>,
    stamp: u32,
    queue: Vec<u32>,
    nbuf: Vec<u32>,
    /// view-id → component-local id scratch for subproblem induction
    /// (entries are only read for the component just written).
    vmap: Vec<u32>,
    /// Recycled degree-array payloads.
    pool: BufferPool<T>,
    /// Recycled u32 buffers for induced-CSR `row_ptr`/`adj` arrays.
    upool: BufferPool<u32>,
    stats: EngineStats,
    /// Self-tuning observation scratch (per-width-bucket node/byte
    /// counts), drained into the job's controller blackboard at stats
    /// flush. Written only when the job carries a tune handle.
    tune_obs: crate::solver::autotune::TuneObs,
    /// Pool counter values already drained into `stats` (the pools keep
    /// cumulative totals across jobs; flushes record deltas).
    flushed_pool_hits: u64,
    flushed_pool_misses: u64,
    /// `stats.tree_nodes` already published to `JobCtl::nodes_expanded`
    /// (progress snapshots); reset with `stats` at every flush.
    published_nodes: u64,
    timer: ActivityTimer,
    deadline_tick: u32,
}

impl<T: DegElem> WorkerCtx<T> {
    pub(crate) fn new(worker: usize, n: usize, instrument: bool) -> Self {
        WorkerCtx {
            worker,
            frontier: None,
            descents: Vec::new(),
            visit: vec![0; n],
            stamp: 0,
            queue: Vec::new(),
            nbuf: Vec::new(),
            vmap: vec![0; n],
            pool: BufferPool::new(),
            upool: BufferPool::new(),
            stats: EngineStats::default(),
            tune_obs: crate::solver::autotune::TuneObs::default(),
            flushed_pool_hits: 0,
            flushed_pool_misses: 0,
            published_nodes: 0,
            timer: if instrument { ActivityTimer::enabled() } else { ActivityTimer::disabled() },
            deadline_tick: 0,
        }
    }

    /// Grow the graph-sized scratch (visit stamps / induction map) to
    /// hold a view of `n` vertices. New entries are unvisited (stamp 0 is
    /// never a live stamp), so resizing between jobs is safe.
    pub(crate) fn ensure_graph(&mut self, n: usize) {
        if self.visit.len() < n {
            self.visit.resize(n, 0);
            self.vmap.resize(n, 0);
        }
    }

    /// Drop every suspended descent, recycling its buffers into the
    /// worker pools. Resident workers call this on idle transitions: an
    /// idle worker found nothing in its own queue, the shared queue, or
    /// any victim, so no queued item can still match its anchors — its
    /// suspended frames are unreachable undo caches (stolen deltas
    /// materialize at the thief and never come back). Pure pool
    /// recycling, no live-byte accounting: resident jobs never run
    /// instrumented, and one-shot runs retire through
    /// [`retire_descent`] instead.
    pub(crate) fn drain_descents(&mut self) {
        while let Some(d) = self.descents.pop() {
            let Descent { mut node, journal, anchors, .. } = d;
            self.upool.release(journal);
            for a in anchors {
                release_chain_buffers(self, a.state);
            }
            self.pool.release(std::mem::take(&mut node.deg));
            self.upool.release(std::mem::take(&mut node.log));
            recycle_view_buffers(self, node.view.take());
        }
    }

    /// Drain the locally-accumulated stats (plus the pool-counter deltas
    /// since the last flush) into a job's stats sink and reset them, so
    /// a resident worker can charge each processed node to the job it
    /// belongs to.
    pub(crate) fn flush_stats_into(&mut self, ctl: &JobCtl) {
        let hits = self.pool.hits + self.upool.hits;
        let misses = self.pool.misses + self.upool.misses;
        self.stats.pool_hits += hits - self.flushed_pool_hits;
        self.stats.pool_misses += misses - self.flushed_pool_misses;
        self.flushed_pool_hits = hits;
        self.flushed_pool_misses = misses;
        if let Some(t) = &ctl.cfg.tune {
            // Per-item deltas: `stats` is reset below, so the globals it
            // carries (undo/materialize traffic) are since the last flush.
            t.shared.absorb(&mut self.tune_obs, &self.stats);
        }
        ctl.nodes_expanded
            .fetch_add(self.stats.tree_nodes - self.published_nodes, Ordering::Relaxed);
        ctl.stats_sink.lock().unwrap().merge(&self.stats);
        self.stats = EngineStats::default();
        self.published_nodes = 0;
    }

    /// Flush this worker's timer, pool, and scheduler counters into its
    /// stats and merge them into the job's sink (one-shot teardown).
    fn finish(mut self, ctl: &JobCtl, counters: WorkerCounters) {
        self.timer.stop();
        self.stats.activity = self.timer.totals();
        self.stats.max_stack_depth = self.stats.max_stack_depth.max(counters.max_depth);
        self.stats.pool_hits += self.pool.hits + self.upool.hits - self.flushed_pool_hits;
        self.stats.pool_misses +=
            self.pool.misses + self.upool.misses - self.flushed_pool_misses;
        let mut per_worker = vec![WorkerCounters::default(); self.worker + 1];
        per_worker[self.worker] = counters;
        self.stats.sched_workers = per_worker;
        ctl.stats_sink.lock().unwrap().merge(&self.stats);
    }
}

/// Run the engine on the (already root-reduced, induced) graph.
///
/// `initial_best` is the residual-relative upper bound (greedy bound
/// minus root-forced vertices for MVC; `k + 1` for PVC). Returns the best
/// value found (`== initial_best` if not improved).
pub fn run<T: DegElem>(g: &Graph, initial_best: u32, cfg: EngineCfg) -> EngineOutcome {
    let workers = cfg.workers.max(1);
    match cfg.scheduler {
        SchedulerKind::WorkSteal => {
            let sched: WorkStealScheduler<NodePayload<T>> =
                WorkStealScheduler::new(workers, cfg.load_balance, cfg.queue_capacity.max(8));
            run_with(g, initial_best, cfg, &sched)
        }
        SchedulerKind::Sharded => {
            let sched: ShardedScheduler<NodePayload<T>> =
                ShardedScheduler::new(workers, cfg.load_balance, cfg.queue_capacity.max(8));
            run_with(g, initial_best, cfg, &sched)
        }
    }
}

fn run_with<T: DegElem, S: Scheduler<NodePayload<T>>>(
    g: &Graph,
    initial_best: u32,
    cfg: EngineCfg,
    sched: &S,
) -> EngineOutcome {
    let n = g.num_vertices();
    let workers = cfg.workers.max(1);
    let ctl = JobCtl::new(cfg.job_cfg(), initial_best);
    let shared = JobView { g, ctl: &ctl };

    // Root node over the full residual graph.
    let root = make_root::<T>(g);
    let root_bytes = root.payload_bytes();
    if cfg.instrument {
        ctl.live_bytes.store(root_bytes, Ordering::Relaxed);
        ctl.peak_live_bytes.store(root_bytes, Ordering::Relaxed);
    }

    if cfg.load_balance {
        sched.inject(NodePayload::Owned(root));
    } else {
        // Static seeding (prior works [3], [4]): expand a frontier of
        // sub-trees breadth-first, then give each worker a fixed share.
        let mut seeder = WorkerCtx::<T>::new(0, n, cfg.instrument);
        let mut seed_handle = sched.handle(0);
        seeder.frontier = Some(std::collections::VecDeque::new());
        seeder.frontier.as_mut().unwrap().push_back(NodePayload::Owned(root));
        let target = workers * 4;
        let mut processed = 0usize;
        while processed < 4096 {
            let Some(node) = seeder.frontier.as_mut().unwrap().pop_front() else { break };
            if seeder.frontier.as_ref().unwrap().len() + 1 >= target {
                seeder.frontier.as_mut().unwrap().push_front(node);
                break;
            }
            process(&shared, &mut seeder, &mut seed_handle, node, PopSource::Local);
            processed += 1;
            if ctl.stop.load(Ordering::SeqCst) {
                break;
            }
        }
        let frontier = seeder.frontier.take().unwrap();
        let seed_counters = seed_handle.counters();
        drop(seed_handle); // release worker 0's handle slot for the real worker
        seeder.finish(&ctl, seed_counters);
        for (i, node) in frontier.into_iter().enumerate() {
            sched.seed(i % workers, node);
        }
    }

    std::thread::scope(|s| {
        for worker in 0..workers {
            let shared = shared;
            s.spawn(move || {
                let mut ctx = WorkerCtx::<T>::new(worker, n, shared.ctl.cfg.instrument);
                let mut handle = sched.handle(worker);
                worker_loop(&shared, &mut ctx, &mut handle);
                let counters = handle.counters();
                drop(handle);
                ctx.finish(shared.ctl, counters);
            });
        }
    });

    let timed_out = ctl.timed_out.load(Ordering::SeqCst);
    if cfg!(debug_assertions) && !timed_out && !ctl.stop.load(Ordering::SeqCst) {
        ctl.registry.assert_drained();
    }
    let best = ctl.best.load(Ordering::SeqCst);
    let improved = ctl.improved.load(Ordering::SeqCst);
    let witness = ctl.registry.take_root_witness();
    let peak = ctl.peak_live_bytes.load(Ordering::Relaxed);
    let registry_len = ctl.registry.len() as u64;
    let mut stats = ctl.stats_sink.into_inner().unwrap();
    stats.worklist_pushes = stats.sched_workers.iter().map(|c| c.offloaded).sum();
    stats.worklist_steals = stats.sched_workers.iter().map(|c| c.steals).sum();
    stats.registry_entries = registry_len;
    // The root payload was created outside any worker context.
    stats.payload_nodes += 1;
    stats.payload_bytes += root_bytes;
    stats.peak_live_bytes = stats.peak_live_bytes.max(peak);
    EngineOutcome { best, improved, witness, stats, timed_out }
}

fn worker_loop<T: DegElem, H: WorkerHandle<NodePayload<T>>>(
    shared: &JobView<'_>,
    ctx: &mut WorkerCtx<T>,
    handle: &mut H,
) {
    loop {
        if shared.ctl.stop.load(Ordering::Relaxed) {
            break;
        }
        ctx.timer.switch(Activity::Queue);
        match handle.pop_traced() {
            Some((node, src)) => {
                process(shared, ctx, handle, node, src);
                handle.on_node_done();
                check_deadline(shared, ctx);
            }
            None => {
                ctx.timer.switch(Activity::Idle);
                if let IdleOutcome::Finished = handle.idle_step() {
                    break;
                }
                check_deadline(shared, ctx);
            }
        }
    }
    // Delta mode keeps live frames across pops; hand their buffers back
    // to the pools (and recycle last-holder views) on the way out.
    while let Some(d) = ctx.descents.pop() {
        retire_descent(shared, ctx, d);
    }
}

#[inline]
fn check_deadline<T: DegElem>(shared: &JobView<'_>, ctx: &mut WorkerCtx<T>) {
    ctx.deadline_tick = ctx.deadline_tick.wrapping_add(1);
    if ctx.deadline_tick % 64 != 0 {
        return;
    }
    shared.ctl.check_deadline();
}

/// Record a node payload coming live (per-node byte accounting; peak
/// tracking only on instrumented runs to keep atomics off the hot path).
#[inline]
fn track_alloc<T: DegElem>(shared: &JobView<'_>, ctx: &mut WorkerCtx<T>, len: usize) {
    let bytes = (len * T::BYTES) as u64;
    ctx.stats.payload_nodes += 1;
    ctx.stats.payload_bytes += bytes;
    if shared.ctl.cfg.tune.is_some() {
        ctx.tune_obs.note_owned(len, bytes);
    }
    if let Some(f) = &shared.ctl.cfg.fault {
        f.on_alloc();
    }
    if shared.ctl.cfg.instrument {
        let live = shared.ctl.live_bytes.fetch_add(bytes, Ordering::Relaxed) + bytes;
        shared.ctl.peak_live_bytes.fetch_max(live, Ordering::Relaxed);
    }
}

/// Count and recycle a retired witness log through the worker's u32
/// pool. The log's length at retirement is its high-water mark, so the
/// byte counter reflects what extraction actually materialized.
fn release_log<T: DegElem>(ctx: &mut WorkerCtx<T>, log: Vec<u32>) {
    if log.capacity() == 0 {
        return;
    }
    ctx.stats.witness_log_bytes += (log.len() * std::mem::size_of::<u32>()) as u64;
    ctx.stats.logs_recycled += 1;
    ctx.upool.release(log);
}

/// Recycle a completed node's payload (degree array + witness log) into
/// the worker pools and hand its view `Arc` back so the caller can
/// retire the CSR buffers once its own borrow of the view is gone (see
/// [`process`]).
fn retire_node<T: DegElem>(
    shared: &JobView<'_>,
    ctx: &mut WorkerCtx<T>,
    mut node: Node<T>,
) -> Option<Arc<GraphView>> {
    if shared.ctl.cfg.instrument {
        shared.ctl.live_bytes.fetch_sub(node.payload_bytes(), Ordering::Relaxed);
    }
    ctx.pool.release(std::mem::take(&mut node.deg));
    release_log(ctx, std::mem::take(&mut node.log));
    node.view.take()
}

/// Process one queued work item (see [`NodePayload`]): owned nodes open
/// a new descent; delta nodes are pruned without reconstruction, undone
/// onto a matching live frame (reverse journal replay — the deep
/// local-pop fast path), or materialized into an owned frame (the
/// thief-side half of speculative in-place branching).
pub(crate) fn process<T: DegElem, H: WorkerHandle<NodePayload<T>>>(
    shared: &JobView<'_>,
    ctx: &mut WorkerCtx<T>,
    handle: &mut H,
    item: NodePayload<T>,
    src: PopSource,
) {
    match item {
        NodePayload::Owned(node) => {
            let track = ctx.frontier.is_none()
                && shared.ctl.repr_for(node.deg.len()) == NodeRepr::Delta;
            let mut d = Descent::new(node, track);
            if track {
                d.journal = ctx.upool.acquire(64);
            }
            drive(shared, ctx, handle, d);
        }
        NodePayload::Delta(dn) => {
            // Prune against the current bound before paying for any
            // state reconstruction (mirrors the owned right child's
            // stopping condition; registry completion must still run).
            let bound = shared.ctl.bound_of(dn.ctx);
            if dn.sol_after >= bound {
                ctx.stats.tree_nodes += 1;
                let c = dn.ctx;
                release_delta(shared, ctx, dn);
                complete(shared.ctl, c);
                return;
            }
            // Stolen nodes can never pin this worker's live descents;
            // locally (or shared-queue) popped ones are matched by frame
            // pointer identity for the undo fast path.
            let resume = if src == PopSource::Stolen {
                None
            } else {
                find_anchor(&ctx.descents, &dn.parent)
            };
            match resume {
                Some((di, ai)) => {
                    // Resume the matched descent and keep the others
                    // suspended: the sharded runtime's offload + fairness
                    // poll can surface a worker's own deltas out of LIFO
                    // order, so descents above the match may still have
                    // resumable children queued locally. Unreachable
                    // frames are bounded by the suspension cap and
                    // reclaimed on eviction or idle.
                    let mut d = ctx.descents.remove(di);
                    resume_delta(shared, ctx, handle, &mut d, ai, dn);
                    ctx.descents.push(d);
                }
                None => {
                    let d = materialize(shared, ctx, dn);
                    drive(shared, ctx, handle, d);
                }
            }
        }
    }
}

/// Run [`descend`] over a descent, then either retire it (owned repr) or
/// keep it as the worker's live frame so queued delta children can be
/// undone onto it (delta repr; bounded suspended stack).
fn drive<T: DegElem, H: WorkerHandle<NodePayload<T>>>(
    shared: &JobView<'_>,
    ctx: &mut WorkerCtx<T>,
    handle: &mut H,
    mut d: Descent<T>,
) {
    // Hold one temporary view reference so `g` stays valid while the
    // frame and its children move around.
    let view = d.node.view.clone();
    {
        let g: &Graph = view.as_ref().map(|v| &v.graph).unwrap_or(shared.g);
        descend(shared, g, ctx, handle, &mut d);
    }
    drop(view);
    if d.track && !shared.ctl.stop.load(Ordering::Relaxed) {
        if ctx.descents.len() >= MAX_SUSPENDED_DESCENTS {
            let old = ctx.descents.remove(0);
            retire_descent(shared, ctx, old);
        }
        ctx.descents.push(d);
    } else {
        retire_descent(shared, ctx, d);
    }
}

/// Consume a locally surfaced delta child on the undo fast path: rewind
/// the live frame to the pinned anchor by reverse journal replay, apply
/// the right branch in place, and continue descending — zero payload
/// copies on the overwhelmingly common local-pop case.
fn resume_delta<T: DegElem, H: WorkerHandle<NodePayload<T>>>(
    shared: &JobView<'_>,
    ctx: &mut WorkerCtx<T>,
    handle: &mut H,
    d: &mut Descent<T>,
    ai: usize,
    dn: DeltaNode<T>,
) {
    let view = d.node.view.clone();
    {
        let g: &Graph = view.as_ref().map(|v| &v.graph).unwrap_or(shared.g);
        undo_to_anchor(shared, g, ctx, d, ai);
        debug_assert_eq!(d.node.ctx, dn.ctx, "delta child crossed registry contexts");
        apply_branch(shared, g, ctx, d, dn.branch);
        debug_assert_eq!(d.node.sol, dn.sol_after, "undo replay out of sync with branch");
        // The matched anchor still pins `dn`'s chain (and the frame its
        // view), so this drop never recycles — it only releases the
        // child's own references.
        drop(dn);
        descend(shared, g, ctx, handle, d);
    }
    drop(view);
}

/// Locate the anchor a delta child's pinned frame points at, searching
/// the current descent first (pure LIFO pops match its top anchor), then
/// suspended ones.
fn find_anchor<T>(descents: &[Descent<T>], parent: &Arc<FrameState<T>>) -> Option<(usize, usize)> {
    for (di, d) in descents.iter().enumerate().rev() {
        for (ai, a) in d.anchors.iter().enumerate().rev() {
            if Arc::ptr_eq(&a.state, parent) {
                return Some((di, ai));
            }
        }
    }
    None
}

/// Rewind the live frame to anchor `ai`: pop journal entries above the
/// anchor, reverting each cover (neighbors with a positive degree were
/// present pre-cover and get re-incremented; tagged entries name the
/// neighbors this cover zeroed, which backward replay could not
/// otherwise tell apart from already-covered ones), then restore the
/// anchor's scalars and truncate the witness log to its prefix.
fn undo_to_anchor<T: DegElem>(
    shared: &JobView<'_>,
    g: &Graph,
    ctx: &mut WorkerCtx<T>,
    d: &mut Descent<T>,
    ai: usize,
) {
    while d.anchors.len() > ai + 1 {
        let a = d.anchors.pop().expect("anchors above the target");
        release_frame_chain(shared, ctx, a.state);
    }
    let a = d.anchors.last().expect("undo target anchor");
    let jpos = a.jpos;
    let (sol, edges, bounds, log_len) = (a.sol, a.edges, a.bounds, a.log_len);
    ctx.stats.undo_pops += 1;
    while d.journal.len() > jpos {
        let v = d.journal.pop().expect("journal entry");
        debug_assert_eq!(v & UNDO_TAG, 0, "cover ops end with the covered vertex");
        let mut cnt = 0u32;
        for &w in g.neighbors(v) {
            let dw = d.node.deg[w as usize].to_u32();
            if dw > 0 {
                d.node.deg[w as usize] = T::from_u32(dw + 1);
                cnt += 1;
            }
        }
        while d.journal.len() > jpos && d.journal.last().is_some_and(|&e| e & UNDO_TAG != 0) {
            let w = d.journal.pop().expect("tagged entry") & !UNDO_TAG;
            d.node.deg[w as usize] = T::from_u32(1);
            cnt += 1;
        }
        d.node.deg[v as usize] = T::from_u32(cnt);
        ctx.stats.undo_covers += 1;
    }
    d.node.sol = sol;
    d.node.edges = edges;
    d.node.bounds = bounds;
    d.node.log.truncate(log_len);
}

/// Apply a delta child's right branch onto the live frame: move every
/// present neighbor of `branch` into the cover (journaled + witness-
/// logged), exactly what [`make_right_child`] bakes into an owned copy.
fn apply_branch<T: DegElem>(
    shared: &JobView<'_>,
    g: &Graph,
    ctx: &mut WorkerCtx<T>,
    d: &mut Descent<T>,
    branch: u32,
) {
    let extract = shared.ctl.cfg.extract_witness;
    ctx.nbuf.clear();
    ctx.nbuf.extend(
        g.neighbors(branch).iter().copied().filter(|&w| d.node.deg[w as usize].to_u32() > 0),
    );
    for &u in &ctx.nbuf {
        if d.node.deg[u as usize].to_u32() > 0 {
            cover_vertex_tracked(g, &mut d.node, Some(&mut d.journal), u);
            log_cover(&mut d.node, u, extract);
            d.node.sol += 1;
        }
    }
    debug_assert_eq!(d.node.deg[branch as usize].to_u32(), 0);
}

/// Materialize a delta child whose pinned frame is not this worker's
/// live descent (it was stolen, or the producer moved on): copy the
/// chain's owned base onto a pooled buffer, replay every suffix outward
/// (recompute-over-copy), then apply the branch. The new descent anchors
/// directly on the pinned chain, so the thief's own first branch links
/// instead of freezing another base.
fn materialize<T: DegElem>(
    shared: &JobView<'_>,
    ctx: &mut WorkerCtx<T>,
    dn: DeltaNode<T>,
) -> Descent<T> {
    ctx.stats.materializations += 1;
    let DeltaNode { parent, branch, sol_after, ctx: rctx, view } = dn;
    let extract = shared.ctl.cfg.extract_witness;
    let gview = view.clone();

    // Walk to the owned base, keeping the links for forward replay.
    let mut links: Vec<&FrameState<T>> = Vec::new();
    let mut cur: &FrameState<T> = parent.as_ref();
    loop {
        links.push(cur);
        match &cur.link {
            FrameLink::Base { .. } => break,
            FrameLink::Link { parent, .. } => cur = parent.as_ref(),
        }
    }
    let FrameLink::Base { deg: bdeg, sol, edges, bounds, log: blog } =
        &links.last().expect("chain has a base").link
    else {
        unreachable!("chain walk ends at the base")
    };
    let mut deg = ctx.pool.acquire(bdeg.len());
    deg.extend_from_slice(bdeg);
    let log = if extract {
        let mut log = ctx.upool.acquire(blog.len());
        log.extend_from_slice(blog);
        log
    } else {
        Vec::new()
    };
    if shared.ctl.cfg.instrument {
        let bytes = (deg.len() * T::BYTES) as u64;
        let live = shared.ctl.live_bytes.fetch_add(bytes, Ordering::Relaxed) + bytes;
        shared.ctl.peak_live_bytes.fetch_max(live, Ordering::Relaxed);
    }
    let node = Node {
        deg,
        sol: *sol,
        edges: *edges,
        bounds: *bounds,
        ctx: rctx,
        view,
        log,
    };
    let mut d = Descent { node, journal: ctx.upool.acquire(64), anchors: Vec::new(), track: true };
    {
        let g: &Graph = gview.as_ref().map(|v| &v.graph).unwrap_or(shared.g);
        for fs in links.iter().rev() {
            if let FrameLink::Link { suffix, .. } = &fs.link {
                for &v in suffix.iter() {
                    cover_vertex(g, &mut d.node, v);
                    log_cover(&mut d.node, v, extract);
                    d.node.sol += 1;
                    ctx.stats.replayed_covers += 1;
                }
            }
        }
        drop(links);
        // Anchor on the pinned chain tip (= the reconstructed state), so
        // deeper branches of this descent extend the shared chain.
        d.anchors.push(Anchor {
            state: Arc::clone(&parent),
            jpos: 0,
            sol: d.node.sol,
            edges: d.node.edges,
            bounds: d.node.bounds,
            log_len: d.node.log.len(),
        });
        apply_branch(shared, g, ctx, &mut d, branch);
        debug_assert_eq!(d.node.sol, sol_after, "materialized replay out of sync");
    }
    drop(gview);
    drop(parent);
    d
}

/// Accounting-free core of [`release_frame_chain`]: recycle the buffers
/// of every chain segment this worker holds the last reference to (the
/// refcount decides eligibility, so chains shared with queued delta
/// children or other descents are left intact and the eventual last
/// holder recycles them). Returns the bytes released so callers with a
/// job context can settle the live-byte accounting.
fn release_chain_buffers<T: DegElem>(
    ctx: &mut WorkerCtx<T>,
    mut state: Arc<FrameState<T>>,
) -> u64 {
    let mut bytes = 0u64;
    loop {
        let Some(fs) = Arc::into_inner(state) else { return bytes };
        match fs.link {
            FrameLink::Base { deg, log, .. } => {
                bytes += (deg.len() * T::BYTES + log.len() * 4) as u64;
                ctx.pool.release(deg);
                ctx.upool.release(log);
                return bytes;
            }
            FrameLink::Link { parent, suffix } => {
                bytes += (suffix.len() * 4) as u64;
                ctx.upool.release(suffix);
                state = parent;
            }
        }
    }
}

/// [`release_chain_buffers`] plus instrumented live-byte settlement.
fn release_frame_chain<T: DegElem>(
    shared: &JobView<'_>,
    ctx: &mut WorkerCtx<T>,
    state: Arc<FrameState<T>>,
) {
    let bytes = release_chain_buffers(ctx, state);
    if shared.ctl.cfg.instrument && bytes > 0 {
        shared.ctl.live_bytes.fetch_sub(bytes, Ordering::Relaxed);
    }
}

/// Release a delta child without running it (pruned, or dropped on a
/// stopped job): chain + view go back through the recycling paths.
fn release_delta<T: DegElem>(shared: &JobView<'_>, ctx: &mut WorkerCtx<T>, dn: DeltaNode<T>) {
    let DeltaNode { parent, view, .. } = dn;
    release_frame_chain(shared, ctx, parent);
    recycle_view(shared, ctx, view);
}

/// Retire a whole descent: journal and anchor chains back to the pools,
/// then the live frame itself (payload, witness log, view).
///
/// On a resident pool this can run while the worker is processing a
/// *different* job's item (suspended-descent eviction), in which case
/// the retirement telemetry (log bytes, pool traffic) is charged to the
/// job currently being processed — a bounded cross-job smear on those
/// counters only; correctness counters (tree nodes, materializations,
/// undo/replay) are always attributed at processing time.
fn retire_descent<T: DegElem>(shared: &JobView<'_>, ctx: &mut WorkerCtx<T>, d: Descent<T>) {
    let Descent { node, journal, anchors, .. } = d;
    ctx.upool.release(journal);
    for a in anchors {
        release_frame_chain(shared, ctx, a.state);
    }
    let view = retire_node(shared, ctx, node);
    recycle_view(shared, ctx, view);
}

/// Accounting-free core of [`recycle_view`]: recycle a component view's
/// CSR buffers if this was the last holder, returning the bytes
/// released. `Arc::into_inner` (not `try_unwrap`) so that when two
/// workers race to retire the last nodes of a view, exactly one of them
/// receives it — the buffers are always recycled and the live-bytes
/// decrement can never be lost to the race.
fn recycle_view_buffers<T: DegElem>(
    ctx: &mut WorkerCtx<T>,
    view: Option<Arc<GraphView>>,
) -> u64 {
    let Some(v) = view else { return 0 };
    let Some(gv) = Arc::into_inner(v) else { return 0 };
    let GraphView { graph, back, memo } = gv;
    let (row_ptr, adj) = graph.into_parts();
    let bytes = view_bytes(&row_ptr, &adj, &back);
    match memo {
        // Memo-registered component: the fold (which happened-before
        // this last drop — the completing node held the view) may have
        // queued an exact result. Offer the CSR buffers to the cache as
        // the entry's verification key; they come back for pool
        // recycling only when the cache declines (satellite invariant:
        // buffers the cache took never return to the BufferPool).
        Some(vm) => {
            if let Some((rp, aj)) = vm.job.publish_at_recycle(vm.fp, row_ptr, adj, &back) {
                ctx.upool.release(rp);
                ctx.upool.release(aj);
            }
        }
        None => {
            ctx.upool.release(row_ptr);
            ctx.upool.release(adj);
        }
    }
    ctx.upool.release(back);
    bytes
}

/// [`recycle_view_buffers`] plus instrumented live-byte settlement.
fn recycle_view<T: DegElem>(
    shared: &JobView<'_>,
    ctx: &mut WorkerCtx<T>,
    view: Option<Arc<GraphView>>,
) {
    let bytes = recycle_view_buffers(ctx, view);
    if shared.ctl.cfg.instrument && bytes > 0 {
        shared.ctl.live_bytes.fetch_sub(bytes, Ordering::Relaxed);
    }
}

/// Bytes of an induced view's CSR arrays plus its back map
/// (live-memory accounting).
#[inline]
fn view_bytes(row_ptr: &[u32], adj: &[u32], back: &[u32]) -> u64 {
    ((row_ptr.len() + adj.len() + back.len()) * std::mem::size_of::<u32>()) as u64
}

/// The branch-and-reduce descent over one live frame (Alg. 2). `g` is
/// the frame's graph view; every vertex id in it is local to that view.
/// The frame is left in its terminal state — the caller retires it
/// (owned repr) or keeps it live for delta undo.
fn descend<T: DegElem, H: WorkerHandle<NodePayload<T>>>(
    shared: &JobView<'_>,
    g: &Graph,
    ctx: &mut WorkerCtx<T>,
    handle: &mut H,
    d: &mut Descent<T>,
) {
    let extract = shared.ctl.cfg.extract_witness;
    loop {
        ctx.stats.tree_nodes += 1;
        if shared.ctl.cfg.tune.is_some() {
            ctx.tune_obs.note_tree_node(d.node.deg.len());
        }
        if let Some(f) = &shared.ctl.cfg.fault {
            f.on_node();
        }

        // Stop flags (cancel / deadline) are otherwise only observed at
        // pop time, but this loop descends in place without popping —
        // under the delta representation a single worker can live here
        // for the whole search. Poll every 64 in-place nodes so
        // cancellation latency stays bounded by a few branch steps, not
        // by the depth of the descent. The same cadence publishes the
        // expanded-node count for `JobHandle::progress()`.
        if ctx.stats.tree_nodes & 63 == 0 {
            shared
                .ctl
                .nodes_expanded
                .fetch_add(ctx.stats.tree_nodes - ctx.published_nodes, Ordering::Relaxed);
            ctx.published_nodes = ctx.stats.tree_nodes;
            if shared.ctl.stop.load(Ordering::SeqCst) || shared.ctl.check_deadline() {
                complete(shared.ctl, d.node.ctx);
                return;
            }
        }

        // ---- reduce (Alg. 2 line 2) ----
        ctx.timer.switch(Activity::Reduce);
        let red = reduce_node(shared, g, d);

        // ---- stopping conditions (lines 3-4) ----
        ctx.timer.switch(Activity::Leaf);
        let bound = shared.ctl.bound_of(d.node.ctx);
        if d.node.sol >= bound {
            complete(shared.ctl, d.node.ctx);
            return;
        }
        let rem = (bound - d.node.sol - 1) as u64;
        if d.node.edges > rem * rem {
            complete(shared.ctl, d.node.ctx);
            return;
        }
        // ---- leaf (lines 5-7) ----
        if d.node.edges == 0 {
            report_leaf(shared.ctl, d.node.ctx, d.node.sol, &d.node.log);
            complete(shared.ctl, d.node.ctx);
            return;
        }

        // ---- component search (line 9) ----
        if shared.ctl.cfg.component_aware {
            ctx.timer.switch(Activity::ComponentSearch);
            match scan_components(g, ctx, &d.node, &red) {
                Scan::Single => {}
                Scan::SingleSpecial(sp) => {
                    ctx.stats.special_solved += 1;
                    let total = d.node.sol + sp.mvc_size();
                    if extract {
                        // the scan's BFS left the whole residual in
                        // ctx.queue; append its closed-form cover (a
                        // later undo truncates it back off the live log)
                        let cover = special_cover_root_ids(
                            g,
                            &ctx.queue,
                            &d.node.deg,
                            d.node.view.as_deref(),
                            sp,
                        );
                        d.node.log.extend_from_slice(&cover);
                    }
                    report_leaf(shared.ctl, d.node.ctx, total, &d.node.log);
                    complete(shared.ctl, d.node.ctx);
                    return;
                }
                Scan::Split { first_size, dmin, dmax } => {
                    if let Some(f) = &shared.ctl.cfg.fault {
                        f.on_split();
                    }
                    branch_on_components(shared, g, ctx, handle, d, first_size, dmin, dmax);
                    return;
                }
            }
        }

        // ---- single-component branch (lines 11-13) ----
        ctx.timer.switch(Activity::Branch);
        let vmax = red.vmax;
        debug_assert_eq!(vmax, max_degree_vertex(&d.node), "fused argmax out of sync");
        debug_assert_ne!(vmax, u32::MAX);

        // right child: N(vmax) into S — an owned payload copy, or a
        // pinned-frame delta under NodeRepr::Delta
        if d.track {
            let right = make_delta_child(shared, g, ctx, d, vmax);
            shared.ctl.registry.on_branch(d.node.ctx);
            push_child(ctx, handle, NodePayload::Delta(right));
        } else {
            let right = make_right_child(shared, g, ctx, &d.node, vmax);
            shared.ctl.registry.on_branch(d.node.ctx);
            push_child(ctx, handle, NodePayload::Owned(right));
        }

        // left child: vmax into S — descend in place
        let journal = d.track.then_some(&mut d.journal);
        cover_vertex_tracked(g, &mut d.node, journal, vmax);
        log_cover(&mut d.node, vmax, extract);
        d.node.sol += 1;
    }
}

/// Freeze the live frame's current state into an immutable pinned
/// [`FrameState`]: a cheap chain link carrying only the covered-vertex
/// suffix since the previous anchor, or — on the first branch of a
/// descent and every `max_pin_depth` links — a full owned base snapshot
/// that bounds later replay. Also pushes the matching anchor.
fn freeze_frame<T: DegElem>(
    shared: &JobView<'_>,
    ctx: &mut WorkerCtx<T>,
    d: &mut Descent<T>,
) -> Arc<FrameState<T>> {
    let jlen = d.journal.len();
    if let Some(a) = d.anchors.last() {
        if a.jpos == jlen {
            // no covers since the previous freeze: same state
            return Arc::clone(&a.state);
        }
    }
    let node = &d.node;
    let link_depth = d.anchors.last().map(|a| a.state.depth + 1);
    let frozen_bytes;
    let state = match link_depth {
        Some(depth) if depth <= shared.ctl.max_pin_depth() => {
            let prev = d.anchors.last().expect("link freeze has a previous anchor");
            let mut suffix = ctx.upool.acquire(jlen - prev.jpos);
            suffix.extend(
                d.journal[prev.jpos..].iter().copied().filter(|&e| e & UNDO_TAG == 0),
            );
            frozen_bytes = (suffix.len() * 4) as u64;
            Arc::new(FrameState {
                depth,
                link: FrameLink::Link { parent: Arc::clone(&prev.state), suffix },
            })
        }
        _ => {
            // first branch of the descent, or pin-depth overflow:
            // periodic materialization keeps undo chains bounded
            let mut deg = ctx.pool.acquire(node.deg.len());
            deg.extend_from_slice(&node.deg);
            let log = if shared.ctl.cfg.extract_witness {
                let mut log = ctx.upool.acquire(node.log.len().max(1));
                log.extend_from_slice(&node.log);
                log
            } else {
                Vec::new()
            };
            frozen_bytes = (deg.len() * T::BYTES + log.len() * 4) as u64;
            ctx.stats.frame_bases += 1;
            Arc::new(FrameState {
                depth: 0,
                link: FrameLink::Base {
                    deg,
                    sol: node.sol,
                    edges: node.edges,
                    bounds: node.bounds,
                    log,
                },
            })
        }
    };
    ctx.stats.pinned_frame_bytes += frozen_bytes;
    ctx.stats.payload_bytes += frozen_bytes;
    if shared.ctl.cfg.tune.is_some() {
        ctx.tune_obs.note_delta_bytes(node.deg.len(), frozen_bytes);
    }
    if shared.ctl.cfg.instrument {
        let live = shared.ctl.live_bytes.fetch_add(frozen_bytes, Ordering::Relaxed) + frozen_bytes;
        shared.ctl.peak_live_bytes.fetch_max(live, Ordering::Relaxed);
    }
    d.anchors.push(Anchor {
        state: Arc::clone(&state),
        jpos: jlen,
        sol: node.sol,
        edges: node.edges,
        bounds: node.bounds,
        log_len: node.log.len(),
    });
    state
}

/// Build the delta right child for a branch at `vmax`: pin the current
/// frame and record the branch vertex — O(delta since the last branch)
/// resident bytes instead of an O(view) payload copy.
fn make_delta_child<T: DegElem>(
    shared: &JobView<'_>,
    g: &Graph,
    ctx: &mut WorkerCtx<T>,
    d: &mut Descent<T>,
    vmax: u32,
) -> DeltaNode<T> {
    let state = freeze_frame(shared, ctx, d);
    let cnt = g
        .neighbors(vmax)
        .iter()
        .filter(|&&w| d.node.deg[w as usize].to_u32() > 0)
        .count() as u32;
    // Payload accounting parity with `track_alloc`: owned nodes charge
    // their heap payload (degree-array bytes), a delta child charges
    // the chain bytes frozen for it (suffix or base — added by
    // `freeze_frame`); neither charges the queue-item struct itself.
    ctx.stats.delta_children += 1;
    ctx.stats.payload_nodes += 1;
    if shared.ctl.cfg.tune.is_some() {
        ctx.tune_obs.note_delta_node(d.node.deg.len());
    }
    DeltaNode {
        parent: state,
        branch: vmax,
        sol_after: d.node.sol + cnt,
        ctx: d.node.ctx,
        view: d.node.view.clone(),
    }
}

/// Outcome of the reduce fixpoint, carrying facts the final sweep
/// computed for free so later stages skip their own window scans.
#[derive(Debug, Clone, Copy)]
struct ReduceOutcome {
    /// Present (non-zero-degree) vertices in the residual.
    present: usize,
    /// First present vertex (BFS seed), or `u32::MAX`.
    first: u32,
    /// Vertex of maximum residual degree, or `u32::MAX`.
    vmax: u32,
}

const NO_VERTEX: ReduceOutcome = ReduceOutcome { present: 0, first: u32::MAX, vmax: u32::MAX };

/// Apply the cheap reduction rules to a fixpoint over the bounds window
/// of the node's graph view `g`.
///
/// The final (unchanged) sweep doubles as the census pass: it counts the
/// present vertices, finds the first one (the component-BFS seed), and
/// selects the maximum-degree branch vertex — so neither the component
/// scan nor the branching step needs another pass over the window.
fn reduce_node<T: DegElem>(
    shared: &JobView<'_>,
    g: &Graph,
    dsc: &mut Descent<T>,
) -> ReduceOutcome {
    let extract = shared.ctl.cfg.extract_witness;
    let track = dsc.track;
    let (node, journal) = (&mut dsc.node, &mut dsc.journal);
    loop {
        if shared.ctl.cfg.use_bounds {
            node.bounds = node.bounds.tighten(&node.deg);
        } else {
            node.bounds = NonZeroBounds::full(node.deg.len());
        }
        if node.edges == 0 || node.bounds.is_empty() {
            return NO_VERTEX;
        }
        let bound = shared.ctl.bound_of(node.ctx);
        if node.sol >= bound {
            return NO_VERTEX; // stopping condition will fire
        }
        let mut changed = false;
        let mut present = 0usize;
        let mut first = u32::MAX;
        let mut vmax = u32::MAX;
        let mut dmax = 0u32;
        let lo = node.bounds.lo as usize;
        let hi = node.bounds.hi as usize;
        let mut v = lo;
        // while-loop over the window: measurably cheaper than the
        // RangeInclusive iterator in this innermost sweep
        while v <= hi {
            let d = node.deg[v].to_u32();
            if d == 0 {
                v += 1;
                continue;
            }
            present += 1;
            if first == u32::MAX {
                first = v as u32;
            }
            if d > dmax {
                dmax = d;
                vmax = v as u32;
            }
            match d {
                1 => {
                    // degree-one: cover the neighbor
                    let u = first_present_neighbor(g, &node.deg, v as u32);
                    cover_vertex_tracked(g, node, track.then_some(&mut *journal), u);
                    log_cover(node, u, extract);
                    node.sol += 1;
                    changed = true;
                }
                2 => {
                    // degree-two triangle: cover both neighbors
                    let (a, b) = two_present_neighbors(g, &node.deg, v as u32);
                    if g.has_edge(a, b) {
                        cover_vertex_tracked(g, node, track.then_some(&mut *journal), a);
                        log_cover(node, a, extract);
                        cover_vertex_tracked(g, node, track.then_some(&mut *journal), b);
                        log_cover(node, b, extract);
                        node.sol += 2;
                        changed = true;
                    }
                }
                d => {
                    // high-degree rule
                    let budget = bound.saturating_sub(node.sol).saturating_sub(1);
                    if d > budget {
                        cover_vertex_tracked(g, node, track.then_some(&mut *journal), v as u32);
                        log_cover(node, v as u32, extract);
                        node.sol += 1;
                        changed = true;
                    }
                }
            }
            if node.edges == 0 || node.sol >= bound {
                return NO_VERTEX;
            }
            v += 1;
        }
        if !changed {
            // nothing fired this sweep, so the census is exact
            return ReduceOutcome { present, first, vmax };
        }
    }
}

/// Remove `v` into the cover: zero its degree, decrement present
/// neighbors, maintain the edge count. (Does not touch `sol`.)
#[inline]
fn cover_vertex<T: DegElem>(g: &Graph, node: &mut Node<T>, v: u32) {
    cover_vertex_tracked(g, node, None, v)
}

/// [`cover_vertex`] with optional undo journaling (delta mode's live
/// frame): records neighbors this cover zeroed (tagged) followed by `v`
/// itself, so reverse replay can reconstruct the exact pre-cover
/// degrees — see [`undo_to_anchor`] for the inverse.
#[inline]
fn cover_vertex_tracked<T: DegElem>(
    g: &Graph,
    node: &mut Node<T>,
    journal: Option<&mut Vec<u32>>,
    v: u32,
) {
    let d = node.deg[v as usize].to_u32();
    debug_assert!(d > 0);
    node.deg[v as usize] = T::from_u32(0);
    node.edges -= d as u64;
    let mut remaining = d;
    match journal {
        None => {
            for &w in g.neighbors(v) {
                let dw = node.deg[w as usize].to_u32();
                if dw > 0 {
                    node.deg[w as usize] = T::from_u32(dw - 1);
                    remaining -= 1;
                    if remaining == 0 {
                        break;
                    }
                }
            }
        }
        Some(j) => {
            debug_assert_eq!(v & UNDO_TAG, 0, "vertex id collides with the undo tag");
            for &w in g.neighbors(v) {
                let dw = node.deg[w as usize].to_u32();
                if dw > 0 {
                    node.deg[w as usize] = T::from_u32(dw - 1);
                    if dw == 1 {
                        j.push(w | UNDO_TAG);
                    }
                    remaining -= 1;
                    if remaining == 0 {
                        break;
                    }
                }
            }
            j.push(v);
        }
    }
    debug_assert_eq!(remaining, 0, "degree count out of sync");
}

/// Append `v` (translated to a root-residual id through the node's view
/// back map) to the node's witness choice log. Pairs with every
/// [`cover_vertex`] call site; a no-op when extraction is off.
#[inline]
fn log_cover<T: DegElem>(node: &mut Node<T>, v: u32, extract: bool) {
    if extract {
        let rid = match &node.view {
            Some(vw) => vw.back[v as usize],
            None => v,
        };
        node.log.push(rid);
    }
}

/// The canonical cover of a classified special component (vertex list in
/// `comp`, view-local ids), translated to root-residual ids through the
/// view's back map. Witness-extraction path only.
fn special_cover_root_ids<T: DegElem>(
    g: &Graph,
    comp: &[u32],
    deg: &[T],
    view: Option<&GraphView>,
    sp: SpecialComponent,
) -> Vec<u32> {
    let mut local = Vec::with_capacity(sp.mvc_size() as usize);
    sp.cover_into(g, comp, |v| deg[v as usize].to_u32() > 0, &mut local);
    match view {
        Some(vw) => local.iter().map(|&v| vw.back[v as usize]).collect(),
        None => local,
    }
}

#[inline]
fn first_present_neighbor<T: DegElem>(g: &Graph, deg: &[T], v: u32) -> u32 {
    for &w in g.neighbors(v) {
        if deg[w as usize].to_u32() > 0 {
            return w;
        }
    }
    unreachable!("degree-1 vertex must have a present neighbor")
}

#[inline]
fn two_present_neighbors<T: DegElem>(g: &Graph, deg: &[T], v: u32) -> (u32, u32) {
    let mut first = u32::MAX;
    for &w in g.neighbors(v) {
        if deg[w as usize].to_u32() > 0 {
            if first == u32::MAX {
                first = w;
            } else {
                return (first, w);
            }
        }
    }
    unreachable!("degree-2 vertex must have two present neighbors")
}

/// Vertex of maximum residual degree within the bounds window
/// (debug cross-check for the fused census in `reduce_node`).
#[cfg_attr(not(debug_assertions), allow(dead_code))]
fn max_degree_vertex<T: DegElem>(node: &Node<T>) -> u32 {
    let mut vmax = u32::MAX;
    let mut dmax = 0u32;
    for v in node.bounds.lo..=node.bounds.hi {
        let d = node.deg[v as usize].to_u32();
        if d > dmax {
            dmax = d;
            vmax = v;
        }
    }
    vmax
}

/// Build the right child (`N(vmax)` into the cover). The payload copy —
/// the engine's hottest allocation — is served from the worker's
/// recycling pool, and is O(view) rather than O(root n) once component
/// induction has shrunk the view.
fn make_right_child<T: DegElem>(
    shared: &JobView<'_>,
    g: &Graph,
    ctx: &mut WorkerCtx<T>,
    node: &Node<T>,
    vmax: u32,
) -> Node<T> {
    ctx.nbuf.clear();
    ctx.nbuf.extend(
        g.neighbors(vmax).iter().copied().filter(|&w| node.deg[w as usize].to_u32() > 0),
    );
    let extract = shared.ctl.cfg.extract_witness;
    let mut deg = ctx.pool.acquire(node.deg.len());
    deg.extend_from_slice(&node.deg);
    track_alloc(shared, ctx, deg.len());
    // the child owns its full choice log (prefix + the N(vmax) covers),
    // so it can be stolen and completed by any worker
    let log = if extract {
        let mut log = ctx.upool.acquire(node.log.len() + ctx.nbuf.len());
        log.extend_from_slice(&node.log);
        log
    } else {
        Vec::new()
    };
    let mut child = Node {
        deg,
        sol: node.sol + ctx.nbuf.len() as u32,
        edges: node.edges,
        bounds: node.bounds,
        ctx: node.ctx,
        view: node.view.clone(),
        log,
    };
    for &u in &ctx.nbuf {
        if child.deg[u as usize].to_u32() > 0 {
            cover_vertex(g, &mut child, u);
            log_cover(&mut child, u, extract);
        }
    }
    debug_assert_eq!(child.deg[vmax as usize].to_u32(), 0);
    debug_assert!(!extract || child.log.len() as u32 == child.sol, "log out of sync with sol");
    child
}

/// Push a child node to the seed frontier (static-seeding phase) or the
/// scheduler.
fn push_child<T: DegElem, H: WorkerHandle<NodePayload<T>>>(
    ctx: &mut WorkerCtx<T>,
    handle: &mut H,
    node: NodePayload<T>,
) {
    if let Some(front) = ctx.frontier.as_mut() {
        front.push_back(node);
        return;
    }
    handle.push(node);
}

/// Report a leaf's total for its context, together with its choice log
/// when extraction is on (`log.len() == size` relative to the context
/// root — the cover achieving the reported size).
fn report_leaf(ctl: &JobCtl, ctx: u32, size: u32, log: &[u32]) {
    let extract = ctl.cfg.extract_witness;
    debug_assert!(!extract || log.len() as u32 == size, "leaf log out of sync with size");
    if ctx == NONE {
        if extract {
            ctl.registry.offer_root_witness(log);
        }
        ctl.on_root_total(size);
    } else {
        let mut on_root = |t: u32| ctl.on_root_total(t);
        if extract {
            ctl.registry.report_witnessed(ctx, size, log, &mut on_root);
        } else {
            ctl.registry.report_solution(ctx, size, &mut on_root);
        }
    }
}

fn complete(ctl: &JobCtl, ctx: u32) {
    let mut on_root = |t: u32| ctl.on_root_total(t);
    ctl.registry.complete_node(ctx, &mut on_root);
}

enum Scan {
    /// Residual graph is one component (not special).
    Single,
    /// One component and it is a clique / chordless cycle, solved in
    /// closed form (the classification drives both the size and, when
    /// extracting, the canonical witness cover).
    SingleSpecial(SpecialComponent),
    /// Multiple components. The detection BFS's component is left in
    /// `ctx.queue` (stamp intact) so the split branch can reuse it.
    Split {
        /// |V| of the already-discovered first component.
        first_size: u32,
        /// Its minimum residual degree.
        dmin: u32,
        /// Its maximum residual degree.
        dmax: u32,
    },
}

/// One BFS from the first present vertex; decides single vs split.
/// On `Single`, also classifies the special-component rules (§III-D).
/// `present_total` comes for free from the reduce fixpoint's final sweep.
fn scan_components<T: DegElem>(
    g: &Graph,
    ctx: &mut WorkerCtx<T>,
    node: &Node<T>,
    red: &ReduceOutcome,
) -> Scan {
    let start = red.first;
    debug_assert!(start != u32::MAX, "edges > 0 implies a present vertex");
    let (size, dmin, dmax) = bfs_component(g, node, ctx, start);
    if (size as usize) == red.present {
        if dmin == dmax {
            if let Some(sp) = classify(size, std::iter::repeat(dmin).take(size as usize)) {
                return Scan::SingleSpecial(sp);
            }
        }
        return Scan::Single;
    }
    Scan::Split { first_size: size, dmin, dmax }
}

/// Branch on components (Alg. 2 lines 14-20): register a parent entry,
/// dispatch each component **eagerly** as it is found (special ones in
/// closed form), and release the discovery reference at the end. The
/// split frame itself stays with the caller — retired into the worker
/// pool under the owned representation, kept live for delta undo.
///
/// The split-detection BFS already discovered the first component
/// (`ctx.queue`, visit stamps intact), so discovery resumes from there
/// instead of re-walking it.
#[allow(clippy::too_many_arguments)]
fn branch_on_components<T: DegElem, H: WorkerHandle<NodePayload<T>>>(
    shared: &JobView<'_>,
    g: &Graph,
    ctx: &mut WorkerCtx<T>,
    handle: &mut H,
    d: &Descent<T>,
    first_size: u32,
    first_dmin: u32,
    first_dmax: u32,
) {
    let node = &d.node;
    ctx.stats.component_branches += 1;
    let parent = shared.ctl.registry.new_parent(node.sol, node.ctx);
    if shared.ctl.cfg.extract_witness {
        // Sum₀'s vertices: the split node's choice log seeds the
        // parent's accumulated witness.
        debug_assert_eq!(node.log.len() as u32, node.sol, "split log out of sync with sol");
        shared.ctl.registry.witness_init_parent(parent, &node.log);
    }
    ctx.stats.registry_entries += 1;

    // Component 1: reuse the detection BFS result.
    dispatch_component(shared, g, ctx, handle, node, parent, first_size, first_dmin, first_dmax);
    let mut comp_count = 1u32;

    // Remaining components: continue scanning under the same stamp.
    let mut cursor = node.bounds.lo;
    loop {
        // next unvisited present vertex
        let mut start = u32::MAX;
        while cursor <= node.bounds.hi {
            let v = cursor;
            cursor += 1;
            if node.deg[v as usize].to_u32() > 0 && ctx.visit[v as usize] != ctx.stamp {
                start = v;
                break;
            }
        }
        if start == u32::MAX {
            break;
        }
        let (size, dmin, dmax) = bfs_component_accumulate(g, node, ctx, start);
        comp_count += 1;
        dispatch_component(shared, g, ctx, handle, node, parent, size, dmin, dmax);
    }

    *ctx.stats.comp_histogram.entry(comp_count).or_insert(0) += 1;
    let mut on_root = |t: u32| shared.ctl.on_root_total(t);
    shared.ctl.registry.finish_scan(parent, &mut on_root);
}

/// Handle one discovered component (vertex list in `ctx.queue`): solve
/// cliques/chordless cycles in closed form (§III-D), otherwise register
/// a child entry and dispatch the component node for search — as a
/// compact induced subproblem when the `induce_threshold` gate passes,
/// or as a full-width masked copy of the parent's view otherwise.
#[allow(clippy::too_many_arguments)]
fn dispatch_component<T: DegElem, H: WorkerHandle<NodePayload<T>>>(
    shared: &JobView<'_>,
    g: &Graph,
    ctx: &mut WorkerCtx<T>,
    handle: &mut H,
    node: &Node<T>,
    parent: u32,
    size: u32,
    dmin: u32,
    dmax: u32,
) {
    let extract = shared.ctl.cfg.extract_witness;
    if dmin == dmax {
        if let Some(sp) = classify(size, std::iter::repeat(dmin).take(size as usize)) {
            ctx.stats.special_solved += 1;
            shared.ctl.registry.add_solved_component(parent, sp.mvc_size());
            if extract {
                let cover =
                    special_cover_root_ids(g, &ctx.queue, &node.deg, node.view.as_deref(), sp);
                shared.ctl.registry.witness_solved_component(parent, &cover);
            }
            return;
        }
    }

    // Bounds for the component child: Best starts at the achievable
    // |V_i|-1; Limit adds the parent's remaining budget.
    let parent_bound = shared.ctl.bound_of_parent(node.ctx, parent);
    let best0 = size - 1;
    let limit = best0.min(parent_bound);

    let view_n = node.deg.len();
    let induce = shared.ctl.induce_gate(size, view_n);
    if induce {
        // Sorting makes the view→local map monotonic, so the induced
        // CSR rows come out sorted (required for `has_edge` binary
        // search) — and the back map below is the sorted component's
        // root-id image.
        ctx.queue.sort_unstable();
    }

    // Cross-job memoization (solver::memo): induced components come out
    // in canonical renumbered form, so build the CSR up front and
    // consult the cache *before* registering a child slot — a hit folds
    // the cached exact answer into the parent like a closed-form special
    // component and skips the subtree entirely. A miss hands the built
    // CSR (plus its fingerprint, registered for publication) on to
    // `induce_component_child`.
    let memo = if induce { shared.ctl.cfg.memo.clone() } else { None };
    let prebuilt = match &memo {
        Some(m) => {
            let (row_ptr, adj, edges2) = build_component_csr(g, ctx, node);
            let fp = fingerprint_csr(&row_ptr, &adj);
            ctx.stats.memo_lookups += 1;
            if let Some((mvc, cover)) = m.lookup(fp, &row_ptr, &adj, extract) {
                ctx.stats.memo_hits += 1;
                ctx.stats.memo_saved_nodes += size as u64;
                shared.ctl.registry.add_solved_component(parent, mvc);
                if extract {
                    // Cached covers are component-local: translate
                    // through the sorted component list and the parent
                    // view's back map into root-residual ids.
                    let cover = cover.expect("memo hit without cover under need_cover");
                    let to_root = |l: u32| {
                        let v = ctx.queue[l as usize];
                        match node.view.as_deref() {
                            Some(vw) => vw.back[v as usize],
                            None => v,
                        }
                    };
                    let root_cover: Vec<u32> = cover.iter().map(|&l| to_root(l)).collect();
                    shared.ctl.registry.witness_solved_component(parent, &root_cover);
                }
                ctx.upool.release(row_ptr);
                ctx.upool.release(adj);
                return;
            }
            Some((row_ptr, adj, edges2, fp))
        }
        None => None,
    };

    let child_ctx = shared.ctl.registry.new_child(parent, best0, limit);
    ctx.stats.registry_entries += 1;

    // The component's root-residual ids: the child's winning-witness
    // slot starts at the achievable all-but-one fallback, and for an
    // induced child the same list *is* its back map (local id i =
    // position i of the sorted component).
    let comp_root: Vec<u32> = if extract {
        match node.view.as_deref() {
            Some(vw) => ctx.queue.iter().map(|&v| vw.back[v as usize]).collect(),
            None => ctx.queue.clone(),
        }
    } else {
        Vec::new()
    };
    if extract {
        shared.ctl.registry.witness_init_child(child_ctx, &comp_root[..comp_root.len() - 1]);
    }
    let child = if induce {
        ctx.stats.induced_subproblems += 1;
        if shared.ctl.cfg.tune.is_some() {
            ctx.tune_obs.note_induced(size as usize);
        }
        let (row_ptr, adj, edges2, view_memo) = match prebuilt {
            Some((row_ptr, adj, edges2, fp)) => {
                // Queue the slot for publication only on publishing
                // (MVC-mode) jobs; the view carries the fingerprint so
                // the last view drop can hand the buffers to the cache.
                let vm = memo.filter(|m| m.publishes()).map(|m| {
                    m.register_pending(child_ctx, fp, best0);
                    ViewMemo { fp, job: m }
                });
                (row_ptr, adj, edges2, vm)
            }
            None => {
                let (row_ptr, adj, edges2) = build_component_csr(g, ctx, node);
                (row_ptr, adj, edges2, None)
            }
        };
        induce_component_child(
            shared, ctx, node, child_ctx, comp_root, row_ptr, adj, edges2, view_memo,
        )
    } else {
        // Full-width fallback (ablation / `--induce-threshold 0`):
        // degrees masked to the component over the parent's view.
        let mut deg = ctx.pool.acquire(view_n);
        deg.resize(view_n, T::from_u32(0));
        let mut edges2 = 0u64;
        let (mut lo, mut hi) = (u32::MAX, 0u32);
        for &v in &ctx.queue {
            let d = node.deg[v as usize];
            deg[v as usize] = d;
            edges2 += d.to_u32() as u64;
            lo = lo.min(v);
            hi = hi.max(v);
        }
        track_alloc(shared, ctx, view_n);
        Node {
            deg,
            sol: 0,
            edges: edges2 / 2,
            bounds: NonZeroBounds { lo, hi },
            ctx: child_ctx,
            view: node.view.clone(),
            log: Vec::new(),
        }
    };
    push_child(ctx, handle, NodePayload::Owned(child));
}

/// Build the canonical induced CSR of the component in `ctx.queue`
/// (already sorted by the dispatch gate) from recycled buffers, filling
/// `ctx.vmap` with the view→local renumbering. Returns
/// `(row_ptr, adj, 2·edges)`. Shared by the memo lookup (which needs the
/// canonical arrays before a child slot exists) and the plain induced
/// dispatch path.
fn build_component_csr<T: DegElem>(
    g: &Graph,
    ctx: &mut WorkerCtx<T>,
    node: &Node<T>,
) -> (Vec<u32>, Vec<u32>, u64) {
    debug_assert!(ctx.queue.windows(2).all(|w| w[0] < w[1]), "component must be sorted");
    let k = ctx.queue.len();
    for (i, &v) in ctx.queue.iter().enumerate() {
        ctx.vmap[v as usize] = i as u32;
    }
    let mut edges2 = 0u64;
    for &v in &ctx.queue {
        edges2 += node.deg[v as usize].to_u32() as u64;
    }
    let mut row_ptr = ctx.upool.acquire(k + 1);
    let mut adj = ctx.upool.acquire(edges2 as usize);
    induce_residual_into(
        g,
        &ctx.queue,
        &ctx.vmap,
        |w| node.deg[w as usize].to_u32(),
        &mut row_ptr,
        &mut adj,
    );
    (row_ptr, adj, edges2)
}

/// Materialize the component in `ctx.queue` (already sorted by the
/// dispatch gate) as a compact, renumbered subproblem: the prebuilt
/// component-local CSR ([`build_component_csr`]) plus a `|C|`-sized
/// degree array from recycled buffers. The paper's §IV-B subgraph
/// induction, applied inside the tree — every descendant of this child
/// now pays O(|C|) per clone and sweeps a |C|-wide window. `back` is the
/// component's root-residual id list (local id `i` → `back[i]`),
/// pre-composed through the parent view's back map; empty when witness
/// extraction is off. `memo` tags the view when the component is
/// registered for memo publication at last view drop.
#[allow(clippy::too_many_arguments)]
fn induce_component_child<T: DegElem>(
    shared: &JobView<'_>,
    ctx: &mut WorkerCtx<T>,
    node: &Node<T>,
    child_ctx: u32,
    back: Vec<u32>,
    row_ptr: Vec<u32>,
    adj: Vec<u32>,
    edges2: u64,
    memo: Option<ViewMemo>,
) -> Node<T> {
    let k = ctx.queue.len();
    let mut deg = ctx.pool.acquire(k);
    for &v in &ctx.queue {
        deg.push(node.deg[v as usize]);
    }
    track_alloc(shared, ctx, k);
    if shared.ctl.cfg.instrument {
        // The view's CSR (and back map) stays live as long as any
        // descendant holds the Arc; count it so off-vs-on peak
        // comparisons are unbiased.
        let bytes = view_bytes(&row_ptr, &adj, &back);
        let live = shared.ctl.live_bytes.fetch_add(bytes, Ordering::Relaxed) + bytes;
        shared.ctl.peak_live_bytes.fetch_max(live, Ordering::Relaxed);
    }
    Node {
        deg,
        sol: 0,
        edges: edges2 / 2,
        bounds: NonZeroBounds::full(k),
        ctx: child_ctx,
        view: Some(Arc::new(GraphView {
            graph: Graph::from_csr_parts(row_ptr, adj),
            back,
            memo,
        })),
        log: Vec::new(),
    }
}

/// BFS one component starting at `start` using a fresh stamp.
/// Returns (size, min residual degree, max residual degree); the visited
/// vertex list is left in `ctx.queue`.
fn bfs_component<T: DegElem>(
    g: &Graph,
    node: &Node<T>,
    ctx: &mut WorkerCtx<T>,
    start: u32,
) -> (u32, u32, u32) {
    fresh_stamp(ctx);
    bfs_component_accumulate(g, node, ctx, start)
}

/// Advance the visit stamp, clearing marks on wraparound.
fn fresh_stamp<T: DegElem>(ctx: &mut WorkerCtx<T>) {
    ctx.stamp = ctx.stamp.wrapping_add(1);
    if ctx.stamp == 0 {
        ctx.visit.fill(0);
        ctx.stamp = 1;
    }
}

/// BFS one component reusing the current stamp (so successive calls in a
/// split scan accumulate the visited set).
fn bfs_component_accumulate<T: DegElem>(
    g: &Graph,
    node: &Node<T>,
    ctx: &mut WorkerCtx<T>,
    start: u32,
) -> (u32, u32, u32) {
    ctx.queue.clear();
    ctx.queue.push(start);
    ctx.visit[start as usize] = ctx.stamp;
    let mut head = 0;
    let (mut dmin, mut dmax) = (u32::MAX, 0u32);
    while head < ctx.queue.len() {
        let u = ctx.queue[head];
        head += 1;
        let du = node.deg[u as usize].to_u32();
        dmin = dmin.min(du);
        dmax = dmax.max(du);
        let mut remaining = du;
        for &w in g.neighbors(u) {
            if node.deg[w as usize].to_u32() > 0 {
                remaining -= 1;
                if ctx.visit[w as usize] != ctx.stamp {
                    ctx.visit[w as usize] = ctx.stamp;
                    ctx.queue.push(w);
                }
                if remaining == 0 {
                    break;
                }
            }
        }
    }
    (ctx.queue.len() as u32, dmin, dmax)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::solver::oracle;

    const BOTH_SCHEDULERS: [SchedulerKind; 2] = [SchedulerKind::WorkSteal, SchedulerKind::Sharded];

    fn cfg_with(
        component_aware: bool,
        load_balance: bool,
        workers: usize,
        scheduler: SchedulerKind,
    ) -> EngineCfg {
        EngineCfg {
            component_aware,
            load_balance,
            workers,
            scheduler,
            ..EngineCfg::default()
        }
    }

    fn run_cfg(
        g: &Graph,
        component_aware: bool,
        load_balance: bool,
        workers: usize,
        scheduler: SchedulerKind,
    ) -> u32 {
        let ub = crate::solver::greedy::greedy_bound(g);
        let out = run::<u32>(g, ub, cfg_with(component_aware, load_balance, workers, scheduler));
        assert!(!out.timed_out);
        out.best
    }

    #[test]
    fn matches_oracle_all_variants_both_schedulers() {
        for seed in 0..15 {
            let g = generators::erdos_renyi(18, 0.18, seed);
            let opt = oracle::mvc_size(&g);
            for sched in BOTH_SCHEDULERS {
                let tag = sched.name();
                assert_eq!(run_cfg(&g, true, true, 4, sched), opt, "proposed {tag} seed {seed}");
                assert_eq!(run_cfg(&g, false, true, 4, sched), opt, "yamout {tag} seed {seed}");
                assert_eq!(run_cfg(&g, true, false, 4, sched), opt, "no-lb {tag} seed {seed}");
                assert_eq!(run_cfg(&g, true, true, 1, sched), opt, "1-worker {tag} seed {seed}");
            }
        }
    }

    #[test]
    fn splitting_graphs_match_oracle() {
        for seed in 0..10 {
            let g = generators::union_of_random(4, 3, 6, 0.3, seed);
            let opt = oracle::mvc_size(&g);
            for sched in BOTH_SCHEDULERS {
                assert_eq!(run_cfg(&g, true, true, 4, sched), opt, "{} seed {seed}", sched.name());
                assert_eq!(run_cfg(&g, false, true, 4, sched), opt, "{} seed {seed}", sched.name());
            }
        }
    }

    #[test]
    fn structured_graphs() {
        let cases: Vec<(Graph, u32)> = vec![
            (generators::cycle(9), 5),
            (generators::clique(7), 6),
            (generators::path(10), 5),
            (generators::star(12), 1),
        ];
        for (g, expect) in cases {
            for sched in BOTH_SCHEDULERS {
                assert_eq!(run_cfg(&g, true, true, 2, sched), expect, "{}", sched.name());
            }
        }
    }

    #[test]
    fn component_branches_counted() {
        // two reduction-proof, non-special components (3-regular,
        // triangle-free) so the split must be handled by the registry
        let g = Graph::disjoint_union(&[generators::petersen(), generators::petersen()]);
        let ub = crate::solver::greedy::greedy_bound(&g);
        for sched in BOTH_SCHEDULERS {
            let out = run::<u32>(&g, ub, cfg_with(true, true, 2, sched));
            assert_eq!(out.best, oracle::mvc_size(&g), "{}", sched.name());
            assert!(out.stats.component_branches >= 1);
            assert!(!out.stats.comp_histogram.is_empty());
        }
    }

    #[test]
    fn pvc_mode_stops_early_when_found() {
        let g = generators::erdos_renyi(20, 0.2, 3);
        let opt = oracle::mvc_size(&g);
        for sched in BOTH_SCHEDULERS {
            // k = opt: initial best = k+1, must improve and stop
            let mut cfg = cfg_with(true, true, 4, sched);
            cfg.stop_on_improvement = true;
            let out = run::<u32>(&g, opt + 1, cfg);
            assert!(out.improved, "{}", sched.name());
            assert!(out.best <= opt, "{}", sched.name());
        }
    }

    #[test]
    fn pvc_mode_k_too_small_finds_nothing() {
        let g = generators::erdos_renyi(16, 0.25, 5);
        let opt = oracle::mvc_size(&g);
        for sched in BOTH_SCHEDULERS {
            let mut cfg = cfg_with(true, true, 4, sched);
            cfg.stop_on_improvement = true;
            // searching for < opt ⇒ impossible
            let out = run::<u32>(&g, opt, cfg);
            assert!(!out.improved, "{}", sched.name());
            assert_eq!(out.best, opt, "{}", sched.name());
        }
    }

    #[test]
    fn small_dtypes_agree() {
        for seed in 0..6 {
            let g = generators::erdos_renyi(20, 0.15, seed);
            let ub = crate::solver::greedy::greedy_bound(&g);
            let cfg = cfg_with(true, true, 3, SchedulerKind::WorkSteal);
            let a = run::<u8>(&g, ub, cfg.clone()).best;
            let b = run::<u16>(&g, ub, cfg.clone()).best;
            let c = run::<u32>(&g, ub, cfg).best;
            assert_eq!(a, b, "seed {seed}");
            assert_eq!(b, c, "seed {seed}");
            assert_eq!(c, oracle::mvc_size(&g), "seed {seed}");
        }
    }

    #[test]
    fn bounds_disabled_agrees() {
        for seed in 0..5 {
            let g = generators::union_of_random(3, 4, 7, 0.25, seed);
            let ub = crate::solver::greedy::greedy_bound(&g);
            let mk = |use_bounds| EngineCfg {
                use_bounds,
                workers: 2,
                ..EngineCfg::default()
            };
            assert_eq!(
                run::<u32>(&g, ub, mk(true)).best,
                run::<u32>(&g, ub, mk(false)).best,
                "seed {seed}"
            );
        }
    }

    #[test]
    fn deadline_times_out() {
        // a dense-ish graph with an immediate deadline must report timeout
        let g = generators::p_hat(60, 0.3, 0.8, 1);
        let ub = crate::solver::greedy::greedy_bound(&g);
        for sched in BOTH_SCHEDULERS {
            let mut cfg = cfg_with(true, true, 2, sched);
            cfg.deadline = Some(Instant::now());
            let out = run::<u32>(&g, ub, cfg);
            assert!(out.timed_out, "{}", sched.name());
        }
    }

    #[test]
    fn instrumentation_records_activity() {
        let g = generators::erdos_renyi(24, 0.2, 9);
        let ub = crate::solver::greedy::greedy_bound(&g);
        let mut cfg = cfg_with(true, true, 2, SchedulerKind::WorkSteal);
        cfg.instrument = true;
        let out = run::<u32>(&g, ub, cfg);
        let busy: u64 = out.stats.activity.iter().sum();
        assert!(busy > 0);
    }

    #[test]
    fn sched_counters_reconcile_with_tree_nodes() {
        // Every node acquired from a queue starts one `process` descent;
        // descents stay in place for left branches, so acquisitions must
        // equal pushes + the injected root, and tree_nodes must be at
        // least the acquisitions.
        let g = generators::erdos_renyi(22, 0.2, 11);
        for sched in BOTH_SCHEDULERS {
            let ub = crate::solver::greedy::greedy_bound(&g);
            let out = run::<u32>(&g, ub, cfg_with(true, true, 4, sched));
            let c: Vec<_> = out.stats.sched_workers.clone();
            let acquired: u64 = c.iter().map(|w| w.acquired()).sum();
            let pushed: u64 = c.iter().map(|w| w.pushes).sum();
            assert_eq!(acquired, pushed + 1, "{}: root + pushes", sched.name());
            assert!(out.stats.tree_nodes >= acquired, "{}", sched.name());
        }
    }

    #[test]
    fn pool_buffers_are_cleared_and_rebuilt_on_reuse() {
        let mut pool = BufferPool::<u32>::new();
        let mut b = pool.acquire(8);
        assert_eq!(pool.misses, 1);
        b.extend_from_slice(&[7; 8]);
        pool.release(b);
        // a smaller request is served from the same class, cleared
        let b2 = pool.acquire(5);
        assert_eq!(pool.hits, 1);
        assert!(b2.is_empty(), "recycled buffer must carry no stale entries");
        assert!(b2.capacity() >= 5);
        // the zero-fill path used by masked component children rebuilds
        // every entry
        let mut b3 = b2;
        b3.resize(5, 0);
        assert!(b3.iter().all(|&x| x == 0));
        pool.release(b3);
        // a request larger than anything pooled allocates fresh
        let big = pool.acquire(1 << 12);
        assert_eq!(pool.misses, 2);
        assert!(big.capacity() >= 1 << 12);
    }

    #[test]
    fn pool_class_always_fits_request() {
        let mut pool = BufferPool::<u8>::new();
        for len in [1usize, 2, 3, 7, 8, 9, 100, 1000] {
            let b = pool.acquire(len);
            assert!(b.capacity() >= len, "len {len}");
            pool.release(b);
        }
        // re-acquire across the same lengths: recycled buffers must fit
        for len in [1000usize, 100, 9, 8, 7, 3, 2, 1] {
            let b = pool.acquire(len);
            assert!(b.capacity() >= len, "len {len}");
            pool.release(b);
        }
    }

    #[test]
    fn induction_on_off_agree_with_oracle() {
        for seed in 0..8 {
            let g = generators::union_of_random(4, 3, 7, 0.3, seed);
            let opt = oracle::mvc_size(&g);
            for sched in BOTH_SCHEDULERS {
                for threshold in [0.0, 0.5, 1.0] {
                    let mut cfg = cfg_with(true, true, 4, sched);
                    cfg.induce_threshold = threshold;
                    let ub = crate::solver::greedy::greedy_bound(&g);
                    let out = run::<u32>(&g, ub, cfg);
                    assert_eq!(
                        out.best,
                        opt,
                        "seed {seed} {} threshold {threshold}",
                        sched.name()
                    );
                }
            }
        }
    }

    #[test]
    fn induced_subproblems_counted_and_pool_reused() {
        let g = Graph::disjoint_union(&[generators::petersen(), generators::petersen()]);
        let ub = crate::solver::greedy::greedy_bound(&g);
        let mut cfg = cfg_with(true, true, 2, SchedulerKind::WorkSteal);
        cfg.instrument = true;
        let out = run::<u32>(&g, ub, cfg.clone());
        assert_eq!(out.best, oracle::mvc_size(&g));
        assert!(out.stats.induced_subproblems >= 2, "both components should induce");
        assert!(out.stats.pool_hits > 0, "right-child clones should recycle");
        assert!(out.stats.payload_nodes > 0);
        assert!(out.stats.peak_live_bytes > 0);
        // with induction off, no induced subproblems are recorded
        cfg.induce_threshold = 0.0;
        let off = run::<u32>(&g, ub, cfg);
        assert_eq!(off.best, out.best);
        assert_eq!(off.stats.induced_subproblems, 0);
    }

    #[test]
    fn induced_children_have_component_sized_payloads() {
        // Two Petersen graphs: after the split each child payload must be
        // 10 entries, not 20, so the total payload bytes with induction
        // must be well below the full-width run's.
        let g = Graph::disjoint_union(&[generators::petersen(), generators::petersen()]);
        let ub = crate::solver::greedy::greedy_bound(&g);
        let on = run::<u32>(&g, ub, cfg_with(true, true, 1, SchedulerKind::WorkSteal));
        let mut cfg_off = cfg_with(true, true, 1, SchedulerKind::WorkSteal);
        cfg_off.induce_threshold = 0.0;
        let off = run::<u32>(&g, ub, cfg_off);
        assert_eq!(on.best, off.best);
        let bpn_on = on.stats.payload_bytes as f64 / on.stats.payload_nodes.max(1) as f64;
        let bpn_off = off.stats.payload_bytes as f64 / off.stats.payload_nodes.max(1) as f64;
        assert!(
            bpn_on < bpn_off,
            "induced bytes/node {bpn_on} must beat full-width {bpn_off}"
        );
    }

    #[test]
    fn witness_extraction_valid_and_optimal() {
        // Splitting graphs across both schedulers, with and without tree
        // induction: the assembled witness must be a genuine optimal
        // cover of the searched graph.
        for seed in 0..8 {
            let g = generators::union_of_random(3, 3, 7, 0.3, seed);
            let opt = oracle::mvc_size(&g);
            let n = g.num_vertices() as u32;
            for sched in BOTH_SCHEDULERS {
                for threshold in [0.0, 1.0] {
                    let mut cfg = cfg_with(true, true, 4, sched);
                    cfg.extract_witness = true;
                    cfg.induce_threshold = threshold;
                    let tag = format!("seed {seed} {} induce={threshold}", sched.name());
                    let out = run::<u32>(&g, n + 1, cfg);
                    assert_eq!(out.best, opt, "{tag}");
                    let w = out.witness.expect("improvement below n+1 must be witnessed");
                    assert_eq!(w.len() as u32, opt, "{tag}");
                    assert!(g.is_vertex_cover(&w), "{tag}");
                    assert!(out.stats.logs_recycled > 0, "{tag}: logs must recycle");
                }
            }
        }
    }

    #[test]
    fn witness_extraction_without_component_awareness() {
        // The prior-work shape (no splits): every leaf reports its full
        // choice log at the root context.
        for seed in 0..6 {
            let g = generators::erdos_renyi(16, 0.22, seed);
            let opt = oracle::mvc_size(&g);
            let n = g.num_vertices() as u32;
            let mut cfg = cfg_with(false, true, 3, SchedulerKind::WorkSteal);
            cfg.extract_witness = true;
            let out = run::<u32>(&g, n + 1, cfg);
            assert_eq!(out.best, opt, "seed {seed}");
            let w = out.witness.expect("witness");
            assert_eq!(w.len() as u32, opt, "seed {seed}");
            assert!(g.is_vertex_cover(&w), "seed {seed}");
        }
    }

    #[test]
    fn witness_small_dtypes_agree() {
        let g = generators::union_of_random(3, 3, 6, 0.3, 11);
        let opt = oracle::mvc_size(&g);
        let n = g.num_vertices() as u32;
        let mut cfg = cfg_with(true, true, 2, SchedulerKind::WorkSteal);
        cfg.extract_witness = true;
        let a = run::<u8>(&g, n + 1, cfg.clone());
        let b = run::<u16>(&g, n + 1, cfg);
        for out in [a, b] {
            assert_eq!(out.best, opt);
            let w = out.witness.expect("witness");
            assert_eq!(w.len() as u32, opt);
            assert!(g.is_vertex_cover(&w));
        }
    }

    #[test]
    fn pvc_witness_respects_bound() {
        // PVC + extraction: early stop waits for an *assembled* witness,
        // so a stopped search always hands back a cover within k.
        for seed in [3u64, 5, 9] {
            let g = generators::erdos_renyi(18, 0.22, seed);
            let opt = oracle::mvc_size(&g);
            for sched in BOTH_SCHEDULERS {
                let mut cfg = cfg_with(true, true, 4, sched);
                cfg.stop_on_improvement = true;
                cfg.extract_witness = true;
                let out = run::<u32>(&g, opt + 1, cfg);
                assert!(out.improved, "seed {seed} {}", sched.name());
                let w = out.witness.expect("stopped search must carry a witness");
                assert!(w.len() as u32 <= opt, "seed {seed} {}", sched.name());
                assert!(g.is_vertex_cover(&w), "seed {seed} {}", sched.name());
            }
        }
    }

    #[test]
    fn witness_off_costs_nothing() {
        let g = generators::union_of_random(3, 3, 6, 0.3, 7);
        let ub = crate::solver::greedy::greedy_bound(&g);
        let out = run::<u32>(&g, ub, cfg_with(true, true, 2, SchedulerKind::WorkSteal));
        assert!(out.witness.is_none());
        assert_eq!(out.stats.witness_log_bytes, 0);
        assert_eq!(out.stats.logs_recycled, 0);
    }

    fn delta_cfg(workers: usize, scheduler: SchedulerKind) -> EngineCfg {
        EngineCfg {
            node_repr: NodeRepr::Delta,
            ..cfg_with(true, true, workers, scheduler)
        }
    }

    #[test]
    fn delta_repr_matches_oracle_both_schedulers() {
        for seed in 0..10 {
            let g = generators::erdos_renyi(18, 0.18, seed);
            let opt = oracle::mvc_size(&g);
            let ub = crate::solver::greedy::greedy_bound(&g);
            for sched in BOTH_SCHEDULERS {
                for workers in [1usize, 4] {
                    let out = run::<u32>(&g, ub, delta_cfg(workers, sched));
                    assert_eq!(out.best, opt, "{} w={workers} seed {seed}", sched.name());
                }
            }
        }
    }

    #[test]
    fn delta_repr_matches_oracle_on_splits_and_dtypes() {
        for seed in 0..6 {
            let g = generators::union_of_random(4, 3, 7, 0.3, seed);
            let opt = oracle::mvc_size(&g);
            let ub = crate::solver::greedy::greedy_bound(&g);
            for threshold in [0.0, 1.0] {
                let mut cfg = delta_cfg(4, SchedulerKind::WorkSteal);
                cfg.induce_threshold = threshold;
                assert_eq!(run::<u8>(&g, ub, cfg.clone()).best, opt, "u8 seed {seed}");
                assert_eq!(run::<u16>(&g, ub, cfg.clone()).best, opt, "u16 seed {seed}");
                assert_eq!(run::<u32>(&g, ub, cfg).best, opt, "u32 seed {seed}");
            }
        }
    }

    #[test]
    fn delta_single_worker_undoes_and_never_materializes() {
        // One worker, one connected component: after the root every
        // queued node is a delta child, every pop is local, and every
        // anchor match must hit — the pure in-place undo regime.
        let g = generators::erdos_renyi(22, 0.25, 7);
        let ub = crate::solver::greedy::greedy_bound(&g);
        let out = run::<u32>(&g, ub, delta_cfg(1, SchedulerKind::WorkSteal));
        assert_eq!(out.best, oracle::mvc_size(&g));
        assert!(out.stats.delta_children > 0, "branches must push delta children");
        assert!(out.stats.undo_pops > 0, "local pops must take the undo path");
        assert!(out.stats.undo_covers > 0, "undo must revert covers");
        assert_eq!(out.stats.materializations, 0, "single local worker never materializes");
        assert!(out.stats.frame_bases > 0, "descents freeze owned bases");
    }

    #[test]
    fn delta_undo_path_preserves_witnesses() {
        for seed in 0..6 {
            let g = generators::union_of_random(3, 3, 7, 0.3, seed);
            let opt = oracle::mvc_size(&g);
            let n = g.num_vertices() as u32;
            for sched in BOTH_SCHEDULERS {
                let mut cfg = delta_cfg(4, sched);
                cfg.extract_witness = true;
                let out = run::<u32>(&g, n + 1, cfg);
                assert_eq!(out.best, opt, "seed {seed} {}", sched.name());
                let w = out.witness.expect("delta run must assemble a witness");
                assert_eq!(w.len() as u32, opt, "seed {seed} {}", sched.name());
                assert!(g.is_vertex_cover(&w), "seed {seed} {}", sched.name());
            }
        }
    }

    #[test]
    fn delta_pvc_stops_early_with_witness() {
        let g = generators::erdos_renyi(18, 0.22, 5);
        let opt = oracle::mvc_size(&g);
        for sched in BOTH_SCHEDULERS {
            let mut cfg = delta_cfg(4, sched);
            cfg.stop_on_improvement = true;
            cfg.extract_witness = true;
            let out = run::<u32>(&g, opt + 1, cfg);
            assert!(out.improved, "{}", sched.name());
            let w = out.witness.expect("stopped delta search must carry a witness");
            assert!(w.len() as u32 <= opt, "{}", sched.name());
            assert!(g.is_vertex_cover(&w), "{}", sched.name());
        }
    }

    #[test]
    fn delta_max_pin_depth_forces_periodic_bases() {
        // A tiny pin bound must freeze many more owned bases than the
        // default on the same search, while agreeing on the optimum.
        let g = generators::erdos_renyi(20, 0.25, 11);
        let ub = crate::solver::greedy::greedy_bound(&g);
        let mut tight = delta_cfg(1, SchedulerKind::WorkSteal);
        tight.max_pin_depth = 0;
        let loose = delta_cfg(1, SchedulerKind::WorkSteal);
        let a = run::<u32>(&g, ub, tight);
        let b = run::<u32>(&g, ub, loose);
        assert_eq!(a.best, b.best);
        assert!(
            a.stats.frame_bases > b.stats.frame_bases,
            "pin depth 0 must snapshot every branch ({} vs {})",
            a.stats.frame_bases,
            b.stats.frame_bases
        );
    }

    #[test]
    fn delta_reduces_payload_bytes_on_wide_views() {
        // A single wide component (no splits, induction irrelevant):
        // owned right children each copy the full-width degree array,
        // delta children freeze only cover suffixes. The baseline pins
        // NodeRepr::Owned explicitly so the comparison survives a
        // CAVC_NODE_REPR=delta environment.
        let g = generators::erdos_renyi(36, 0.15, 3);
        let ub = crate::solver::greedy::greedy_bound(&g);
        let owned_cfg = EngineCfg {
            node_repr: NodeRepr::Owned,
            ..cfg_with(true, true, 1, SchedulerKind::WorkSteal)
        };
        let owned = run::<u32>(&g, ub, owned_cfg);
        let delta = run::<u32>(&g, ub, delta_cfg(1, SchedulerKind::WorkSteal));
        assert_eq!(owned.best, delta.best);
        let bpn_owned = owned.stats.payload_bytes as f64 / owned.stats.payload_nodes.max(1) as f64;
        let bpn_delta = delta.stats.payload_bytes as f64 / delta.stats.payload_nodes.max(1) as f64;
        assert!(
            bpn_delta < bpn_owned,
            "delta bytes/node {bpn_delta:.1} must beat owned {bpn_owned:.1}"
        );
    }

    #[test]
    fn delta_stolen_children_materialize() {
        // Many workers on one connected component: every queued node is
        // a delta child, so any steal must materialize. Retry a few
        // seeds — steals are probabilistic, but 8 workers on a deep
        // search virtually always steal at least once.
        let mut saw_materialization = false;
        for seed in 0..10 {
            let g = generators::erdos_renyi(26, 0.25, seed);
            let ub = crate::solver::greedy::greedy_bound(&g);
            let out = run::<u32>(&g, ub, delta_cfg(8, SchedulerKind::WorkSteal));
            assert_eq!(out.best, oracle::mvc_size(&g), "seed {seed}");
            if out.stats.worklist_steals > 0 && out.stats.materializations > 0 {
                saw_materialization = true;
                break;
            }
        }
        assert!(saw_materialization, "no steal materialized across 10 seeds");
    }

    #[test]
    fn work_steal_observes_steals_on_split_workload() {
        // A many-component union keeps several workers busy; with the
        // work stealer the traffic shows up in the per-worker counters.
        let g = generators::union_of_random(8, 6, 10, 0.3, 21);
        let ub = crate::solver::greedy::greedy_bound(&g);
        let out = run::<u32>(&g, ub, cfg_with(true, true, 4, SchedulerKind::WorkSteal));
        assert_eq!(out.best, oracle::mvc_size(&g));
        assert!(!out.stats.sched_workers.is_empty());
        let pushes: u64 = out.stats.sched_workers.iter().map(|w| w.pushes).sum();
        assert!(pushes > 0);
    }
}
