//! Deterministic fault injection for the resident service.
//!
//! A [`FaultPlan`] is a small, seed-derived description of *where* a job
//! should misbehave: a panic at the Nth processed node, at the Nth
//! component split, during setup or finalization; a forced
//! allocation-failure path at the Nth tracked allocation; or an
//! artificially stalled worker. A [`FaultInjector`] carries the plan
//! plus the trigger counters and is threaded through `JobCfg` so the
//! engine's hot paths can consult it with one relaxed atomic bump.
//!
//! Everything is derived from a single `u64` seed through
//! [`SplitMix64`], so a failing chaos run is replayed exactly by
//! re-running with the same seed (`CAVC_FAULT_SEED`). The injector
//! never fires in production builds unless explicitly wired via
//! `JobOptions::fault` or the environment — the `Option<Arc<..>>` in
//! `JobCfg` is `None` on every default path.

use crate::util::rng::SplitMix64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Marker prefix on every injected panic payload, so tests (and the
/// service's failure log) can tell injected faults from real bugs.
pub const INJECTED_PANIC_TAG: &str = "cavc-fault:";

/// A deterministic, seed-derived description of one job's faults.
///
/// All trigger points are 1-based ordinals over the job's own event
/// stream (nodes processed, splits performed, allocations tracked), so
/// the same plan fires at the same logical point regardless of worker
/// count or scheduler — the *interleaving* varies, the fault does not.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed this plan was derived from (kept for replay logs).
    pub seed: u64,
    /// Panic when the job processes its Nth search node.
    pub panic_at_node: Option<u64>,
    /// Panic when the job performs its Nth component split.
    pub panic_at_split: Option<u64>,
    /// Panic inside job setup (prepare/root-push).
    pub panic_in_setup: bool,
    /// Panic inside finalization (outcome assembly).
    pub panic_in_finalize: bool,
    /// Take the forced allocation-failure path at the Nth tracked
    /// payload allocation.
    pub alloc_fail_at: Option<u64>,
    /// Stall the worker that reaches the Nth node for the given
    /// duration (models a descheduled/preempted worker, not a crash).
    pub stall_at_node: Option<(u64, Duration)>,
}

impl FaultPlan {
    /// A plan that injects nothing (useful as a chaos-suite control).
    pub fn none(seed: u64) -> Self {
        FaultPlan {
            seed,
            panic_at_node: None,
            panic_at_split: None,
            panic_in_setup: false,
            panic_in_finalize: false,
            alloc_fail_at: None,
            stall_at_node: None,
        }
    }

    /// Derive a plan from a seed. Exactly one *primary* fault is chosen
    /// (panic at node/split/setup/finalize, or an allocation failure);
    /// with ~25% probability an unrelated worker stall is layered on
    /// top so crashes are exercised under skewed progress too.
    pub fn from_seed(seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let mut plan = FaultPlan::none(seed);
        // Ordinals are kept small so faults land while the job is still
        // branching (chaos graphs are sized to expand >> 64 nodes).
        match rng.next_below(5) {
            0 => plan.panic_at_node = Some(1 + rng.next_below(48)),
            1 => plan.panic_at_split = Some(1 + rng.next_below(8)),
            2 => plan.panic_in_setup = true,
            3 => plan.panic_in_finalize = true,
            _ => plan.alloc_fail_at = Some(1 + rng.next_below(48)),
        }
        if rng.chance(0.25) {
            let ms = 1 + rng.next_below(20);
            plan.stall_at_node = Some((1 + rng.next_below(32), Duration::from_millis(ms)));
        }
        plan
    }

    /// Read a plan from `CAVC_FAULT_SEED` (decimal u64), if set.
    pub fn from_env() -> Option<Self> {
        let seed = std::env::var("CAVC_FAULT_SEED").ok()?.trim().parse::<u64>().ok()?;
        Some(Self::from_seed(seed))
    }

    /// One-line replay log entry (`seed=.. faults=[..]`).
    pub fn describe(&self) -> String {
        let mut faults = Vec::new();
        if let Some(n) = self.panic_at_node {
            faults.push(format!("panic@node:{n}"));
        }
        if let Some(n) = self.panic_at_split {
            faults.push(format!("panic@split:{n}"));
        }
        if self.panic_in_setup {
            faults.push("panic@setup".to_string());
        }
        if self.panic_in_finalize {
            faults.push("panic@finalize".to_string());
        }
        if let Some(n) = self.alloc_fail_at {
            faults.push(format!("alloc-fail:{n}"));
        }
        if let Some((n, d)) = self.stall_at_node {
            faults.push(format!("stall@node:{n}:{}ms", d.as_millis()));
        }
        if faults.is_empty() {
            faults.push("none".to_string());
        }
        format!("seed={} faults=[{}]", self.seed, faults.join(","))
    }
}

/// Shared trigger state for one job's [`FaultPlan`]. Hot-path hooks are
/// a relaxed fetch-add plus a compare against the plan's ordinals; with
/// no plan wired in, none of this exists (`Option<Arc<FaultInjector>>`
/// is `None`).
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    nodes: AtomicU64,
    splits: AtomicU64,
    allocs: AtomicU64,
    /// Panics actually raised by this injector (setup/node/split/
    /// finalize/alloc all count; stalls do not).
    fired_panics: AtomicU64,
    fired_stalls: AtomicU64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        FaultInjector {
            plan,
            nodes: AtomicU64::new(0),
            splits: AtomicU64::new(0),
            allocs: AtomicU64::new(0),
            fired_panics: AtomicU64::new(0),
            fired_stalls: AtomicU64::new(0),
        }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Panics this injector has raised so far.
    pub fn fired_panics(&self) -> u64 {
        self.fired_panics.load(Ordering::Relaxed)
    }

    /// Worker stalls this injector has performed so far.
    pub fn fired_stalls(&self) -> u64 {
        self.fired_stalls.load(Ordering::Relaxed)
    }

    /// Hook: a search node is about to be processed.
    pub fn on_node(&self) {
        let n = self.nodes.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some((at, dur)) = self.plan.stall_at_node {
            if n == at {
                self.fired_stalls.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(dur);
            }
        }
        if self.plan.panic_at_node == Some(n) {
            self.fired_panics.fetch_add(1, Ordering::Relaxed);
            panic!("{INJECTED_PANIC_TAG} node #{n} (seed {})", self.plan.seed);
        }
    }

    /// Hook: a component split is about to be performed.
    pub fn on_split(&self) {
        let n = self.splits.fetch_add(1, Ordering::Relaxed) + 1;
        if self.plan.panic_at_split == Some(n) {
            self.fired_panics.fetch_add(1, Ordering::Relaxed);
            panic!("{INJECTED_PANIC_TAG} split #{n} (seed {})", self.plan.seed);
        }
    }

    /// Hook: a payload allocation was just tracked. Models the paper's
    /// out-of-slots condition: the engine has no fallible-alloc path,
    /// so the forced failure surfaces as a contained panic the service
    /// must absorb exactly like a real allocator abort.
    pub fn on_alloc(&self) {
        let n = self.allocs.fetch_add(1, Ordering::Relaxed) + 1;
        if self.plan.alloc_fail_at == Some(n) {
            self.fired_panics.fetch_add(1, Ordering::Relaxed);
            panic!("{INJECTED_PANIC_TAG} allocation failure #{n} (seed {})", self.plan.seed);
        }
    }

    /// Hook: job setup is running (after admission, before root push).
    pub fn on_setup(&self) {
        if self.plan.panic_in_setup {
            self.fired_panics.fetch_add(1, Ordering::Relaxed);
            panic!("{INJECTED_PANIC_TAG} setup (seed {})", self.plan.seed);
        }
    }

    /// Hook: finalization is assembling the outcome.
    pub fn on_finalize(&self) {
        if self.plan.panic_in_finalize {
            self.fired_panics.fetch_add(1, Ordering::Relaxed);
            panic!("{INJECTED_PANIC_TAG} finalize (seed {})", self.plan.seed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_deterministic_and_pick_one_primary_fault() {
        for seed in 0..500u64 {
            let a = FaultPlan::from_seed(seed);
            let b = FaultPlan::from_seed(seed);
            assert_eq!(a, b, "seed {seed} not deterministic");
            let primaries = [
                a.panic_at_node.is_some(),
                a.panic_at_split.is_some(),
                a.panic_in_setup,
                a.panic_in_finalize,
                a.alloc_fail_at.is_some(),
            ]
            .iter()
            .filter(|&&x| x)
            .count();
            assert_eq!(primaries, 1, "seed {seed}: {}", a.describe());
        }
    }

    #[test]
    fn injector_fires_at_exact_ordinals() {
        let mut plan = FaultPlan::none(7);
        plan.panic_at_node = Some(3);
        let inj = FaultInjector::new(plan);
        inj.on_node();
        inj.on_node();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| inj.on_node()))
            .expect_err("third node must panic");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.starts_with(INJECTED_PANIC_TAG), "payload: {msg}");
        assert_eq!(inj.fired_panics(), 1);
        // past the trigger, the hook is inert again
        inj.on_node();
        assert_eq!(inj.fired_panics(), 1);
    }

    #[test]
    fn describe_round_trips_the_seed() {
        let p = FaultPlan::from_seed(42);
        assert!(p.describe().starts_with("seed=42 "));
    }
}
