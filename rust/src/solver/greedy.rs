//! Greedy approximate vertex cover — the initial upper bound for the
//! branch-and-reduce search (paper §II-B: "best is an approximate minimum
//! computed by an approximate algorithm such as a greedy one").

use crate::graph::Graph;
use std::collections::BinaryHeap;

/// Max-degree greedy cover: repeatedly add the highest-degree vertex and
/// delete it, until no edges remain. Returns the cover (original ids).
pub fn greedy_cover(g: &Graph) -> Vec<u32> {
    let n = g.num_vertices();
    let mut deg: Vec<u32> = (0..n as u32).map(|v| g.degree(v)).collect();
    // lazy-deletion max-heap of (degree, vertex)
    let mut heap: BinaryHeap<(u32, u32)> = (0..n as u32)
        .filter(|&v| deg[v as usize] > 0)
        .map(|v| (deg[v as usize], v))
        .collect();
    let mut cover = Vec::new();
    let mut edges: u64 = g.num_edges() as u64;
    while edges > 0 {
        let (d, v) = heap.pop().expect("edges remain but heap empty");
        if deg[v as usize] != d || d == 0 {
            continue; // stale entry
        }
        cover.push(v);
        deg[v as usize] = 0;
        edges -= d as u64;
        for &w in g.neighbors(v) {
            if deg[w as usize] > 0 {
                deg[w as usize] -= 1;
                if deg[w as usize] > 0 {
                    heap.push((deg[w as usize], w));
                }
            }
        }
    }
    cover
}

/// Greedy upper bound size.
pub fn greedy_bound(g: &Graph) -> u32 {
    greedy_cover(g).len() as u32
}

/// 2-approximation via maximal matching (both endpoints of each matched
/// edge). Used as a sanity cross-check in tests: `opt ≤ greedy ≤ 2·opt`
/// does not hold for max-degree greedy in theory, but matching gives a
/// certified `≤ 2·opt` bound.
pub fn matching_cover(g: &Graph) -> Vec<u32> {
    let matched =
        crate::reduce::matching::greedy_maximal_matching(g.num_vertices(), g.edges());
    let mut used = vec![false; g.num_vertices()];
    let mut cover = Vec::new();
    for (u, v) in g.edges() {
        if !used[u as usize] && !used[v as usize] && matched[u as usize] && matched[v as usize] {
            // endpoints of a matched edge: take both
            used[u as usize] = true;
            used[v as usize] = true;
            cover.push(u);
            cover.push(v);
        }
    }
    // matching may leave some edges covered by only the matched marks;
    // fall back: any uncovered edge gets an endpoint (cannot happen for a
    // true maximal matching, guarded in debug builds).
    debug_assert!(g.is_vertex_cover(&cover));
    cover
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn greedy_is_a_cover() {
        for seed in 0..10 {
            let g = generators::erdos_renyi(50, 0.08, seed);
            let c = greedy_cover(&g);
            assert!(g.is_vertex_cover(&c), "seed {seed}");
        }
    }

    #[test]
    fn greedy_star_takes_hub() {
        let g = generators::star(10);
        assert_eq!(greedy_cover(&g), vec![0]);
    }

    #[test]
    fn greedy_never_below_optimal() {
        for seed in 0..8 {
            let g = generators::erdos_renyi(14, 0.25, seed);
            let opt = crate::solver::oracle::mvc_size(&g);
            assert!(greedy_bound(&g) >= opt, "seed {seed}");
        }
    }

    #[test]
    fn matching_cover_is_cover_and_2approx() {
        for seed in 0..8 {
            let g = generators::erdos_renyi(16, 0.2, seed);
            let c = matching_cover(&g);
            assert!(g.is_vertex_cover(&c), "seed {seed}");
            let opt = crate::solver::oracle::mvc_size(&g);
            assert!(c.len() as u32 <= 2 * opt.max(1), "seed {seed}");
        }
    }

    #[test]
    fn empty_graph_empty_cover() {
        let g = Graph::from_edges(5, &[]);
        assert!(greedy_cover(&g).is_empty());
    }
}
