//! Cross-job component memoization: a sharded, concurrent
//! component → solution cache owned by [`VcService`](super::VcService)
//! and consulted at every component dispatch.
//!
//! # Why this works
//!
//! Component-aware branching (§III-C) already isolates every split
//! component into its own registry child slot, and tree induction
//! (§IV-B) re-numbers each component into a *canonical* compact CSR:
//! vertices are renamed `0..k` in ascending order of their parent-view
//! ids and each adjacency row is sorted. Two structurally identical
//! components therefore induce **bit-identical** CSR arrays, no matter
//! which job, graph, or tree depth they came from. That canonical form
//! is the cache key: a 64-bit fingerprint of the induced
//! `(row_ptr, adj)` arrays (the row pointers *are* the degree profile),
//! verified on lookup by exact comparison against the retained arrays,
//! so hash collisions can never corrupt an answer.
//!
//! # Hit flow through the fold algebra
//!
//! A component's solved value enters its parent through the registry
//! fold (`val = Sum` over child slots, witness side-table concatenation).
//! A cache hit feeds that algebra directly: the engine calls
//! `Registry::add_solved_component(parent, mvc)` — exactly how special
//! (clique/chain) components are folded in — appends the cached cover
//! (translated through the component's `back` map into root-residual
//! ids) to the parent's witness row, and **never registers a child
//! slot**: the entire subtree is skipped.
//!
//! # The exact-covers-only invariant
//!
//! Only *exact* component covers may be published:
//!
//! * **Bound-pruned subtrees are rejected at the fold.** A child slot
//!   finishing with `best` is exact iff `best < limit` (some leaf beat
//!   the pruning bound, so no pruned subtree could have held anything
//!   smaller) or `limit == best0` (the search ran as pure
//!   branch-and-bound from the always-achievable `|C|-1` cover).
//!   PVC jobs (`propagate` mode) never publish at all.
//! * **Truncated subtrees are rejected by poisoning.** Every site that
//!   can raise the job's stop flag (cancel, deadline, worker-failure,
//!   finalize-panic) first marks the job's [`JobMemo`] poisoned; the
//!   in-flight folds that fire while workers drain are discarded.
//! * **Failed jobs are retracted.** Entries are versioned by publishing
//!   job id; a job that terminates `Failed` retracts anything it
//!   published as belt-and-suspenders on top of poisoning.
//!
//! # Publication without a data race
//!
//! The fold fires while the completing descendant still holds the
//! component's view `Arc`, so the fold *happens-before* the last view
//! drop. Publication is therefore two-phase: the fold observer moves an
//! exact result from the `pending` (ctx-keyed) to the `ready`
//! (fingerprint-keyed) table, and the actual insert happens when
//! `recycle_view_buffers` drops the last view reference — at which
//! point the engine hands the cache the component's own `row_ptr`/`adj`
//! buffers as the verification key instead of returning them to the
//! `BufferPool` (the pool simply never sees them again; evicted entries
//! are dropped, not re-pooled).
//!
//! # Budget and eviction
//!
//! The cache is 16-way sharded; each shard runs a CLOCK (second-chance)
//! ring over its entries with a per-shard slice of the byte budget
//! (default: [`OccupancyModel::memo_budget_bytes`]
//! (super::occupancy::OccupancyModel::memo_budget_bytes)). Resident
//! bytes are charged against the service admission ledger through
//! [`MemoLedger`], so the memory watchdog sees them — and to keep the
//! watchdog ladder honest the cache is the *first* rung shed under
//! pressure: the dispatcher drops the whole cache before holding
//! throughput-lane dispatch, and an over-hard-limit admission sheds it
//! before refusing a submit.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Number of independent shards (and CLOCK rings) in a [`MemoCache`].
const SHARDS: usize = 16;

/// Fixed per-entry overhead charged on top of the array payloads
/// (hash-map slot, ring slot, entry header).
const ENTRY_OVERHEAD: u64 = 64;

/// Counters describing cache behaviour, surfaced through
/// `ServiceStats::memo` and the `--jobs` batch summary.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct MemoStats {
    /// Component dispatches that consulted the cache.
    pub lookups: u64,
    /// Lookups that skipped a subtree (exact CSR match, witness
    /// available when required).
    pub hits: u64,
    /// Lookups that fell through to a normal branch.
    pub misses: u64,
    /// Exact component solutions published into the cache.
    pub inserts: u64,
    /// Entries dropped by CLOCK pressure, pressure shed, or retraction.
    pub evictions: u64,
    /// Resident cache bytes (arrays + per-entry overhead).
    pub bytes: u64,
    /// Coarse lower-bound estimate of tree nodes not expanded thanks to
    /// hits: the component size `k` per hit (an exact subtree on `k`
    /// vertices has at least `k` nodes on its leftmost spine).
    pub saved_nodes: u64,
}

/// Byte-accounting hook: the cache charges resident bytes against the
/// owning service's admission ledger so the memory watchdog sees them.
pub trait MemoLedger: Send + Sync {
    /// Account `bytes` of newly resident cache memory.
    fn charge(&self, bytes: u64);
    /// Return `bytes` of freed cache memory.
    fn release(&self, bytes: u64);
}

/// One cached exact component solution. The retained `row_ptr`/`adj`
/// arrays are the verification key: a fingerprint match alone is never
/// trusted.
struct Entry {
    row_ptr: Vec<u32>,
    adj: Vec<u32>,
    /// Exact MVC size of the component.
    mvc: u32,
    /// Exact cover in component-local ids (ascending), when the
    /// publishing job extracted witnesses.
    cover: Option<Box<[u32]>>,
    /// Accounted bytes (arrays + overhead).
    bytes: u64,
    /// Publishing job id, for retraction.
    job: u64,
    /// CLOCK second-chance bit, set on every hit.
    ref_bit: bool,
}

impl Entry {
    fn matches(&self, row_ptr: &[u32], adj: &[u32]) -> bool {
        self.row_ptr[..] == *row_ptr && self.adj[..] == *adj
    }
}

#[derive(Default)]
struct Shard {
    map: HashMap<u64, Entry>,
    /// CLOCK ring of fingerprints present in `map`.
    ring: Vec<u64>,
    hand: usize,
    bytes: u64,
}

/// Sharded, concurrent component → solution cache. See the module docs
/// for the key scheme, exactness invariant, and eviction policy.
pub struct MemoCache {
    shards: Vec<Mutex<Shard>>,
    /// Per-shard slice of the byte budget. Atomic so the service's
    /// self-tuning controller can re-plan the budget from live ledger
    /// bytes without stopping the cache ([`set_budget`]
    /// (MemoCache::set_budget)).
    shard_budget: AtomicU64,
    budget: AtomicU64,
    ledger: Option<Arc<dyn MemoLedger>>,
    lookups: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
    evictions: AtomicU64,
    bytes: AtomicU64,
    saved_nodes: AtomicU64,
}

impl fmt::Debug for MemoCache {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("MemoCache")
            .field("budget", &self.budget.load(Ordering::Relaxed))
            .field("bytes", &self.bytes.load(Ordering::Relaxed))
            .finish_non_exhaustive()
    }
}

/// A cache lock section never runs caller code, so a poisoned mutex
/// (worker panicked elsewhere while unwinding through a drop) only
/// guards plain counters: continue with the inner state.
fn lock(m: &Mutex<Shard>) -> MutexGuard<'_, Shard> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl MemoCache {
    /// A cache bounded to `budget` resident bytes, charging them
    /// against `ledger` when present.
    pub fn new(budget: u64, ledger: Option<Arc<dyn MemoLedger>>) -> Self {
        MemoCache {
            shards: (0..SHARDS).map(|_| Mutex::new(Shard::default())).collect(),
            shard_budget: AtomicU64::new((budget / SHARDS as u64).max(1)),
            budget: AtomicU64::new(budget),
            ledger,
            lookups: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            saved_nodes: AtomicU64::new(0),
        }
    }

    /// Configured byte budget.
    pub fn budget(&self) -> u64 {
        self.budget.load(Ordering::Relaxed)
    }

    /// Re-plan the byte budget online. Shrinking does not evict
    /// eagerly — the CLOCK sweep in the next insert brings each shard
    /// back under its new slice, and the watchdog ladder (shed) still
    /// covers acute pressure.
    pub fn set_budget(&self, budget: u64) {
        self.budget.store(budget, Ordering::Relaxed);
        self.shard_budget.store((budget / SHARDS as u64).max(1), Ordering::Relaxed);
    }

    fn shard_of(fp: u64) -> usize {
        // High bits: the fingerprint finalizer avalanches, and the low
        // bits already pick the hash-map bucket.
        (fp >> 59) as usize % SHARDS
    }

    fn entry_bytes(row_ptr: &[u32], adj: &[u32], cover: Option<&[u32]>) -> u64 {
        let words = row_ptr.len() + adj.len() + cover.map_or(0, <[u32]>::len);
        words as u64 * 4 + ENTRY_OVERHEAD
    }

    /// Look up a component by fingerprint, verifying the induced CSR
    /// byte-for-byte. `need_cover` lookups (witness-extracting jobs)
    /// treat an entry without a stored cover as a miss. Returns the
    /// exact MVC size and, when stored, the cover in component-local
    /// ids.
    pub fn lookup(
        &self,
        fp: u64,
        row_ptr: &[u32],
        adj: &[u32],
        need_cover: bool,
    ) -> Option<(u32, Option<Vec<u32>>)> {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let mut s = lock(&self.shards[Self::shard_of(fp)]);
        if let Some(e) = s.map.get_mut(&fp) {
            if e.matches(row_ptr, adj) && (!need_cover || e.cover.is_some()) {
                e.ref_bit = true;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Some((e.mvc, e.cover.as_ref().map(|c| c.to_vec())));
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Publish an exact component solution, taking ownership of the
    /// induced CSR arrays as the verification key. Returns the arrays
    /// when the cache did *not* take them (duplicate fingerprint,
    /// entry larger than a shard's budget slice) so the caller can
    /// recycle them to its `BufferPool`.
    pub fn insert(
        &self,
        fp: u64,
        row_ptr: Vec<u32>,
        adj: Vec<u32>,
        mvc: u32,
        cover: Option<Box<[u32]>>,
        job: u64,
    ) -> Option<(Vec<u32>, Vec<u32>)> {
        let bytes = Self::entry_bytes(&row_ptr, &adj, cover.as_deref());
        let shard_budget = self.shard_budget.load(Ordering::Relaxed);
        if bytes > shard_budget {
            return Some((row_ptr, adj));
        }
        let mut s = lock(&self.shards[Self::shard_of(fp)]);
        if s.map.contains_key(&fp) {
            // First publisher wins; identical components re-derive the
            // same exact answer, so nothing is lost.
            return Some((row_ptr, adj));
        }
        // CLOCK (second-chance) sweep until the new entry fits.
        let mut freed = 0u64;
        let mut evicted = 0u64;
        while s.bytes + bytes > shard_budget && !s.ring.is_empty() {
            let hand = s.hand % s.ring.len();
            let victim = s.ring[hand];
            let spare = match s.map.get_mut(&victim) {
                Some(e) if e.ref_bit => {
                    e.ref_bit = false;
                    s.hand = hand + 1;
                    continue;
                }
                Some(e) => e.bytes,
                // Ring hygiene: `retract` removes map entries lazily.
                None => 0,
            };
            s.ring.swap_remove(hand);
            s.hand = hand;
            if spare > 0 {
                s.map.remove(&victim);
                s.bytes -= spare;
                freed += spare;
                evicted += 1;
            }
        }
        s.ring.push(fp);
        s.bytes += bytes;
        s.map.insert(fp, Entry { row_ptr, adj, mvc, cover, bytes, job, ref_bit: false });
        // Account while still holding the shard: an entry visible in the
        // map is always already charged, so a concurrent shed/retract
        // can never release bytes from the admission ledger before their
        // matching charge (which would underflow the watchdog counter).
        self.evictions.fetch_add(evicted, Ordering::Relaxed);
        self.inserts.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.bytes.fetch_sub(freed, Ordering::Relaxed);
        if let Some(l) = &self.ledger {
            l.charge(bytes);
            if freed > 0 {
                l.release(freed);
            }
        }
        drop(s);
        None
    }

    /// Drop every entry a job published. Called when a job terminates
    /// `Failed`: poisoning already discards in-flight folds, this
    /// retracts anything that slipped through before the failure.
    pub fn retract(&self, job: u64) {
        let mut freed = 0u64;
        let mut evicted = 0u64;
        for sh in &self.shards {
            let mut s = lock(sh);
            let before = s.map.len();
            s.map.retain(|_, e| {
                if e.job == job {
                    freed += e.bytes;
                    false
                } else {
                    true
                }
            });
            let removed = before - s.map.len();
            if removed > 0 {
                evicted += removed as u64;
                let mut ring = std::mem::take(&mut s.ring);
                ring.retain(|fp| s.map.contains_key(fp));
                s.ring = ring;
                s.hand = 0;
                s.bytes = s.map.values().map(|e| e.bytes).sum();
            }
        }
        if evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
            self.bytes.fetch_sub(freed, Ordering::Relaxed);
            if let Some(l) = &self.ledger {
                l.release(freed);
            }
        }
    }

    /// Drop everything. The first rung of the degradation ladder:
    /// memory pressure sheds the cache before the service holds
    /// dispatch or refuses submits. Returns the bytes freed.
    pub fn shed(&self) -> u64 {
        let mut freed = 0u64;
        let mut evicted = 0u64;
        for sh in &self.shards {
            let mut s = lock(sh);
            freed += s.bytes;
            evicted += s.map.len() as u64;
            s.map.clear();
            s.ring.clear();
            s.hand = 0;
            s.bytes = 0;
        }
        if freed > 0 || evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
            self.bytes.fetch_sub(freed, Ordering::Relaxed);
            if let Some(l) = &self.ledger {
                l.release(freed);
            }
        }
        freed
    }

    /// Resident cache bytes (as charged to the ledger).
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn note_saved(&self, nodes: u64) {
        self.saved_nodes.fetch_add(nodes, Ordering::Relaxed);
    }

    /// Snapshot of the cache counters.
    pub fn stats(&self) -> MemoStats {
        MemoStats {
            lookups: self.lookups.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            saved_nodes: self.saved_nodes.load(Ordering::Relaxed),
        }
    }
}

/// A component queued for publication: registered at dispatch (miss)
/// time, resolved by the fold observer.
struct Pending {
    fp: u64,
    /// The child slot's initial bound `|C| - 1`; `limit == best0` means
    /// the slot ran as pure branch-and-bound (see module docs).
    best0: u32,
}

/// An exact result awaiting its buffer hand-off: the fold proved
/// exactness, `publish_at_recycle` supplies the CSR key.
struct Ready {
    mvc: u32,
    /// Winning cover in root-residual ids (the witness side-table's id
    /// space); translated to component-local ids at insert time.
    cover: Option<Box<[u32]>>,
}

/// Per-job view of the cache, carried in `JobCfg`. Tracks which child
/// slots should publish on completion (`pending` → `ready` two-phase
/// hand-off, see module docs) and whether the job has been poisoned by
/// a cancel/deadline/failure — in which case nothing it folds is
/// trusted.
pub struct JobMemo {
    job: u64,
    cache: Arc<MemoCache>,
    /// MVC-mode jobs publish; PVC (`propagate`) jobs only consume.
    publish: bool,
    poisoned: AtomicBool,
    pending: Mutex<HashMap<u32, Pending>>,
    ready: Mutex<HashMap<u64, Ready>>,
}

impl fmt::Debug for JobMemo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("JobMemo")
            .field("job", &self.job)
            .field("publish", &self.publish)
            .field("poisoned", &self.poisoned.load(Ordering::SeqCst))
            .finish_non_exhaustive()
    }
}

impl JobMemo {
    /// A job's cache handle. `publish` is false for PVC jobs, whose
    /// bound-pruned components must never be cached.
    pub fn new(job: u64, cache: Arc<MemoCache>, publish: bool) -> Self {
        JobMemo {
            job,
            cache,
            publish,
            poisoned: AtomicBool::new(false),
            pending: Mutex::new(HashMap::new()),
            ready: Mutex::new(HashMap::new()),
        }
    }

    /// The shared cache this job consults.
    pub fn cache(&self) -> &Arc<MemoCache> {
        &self.cache
    }

    /// Whether exact results of this job may enter the cache.
    pub fn publishes(&self) -> bool {
        self.publish
    }

    /// Mark every in-flight and future fold of this job untrusted.
    /// MUST be called (SeqCst) *before* raising the job's stop flag:
    /// workers poll stop and then complete their truncated subtrees,
    /// so the poison store has to be visible first.
    pub fn poison(&self) {
        self.poisoned.store(true, Ordering::SeqCst);
    }

    fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }

    /// Consult the cache for a component about to be dispatched.
    /// On a hit, credits the saved-subtree estimate with the component
    /// size `k`.
    pub fn lookup(
        &self,
        fp: u64,
        row_ptr: &[u32],
        adj: &[u32],
        need_cover: bool,
    ) -> Option<(u32, Option<Vec<u32>>)> {
        let hit = self.cache.lookup(fp, row_ptr, adj, need_cover);
        if hit.is_some() {
            self.cache.note_saved(row_ptr.len().saturating_sub(1) as u64);
        }
        hit
    }

    /// Record that child slot `ctx` is a cache-miss component with
    /// fingerprint `fp` and initial bound `best0`, to be published if
    /// its fold proves exactness.
    pub fn register_pending(&self, ctx: u32, fp: u64, best0: u32) {
        if !self.publish || self.is_poisoned() {
            return;
        }
        let mut p = self.pending.lock().unwrap_or_else(|e| e.into_inner());
        p.insert(ctx, Pending { fp, best0 });
    }

    /// Registry fold observer: child slot `ctx` folded with final value
    /// `best` under pruning bound `limit`; `cover` is the winning
    /// witness (root-residual ids) when the job extracts witnesses.
    /// Moves exact results to the `ready` table (see module docs for
    /// the exactness gate).
    pub fn on_fold(&self, ctx: u32, best: u32, limit: u32, cover: Option<&[u32]>) {
        let Some(p) = self.pending.lock().unwrap_or_else(|e| e.into_inner()).remove(&ctx) else {
            return;
        };
        if self.is_poisoned() {
            return;
        }
        // Exactness gate: `best < limit` means a leaf beat the pruning
        // bound (every pruned subtree held only covers >= limit >
        // best); `limit == best0` means the slot never inherited a
        // tighter parent bound, so the search was pure B&B from the
        // always-achievable |C|-1 cover.
        if best < limit || limit == p.best0 {
            let mut r = self.ready.lock().unwrap_or_else(|e| e.into_inner());
            r.insert(p.fp, Ready { mvc: best, cover: cover.map(Box::from) });
        }
    }

    /// Buffer hand-off at last view drop: if slot fingerprint `fp` has
    /// a ready exact result, publish it using the view's own CSR arrays
    /// as the verification key and translate the cover from
    /// root-residual ids to component-local ids through the (strictly
    /// ascending) `back` map. Returns the arrays when the caller should
    /// recycle them to the pool (no ready result, poisoned, or the
    /// cache declined).
    pub fn publish_at_recycle(
        &self,
        fp: u64,
        row_ptr: Vec<u32>,
        adj: Vec<u32>,
        back: &[u32],
    ) -> Option<(Vec<u32>, Vec<u32>)> {
        let ready = self.ready.lock().unwrap_or_else(|e| e.into_inner()).remove(&fp);
        let Some(r) = ready else {
            return Some((row_ptr, adj));
        };
        if self.is_poisoned() {
            return Some((row_ptr, adj));
        }
        let cover = match r.cover {
            Some(c) => {
                let mut local: Vec<u32> = Vec::with_capacity(c.len());
                for &root in c.iter() {
                    match back.binary_search(&root) {
                        Ok(l) => local.push(l as u32),
                        // A cover vertex outside the component: the
                        // slot's witness row was contaminated (should
                        // be impossible) — do not cache a wrong cover.
                        Err(_) => return Some((row_ptr, adj)),
                    }
                }
                local.sort_unstable();
                Some(local.into_boxed_slice())
            }
            None => None,
        };
        self.cache.insert(fp, row_ptr, adj, r.mvc, cover, self.job)
    }

    /// Retract everything this job published (terminal failure path).
    pub fn retract(&self) {
        self.cache.retract(self.job);
    }
}

/// Process-wide default for whether memoization is enabled, from
/// `CAVC_MEMO` (`on`/`off`, `1`/`0`, `true`/`false`). `None` when
/// unset or unparsable.
pub fn env_memo_default() -> Option<bool> {
    let v = std::env::var("CAVC_MEMO").ok()?;
    match v.trim().to_ascii_lowercase().as_str() {
        "on" | "1" | "true" | "yes" => Some(true),
        "off" | "0" | "false" | "no" => Some(false),
        _ => None,
    }
}

/// Process-wide default cache byte budget, from `CAVC_MEMO_BYTES`.
pub fn env_memo_bytes() -> Option<u64> {
    std::env::var("CAVC_MEMO_BYTES").ok()?.trim().parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicI64;

    #[derive(Default)]
    struct TestLedger {
        net: AtomicI64,
    }

    impl MemoLedger for TestLedger {
        fn charge(&self, bytes: u64) {
            self.net.fetch_add(bytes as i64, Ordering::SeqCst);
        }
        fn release(&self, bytes: u64) {
            self.net.fetch_sub(bytes as i64, Ordering::SeqCst);
        }
    }

    fn csr(k: u32) -> (Vec<u32>, Vec<u32>) {
        // Path on k vertices in canonical induced form.
        let mut row_ptr = vec![0u32];
        let mut adj = Vec::new();
        for v in 0..k {
            if v > 0 {
                adj.push(v - 1);
            }
            if v + 1 < k {
                adj.push(v + 1);
            }
            row_ptr.push(adj.len() as u32);
        }
        (row_ptr, adj)
    }

    #[test]
    fn lookup_verifies_exact_arrays() {
        let c = MemoCache::new(1 << 20, None);
        let (rp, aj) = csr(5);
        assert!(c.insert(7, rp.clone(), aj.clone(), 2, None, 1).is_none());
        assert_eq!(c.lookup(7, &rp, &aj, false), Some((2, None)));
        // Same fingerprint, different arrays: collision must miss.
        let (rp2, aj2) = csr(6);
        assert_eq!(c.lookup(7, &rp2, &aj2, false), None);
        let s = c.stats();
        assert_eq!((s.lookups, s.hits, s.misses, s.inserts), (2, 1, 1, 1));
        assert!(s.bytes > 0);
    }

    #[test]
    fn need_cover_misses_value_only_entries() {
        let c = MemoCache::new(1 << 20, None);
        let (rp, aj) = csr(4);
        assert!(c.insert(1, rp.clone(), aj.clone(), 2, None, 1).is_none());
        assert_eq!(c.lookup(1, &rp, &aj, true), None);
        let cover: Box<[u32]> = vec![1, 2].into_boxed_slice();
        let (rp2, aj2) = csr(3);
        assert!(c.insert(2, rp2.clone(), aj2.clone(), 1, Some(cover), 1).is_none());
        let (mvc, cv) = c.lookup(2, &rp2, &aj2, true).unwrap();
        assert_eq!((mvc, cv.as_deref()), (1, Some(&[1u32, 2][..])));
    }

    #[test]
    fn duplicate_insert_returns_buffers() {
        let c = MemoCache::new(1 << 20, None);
        let (rp, aj) = csr(5);
        assert!(c.insert(9, rp.clone(), aj.clone(), 2, None, 1).is_none());
        let back = c.insert(9, rp.clone(), aj.clone(), 2, None, 2);
        assert_eq!(back, Some((rp, aj)));
        assert_eq!(c.stats().inserts, 1);
    }

    #[test]
    fn clock_eviction_stays_under_budget_and_ledgered() {
        let ledger = Arc::new(TestLedger::default());
        // Tiny budget: each shard holds roughly one small entry.
        let c = MemoCache::new(4096, Some(ledger.clone() as Arc<dyn MemoLedger>));
        for i in 0..256u64 {
            let (rp, aj) = csr(8);
            c.insert(i.wrapping_mul(0x9e3779b97f4a7c15), rp, aj, 4, None, 1);
        }
        let s = c.stats();
        assert!(s.evictions > 0, "tiny budget must evict");
        assert!(s.bytes <= 4096 + ENTRY_OVERHEAD * SHARDS as u64);
        assert_eq!(ledger.net.load(Ordering::SeqCst), s.bytes as i64);
        c.shed();
        assert_eq!(c.bytes(), 0);
        assert_eq!(ledger.net.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn set_budget_replans_online() {
        let c = MemoCache::new(1 << 20, None);
        let (rp, aj) = csr(5);
        assert!(c.insert(1, rp, aj, 2, None, 1).is_none());
        // Shrink so no entry fits a shard slice any more: inserts decline.
        c.set_budget(16);
        assert_eq!(c.budget(), 16);
        let (rp2, aj2) = csr(6);
        assert!(c.insert(2, rp2.clone(), aj2.clone(), 3, None, 1).is_some());
        // Grow back: inserts resume.
        c.set_budget(1 << 20);
        assert!(c.insert(2, rp2, aj2, 3, None, 1).is_none());
    }

    #[test]
    fn retract_drops_only_that_jobs_entries() {
        let ledger = Arc::new(TestLedger::default());
        let c = MemoCache::new(1 << 20, Some(ledger.clone() as Arc<dyn MemoLedger>));
        let (rp, aj) = csr(5);
        let (rp2, aj2) = csr(6);
        assert!(c.insert(1, rp.clone(), aj.clone(), 2, None, 10).is_none());
        assert!(c.insert(2, rp2.clone(), aj2.clone(), 3, None, 11).is_none());
        c.retract(10);
        assert_eq!(c.lookup(1, &rp, &aj, false), None);
        assert_eq!(c.lookup(2, &rp2, &aj2, false), Some((3, None)));
        assert_eq!(ledger.net.load(Ordering::SeqCst), c.bytes() as i64);
    }

    #[test]
    fn job_memo_two_phase_publish_and_exactness_gate() {
        let c = Arc::new(MemoCache::new(1 << 20, None));
        let m = JobMemo::new(1, c.clone(), true);
        let (rp, aj) = csr(5);

        // Pruned at the limit with an inherited tighter bound: not exact.
        m.register_pending(100, 77, 4);
        m.on_fold(100, 3, 3, None); // best == limit, limit != best0
        assert_eq!(
            m.publish_at_recycle(77, rp.clone(), aj.clone(), &[0, 1, 2, 3, 4]),
            Some((rp.clone(), aj.clone()))
        );

        // Pure B&B slot (limit == best0): exact even at best == limit.
        m.register_pending(101, 78, 4);
        m.on_fold(101, 4, 4, None);
        assert!(m.publish_at_recycle(78, rp.clone(), aj.clone(), &[0, 1, 2, 3, 4]).is_none());
        assert_eq!(c.lookup(78, &rp, &aj, false), Some((4, None)));

        // best < limit: exact.
        let (rp2, aj2) = csr(6);
        m.register_pending(102, 79, 5);
        m.on_fold(102, 2, 4, None);
        assert!(m.publish_at_recycle(79, rp2.clone(), aj2.clone(), &[0, 1, 2, 3, 4, 5]).is_none());
        assert_eq!(c.lookup(79, &rp2, &aj2, false), Some((2, None)));
    }

    #[test]
    fn cover_translated_to_local_ids() {
        let c = Arc::new(MemoCache::new(1 << 20, None));
        let m = JobMemo::new(1, c.clone(), true);
        let (rp, aj) = csr(4);
        m.register_pending(5, 42, 3);
        // Winning cover in root ids {12, 30}; back maps local -> root.
        m.on_fold(5, 2, 3, Some(&[30, 12]));
        let back = [7, 12, 19, 30];
        assert!(m.publish_at_recycle(42, rp.clone(), aj.clone(), &back).is_none());
        let (mvc, cover) = c.lookup(42, &rp, &aj, true).unwrap();
        assert_eq!((mvc, cover.as_deref()), (2, Some(&[1u32, 3][..])));
    }

    #[test]
    fn poison_discards_pending_and_ready() {
        let c = Arc::new(MemoCache::new(1 << 20, None));
        let m = JobMemo::new(1, c.clone(), true);
        let (rp, aj) = csr(5);
        m.register_pending(7, 55, 4);
        m.poison();
        m.on_fold(7, 2, 4, None);
        assert_eq!(
            m.publish_at_recycle(55, rp.clone(), aj.clone(), &[0, 1, 2, 3, 4]),
            Some((rp.clone(), aj.clone()))
        );
        assert_eq!(c.stats().inserts, 0);
    }

    #[test]
    fn non_publishing_job_never_registers() {
        let c = Arc::new(MemoCache::new(1 << 20, None));
        let m = JobMemo::new(1, c.clone(), false); // PVC
        let (rp, aj) = csr(5);
        m.register_pending(3, 66, 4);
        m.on_fold(3, 2, 4, None);
        assert_eq!(
            m.publish_at_recycle(66, rp.clone(), aj.clone(), &[0, 1, 2, 3, 4]),
            Some((rp, aj))
        );
        assert_eq!(c.stats().inserts, 0);
    }
}
