//! Maximum Independent Set via vertex cover complementation.
//!
//! The complement of a minimum vertex cover is a maximum independent set
//! (paper §VI: "our proposed techniques for load balancing
//! non-tail-recursive parallel branching can also be used in parallel
//! implementations of exact maximum independent set"). This wrapper
//! exposes that dual directly on top of the solver pipeline.

use crate::graph::Graph;
use crate::solver::{solve_mvc, witness, SolveResult, SolverConfig};

/// Result of a maximum independent set computation.
#[derive(Debug, Clone)]
pub struct MisResult {
    /// Independence number α(G) (lower bound if the MVC search timed out).
    pub alpha: u32,
    /// A witness independent set (any variant with
    /// [`SolverConfig::extract_cover`]).
    pub set: Option<Vec<u32>>,
    /// The underlying MVC solve.
    pub mvc: SolveResult,
}

/// Compute a maximum independent set: `α(G) = |V| − MVC(G)`.
pub fn solve_mis(g: &Graph, cfg: &SolverConfig) -> MisResult {
    let mvc = solve_mvc(g, cfg);
    let alpha = g.num_vertices() as u32 - mvc.best;
    let set = mvc.cover.as_ref().map(|cover| witness::complement(g, cover));
    MisResult { alpha, set, mvc }
}

/// Check that a vertex set is independent (no internal edges). Thin
/// wrapper over [`witness::verify_independent_set`], kept for callers
/// that only need the boolean.
pub fn is_independent_set(g: &Graph, set: &[u32]) -> bool {
    witness::verify_independent_set(g, set).is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::solver::oracle;

    #[test]
    fn known_alphas() {
        // α(C5)=2, α(K6)=1, α(P5)=3, α(Petersen)=4
        assert_eq!(solve_mis(&generators::cycle(5), &SolverConfig::proposed()).alpha, 2);
        assert_eq!(solve_mis(&generators::clique(6), &SolverConfig::proposed()).alpha, 1);
        assert_eq!(solve_mis(&generators::path(5), &SolverConfig::proposed()).alpha, 3);
        assert_eq!(solve_mis(&generators::petersen(), &SolverConfig::proposed()).alpha, 4);
    }

    #[test]
    fn witness_is_independent_and_maximum() {
        for seed in 0..8 {
            let g = generators::erdos_renyi(16, 0.2, seed);
            let mut cfg = SolverConfig::sequential();
            cfg.extract_cover = true;
            let r = solve_mis(&g, &cfg);
            assert_eq!(r.alpha, 16 - oracle::mvc_size(&g), "seed {seed}");
            if let Some(set) = &r.set {
                assert!(is_independent_set(&g, set), "seed {seed}");
                assert_eq!(set.len() as u32, r.alpha, "seed {seed}");
            }
        }
    }

    #[test]
    fn parallel_witness_is_independent_and_maximum() {
        for seed in 0..6 {
            let g = generators::erdos_renyi(16, 0.2, seed);
            let mut cfg = SolverConfig::proposed();
            cfg.extract_cover = true;
            let r = solve_mis(&g, &cfg);
            assert_eq!(r.alpha, 16 - oracle::mvc_size(&g), "seed {seed}");
            let set = r.set.expect("parallel extraction must produce a witness");
            assert!(is_independent_set(&g, &set), "seed {seed}");
            assert_eq!(set.len() as u32, r.alpha, "seed {seed}");
        }
    }

    #[test]
    fn independence_check() {
        let g = generators::path(4);
        assert!(is_independent_set(&g, &[0, 2]));
        assert!(!is_independent_set(&g, &[0, 1]));
        assert!(is_independent_set(&g, &[]));
    }
}
