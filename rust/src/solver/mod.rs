//! Vertex-cover solvers: public pipeline over the parallel engine and the
//! sequential baseline.
//!
//! The parallel engine runs on a pluggable scheduling runtime (see
//! [`sched`]): lock-free Chase–Lev work stealing by default, or the
//! mutex-sharded worklist baseline via
//! [`SolverConfig::with_scheduler`] — orthogonal to the variant presets
//! below, so schedulers can be compared on identical searches.
//!
//! Variant presets mirror the paper's Table I columns:
//! * [`SolverConfig::proposed`] — component-aware + load-balanced + all
//!   degree-array optimizations (the paper's contribution);
//! * [`SolverConfig::prior_work`] — the Yamout et al. baseline
//!   (load-balanced, *not* component-aware, no §IV optimizations);
//! * [`SolverConfig::no_load_balance`] — component-aware with private
//!   stacks only;
//! * [`SolverConfig::sequential`] — single-threaded Algorithm 2 with all
//!   optimizations.
//!
//! **Witness extraction** ([`SolverConfig::extract_cover`]) works on
//! *every* variant: the parallel engine carries per-node choice logs and
//! reassembles component-local covers at the registry's last-descendant
//! aggregation, then the [`witness`] module lifts the winning log back
//! through the induction renumbering and the root-reduction unwind to
//! original vertex ids — and can verify the result edge-by-edge.

pub mod autotune;
pub mod engine;
pub mod faults;
pub mod memo;
pub mod mis;
pub mod greedy;
pub mod occupancy;
pub mod oracle;
pub mod registry;
pub mod sched;
pub mod sequential;
pub mod server;
pub mod service;
pub mod wire;
pub mod witness;
pub mod worklist;

use crate::degree::Dtype;
use crate::graph::Graph;
use crate::prep::{self, PrepConfig};
use engine::{EngineCfg, EngineStats};
pub use engine::NodeRepr;
pub use autotune::AutotuneStats;
use occupancy::{Occupancy, OccupancyModel};
pub use faults::{FaultInjector, FaultPlan};
pub use memo::MemoStats;
pub use sched::SchedulerKind;
pub use server::{ClientError, ServerConfig, ServerReply, VcClient, VcServer};
pub use service::{
    default_service, AdmissionStats, JobHandle, JobOptions, JobProgress, Lane, Problem,
    ProblemKind, RetryPolicy, ServiceStats, Solution, SubmitError, TenantQuota, Termination,
    VcService,
};
pub use wire::{WireOptions, WireSolution, PROTOCOL_VERSION};
use std::time::{Duration, Instant};

/// Which execution strategy to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Variant {
    /// Parallel, component-aware, load-balanced (the paper's system).
    Proposed,
    /// Parallel, load-balanced, *not* component-aware and without the
    /// §IV degree-array optimizations (Yamout et al. [5]).
    PriorWork,
    /// Parallel, component-aware, but no shared worklist.
    NoLoadBalance,
    /// Single-threaded recursive Algorithm 2 with all optimizations.
    Sequential,
}

impl Variant {
    /// Short display name used in harness tables.
    pub fn name(self) -> &'static str {
        match self {
            Variant::Proposed => "proposed",
            Variant::PriorWork => "yamout",
            Variant::NoLoadBalance => "no-lb",
            Variant::Sequential => "sequential",
        }
    }
}

/// Full solver configuration.
#[derive(Debug, Clone)]
pub struct SolverConfig {
    /// Execution strategy.
    pub variant: Variant,
    /// Branch on components (§III). Defaults per variant.
    pub component_aware: bool,
    /// Root reduction + induced subgraph (§IV-B).
    pub reduce_root: bool,
    /// Crown rule at the root (§IV-B).
    pub use_crown: bool,
    /// Non-zero bounds windows (§IV-C).
    pub use_bounds: bool,
    /// Small degree dtypes (§IV-D).
    pub small_dtypes: bool,
    /// Component-local subproblem induction inside the tree: a split
    /// component is re-induced as a compact renumbered subproblem when
    /// `|C| ≤ induce_threshold × view`. `1.0` (default) induces every
    /// component; `0.0` disables tree induction for ablation
    /// (`--induce-threshold` on the CLI).
    pub induce_threshold: f64,
    /// Worker override (default: occupancy model ∧ hardware threads).
    pub workers: Option<usize>,
    /// Scheduling runtime for the parallel engine: lock-free work
    /// stealing (default) or the mutex-sharded worklist baseline.
    /// Orthogonal to the variant, so schedulers can be compared on
    /// identical searches.
    pub scheduler: SchedulerKind,
    /// Wall-clock budget (tables use this as the ">6hrs" stand-in).
    pub timeout: Option<Duration>,
    /// Record Figure-4 activity timings.
    pub instrument: bool,
    /// Extract a witness cover. Every variant supports this: the
    /// sequential baseline tracks its recursion, the parallel engine
    /// carries per-node choice logs and reassembles the cover at the
    /// registry's last-descendant aggregation. The witness is always
    /// lifted to *original* vertex ids (induction renumbering undone,
    /// root reductions unwound).
    pub extract_cover: bool,
    /// Force the one-shot engine even for service-compatible configs
    /// (per-call `thread::scope` pool, occupancy-model worker sizing).
    /// The harness tables set this so variant comparisons share the
    /// same cold-start shape and per-graph pool sizing.
    pub one_shot: bool,
    /// Physical node representation for the parallel engine: `Owned`
    /// payload copies (the ablation baseline) or `Delta` speculative
    /// in-place branching with steal-time materialization
    /// (`--node-repr` on the CLI; `CAVC_NODE_REPR` sets the process
    /// default).
    pub node_repr: NodeRepr,
    /// Delta mode: pinned-chain length bound forcing periodic
    /// materialization (see `EngineCfg::max_pin_depth`).
    pub max_pin_depth: u32,
    /// Cross-job component memoization (`solver::memo`): consult the
    /// resident service's component → solution cache at every component
    /// dispatch. `None` (default) resolves through the `CAVC_MEMO`
    /// environment default, then `on`; `Some(false)` is the ablation
    /// baseline (`--memo off`). Only meaningful through the service —
    /// one-shot engines never memoize.
    pub memo: Option<bool>,
    /// Self-tuning controller (`solver::autotune`): let the resident
    /// service pick node representation, pin depth, induction gating,
    /// and pool shape online from its own measurements. `None`
    /// (default) resolves through the `CAVC_AUTOTUNE` environment
    /// default, then `on`; `Some(false)` is the ablation baseline
    /// (`--autotune off`). Inert for one-shot engines. Explicitly set
    /// static knobs pin their own dimension even when the controller
    /// is on.
    pub autotune: Option<bool>,
}

impl SolverConfig {
    /// The paper's proposed solver.
    pub fn proposed() -> SolverConfig {
        SolverConfig {
            variant: Variant::Proposed,
            component_aware: true,
            reduce_root: true,
            use_crown: true,
            use_bounds: true,
            small_dtypes: true,
            induce_threshold: engine::DEFAULT_INDUCE_THRESHOLD,
            workers: None,
            scheduler: SchedulerKind::default(),
            timeout: None,
            instrument: false,
            extract_cover: false,
            one_shot: false,
            node_repr: NodeRepr::from_env(),
            max_pin_depth: engine::DEFAULT_MAX_PIN_DEPTH,
            memo: None,
            autotune: None,
        }
    }

    /// The prior state-of-the-art GPU solution (Yamout et al. [5]):
    /// worklist load balancing, but no component awareness and none of
    /// the degree-array optimizations.
    pub fn prior_work() -> SolverConfig {
        SolverConfig {
            variant: Variant::PriorWork,
            component_aware: false,
            reduce_root: false,
            use_crown: false,
            use_bounds: false,
            small_dtypes: false,
            ..SolverConfig::proposed()
        }
    }

    /// Component-aware but statically scheduled (Table I column 3).
    pub fn no_load_balance() -> SolverConfig {
        SolverConfig { variant: Variant::NoLoadBalance, ..SolverConfig::proposed() }
    }

    /// Sequential baseline with all optimizations (Table I column 2).
    pub fn sequential() -> SolverConfig {
        SolverConfig { variant: Variant::Sequential, ..SolverConfig::proposed() }
    }

    /// Set a wall-clock budget.
    pub fn with_timeout(mut self, t: Duration) -> SolverConfig {
        self.timeout = Some(t);
        self
    }

    /// Set an explicit worker count.
    pub fn with_workers(mut self, w: usize) -> SolverConfig {
        self.workers = Some(w);
        self
    }

    /// Select the scheduling runtime for the parallel engine.
    pub fn with_scheduler(mut self, s: SchedulerKind) -> SolverConfig {
        self.scheduler = s;
        self
    }

    /// Set the component-induction gate (`0.0` disables tree induction,
    /// `1.0` induces every split component).
    pub fn with_induce_threshold(mut self, t: f64) -> SolverConfig {
        self.induce_threshold = t;
        self
    }

    /// Force the one-shot engine (per-call pool, occupancy-sized) even
    /// for service-compatible configurations.
    pub fn with_one_shot(mut self) -> SolverConfig {
        self.one_shot = true;
        self
    }

    /// Select the physical node representation (`Owned` payload copies
    /// vs `Delta` speculative in-place branching).
    pub fn with_node_repr(mut self, r: NodeRepr) -> SolverConfig {
        self.node_repr = r;
        self
    }

    /// Delta mode: bound the pinned-frame chain length (forces periodic
    /// materialization so undo chains stay bounded).
    pub fn with_max_pin_depth(mut self, d: u32) -> SolverConfig {
        self.max_pin_depth = d;
        self
    }

    /// Enable or disable cross-job component memoization for jobs run
    /// under this config (`--memo {on,off}` on the CLI).
    pub fn with_memo(mut self, on: bool) -> SolverConfig {
        self.memo = Some(on);
        self
    }

    /// Enable or disable the service's self-tuning controller for jobs
    /// run under this config (`--autotune {on,off}` on the CLI).
    pub fn with_autotune(mut self, on: bool) -> SolverConfig {
        self.autotune = Some(on);
        self
    }

    /// The preparation-stage half of this configuration (§IV-B knobs).
    /// Shared by the MVC/PVC one-shot entry points and the service's
    /// job-setup stage, so the prep flags can never drift between them.
    pub fn prep_cfg(&self) -> PrepConfig {
        PrepConfig {
            reduce_root: self.reduce_root,
            use_crown: self.use_crown,
            small_dtypes: self.small_dtypes,
        }
    }
}

/// True when a call can be served by the shared resident service: a
/// parallel load-balanced variant with the default pool shape (witness
/// extraction rides along as a per-job option). Explicit
/// `workers`/`scheduler` overrides, instrumented runs, and the
/// static-seeding variant keep the one-shot engine (benches rely on
/// those to race pool shapes per call). Setting `CAVC_ONESHOT=1` forces
/// the one-shot path everywhere.
fn service_compatible(cfg: &SolverConfig) -> bool {
    matches!(cfg.variant, Variant::Proposed | Variant::PriorWork)
        && !cfg.one_shot
        && cfg.workers.is_none()
        && cfg.scheduler == SchedulerKind::default()
        && !cfg.instrument
        && std::env::var_os("CAVC_ONESHOT").is_none()
}

/// Lift a sequential outcome's counters into the unified stats type
/// (merged rather than field-by-field copied at the call sites).
fn sequential_stats(tree_nodes: u64, component_branches: u64) -> EngineStats {
    EngineStats { tree_nodes, component_branches, ..EngineStats::default() }
}

/// Occupancy plan used for scheduler sizing: with tree induction on, the
/// memory model charges a shrinking-payload path (§IV-B applied at every
/// split) instead of depth × full-width, which buys deeper initial
/// queues for the same modeled stack budget. Under the delta node
/// representation the per-node charge collapses to O(delta) plus the
/// pinned base frames the `max_pin_depth` knob forces.
fn sizing_occupancy(cfg: &SolverConfig, p: &prep::Prepared) -> Occupancy {
    let n = p.residual.graph.num_vertices();
    let alpha = if cfg.component_aware { cfg.induce_threshold } else { 0.0 };
    if cfg.node_repr == NodeRepr::Delta {
        OccupancyModel::default().plan_delta(n, p.dtype, alpha, cfg.max_pin_depth)
    } else if alpha > 0.0 {
        OccupancyModel::default().plan_induced(n, p.dtype, alpha)
    } else {
        p.occupancy.clone()
    }
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct SolveResult {
    /// Minimum vertex cover size (MVC), or the best found before timeout.
    pub best: u32,
    /// Witness cover (sequential variant with `extract_cover`).
    pub cover: Option<Vec<u32>>,
    /// Engine statistics (tree nodes, splits, histogram, …).
    pub stats: EngineStats,
    /// Vertices forced at the root / residual sizes (Table IV inputs).
    pub prep: PrepSummary,
    /// Wall-clock time.
    pub elapsed: Duration,
    /// True if the timeout fired before the search finished (the reported
    /// `best` is then only an upper bound).
    pub timed_out: bool,
}

/// Compact summary of the preparation stage.
#[derive(Debug, Clone)]
pub struct PrepSummary {
    /// |V| of the original graph.
    pub n_original: usize,
    /// |V| of the residual (induced) graph the engine ran on.
    pub n_residual: usize,
    /// Vertices forced into the cover at the root.
    pub forced: usize,
    /// Greedy upper bound.
    pub greedy_ub: u32,
    /// Degree dtype used.
    pub dtype: Dtype,
    /// Modeled thread blocks (occupancy).
    pub blocks: usize,
    /// Whether one degree array fits in modeled shared memory.
    pub fits_shared_mem: bool,
    /// Worker threads actually used.
    pub workers: usize,
}

/// PVC output.
#[derive(Debug, Clone)]
pub struct PvcResult {
    /// Whether a cover of size ≤ k exists (false may also mean timeout).
    pub found: bool,
    /// Size of the found cover (≤ k) when `found`.
    pub size: Option<u32>,
    /// The found cover itself (original vertex ids, `|cover| ≤ k`), when
    /// `found` and [`SolverConfig::extract_cover`] was set.
    pub cover: Option<Vec<u32>>,
    /// Engine statistics.
    pub stats: EngineStats,
    /// Wall-clock time.
    pub elapsed: Duration,
    /// True if the timeout fired before the search was exhausted.
    pub timed_out: bool,
}

/// Map a service job's outcome back onto the legacy one-shot contract:
/// the one-shot engine propagated worker panics to the caller, so a
/// `Failed` job (a worker panicked mid-search) must not return silently.
fn expect_not_failed(sol: &Solution) {
    assert!(
        sol.termination != Termination::Failed,
        "resident service job failed (worker panic); rerun with CAVC_ONESHOT=1 for a direct backtrace"
    );
}

/// Solve Minimum Vertex Cover.
///
/// Service-compatible configurations (see [`VcService`]) are routed
/// through the lazily-built process-wide resident pool — repeated calls
/// pay no thread spawn, but each call copies the graph into the job
/// (workers outlive the borrow); callers looping over one very large
/// graph should submit `Problem::mvc(Arc<Graph>)` to a [`VcService`]
/// directly, or force [`SolverConfig::with_one_shot`]. Sequential /
/// no-load-balance variants, explicit `workers`/`scheduler` overrides,
/// and instrumented runs keep the one-shot engine.
pub fn solve_mvc(g: &Graph, cfg: &SolverConfig) -> SolveResult {
    if service_compatible(cfg) {
        let sol = default_service()
            .submit_with(
                Problem::mvc(g.clone()),
                JobOptions {
                    timeout: cfg.timeout,
                    config: Some(cfg.clone()),
                    extract_witness: cfg.extract_cover,
                    ..JobOptions::default()
                },
            )
            .wait();
        expect_not_failed(&sol);
        return SolveResult {
            best: sol.objective,
            cover: sol.witness,
            stats: sol.stats,
            prep: sol.prep,
            elapsed: sol.elapsed,
            timed_out: sol.timed_out(),
        };
    }
    let start = Instant::now();
    let deadline = cfg.timeout.map(|t| start + t);
    let p = prep::prepare(g, &cfg.prep_cfg(), None);
    let workers = resolve_workers(cfg, &p);

    let initial = p.residual_ub;
    let (engine_out, cover) = match cfg.variant {
        Variant::Sequential => {
            let out = sequential::solve(
                &p.residual.graph,
                initial,
                cfg.component_aware,
                cfg.extract_cover,
                deadline,
            );
            let mut stats = EngineStats::default();
            stats.merge(&sequential_stats(out.tree_nodes, out.component_branches));
            let cover = out.cover.map(|c| p.lift_residual_cover(&c));
            (
                engine::EngineOutcome {
                    best: out.best,
                    improved: out.best < initial,
                    witness: None,
                    stats,
                    timed_out: out.timed_out,
                },
                cover,
            )
        }
        _ => {
            let ecfg = EngineCfg {
                component_aware: cfg.component_aware,
                load_balance: cfg.variant != Variant::NoLoadBalance,
                use_bounds: cfg.use_bounds,
                workers,
                stop_on_improvement: false,
                deadline,
                instrument: cfg.instrument,
                scheduler: cfg.scheduler,
                queue_capacity: sizing_occupancy(cfg, &p).queue_capacity(),
                induce_threshold: cfg.induce_threshold,
                extract_witness: cfg.extract_cover,
                node_repr: cfg.node_repr,
                max_pin_depth: cfg.max_pin_depth,
            };
            let mut out = run_engine(&p.residual.graph, p.dtype, initial, ecfg);
            let cover = out.witness.take().map(|w| p.lift_residual_cover(&w));
            (out, cover)
        }
    };

    // best = min(greedy, forced + residual best)
    let total = p.total_size(engine_out.best.min(initial));
    let best = total.min(p.greedy_ub);
    let cover = if cfg.extract_cover {
        witness::cover_of_record(cover, best, p.greedy_ub, g)
    } else {
        None
    };

    SolveResult {
        best,
        cover,
        stats: engine_out.stats,
        prep: summarize(g, &p, workers),
        elapsed: start.elapsed(),
        timed_out: engine_out.timed_out,
    }
}

/// Solve Parameterized Vertex Cover: is there a cover of size ≤ k?
///
/// Service-compatible configurations run on the shared resident pool
/// (see [`solve_mvc`]).
pub fn solve_pvc(g: &Graph, k: u32, cfg: &SolverConfig) -> PvcResult {
    if service_compatible(cfg) {
        let sol = default_service()
            .submit_with(
                Problem::pvc(g.clone(), k),
                JobOptions {
                    timeout: cfg.timeout,
                    config: Some(cfg.clone()),
                    extract_witness: cfg.extract_cover,
                    ..JobOptions::default()
                },
            )
            .wait();
        expect_not_failed(&sol);
        return PvcResult {
            found: sol.feasible,
            size: sol.feasible.then_some(sol.objective),
            cover: sol.witness,
            stats: sol.stats,
            elapsed: sol.elapsed,
            timed_out: sol.timed_out(),
        };
    }
    let start = Instant::now();
    let deadline = cfg.timeout.map(|t| start + t);
    // ub = k+1 keeps the high-degree rule sound for covers ≤ k.
    let p = prep::prepare(g, &cfg.prep_cfg(), Some(k.saturating_add(1)));

    // The greedy bound may already satisfy k.
    if p.greedy_ub <= k {
        return PvcResult {
            found: true,
            size: Some(p.greedy_ub),
            cover: cfg.extract_cover.then(|| greedy::greedy_cover(g)),
            stats: EngineStats::default(),
            elapsed: start.elapsed(),
            timed_out: false,
        };
    }
    let forced = p.forced_cover.len() as u32;
    if forced > k {
        return PvcResult {
            found: false,
            size: None,
            cover: None,
            stats: EngineStats::default(),
            elapsed: start.elapsed(),
            timed_out: false,
        };
    }
    let k_resid = k - forced;
    let initial = (k_resid + 1).min(p.residual.graph.num_vertices() as u32 + 1);
    let workers = resolve_workers(cfg, &p);

    let (out, cover) = match cfg.variant {
        Variant::Sequential => {
            // sequential PVC: same bound trick; recursion stops via best
            let o = sequential::solve(
                &p.residual.graph,
                initial,
                cfg.component_aware,
                cfg.extract_cover,
                deadline,
            );
            let cover = o.cover.as_ref().map(|c| p.lift_residual_cover(c));
            (
                engine::EngineOutcome {
                    best: o.best,
                    improved: o.best < initial,
                    witness: None,
                    stats: sequential_stats(o.tree_nodes, o.component_branches),
                    timed_out: o.timed_out,
                },
                cover,
            )
        }
        _ => {
            let ecfg = EngineCfg {
                component_aware: cfg.component_aware,
                load_balance: cfg.variant != Variant::NoLoadBalance,
                use_bounds: cfg.use_bounds,
                workers,
                stop_on_improvement: true,
                deadline,
                instrument: cfg.instrument,
                scheduler: cfg.scheduler,
                queue_capacity: sizing_occupancy(cfg, &p).queue_capacity(),
                induce_threshold: cfg.induce_threshold,
                extract_witness: cfg.extract_cover,
                node_repr: cfg.node_repr,
                max_pin_depth: cfg.max_pin_depth,
            };
            let mut out = run_engine(&p.residual.graph, p.dtype, initial, ecfg);
            let cover = out.witness.take().map(|w| p.lift_residual_cover(&w));
            (out, cover)
        }
    };

    let found = out.improved && out.best <= k_resid;
    PvcResult {
        found,
        size: if found { Some(forced + out.best) } else { None },
        // the assembled PVC witness always respects k (extraction gates
        // early stop on assembled covers); it may exceed `size` when an
        // est-propagated bound beat the assembled one to the stop
        cover: if found { cover.filter(|c| c.len() as u32 <= k) } else { None },
        stats: out.stats,
        elapsed: start.elapsed(),
        timed_out: out.timed_out,
    }
}

/// Dispatch the engine over the selected degree dtype (§IV-D: the dtype
/// changes the physical size of every stack entry).
fn run_engine(g: &Graph, dtype: Dtype, initial: u32, cfg: EngineCfg) -> engine::EngineOutcome {
    match dtype {
        Dtype::U8 => engine::run::<u8>(g, initial, cfg),
        Dtype::U16 => engine::run::<u16>(g, initial, cfg),
        Dtype::U32 => engine::run::<u32>(g, initial, cfg),
    }
}

fn resolve_workers(cfg: &SolverConfig, p: &prep::Prepared) -> usize {
    match cfg.variant {
        Variant::Sequential => 1,
        _ => cfg.workers.unwrap_or_else(|| {
            let hw = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4);
            p.occupancy.blocks.min(hw).max(1)
        }),
    }
}

fn summarize(g: &Graph, p: &prep::Prepared, workers: usize) -> PrepSummary {
    PrepSummary {
        n_original: g.num_vertices(),
        n_residual: p.residual.graph.num_vertices(),
        forced: p.forced_cover.len(),
        greedy_ub: p.greedy_ub,
        dtype: p.dtype,
        blocks: p.occupancy.blocks,
        fits_shared_mem: p.occupancy.fits_shared_mem,
        workers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn all_variants_agree_with_oracle() {
        for seed in 0..10 {
            let g = generators::erdos_renyi(18, 0.18, seed);
            let opt = oracle::mvc_size(&g);
            for cfg in [
                SolverConfig::proposed(),
                SolverConfig::prior_work(),
                SolverConfig::no_load_balance(),
                SolverConfig::sequential(),
            ] {
                let r = solve_mvc(&g, &cfg);
                assert_eq!(r.best, opt, "{} seed {seed}", cfg.variant.name());
                assert!(!r.timed_out);
            }
        }
    }

    #[test]
    fn splitting_graph_all_variants() {
        let g = generators::union_of_random(5, 4, 7, 0.3, 3);
        let opt = oracle::mvc_size(&g);
        for cfg in [
            SolverConfig::proposed(),
            SolverConfig::prior_work(),
            SolverConfig::no_load_balance(),
            SolverConfig::sequential(),
        ] {
            assert_eq!(solve_mvc(&g, &cfg).best, opt, "{}", cfg.variant.name());
        }
    }

    #[test]
    fn sequential_extraction_is_valid() {
        let g = generators::erdos_renyi(20, 0.15, 7);
        let mut cfg = SolverConfig::sequential();
        cfg.extract_cover = true;
        let r = solve_mvc(&g, &cfg);
        if let Some(c) = &r.cover {
            assert!(g.is_vertex_cover(c));
            assert_eq!(c.len() as u32, r.best);
        }
        assert_eq!(r.best, oracle::mvc_size(&g));
    }

    #[test]
    fn parallel_extraction_is_valid_all_variants() {
        for seed in 0..6 {
            let g = generators::union_of_random(3, 3, 6, 0.3, seed);
            let opt = oracle::mvc_size(&g);
            for mut cfg in [
                SolverConfig::proposed(),
                SolverConfig::prior_work(),
                SolverConfig::no_load_balance(),
            ] {
                cfg.extract_cover = true;
                let r = solve_mvc(&g, &cfg);
                assert_eq!(r.best, opt, "{} seed {seed}", cfg.variant.name());
                let c = r.cover.expect("extraction must produce a witness");
                assert_eq!(c.len() as u32, opt, "{} seed {seed}", cfg.variant.name());
                assert!(g.is_vertex_cover(&c), "{} seed {seed}", cfg.variant.name());
            }
        }
    }

    #[test]
    fn pvc_extraction_returns_cover_within_k() {
        for seed in 0..5 {
            let g = generators::erdos_renyi(16, 0.22, seed);
            let opt = oracle::mvc_size(&g);
            let mut cfg = SolverConfig::proposed();
            cfg.extract_cover = true;
            let r = solve_pvc(&g, opt, &cfg);
            assert!(r.found, "seed {seed}");
            let c = r.cover.expect("found PVC must carry a cover");
            assert!(c.len() as u32 <= opt, "seed {seed}");
            assert!(g.is_vertex_cover(&c), "seed {seed}");
            // a generous budget may be answered by the greedy bound —
            // still a genuine cover within k
            let r2 = solve_pvc(&g, opt + 2, &cfg);
            assert!(r2.found, "seed {seed}");
            let c2 = r2.cover.expect("cover");
            assert!(c2.len() as u32 <= opt + 2, "seed {seed}");
            assert!(g.is_vertex_cover(&c2), "seed {seed}");
        }
    }

    #[test]
    fn pvc_boundary_values() {
        for seed in 0..8 {
            let g = generators::erdos_renyi(16, 0.22, seed);
            let opt = oracle::mvc_size(&g);
            let cfg = SolverConfig::proposed();
            assert!(!solve_pvc(&g, opt.saturating_sub(1), &cfg).found, "k=opt-1 seed {seed}");
            let at = solve_pvc(&g, opt, &cfg);
            assert!(at.found, "k=opt seed {seed}");
            assert!(at.size.unwrap() <= opt);
            assert!(solve_pvc(&g, opt + 1, &cfg).found, "k=opt+1 seed {seed}");
        }
    }

    #[test]
    fn pvc_all_variants_agree() {
        let g = generators::union_of_random(3, 4, 7, 0.3, 5);
        let opt = oracle::mvc_size(&g);
        for cfg in [
            SolverConfig::proposed(),
            SolverConfig::prior_work(),
            SolverConfig::no_load_balance(),
            SolverConfig::sequential(),
        ] {
            assert!(solve_pvc(&g, opt, &cfg).found, "{} k=opt", cfg.variant.name());
            assert!(
                !solve_pvc(&g, opt.saturating_sub(1), &cfg).found,
                "{} k=opt-1",
                cfg.variant.name()
            );
        }
    }

    #[test]
    fn schedulers_agree_on_all_parallel_variants() {
        for seed in 0..6 {
            let g = generators::union_of_random(3, 4, 7, 0.3, seed);
            let opt = oracle::mvc_size(&g);
            for kind in [SchedulerKind::WorkSteal, SchedulerKind::Sharded] {
                for cfg in [
                    SolverConfig::proposed(),
                    SolverConfig::prior_work(),
                    SolverConfig::no_load_balance(),
                ] {
                    let cfg = cfg.with_scheduler(kind);
                    let r = solve_mvc(&g, &cfg);
                    assert_eq!(
                        r.best,
                        opt,
                        "{}/{} seed {seed}",
                        cfg.variant.name(),
                        kind.name()
                    );
                    assert!(solve_pvc(&g, opt, &cfg).found, "{} pvc", kind.name());
                }
            }
        }
    }

    #[test]
    fn induce_threshold_knob_preserves_results() {
        for seed in 0..6 {
            let g = generators::union_of_random(4, 3, 7, 0.3, seed);
            let opt = oracle::mvc_size(&g);
            for t in [0.0, 0.4, 1.0] {
                let cfg = SolverConfig::proposed().with_induce_threshold(t);
                let r = solve_mvc(&g, &cfg);
                assert_eq!(r.best, opt, "seed {seed} threshold {t}");
                assert!(solve_pvc(&g, opt, &cfg).found, "seed {seed} threshold {t} pvc");
            }
        }
    }

    #[test]
    fn timeout_is_reported_and_best_is_upper_bound() {
        let g = generators::p_hat(80, 0.3, 0.8, 4);
        let cfg = SolverConfig::proposed().with_timeout(Duration::from_millis(1));
        let r = solve_mvc(&g, &cfg);
        assert!(r.best >= 1); // still a sound upper bound (greedy at worst)
        // dense p_hat(80) cannot finish in 1ms
        assert!(r.timed_out);
    }

    #[test]
    fn prep_summary_populated() {
        let g = generators::web_crawl(50, 200, 9);
        let r = solve_mvc(&g, &SolverConfig::proposed());
        assert_eq!(r.prep.n_original, 250);
        assert!(r.prep.n_residual < 250);
        assert!(r.prep.blocks >= 1);
        assert!(r.prep.workers >= 1);
    }

    #[test]
    fn empty_and_trivial_graphs() {
        let empty = Graph::from_edges(5, &[]);
        assert_eq!(solve_mvc(&empty, &SolverConfig::proposed()).best, 0);
        let single = Graph::from_edges(2, &[(0, 1)]);
        assert_eq!(solve_mvc(&single, &SolverConfig::proposed()).best, 1);
        assert!(solve_pvc(&single, 1, &SolverConfig::proposed()).found);
        assert!(!solve_pvc(&single, 0, &SolverConfig::proposed()).found);
    }
}
