//! GPU occupancy model (paper §IV, Table IV).
//!
//! On the V100 the number of concurrently resident thread blocks is
//! limited by the per-block stack of degree arrays in global memory and
//! by whether one degree array fits in shared memory. We have no GPU, so
//! this model reproduces those *decisions* analytically: the engine
//! launches `min(blocks, hw_threads)` workers, and Table IV reports the
//! modeled block counts — the same lever the paper's optimizations move.

use crate::degree::Dtype;

/// V100-derived model constants.
#[derive(Debug, Clone)]
pub struct OccupancyModel {
    /// Global-memory budget dedicated to per-block stacks (bytes).
    pub stack_budget_bytes: u64,
    /// Shared-memory capacity available per block for one degree array.
    pub shared_mem_bytes: u64,
    /// Hard cap on resident blocks (paper's observed maximum grid).
    pub max_blocks: usize,
}

impl Default for OccupancyModel {
    fn default() -> Self {
        OccupancyModel {
            // 4 GiB of the V100's 32 GiB device memory for stacks.
            stack_budget_bytes: 4 << 30,
            // 32 KiB threshold reproduces every Yes/No in the paper's
            // Table IV (96 KiB/SM shared among resident blocks).
            shared_mem_bytes: 32 << 10,
            max_blocks: 2560,
        }
    }
}

/// Occupancy decision for one solver launch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Occupancy {
    /// Modeled number of thread blocks the GPU could keep resident.
    pub blocks: usize,
    /// Bytes of one degree array (stack entry payload).
    pub degree_array_bytes: u64,
    /// Modeled per-block stack depth bound.
    pub stack_depth: u64,
    /// Whether one degree array fits in shared memory.
    pub fits_shared_mem: bool,
    /// Degree-array element type.
    pub dtype: Dtype,
}

impl Occupancy {
    /// Initial per-worker scheduler queue capacity derived from the
    /// modeled stack depth: on the GPU each block's stack is preallocated
    /// to the branching-depth bound, and the work-stealing deques reuse
    /// that bound as their starting size so the common case never grows.
    pub fn queue_capacity(&self) -> usize {
        (self.stack_depth as usize).next_power_of_two().clamp(64, 4096)
    }
}

impl OccupancyModel {
    /// Model a launch for a degree array of `n` entries of `dtype`.
    ///
    /// The stack depth bound follows §IV-B: branching depth is bounded by
    /// the number of vertices that can still be removed, i.e. the reduced
    /// |V| (+1 root frame), and root reductions tighten it.
    pub fn plan(&self, n: usize, dtype: Dtype) -> Occupancy {
        let degree_array_bytes = (n as u64) * dtype.bytes() as u64;
        let stack_depth = (n as u64 + 1).min(4096);
        let per_block = degree_array_bytes.saturating_mul(stack_depth).max(1);
        let blocks = (self.stack_budget_bytes / per_block)
            .clamp(1, self.max_blocks as u64) as usize;
        Occupancy {
            blocks,
            degree_array_bytes,
            stack_depth,
            fits_shared_mem: degree_array_bytes <= self.shared_mem_bytes,
            dtype,
        }
    }

    /// Number of OS worker threads to actually run for a modeled launch:
    /// the model's block count capped by the hardware parallelism.
    pub fn workers(&self, n: usize, dtype: Dtype) -> usize {
        let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        self.plan(n, dtype).blocks.min(hw).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smaller_arrays_more_blocks() {
        let m = OccupancyModel::default();
        let big = m.plan(90_000, Dtype::U32);
        let small = m.plan(3_500, Dtype::U16);
        assert!(small.blocks > big.blocks);
        assert!(small.blocks >= 100 * big.blocks.max(1) / 100);
    }

    #[test]
    fn shared_mem_threshold_matches_paper_rows() {
        let m = OccupancyModel::default();
        // paper Table IV: (n, dtype_after) → fits?
        assert!(!m.plan(16_062, Dtype::U32).fits_shared_mem); // webbase before
        assert!(m.plan(1_631, Dtype::U16).fits_shared_mem); // webbase after
        assert!(m.plan(4_767, Dtype::U32).fits_shared_mem); // web-spam before
        assert!(!m.plan(10_972, Dtype::U32).fits_shared_mem); // dublin before
        assert!(m.plan(9_785, Dtype::U16).fits_shared_mem); // dublin after
        assert!(!m.plan(21_900, Dtype::U16).fits_shared_mem); // SYNTHETIC after
        assert!(!m.plan(36_099, Dtype::U16).fits_shared_mem); // PROTEINS after
    }

    #[test]
    fn max_blocks_cap_for_tiny_arrays() {
        let m = OccupancyModel::default();
        assert_eq!(m.plan(324, Dtype::U8).blocks, 2560); // qc324 stays at max
    }

    #[test]
    fn at_least_one_block() {
        let m = OccupancyModel::default();
        assert!(m.plan(10_000_000, Dtype::U32).blocks >= 1);
    }

    #[test]
    fn queue_capacity_tracks_stack_depth() {
        let m = OccupancyModel::default();
        let small = m.plan(100, Dtype::U8);
        assert_eq!(small.queue_capacity(), (small.stack_depth as usize).next_power_of_two());
        let big = m.plan(1 << 20, Dtype::U32);
        assert_eq!(big.queue_capacity(), 4096); // clamped at the depth cap
        assert!(m.plan(3, Dtype::U8).queue_capacity() >= 64);
    }

    #[test]
    fn workers_bounded_by_hw() {
        let m = OccupancyModel::default();
        let hw = std::thread::available_parallelism().unwrap().get();
        assert!(m.workers(324, Dtype::U8) <= hw);
        assert!(m.workers(324, Dtype::U8) >= 1);
    }
}
