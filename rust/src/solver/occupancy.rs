//! GPU occupancy model (paper §IV, Table IV).
//!
//! On the V100 the number of concurrently resident thread blocks is
//! limited by the per-block stack of degree arrays in global memory and
//! by whether one degree array fits in shared memory. We have no GPU, so
//! this model reproduces those *decisions* analytically: the engine
//! launches `min(blocks, hw_threads)` workers, and Table IV reports the
//! modeled block counts — the same lever the paper's optimizations move.

use crate::degree::Dtype;

/// Modeled resident bytes of one admission-queue entry (job headers +
/// amortized share of the queued problem's host-side footprint); see
/// [`OccupancyModel::admission_capacity`].
pub const ADMISSION_ENTRY_BYTES: u64 = 512;

/// V100-derived model constants.
#[derive(Debug, Clone)]
pub struct OccupancyModel {
    /// Global-memory budget dedicated to per-block stacks (bytes).
    pub stack_budget_bytes: u64,
    /// Shared-memory capacity available per block for one degree array.
    pub shared_mem_bytes: u64,
    /// Hard cap on resident blocks (paper's observed maximum grid).
    pub max_blocks: usize,
}

impl Default for OccupancyModel {
    fn default() -> Self {
        OccupancyModel {
            // 4 GiB of the V100's 32 GiB device memory for stacks.
            stack_budget_bytes: 4 << 30,
            // 32 KiB threshold reproduces every Yes/No in the paper's
            // Table IV (96 KiB/SM shared among resident blocks).
            shared_mem_bytes: 32 << 10,
            max_blocks: 2560,
        }
    }
}

/// Occupancy decision for one solver launch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Occupancy {
    /// Modeled number of thread blocks the GPU could keep resident.
    pub blocks: usize,
    /// Bytes of one *root-width* degree array (stack entry payload).
    pub degree_array_bytes: u64,
    /// Modeled per-block stack depth bound (node count).
    pub stack_depth: u64,
    /// Modeled payload bytes along one root-to-leaf stack path. Without
    /// tree induction every frame is full-width, so this is
    /// `degree_array_bytes × stack_depth`; with component-local induction
    /// ([`OccupancyModel::plan_induced`]) payloads shrink at every split
    /// and the path sum collapses to a small multiple of the root array.
    /// Under the delta node representation
    /// ([`OccupancyModel::plan_delta`]) this charges only the O(delta)
    /// queued payloads; the pinned snapshots are `pinned_bytes`.
    pub path_bytes: u64,
    /// Delta mode only: modeled bytes of pinned base frames along one
    /// path — one full-width snapshot per `max_pin_depth` chain links
    /// (the periodic-materialization knob). *Not* included in
    /// `path_bytes`: consumers charge `path_bytes + pinned_bytes`, so
    /// the budget those frames still occupy is never double-counted as
    /// savings. 0 for owned-representation plans.
    pub pinned_bytes: u64,
    /// Whether one degree array fits in shared memory.
    pub fits_shared_mem: bool,
    /// Degree-array element type.
    pub dtype: Dtype,
}

impl Occupancy {
    /// Initial per-worker scheduler queue capacity derived from the
    /// modeled stack depth: on the GPU each block's stack is preallocated
    /// to the branching-depth bound, and both schedulers reuse that bound
    /// as their starting size so the common case never grows.
    ///
    /// When tree induction shrinks the per-path payload (`path_bytes`
    /// below `degree_array_bytes × stack_depth`), the saved stack budget
    /// is surfaced as deeper initial queues: the same bytes now admit
    /// more in-flight nodes per worker, which is exactly the paper's
    /// "memory footprint limits concurrent workers" lever.
    ///
    /// Delta-mode plans charge almost nothing per node, but their pinned
    /// base frames (`pinned_bytes`) still occupy the stack budget — they
    /// are added to the effective charge so the boost never re-spends
    /// budget that the pinned snapshots already consume.
    pub fn queue_capacity(&self) -> usize {
        let base = (self.stack_depth as usize).next_power_of_two().clamp(64, 4096);
        // Effective full-width frames the memory model charges per path:
        // queued payloads plus (delta mode) the pinned snapshots.
        let charged = self.path_bytes.saturating_add(self.pinned_bytes);
        let eff = (charged / self.degree_array_bytes.max(1)).max(1);
        let boost = ((self.stack_depth / eff).max(1) as usize).next_power_of_two().min(8);
        (base * boost).clamp(64, 8192)
    }
}

impl OccupancyModel {
    /// Model a launch for a degree array of `n` entries of `dtype`.
    ///
    /// The stack depth bound follows §IV-B: branching depth is bounded by
    /// the number of vertices that can still be removed, i.e. the reduced
    /// |V| (+1 root frame), and root reductions tighten it.
    pub fn plan(&self, n: usize, dtype: Dtype) -> Occupancy {
        let degree_array_bytes = (n as u64) * dtype.bytes() as u64;
        let stack_depth = (n as u64 + 1).min(4096);
        let path_bytes = degree_array_bytes.saturating_mul(stack_depth).max(1);
        let blocks = (self.stack_budget_bytes / path_bytes)
            .clamp(1, self.max_blocks as u64) as usize;
        Occupancy {
            blocks,
            degree_array_bytes,
            stack_depth,
            path_bytes,
            pinned_bytes: 0,
            fits_shared_mem: degree_array_bytes <= self.shared_mem_bytes,
            dtype,
        }
    }

    /// Model a launch when the engine re-induces each component as a
    /// compact subproblem inside the tree (gated on `|C| ≤ alpha·n`; see
    /// `EngineCfg::induce_threshold`). `alpha ≤ 0` means induction is off
    /// and the plan degenerates to [`OccupancyModel::plan`].
    ///
    /// With induction, a node's payload after `k` enclosing splits is at
    /// most `alpha^k · n` entries, so the payload bytes along one
    /// root-to-leaf stack path form the geometric series
    /// `n·(1 + α + α² + …) = n/(1−α)` instead of `n × depth`. We charge a
    /// small constant of full-width frames for the pre-split prefix plus
    /// the series tail, clamping `α` away from 1 (α = 1 still shrinks —
    /// components are strict subsets of their parent — but the geometric
    /// model needs a finite ratio). The collapsed `path_bytes` is what
    /// lets the block count recover toward `max_blocks` on large graphs:
    /// the paper's root-induction occupancy win, applied at every split.
    pub fn plan_induced(&self, n: usize, dtype: Dtype, alpha: f64) -> Occupancy {
        let base = self.plan(n, dtype);
        if alpha <= 0.0 {
            return base;
        }
        const PRE_SPLIT_FRAMES: u64 = 8;
        let r = alpha.clamp(0.1, 0.875);
        let series = (1.0 / (1.0 - r)).ceil() as u64;
        let eff_depth = (PRE_SPLIT_FRAMES + series).min(base.stack_depth);
        let path_bytes = base.degree_array_bytes.saturating_mul(eff_depth).max(1);
        let blocks = (self.stack_budget_bytes / path_bytes)
            .clamp(1, self.max_blocks as u64) as usize;
        Occupancy { blocks, path_bytes, ..base }
    }

    /// Model a launch under the delta/undo node representation: right
    /// children are (pinned parent frame + covered-vertex delta), so the
    /// per-node stack charge collapses to a small constant, and the
    /// dominant memory term becomes the pinned base frames — one
    /// full-width snapshot per `max_pin_depth` chain links, the knob
    /// that forces periodic materialization so undo/replay chains stay
    /// bounded. Builds on [`OccupancyModel::plan_induced`], so the
    /// geometric payload shrink of tree induction composes with the
    /// delta charge.
    pub fn plan_delta(&self, n: usize, dtype: Dtype, alpha: f64, max_pin_depth: u32) -> Occupancy {
        /// Modeled resident bytes of one queued delta node (fixed part;
        /// suffixes are charged through the pinned chain).
        const DELTA_NODE_BYTES: u64 = 48;
        let base = self.plan_induced(n, dtype, alpha);
        // Full-width frames the induced model charges per path — the
        // frames that still exist as *undo substrates* in delta mode,
        // now pinned once per max_pin_depth links instead of per node.
        let frames = (base.path_bytes / base.degree_array_bytes.max(1)).max(1);
        let pin = max_pin_depth.max(1) as u64;
        let bases = frames.div_ceil(pin).max(1);
        let pinned_bytes = base.degree_array_bytes.saturating_mul(bases);
        // Queued payloads are O(delta); the pinned snapshots are kept in
        // their own field and both terms are charged against the budget.
        let path_bytes = DELTA_NODE_BYTES.saturating_mul(base.stack_depth).max(1);
        let charged = path_bytes.saturating_add(pinned_bytes);
        let blocks =
            (self.stack_budget_bytes / charged).clamp(1, self.max_blocks as u64) as usize;
        Occupancy { blocks, path_bytes, pinned_bytes, ..base }
    }

    /// Default bound on the service's admission queue, charged against
    /// the same stack budget the per-block stacks draw from: a queued
    /// job holds its problem graph host-side, so admission depth is a
    /// memory commitment, not a free list. We dedicate 1/256th of the
    /// stack budget to queued submissions at a modeled
    /// [`ADMISSION_ENTRY_BYTES`] apiece, clamped to a sane range.
    pub fn admission_capacity(&self) -> usize {
        let slice = (self.stack_budget_bytes >> 8).max(ADMISSION_ENTRY_BYTES);
        ((slice / ADMISSION_ENTRY_BYTES) as usize).clamp(64, 4096)
    }

    /// Default soft limit for the service's memory watchdog: the live-
    /// bytes ledger (queued payloads + pinned snapshots across all jobs)
    /// crossing the modeled stack budget means the pool is holding more
    /// node state than the device stacks were provisioned for — new jobs
    /// are degraded (forced delta repr, throughput lane held back)
    /// rather than refused.
    pub fn watchdog_soft_bytes(&self) -> u64 {
        self.stack_budget_bytes
    }

    /// Default hard limit for the memory watchdog: twice the stack
    /// budget. Past this, admission sheds load with
    /// `SubmitError::MemoryPressure` — the runtime analogue of the
    /// static occupancy plan refusing a launch that cannot fit.
    pub fn watchdog_hard_bytes(&self) -> u64 {
        self.stack_budget_bytes.saturating_mul(2)
    }

    /// Default byte budget for the cross-job component memo cache
    /// (`solver::memo`): a quarter of the stack budget. Cache bytes are
    /// charged against the same admission ledger the watchdog reads, so
    /// a full cache consumes a bounded slice of the soft limit
    /// ([`OccupancyModel::watchdog_soft_bytes`]) and the cache is shed
    /// outright when the watchdog trips — reuse never outranks live
    /// search state.
    pub fn memo_budget_bytes(&self) -> u64 {
        self.stack_budget_bytes / 4
    }

    /// Re-plan the admission capacity from *live* ledger bytes instead
    /// of the seed-time estimate: the budget still unclaimed by resident
    /// node state (`stack_budget − live`) is what queued submissions can
    /// actually draw on, so the self-tuning controller periodically
    /// replaces the static [`OccupancyModel::admission_capacity`] with
    /// this value as the pool fills and drains. Same 1/256 slice and
    /// clamps as the static plan; a fully-consumed budget floors at the
    /// minimum rather than refusing admission outright (the watchdog,
    /// not the queue bound, owns shedding).
    pub fn replan_admission(&self, live_bytes: u64) -> usize {
        let remaining = self.stack_budget_bytes.saturating_sub(live_bytes);
        let slice = (remaining >> 8).max(ADMISSION_ENTRY_BYTES);
        ((slice / ADMISSION_ENTRY_BYTES) as usize).clamp(64, 4096)
    }

    /// Re-plan the per-worker queue capacity from live ledger bytes:
    /// the remaining stack budget divided by the modeled full-width
    /// frame charge, spread across `workers` queues. Published by the
    /// self-tuning controller as the current plan (resident deques grow
    /// on demand, so this is telemetry plus the seed for future pools,
    /// not a hard cap).
    pub fn replan_queue_capacity(
        &self,
        live_bytes: u64,
        frame_bytes: u64,
        workers: usize,
    ) -> usize {
        let remaining = self.stack_budget_bytes.saturating_sub(live_bytes);
        let per_worker = remaining / frame_bytes.max(1) / workers.max(1) as u64;
        (per_worker as usize).next_power_of_two().clamp(64, 8192)
    }

    /// Re-plan the memo budget from live ledger bytes: the same quarter
    /// slice as [`OccupancyModel::memo_budget_bytes`], but of the budget
    /// *remaining* after live search state — as jobs pin more node
    /// state, the cache's allowance shrinks ahead of the watchdog's
    /// shed, and it grows back when the pool drains.
    pub fn replan_memo_budget(&self, live_bytes: u64) -> u64 {
        self.stack_budget_bytes.saturating_sub(live_bytes) / 4
    }

    /// Number of OS worker threads to actually run for a modeled launch:
    /// the model's block count capped by the hardware parallelism.
    pub fn workers(&self, n: usize, dtype: Dtype) -> usize {
        let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        self.plan(n, dtype).blocks.min(hw).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smaller_arrays_more_blocks() {
        let m = OccupancyModel::default();
        let big = m.plan(90_000, Dtype::U32);
        let small = m.plan(3_500, Dtype::U16);
        assert!(small.blocks > big.blocks);
        assert!(small.blocks >= 100 * big.blocks.max(1) / 100);
    }

    #[test]
    fn shared_mem_threshold_matches_paper_rows() {
        let m = OccupancyModel::default();
        // paper Table IV: (n, dtype_after) → fits?
        assert!(!m.plan(16_062, Dtype::U32).fits_shared_mem); // webbase before
        assert!(m.plan(1_631, Dtype::U16).fits_shared_mem); // webbase after
        assert!(m.plan(4_767, Dtype::U32).fits_shared_mem); // web-spam before
        assert!(!m.plan(10_972, Dtype::U32).fits_shared_mem); // dublin before
        assert!(m.plan(9_785, Dtype::U16).fits_shared_mem); // dublin after
        assert!(!m.plan(21_900, Dtype::U16).fits_shared_mem); // SYNTHETIC after
        assert!(!m.plan(36_099, Dtype::U16).fits_shared_mem); // PROTEINS after
    }

    #[test]
    fn max_blocks_cap_for_tiny_arrays() {
        let m = OccupancyModel::default();
        assert_eq!(m.plan(324, Dtype::U8).blocks, 2560); // qc324 stays at max
    }

    #[test]
    fn at_least_one_block() {
        let m = OccupancyModel::default();
        assert!(m.plan(10_000_000, Dtype::U32).blocks >= 1);
    }

    #[test]
    fn memo_budget_is_a_bounded_slice_of_the_watchdog() {
        let m = OccupancyModel::default();
        assert_eq!(m.memo_budget_bytes(), m.stack_budget_bytes / 4);
        assert!(m.memo_budget_bytes() < m.watchdog_soft_bytes());
    }

    #[test]
    fn queue_capacity_tracks_stack_depth() {
        let m = OccupancyModel::default();
        let small = m.plan(100, Dtype::U8);
        assert_eq!(small.queue_capacity(), (small.stack_depth as usize).next_power_of_two());
        let big = m.plan(1 << 20, Dtype::U32);
        assert_eq!(big.queue_capacity(), 4096); // clamped at the depth cap
        assert!(m.plan(3, Dtype::U8).queue_capacity() >= 64);
    }

    #[test]
    fn induced_plan_recovers_blocks_on_large_graphs() {
        let m = OccupancyModel::default();
        let flat = m.plan(90_000, Dtype::U32);
        let induced = m.plan_induced(90_000, Dtype::U32, 1.0);
        // the collapsed path charge admits far more resident blocks
        assert!(induced.blocks > flat.blocks);
        assert!(induced.path_bytes < flat.path_bytes);
        // per-frame payload and shared-mem fit are unchanged: induction
        // shrinks the *stack*, not the root array
        assert_eq!(induced.degree_array_bytes, flat.degree_array_bytes);
        assert_eq!(induced.fits_shared_mem, flat.fits_shared_mem);
        assert_eq!(induced.stack_depth, flat.stack_depth);
    }

    #[test]
    fn induced_plan_alpha_zero_is_flat() {
        let m = OccupancyModel::default();
        assert_eq!(m.plan_induced(10_000, Dtype::U16, 0.0), m.plan(10_000, Dtype::U16));
    }

    #[test]
    fn induced_queue_capacity_is_boosted_and_bounded() {
        let m = OccupancyModel::default();
        let flat = m.plan(5_000, Dtype::U16);
        let induced = m.plan_induced(5_000, Dtype::U16, 0.5);
        assert!(induced.queue_capacity() >= flat.queue_capacity());
        assert!(induced.queue_capacity() <= 8192);
        // tiny graphs stay at the floor either way
        assert_eq!(m.plan_induced(3, Dtype::U8, 1.0).queue_capacity(), 64);
    }

    #[test]
    fn delta_plan_charges_pinned_frames_and_recovers_blocks() {
        let m = OccupancyModel::default();
        let induced = m.plan_induced(90_000, Dtype::U32, 1.0);
        let delta = m.plan_delta(90_000, Dtype::U32, 1.0, 24);
        // the per-node charge collapses below even the induced model,
        // and the total (payloads + pinned snapshots) still admits more
        // resident blocks
        assert!(delta.path_bytes < induced.path_bytes);
        assert!(delta.blocks >= induced.blocks);
        // the pinned base frames are modeled and non-zero
        assert!(delta.pinned_bytes > 0);
        assert_eq!(induced.pinned_bytes, 0);
        // per-frame payload and shared-mem fit are representation-free
        assert_eq!(delta.degree_array_bytes, induced.degree_array_bytes);
        assert_eq!(delta.fits_shared_mem, induced.fits_shared_mem);
    }

    #[test]
    fn delta_plan_smaller_pin_depth_pins_more() {
        let m = OccupancyModel::default();
        let tight = m.plan_delta(50_000, Dtype::U16, 1.0, 2);
        let loose = m.plan_delta(50_000, Dtype::U16, 1.0, 64);
        assert!(tight.pinned_bytes >= loose.pinned_bytes);
        // more pinned bytes ⇒ a bigger total charge ⇒ no more blocks
        assert!(tight.blocks <= loose.blocks);
    }

    #[test]
    fn queue_capacity_not_double_counted_under_delta() {
        // The delta plan's tiny per-node path charge must not explode
        // the queue boost as if the whole stack budget were freed: the
        // pinned-frame bytes are added to the effective charge, so the
        // boost can never exceed what ignoring the snapshots would
        // grant, and it stays within the model's global cap.
        let m = OccupancyModel::default();
        for pin in [1u32, 24] {
            let delta = m.plan_delta(5_000, Dtype::U16, 0.5, pin);
            let mut unpinned = delta.clone();
            unpinned.pinned_bytes = 0;
            assert!(delta.queue_capacity() <= unpinned.queue_capacity(), "pin {pin}");
            assert!(delta.queue_capacity() <= 8192, "pin {pin}");
        }
        // pinned-dominant shape: frequent snapshots on a wide u32 plan
        // outweigh the per-node delta payloads, and the charge follows
        let tight = m.plan_delta(200_000, Dtype::U32, 1.0, 1);
        assert!(tight.pinned_bytes > tight.path_bytes);
        assert!(tight.queue_capacity() <= 8192);
    }

    #[test]
    fn admission_capacity_scales_with_budget_and_clamps() {
        let m = OccupancyModel::default();
        // default: (4 GiB >> 8) / 512 = 32768, clamped to the 4096 cap
        assert_eq!(m.admission_capacity(), 4096);
        let tiny = OccupancyModel { stack_budget_bytes: 1 << 20, ..m.clone() };
        // 4 KiB slice / 512 = 8, clamped up to the 64 floor
        assert_eq!(tiny.admission_capacity(), 64);
        let mid = OccupancyModel { stack_budget_bytes: 64 << 20, ..m };
        assert_eq!(mid.admission_capacity(), 512);
    }

    #[test]
    fn replans_shrink_with_live_bytes_and_recover() {
        let m = OccupancyModel::default();
        // Empty ledger: the replan equals the static plan.
        assert_eq!(m.replan_admission(0), m.admission_capacity());
        assert_eq!(m.replan_memo_budget(0), m.memo_budget_bytes());
        // Half the budget live: capacities shrink but stay in range.
        let half = m.stack_budget_bytes / 2;
        assert!(m.replan_admission(half) <= m.admission_capacity());
        assert_eq!(m.replan_memo_budget(half), half / 4);
        // Budget exhausted (or overshot): floors, never zero/panic.
        assert_eq!(m.replan_admission(u64::MAX), 64);
        assert_eq!(m.replan_memo_budget(u64::MAX), 0);
        let q_empty = m.replan_queue_capacity(0, 4096, 8);
        let q_full = m.replan_queue_capacity(u64::MAX, 4096, 8);
        assert!(q_empty >= q_full);
        assert!((64..=8192).contains(&q_full));
        assert!((64..=8192).contains(&q_empty));
    }

    #[test]
    fn workers_bounded_by_hw() {
        let m = OccupancyModel::default();
        let hw = std::thread::available_parallelism().unwrap().get();
        assert!(m.workers(324, Dtype::U8) <= hw);
        assert!(m.workers(324, Dtype::U8) >= 1);
    }
}
