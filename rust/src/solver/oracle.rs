//! Exact brute-force oracle for small graphs (≤ 64 vertices).
//!
//! Used only by tests and the harness's self-checks: an independent,
//! dead-simple implementation (bitmask branch-and-bound, no reduction
//! rules beyond degree-0) that every production solver is validated
//! against on thousands of random instances.

use crate::graph::Graph;

/// Exact minimum vertex cover size. Panics if `g` has more than 64
/// vertices (use the real solvers beyond that).
pub fn mvc_size(g: &Graph) -> u32 {
    let n = g.num_vertices();
    assert!(n <= 64, "oracle supports ≤ 64 vertices");
    let adj: Vec<u64> = (0..n as u32)
        .map(|v| g.neighbors(v).iter().fold(0u64, |m, &w| m | (1u64 << w)))
        .collect();
    let present: u64 = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
    let mut best = n as u32;
    branch(&adj, present, 0, &mut best);
    best
}

/// Exact minimum vertex cover (one witness), for cover-validity tests.
pub fn mvc_cover(g: &Graph) -> Vec<u32> {
    let n = g.num_vertices();
    assert!(n <= 64, "oracle supports ≤ 64 vertices");
    let adj: Vec<u64> = (0..n as u32)
        .map(|v| g.neighbors(v).iter().fold(0u64, |m, &w| m | (1u64 << w)))
        .collect();
    let mut present: u64 = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
    let mut cover = Vec::new();
    // Self-reducibility: vertex v is in some MVC of the residual iff
    // mvc(residual − v) == mvc(residual) − 1.
    loop {
        let mut remaining = (present.count_ones()).max(1);
        branch(&adj, present, 0, &mut remaining);
        if remaining == 0 {
            break;
        }
        let v = (0..n)
            .find(|&v| {
                if present >> v & 1 == 0 || adj[v] & present == 0 {
                    return false;
                }
                let mut sub = remaining; // prune at remaining → finds < remaining
                branch(&adj, present & !(1u64 << v), 0, &mut sub);
                sub <= remaining - 1
            })
            .expect("witness vertex must exist");
        cover.push(v as u32);
        present &= !(1u64 << v);
    }
    // shared witness verifier: the oracle's covers go through the same
    // edge-by-edge check as every production extraction path
    debug_assert!(crate::solver::witness::verify_cover(g, &cover).is_ok());
    cover
}

fn branch(adj: &[u64], present: u64, size: u32, best: &mut u32) {
    if size >= *best {
        return;
    }
    // find a vertex with maximum residual degree
    let mut vmax = usize::MAX;
    let mut dmax = 0u32;
    let mut p = present;
    while p != 0 {
        let v = p.trailing_zeros() as usize;
        p &= p - 1;
        let d = (adj[v] & present).count_ones();
        if d > dmax {
            dmax = d;
            vmax = v;
        }
    }
    if dmax == 0 {
        *best = size; // no edges left; size < *best guaranteed above
        return;
    }
    if dmax == 1 {
        // residual is a perfect matching fragment: one vertex per edge
        let mut extra = 0u32;
        let mut q = present;
        let mut seen = 0u64;
        while q != 0 {
            let v = q.trailing_zeros() as usize;
            q &= q - 1;
            if seen >> v & 1 == 1 {
                continue;
            }
            let nb = adj[v] & present & !seen;
            if nb != 0 {
                let w = nb.trailing_zeros() as usize;
                seen |= (1u64 << v) | (1u64 << w);
                extra += 1;
            }
        }
        if size + extra < *best {
            *best = size + extra;
        }
        return;
    }
    // include vmax
    branch(adj, present & !(1u64 << vmax), size + 1, best);
    // include N(vmax)
    let nb = adj[vmax] & present;
    branch(
        adj,
        present & !nb & !(1u64 << vmax),
        size + nb.count_ones(),
        best,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn known_values() {
        assert_eq!(mvc_size(&generators::path(2)), 1);
        assert_eq!(mvc_size(&generators::path(5)), 2);
        assert_eq!(mvc_size(&generators::cycle(5)), 3);
        assert_eq!(mvc_size(&generators::cycle(6)), 3);
        assert_eq!(mvc_size(&generators::clique(6)), 5);
        assert_eq!(mvc_size(&generators::star(9)), 1);
        assert_eq!(mvc_size(&Graph::from_edges(4, &[])), 0);
    }

    #[test]
    fn petersen_graph() {
        // Petersen: MVC = 6 (independence number 4).
        let edges = [
            (0, 1), (1, 2), (2, 3), (3, 4), (4, 0), // outer C5
            (5, 7), (7, 9), (9, 6), (6, 8), (8, 5), // inner pentagram
            (0, 5), (1, 6), (2, 7), (3, 8), (4, 9), // spokes
        ];
        let g = Graph::from_edges(10, &edges);
        assert_eq!(mvc_size(&g), 6);
    }

    #[test]
    fn disjoint_union_adds() {
        let g = Graph::disjoint_union(&[generators::cycle(5), generators::clique(4)]);
        assert_eq!(mvc_size(&g), 3 + 3);
    }

    #[test]
    fn cover_witness_valid_and_optimal() {
        for seed in 0..6 {
            let g = generators::erdos_renyi(12, 0.25, seed);
            let c = mvc_cover(&g);
            assert!(g.is_vertex_cover(&c), "seed {seed}");
            assert_eq!(c.len() as u32, mvc_size(&g), "seed {seed}");
        }
    }

    #[test]
    fn matches_complement_independent_set_bound() {
        // sanity: n - mvc = max independent set ≥ n/(Δ+1)
        for seed in 0..6 {
            let g = generators::erdos_renyi(16, 0.2, seed);
            let mis = 16 - mvc_size(&g);
            let lower = 16 / (g.max_degree() + 1);
            assert!(mis >= lower, "seed {seed}");
        }
    }
}
