//! The component branch registry (paper §III-C) — the mechanism that
//! makes *non-tail-recursive* branches load-balanceable.
//!
//! Branching on components needs post-processing after the children
//! return (accumulate each component's minimum into the parent's sum;
//! fold the completed sum into the enclosing context). Instead of pinning
//! a parent and its descendants to one worker, every component branch is
//! *registered* in shared memory and the post-processing is delegated to
//! the **last descendant** of each branch:
//!
//! * a **child** (component) entry holds `Best` (smallest achievable
//!   cover found for the component so far), a prune `Limit`, `LiveNodes`
//!   (descendants still executing), and `ParentIdx`;
//! * a **parent** (split) entry holds `Sum` (solution vertices committed
//!   by the parent plus all finished components), `LiveComps` (components
//!   still being solved — including one reference held by the parent
//!   while it is still *discovering* components, so the count cannot hit
//!   zero early), and `AncestorIdx` (the context the parent node itself
//!   was solving, possibly another child entry: splits nest arbitrarily).
//!
//! All updates are atomic; whoever decrements a counter to zero owns the
//! continuation. The cascade in [`Registry::complete_node`] implements
//! lines 19–20 of Algorithm 2 across arbitrary nesting.
//!
//! ### MVC vs PVC
//! MVC defers all upward reporting to the last descendant. PVC (§III-E)
//! additionally propagates *achievable* improvements to the root as they
//! happen so the search can stop as soon as the root bound reaches `k`:
//! each parent maintains `Est = Sum₀ + Σ child Best` (always achievable
//! once component discovery has finished, since every child's `Best`
//! starts at the achievable `|V_i| − 1`), and child improvements bubble
//! through `Est` while discovery-complete. The paper conflates `Best`
//! with the prune bound; we split `Best` (achievable) from `Limit`
//! (prune-only) so the propagated totals are always sound.
//!
//! ### Witness reassembly (opt-in)
//! With [`Registry::with_witnesses`], every entry gets a side slot for
//! the vertex list behind its value: a child slot holds the *winning*
//! component cover (initialized to the achievable all-but-one fallback,
//! replaced by any strictly shorter leaf report or nested-split total),
//! a parent slot *accumulates* (the split node's choice-log prefix, the
//! closed-form special covers, then each finished component's winning
//! cover as the last-descendant cascade folds it). When a parent
//! finishes, its accumulated list is the assembled cover for the whole
//! split and travels up the same cascade; root-level totals land in a
//! root slot that keeps the shortest assembled cover seen. In MVC mode
//! every root-reported total is assembled, so the final root witness
//! length always equals the final best; in PVC mode `Est` propagation
//! reports *unassembled* achievable totals, so the engine gates early
//! stopping on the root slot instead (the witness may transiently be
//! longer than the bound, never invalid). Slots live in a mutexed side
//! table — witness extraction is opt-in and off the default hot path.
//!
//! Under the engine's delta node representation the registry contract
//! is unchanged: a delta right child reports the same leaf logs once it
//! runs, because its choice-log prefix is *shared with its pinned
//! parent frame* (the frame chain's base snapshot stores the log
//! prefix; undo truncates the live log back to it, materialization
//! re-extends a copy of it) rather than owned per queued node — the
//! log-concatenation algebra here never observes the difference.

use std::sync::atomic::{AtomicPtr, AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;

/// Sentinel context: "report to the global best" (the search root).
pub const NONE: u32 = u32::MAX;

const CHUNK_BITS: usize = 14;
const CHUNK: usize = 1 << CHUNK_BITS; // entries per chunk
const MAX_CHUNKS: usize = 1 << 16; // ~1.07e9 entries max

const KIND_CHILD: u32 = 1;
const KIND_PARENT: u32 = 2;
const FLAG_SCAN_DONE: u32 = 4;

/// One registry entry (child or parent role; see module docs).
#[derive(Debug)]
pub struct Entry {
    /// child: `Best`; parent: `Sum`.
    val: AtomicU32,
    /// child: `LiveNodes`; parent: `LiveComps`. u64 with debug underflow checks.
    live: AtomicU64,
    /// child: `ParentIdx`; parent: `AncestorIdx` (or [`NONE`]).
    link: AtomicU32,
    /// child: prune `Limit`; parent: `Est` for PVC propagation.
    aux: AtomicU32,
    /// role + scan-done flag.
    flags: AtomicU32,
}

impl Entry {
    const fn empty() -> Entry {
        Entry {
            val: AtomicU32::new(0),
            live: AtomicU64::new(0),
            link: AtomicU32::new(NONE),
            aux: AtomicU32::new(0),
            flags: AtomicU32::new(0),
        }
    }
}

/// Append-only atomic arena of registry entries.
///
/// Entries are addressed by dense `u32` ids; storage grows in chunks whose
/// base pointers are published through `AtomicPtr`, so readers never take
/// a lock and ids stay valid for the lifetime of the registry (mirroring
/// the paper's preallocated global-memory registry).
pub struct Registry {
    chunks: Vec<AtomicPtr<Entry>>,
    next: AtomicU64,
    grow: Mutex<()>,
    /// PVC mode: maintain `Est` and propagate improvements upward.
    propagate: bool,
    /// Witness side table (enabled by [`Registry::with_witnesses`]).
    witness: Option<WitnessStore>,
    /// Last-descendant fold hook (enabled by
    /// [`Registry::with_fold_observer`]): observes every child slot's
    /// terminal `(ctx, best, limit, witness)` as it folds into its
    /// parent. The memo subsystem uses it to detect exactly-solved
    /// components worth publishing to the cross-job cache.
    fold_observer: Option<FoldObserver>,
}

/// Observer of child-slot folds: `(ctx, best, limit, witness)` where
/// `limit` is the slot's pruning bound (`aux`) and `witness` the winning
/// cover behind `best` when extraction is on. Runs inside the completion
/// cascade — it must be cheap and must not call back into the registry.
pub type FoldObserver = Box<dyn Fn(u32, u32, u32, Option<&[u32]>) + Send + Sync>;

/// Side table of witness vertex lists, indexed by entry id, plus the
/// root slot. Entries are only touched when extraction is on; the mutex
/// is uncontended relative to the search work behind each update.
struct WitnessStore {
    slots: Mutex<Vec<Option<Vec<u32>>>>,
    root: Mutex<Option<Vec<u32>>>,
    /// Lock-free mirror of the root slot's length (`u32::MAX` = none
    /// yet), maintained under the root lock so anytime-progress pollers
    /// ([`Registry::root_witness_len`]) never contend with the workers'
    /// shortest-wins offers.
    root_len: AtomicU32,
}

impl WitnessStore {
    fn new() -> WitnessStore {
        WitnessStore {
            slots: Mutex::new(Vec::new()),
            root: Mutex::new(None),
            root_len: AtomicU32::new(u32::MAX),
        }
    }

    /// The slot for entry `idx`, growing the table as needed (all slot
    /// mutations go through here so the growth policy lives once).
    fn slot_mut(slots: &mut Vec<Option<Vec<u32>>>, idx: u32) -> &mut Option<Vec<u32>> {
        if slots.len() <= idx as usize {
            slots.resize(idx as usize + 1, None);
        }
        &mut slots[idx as usize]
    }

    /// Set a slot unconditionally (entry initialization).
    fn put(&self, idx: u32, w: Vec<u32>) {
        let mut slots = self.slots.lock().unwrap();
        *Self::slot_mut(&mut slots, idx) = Some(w);
    }

    /// Append vertices to a parent's accumulated list.
    fn append(&self, idx: u32, extra: &[u32]) {
        let mut slots = self.slots.lock().unwrap();
        match Self::slot_mut(&mut slots, idx) {
            Some(acc) => acc.extend_from_slice(extra),
            none => *none = Some(extra.to_vec()),
        }
    }

    /// Replace a child's winning list if `w` is strictly shorter.
    fn improve(&self, idx: u32, w: &[u32]) {
        let mut slots = self.slots.lock().unwrap();
        let slot = Self::slot_mut(&mut slots, idx);
        if slot.as_ref().is_none_or(|cur| w.len() < cur.len()) {
            *slot = Some(w.to_vec());
        }
    }

    /// Take a slot's list (entry finished; no further reads).
    fn take(&self, idx: u32) -> Option<Vec<u32>> {
        let mut slots = self.slots.lock().unwrap();
        slots.get_mut(idx as usize).and_then(Option::take)
    }

    /// Keep the shorter of the current root witness and `w`.
    fn offer_root(&self, w: &[u32]) {
        let mut root = self.root.lock().unwrap();
        if root.as_ref().is_none_or(|cur| w.len() < cur.len()) {
            *root = Some(w.to_vec());
            self.root_len.store(w.len() as u32, Ordering::Release);
        }
    }
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Registry").field("len", &self.len()).finish()
    }
}

impl Drop for Registry {
    fn drop(&mut self) {
        for c in &self.chunks {
            let p = c.load(Ordering::Acquire);
            if !p.is_null() {
                // SAFETY: allocated as Box<[Entry; CHUNK]> in ensure_chunk.
                unsafe { drop(Box::from_raw(p as *mut [Entry; CHUNK])) };
            }
        }
    }
}

impl Registry {
    /// Create an empty registry. `propagate` enables PVC-style upward
    /// propagation of achievable totals.
    pub fn new(propagate: bool) -> Registry {
        let mut chunks = Vec::with_capacity(MAX_CHUNKS);
        chunks.resize_with(MAX_CHUNKS, || AtomicPtr::new(std::ptr::null_mut()));
        Registry {
            chunks,
            next: AtomicU64::new(0),
            grow: Mutex::new(()),
            propagate,
            witness: None,
            fold_observer: None,
        }
    }

    /// Enable witness reassembly: every entry gains a side slot for the
    /// vertex list behind its value, and the completion cascade
    /// concatenates component witnesses as it folds sizes (module docs).
    pub fn with_witnesses(mut self) -> Registry {
        self.witness = Some(WitnessStore::new());
        self
    }

    /// True when witness reassembly is enabled.
    pub fn extracting(&self) -> bool {
        self.witness.is_some()
    }

    /// Install a last-descendant fold observer (see [`FoldObserver`]).
    pub fn with_fold_observer(mut self, obs: FoldObserver) -> Registry {
        self.fold_observer = Some(obs);
        self
    }

    /// Number of entries ever allocated.
    pub fn len(&self) -> usize {
        self.next.load(Ordering::Relaxed) as usize
    }

    /// True if no entries were allocated.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn ensure_chunk(&self, ci: usize) -> *mut Entry {
        let p = self.chunks[ci].load(Ordering::Acquire);
        if !p.is_null() {
            return p;
        }
        let _g = self.grow.lock().unwrap();
        let p = self.chunks[ci].load(Ordering::Acquire);
        if !p.is_null() {
            return p;
        }
        let boxed: Box<[Entry; CHUNK]> = {
            // avoid large stack temporaries: build via Vec
            let mut v = Vec::with_capacity(CHUNK);
            v.resize_with(CHUNK, Entry::empty);
            v.into_boxed_slice().try_into().ok().expect("exact chunk size")
        };
        let raw = Box::into_raw(boxed) as *mut Entry;
        self.chunks[ci].store(raw, Ordering::Release);
        raw
    }

    #[inline]
    fn entry(&self, idx: u32) -> &Entry {
        debug_assert!((idx as usize) < self.len(), "registry index {idx} out of range");
        let ci = idx as usize >> CHUNK_BITS;
        let off = idx as usize & (CHUNK - 1);
        let base = self.chunks[ci].load(Ordering::Acquire);
        debug_assert!(!base.is_null());
        // SAFETY: chunk pointers are published once and never freed until drop.
        unsafe { &*base.add(off) }
    }

    fn alloc(&self) -> u32 {
        let idx = self.next.fetch_add(1, Ordering::Relaxed);
        assert!(idx < (MAX_CHUNKS * CHUNK) as u64, "registry exhausted");
        self.ensure_chunk(idx as usize >> CHUNK_BITS);
        idx as u32
    }

    /// Register a parent (split) entry: `sum0` = |S| committed at the
    /// split node, `ancestor` = the node's own context. `LiveComps`
    /// starts at 1 — the discovery reference held by the scanning parent.
    pub fn new_parent(&self, sum0: u32, ancestor: u32) -> u32 {
        let idx = self.alloc();
        let e = self.entry(idx);
        e.val.store(sum0, Ordering::SeqCst);
        e.live.store(1, Ordering::SeqCst);
        e.link.store(ancestor, Ordering::SeqCst);
        e.aux.store(sum0, Ordering::SeqCst); // Est = Sum₀ (+ children as they register)
        e.flags.store(KIND_PARENT, Ordering::SeqCst);
        idx
    }

    /// Register a child (component) entry under `parent`.
    ///
    /// `best0` must be *achievable* for the component (the paper's
    /// `|V_i| − 1`); `limit` is the prune-only bound
    /// `min(ctx_bound − sum, |V_i| − 1)`. Increments the parent's
    /// `LiveComps` and folds `best0` into the parent's `Est`.
    pub fn new_child(&self, parent: u32, best0: u32, limit: u32) -> u32 {
        let idx = self.alloc();
        let e = self.entry(idx);
        e.val.store(best0, Ordering::SeqCst);
        e.live.store(1, Ordering::SeqCst);
        e.link.store(parent, Ordering::SeqCst);
        e.aux.store(limit, Ordering::SeqCst);
        e.flags.store(KIND_CHILD, Ordering::SeqCst);
        let p = self.entry(parent);
        debug_assert_eq!(p.flags.load(Ordering::SeqCst) & KIND_PARENT, KIND_PARENT);
        p.live.fetch_add(1, Ordering::SeqCst);
        p.aux.fetch_add(best0, Ordering::SeqCst);
        idx
    }

    /// A component solved in closed form during discovery (clique /
    /// chordless-cycle rules, §III-D): fold its exact cover size straight
    /// into the parent's `Sum`/`Est` without allocating a child entry.
    pub fn add_solved_component(&self, parent: u32, mvc: u32) {
        let p = self.entry(parent);
        p.val.fetch_add(mvc, Ordering::SeqCst);
        p.aux.fetch_add(mvc, Ordering::SeqCst);
    }

    /// The prune bound for a node in context `ctx`: `min(Best, Limit)` of
    /// the child entry (callers handle `ctx == NONE` via the global best).
    #[inline]
    pub fn bound(&self, ctx: u32) -> u32 {
        let e = self.entry(ctx);
        e.val.load(Ordering::SeqCst).min(e.aux.load(Ordering::SeqCst))
    }

    /// A node in context `ctx` branched into two children: one extra live
    /// descendant.
    #[inline]
    pub fn on_branch(&self, ctx: u32) {
        if ctx != NONE {
            self.entry(ctx).live.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// Extra live descendant for an out-of-band hand-off (e.g. seeding).
    pub fn add_live(&self, ctx: u32, n: u64) {
        if ctx != NONE && n > 0 {
            self.entry(ctx).live.fetch_add(n, Ordering::SeqCst);
        }
    }

    /// A leaf in context `ctx` found a cover of size `size` for its
    /// component. Records it and, in PVC mode, propagates the achievable
    /// total toward the root. `on_root` receives any resulting achievable
    /// *root-level* total (for the global best / early termination).
    pub fn report_solution(&self, ctx: u32, size: u32, on_root: &mut dyn FnMut(u32)) {
        debug_assert_ne!(ctx, NONE);
        if self.propagate {
            self.propagate_improvement(ctx, size, on_root);
        } else {
            cas_min(&self.entry(ctx).val, size);
        }
    }

    /// [`Registry::report_solution`] with the leaf's witness (the
    /// covered-vertex list achieving `size`): the child's winning slot
    /// keeps the shortest report, so the last-descendant fold hands the
    /// matching cover up with the folded size.
    pub fn report_witnessed(
        &self,
        ctx: u32,
        size: u32,
        witness: &[u32],
        on_root: &mut dyn FnMut(u32),
    ) {
        debug_assert_eq!(witness.len() as u32, size, "witness length must match its size");
        if let Some(ws) = &self.witness {
            ws.improve(ctx, witness);
        }
        self.report_solution(ctx, size, on_root);
    }

    /// Seed a new parent's accumulated witness with the split node's
    /// choice-log prefix (the `Sum₀` vertices).
    pub fn witness_init_parent(&self, parent: u32, prefix: &[u32]) {
        if let Some(ws) = &self.witness {
            ws.put(parent, prefix.to_vec());
        }
    }

    /// Seed a new child's winning witness with the achievable
    /// all-but-one fallback (length must equal the child's `best0`).
    pub fn witness_init_child(&self, child: u32, fallback: &[u32]) {
        if let Some(ws) = &self.witness {
            ws.put(child, fallback.to_vec());
        }
    }

    /// Fold a closed-form special component's canonical cover into the
    /// parent's accumulated witness (the vertex-list counterpart of
    /// [`Registry::add_solved_component`]).
    pub fn witness_solved_component(&self, parent: u32, cover: &[u32]) {
        if let Some(ws) = &self.witness {
            ws.append(parent, cover);
        }
    }

    /// A root-context leaf's assembled cover: keep it if it is the
    /// shortest seen. Callers pair this with the root-total report.
    pub fn offer_root_witness(&self, witness: &[u32]) {
        if let Some(ws) = &self.witness {
            ws.offer_root(witness);
        }
    }

    /// Length of the best assembled root witness so far, if any.
    /// Lock-free (reads the length mirror, not the slot), so anytime
    /// callers — [`crate::solver::JobHandle::progress`], a deadline
    /// about to fire — can poll it at any rate without slowing the
    /// workers' shortest-wins offers. Monotone non-increasing; it keeps
    /// reporting the last length even after
    /// [`Registry::take_root_witness`] retires the slot itself.
    pub fn root_witness_len(&self) -> Option<usize> {
        self.witness.as_ref().and_then(|ws| {
            match ws.root_len.load(Ordering::Acquire) {
                u32::MAX => None,
                len => Some(len as usize),
            }
        })
    }

    /// Take the best assembled root witness (end of the run).
    pub fn take_root_witness(&self) -> Option<Vec<u32>> {
        self.witness.as_ref().and_then(|ws| ws.root.lock().unwrap().take())
    }

    /// Component discovery at parent `p` finished: release the discovery
    /// reference (may trigger the completion cascade if every component
    /// already finished) and enable PVC propagation through `p`.
    pub fn finish_scan(&self, p: u32, on_root: &mut dyn FnMut(u32)) {
        let e = self.entry(p);
        e.flags.fetch_or(FLAG_SCAN_DONE, Ordering::SeqCst);
        if self.propagate {
            // One propagation now that Est covers all components. The
            // est total is achievable but not assembled, so it carries
            // no witness (module docs: witness reassembly under PVC).
            let est = e.aux.load(Ordering::SeqCst);
            let anc = e.link.load(Ordering::SeqCst);
            if anc == NONE {
                on_root(est);
            } else {
                self.propagate_improvement(anc, est, on_root);
            }
        }
        self.complete_parent_ref(p, on_root);
    }

    /// A node in context `ctx` completed (leaf, pruned, or branched-away).
    /// Runs the last-descendant cascade (paper §III-C / Figure 3).
    pub fn complete_node(&self, mut ctx: u32, on_root: &mut dyn FnMut(u32)) {
        while ctx != NONE {
            let e = self.entry(ctx);
            debug_assert_eq!(e.flags.load(Ordering::SeqCst) & KIND_CHILD, KIND_CHILD);
            let prev = e.live.fetch_sub(1, Ordering::SeqCst);
            debug_assert!(prev >= 1, "LiveNodes underflow");
            if prev != 1 {
                return; // other descendants still running
            }
            // Last descendant of component `ctx`: fold Best into parent
            // Sum, and the winning witness into the parent's accumulated
            // list (all reports for `ctx` happened-before this fold).
            let parent = e.link.load(Ordering::SeqCst);
            let best = e.val.load(Ordering::SeqCst);
            let cw = self.witness.as_ref().and_then(|ws| ws.take(ctx));
            if let Some(obs) = &self.fold_observer {
                obs(ctx, best, e.aux.load(Ordering::SeqCst), cw.as_deref());
            }
            if let (Some(ws), Some(cw)) = (&self.witness, &cw) {
                ws.append(parent, cw);
            }
            let p = self.entry(parent);
            p.val.fetch_add(best, Ordering::SeqCst);
            match self.release_parent_ref(parent) {
                ParentState::StillLive => return,
                ParentState::Finished { total, ancestor, witness } => {
                    if ancestor == NONE {
                        if let Some(w) = &witness {
                            self.offer_root_witness(w);
                        }
                        on_root(total);
                        return;
                    }
                    // Fold the completed split into the enclosing component
                    // and continue the cascade there.
                    self.improve_child_value(ancestor, total, witness.as_deref(), on_root);
                    ctx = ancestor;
                }
            }
        }
    }

    /// Decrement a parent's `LiveComps` due to `complete_node` folding.
    /// On the last reference, hand back the assembled witness too.
    fn release_parent_ref(&self, p_idx: u32) -> ParentState {
        let p = self.entry(p_idx);
        let prev = p.live.fetch_sub(1, Ordering::SeqCst);
        debug_assert!(prev >= 1, "LiveComps underflow");
        if prev != 1 {
            return ParentState::StillLive;
        }
        let total = p.val.load(Ordering::SeqCst);
        let witness = self.witness.as_ref().and_then(|ws| ws.take(p_idx));
        if let Some(w) = &witness {
            // In MVC mode every fold is assembled, so lengths are exact;
            // PVC est propagation can leave the witness transiently
            // longer than the folded total (never shorter, never wrong).
            debug_assert!(
                self.propagate || w.len() as u32 == total,
                "assembled witness length {} != folded total {total}",
                w.len()
            );
        }
        ParentState::Finished { total, ancestor: p.link.load(Ordering::SeqCst), witness }
    }

    /// Release the discovery reference and, if that finished the parent,
    /// continue the cascade (shared by `finish_scan`).
    fn complete_parent_ref(&self, p_idx: u32, on_root: &mut dyn FnMut(u32)) {
        match self.release_parent_ref(p_idx) {
            ParentState::StillLive => {}
            ParentState::Finished { total, ancestor, witness } => {
                if ancestor == NONE {
                    if let Some(w) = &witness {
                        self.offer_root_witness(w);
                    }
                    on_root(total);
                } else {
                    self.improve_child_value(ancestor, total, witness.as_deref(), on_root);
                    self.complete_node(ancestor, on_root);
                }
            }
        }
    }

    /// CAS-min a child's `Best` and keep parent `Est` consistent (PVC);
    /// `witness` is the assembled cover behind `val`, when one exists.
    fn improve_child_value(
        &self,
        ctx: u32,
        val: u32,
        witness: Option<&[u32]>,
        on_root: &mut dyn FnMut(u32),
    ) {
        if let (Some(ws), Some(w)) = (&self.witness, witness) {
            ws.improve(ctx, w);
        }
        if self.propagate {
            self.propagate_improvement(ctx, val, on_root);
        } else {
            cas_min(&self.entry(ctx).val, val);
        }
    }

    /// PVC upward propagation (§III-E): improve `ctx.Best`, adjust the
    /// parent's `Est` by the achieved delta, and if the parent finished
    /// discovery, push the (achievable) `Est` further up — all the way to
    /// the root when the chain allows.
    fn propagate_improvement(&self, mut ctx: u32, mut val: u32, on_root: &mut dyn FnMut(u32)) {
        loop {
            let e = self.entry(ctx);
            let Some(old) = cas_min(&e.val, val) else { return };
            let delta = old - val;
            let p_idx = e.link.load(Ordering::SeqCst);
            let p = self.entry(p_idx);
            p.aux.fetch_sub(delta, Ordering::SeqCst);
            if p.flags.load(Ordering::SeqCst) & FLAG_SCAN_DONE == 0 {
                return; // Est incomplete until discovery ends
            }
            let est = p.aux.load(Ordering::SeqCst);
            let anc = p.link.load(Ordering::SeqCst);
            if anc == NONE {
                on_root(est);
                return;
            }
            ctx = anc;
            val = est;
        }
    }

    /// Test/diagnostic: (val, live, link, aux) of an entry.
    pub fn snapshot(&self, idx: u32) -> (u32, u64, u32, u32) {
        let e = self.entry(idx);
        (
            e.val.load(Ordering::SeqCst),
            e.live.load(Ordering::SeqCst),
            e.link.load(Ordering::SeqCst),
            e.aux.load(Ordering::SeqCst),
        )
    }

    /// Invariant check after a run: every counter drained to zero.
    pub fn assert_drained(&self) {
        for i in 0..self.len() as u32 {
            let (_, live, _, _) = self.snapshot(i);
            assert_eq!(live, 0, "entry {i} still live after completion");
        }
    }
}

enum ParentState {
    StillLive,
    Finished { total: u32, ancestor: u32, witness: Option<Vec<u32>> },
}

/// Atomic CAS-min; returns the displaced larger value if it decreased.
pub fn cas_min(a: &AtomicU32, new: u32) -> Option<u32> {
    let mut cur = a.load(Ordering::SeqCst);
    loop {
        if cur <= new {
            return None;
        }
        match a.compare_exchange_weak(cur, new, Ordering::SeqCst, Ordering::SeqCst) {
            Ok(_) => return Some(cur),
            Err(c) => cur = c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Single split with two components, solved sequentially.
    #[test]
    fn basic_split_aggregates() {
        let reg = Registry::new(false);
        let root_totals = std::cell::RefCell::new(Vec::<u32>::new());
        let mut on_root = |t: u32| root_totals.borrow_mut().push(t);

        let p = reg.new_parent(3, NONE); // parent committed 3 vertices
        let c1 = reg.new_child(p, 4, 4); // component of 5 vertices
        let c2 = reg.new_child(p, 2, 2);
        reg.finish_scan(p, &mut on_root);

        // component 1 solved with best 2 (a leaf reports, then completes)
        reg.report_solution(c1, 2, &mut on_root);
        reg.complete_node(c1, &mut on_root);
        assert!(root_totals.borrow().is_empty());

        // component 2 keeps its initial best (fully pruned)
        reg.complete_node(c2, &mut on_root);
        assert_eq!(*root_totals.borrow(), vec![3 + 2 + 2]);
        reg.assert_drained();
    }

    /// The discovery reference keeps LiveComps from reaching zero early.
    #[test]
    fn scan_reference_blocks_early_completion() {
        let reg = Registry::new(false);
        let root_totals = std::cell::RefCell::new(Vec::<u32>::new());
        let mut on_root = |t: u32| root_totals.borrow_mut().push(t);

        let p = reg.new_parent(0, NONE);
        let c1 = reg.new_child(p, 1, 1);
        // child finishes BEFORE the scan ends
        reg.complete_node(c1, &mut on_root);
        assert!(root_totals.borrow().is_empty(), "must wait for finish_scan");
        reg.finish_scan(p, &mut on_root);
        assert_eq!(*root_totals.borrow(), vec![1]);
    }

    /// Nested splits: the cascade walks multiple levels (paper Figure 3).
    #[test]
    fn nested_cascade() {
        let reg = Registry::new(false);
        let root_totals = std::cell::RefCell::new(Vec::<u32>::new());
        let mut on_root = |t: u32| root_totals.borrow_mut().push(t);

        // node1 splits into comps 2 and 3 (Figure 3)
        let p1 = reg.new_parent(0, NONE);
        let c2 = reg.new_child(p1, 5, 5);
        let c3 = reg.new_child(p1, 9, 9);
        reg.finish_scan(p1, &mut on_root);

        // node 12 (a descendant of c3) splits into comps 13, 14
        let p12 = reg.new_parent(1, c3); // 1 vertex committed on the path
        let c13 = reg.new_child(p12, 3, 3);
        let c14 = reg.new_child(p12, 2, 2);
        reg.on_branch(c3); // node12 branched from c3's tree: net +1 live
        reg.finish_scan(p12, &mut on_root);

        // solve comp 13 with best 2, comp 14 with best 1
        reg.report_solution(c13, 2, &mut on_root);
        reg.complete_node(c13, &mut on_root);
        reg.report_solution(c14, 1, &mut on_root);
        reg.complete_node(c14, &mut on_root);
        // split p12 finished: total = 1+2+1 = 4 < c3.best (9), improves c3,
        // and cascades: c3 live 2-1=1 (node12 done), still live
        assert!(root_totals.borrow().is_empty());
        let (c3_best, c3_live, _, _) = reg.snapshot(c3);
        assert_eq!(c3_best, 4);
        assert_eq!(c3_live, 1);

        // remaining c3 descendant and c2 finish
        reg.complete_node(c3, &mut on_root);
        assert!(root_totals.borrow().is_empty());
        reg.complete_node(c2, &mut on_root);
        assert_eq!(*root_totals.borrow(), vec![5 + 4]);
        reg.assert_drained();
    }

    /// Closed-form components fold into Sum without child entries.
    #[test]
    fn solved_component_folds_into_sum() {
        let reg = Registry::new(false);
        let root_totals = std::cell::RefCell::new(Vec::<u32>::new());
        let mut on_root = |t: u32| root_totals.borrow_mut().push(t);
        let p = reg.new_parent(2, NONE);
        reg.add_solved_component(p, 3); // a clique handled by §III-D
        let c = reg.new_child(p, 4, 4);
        reg.finish_scan(p, &mut on_root);
        reg.complete_node(c, &mut on_root);
        assert_eq!(*root_totals.borrow(), vec![2 + 3 + 4]);
    }

    /// PVC propagation reaches the root before completion.
    #[test]
    fn pvc_propagates_achievable_totals() {
        let reg = Registry::new(true);
        let root_totals = std::cell::RefCell::new(Vec::<u32>::new());
        let mut on_root = |t: u32| root_totals.borrow_mut().push(t);

        let p = reg.new_parent(1, NONE);
        let c1 = reg.new_child(p, 4, 4);
        let _c2 = reg.new_child(p, 6, 6);
        // no propagation before the scan completes
        reg.report_solution(c1, 3, &mut on_root);
        assert!(root_totals.borrow().is_empty());
        reg.finish_scan(p, &mut on_root);
        // Est = 1 + 3 + 6 = 10 announced at scan end
        assert_eq!(*root_totals.borrow(), vec![10]);
        // an improvement on c1 now bubbles immediately
        reg.report_solution(c1, 2, &mut on_root);
        assert_eq!(*root_totals.borrow(), vec![10, 9]);
    }

    #[test]
    fn bound_is_min_of_best_and_limit() {
        let reg = Registry::new(false);
        let p = reg.new_parent(0, NONE);
        let c = reg.new_child(p, 10, 7);
        assert_eq!(reg.bound(c), 7);
        let mut sink = |_t: u32| {};
        reg.report_solution(c, 5, &mut sink);
        assert_eq!(reg.bound(c), 5);
    }

    #[test]
    fn on_branch_tracks_live_nodes() {
        let reg = Registry::new(false);
        let p = reg.new_parent(0, NONE);
        let c = reg.new_child(p, 3, 3);
        reg.on_branch(c);
        reg.on_branch(c);
        assert_eq!(reg.snapshot(c).1, 3);
        let mut sink = |_t: u32| {};
        reg.complete_node(c, &mut sink);
        reg.complete_node(c, &mut sink);
        assert_eq!(reg.snapshot(c).1, 1);
    }

    /// Witnesses are reassembled exactly as sizes are folded: prefix +
    /// special covers + per-component winning witnesses.
    #[test]
    fn witness_reassembled_across_split() {
        let reg = Registry::new(false).with_witnesses();
        let root_totals = std::cell::RefCell::new(Vec::<u32>::new());
        let mut on_root = |t: u32| root_totals.borrow_mut().push(t);

        let p = reg.new_parent(2, NONE);
        reg.witness_init_parent(p, &[100, 101]); // split node's choice log
        reg.add_solved_component(p, 1);
        reg.witness_solved_component(p, &[50]); // a closed-form K2
        let c1 = reg.new_child(p, 4, 4);
        reg.witness_init_child(c1, &[10, 11, 12, 13]); // all-but-one fallback
        let c2 = reg.new_child(p, 2, 2);
        reg.witness_init_child(c2, &[20, 21]);
        reg.finish_scan(p, &mut on_root);

        // component 1 improves to 2 with a real cover; component 2 keeps
        // its fallback (fully pruned)
        reg.report_witnessed(c1, 2, &[10, 12], &mut on_root);
        reg.complete_node(c1, &mut on_root);
        reg.complete_node(c2, &mut on_root);

        assert_eq!(*root_totals.borrow(), vec![2 + 1 + 2 + 2]);
        let mut w = reg.take_root_witness().expect("assembled root witness");
        w.sort_unstable();
        assert_eq!(w, vec![10, 12, 20, 21, 50, 100, 101]);
        reg.assert_drained();
    }

    /// Nested splits assemble recursively: the inner split's total
    /// witness becomes the enclosing component's winning witness.
    #[test]
    fn witness_nested_splits_assemble() {
        let reg = Registry::new(false).with_witnesses();
        let mut on_root = |_t: u32| {};

        let p1 = reg.new_parent(0, NONE);
        reg.witness_init_parent(p1, &[]);
        let c2 = reg.new_child(p1, 2, 2);
        reg.witness_init_child(c2, &[1, 2]);
        let c3 = reg.new_child(p1, 9, 9);
        reg.witness_init_child(c3, &[10, 11, 12, 13, 14, 15, 16, 17, 18]);
        reg.finish_scan(p1, &mut on_root);

        // a descendant of c3 splits after committing vertex 10
        let p12 = reg.new_parent(1, c3);
        reg.witness_init_parent(p12, &[10]);
        let c13 = reg.new_child(p12, 3, 3);
        reg.witness_init_child(c13, &[11, 12, 13]);
        let c14 = reg.new_child(p12, 2, 2);
        reg.witness_init_child(c14, &[15, 16]);
        reg.on_branch(c3); // the splitting node branched from c3's tree
        reg.finish_scan(p12, &mut on_root);

        reg.report_witnessed(c13, 2, &[11, 13], &mut on_root);
        reg.complete_node(c13, &mut on_root);
        reg.report_witnessed(c14, 1, &[15], &mut on_root);
        reg.complete_node(c14, &mut on_root);
        // p12 finished with total 1+2+1 = 4 < 9: c3's witness is now the
        // assembled nested cover
        let (c3_best, _, _, _) = reg.snapshot(c3);
        assert_eq!(c3_best, 4);

        reg.complete_node(c3, &mut on_root);
        reg.complete_node(c2, &mut on_root);
        let mut w = reg.take_root_witness().expect("root witness");
        w.sort_unstable();
        assert_eq!(w, vec![1, 2, 10, 11, 13, 15]);
        reg.assert_drained();
    }

    /// The root slot keeps the shortest assembled witness.
    #[test]
    fn root_witness_keeps_shortest() {
        let reg = Registry::new(false).with_witnesses();
        reg.offer_root_witness(&[1, 2, 3]);
        reg.offer_root_witness(&[4, 5, 6, 7]);
        assert_eq!(reg.root_witness_len(), Some(3));
        reg.offer_root_witness(&[8]);
        assert_eq!(reg.take_root_witness(), Some(vec![8]));
        assert_eq!(reg.take_root_witness(), None);
    }

    /// A longer witnessed report never displaces a shorter one.
    #[test]
    fn child_witness_keeps_minimum() {
        let reg = Registry::new(false).with_witnesses();
        let mut sink = |_t: u32| {};
        let p = reg.new_parent(0, NONE);
        reg.witness_init_parent(p, &[]);
        let c = reg.new_child(p, 3, 3);
        reg.witness_init_child(c, &[1, 2, 3]);
        reg.report_witnessed(c, 2, &[4, 5], &mut sink);
        reg.report_witnessed(c, 3, &[6, 7, 8], &mut sink); // ignored: longer
        reg.finish_scan(p, &mut sink);
        reg.complete_node(c, &mut sink);
        let mut w = reg.take_root_witness().unwrap();
        w.sort_unstable();
        assert_eq!(w, vec![4, 5]);
    }

    #[test]
    fn witness_disabled_is_free_and_absent() {
        let reg = Registry::new(false);
        assert!(!reg.extracting());
        reg.offer_root_witness(&[1, 2]);
        assert_eq!(reg.root_witness_len(), None);
        assert_eq!(reg.take_root_witness(), None);
    }

    #[test]
    fn cas_min_behaviour() {
        let a = AtomicU32::new(10);
        assert_eq!(cas_min(&a, 7), Some(10));
        assert_eq!(cas_min(&a, 9), None);
        assert_eq!(a.load(Ordering::SeqCst), 7);
    }

    /// Hammer the registry from many threads: a two-component split where
    /// each component is "solved" by T workers branching and completing.
    #[test]
    fn concurrent_cascade_exactly_once() {
        use std::sync::atomic::AtomicUsize;
        for trial in 0..20 {
            let reg = Registry::new(false);
            let fired = AtomicUsize::new(0);
            let p = reg.new_parent(0, NONE);
            let c1 = reg.new_child(p, 8, 8);
            let c2 = reg.new_child(p, 8, 8);
            // pre-add live nodes for 8 simulated descendants per component
            reg.add_live(c1, 7);
            reg.add_live(c2, 7);
            {
                let mut sink = |_t: u32| {};
                reg.finish_scan(p, &mut sink);
            }
            std::thread::scope(|s| {
                for t in 0..16usize {
                    let reg = &reg;
                    let fired = &fired;
                    let ctx = if t % 2 == 0 { c1 } else { c2 };
                    s.spawn(move || {
                        let mut on_root = |_t: u32| {
                            fired.fetch_add(1, Ordering::SeqCst);
                        };
                        reg.report_solution(ctx, 4 + (t as u32 % 3), &mut on_root);
                        reg.complete_node(ctx, &mut on_root);
                    });
                }
            });
            assert_eq!(fired.load(Ordering::SeqCst), 1, "trial {trial}");
            reg.assert_drained();
            // final total = best(c1) + best(c2) = 4 + 4
            assert_eq!(reg.snapshot(p).0, 8, "trial {trial}");
        }
    }
}
