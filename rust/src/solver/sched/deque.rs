//! Chase–Lev work-stealing deque (Chase & Lev, SPAA'05; memory orderings
//! after Lê et al., PPoPP'13).
//!
//! One deque per worker. The **owner** pushes and pops at the *bottom*
//! (LIFO, so the search stays depth-first and cache-hot); **thieves**
//! steal from the *top* (FIFO, so they take the oldest — and on a
//! branch-and-reduce tree, largest — sub-trees). The owner's fast path is
//! a plain load + store; only the last-item race and steals use CAS.
//!
//! Reclamation is deliberately simple: buffers retired by [`grow`] are
//! kept alive until the deque drops (a thief may still hold a pointer to
//! an old buffer). Growth is doubling, so retired memory is at most the
//! size of the live buffer — the same bound the paper's preallocated
//! per-block stacks accept.
//!
//! [`grow`]: ChaseLev::grow

use std::cell::UnsafeCell;
use std::marker::PhantomData;
use std::mem::MaybeUninit;
use std::sync::atomic::{fence, AtomicIsize, AtomicPtr, Ordering};
use std::sync::Mutex;

/// Result of a steal attempt.
#[derive(Debug)]
pub enum Steal<T> {
    /// The deque looked empty.
    Empty,
    /// Lost a race with the owner or another thief; worth retrying.
    Retry,
    /// Stole an item.
    Taken(T),
}

struct Buffer<T> {
    cap: usize,
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
}

impl<T> Buffer<T> {
    fn alloc(cap: usize) -> *mut Buffer<T> {
        debug_assert!(cap.is_power_of_two());
        let slots: Box<[UnsafeCell<MaybeUninit<T>>]> =
            (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
        Box::into_raw(Box::new(Buffer { cap, slots }))
    }

    /// Pointer to the slot for logical index `i` (indices are monotonic;
    /// the buffer is circular).
    #[inline]
    unsafe fn at(&self, i: isize) -> *mut T {
        (*self.slots[(i as usize) & (self.cap - 1)].get()).as_mut_ptr()
    }
}

/// A single-owner, multi-thief lock-free deque.
///
/// Owner operations ([`push`], [`pop`]) are `unsafe`: they must only ever
/// be called from one thread at a time (the deque's owner). [`steal`],
/// [`len`] and [`is_empty`] are safe from any thread.
///
/// [`push`]: ChaseLev::push
/// [`pop`]: ChaseLev::pop
/// [`steal`]: ChaseLev::steal
/// [`len`]: ChaseLev::len
/// [`is_empty`]: ChaseLev::is_empty
pub struct ChaseLev<T> {
    /// Next index thieves take from (monotonically increasing).
    top: AtomicIsize,
    /// Next index the owner pushes to.
    bottom: AtomicIsize,
    /// Current circular buffer.
    buf: AtomicPtr<Buffer<T>>,
    /// Buffers replaced by `grow`, freed on drop (thieves may still read
    /// them; cold path, touched only while growing).
    retired: Mutex<Vec<*mut Buffer<T>>>,
    _marker: PhantomData<T>,
}

// SAFETY: items are Send; all shared mutation goes through atomics, and
// the owner-only operations are marked unsafe with a single-caller
// contract.
unsafe impl<T: Send> Send for ChaseLev<T> {}
unsafe impl<T: Send> Sync for ChaseLev<T> {}

impl<T> ChaseLev<T> {
    /// Create a deque with at least `capacity_hint` slots (rounded up to
    /// a power of two; grows automatically beyond it).
    pub fn with_capacity(capacity_hint: usize) -> ChaseLev<T> {
        let cap = capacity_hint.next_power_of_two().clamp(8, 1 << 20);
        ChaseLev {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            buf: AtomicPtr::new(Buffer::alloc(cap)),
            retired: Mutex::new(Vec::new()),
            _marker: PhantomData,
        }
    }

    /// Approximate number of queued items (exact for the owner when no
    /// steal is in flight).
    pub fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::SeqCst);
        let t = self.top.load(Ordering::SeqCst);
        (b - t).max(0) as usize
    }

    /// Approximate emptiness check (used by the termination sweep, which
    /// revalidates against the epoch counter).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Double the buffer, copying the live range `t..b`. Owner-only.
    #[cold]
    unsafe fn grow(&self, t: isize, b: isize) {
        let old = self.buf.load(Ordering::Relaxed);
        let new = Buffer::alloc((*old).cap * 2);
        for i in t..b {
            // Bitwise duplication: either this copy or the old slot is
            // consumed, never both (top only increases; slots below top
            // are never read again).
            std::ptr::write((*new).at(i), std::ptr::read((*old).at(i)));
        }
        self.buf.store(new, Ordering::Release);
        self.retired.lock().unwrap().push(old);
    }

    /// Push at the bottom.
    ///
    /// # Safety
    /// Must only be called by the deque's owner (one thread at a time,
    /// never concurrently with [`ChaseLev::pop`]).
    pub unsafe fn push(&self, item: T) {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut buf = self.buf.load(Ordering::Relaxed);
        if b - t >= (*buf).cap as isize {
            self.grow(t, b);
            buf = self.buf.load(Ordering::Relaxed);
        }
        std::ptr::write((*buf).at(b), item);
        // Publish the slot before the new bottom becomes visible.
        self.bottom.store(b + 1, Ordering::SeqCst);
    }

    /// Pop at the bottom (LIFO).
    ///
    /// # Safety
    /// Must only be called by the deque's owner.
    pub unsafe fn pop(&self) -> Option<T> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        let buf = self.buf.load(Ordering::Relaxed);
        self.bottom.store(b, Ordering::SeqCst);
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::SeqCst);
        if t > b {
            // Empty: restore bottom.
            self.bottom.store(b + 1, Ordering::SeqCst);
            return None;
        }
        if t == b {
            // Last item: race thieves for it via top.
            let won = self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok();
            self.bottom.store(b + 1, Ordering::SeqCst);
            if !won {
                return None; // a thief took it
            }
            return Some(std::ptr::read((*buf).at(b)));
        }
        // t < b: thieves can reach at most index b-1 (they observed
        // bottom == b at the earliest after our store above).
        Some(std::ptr::read((*buf).at(b)))
    }

    /// Steal from the top (FIFO). Safe from any thread.
    pub fn steal(&self) -> Steal<T> {
        let t = self.top.load(Ordering::SeqCst);
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::SeqCst);
        if t >= b {
            return Steal::Empty;
        }
        let buf = self.buf.load(Ordering::Acquire);
        // Speculative read into MaybeUninit: if another thief takes slot
        // `t` first, the owner may wrap a push onto it while we are still
        // copying, so the bytes can be torn — which is why they must not
        // materialize as a `T` yet. Ownership is decided by the CAS: on
        // failure the (possibly garbage) bytes are dropped as
        // MaybeUninit (a no-op); on success no overwrite can have
        // happened before our read (an overwrite requires `top > t`,
        // which would have failed the CAS), so the bytes are a valid T.
        let item = unsafe { std::ptr::read((*buf).at(t) as *const MaybeUninit<T>) };
        if self
            .top
            .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::SeqCst)
            .is_err()
        {
            return Steal::Retry;
        }
        Steal::Taken(unsafe { item.assume_init() })
    }
}

impl<T> Drop for ChaseLev<T> {
    fn drop(&mut self) {
        // Exclusive access: drop live items, then free all buffers.
        let t = *self.top.get_mut();
        let b = *self.bottom.get_mut();
        let buf = *self.buf.get_mut();
        unsafe {
            for i in t..b.max(t) {
                std::ptr::drop_in_place((*buf).at(i));
            }
            drop(Box::from_raw(buf));
        }
        for p in self.retired.get_mut().unwrap().drain(..) {
            unsafe { drop(Box::from_raw(p)) };
        }
    }
}

impl<T> std::fmt::Debug for ChaseLev<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ChaseLev").field("len", &self.len()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn owner_lifo() {
        let d = ChaseLev::with_capacity(4);
        unsafe {
            d.push(1);
            d.push(2);
            d.push(3);
            assert_eq!(d.pop(), Some(3));
            assert_eq!(d.pop(), Some(2));
            assert_eq!(d.pop(), Some(1));
            assert_eq!(d.pop(), None);
            assert_eq!(d.pop(), None);
        }
    }

    #[test]
    fn steal_fifo_from_top() {
        let d = ChaseLev::with_capacity(4);
        unsafe {
            d.push(10);
            d.push(20);
        }
        match d.steal() {
            Steal::Taken(x) => assert_eq!(x, 10),
            s => panic!("expected Taken(10), got {s:?}"),
        }
        unsafe { assert_eq!(d.pop(), Some(20)) };
        assert!(matches!(d.steal(), Steal::Empty));
    }

    #[test]
    fn growth_preserves_items() {
        let d = ChaseLev::with_capacity(8);
        unsafe {
            for i in 0..1000 {
                d.push(i);
            }
            for i in (0..1000).rev() {
                assert_eq!(d.pop(), Some(i));
            }
            assert_eq!(d.pop(), None);
        }
    }

    #[test]
    fn drop_frees_unpopped_boxes() {
        // Box items left in the deque (and in retired buffers after
        // growth) must be freed exactly once by Drop.
        let d = ChaseLev::with_capacity(8);
        unsafe {
            for i in 0..100 {
                d.push(Box::new(i));
            }
            assert_eq!(*d.pop().unwrap(), 99);
        }
        drop(d); // leak-checked under sanitizers / valgrind runs
    }

    #[test]
    fn concurrent_owner_and_thieves_conserve_items() {
        const ITEMS: usize = 20_000;
        const THIEVES: usize = 4;
        let d = Arc::new(ChaseLev::with_capacity(16));
        let taken = Arc::new(AtomicUsize::new(0));
        let sum = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..THIEVES {
                let d = Arc::clone(&d);
                let taken = Arc::clone(&taken);
                let sum = Arc::clone(&sum);
                s.spawn(move || loop {
                    match d.steal() {
                        Steal::Taken(x) => {
                            sum.fetch_add(x, Ordering::Relaxed);
                            taken.fetch_add(1, Ordering::Relaxed);
                        }
                        Steal::Retry => {}
                        Steal::Empty => {
                            if taken.load(Ordering::Relaxed) == ITEMS {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                });
            }
            // Owner interleaves pushes and pops.
            let d = Arc::clone(&d);
            let taken = Arc::clone(&taken);
            let sum = Arc::clone(&sum);
            s.spawn(move || {
                for i in 1..=ITEMS {
                    unsafe { d.push(i) };
                    if i % 3 == 0 {
                        if let Some(x) = unsafe { d.pop() } {
                            sum.fetch_add(x, Ordering::Relaxed);
                            taken.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                // Drain whatever the thieves left behind.
                while let Some(x) = unsafe { d.pop() } {
                    sum.fetch_add(x, Ordering::Relaxed);
                    taken.fetch_add(1, Ordering::Relaxed);
                }
            });
        });
        assert_eq!(taken.load(Ordering::Relaxed), ITEMS);
        assert_eq!(sum.load(Ordering::Relaxed), ITEMS * (ITEMS + 1) / 2);
        assert!(d.is_empty());
    }
}
