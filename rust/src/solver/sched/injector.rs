//! Global injector queue — the analog of the paper's broker queue entry
//! point: root search-tree nodes (and any out-of-band restarts, e.g. a
//! PVC re-launch) are injected here, and idle workers drain it before
//! resorting to stealing from each other.
//!
//! A Michael–Scott MPMC FIFO queue (PODC'96) with one deliberate
//! simplification: nodes are **never freed while the queue is live** —
//! popped nodes stay linked (the head just advances past them) and the
//! whole chain is reclaimed on drop. That removes the ABA/use-after-free
//! hazard that otherwise requires hazard pointers or epochs, at the cost
//! of retaining one small node per injected item. Injection is cold
//! (O(components at the root), not O(tree nodes)), so the retained memory
//! is negligible.

use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicPtr, Ordering};

struct Node<T> {
    item: MaybeUninit<T>,
    next: AtomicPtr<Node<T>>,
}

impl<T> Node<T> {
    fn alloc(item: MaybeUninit<T>) -> *mut Node<T> {
        Box::into_raw(Box::new(Node { item, next: AtomicPtr::new(std::ptr::null_mut()) }))
    }
}

/// Lock-free MPMC FIFO queue for root/restart work items.
pub struct Injector<T> {
    head: AtomicPtr<Node<T>>,
    tail: AtomicPtr<Node<T>>,
    /// The original dummy node: every node ever allocated is reachable
    /// from here via `next`, which is what drop walks.
    first: *mut Node<T>,
}

// SAFETY: all shared state is behind atomics; items are Send.
unsafe impl<T: Send> Send for Injector<T> {}
unsafe impl<T: Send> Sync for Injector<T> {}

impl<T> Injector<T> {
    /// Create an empty injector.
    pub fn new() -> Injector<T> {
        let dummy = Node::alloc(MaybeUninit::uninit());
        Injector { head: AtomicPtr::new(dummy), tail: AtomicPtr::new(dummy), first: dummy }
    }

    /// Enqueue an item (any thread).
    pub fn push(&self, item: T) {
        let n = Node::alloc(MaybeUninit::new(item));
        loop {
            let t = self.tail.load(Ordering::SeqCst);
            // SAFETY: nodes are never freed while the queue is live.
            let next = unsafe { (*t).next.load(Ordering::SeqCst) };
            if next.is_null() {
                if unsafe {
                    (*t).next
                        .compare_exchange(
                            std::ptr::null_mut(),
                            n,
                            Ordering::SeqCst,
                            Ordering::SeqCst,
                        )
                        .is_ok()
                } {
                    let _ = self.tail.compare_exchange(t, n, Ordering::SeqCst, Ordering::SeqCst);
                    return;
                }
            } else {
                // Help a lagging tail along.
                let _ = self.tail.compare_exchange(t, next, Ordering::SeqCst, Ordering::SeqCst);
            }
        }
    }

    /// Dequeue an item (any thread).
    pub fn pop(&self) -> Option<T> {
        loop {
            let h = self.head.load(Ordering::SeqCst);
            let t = self.tail.load(Ordering::SeqCst);
            // SAFETY: nodes are never freed while the queue is live.
            let next = unsafe { (*h).next.load(Ordering::SeqCst) };
            if next.is_null() {
                return None;
            }
            if h == t {
                // Tail lagging behind a completed push: help it.
                let _ = self.tail.compare_exchange(t, next, Ordering::SeqCst, Ordering::SeqCst);
                continue;
            }
            if self.head.compare_exchange(h, next, Ordering::SeqCst, Ordering::SeqCst).is_ok() {
                // Exactly one winner per head value (addresses are never
                // reused while live, so no ABA); the winner owns `next`'s
                // item and `next` becomes the new dummy.
                return Some(unsafe { (*next).item.as_ptr().read() });
            }
        }
    }

    /// True if no items are queued (validated by the termination sweep's
    /// epoch recheck, like the deque emptiness probes).
    pub fn is_empty(&self) -> bool {
        let h = self.head.load(Ordering::SeqCst);
        // SAFETY: nodes are never freed while the queue is live.
        unsafe { (*h).next.load(Ordering::SeqCst).is_null() }
    }
}

impl<T> Default for Injector<T> {
    fn default() -> Self {
        Injector::new()
    }
}

impl<T> Drop for Injector<T> {
    fn drop(&mut self) {
        // Exclusive access: walk the full allocation chain from the
        // original dummy. Nodes up to and including the current head have
        // had their items consumed (or never held one); nodes after it
        // hold live items that must be dropped.
        let head = *self.head.get_mut();
        let mut cur = self.first;
        let mut live = false;
        while !cur.is_null() {
            unsafe {
                let next = (*cur).next.load(Ordering::Relaxed);
                if live {
                    std::ptr::drop_in_place((*cur).item.as_mut_ptr());
                }
                if cur == head {
                    live = true;
                }
                drop(Box::from_raw(cur));
                cur = next;
            }
        }
    }
}

impl<T> std::fmt::Debug for Injector<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Injector").field("is_empty", &self.is_empty()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = Injector::new();
        q.push(1);
        q.push(2);
        q.push(3);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_push_pop() {
        let q = Injector::new();
        q.push(10);
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), None);
        q.push(20);
        q.push(30);
        assert_eq!(q.pop(), Some(20));
        q.push(40);
        assert_eq!(q.pop(), Some(30));
        assert_eq!(q.pop(), Some(40));
        assert!(q.is_empty());
    }

    #[test]
    fn drop_frees_live_items() {
        let q = Injector::new();
        for i in 0..50 {
            q.push(Box::new(i));
        }
        assert_eq!(*q.pop().unwrap(), 0);
        drop(q); // 49 live boxes reclaimed by Drop
    }

    #[test]
    fn mpmc_conserves_items() {
        const PRODUCERS: usize = 4;
        const CONSUMERS: usize = 4;
        const PER: usize = 5_000;
        let q = Arc::new(Injector::new());
        let got = Arc::new(AtomicUsize::new(0));
        let sum = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for p in 0..PRODUCERS {
                let q = Arc::clone(&q);
                s.spawn(move || {
                    for i in 0..PER {
                        q.push(p * PER + i + 1);
                    }
                });
            }
            for _ in 0..CONSUMERS {
                let q = Arc::clone(&q);
                let got = Arc::clone(&got);
                let sum = Arc::clone(&sum);
                s.spawn(move || loop {
                    match q.pop() {
                        Some(x) => {
                            sum.fetch_add(x, Ordering::Relaxed);
                            got.fetch_add(1, Ordering::Relaxed);
                        }
                        None => {
                            if got.load(Ordering::Relaxed) == PRODUCERS * PER {
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                });
            }
        });
        assert_eq!(got.load(Ordering::Relaxed), PRODUCERS * PER);
        let n = PRODUCERS * PER;
        assert_eq!(sum.load(Ordering::Relaxed), n * (n + 1) / 2);
        assert!(q.is_empty());
    }
}
