//! Pluggable scheduling runtimes for the branch-and-reduce engine.
//!
//! The paper's GPU maps search onto thread blocks with private stacks
//! plus a shared broker worklist (§II-C). This module abstracts that
//! machinery behind the [`Scheduler`] trait so the engine is generic over
//! *how* search-tree nodes move between workers, with two
//! implementations:
//!
//! * [`WorkStealScheduler`] — a lock-free work-stealing runtime: one
//!   Chase–Lev deque per worker (the "private stack", except its top is
//!   stealable), a global [`injector::Injector`] for root nodes and
//!   restarts, and an epoch-validated idle-count termination detector.
//!   GPU analogy: thread block → worker/deque owner, broker queue →
//!   injector + the stealable tops of all deques.
//! * [`ShardedScheduler`] — the previous runtime, kept as the comparison
//!   baseline: worker-private `Vec` stacks that offload to mutex-sharded
//!   FIFO queues (`solver::worklist`) when the shared queue runs hungry,
//!   with an outstanding-node counter for termination.
//!
//! Both are selectable from `SolverConfig`/`EngineCfg`
//! ([`SchedulerKind`]), which keeps the paper's three variants —
//! proposed / prior-work / no-load-balance — expressible as a scheduler
//! plus configuration flags, and lets the benches race the runtimes
//! head-to-head on identical searches.
//!
//! ## Ownership protocol
//!
//! A scheduler is driven through per-worker [`WorkerHandle`]s. Exactly
//! one live handle may exist per worker index (enforced at runtime by the
//! work-stealing implementation); the handle's owner calls
//! [`WorkerHandle::push`]/[`WorkerHandle::pop`] from its own thread only.
//! [`Scheduler::inject`] is safe from any thread at any time;
//! [`Scheduler::seed`] is a single-threaded setup-phase operation used by
//! the static (no-load-balance) seeding path.
//!
//! Items move **by value** through every queue in both runtimes: a
//! stolen search node carries its entire payload — degree array, view
//! `Arc`, and (under witness extraction) its choice log — so the thief
//! owns the node's state outright and completes it without ever touching
//! the victim's memory. The delta node representation keeps this
//! contract without the copies: a delta child moves by value too, but
//! its payload is an `Arc`-pinned *immutable* frame chain, and
//! [`WorkerHandle::pop_traced`] reports where an item came from
//! ([`PopSource`]) so the engine materializes stolen deltas into owned
//! payloads at steal time while local pops take the in-place undo path.
//!
//! ## Termination
//!
//! [`WorkerHandle::pop`] returning `None` does **not** mean the search is
//! over — another worker may still be expanding nodes. The worker then
//! calls [`WorkerHandle::idle_step`], which performs one bounded
//! wait/recheck and reports [`IdleOutcome::Finished`] only once global
//! quiescence is certain (all workers idle, every queue empty, and no
//! state transition observed during the sweep — see
//! `WorkStealScheduler`'s epoch protocol).
//!
//! ## Resident pools
//!
//! Both runtimes can also be built *resident*
//! (`new_resident`) for the [`crate::solver::service`] layer: proven
//! quiescence then **parks** the workers on a condvar instead of
//! finishing them, a later [`Scheduler::inject`] (the next job — a new
//! work epoch) wakes the pool, and `idle_step` reports `Finished` only
//! after `request_shutdown` once every queue has drained. Handles also
//! poll the shared entry queue every 64th pop so a newly injected job is
//! picked up even while deep local queues keep every worker busy.

pub mod deque;
pub mod injector;
mod sharded;
mod work_steal;

pub use sharded::ShardedScheduler;
pub use work_steal::WorkStealScheduler;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Park/unpark state for **resident** pools (see
/// [`crate::solver::service`]): a one-shot run ends with scope-join
/// termination, but a resident pool's workers must outlive any single
/// job — when the queues drain they *park* on a condvar instead of
/// exiting, and a later `inject` (the next job's root — a new "epoch" of
/// work) wakes them. Shutdown is a request flag: workers drain every
/// queue first and only then exit, so jobs submitted before shutdown
/// still complete.
pub(crate) struct ResidentCtl {
    lock: Mutex<()>,
    cv: Condvar,
    /// Workers currently blocked in [`ResidentCtl::park`].
    parked: AtomicUsize,
    /// Cumulative park events over the pool's lifetime (service QoS
    /// telemetry: an idle pool parks, a saturated one never does).
    parks: AtomicU64,
    shutdown: AtomicBool,
}

impl ResidentCtl {
    pub(crate) fn new() -> ResidentCtl {
        ResidentCtl {
            lock: Mutex::new(()),
            cv: Condvar::new(),
            parked: AtomicUsize::new(0),
            parks: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
        }
    }

    /// Total park events so far.
    pub(crate) fn total_parks(&self) -> u64 {
        self.parks.load(Ordering::Relaxed)
    }

    pub(crate) fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Ask the pool to drain and exit; wakes every parked worker.
    pub(crate) fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _g = self.lock.lock().unwrap();
        self.cv.notify_all();
    }

    /// Block until notified or `timeout`. `work_visible` is re-checked
    /// after registering as parked (both under the lock and with SeqCst
    /// ordering against the registration), which closes the
    /// check-then-park race with [`ResidentCtl::unpark_one_if_parked`]:
    /// a producer that misses our registration published its work before
    /// our re-check, and a producer that sees it will notify.
    pub(crate) fn park(&self, timeout: Duration, work_visible: impl Fn() -> bool) {
        let guard = self.lock.lock().unwrap();
        self.parked.fetch_add(1, Ordering::SeqCst);
        if work_visible() || self.shutdown.load(Ordering::SeqCst) {
            self.parked.fetch_sub(1, Ordering::SeqCst);
            return;
        }
        self.parks.fetch_add(1, Ordering::Relaxed);
        let _ = self.cv.wait_timeout(guard, timeout);
        self.parked.fetch_sub(1, Ordering::SeqCst);
    }

    /// Wake every parked worker (new-job injection).
    pub(crate) fn unpark_all(&self) {
        let _g = self.lock.lock().unwrap();
        self.cv.notify_all();
    }

    /// Wake one parked worker if any (stealable/shared work appeared
    /// while the pool was partly asleep). The unlocked fast-path load
    /// keeps this off the busy path when nobody is parked.
    pub(crate) fn unpark_one_if_parked(&self) {
        if self.parked.load(Ordering::SeqCst) > 0 {
            let _g = self.lock.lock().unwrap();
            self.cv.notify_one();
        }
    }
}

/// Shared lane-awareness hint between a resident scheduler and the
/// service's admission layer (the QoS "latency lane").
///
/// `pending` counts latency-lane work items currently sitting in the
/// scheduler's *shared* entry queue (job setups and latency-job roots,
/// marked by the service at injection time and cleared when the item is
/// popped). While it is non-zero, every worker's [`WorkerHandle::pop`]
/// polls the shared queue on **every** pop instead of every 64th — the
/// fairness cadence that is fine for throughput jobs would otherwise add
/// up to 63 node-expansions of latency before a small job's setup is
/// even looked at. The busy-path cost when no latency work is queued is
/// one relaxed load per pop.
#[derive(Default)]
pub struct LaneHint {
    /// Latency-lane items currently in the shared entry queue.
    pub(crate) pending: AtomicU64,
}

impl LaneHint {
    /// True when a latency-lane item is waiting in the shared queue.
    #[inline]
    pub(crate) fn urgent(&self) -> bool {
        self.pending.load(Ordering::Relaxed) > 0
    }
}

/// Which scheduling runtime the engine should use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedulerKind {
    /// Lock-free Chase–Lev work stealing (the default).
    #[default]
    WorkSteal,
    /// Mutex-sharded worklist with private stacks (legacy baseline).
    Sharded,
}

impl SchedulerKind {
    /// Short display name used in harness tables and benches.
    pub fn name(self) -> &'static str {
        match self {
            SchedulerKind::WorkSteal => "steal",
            SchedulerKind::Sharded => "sharded",
        }
    }

    /// Parse a name as accepted by `--sched` / `CAVC_SCHED`.
    pub fn parse(s: &str) -> Option<SchedulerKind> {
        match s {
            "steal" | "worksteal" | "work-steal" | "chase-lev" => Some(SchedulerKind::WorkSteal),
            "sharded" | "worklist" | "mutex" => Some(SchedulerKind::Sharded),
            _ => None,
        }
    }
}

/// Per-worker scheduling counters (Figure-4 instrumentation: the queue
/// traffic behind the `stack/worklist` activity bar).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerCounters {
    /// Children this worker enqueued (any destination).
    pub pushes: u64,
    /// Of `pushes`, how many landed somewhere other workers can take
    /// from (shared shard for the sharded runtime; every deque push for
    /// the work-stealing runtime, whose whole deque is stealable).
    pub offloaded: u64,
    /// Nodes taken from the worker's own stack/deque.
    pub pops: u64,
    /// Nodes taken from the shared entry queue (injector / home shard).
    pub shared_pops: u64,
    /// Nodes taken from *another* worker.
    pub steals: u64,
    /// Steal attempts that lost a race and had to retry.
    pub steal_retries: u64,
    /// Deepest local queue observed (sampled every 64th push on the
    /// work-stealing runtime to keep the probe off the hot path; exact
    /// for the sharded runtime's private stacks).
    pub max_depth: usize,
}

impl WorkerCounters {
    /// Elementwise accumulate (max for depth).
    pub fn accumulate(&mut self, other: &WorkerCounters) {
        self.pushes += other.pushes;
        self.offloaded += other.offloaded;
        self.pops += other.pops;
        self.shared_pops += other.shared_pops;
        self.steals += other.steals;
        self.steal_retries += other.steal_retries;
        self.max_depth = self.max_depth.max(other.max_depth);
    }

    /// Total nodes this worker acquired from any source.
    pub fn acquired(&self) -> u64 {
        self.pops + self.shared_pops + self.steals
    }

    /// Steal rate of this worker's acquisitions, in parts per million
    /// (see [`steal_rate_ppm`]).
    pub fn steal_rate_ppm(&self) -> u64 {
        steal_rate_ppm(self.steals, self.acquired())
    }
}

/// Pool-wide steal rate: stolen acquisitions per million acquired
/// nodes. This is the self-tuning controller's scheduler-side input —
/// a low rate means the undo fast path dominates and delta chains may
/// lengthen; a high rate means thieves pay materialization replay and
/// chains should shorten. 0 when nothing was acquired.
#[inline]
pub fn steal_rate_ppm(steals: u64, acquired: u64) -> u64 {
    if acquired == 0 {
        0
    } else {
        steals.saturating_mul(1_000_000) / acquired
    }
}

/// Outcome of one bounded idle step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IdleOutcome {
    /// Global quiescence: the worker can exit its loop.
    Finished,
    /// Work may still appear; poll again.
    Retry,
}

/// Where an acquired work item came from — the steal-time
/// materialization hook on the scheduler/engine boundary. Under the
/// delta node representation a *stolen* node cannot share the victim's
/// live frame, so the engine uses this provenance to materialize stolen
/// (and shared-queue) delta nodes into owned payloads at acquisition
/// time, while locally popped nodes stay eligible for the in-place
/// undo fast path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PopSource {
    /// The worker's own stack/deque (LIFO fast path).
    Local,
    /// The shared entry queue (injector / home shard).
    Shared,
    /// Another worker's queue — a cross-worker steal.
    Stolen,
}

/// One worker's view of a scheduler. See the module docs for the
/// ownership protocol.
pub trait WorkerHandle<N> {
    /// Enqueue a child node produced by this worker.
    fn push(&mut self, item: N);
    /// Acquire the next node together with its provenance: own queue
    /// first, then the shared injector, then (if enabled) stealing from
    /// other workers.
    fn pop_traced(&mut self) -> Option<(N, PopSource)>;
    /// Acquire the next node, discarding provenance.
    fn pop(&mut self) -> Option<N> {
        self.pop_traced().map(|(n, _)| n)
    }
    /// Called once after each acquired node is fully processed.
    fn on_node_done(&mut self);
    /// One bounded wait/recheck after `pop` returned `None`.
    fn idle_step(&mut self) -> IdleOutcome;
    /// Counters accumulated by this worker so far.
    fn counters(&self) -> WorkerCounters;
}

/// A scheduling runtime for `N`-typed work items.
pub trait Scheduler<N: Send>: Sync {
    /// The per-worker handle type.
    type Handle<'a>: WorkerHandle<N>
    where
        Self: 'a,
        N: 'a;

    /// Number of workers this scheduler was built for.
    fn workers(&self) -> usize;

    /// Enqueue a root/restart item into the global entry queue. Safe
    /// from any thread **while the pool is active** — setup phase or
    /// while at least one worker is still processing. Items injected
    /// after the termination detector has latched quiescence are not
    /// picked up (debug builds assert against it).
    fn inject(&self, item: N);

    /// Statically place an item on `worker`'s local queue. Setup-phase
    /// only: must happen single-threaded, before worker handles exist.
    fn seed(&self, worker: usize, item: N);

    /// Create the handle for `worker`. At most one live handle per
    /// worker index.
    fn handle(&self, worker: usize) -> Self::Handle<'_>;
}
