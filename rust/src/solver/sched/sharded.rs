//! The mutex-sharded baseline runtime, adapted to the [`Scheduler`]
//! trait.
//!
//! This is the engine's previous scheduling machinery, preserved
//! verbatim so benches can race it against the lock-free work stealer on
//! identical searches: worker-private `Vec` stacks (zero-cost LIFO),
//! offload to a [`Worklist`] shard when the shared queue is *hungry*
//! (holds fewer than `2 × workers` items), and an outstanding-node
//! counter for termination — two sequentially-consistent RMWs per node,
//! which is exactly the hot-path cost the work stealer eliminates.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::solver::worklist::Worklist;

use super::{IdleOutcome, LaneHint, PopSource, ResidentCtl, Scheduler, WorkerCounters, WorkerHandle};

const SPINS_BEFORE_SLEEP: u32 = 64;
const IDLE_SLEEP: std::time::Duration = std::time::Duration::from_micros(50);
const PARK_BASE: std::time::Duration = std::time::Duration::from_micros(100);
const PARK_MAX_EXP: u32 = 8;

/// Sharded-worklist scheduler (legacy baseline; see module docs).
pub struct ShardedScheduler<N: Send> {
    worklist: Worklist<N>,
    /// Nodes acquired but not yet fully processed, plus nodes queued
    /// anywhere. Zero ⇒ the search is drained.
    pending: AtomicU64,
    /// Offload threshold: the shared queue is hungry below this length.
    low_water: usize,
    load_balance: bool,
    /// Statically-assigned nodes, taken over by the worker's handle.
    seeds: Vec<Mutex<Vec<N>>>,
    workers: usize,
    /// Initial private-stack capacity (the occupancy model's stack-depth
    /// bound — induction-aware, so shrinking payloads buy deeper stacks).
    queue_capacity: usize,
    /// Present in resident pools: park/unpark + shutdown protocol.
    resident: Option<ResidentCtl>,
    /// Latency-lane hint shared with the service's admission layer (see
    /// [`LaneHint`]): urgent shared-queue work makes the fairness poll
    /// fire on every pop instead of every 64th.
    urgent: Arc<LaneHint>,
}

impl<N: Send> ShardedScheduler<N> {
    /// Build a scheduler with one shard and one seed slot per worker.
    /// `queue_capacity` presizes each worker's private stack (stacks
    /// still grow beyond it as needed).
    pub fn new(workers: usize, load_balance: bool, queue_capacity: usize) -> ShardedScheduler<N> {
        let workers = workers.max(1);
        ShardedScheduler {
            worklist: Worklist::new(workers),
            pending: AtomicU64::new(0),
            low_water: 2 * workers,
            load_balance,
            seeds: (0..workers).map(|_| Mutex::new(Vec::new())).collect(),
            workers,
            queue_capacity,
            resident: None,
            urgent: Arc::new(LaneHint::default()),
        }
    }

    /// Build a **resident** scheduler: a drained pool (`pending == 0`)
    /// parks its workers instead of terminating them; a later `inject`
    /// wakes the pool; termination requires an explicit
    /// [`ShardedScheduler::request_shutdown`]. Load balancing is always
    /// on — a resident pool exists to share its workers across jobs.
    pub fn new_resident(workers: usize, queue_capacity: usize) -> ShardedScheduler<N> {
        ShardedScheduler {
            resident: Some(ResidentCtl::new()),
            ..ShardedScheduler::new(workers, true, queue_capacity)
        }
    }

    /// Ask a resident pool to drain its queues and exit its workers.
    /// No-op on non-resident schedulers.
    pub fn request_shutdown(&self) {
        if let Some(r) = &self.resident {
            r.request_shutdown();
        }
    }

    /// Cumulative worker park events (resident pools; 0 otherwise).
    pub fn parks(&self) -> u64 {
        self.resident.as_ref().map(|r| r.total_parks()).unwrap_or(0)
    }

    /// Approximate queued-node backlog (shared worklist length). Racy
    /// snapshot; used by the service's `PoolStats` and memory watchdog.
    pub fn backlog(&self) -> usize {
        self.worklist.len()
    }

    /// The shared latency-lane hint (service admission marks urgent
    /// injections through it; see [`LaneHint`]).
    pub(crate) fn lane_hint(&self) -> Arc<LaneHint> {
        Arc::clone(&self.urgent)
    }
}

impl<N: Send> Scheduler<N> for ShardedScheduler<N> {
    type Handle<'a>
        = ShardedHandle<'a, N>
    where
        Self: 'a,
        N: 'a;

    fn workers(&self) -> usize {
        self.workers
    }

    fn inject(&self, item: N) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.worklist.push(0, item);
        if let Some(r) = &self.resident {
            r.unpark_all();
        }
    }

    fn seed(&self, worker: usize, item: N) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.seeds[worker % self.workers].lock().unwrap().push(item);
    }

    fn handle(&self, worker: usize) -> ShardedHandle<'_, N> {
        assert!(worker < self.workers, "worker {worker} out of range");
        let mut stack = std::mem::take(&mut *self.seeds[worker].lock().unwrap());
        if stack.capacity() < self.queue_capacity {
            stack.reserve(self.queue_capacity - stack.len());
        }
        ShardedHandle {
            s: self,
            id: worker,
            stack,
            spins: 0,
            polls: 0,
            c: WorkerCounters::default(),
        }
    }
}

/// Per-worker handle of the sharded scheduler.
pub struct ShardedHandle<'a, N: Send> {
    s: &'a ShardedScheduler<N>,
    id: usize,
    /// The worker-private LIFO stack (the GPU "private stack").
    stack: Vec<N>,
    spins: u32,
    /// Pop counter driving the periodic shared-queue fairness poll.
    polls: u64,
    c: WorkerCounters,
}

impl<N: Send> WorkerHandle<N> for ShardedHandle<'_, N> {
    fn push(&mut self, item: N) {
        self.s.pending.fetch_add(1, Ordering::SeqCst);
        self.c.pushes += 1;
        if self.s.load_balance && self.s.worklist.is_hungry(self.s.low_water) {
            self.s.worklist.push(self.id, item);
            self.c.offloaded += 1;
            if let Some(r) = &self.s.resident {
                // The offloaded node is visible to every worker: hand it
                // to a parked one.
                r.unpark_one_if_parked();
            }
        } else {
            self.stack.push(item);
            if self.stack.len() > self.c.max_depth {
                self.c.max_depth = self.stack.len();
            }
        }
    }

    fn pop_traced(&mut self) -> Option<(N, PopSource)> {
        // Fairness: take from the shared worklist periodically even
        // while the private stack holds work, so injected items (new
        // jobs on a resident pool) are never starved behind it. While
        // latency-lane work is pending the poll fires on every pop so
        // small jobs preempt the 64-pop cadence.
        self.polls = self.polls.wrapping_add(1);
        if self.s.load_balance && (self.polls & 63 == 0 || self.s.urgent.urgent()) {
            if let Some((item, stolen)) = self.s.worklist.pop_traced(self.id) {
                let src = if stolen {
                    self.c.steals += 1;
                    PopSource::Stolen
                } else {
                    self.c.shared_pops += 1;
                    PopSource::Shared
                };
                self.spins = 0;
                return Some((item, src));
            }
        }
        if let Some(item) = self.stack.pop() {
            self.c.pops += 1;
            self.spins = 0;
            return Some((item, PopSource::Local));
        }
        if self.s.load_balance {
            if let Some((item, stolen)) = self.s.worklist.pop_traced(self.id) {
                let src = if stolen {
                    self.c.steals += 1;
                    PopSource::Stolen
                } else {
                    self.c.shared_pops += 1;
                    PopSource::Shared
                };
                self.spins = 0;
                return Some((item, src));
            }
        }
        None
    }

    fn on_node_done(&mut self) {
        let prev = self.s.pending.fetch_sub(1, Ordering::SeqCst);
        debug_assert!(prev >= 1, "pending underflow");
    }

    fn idle_step(&mut self) -> IdleOutcome {
        let drained = self.s.pending.load(Ordering::SeqCst) == 0;
        match &self.s.resident {
            None => {
                if drained {
                    return IdleOutcome::Finished;
                }
            }
            Some(r) => {
                // Resident pool: a drained pool parks until the next job
                // is injected; only shutdown + drained terminates.
                if drained && r.shutdown_requested() {
                    return IdleOutcome::Finished;
                }
                self.spins += 1;
                if self.spins > SPINS_BEFORE_SLEEP {
                    let exp = (self.spins - SPINS_BEFORE_SLEEP).min(PARK_MAX_EXP);
                    let s = self.s;
                    r.park(PARK_BASE * (1u32 << exp), || !s.worklist.is_empty());
                } else {
                    std::thread::yield_now();
                }
                return IdleOutcome::Retry;
            }
        }
        self.spins += 1;
        if self.spins > SPINS_BEFORE_SLEEP {
            std::thread::sleep(IDLE_SLEEP);
        } else {
            std::thread::yield_now();
        }
        IdleOutcome::Retry
    }

    fn counters(&self) -> WorkerCounters {
        self.c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn drains_branching_workload() {
        for workers in [1usize, 4] {
            let s: ShardedScheduler<u32> = ShardedScheduler::new(workers, true, 64);
            s.inject(10);
            let leaves = AtomicU64::new(0);
            std::thread::scope(|scope| {
                for w in 0..workers {
                    let s = &s;
                    let leaves = &leaves;
                    scope.spawn(move || {
                        let mut h = s.handle(w);
                        loop {
                            match h.pop() {
                                Some(0) => {
                                    leaves.fetch_add(1, Ordering::Relaxed);
                                    h.on_node_done();
                                }
                                Some(x) => {
                                    h.push(x - 1);
                                    h.push(x - 1);
                                    h.on_node_done();
                                }
                                None => {
                                    if h.idle_step() == IdleOutcome::Finished {
                                        break;
                                    }
                                }
                            }
                        }
                    });
                }
            });
            assert_eq!(leaves.load(Ordering::Relaxed), 1 << 10, "workers={workers}");
        }
    }

    #[test]
    fn seeds_partition_statically() {
        let s: ShardedScheduler<u32> = ShardedScheduler::new(2, false, 64);
        s.seed(0, 1);
        s.seed(0, 2);
        s.seed(1, 3);
        let mut h0 = s.handle(0);
        let mut h1 = s.handle(1);
        assert_eq!(h0.pop(), Some(2)); // private stack is LIFO
        assert_eq!(h0.pop(), Some(1));
        h0.on_node_done();
        h0.on_node_done();
        assert_eq!(h0.pop(), None); // no balancing: cannot see worker 1's seed
        assert_eq!(h1.pop(), Some(3));
        h1.on_node_done();
        assert_eq!(h0.idle_step(), IdleOutcome::Finished);
        assert_eq!(h1.idle_step(), IdleOutcome::Finished);
    }
}
