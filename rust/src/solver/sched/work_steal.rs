//! The lock-free work-stealing runtime.
//!
//! Layout: one [`ChaseLev`] deque per worker plus one global
//! [`Injector`]. A worker's `push`/`pop` touch only its own deque bottom
//! (no locks, no contention on the fast path); when it runs dry it drains
//! the injector, then sweeps the other deques' tops, stealing the oldest
//! (= shallowest, largest) sub-trees first — the same "offload big
//! sub-trees" policy the paper's broker queue implements with explicit
//! donation, inverted into thief-pull form so the busy path pays nothing.
//!
//! ## Termination: epoch-validated idle counting
//!
//! The sharded runtime tracks an outstanding-node counter with two
//! sequentially-consistent RMWs per node. Here termination costs nothing
//! on the hot path: a worker registers in an idle count only when it has
//! no node in hand and found nothing to take, and *deregisters before
//! every acquisition attempt*. Both transitions bump an epoch counter.
//! A worker that observes `idle == workers` runs a verification sweep —
//! all deques empty, injector empty, epoch unchanged across the whole
//! sweep, idle still full — and only then declares global quiescence.
//!
//! Why this is safe: work moves only via (a) an owner push, (b) an
//! injector push, or (c) an acquisition by some worker. (a) and (c) are
//! performed by workers that are *not* registered idle at that moment
//! (they deregistered first, bumping the epoch), and (b) bumps the epoch
//! directly. So if the epoch is identical at both ends of a sweep that
//! saw every queue empty and every worker idle, no item existed or moved
//! anywhere during the sweep — quiescence. The `done` flag then latches
//! the decision for the remaining workers.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

use std::sync::Arc;

use super::deque::{ChaseLev, Steal};
use super::injector::Injector;
use super::{IdleOutcome, LaneHint, PopSource, ResidentCtl, Scheduler, WorkerCounters, WorkerHandle};

/// Spins before an idle worker starts sleeping between rechecks.
const SPINS_BEFORE_SLEEP: u32 = 64;
/// Sleep quantum once spinning has not produced work.
const IDLE_SLEEP: std::time::Duration = std::time::Duration::from_micros(50);
/// First park timeout of a resident worker (doubles up to the cap; the
/// timeout is only a backstop — injects and visible-work pushes notify).
const PARK_BASE: std::time::Duration = std::time::Duration::from_micros(100);
/// Cap on the park-timeout exponent (100µs << 8 ≈ 25.6ms): an idle
/// resident pool costs a handful of wakeups per second per worker.
const PARK_MAX_EXP: u32 = 8;

/// Lock-free work-stealing scheduler (see module docs).
pub struct WorkStealScheduler<N: Send> {
    deques: Vec<ChaseLev<N>>,
    injector: Injector<N>,
    /// Guards the one-live-handle-per-worker protocol.
    taken: Vec<AtomicBool>,
    /// Stealing enabled (false reproduces the paper's no-load-balance
    /// variant: private deques + static seeds only).
    steal: bool,
    /// Workers currently registered idle.
    idle: AtomicUsize,
    /// Bumped on every idle transition and injector push; validates
    /// termination sweeps.
    epoch: AtomicU64,
    /// Latched once quiescence has been proven.
    done: AtomicBool,
    /// Present in resident pools: park/unpark + shutdown protocol
    /// (multi-job epochs instead of scope-join termination).
    resident: Option<ResidentCtl>,
    /// Latency-lane hint shared with the service's admission layer: when
    /// it reports urgent shared-queue work, the fairness poll fires on
    /// every pop instead of every 64th.
    urgent: Arc<LaneHint>,
}

impl<N: Send> WorkStealScheduler<N> {
    /// Build a scheduler for `workers` deque owners. `capacity_hint`
    /// pre-sizes each deque (the occupancy model's stack-depth bound);
    /// deques still grow beyond it.
    pub fn new(workers: usize, steal: bool, capacity_hint: usize) -> WorkStealScheduler<N> {
        let workers = workers.max(1);
        WorkStealScheduler {
            deques: (0..workers).map(|_| ChaseLev::with_capacity(capacity_hint)).collect(),
            injector: Injector::new(),
            taken: (0..workers).map(|_| AtomicBool::new(false)).collect(),
            steal,
            idle: AtomicUsize::new(0),
            epoch: AtomicU64::new(0),
            done: AtomicBool::new(false),
            resident: None,
            urgent: Arc::new(LaneHint::default()),
        }
    }

    /// Build a **resident** scheduler: quiescence parks the workers
    /// instead of terminating them, a later `inject` (the next job)
    /// wakes the pool, and termination happens only after
    /// [`WorkStealScheduler::request_shutdown`] once every queue has
    /// drained. Stealing is always on — a resident pool exists to share
    /// its workers across jobs.
    pub fn new_resident(workers: usize, capacity_hint: usize) -> WorkStealScheduler<N> {
        WorkStealScheduler {
            resident: Some(ResidentCtl::new()),
            ..WorkStealScheduler::new(workers, true, capacity_hint)
        }
    }

    /// Ask a resident pool to drain its queues and exit its workers.
    /// No-op on non-resident schedulers (scope-join terminates those).
    pub fn request_shutdown(&self) {
        if let Some(r) = &self.resident {
            r.request_shutdown();
        }
    }

    /// Cumulative worker park events (resident pools; 0 otherwise).
    pub fn parks(&self) -> u64 {
        self.resident.as_ref().map(|r| r.total_parks()).unwrap_or(0)
    }

    /// Approximate queued-node backlog: the sum of per-worker deque
    /// lengths. Racy by construction (each length is a snapshot), but
    /// good enough for the service's memory watchdog and `PoolStats` —
    /// it converges to the true value on a quiescent pool.
    pub fn backlog(&self) -> usize {
        self.deques.iter().map(|d| d.len()).sum()
    }

    /// The shared latency-lane hint (service admission marks urgent
    /// injections through it; see [`LaneHint`]).
    pub(crate) fn lane_hint(&self) -> Arc<LaneHint> {
        Arc::clone(&self.urgent)
    }

    /// Termination verification sweep; caller observed `idle == workers`.
    fn try_terminate(&self) -> bool {
        let e0 = self.epoch.load(Ordering::SeqCst);
        if self.idle.load(Ordering::SeqCst) != self.deques.len() {
            return false;
        }
        if !self.injector.is_empty() {
            return false;
        }
        if self.deques.iter().any(|d| !d.is_empty()) {
            return false;
        }
        if self.epoch.load(Ordering::SeqCst) != e0
            || self.idle.load(Ordering::SeqCst) != self.deques.len()
        {
            return false;
        }
        self.done.store(true, Ordering::SeqCst);
        true
    }
}

impl<N: Send> Scheduler<N> for WorkStealScheduler<N> {
    type Handle<'a>
        = StealHandle<'a, N>
    where
        Self: 'a,
        N: 'a;

    fn workers(&self) -> usize {
        self.deques.len()
    }

    fn inject(&self, item: N) {
        // Injection must happen before quiescence is declared: once every
        // worker has exited there is no one left to run the item (see the
        // trait docs). The epoch bump precedes the push so a termination
        // sweep whose e0 predates this call re-reads the injector.
        debug_assert!(
            !self.done.load(Ordering::SeqCst),
            "inject() after the pool reached quiescence"
        );
        self.epoch.fetch_add(1, Ordering::SeqCst);
        self.injector.push(item);
        if let Some(r) = &self.resident {
            // New job epoch: wake the whole pool, not just one worker —
            // the injected root usually fans out immediately.
            r.unpark_all();
        }
    }

    fn seed(&self, worker: usize, item: N) {
        let w = worker % self.deques.len();
        assert!(
            !self.taken[w].load(Ordering::SeqCst),
            "seed() must run before worker handles exist"
        );
        // SAFETY: setup phase — no handle exists for `w` (asserted), so
        // this thread is the sole owner of the deque right now.
        unsafe { self.deques[w].push(item) };
    }

    fn handle(&self, worker: usize) -> StealHandle<'_, N> {
        assert!(worker < self.deques.len(), "worker {worker} out of range");
        assert!(
            !self.taken[worker].swap(true, Ordering::SeqCst),
            "worker {worker} already has a live handle"
        );
        StealHandle {
            s: self,
            id: worker,
            idle_registered: false,
            spins: 0,
            polls: 0,
            c: WorkerCounters::default(),
        }
    }
}

/// Per-worker handle of the work-stealing scheduler.
pub struct StealHandle<'a, N: Send> {
    s: &'a WorkStealScheduler<N>,
    id: usize,
    idle_registered: bool,
    spins: u32,
    /// Pop counter driving the periodic injector fairness poll.
    polls: u64,
    c: WorkerCounters,
}

impl<N: Send> StealHandle<'_, N> {
    fn enter_idle(&mut self) {
        debug_assert!(!self.idle_registered);
        self.s.idle.fetch_add(1, Ordering::SeqCst);
        self.s.epoch.fetch_add(1, Ordering::SeqCst);
        self.idle_registered = true;
    }

    fn exit_idle(&mut self) {
        debug_assert!(self.idle_registered);
        self.s.epoch.fetch_add(1, Ordering::SeqCst);
        self.s.idle.fetch_sub(1, Ordering::SeqCst);
        self.idle_registered = false;
    }

    /// Sweep the other deques once, oldest-first per victim.
    fn try_steal(&mut self) -> Option<N> {
        let n = self.s.deques.len();
        for k in 1..n {
            let victim = (self.id + k) % n;
            loop {
                match self.s.deques[victim].steal() {
                    Steal::Taken(item) => {
                        self.c.steals += 1;
                        return Some(item);
                    }
                    Steal::Retry => {
                        // Lost a race — someone made progress; try again.
                        self.c.steal_retries += 1;
                        std::hint::spin_loop();
                    }
                    Steal::Empty => break,
                }
            }
        }
        None
    }
}

impl<N: Send> WorkerHandle<N> for StealHandle<'_, N> {
    fn push(&mut self, item: N) {
        // SAFETY: one live handle per worker (enforced in `handle()`),
        // and handles are driven from a single thread.
        unsafe { self.s.deques[self.id].push(item) };
        self.c.pushes += 1;
        self.c.offloaded += 1; // every deque slot is stealable
        if let Some(r) = &self.s.resident {
            // The new deque slot is stealable: hand it to a parked
            // thief. The fast path is one uncontended atomic load.
            r.unpark_one_if_parked();
        }
        // max_depth is a sampled statistic: deque.len() reads `top`,
        // a cache line thieves are CAS-ing, so probing it on every push
        // would put coherence traffic on the exact path this scheduler
        // exists to keep private. One probe per 64 pushes is plenty for
        // a high-water mark.
        if self.c.pushes & 63 == 0 {
            let depth = self.s.deques[self.id].len();
            if depth > self.c.max_depth {
                self.c.max_depth = depth;
            }
        }
    }

    fn pop_traced(&mut self) -> Option<(N, PopSource)> {
        // Deregister *before* any acquisition attempt so the termination
        // detector can never certify quiescence while an item is being
        // moved into this worker's hands (see module docs).
        if self.idle_registered {
            self.exit_idle();
        }
        // Fairness: drain the shared entry queue periodically even while
        // local work remains, so injected items (new jobs on a resident
        // pool) are never starved behind a deep deque. In one-shot runs
        // the injector is empty after the root, so this costs a few
        // atomic loads every 64th pop. Lane awareness: while the service
        // reports urgent (latency-lane) items in the injector, the poll
        // fires on *every* pop — the latency lane preempts the cadence.
        self.polls = self.polls.wrapping_add(1);
        if self.polls & 63 == 0 || self.s.urgent.urgent() {
            if let Some(item) = self.s.injector.pop() {
                self.c.shared_pops += 1;
                self.spins = 0;
                return Some((item, PopSource::Shared));
            }
        }
        // SAFETY: single live handle per worker.
        if let Some(item) = unsafe { self.s.deques[self.id].pop() } {
            self.c.pops += 1;
            self.spins = 0;
            return Some((item, PopSource::Local));
        }
        if let Some(item) = self.s.injector.pop() {
            self.c.shared_pops += 1;
            self.spins = 0;
            return Some((item, PopSource::Shared));
        }
        if self.s.steal {
            if let Some(item) = self.try_steal() {
                self.spins = 0;
                return Some((item, PopSource::Stolen));
            }
        }
        self.enter_idle();
        None
    }

    fn on_node_done(&mut self) {
        // Termination is inferred from idle registration, not from node
        // accounting — nothing to do on the hot path.
    }

    fn idle_step(&mut self) -> IdleOutcome {
        debug_assert!(self.idle_registered, "idle_step without a failed pop");
        if self.s.done.load(Ordering::SeqCst) {
            return IdleOutcome::Finished;
        }
        if let Some(r) = &self.s.resident {
            // Resident pool: quiescence is not termination — only a
            // drained pool with shutdown requested may exit (same epoch
            // sweep as one-shot mode, so the `done` latch still fans the
            // decision out to the remaining workers).
            if r.shutdown_requested()
                && self.s.idle.load(Ordering::SeqCst) == self.s.deques.len()
                && self.s.try_terminate()
            {
                return IdleOutcome::Finished;
            }
            self.spins += 1;
            if self.spins > SPINS_BEFORE_SLEEP {
                let exp = (self.spins - SPINS_BEFORE_SLEEP).min(PARK_MAX_EXP);
                let timeout = PARK_BASE * (1u32 << exp);
                let s = self.s;
                r.park(timeout, || {
                    !s.injector.is_empty() || s.deques.iter().any(|d| !d.is_empty())
                });
            } else {
                std::thread::yield_now();
            }
            return IdleOutcome::Retry;
        }
        if !self.s.steal {
            // Static partition: no other worker can feed this deque, so
            // an empty local queue + empty injector is final.
            if self.s.deques[self.id].is_empty() && self.s.injector.is_empty() {
                return IdleOutcome::Finished;
            }
        } else if self.s.idle.load(Ordering::SeqCst) == self.s.deques.len()
            && self.s.try_terminate()
        {
            return IdleOutcome::Finished;
        }
        self.spins += 1;
        if self.spins > SPINS_BEFORE_SLEEP {
            std::thread::sleep(IDLE_SLEEP);
        } else {
            std::thread::yield_now();
        }
        IdleOutcome::Retry
    }

    fn counters(&self) -> WorkerCounters {
        self.c
    }
}

impl<N: Send> Drop for StealHandle<'_, N> {
    fn drop(&mut self) {
        if self.idle_registered {
            self.exit_idle();
        }
        self.s.taken[self.id].store(false, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive an artificial branching workload through the scheduler from
    /// many threads: each item of weight w expands into two children of
    /// weight w-1 until w == 0. Total leaves = 2^w0 per root.
    fn run_workload(workers: usize, roots: &[u32]) -> (u64, Vec<WorkerCounters>) {
        let s: WorkStealScheduler<u32> = WorkStealScheduler::new(workers, true, 64);
        for &r in roots {
            s.inject(r);
        }
        let leaves = AtomicU64::new(0);
        let mut counters = vec![WorkerCounters::default(); workers];
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|w| {
                    let s = &s;
                    let leaves = &leaves;
                    scope.spawn(move || {
                        let mut h = s.handle(w);
                        loop {
                            match h.pop() {
                                Some(0) => {
                                    leaves.fetch_add(1, Ordering::Relaxed);
                                    h.on_node_done();
                                }
                                Some(x) => {
                                    h.push(x - 1);
                                    h.push(x - 1);
                                    h.on_node_done();
                                }
                                None => {
                                    if h.idle_step() == IdleOutcome::Finished {
                                        break;
                                    }
                                }
                            }
                        }
                        h.counters()
                    })
                })
                .collect();
            for (w, jh) in handles.into_iter().enumerate() {
                counters[w] = jh.join().unwrap();
            }
        });
        (leaves.load(Ordering::Relaxed), counters)
    }

    #[test]
    fn drains_and_terminates_single_worker() {
        let (leaves, counters) = run_workload(1, &[10]);
        assert_eq!(leaves, 1 << 10);
        assert_eq!(counters[0].steals, 0);
        assert_eq!(counters[0].shared_pops, 1);
    }

    #[test]
    fn drains_and_terminates_many_workers() {
        for workers in [2usize, 4, 8] {
            let (leaves, counters) = run_workload(workers, &[12]);
            assert_eq!(leaves, 1 << 12, "workers={workers}");
            // Conservation: every acquired item was either a leaf or
            // expanded into exactly two pushes.
            let acquired: u64 = counters.iter().map(|c| c.acquired()).sum();
            let pushed: u64 = counters.iter().map(|c| c.pushes).sum();
            assert_eq!(acquired, pushed + 1, "workers={workers}"); // +1 injected root
        }
    }

    #[test]
    fn no_steal_mode_static_partition() {
        let s: WorkStealScheduler<u32> = WorkStealScheduler::new(4, false, 16);
        for i in 0..16 {
            s.seed(i % 4, 0);
        }
        let done = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for w in 0..4 {
                let s = &s;
                let done = &done;
                scope.spawn(move || {
                    let mut h = s.handle(w);
                    loop {
                        match h.pop() {
                            Some(_) => {
                                done.fetch_add(1, Ordering::Relaxed);
                                h.on_node_done();
                            }
                            None => {
                                if h.idle_step() == IdleOutcome::Finished {
                                    break;
                                }
                            }
                        }
                    }
                    assert_eq!(h.counters().steals, 0, "stealing must be off");
                });
            }
        });
        assert_eq!(done.load(Ordering::Relaxed), 16);
    }

    #[test]
    #[should_panic(expected = "already has a live handle")]
    fn double_handle_panics() {
        let s: WorkStealScheduler<u32> = WorkStealScheduler::new(2, true, 8);
        let _a = s.handle(0);
        let _b = s.handle(0);
    }

    #[test]
    fn handle_slot_released_on_drop() {
        let s: WorkStealScheduler<u32> = WorkStealScheduler::new(1, true, 8);
        drop(s.handle(0));
        drop(s.handle(0)); // second acquisition succeeds after release
    }

    #[test]
    fn resident_pool_survives_quiescence_between_epochs() {
        // A resident pool must park (not terminate) when drained, pick
        // up a second injected epoch, and exit only on shutdown.
        let s: WorkStealScheduler<u32> = WorkStealScheduler::new_resident(2, 8);
        let leaves = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for w in 0..2 {
                let s = &s;
                let leaves = &leaves;
                scope.spawn(move || {
                    let mut h = s.handle(w);
                    loop {
                        match h.pop() {
                            Some(0) => {
                                leaves.fetch_add(1, Ordering::SeqCst);
                                h.on_node_done();
                            }
                            Some(x) => {
                                h.push(x - 1);
                                h.on_node_done();
                            }
                            None => {
                                if h.idle_step() == IdleOutcome::Finished {
                                    break;
                                }
                            }
                        }
                    }
                });
            }
            s.inject(3); // epoch 1: one chain, one leaf
            while leaves.load(Ordering::SeqCst) < 1 {
                std::thread::yield_now();
            }
            // give the pool time to go fully quiescent and park
            std::thread::sleep(std::time::Duration::from_millis(5));
            s.inject(2); // epoch 2 must still be picked up
            while leaves.load(Ordering::SeqCst) < 2 {
                std::thread::yield_now();
            }
            s.request_shutdown();
        });
        assert_eq!(leaves.load(Ordering::SeqCst), 2);
    }
}
