//! Sequential component-aware solver (Algorithm 2, recursive form).
//!
//! This is the paper's "Sequential" baseline: a single-threaded CPU
//! implementation that embodies *all* the proposed optimizations
//! (component-awareness, clique/cycle rules, reduced + induced root,
//! bounds) but none of the parallel machinery. It supports **cover
//! extraction**, sharing the canonical special-component covers and the
//! verifier with the parallel engine's choice-log path
//! ([`crate::solver::witness`]), so it doubles as the differential
//! witness reference.

use crate::degree::NonZeroBounds;
use crate::graph::Graph;
use crate::reduce::special::classify;
use std::time::Instant;

/// Outcome of a sequential search.
#[derive(Debug, Clone)]
pub struct SeqOutcome {
    /// Best cover size found (== initial bound if not improved).
    pub best: u32,
    /// A witness cover of size `best`, if one strictly better than the
    /// initial bound was found and extraction was requested.
    pub cover: Option<Vec<u32>>,
    /// Search-tree nodes visited.
    pub tree_nodes: u64,
    /// Nodes that branched on components.
    pub component_branches: u64,
    /// True if the deadline fired.
    pub timed_out: bool,
}

struct Seq<'g> {
    g: &'g Graph,
    component_aware: bool,
    extract: bool,
    deadline: Option<Instant>,
    tree_nodes: u64,
    component_branches: u64,
    timed_out: bool,
}

/// Solve MVC on `g` sequentially. `initial_best` is an exclusive upper
/// bound (search for strictly smaller covers). Returns the best size and
/// optionally a witness for the improvement.
pub fn solve(
    g: &Graph,
    initial_best: u32,
    component_aware: bool,
    extract: bool,
    deadline: Option<Instant>,
) -> SeqOutcome {
    let mut s = Seq {
        g,
        component_aware,
        extract,
        deadline,
        tree_nodes: 0,
        component_branches: 0,
        timed_out: false,
    };
    let deg: Vec<u32> = (0..g.num_vertices() as u32).map(|v| g.degree(v)).collect();
    let edges = g.num_edges() as u64;
    let mut best = initial_best;
    let mut cover = None;
    s.mvc(deg, edges, 0, &mut best, &mut cover, &mut Vec::new());
    SeqOutcome {
        best,
        cover,
        tree_nodes: s.tree_nodes,
        component_branches: s.component_branches,
        timed_out: s.timed_out,
    }
}

impl<'g> Seq<'g> {
    /// Algorithm 2. `sol` is the vertices committed on this branch (kept
    /// only when extracting); on improvement, `best`/`best_cover` update.
    #[allow(clippy::too_many_arguments)]
    fn mvc(
        &mut self,
        mut deg: Vec<u32>,
        mut edges: u64,
        mut sol_size: u32,
        best: &mut u32,
        best_cover: &mut Option<Vec<u32>>,
        sol: &mut Vec<u32>,
    ) {
        if self.timed_out {
            return;
        }
        self.tree_nodes += 1;
        if self.tree_nodes % 128 == 0 {
            if let Some(d) = self.deadline {
                if Instant::now() >= d {
                    self.timed_out = true;
                    return;
                }
            }
        }
        let sol_mark = sol.len();

        // reduce (line 2)
        self.reduce(&mut deg, &mut edges, &mut sol_size, *best, sol);

        // stopping conditions (lines 3-4)
        let prune = sol_size >= *best || {
            let rem = (*best - sol_size - 1) as u64;
            edges > rem * rem
        };
        if prune {
            sol.truncate(sol_mark);
            return;
        }
        // leaf (lines 5-7)
        if edges == 0 {
            *best = sol_size;
            if self.extract {
                *best_cover = Some(sol.clone());
            }
            sol.truncate(sol_mark);
            return;
        }

        // components (lines 9-20)
        if self.component_aware {
            let comps = self.components(&deg);
            if comps.len() > 1 {
                self.component_branches += 1;
                let mut sum = sol_size;
                let comp_mark = sol.len();
                for comp in &comps {
                    // closed-form special components (§III-D)
                    if let Some(sp) =
                        classify(comp.len() as u32, comp.iter().map(|&v| deg[v as usize]))
                    {
                        sum += sp.mvc_size();
                        if self.extract {
                            // canonical cover shared with the root
                            // reducer and the parallel engine
                            sp.cover_into(self.g, comp, |v| deg[v as usize] > 0, sol);
                        }
                        continue;
                    }
                    // best_i = min(best - sum, |V_i| - 1)   (line 17)
                    let size = comp.len() as u32;
                    let cap = (*best).saturating_sub(sum).min(size - 1);
                    // sub-degree array restricted to the component
                    let mut sdeg = vec![0u32; deg.len()];
                    let mut sedges = 0u64;
                    for &v in comp {
                        sdeg[v as usize] = deg[v as usize];
                        sedges += deg[v as usize] as u64;
                    }
                    let mut sub_cover: Option<Vec<u32>> = None;
                    let mut sub_sol = Vec::new();
                    let mut limit = cap;
                    // search strictly below `cap`; fall back to the
                    // always-achievable all-but-one cover if nothing better
                    self.mvc(sdeg, sedges / 2, 0, &mut limit, &mut sub_cover, &mut sub_sol);
                    let improved = limit < cap;
                    let best_i = if improved { limit } else { size - 1 };
                    sum += best_i;
                    if self.extract {
                        match sub_cover {
                            Some(c) if improved => sol.extend(c),
                            // all-but-one witness for the unimproved bound
                            _ => sol.extend(comp.iter().skip(1).copied()),
                        }
                    }
                    if self.timed_out {
                        sol.truncate(sol_mark);
                        return;
                    }
                }
                if sum < *best {
                    *best = sum; // line 20
                    if self.extract {
                        *best_cover = Some(sol.clone());
                    }
                }
                let _ = comp_mark;
                sol.truncate(sol_mark);
                return;
            }
        }

        // single-component branch (lines 11-13)
        let vmax = (0..deg.len() as u32).max_by_key(|&v| deg[v as usize]).unwrap();
        debug_assert!(deg[vmax as usize] > 0);

        // `sol` currently holds the ancestor prefix plus this node's
        // reduction commits; both branches extend from here.
        let reduce_mark = sol.len();

        // left: vmax into S
        {
            let mut d2 = deg.clone();
            let mut e2 = edges;
            let mut s2 = sol_size;
            self.cover(&mut d2, &mut e2, &mut s2, vmax, sol);
            self.mvc(d2, e2, s2, best, best_cover, sol);
            sol.truncate(reduce_mark);
        }
        // right: N(vmax) into S (consumes this node's arrays)
        {
            let nbrs: Vec<u32> = self
                .g
                .neighbors(vmax)
                .iter()
                .copied()
                .filter(|&w| deg[w as usize] > 0)
                .collect();
            for &u in &nbrs {
                if deg[u as usize] > 0 {
                    self.cover(&mut deg, &mut edges, &mut sol_size, u, sol);
                }
            }
            self.mvc(deg, edges, sol_size, best, best_cover, sol);
            sol.truncate(sol_mark);
        }
    }

    fn cover(&self, deg: &mut [u32], edges: &mut u64, sol_size: &mut u32, v: u32, sol: &mut Vec<u32>) {
        let d = deg[v as usize];
        debug_assert!(d > 0);
        deg[v as usize] = 0;
        *edges -= d as u64;
        let mut rem = d;
        for &w in self.g.neighbors(v) {
            if deg[w as usize] > 0 {
                deg[w as usize] -= 1;
                rem -= 1;
                if rem == 0 {
                    break;
                }
            }
        }
        *sol_size += 1;
        if self.extract {
            sol.push(v);
        }
    }

    /// Reduction fixpoint (degree-1, degree-2 triangle, high-degree).
    fn reduce(
        &self,
        deg: &mut Vec<u32>,
        edges: &mut u64,
        sol_size: &mut u32,
        best: u32,
        sol: &mut Vec<u32>,
    ) {
        loop {
            if *edges == 0 || *sol_size >= best {
                return;
            }
            let mut changed = false;
            let w = NonZeroBounds::exact(deg.as_slice());
            if w.is_empty() {
                return;
            }
            for v in w.lo..=w.hi {
                let d = deg[v as usize];
                match d {
                    0 => continue,
                    1 => {
                        let u = self
                            .g
                            .neighbors(v)
                            .iter()
                            .copied()
                            .find(|&w| deg[w as usize] > 0)
                            .unwrap();
                        self.cover(deg, edges, sol_size, u, sol);
                        changed = true;
                    }
                    2 => {
                        let mut it = self
                            .g
                            .neighbors(v)
                            .iter()
                            .copied()
                            .filter(|&w| deg[w as usize] > 0);
                        let a = it.next().unwrap();
                        let b = it.next().unwrap();
                        if self.g.has_edge(a, b) {
                            self.cover(deg, edges, sol_size, a, sol);
                            self.cover(deg, edges, sol_size, b, sol);
                            changed = true;
                        }
                    }
                    d => {
                        let budget = best.saturating_sub(*sol_size).saturating_sub(1);
                        if d > budget {
                            self.cover(deg, edges, sol_size, v, sol);
                            changed = true;
                        }
                    }
                }
                if *edges == 0 || *sol_size >= best {
                    return;
                }
            }
            if !changed {
                return;
            }
        }
    }

    /// Connected components of the residual graph (vertex lists).
    fn components(&self, deg: &[u32]) -> Vec<Vec<u32>> {
        let n = deg.len();
        let mut seen = vec![false; n];
        let mut comps = Vec::new();
        for s in 0..n as u32 {
            if deg[s as usize] == 0 || seen[s as usize] {
                continue;
            }
            let mut comp = vec![s];
            seen[s as usize] = true;
            let mut head = 0;
            while head < comp.len() {
                let u = comp[head];
                head += 1;
                for &w in self.g.neighbors(u) {
                    if deg[w as usize] > 0 && !seen[w as usize] {
                        seen[w as usize] = true;
                        comp.push(w);
                    }
                }
            }
            comps.push(comp);
        }
        comps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::solver::{greedy, oracle};

    fn seq_best(g: &Graph, component_aware: bool) -> u32 {
        let ub = greedy::greedy_bound(g);
        solve(g, ub + 1, component_aware, false, None).best.min(ub)
    }

    #[test]
    fn matches_oracle_random() {
        for seed in 0..15 {
            let g = generators::erdos_renyi(16, 0.2, seed);
            let opt = oracle::mvc_size(&g);
            assert_eq!(seq_best(&g, true), opt, "ca seed {seed}");
            assert_eq!(seq_best(&g, false), opt, "plain seed {seed}");
        }
    }

    #[test]
    fn matches_oracle_split_graphs() {
        for seed in 0..10 {
            let g = generators::union_of_random(4, 3, 6, 0.3, seed);
            let opt = oracle::mvc_size(&g);
            assert_eq!(seq_best(&g, true), opt, "seed {seed}");
        }
    }

    #[test]
    fn extraction_produces_valid_optimal_cover() {
        for seed in 0..10 {
            let g = generators::erdos_renyi(15, 0.22, seed);
            let opt = oracle::mvc_size(&g);
            let n = g.num_vertices() as u32;
            let out = solve(&g, n + 1, true, true, None);
            assert_eq!(out.best, opt, "seed {seed}");
            if opt <= n {
                let cover = out.cover.expect("improvement below n+1 must exist");
                assert_eq!(cover.len() as u32, opt, "seed {seed}");
                assert!(g.is_vertex_cover(&cover), "seed {seed}");
            }
        }
    }

    #[test]
    fn extraction_on_component_split() {
        let g = Graph::disjoint_union(&[
            generators::cycle(7),
            generators::clique(5),
            generators::erdos_renyi(10, 0.3, 3),
        ]);
        let opt = oracle::mvc_size(&g);
        let out = solve(&g, g.num_vertices() as u32 + 1, true, true, None);
        assert_eq!(out.best, opt);
        let cover = out.cover.unwrap();
        assert_eq!(cover.len() as u32, opt);
        assert!(g.is_vertex_cover(&cover));
        assert!(out.component_branches >= 1);
    }

    #[test]
    fn component_awareness_visits_fewer_nodes() {
        // reduction-proof components: the component-aware tree must be
        // smaller than the oblivious one (paper §III-A)
        let g = Graph::disjoint_union(&[
            generators::petersen(),
            generators::generalized_petersen(7, 2),
            generators::generalized_petersen(9, 2),
        ]);
        let ub = greedy::greedy_bound(&g) + 1;
        let with = solve(&g, ub, true, false, None);
        let without = solve(&g, ub, false, false, None);
        assert_eq!(with.best, without.best);
        assert!(
            with.tree_nodes < without.tree_nodes,
            "with={} without={}",
            with.tree_nodes,
            without.tree_nodes
        );
    }

    #[test]
    fn timeout_reported() {
        // hard enough to exceed the first deadline check
        let g = generators::generalized_petersen(40, 2);
        let out = solve(&g, g.num_vertices() as u32, true, false, Some(Instant::now()));
        assert!(out.timed_out);
    }
}
