//! TCP front end for a resident [`VcService`]: [`VcServer`] accepts
//! connections speaking the [`crate::solver::wire`] protocol and
//! [`VcClient`] is the matching blocking client.
//!
//! # Serving over the network: why one coordinator
//!
//! The socket layer is deliberately *thin*. Per-connection reader
//! threads do nothing but decode frames and push them into **one
//! bounded ingress channel**; a single coordinator thread drains it and
//! is the only caller of [`VcService::try_submit_with`] /
//! [`VcService::submit_within`]. This mirrors the ROADMAP's caution
//! (from the galette line of work) that a fleet of sockets each
//! negotiating admission on its own loses to one coordinator on
//! kernel↔user synchronization overhead — and it keeps the service's
//! single-threaded admission dispatcher the only policy engine: the
//! network adds transport, not a second scheduler. Backpressure
//! composes the same way: the ingress channel is bounded (a flooding
//! connection blocks its own reader, not the pool), and admission
//! verdicts ([`SubmitError`]) travel back as typed error frames.
//!
//! Each connection gets a writer thread fed by an unbounded queue of
//! pre-encoded frames, so a slow client never blocks the coordinator or
//! another connection's replies. Per-request waiter threads block on
//! [`JobHandle::wait`] and post the solution frame when the job
//! finalizes — the coordinator never waits on a job.
//!
//! **Lifecycle.** Reads carry a timeout so readers notice the shutdown
//! flag; a client disconnect (EOF or error) cancels that connection's
//! outstanding jobs via [`JobHandle::cancel`] — a caller who hung up
//! should not keep burning pool time. Malformed frames are answered
//! with a typed error frame and the connection keeps serving (the
//! framing keeps the stream in sync); only unframeable input — an
//! oversized length prefix, a mid-frame stall — closes the connection.
//! [`VcServer::shutdown`] (also run on drop) drains rather than aborts:
//! stop accepting, let readers exit, drain the ingress queue, wait for
//! every outstanding job's reply to be written, then join all threads.
//!
//! [`JobHandle::wait`]: super::service::JobHandle::wait
//! [`JobHandle::cancel`]: super::service::JobHandle::cancel

use std::collections::HashMap;
use std::io::{self, BufWriter, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::service::{JobHandle, Problem, ServiceStats, SubmitError, VcService};
use super::wire::{
    self, ErrorCode, Frame, SubmitRequest, WireError, WireErrorFrame, WireOptions, WireSolution,
    PROTOCOL_VERSION, WIRE_MAGIC,
};

/// How long a fresh connection gets to complete the `Hello` handshake
/// before its slot is reclaimed.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// Knobs for [`VcServer::bind`].
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Maximum simultaneous connections; excess connects are answered
    /// with an [`ErrorCode::ConnLimit`] frame and closed.
    pub max_conns: usize,
    /// Socket read timeout: the idle-poll cadence at which reader
    /// threads re-check the shutdown flag, and the patience for a
    /// started-but-stalled frame (a mid-frame stall past it closes the
    /// connection).
    pub read_timeout: Duration,
    /// How long the coordinator lets a submit wait on admission
    /// backpressure ([`VcService::submit_within`]). Zero (the default)
    /// means pure [`VcService::try_submit_with`]: the queue-full verdict
    /// travels back immediately as a typed error frame.
    pub submit_wait: Duration,
    /// Bound of the shared ingress channel. A connection that floods
    /// submits faster than the coordinator drains them blocks its own
    /// reader thread here — per-connection backpressure, not a global
    /// stall.
    pub ingress_depth: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_conns: 64,
            read_timeout: Duration::from_millis(100),
            submit_wait: Duration::ZERO,
            ingress_depth: 256,
        }
    }
}

/// What a reader thread hands the coordinator.
enum Ingress {
    Submit { conn: Arc<ConnState>, req: SubmitRequest },
    Cancel { conn: Arc<ConnState>, req_id: u64 },
    Stats { conn: Arc<ConnState> },
}

/// Per-connection shared state: the reply queue and the outstanding
/// requests (for cancel-on-disconnect).
struct ConnState {
    /// Pre-encoded reply frames headed for the writer thread; `None`
    /// once the connection is torn down.
    writer: Mutex<Option<Sender<Vec<u8>>>>,
    /// Outstanding request id → running job.
    pending: Mutex<HashMap<u64, JobHandle>>,
    /// Set on teardown so late coordinator work and waiters skip it.
    closed: AtomicBool,
}

impl ConnState {
    fn send(&self, frame: &Frame) {
        let bytes = wire::encode_frame(frame);
        if let Some(tx) = self.writer.lock().unwrap().as_ref() {
            let _ = tx.send(bytes);
        }
    }

    fn send_error(&self, req_id: u64, code: ErrorCode, detail: String) {
        self.send(&Frame::Error(WireErrorFrame { req_id, code, detail }));
    }

    /// Disconnect teardown: cancel every outstanding job (the client is
    /// gone; nobody will read the answers) and close the reply queue.
    fn close_and_cancel(&self) {
        self.closed.store(true, Ordering::SeqCst);
        let handles: Vec<JobHandle> =
            self.pending.lock().unwrap().drain().map(|(_, h)| h).collect();
        for h in &handles {
            h.cancel();
        }
        *self.writer.lock().unwrap() = None;
    }
}

struct Shared {
    service: VcService,
    cfg: ServerConfig,
    shutdown: AtomicBool,
    conns: AtomicUsize,
    /// Outstanding remote jobs (admitted, reply not yet posted); the
    /// drain barrier `shutdown` waits on.
    inflight: Mutex<usize>,
    idle_cv: Condvar,
    conn_threads: Mutex<Vec<JoinHandle<()>>>,
}

impl Shared {
    fn inflight_add(&self) {
        *self.inflight.lock().unwrap() += 1;
    }

    fn inflight_done(&self) {
        let mut n = self.inflight.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.idle_cv.notify_all();
        }
    }
}

/// A TCP server exposing one [`VcService`] over the wire protocol. See
/// the module docs for the threading model.
pub struct VcServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    ingress: Option<SyncSender<Ingress>>,
    accept: Option<JoinHandle<()>>,
    coord: Option<JoinHandle<()>>,
}

impl VcServer {
    /// Bind `addr` and start serving `service`. The service is owned by
    /// the server (and dropped — draining its pool — when the server
    /// shuts down); use [`VcServer::service`] for in-process access to
    /// the same instance.
    pub fn bind(
        addr: impl ToSocketAddrs,
        service: VcService,
        cfg: ServerConfig,
    ) -> io::Result<VcServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let (tx, rx) = mpsc::sync_channel::<Ingress>(cfg.ingress_depth.max(1));
        let shared = Arc::new(Shared {
            service,
            cfg,
            shutdown: AtomicBool::new(false),
            conns: AtomicUsize::new(0),
            inflight: Mutex::new(0),
            idle_cv: Condvar::new(),
            conn_threads: Mutex::new(Vec::new()),
        });
        let coord = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("cavc-net-coord".into())
                .spawn(move || coordinator_loop(&shared, rx))?
        };
        let accept = {
            let shared = Arc::clone(&shared);
            let tx = tx.clone();
            std::thread::Builder::new()
                .name("cavc-net-accept".into())
                .spawn(move || accept_loop(&shared, listener, tx))?
        };
        Ok(VcServer {
            shared,
            addr: local,
            ingress: Some(tx),
            accept: Some(accept),
            coord: Some(coord),
        })
    }

    /// The bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The served [`VcService`] — in-process submits and `stats()` see
    /// exactly the instance remote clients are talking to.
    pub fn service(&self) -> &VcService {
        &self.shared.service
    }

    /// Live connection count.
    pub fn connections(&self) -> usize {
        self.shared.conns.load(Ordering::SeqCst)
    }

    /// Drain-then-exit shutdown: stop accepting, let readers notice and
    /// exit, drain queued ingress, wait for every outstanding job's
    /// reply to be posted, then join all server threads. Also runs on
    /// drop.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if self.shared.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Readers exit within one read timeout of the flag; once their
        // ingress senders (and ours) drop, the coordinator drains the
        // channel and exits.
        self.ingress = None;
        if let Some(h) = self.coord.take() {
            let _ = h.join();
        }
        // Wait for outstanding jobs to finalize and their replies to be
        // queued (disconnected connections already cancelled theirs).
        let mut n = self.shared.inflight.lock().unwrap();
        while *n > 0 {
            n = self.shared.idle_cv.wait(n).unwrap();
        }
        drop(n);
        // Writers exit once the last reply queue closes; join everyone.
        let threads = std::mem::take(&mut *self.shared.conn_threads.lock().unwrap());
        for t in threads {
            let _ = t.join();
        }
    }
}

impl Drop for VcServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(shared: &Arc<Shared>, listener: TcpListener, ingress: SyncSender<Ingress>) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        if shared.conns.load(Ordering::SeqCst) >= shared.cfg.max_conns {
            // Best-effort typed rejection; the slot was never taken.
            let mut s = stream;
            let _ = s.write_all(&wire::encode_frame(&Frame::Error(WireErrorFrame {
                req_id: 0,
                code: ErrorCode::ConnLimit,
                detail: format!("connection limit {} reached", shared.cfg.max_conns),
            })));
            let _ = s.shutdown(Shutdown::Both);
            continue;
        }
        let write_half = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => continue,
        };
        let _ = stream.set_read_timeout(Some(shared.cfg.read_timeout));
        let _ = stream.set_nodelay(true);
        shared.conns.fetch_add(1, Ordering::SeqCst);
        let (wtx, wrx) = mpsc::channel::<Vec<u8>>();
        let conn = Arc::new(ConnState {
            writer: Mutex::new(Some(wtx)),
            pending: Mutex::new(HashMap::new()),
            closed: AtomicBool::new(false),
        });
        let reader = {
            let shared = Arc::clone(shared);
            let tx = ingress.clone();
            std::thread::Builder::new()
                .name("cavc-net-read".into())
                .spawn(move || conn_reader(&shared, stream, &conn, &tx))
        };
        let writer = std::thread::Builder::new()
            .name("cavc-net-write".into())
            .spawn(move || writer_loop(write_half, wrx));
        let mut threads = shared.conn_threads.lock().unwrap();
        if let Ok(h) = reader {
            threads.push(h);
        }
        if let Ok(h) = writer {
            threads.push(h);
        }
    }
}

/// Drain pre-encoded reply frames onto the socket until the queue
/// closes or the peer stops reading.
fn writer_loop(stream: TcpStream, rx: Receiver<Vec<u8>>) {
    let mut w = BufWriter::new(stream);
    for bytes in rx {
        if w.write_all(&bytes).and_then(|_| w.flush()).is_err() {
            return;
        }
    }
}

/// What one blocking read attempt produced.
enum NetRead {
    Frame(Frame),
    /// Read timeout before any byte of a frame arrived — re-check flags
    /// and poll again.
    Idle,
    /// Orderly EOF at a frame boundary.
    Eof,
    /// Connection-fatal: an I/O error, a mid-frame stall, or an
    /// unframeable length prefix.
    Fatal,
    /// The frame was consumed exactly but did not decode: reply with a
    /// typed error frame and keep the connection.
    Bad(WireError),
}

fn read_one(stream: &mut TcpStream) -> NetRead {
    // First length byte read separately: a timeout here means "no
    // traffic", not "broken frame", because no bytes were consumed.
    let mut first = [0u8; 1];
    loop {
        match stream.read(&mut first) {
            Ok(0) => return NetRead::Eof,
            Ok(_) => break,
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                return NetRead::Idle;
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return NetRead::Fatal,
        }
    }
    let mut rest = [0u8; 3];
    if stream.read_exact(&mut rest).is_err() {
        return NetRead::Fatal;
    }
    let len = u32::from_le_bytes([first[0], rest[0], rest[1], rest[2]]);
    match wire::read_body(stream, len) {
        Ok(frame) => NetRead::Frame(frame),
        // Oversized: the declared payload was not consumed, so the
        // stream position is lost. I/O: the socket broke mid-frame.
        Err(WireError::Oversized(n)) => NetRead::Bad(WireError::Oversized(n)),
        Err(WireError::Io(_)) => NetRead::Fatal,
        Err(e) => NetRead::Bad(e),
    }
}

/// Read loop of one connection: handshake, then frames into the
/// ingress channel. Returns `true` when exiting for server shutdown
/// (pending jobs drain normally) and `false` on disconnect (pending
/// jobs are cancelled).
fn reader_session(
    shared: &Shared,
    stream: &mut TcpStream,
    conn: &Arc<ConnState>,
    tx: &SyncSender<Ingress>,
) -> bool {
    // Handshake: the first frame must be a valid Hello.
    let deadline = Instant::now() + HANDSHAKE_TIMEOUT;
    let client_version = loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return true;
        }
        if Instant::now() > deadline {
            conn.send_error(0, ErrorCode::Protocol, "handshake timeout".into());
            return false;
        }
        match read_one(stream) {
            NetRead::Idle => continue,
            NetRead::Frame(Frame::Hello { version, .. }) => break version,
            NetRead::Frame(_) => {
                conn.send_error(0, ErrorCode::Protocol, "expected hello".into());
                return false;
            }
            NetRead::Bad(e) => {
                conn.send_error(0, e.code(), e.to_string());
                if !e.recoverable() {
                    return false;
                }
            }
            NetRead::Eof | NetRead::Fatal => return false,
        }
    };
    conn.send(&Frame::HelloAck { version: client_version.min(PROTOCOL_VERSION) });

    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return true;
        }
        let msg = match read_one(stream) {
            NetRead::Idle => continue,
            NetRead::Eof | NetRead::Fatal => return false,
            NetRead::Bad(e) => {
                // Malformed but exactly-framed input: typed error frame,
                // keep serving this connection (robustness contract —
                // garbage must never take the server down).
                conn.send_error(0, e.code(), e.to_string());
                if !e.recoverable() {
                    return false;
                }
                continue;
            }
            NetRead::Frame(Frame::Submit(req)) => {
                Ingress::Submit { conn: Arc::clone(conn), req }
            }
            NetRead::Frame(Frame::Cancel { req_id }) => {
                Ingress::Cancel { conn: Arc::clone(conn), req_id }
            }
            NetRead::Frame(Frame::StatsRequest) => Ingress::Stats { conn: Arc::clone(conn) },
            NetRead::Frame(_) => {
                conn.send_error(0, ErrorCode::Protocol, "unexpected frame from client".into());
                continue;
            }
        };
        // A full ingress channel blocks this reader only (bounded
        // transport backpressure); an error means the coordinator is
        // gone, i.e. shutdown.
        if tx.send(msg).is_err() {
            return true;
        }
    }
}

fn conn_reader(
    shared: &Arc<Shared>,
    mut stream: TcpStream,
    conn: &Arc<ConnState>,
    tx: &SyncSender<Ingress>,
) {
    let drain = reader_session(shared, &mut stream, conn, tx);
    if !drain {
        // Cancel outstanding jobs and close the reply queue; the writer
        // thread flushes any queued error frame, then its clone of the
        // socket drops and the connection closes.
        conn.close_and_cancel();
    }
    // On the drain path the reply queue stays open: outstanding waiters
    // still post their solutions, and the writer exits when the last
    // `ConnState` reference drops.
    shared.conns.fetch_sub(1, Ordering::SeqCst);
}

/// The single coordinator: the only thread that talks to the service's
/// admission layer on behalf of the network.
fn coordinator_loop(shared: &Arc<Shared>, rx: Receiver<Ingress>) {
    while let Ok(msg) = rx.recv() {
        match msg {
            Ingress::Submit { conn, req } => handle_submit(shared, conn, req),
            Ingress::Cancel { conn, req_id } => {
                // The handle stays pending: the job's anytime Solution
                // (Termination::Cancelled) is still the one reply this
                // request gets.
                let handle = conn.pending.lock().unwrap().get(&req_id).cloned();
                if let Some(h) = handle {
                    h.cancel();
                }
            }
            Ingress::Stats { conn } => {
                conn.send(&Frame::StatsReply(Box::new(shared.service.stats())));
            }
        }
    }
}

fn handle_submit(shared: &Arc<Shared>, conn: Arc<ConnState>, req: SubmitRequest) {
    if conn.closed.load(Ordering::SeqCst) {
        return;
    }
    let SubmitRequest { req_id, problem, opts } = req;
    if conn.pending.lock().unwrap().contains_key(&req_id) {
        conn.send_error(req_id, ErrorCode::Protocol, format!("duplicate request id {req_id}"));
        return;
    }
    let wait = shared.cfg.submit_wait;
    let admitted = if wait.is_zero() {
        shared.service.try_submit_with(problem, opts.job_options())
    } else {
        shared.service.submit_within(problem, opts.job_options(), wait)
    };
    match admitted {
        Ok(handle) => {
            conn.pending.lock().unwrap().insert(req_id, handle.clone());
            shared.inflight_add();
            let sh = Arc::clone(shared);
            let waiter_conn = Arc::clone(&conn);
            let spawned = std::thread::Builder::new().name("cavc-net-wait".into()).spawn(
                move || {
                    let sol = handle.wait();
                    // A disconnect teardown drains `pending`; if our
                    // entry is gone the client is too.
                    if waiter_conn.pending.lock().unwrap().remove(&req_id).is_some() {
                        waiter_conn.send(&Frame::Solution(Box::new(
                            WireSolution::from_solution(req_id, &sol),
                        )));
                    }
                    sh.inflight_done();
                },
            );
            if spawned.is_err() {
                // Could not spawn a waiter: undo the bookkeeping and
                // report the job as shed.
                shared.inflight_done();
                if let Some(h) = conn.pending.lock().unwrap().remove(&req_id) {
                    h.cancel();
                }
                conn.send_error(req_id, ErrorCode::Protocol, "server thread spawn failed".into());
            }
        }
        Err(e) => conn.send_error(req_id, ErrorCode::from(e), e.to_string()),
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Client-side failure talking to a [`VcServer`].
#[derive(Debug)]
pub enum ClientError {
    /// The connection failed.
    Io(io::Error),
    /// A reply frame did not decode.
    Wire(WireError),
    /// The server answered with a typed error frame (admission
    /// backpressure, protocol violation, connection cap…).
    Rejected(WireErrorFrame),
    /// The server sent a frame that makes no sense here.
    Protocol(&'static str),
}

impl ClientError {
    /// The in-process [`SubmitError`] behind a typed rejection, when
    /// the server shed this submit for admission reasons.
    pub fn submit_error(&self) -> Option<SubmitError> {
        match self {
            ClientError::Rejected(e) => e.code.submit_error(),
            _ => None,
        }
    }
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "connection: {e}"),
            ClientError::Wire(e) => write!(f, "wire: {e}"),
            ClientError::Rejected(e) => write!(f, "server: {} ({:?})", e.detail, e.code),
            ClientError::Protocol(what) => write!(f, "protocol: {what}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<io::Error> for ClientError {
    fn from(e: io::Error) -> ClientError {
        ClientError::Io(e)
    }
}

impl From<WireError> for ClientError {
    fn from(e: WireError) -> ClientError {
        ClientError::Wire(e)
    }
}

/// A reply frame a client can receive.
#[derive(Debug, Clone)]
pub enum ServerReply {
    /// A finished job.
    Solution(WireSolution),
    /// A typed rejection.
    Error(WireErrorFrame),
    /// A stats scrape.
    Stats(ServiceStats),
}

/// Blocking client for the wire protocol: connect, submit problems
/// (pipelined — replies carry the request id), scrape stats.
pub struct VcClient {
    stream: TcpStream,
    version: u16,
    next_req: u64,
}

impl VcClient {
    /// Connect and run the version handshake.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<VcClient, ClientError> {
        let mut stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        wire::write_frame(
            &mut stream,
            &Frame::Hello { magic: WIRE_MAGIC, version: PROTOCOL_VERSION },
        )?;
        match wire::read_frame(&mut stream)? {
            Frame::HelloAck { version } => Ok(VcClient { stream, version, next_req: 1 }),
            Frame::Error(e) => Err(ClientError::Rejected(e)),
            _ => Err(ClientError::Protocol("expected hello-ack")),
        }
    }

    /// The negotiated protocol version.
    pub fn version(&self) -> u16 {
        self.version
    }

    /// Submit a problem; returns the request id its reply will carry.
    pub fn submit(&mut self, problem: &Problem, opts: WireOptions) -> Result<u64, ClientError> {
        let req_id = self.next_req;
        self.next_req += 1;
        wire::write_frame(
            &mut self.stream,
            &Frame::Submit(SubmitRequest { req_id, problem: problem.clone(), opts }),
        )?;
        Ok(req_id)
    }

    /// Ask the server to cancel an outstanding request. Its `Solution`
    /// still arrives, terminated `Cancelled` (anytime result).
    pub fn cancel(&mut self, req_id: u64) -> Result<(), ClientError> {
        wire::write_frame(&mut self.stream, &Frame::Cancel { req_id })?;
        Ok(())
    }

    /// Block for the next reply frame.
    pub fn recv(&mut self) -> Result<ServerReply, ClientError> {
        match wire::read_frame(&mut self.stream)? {
            Frame::Solution(s) => Ok(ServerReply::Solution(*s)),
            Frame::Error(e) => Ok(ServerReply::Error(e)),
            Frame::StatsReply(s) => Ok(ServerReply::Stats(*s)),
            _ => Err(ClientError::Protocol("unexpected server frame")),
        }
    }

    /// Submit one problem and block for its solution; a typed error
    /// reply (admission backpressure) surfaces as
    /// [`ClientError::Rejected`]. Replies to other in-flight requests
    /// on this connection are *not* consumed out of order — use
    /// [`VcClient::submit`] + [`VcClient::recv`] for pipelining.
    pub fn solve(
        &mut self,
        problem: &Problem,
        opts: WireOptions,
    ) -> Result<WireSolution, ClientError> {
        let req_id = self.submit(problem, opts)?;
        loop {
            match self.recv()? {
                ServerReply::Solution(s) if s.req_id == req_id => return Ok(s),
                ServerReply::Error(e) if e.req_id == req_id || e.req_id == 0 => {
                    return Err(ClientError::Rejected(e));
                }
                // A stale stats scrape or another request's reply.
                _ => continue,
            }
        }
    }

    /// Scrape the server's [`ServiceStats`] snapshot.
    pub fn stats(&mut self) -> Result<ServiceStats, ClientError> {
        wire::write_frame(&mut self.stream, &Frame::StatsRequest)?;
        loop {
            match self.recv()? {
                ServerReply::Stats(s) => return Ok(s),
                ServerReply::Error(e) if e.req_id == 0 => return Err(ClientError::Rejected(e)),
                // Solutions to in-flight submits may arrive first; they
                // are lost to this simple scrape path, so scrape on a
                // dedicated connection when pipelining.
                _ => continue,
            }
        }
    }
}

impl std::fmt::Debug for VcClient {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("VcClient").field("version", &self.version).finish_non_exhaustive()
    }
}
