//! The resident solver service: a persistent worker pool that jobs are
//! *injected into*, instead of a thread pool reconstructed around every
//! call.
//!
//! The paper (and Yamout et al.) treat the GPU workers as a resident
//! grid fed by a shared worklist; this module gives the host API the
//! same shape. A [`VcService`] is built once
//! (`VcService::builder().workers(n).scheduler(kind).build()`) and owns:
//!
//! * a **resident scheduler** (work-stealing by default) whose workers
//!   park on quiescence instead of terminating — see
//!   `sched::ResidentCtl`;
//! * one OS thread per worker, each with per-dtype [`WorkerCtx`] scratch
//!   (BFS stamps, buffer pools) that is *shared across jobs* — a small
//!   graph solved after a big one reuses the big one's recycled buffers;
//! * a monotonically increasing job-id counter.
//!
//! ## Job lifecycle
//!
//! [`VcService::submit`] wraps a [`Problem`] into a job and injects a
//! single `Setup` work item. A worker pops it, runs the preparation
//! pipeline (greedy bound → root reduction → induction → dtype/occupancy
//! selection — the "job setup" half of the old engine), and pushes the
//! job's root search node. From there the ordinary branch-and-reduce
//! node processing takes over; every node in the shared worklist carries
//! an `Arc` to its job's state (`JobCtl`: registry, global best, stop
//! flags, stats sink), which is what keeps completion, pruning, and
//! last-descendant aggregation **job-local** — the registry context ids
//! inside a node index that job's private registry, so two jobs'
//! component cascades can never interleave even though their nodes share
//! deques.
//!
//! Completion detection is a per-job outstanding-node count: every
//! pushed item increments it *before* entering the worklist, every
//! processed (or dropped) item decrements it after; the worker that
//! drives it to zero finalizes the [`Solution`] and wakes the waiters.
//! Cancellation ([`JobHandle::cancel`]) and the per-job deadline
//! ([`JobOptions::timeout`]) latch the job's `stop` flag: queued nodes
//! of a stopped job are dropped on pop, so a cancelled job drains at
//! pop speed without touching other jobs.
//!
//! Many small jobs therefore run concurrently with one large branching
//! job on the same pool: the large job's nodes fill the deques, a small
//! job's setup + nodes interleave via the shared injector, and idle
//! workers steal whatever is oldest.
//!
//! Jobs submitted with [`JobOptions::extract_witness`] additionally get
//! an actual solution vertex set back: nodes of the job carry choice
//! logs, the job's private registry reassembles component covers at
//! last-descendant aggregation, and finalization lifts the winning
//! cover to original ids (induction renumbering + reduction unwind) and
//! verifies it against the original graph ([`Solution::witness`],
//! [`Solution::witness_verified`]).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::degree::{DegElem, Dtype};
use crate::graph::Graph;
use crate::prep::{self, PrepConfig};

use super::engine::{self, EngineStats, JobCfg, JobCtl, JobView, NodePayload, WorkerCtx};
use super::sched::{
    IdleOutcome, PopSource, Scheduler, SchedulerKind, ShardedScheduler, WorkStealScheduler,
    WorkerCounters, WorkerHandle,
};
use super::witness::{self, CoverLift};
use super::{greedy, PrepSummary, SolverConfig};

/// A problem submitted to the service. Graphs are `Arc`-shared so a
/// batch driver can submit the same graph under several parameters
/// without copying it.
#[derive(Debug, Clone)]
pub enum Problem {
    /// Minimum vertex cover.
    Mvc {
        /// The input graph.
        g: Arc<Graph>,
    },
    /// Parameterized vertex cover: is there a cover of size ≤ `k`?
    Pvc {
        /// The input graph.
        g: Arc<Graph>,
        /// The cover-size budget.
        k: u32,
    },
    /// Maximum independent set (solved as `|V| − MVC`).
    Mis {
        /// The input graph.
        g: Arc<Graph>,
    },
}

impl Problem {
    /// A minimum-vertex-cover problem.
    pub fn mvc(g: impl Into<Arc<Graph>>) -> Problem {
        Problem::Mvc { g: g.into() }
    }

    /// A parameterized-vertex-cover problem (`∃ cover ≤ k?`).
    pub fn pvc(g: impl Into<Arc<Graph>>, k: u32) -> Problem {
        Problem::Pvc { g: g.into(), k }
    }

    /// A maximum-independent-set problem.
    pub fn mis(g: impl Into<Arc<Graph>>) -> Problem {
        Problem::Mis { g: g.into() }
    }

    /// The input graph.
    pub fn graph(&self) -> &Arc<Graph> {
        match self {
            Problem::Mvc { g } | Problem::Pvc { g, .. } | Problem::Mis { g } => g,
        }
    }

    /// The problem kind tag.
    pub fn kind(&self) -> ProblemKind {
        match self {
            Problem::Mvc { .. } => ProblemKind::Mvc,
            Problem::Pvc { .. } => ProblemKind::Pvc,
            Problem::Mis { .. } => ProblemKind::Mis,
        }
    }
}

/// Which problem a [`Solution`] answers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProblemKind {
    /// Minimum vertex cover.
    Mvc,
    /// Parameterized vertex cover.
    Pvc,
    /// Maximum independent set.
    Mis,
}

/// Why a job stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Termination {
    /// The search ran to completion (for PVC this includes stopping at
    /// the first cover ≤ k, which answers the decision problem).
    Complete,
    /// The per-job deadline fired; the reported objective is only a
    /// bound (upper for MVC, lower for MIS; PVC may report infeasible
    /// without proof).
    DeadlineExpired,
    /// [`JobHandle::cancel`] was called before the search finished.
    Cancelled,
    /// A worker panicked while running this job (internal error). The
    /// panic is contained — the pool and other jobs are unaffected, and
    /// `wait` still returns — but this job's objective/stats are not
    /// trustworthy. The one-shot shims turn this back into a panic to
    /// preserve the old loud-failure contract.
    Failed,
}

/// Unified result of any [`Problem`] — replaces the old
/// `SolveResult`/`PvcResult`/`MisResult` triplet at the service layer
/// (the one-shot shims still expose the legacy structs).
#[derive(Debug, Clone)]
pub struct Solution {
    /// Which problem this answers.
    pub problem: ProblemKind,
    /// MVC: cover size (an upper bound if not [`Termination::Complete`]).
    /// MIS: independence number (lower bound if not complete).
    /// PVC: size of the found cover when `feasible`, else `k + 1`.
    pub objective: u32,
    /// PVC: whether a cover of size ≤ k was found (`false` under
    /// deadline/cancel means "unknown", mirroring `PvcResult::found`).
    /// Always `true` for MVC/MIS.
    pub feasible: bool,
    /// Witness vertex set, populated when the job was submitted with
    /// [`JobOptions::extract_witness`]: the cover (MVC/PVC) or
    /// independent set (MIS) in *original* vertex ids, assembled from
    /// the engine's per-node choice logs and lifted through the
    /// induction renumbering and root-reduction unwind. `|witness| ==
    /// objective` for MVC/MIS; for PVC it is a cover with `|witness| ≤
    /// k` (equal to `objective` except when an est-propagated bound
    /// beat the assembled cover to the early stop).
    pub witness: Option<Vec<u32>>,
    /// Whether the extracted witness verified edge-by-edge against the
    /// original graph (`solver::witness`); `None` when no witness was
    /// requested or produced.
    pub witness_verified: Option<bool>,
    /// Engine counters for this job only.
    pub stats: EngineStats,
    /// Preparation summary (root reduction, dtype, occupancy).
    pub prep: PrepSummary,
    /// Wall-clock time from submission to finalization.
    pub elapsed: Duration,
    /// Why the job stopped.
    pub termination: Termination,
}

impl Solution {
    /// True if the job's deadline fired (legacy `timed_out` spelling).
    pub fn timed_out(&self) -> bool {
        self.termination == Termination::DeadlineExpired
    }
}

/// Per-job submission options.
#[derive(Debug, Clone, Default)]
pub struct JobOptions {
    /// Per-job wall-clock budget (falls back to the service config's
    /// timeout when `None`).
    pub timeout: Option<Duration>,
    /// Per-job solver knobs (component awareness, root reduction,
    /// bounds, dtypes, induce threshold) overriding the service
    /// defaults. The pool-shape fields (`variant`, `workers`,
    /// `scheduler`) are ignored — the resident pool is fixed at build.
    pub config: Option<SolverConfig>,
    /// Return an actual witness in [`Solution::witness`]: the engine
    /// carries per-node choice logs for this job and reassembles the
    /// winning cover at last-descendant aggregation. Costs one extra
    /// pooled buffer per node plus a lock per leaf report; off by
    /// default. A `config` with `extract_cover` set requests the same
    /// thing.
    pub extract_witness: bool,
}

/// A submitted job: await it, poll it, or cancel it. Cloning the handle
/// is cheap; all clones observe the same job.
#[derive(Clone)]
pub struct JobHandle {
    job: Arc<JobInner>,
}

impl JobHandle {
    /// The service-unique job id.
    pub fn id(&self) -> u64 {
        self.job.id
    }

    /// Block until the job finalizes and return its solution.
    pub fn wait(&self) -> Solution {
        let mut out = self.job.outcome.lock().unwrap();
        loop {
            if let Some(sol) = out.as_ref() {
                return sol.clone();
            }
            out = self.job.done_cv.wait(out).unwrap();
        }
    }

    /// Non-blocking poll: the solution if the job already finalized.
    pub fn try_result(&self) -> Option<Solution> {
        self.job.outcome.lock().unwrap().as_ref().cloned()
    }

    /// Request cancellation. Queued nodes of the job are dropped as they
    /// surface; `wait` then returns with [`Termination::Cancelled`].
    /// Cancelling a finished job is a no-op.
    pub fn cancel(&self) {
        // Order matters: the flag that *labels* the stop must be set
        // before the flag that *causes* it, so finalization can't read
        // a stop with no recorded reason.
        self.job.cancelled.store(true, Ordering::SeqCst);
        self.job.ctl.stop.store(true, Ordering::SeqCst);
    }
}

impl std::fmt::Debug for JobHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobHandle")
            .field("id", &self.job.id)
            .field("done", &self.try_result().is_some())
            .finish()
    }
}

/// Job-prep results published by the `Setup` work item (read by every
/// subsequent node of the job).
struct JobPrep {
    /// The residual (root-reduced, induced) graph the search runs on.
    graph: Arc<Graph>,
    /// Residual-relative initial upper bound handed to the engine.
    initial: u32,
    /// Vertices forced into the cover at the root.
    forced: u32,
    /// Greedy upper bound on the original graph.
    greedy_ub: u32,
    /// PVC: residual budget `k − forced` (when the search ran).
    k_resid: Option<u32>,
    /// Witness lift (induction map + reduction unwind), kept only for
    /// extracting jobs so finalization can translate the assembled
    /// residual cover back to original vertex ids.
    lift: Option<CoverLift>,
    /// Prep summary for the solution.
    summary: PrepSummary,
    /// Payload bytes of the root node (charged at finalization, like the
    /// one-shot runner charges its out-of-worker root).
    root_bytes: u64,
    /// Whether a root node entered the worklist (false for jobs decided
    /// at prep: trivial PVC answers, pre-expired deadlines, cancels).
    root_pushed: bool,
    /// PVC decided during prep, before any search node existed.
    decided: Option<PvcDecided>,
}

/// PVC answers that fall out of the preparation stage.
enum PvcDecided {
    /// The greedy bound already satisfies k.
    FoundGreedy(u32),
    /// More than k vertices are forced at the root: no cover ≤ k.
    Infeasible,
}

/// Shared state of one job. Nodes in the worklist hold an `Arc` to this
/// — that Arc *is* the job id the issue's registry scoping refers to:
/// each job owns a private registry (inside `ctl`), so context ids in a
/// node are meaningful only together with the job pointer riding next to
/// them.
struct JobInner {
    id: u64,
    problem: Problem,
    /// Registry, global best, stop/improved/timed-out flags, stats sink.
    ctl: JobCtl,
    prep_cfg: PrepConfig,
    /// Outstanding work items (setup + queued/executing nodes). The
    /// decrement-to-zero owner finalizes the job.
    live_nodes: AtomicU64,
    cancelled: AtomicBool,
    /// A worker panicked while running this job's setup or a node.
    failed: AtomicBool,
    prepared: OnceLock<JobPrep>,
    outcome: Mutex<Option<Solution>>,
    done_cv: Condvar,
    started: Instant,
    pool_workers: usize,
    /// The service's shared stats accumulators — finalization folds this
    /// job's engine counters into its class slot.
    counters: Arc<ServiceCounters>,
}

/// One unit of service work: either a job's setup stage or one search
/// node (dtype-erased so jobs of different degree dtypes share queues).
struct WorkItem {
    job: Arc<JobInner>,
    work: Work,
}

enum Work {
    Setup,
    Node(AnyNode),
}

/// Dtype-erased search node (§IV-D: each job picks the smallest dtype
/// that fits its max degree; the shared worklist must carry them all).
/// Each variant is a [`NodePayload`] — an owned payload or a delta
/// right child, per the job's `node_repr`.
enum AnyNode {
    U8(NodePayload<u8>),
    U16(NodePayload<u16>),
    U32(NodePayload<u32>),
}

impl From<NodePayload<u8>> for AnyNode {
    fn from(n: NodePayload<u8>) -> AnyNode {
        AnyNode::U8(n)
    }
}
impl From<NodePayload<u16>> for AnyNode {
    fn from(n: NodePayload<u16>) -> AnyNode {
        AnyNode::U16(n)
    }
}
impl From<NodePayload<u32>> for AnyNode {
    fn from(n: NodePayload<u32>) -> AnyNode {
        AnyNode::U32(n)
    }
}

/// The resident scheduler, selected at build time.
enum ResidentSched {
    Steal(WorkStealScheduler<WorkItem>),
    Sharded(ShardedScheduler<WorkItem>),
}

impl ResidentSched {
    fn inject(&self, item: WorkItem) {
        match self {
            ResidentSched::Steal(s) => s.inject(item),
            ResidentSched::Sharded(s) => s.inject(item),
        }
    }

    fn request_shutdown(&self) {
        match self {
            ResidentSched::Steal(s) => s.request_shutdown(),
            ResidentSched::Sharded(s) => s.request_shutdown(),
        }
    }

    fn parks(&self) -> u64 {
        match self {
            ResidentSched::Steal(s) => s.parks(),
            ResidentSched::Sharded(s) => s.parks(),
        }
    }
}

/// Pool-level scheduler counters surfaced by [`VcService::stats`]:
/// queue traffic and park events aggregated over every resident worker.
/// Nodes of all job classes share the same deques, so these are
/// pool-wide; the per-class breakdown lives in [`ClassStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Children enqueued by the pool's workers.
    pub pushes: u64,
    /// Nodes taken from a worker's own queue.
    pub pops: u64,
    /// Nodes taken from the shared entry queue.
    pub shared_pops: u64,
    /// Nodes taken from another worker (cross-worker steals).
    pub steals: u64,
    /// Steal attempts that lost a race and retried.
    pub steal_retries: u64,
    /// Worker park events (an idle pool parks; a saturated one never
    /// does — the service QoS "is the pool starved or drowning" signal).
    pub parks: u64,
}

/// Per-job-class counters surfaced by [`VcService::stats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClassStats {
    /// Jobs of this class finalized.
    pub jobs: u64,
    /// Work items of this class acquired via cross-worker steals.
    pub steals: u64,
    /// Search-tree nodes visited for this class.
    pub tree_nodes: u64,
    /// Delta right children pushed for this class (delta node
    /// representation only).
    pub delta_children: u64,
    /// Delta nodes consumed on the in-place undo fast path.
    pub undo_pops: u64,
    /// Delta nodes materialized into owned payloads (stolen/foreign).
    pub materializations: u64,
}

/// Aggregate scheduler/engine telemetry of a running service (the
/// ROADMAP "Service QoS" counters endpoint).
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    /// Pool-wide queue traffic and park events.
    pub pool: PoolStats,
    /// MVC-class jobs.
    pub mvc: ClassStats,
    /// PVC-class jobs.
    pub pvc: ClassStats,
    /// MIS-class jobs.
    pub mis: ClassStats,
}

impl ServiceStats {
    /// The per-class counters for `kind`.
    pub fn class(&self, kind: ProblemKind) -> &ClassStats {
        match kind {
            ProblemKind::Mvc => &self.mvc,
            ProblemKind::Pvc => &self.pvc,
            ProblemKind::Mis => &self.mis,
        }
    }
}

/// Internal atomic accumulators behind [`ServiceStats`].
#[derive(Default)]
struct ClassAgg {
    jobs: AtomicU64,
    steals: AtomicU64,
    tree_nodes: AtomicU64,
    delta_children: AtomicU64,
    undo_pops: AtomicU64,
    materializations: AtomicU64,
}

impl ClassAgg {
    fn snapshot(&self) -> ClassStats {
        ClassStats {
            jobs: self.jobs.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            tree_nodes: self.tree_nodes.load(Ordering::Relaxed),
            delta_children: self.delta_children.load(Ordering::Relaxed),
            undo_pops: self.undo_pops.load(Ordering::Relaxed),
            materializations: self.materializations.load(Ordering::Relaxed),
        }
    }
}

/// Shared atomic counter block: workers flush queue-traffic deltas into
/// the pool half, finalization folds each job's engine stats into its
/// class half. `Arc`-shared between the service and every job so
/// finalize (which only sees the job) can attribute per-class counts.
#[derive(Default)]
struct ServiceCounters {
    pushes: AtomicU64,
    pops: AtomicU64,
    shared_pops: AtomicU64,
    steals: AtomicU64,
    steal_retries: AtomicU64,
    classes: [ClassAgg; 3],
}

impl ServiceCounters {
    fn class(&self, kind: ProblemKind) -> &ClassAgg {
        match kind {
            ProblemKind::Mvc => &self.classes[0],
            ProblemKind::Pvc => &self.classes[1],
            ProblemKind::Mis => &self.classes[2],
        }
    }

    /// Fold the delta of a worker's counters since its last flush.
    fn flush_worker(&self, now: &WorkerCounters, flushed: &mut WorkerCounters) {
        self.pushes.fetch_add(now.pushes - flushed.pushes, Ordering::Relaxed);
        self.pops.fetch_add(now.pops - flushed.pops, Ordering::Relaxed);
        self.shared_pops.fetch_add(now.shared_pops - flushed.shared_pops, Ordering::Relaxed);
        self.steals.fetch_add(now.steals - flushed.steals, Ordering::Relaxed);
        self.steal_retries
            .fetch_add(now.steal_retries - flushed.steal_retries, Ordering::Relaxed);
        *flushed = *now;
    }
}

struct ServiceInner {
    sched: ResidentSched,
    defaults: SolverConfig,
    workers: usize,
    next_job: AtomicU64,
    counters: Arc<ServiceCounters>,
}

/// Builder for [`VcService`].
pub struct VcServiceBuilder {
    workers: Option<usize>,
    scheduler: SchedulerKind,
    queue_capacity: usize,
    defaults: SolverConfig,
}

impl VcServiceBuilder {
    /// Number of resident worker threads (default: hardware threads).
    pub fn workers(mut self, n: usize) -> VcServiceBuilder {
        self.workers = Some(n.max(1));
        self
    }

    /// Scheduling runtime for the shared pool (default: work stealing).
    pub fn scheduler(mut self, kind: SchedulerKind) -> VcServiceBuilder {
        self.scheduler = kind;
        self
    }

    /// Initial per-worker queue capacity.
    pub fn queue_capacity(mut self, cap: usize) -> VcServiceBuilder {
        self.queue_capacity = cap.max(8);
        self
    }

    /// Default solver knobs applied to every job (component awareness,
    /// root reduction, bounds, dtypes, induce threshold, default
    /// timeout). The `variant`/`workers`/`scheduler` fields of the
    /// config are ignored — the pool shape is the builder's business.
    pub fn config(mut self, cfg: SolverConfig) -> VcServiceBuilder {
        self.defaults = cfg;
        self
    }

    /// Spawn the worker pool and return the service.
    pub fn build(self) -> VcService {
        let workers = self.workers.unwrap_or_else(|| {
            std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4)
        });
        let sched = match self.scheduler {
            SchedulerKind::WorkSteal => {
                ResidentSched::Steal(WorkStealScheduler::new_resident(workers, self.queue_capacity))
            }
            SchedulerKind::Sharded => ResidentSched::Sharded(ShardedScheduler::new_resident(
                workers,
                self.queue_capacity,
            )),
        };
        let inner = Arc::new(ServiceInner {
            sched,
            defaults: self.defaults,
            workers,
            next_job: AtomicU64::new(0),
            counters: Arc::new(ServiceCounters::default()),
        });
        let threads = (0..workers)
            .map(|w| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("cavc-svc-{w}"))
                    .spawn(move || match &inner.sched {
                        ResidentSched::Steal(s) => resident_loop(s, w, &inner.counters),
                        ResidentSched::Sharded(s) => resident_loop(s, w, &inner.counters),
                    })
                    .expect("spawn service worker")
            })
            .collect();
        VcService { inner, threads }
    }
}

/// A resident vertex-cover solver service (see the module docs).
///
/// Dropping the service requests shutdown and joins the workers after
/// they drain every outstanding job — held [`JobHandle`]s stay valid and
/// their `wait` calls return.
pub struct VcService {
    inner: Arc<ServiceInner>,
    threads: Vec<JoinHandle<()>>,
}

impl VcService {
    /// Start building a service.
    pub fn builder() -> VcServiceBuilder {
        VcServiceBuilder {
            workers: None,
            scheduler: SchedulerKind::default(),
            queue_capacity: engine::DEFAULT_QUEUE_CAPACITY,
            defaults: SolverConfig::proposed(),
        }
    }

    /// Number of resident worker threads.
    pub fn workers(&self) -> usize {
        self.inner.workers
    }

    /// Submit a problem with the service's default options.
    pub fn submit(&self, problem: Problem) -> JobHandle {
        self.submit_with(problem, JobOptions::default())
    }

    /// Submit a problem with per-job options.
    pub fn submit_with(&self, problem: Problem, opts: JobOptions) -> JobHandle {
        let cfg = opts.config.as_ref().unwrap_or(&self.inner.defaults);
        let job_cfg = JobCfg {
            component_aware: cfg.component_aware,
            use_bounds: cfg.use_bounds,
            stop_on_improvement: matches!(problem, Problem::Pvc { .. }),
            deadline: opts.timeout.or(cfg.timeout).map(|t| Instant::now() + t),
            // Per-activity timers are per-worker, not per-job; resident
            // jobs track counters (incl. byte accounting) only.
            instrument: false,
            induce_threshold: cfg.induce_threshold,
            extract_witness: opts.extract_witness || cfg.extract_cover,
            node_repr: cfg.node_repr,
            max_pin_depth: cfg.max_pin_depth,
        };
        let job = Arc::new(JobInner {
            id: self.inner.next_job.fetch_add(1, Ordering::SeqCst),
            ctl: JobCtl::new(job_cfg, u32::MAX),
            prep_cfg: cfg.prep_cfg(),
            live_nodes: AtomicU64::new(1), // the Setup item
            cancelled: AtomicBool::new(false),
            failed: AtomicBool::new(false),
            prepared: OnceLock::new(),
            outcome: Mutex::new(None),
            done_cv: Condvar::new(),
            started: Instant::now(),
            pool_workers: self.inner.workers,
            counters: Arc::clone(&self.inner.counters),
            problem,
        });
        self.inner.sched.inject(WorkItem { job: Arc::clone(&job), work: Work::Setup });
        JobHandle { job }
    }

    /// Submit-and-wait convenience for one problem.
    pub fn solve(&self, problem: Problem) -> Solution {
        self.submit(problem).wait()
    }

    /// Snapshot the pool-level scheduler counters and the per-job-class
    /// breakdown (steals / parks / materializations…): the ROADMAP
    /// "Service QoS" telemetry endpoint. Pool counters are flushed by
    /// workers on idle transitions and every 256 processed items, so a
    /// snapshot taken mid-burst can trail the true totals slightly;
    /// class counters for *finalized* jobs are exact.
    pub fn stats(&self) -> ServiceStats {
        let c = &self.inner.counters;
        ServiceStats {
            pool: PoolStats {
                pushes: c.pushes.load(Ordering::Relaxed),
                pops: c.pops.load(Ordering::Relaxed),
                shared_pops: c.shared_pops.load(Ordering::Relaxed),
                steals: c.steals.load(Ordering::Relaxed),
                steal_retries: c.steal_retries.load(Ordering::Relaxed),
                parks: self.inner.sched.parks(),
            },
            mvc: c.classes[0].snapshot(),
            pvc: c.classes[1].snapshot(),
            mis: c.classes[2].snapshot(),
        }
    }
}

impl Drop for VcService {
    fn drop(&mut self) {
        self.inner.sched.request_shutdown();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl std::fmt::Debug for VcService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("VcService").field("workers", &self.inner.workers).finish()
    }
}

/// The process-wide default service used by the `solve_mvc`/`solve_pvc`
/// one-shot shims for service-compatible configurations. Built lazily on
/// first use with hardware-thread workers; lives for the process (idle
/// cost is a few parked-timeout wakeups per second).
pub fn default_service() -> &'static VcService {
    static DEFAULT: OnceLock<VcService> = OnceLock::new();
    DEFAULT.get_or_init(|| VcService::builder().build())
}

// ---------------------------------------------------------------------
// Resident worker loop
// ---------------------------------------------------------------------

/// Per-worker, per-dtype engine scratch, persistent across jobs.
struct Scratch {
    u8: WorkerCtx<u8>,
    u16: WorkerCtx<u16>,
    u32: WorkerCtx<u32>,
}

impl Scratch {
    fn new(worker: usize) -> Scratch {
        Scratch {
            u8: WorkerCtx::new(worker, 0, false),
            u16: WorkerCtx::new(worker, 0, false),
            u32: WorkerCtx::new(worker, 0, false),
        }
    }
}

fn resident_loop<S: Scheduler<WorkItem>>(sched: &S, worker: usize, counters: &ServiceCounters) {
    let mut scratch = Scratch::new(worker);
    let mut handle = sched.handle(worker);
    let mut flushed = WorkerCounters::default();
    let mut since_flush = 0u32;
    loop {
        match handle.pop_traced() {
            Some((item, src)) => {
                if src == PopSource::Stolen {
                    // Steals *are* attributable to a class: the stolen
                    // item carries its job.
                    counters
                        .class(item.job.problem.kind())
                        .steals
                        .fetch_add(1, Ordering::Relaxed);
                }
                process_item(item, &mut scratch, &mut handle, src);
                handle.on_node_done();
                since_flush += 1;
                if since_flush >= 256 {
                    counters.flush_worker(&handle.counters(), &mut flushed);
                    since_flush = 0;
                }
            }
            None => {
                counters.flush_worker(&handle.counters(), &mut flushed);
                since_flush = 0;
                // An idle worker's suspended delta frames are
                // unreachable (no queued item can match them anymore);
                // recycle them so a finished big job's frames don't
                // stay resident across unrelated later jobs.
                scratch.u8.drain_descents();
                scratch.u16.drain_descents();
                scratch.u32.drain_descents();
                if let IdleOutcome::Finished = handle.idle_step() {
                    return;
                }
            }
        }
    }
}

fn process_item<H: WorkerHandle<WorkItem>>(
    item: WorkItem,
    scratch: &mut Scratch,
    handle: &mut H,
    src: PopSource,
) {
    let WorkItem { job, work } = item;
    // Contain panics (debug assertions, engine bugs): the one-shot
    // engine propagates them through `thread::scope`, but a resident
    // worker must survive — an escaped panic here would kill the thread
    // with the live-count decrement below unexecuted, hanging every
    // `wait` on the job. The scratch stays structurally valid across an
    // unwind (plain buffers and counters), so it may keep serving other
    // jobs.
    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match work {
        Work::Setup => setup_job(&job, handle),
        Work::Node(node) => {
            job.ctl.check_deadline();
            // A stopped job (cancelled, past-deadline, or PVC already
            // answered) drops its node here; the decrement below still
            // counts it, so the job drains to finalization at pop speed.
            if !job.ctl.stop.load(Ordering::SeqCst) {
                let p = job.prepared.get().expect("node processed before its job's setup");
                match node {
                    AnyNode::U8(n) => run_node(&job, p, n, &mut scratch.u8, handle, src),
                    AnyNode::U16(n) => run_node(&job, p, n, &mut scratch.u16, handle, src),
                    AnyNode::U32(n) => run_node(&job, p, n, &mut scratch.u32, handle, src),
                }
            }
        }
    }));
    if run.is_err() {
        // Label first, then stop (same ordering argument as `cancel`):
        // the job's remaining nodes drain as drops and the normal
        // completion count finalizes it with `Termination::Failed`.
        job.failed.store(true, Ordering::SeqCst);
        job.ctl.stop.store(true, Ordering::SeqCst);
    }
    if job.live_nodes.fetch_sub(1, Ordering::SeqCst) == 1 {
        // `finalize` itself can assert (debug registry invariants); a
        // panic there must not leave waiters hanging either.
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| finalize(&job))).is_err() {
            job.failed.store(true, Ordering::SeqCst);
            store_outcome(&job, failed_solution(&job));
        }
    }
}

/// Run one search node of a job through the engine's node processor,
/// wrapping the pool handle so children are re-tagged with the job.
fn run_node<T: DegElem, H: WorkerHandle<WorkItem>>(
    job: &Arc<JobInner>,
    p: &JobPrep,
    node: NodePayload<T>,
    ctx: &mut WorkerCtx<T>,
    handle: &mut H,
    src: PopSource,
) where
    AnyNode: From<NodePayload<T>>,
{
    ctx.ensure_graph(p.graph.num_vertices());
    let view = JobView { g: p.graph.as_ref(), ctl: &job.ctl };
    let mut push = JobPush { job, inner: handle };
    engine::process(&view, ctx, &mut push, node, src);
    // Flush per item, not per job-switch: any decrement of the job's
    // live count may be the final one, and the finalizing worker must
    // observe complete stats in the sink. The lock is per *descent*
    // (one pop may expand a whole left spine), so it amortizes over
    // many tree nodes — cheaper than the sharded runtime's two RMWs
    // per node, which the benches accept as the baseline.
    ctx.flush_stats_into(&job.ctl);
}

/// Push-only [`WorkerHandle`] adapter: the engine's node processor sees
/// a typed handle, the pool sees job-tagged [`WorkItem`]s.
struct JobPush<'a, H> {
    job: &'a Arc<JobInner>,
    inner: &'a mut H,
}

impl<T: DegElem, H: WorkerHandle<WorkItem>> WorkerHandle<NodePayload<T>> for JobPush<'_, H>
where
    AnyNode: From<NodePayload<T>>,
{
    fn push(&mut self, item: NodePayload<T>) {
        // Increment before the item becomes visible so the job's live
        // count can never reach zero while a node sits in a queue.
        self.job.live_nodes.fetch_add(1, Ordering::SeqCst);
        self.inner
            .push(WorkItem { job: Arc::clone(self.job), work: Work::Node(AnyNode::from(item)) });
    }

    fn pop_traced(&mut self) -> Option<(NodePayload<T>, PopSource)> {
        unreachable!("job adapter is push-only; the resident loop owns pops")
    }

    fn on_node_done(&mut self) {
        unreachable!("job adapter is push-only; the resident loop owns node accounting")
    }

    fn idle_step(&mut self) -> IdleOutcome {
        unreachable!("job adapter is push-only; the resident loop owns idling")
    }

    fn counters(&self) -> WorkerCounters {
        WorkerCounters::default()
    }
}

/// The job-setup stage, run on a worker: preparation pipeline, initial
/// bound, trivial answers, and the root-node push.
fn setup_job<H: WorkerHandle<WorkItem>>(job: &Arc<JobInner>, handle: &mut H) {
    let g: &Graph = job.problem.graph();
    let (p, k) = match &job.problem {
        // ub = k+1 keeps the high-degree rule sound for covers ≤ k.
        Problem::Pvc { k, .. } => {
            (prep::prepare(g, &job.prep_cfg, Some(k.saturating_add(1))), Some(*k))
        }
        _ => (prep::prepare(g, &job.prep_cfg, None), None),
    };
    let forced = p.forced_cover.len() as u32;
    let n_resid = p.residual.graph.num_vertices();
    let summary = PrepSummary {
        n_original: g.num_vertices(),
        n_residual: n_resid,
        forced: forced as usize,
        greedy_ub: p.greedy_ub,
        dtype: p.dtype,
        blocks: p.occupancy.blocks,
        fits_shared_mem: p.occupancy.fits_shared_mem,
        workers: job.pool_workers,
    };

    let (initial, k_resid, decided) = match k {
        None => (p.residual_ub, None, None),
        Some(k) => {
            if p.greedy_ub <= k {
                (0, None, Some(PvcDecided::FoundGreedy(p.greedy_ub)))
            } else if forced > k {
                (0, None, Some(PvcDecided::Infeasible))
            } else {
                let k_resid = k - forced;
                ((k_resid + 1).min(n_resid as u32 + 1), Some(k_resid), None)
            }
        }
    };

    // The lift must be captured before the residual graph moves into
    // the job (it clones the induction map + reduction unwind).
    let lift = job.ctl.cfg.extract_witness.then(|| p.cover_lift());
    let graph = Arc::new(p.residual.graph);
    // Publish the bound before any node can observe it (the root is
    // pushed below, after the store). `initial` doubles as the
    // reference for the witnessed-stop gate.
    job.ctl.best.store(initial, Ordering::SeqCst);
    job.ctl.initial.store(initial, Ordering::SeqCst);

    // A job stopped before its search begins (trivial PVC answer,
    // pre-expired deadline, early cancel) pushes no root.
    job.ctl.check_deadline();
    let start_search = decided.is_none() && !job.ctl.stop.load(Ordering::SeqCst);
    let (root, root_bytes) = if start_search {
        let root = match p.dtype {
            Dtype::U8 => AnyNode::U8(NodePayload::Owned(engine::make_root::<u8>(&graph))),
            Dtype::U16 => AnyNode::U16(NodePayload::Owned(engine::make_root::<u16>(&graph))),
            Dtype::U32 => AnyNode::U32(NodePayload::Owned(engine::make_root::<u32>(&graph))),
        };
        let bytes = match &root {
            AnyNode::U8(n) => n.payload_bytes(),
            AnyNode::U16(n) => n.payload_bytes(),
            AnyNode::U32(n) => n.payload_bytes(),
        };
        (Some(root), bytes)
    } else {
        (None, 0)
    };

    let prep_record = JobPrep {
        graph,
        initial,
        forced,
        greedy_ub: p.greedy_ub,
        k_resid,
        lift,
        summary,
        root_bytes,
        root_pushed: root.is_some(),
        decided,
    };
    // Publish prep before the root enters the worklist: any worker that
    // pops a node of this job must see it.
    let _ = job.prepared.set(prep_record);

    if let Some(root) = root {
        job.live_nodes.fetch_add(1, Ordering::SeqCst);
        handle.push(WorkItem { job: Arc::clone(job), work: Work::Node(root) });
    }
}

/// Publish a finished job's solution (first writer wins) and wake the
/// waiters.
fn store_outcome(job: &Arc<JobInner>, solution: Solution) {
    let mut out = job.outcome.lock().unwrap();
    if out.is_none() {
        *out = Some(solution);
    }
    job.done_cv.notify_all();
}

/// Degenerate outcome for a job whose setup or finalization panicked:
/// no trustworthy objective, but `wait` must still return.
fn failed_solution(job: &Arc<JobInner>) -> Solution {
    let g = job.problem.graph();
    let prep = match job.prepared.get() {
        Some(p) => p.summary.clone(),
        None => PrepSummary {
            n_original: g.num_vertices(),
            n_residual: 0,
            forced: 0,
            greedy_ub: 0,
            dtype: Dtype::U32,
            blocks: 0,
            fits_shared_mem: false,
            workers: job.pool_workers,
        },
    };
    Solution {
        problem: job.problem.kind(),
        objective: 0,
        feasible: false,
        witness: None,
        witness_verified: None,
        stats: EngineStats::default(),
        prep,
        elapsed: job.started.elapsed(),
        termination: Termination::Failed,
    }
}

/// Assemble the [`Solution`] once the job's last work item retired; the
/// caller observed `live_nodes` hit zero, so it owns the continuation.
fn finalize(job: &Arc<JobInner>) {
    let termination = if job.failed.load(Ordering::SeqCst) {
        Termination::Failed
    } else if job.cancelled.load(Ordering::SeqCst) {
        Termination::Cancelled
    } else if job.ctl.timed_out.load(Ordering::SeqCst) {
        Termination::DeadlineExpired
    } else {
        Termination::Complete
    };
    let Some(p) = job.prepared.get() else {
        // Setup panicked before publishing prep: degenerate outcome.
        store_outcome(job, failed_solution(job));
        return;
    };

    #[cfg(debug_assertions)]
    {
        // A fully-explored search must have drained its registry (PVC
        // early stop and cancelled/timed-out jobs legitimately leave
        // live entries behind).
        if termination == Termination::Complete && !job.ctl.stop.load(Ordering::SeqCst) {
            job.ctl.registry.assert_drained();
        }
    }

    let mut stats = job.ctl.stats_sink.lock().unwrap().clone();
    stats.registry_entries = job.ctl.registry.len() as u64;
    if p.root_pushed {
        // The root payload was created in setup, outside any descent.
        stats.payload_nodes += 1;
        stats.payload_bytes += p.root_bytes;
    }
    // Fold this job's engine counters into the service's per-class
    // telemetry ([`VcService::stats`]).
    let agg = job.counters.class(job.problem.kind());
    agg.jobs.fetch_add(1, Ordering::Relaxed);
    agg.tree_nodes.fetch_add(stats.tree_nodes, Ordering::Relaxed);
    agg.delta_children.fetch_add(stats.delta_children, Ordering::Relaxed);
    agg.undo_pops.fetch_add(stats.undo_pops, Ordering::Relaxed);
    agg.materializations.fetch_add(stats.materializations, Ordering::Relaxed);

    let best_resid = job.ctl.best.load(Ordering::SeqCst);
    let improved = job.ctl.improved.load(Ordering::SeqCst);
    // The engine's assembled residual witness, lifted to original ids
    // (extracting jobs only; decided-at-prep jobs never searched).
    let extract = job.ctl.cfg.extract_witness;
    let lifted: Option<Vec<u32>> = job
        .ctl
        .registry
        .take_root_witness()
        .and_then(|w| p.lift.as_ref().map(|lift| lift.lift(&w)));
    let g_orig = job.problem.graph();
    let (objective, feasible, witness) = match (&job.problem, &p.decided) {
        (Problem::Pvc { .. }, Some(PvcDecided::FoundGreedy(s))) => {
            let w = extract.then(|| greedy::greedy_cover(g_orig));
            (*s, true, w)
        }
        (Problem::Pvc { k, .. }, Some(PvcDecided::Infeasible)) => {
            (k.saturating_add(1), false, None)
        }
        (Problem::Pvc { k, .. }, None) => {
            let k_resid = p.k_resid.expect("searched PVC has a residual budget");
            let found = improved && best_resid <= k_resid;
            if found {
                // The assembled cover always respects k (extraction
                // gates early stop on assembled witnesses); it may be
                // longer than the est-propagated objective.
                let w = lifted.filter(|c| c.len() as u32 <= *k);
                (p.forced + best_resid, true, w)
            } else {
                (k.saturating_add(1), false, None)
            }
        }
        (Problem::Mvc { .. }, _) | (Problem::Mis { .. }, _) => {
            let total = p.forced + best_resid.min(p.initial);
            let mvc = total.min(p.greedy_ub);
            let cover = if extract {
                witness::cover_of_record(lifted, mvc, p.greedy_ub, g_orig)
            } else {
                None
            };
            if matches!(job.problem, Problem::Mis { .. }) {
                let set = cover.map(|c| witness::complement(g_orig, &c));
                (g_orig.num_vertices() as u32 - mvc, true, set)
            } else {
                (mvc, true, cover)
            }
        }
    };
    let witness_verified = witness.as_ref().map(|w| match job.problem.kind() {
        ProblemKind::Mis => witness::verify_independent_set(g_orig, w).is_ok(),
        ProblemKind::Mvc | ProblemKind::Pvc => witness::verify_cover(g_orig, w).is_ok(),
    });

    store_outcome(
        job,
        Solution {
            problem: job.problem.kind(),
            objective,
            feasible,
            witness,
            witness_verified,
            stats,
            prep: p.summary.clone(),
            elapsed: job.started.elapsed(),
            termination,
        },
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::solver::oracle;

    #[test]
    fn single_mvc_job_matches_oracle() {
        let svc = VcService::builder().workers(2).build();
        for seed in 0..6 {
            let g = generators::erdos_renyi(18, 0.2, seed);
            let opt = oracle::mvc_size(&g);
            let sol = svc.solve(Problem::mvc(g));
            assert_eq!(sol.objective, opt, "seed {seed}");
            assert_eq!(sol.termination, Termination::Complete);
            assert!(sol.feasible);
            assert!(sol.stats.tree_nodes > 0 || sol.prep.n_residual == 0, "seed {seed}");
        }
    }

    #[test]
    fn pvc_jobs_answer_both_sides() {
        let svc = VcService::builder().workers(3).build();
        for seed in 0..6 {
            let g = generators::erdos_renyi(16, 0.22, seed);
            let opt = oracle::mvc_size(&g);
            let yes = svc.solve(Problem::pvc(g.clone(), opt));
            assert!(yes.feasible, "seed {seed} k=opt");
            assert!(yes.objective <= opt, "seed {seed}");
            let no = svc.solve(Problem::pvc(g, opt.saturating_sub(1)));
            assert!(!no.feasible, "seed {seed} k=opt-1");
            assert_eq!(no.objective, opt, "infeasible reports k+1");
        }
    }

    #[test]
    fn mis_job_complements_mvc() {
        let svc = VcService::builder().workers(2).build();
        let g = generators::petersen();
        let sol = svc.solve(Problem::mis(g));
        assert_eq!(sol.objective, 4); // α(Petersen) = 4
        assert_eq!(sol.problem, ProblemKind::Mis);
    }

    fn extract_opts() -> JobOptions {
        JobOptions { extract_witness: true, ..JobOptions::default() }
    }

    #[test]
    fn extracting_jobs_return_verified_witnesses() {
        let svc = VcService::builder().workers(3).build();
        for seed in 0..6 {
            let g = generators::union_of_random(3, 3, 6, 0.3, seed);
            let opt = oracle::mvc_size(&g);
            let sol = svc.submit_with(Problem::mvc(g.clone()), extract_opts()).wait();
            assert_eq!(sol.objective, opt, "seed {seed}");
            let w = sol.witness.as_ref().expect("MVC witness");
            assert_eq!(w.len() as u32, opt, "seed {seed}");
            assert!(g.is_vertex_cover(w), "seed {seed}");
            assert_eq!(sol.witness_verified, Some(true), "seed {seed}");
        }
    }

    #[test]
    fn extracting_pvc_and_mis_jobs() {
        let svc = VcService::builder().workers(2).build();
        for seed in 0..5 {
            let g = generators::erdos_renyi(15, 0.22, seed);
            let opt = oracle::mvc_size(&g);
            let pvc = svc.submit_with(Problem::pvc(g.clone(), opt), extract_opts()).wait();
            assert!(pvc.feasible, "seed {seed}");
            let w = pvc.witness.as_ref().expect("PVC witness");
            assert!(w.len() as u32 <= opt, "seed {seed}");
            assert!(g.is_vertex_cover(w), "seed {seed}");
            assert_eq!(pvc.witness_verified, Some(true), "seed {seed}");

            let mis = svc.submit_with(Problem::mis(g.clone()), extract_opts()).wait();
            let n = g.num_vertices() as u32;
            assert_eq!(mis.objective, n - opt, "seed {seed}");
            let set = mis.witness.as_ref().expect("MIS witness");
            assert_eq!(set.len() as u32, mis.objective, "seed {seed}");
            assert_eq!(mis.witness_verified, Some(true), "seed {seed}");
        }
    }

    #[test]
    fn config_extract_cover_requests_witness() {
        // a per-job SolverConfig with extract_cover set is equivalent to
        // JobOptions::extract_witness (the one-shot shims rely on it)
        let svc = VcService::builder().workers(1).build();
        let mut cfg = SolverConfig::proposed();
        cfg.extract_cover = true;
        let g = generators::petersen();
        let opts = JobOptions { config: Some(cfg), ..JobOptions::default() };
        let sol = svc.submit_with(Problem::mvc(g.clone()), opts).wait();
        assert_eq!(sol.objective, 6);
        let w = sol.witness.expect("config.extract_cover requests a witness");
        assert_eq!(w.len(), 6);
        assert!(g.is_vertex_cover(&w));
        assert_eq!(sol.witness_verified, Some(true));
    }

    #[test]
    fn non_extracting_jobs_have_no_witness() {
        let svc = VcService::builder().workers(1).build();
        let sol = svc.solve(Problem::mvc(generators::petersen()));
        assert_eq!(sol.objective, 6);
        assert!(sol.witness.is_none());
        assert_eq!(sol.witness_verified, None);
        assert_eq!(sol.stats.witness_log_bytes, 0);
    }

    #[test]
    fn many_concurrent_jobs_all_resolve() {
        let svc = VcService::builder().workers(4).build();
        let handles: Vec<(JobHandle, u32)> = (0..24u64)
            .map(|seed| {
                let g = generators::erdos_renyi(14 + (seed as usize % 6), 0.2, seed);
                let opt = oracle::mvc_size(&g);
                (svc.submit(Problem::mvc(g)), opt)
            })
            .collect();
        for (i, (h, opt)) in handles.iter().enumerate() {
            let sol = h.wait();
            assert_eq!(sol.objective, *opt, "job {i}");
            assert_eq!(sol.termination, Termination::Complete, "job {i}");
        }
    }

    #[test]
    fn service_drop_drains_outstanding_jobs() {
        let svc = VcService::builder().workers(2).build();
        let pairs: Vec<(JobHandle, u32)> = (0..8u64)
            .map(|seed| {
                let g = generators::union_of_random(3, 3, 6, 0.3, seed);
                let opt = oracle::mvc_size(&g);
                (svc.submit(Problem::mvc(g)), opt)
            })
            .collect();
        drop(svc); // graceful shutdown must drain, not abandon
        for (h, opt) in pairs {
            let sol = h.wait();
            assert_eq!(sol.objective, opt);
        }
    }

    #[test]
    fn empty_and_trivial_graphs_through_service() {
        let svc = VcService::builder().workers(1).build();
        let empty = Graph::from_edges(5, &[]);
        assert_eq!(svc.solve(Problem::mvc(empty)).objective, 0);
        let single = Graph::from_edges(2, &[(0, 1)]);
        assert_eq!(svc.solve(Problem::mvc(single.clone())).objective, 1);
        assert!(svc.solve(Problem::pvc(single.clone(), 1)).feasible);
        assert!(!svc.solve(Problem::pvc(single, 0)).feasible);
    }

    #[test]
    fn sharded_resident_pool_agrees() {
        let svc =
            VcService::builder().workers(3).scheduler(SchedulerKind::Sharded).build();
        for seed in 0..5 {
            let g = generators::union_of_random(3, 3, 7, 0.3, seed);
            let opt = oracle::mvc_size(&g);
            assert_eq!(svc.solve(Problem::mvc(g)).objective, opt, "seed {seed}");
        }
    }

    #[test]
    fn stats_endpoint_counts_classes_and_parks() {
        let svc = VcService::builder().workers(2).build();
        for seed in 0..3 {
            let g = generators::erdos_renyi(14, 0.2, seed);
            let opt = oracle::mvc_size(&g);
            assert_eq!(svc.solve(Problem::mvc(g.clone())).objective, opt);
            assert!(svc.solve(Problem::pvc(g, opt)).feasible);
        }
        let stats = svc.stats();
        assert_eq!(stats.mvc.jobs, 3);
        assert_eq!(stats.pvc.jobs, 3);
        assert_eq!(stats.mis.jobs, 0);
        assert!(stats.mvc.tree_nodes > 0);
        assert_eq!(stats.class(ProblemKind::Pvc).jobs, 3);
        // an idle resident pool parks its workers; give it a beat
        let mut parks = svc.stats().pool.parks;
        for _ in 0..400 {
            if parks > 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
            parks = svc.stats().pool.parks;
        }
        assert!(parks > 0, "idle pool must park");
    }

    #[test]
    fn job_ids_are_unique_and_monotonic() {
        let svc = VcService::builder().workers(1).build();
        let a = svc.submit(Problem::mvc(generators::path(4)));
        let b = svc.submit(Problem::mvc(generators::path(5)));
        assert!(b.id() > a.id());
        a.wait();
        b.wait();
    }
}
