//! The resident solver service: a persistent worker pool that jobs are
//! *injected into*, instead of a thread pool reconstructed around every
//! call.
//!
//! The paper (and Yamout et al.) treat the GPU workers as a resident
//! grid fed by a shared worklist; this module gives the host API the
//! same shape. A [`VcService`] is built once
//! (`VcService::builder().workers(n).scheduler(kind).build()`) and owns:
//!
//! * a **resident scheduler** (work-stealing by default) whose workers
//!   park on quiescence instead of terminating — see
//!   `sched::ResidentCtl`;
//! * one OS thread per worker, each with per-dtype [`WorkerCtx`] scratch
//!   (BFS stamps, buffer pools) that is *shared across jobs* — a small
//!   graph solved after a big one reuses the big one's recycled buffers;
//! * a monotonically increasing job-id counter.
//!
//! ## Job lifecycle
//!
//! [`VcService::submit`] wraps a [`Problem`] into a job and injects a
//! single `Setup` work item. A worker pops it, runs the preparation
//! pipeline (greedy bound → root reduction → induction → dtype/occupancy
//! selection — the "job setup" half of the old engine), and pushes the
//! job's root search node. From there the ordinary branch-and-reduce
//! node processing takes over; every node in the shared worklist carries
//! an `Arc` to its job's state (`JobCtl`: registry, global best, stop
//! flags, stats sink), which is what keeps completion, pruning, and
//! last-descendant aggregation **job-local** — the registry context ids
//! inside a node index that job's private registry, so two jobs'
//! component cascades can never interleave even though their nodes share
//! deques.
//!
//! Completion detection is a per-job outstanding-node count: every
//! pushed item increments it *before* entering the worklist, every
//! processed (or dropped) item decrements it after; the worker that
//! drives it to zero finalizes the [`Solution`] and wakes the waiters.
//! Cancellation ([`JobHandle::cancel`]) and the per-job deadline
//! ([`JobOptions::timeout`]) latch the job's `stop` flag: queued nodes
//! of a stopped job are dropped on pop, so a cancelled job drains at
//! pop speed without touching other jobs.
//!
//! Many small jobs therefore run concurrently with one large branching
//! job on the same pool: the large job's nodes fill the deques, a small
//! job's setup + nodes interleave via the shared injector, and idle
//! workers steal whatever is oldest.
//!
//! Jobs submitted with [`JobOptions::extract_witness`] additionally get
//! an actual solution vertex set back: nodes of the job carry choice
//! logs, the job's private registry reassembles component covers at
//! last-descendant aggregation, and finalization lifts the winning
//! cover to original ids (induction renumbering + reduction unwind) and
//! verifies it against the original graph ([`Solution::witness`],
//! [`Solution::witness_verified`]).
//!
//! ## Admission & QoS
//!
//! Submissions pass through a bounded, QoS-aware admission layer before
//! they reach the pool's shared injector:
//!
//! * **Bounded queue / backpressure** — the admission queue holds at
//!   most `max_queued` jobs (default: the occupancy model's
//!   `admission_capacity`, which charges queued submissions against the
//!   same memory budget as the per-worker stacks). A full queue
//!   *rejects* [`VcService::try_submit`] with [`SubmitError::QueueFull`]
//!   and *blocks* [`VcService::submit`] (bounded-wait:
//!   [`VcService::submit_within`]), so submission pressure turns into
//!   caller backpressure instead of unbounded queue growth.
//! * **Lanes** — every job is classified into a [`Lane`]: explicitly
//!   via [`JobOptions::priority`], otherwise estimated from the input
//!   size at admission and refined from the *reduced* graph size at
//!   prep (`latency_threshold`). A single dispatcher thread drains the
//!   queue by weighted deficit round robin (latency 4 : throughput 1)
//!   into the injector — admission stays single-threaded and cheap,
//!   and the existing injector fans the work out. Latency-lane setups
//!   and roots are injected *urgent*: a lane hint shared with both
//!   scheduler runtimes makes every worker poll the shared queue on
//!   every pop (instead of every 64th) until they are picked up.
//! * **Quotas** — jobs carrying [`JobOptions::tenant`] are charged
//!   against per-tenant quotas ([`TenantQuota`]): concurrent jobs and
//!   outstanding live nodes, both checked at admission. Node charges
//!   are taken when an item enters the worklist and released as it
//!   retires; the job slot is released exactly once, when the job's
//!   outcome is published.
//! * **Live-jobs bound** — at most `max_live_jobs` dispatched jobs are
//!   in flight at once; beyond it the dispatcher holds jobs back in the
//!   admission queue, which is what lets the queue bound actually fill
//!   and exert backpressure.
//!
//! Lane scheduling changes only *when* work is picked up, never what is
//! computed: objectives and witnesses are identical with lanes on or
//! off (asserted by `tests/qos_admission.rs`).
//!
//! ## Failure model & degradation ladder
//!
//! A job's answer degrades in well-defined rungs — each rung trades
//! *progress* away while keeping the answer *trustworthy*, and only the
//! last rung gives up on trust:
//!
//! 1. **Complete** — the search ran to exhaustion (or, PVC, to its
//!    decision). Objective exact, witness (if requested) verified.
//! 2. **Anytime** ([`Termination::DeadlineExpired`] /
//!    [`Termination::Cancelled`]) — the deadline fired or the caller
//!    cancelled. MVC/MIS jobs still return the best bound found *and*,
//!    for extracting jobs, the best cover the engine had assembled (the
//!    registry's shortest-wins root slot), re-anchored so `|witness| ==
//!    objective` and verified edge-by-edge; with no assembled cover yet,
//!    the greedy cover stands in. [`JobHandle::progress`] exposes the
//!    same bound while the job is still running.
//! 3. **Sequential retry** ([`Termination::Recovered`]) — a worker
//!    *panicked* while running the job, and a [`RetryPolicy`] was set
//!    (per job or builder-wide): the `cavc-svc-retry` thread reruns the
//!    job from scratch on the sequential solver — no shared queues, no
//!    registry, no speculation — and publishes its trusted answer.
//!    Degraded throughput, not degraded truth; [`Solution::failure`]
//!    still carries the original panic message.
//! 4. **Failed** ([`Termination::Failed`]) — the job panicked and there
//!    was no retry budget (or every rescue attempt panicked too — those
//!    jobs are *quarantined*, [`AdmissionStats::quarantined`]). The
//!    outcome is degenerate but `wait` always returns, and
//!    [`Solution::failure`] says why. Panics never escape a worker: the
//!    pool and co-scheduled jobs are unaffected.
//!
//! Admission itself sheds load in its own order —
//! [`SubmitError::MemoryPressure`] when the watchdog's hard limit is
//! exceeded (checked first: a full queue under memory pressure is a
//! memory problem), [`SubmitError::QuotaExceeded`] when the tenant is at
//! quota, [`SubmitError::QueueFull`] when the bounded queue is at
//! capacity. Between the soft and hard limits the service degrades
//! instead of shedding: throughput-lane dispatch pauses and new jobs are
//! forced onto the delta node representation.
//!
//! The cross-job memo cache ([`crate::solver::memo`]) sits *below* all
//! of those rungs: its resident bytes are charged to the same ledger
//! the watchdog reads, and under memory pressure the cache is shed
//! outright — the dispatcher drops it before holding throughput
//! dispatch, and an over-hard-limit submit drops it before shedding the
//! submit itself. Cached reuse is pure speedup, so it is always the
//! first thing traded away.
//!
//! The whole ladder is exercised deterministically by the seeded
//! fault-injection harness ([`crate::solver::faults`], `tests/chaos.rs`).
//!
//! ## Self-tuning controller
//!
//! The engine's memory/scheduling knobs — node representation,
//! `max_pin_depth`, the induction threshold, admission capacity, and
//! the memo budget — default to an *online controller*
//! ([`crate::solver::autotune`]) instead of static values. A
//! `cavc-svc-tune` thread beside the dispatcher ticks every
//! ~25 ms, reading the measurements the service already keeps:
//!
//! * per-width-bucket **bytes/node** EWMAs and the undo-vs-materialize
//!   cost split from the engine stats flush, deciding owned-vs-delta
//!   per dispatched component width;
//! * the pool-wide **steal rate** from the worker publication slots,
//!   lengthening delta chains (`max_pin_depth`) when the undo fast
//!   path dominates and shortening them when thieves pay replay;
//! * per-bucket **induction amortization** (tree nodes per induced
//!   rebuild), gating the §IV-B induce threshold where the CSR
//!   rebuild does not pay for itself;
//! * live ledger bytes, **re-planning** the admission capacity and the
//!   memo byte budget through [`OccupancyModel`] instead of trusting
//!   seed-time estimates.
//!
//! Decisions are published to a lock-free blackboard and consulted
//! per dispatch in `engine.rs`; convergence (epochs, flips,
//! converged-at epoch) surfaces as [`AutotuneStats`] in
//! [`ServiceStats`] and the wire stats frame.
//!
//! **Override precedence**, strongest first: (1) the memory watchdog's
//! soft-pressure forced-delta override — the degradation ladder always
//! outranks tuning; (2) explicit static knobs (a non-default
//! `node_repr` / `max_pin_depth` / `induce_threshold` in the job's
//! config, or `CAVC_NODE_REPR`) pin that knob and the controller never
//! touches it — this is what keeps ablation baselines exact; (3) the
//! controller's decision; (4) the built-in default. `--autotune off`
//! (or `CAVC_AUTOTUNE=off`, or [`VcServiceBuilder::autotune`]) removes
//! rungs 3 entirely. Tuning never changes *what* is computed — only
//! representation and pacing — so objectives and witnesses are
//! bit-identical with the controller on or off
//! (`tests/autotune_invariance.rs`).
//!
//! ## Serving over the network
//!
//! Everything above is also reachable over TCP: [`crate::solver::wire`]
//! frames [`Problem`]s, a [`JobOptions`] subset (lane, deadline, tenant,
//! witness, memo), [`Solution`] digests, and [`ServiceStats`] scrapes in
//! a length-prefixed binary protocol, and [`crate::solver::server`]
//! mounts one service behind a listener — reader threads feed a single
//! coordinator that is the only admission caller, so the network path
//! exercises exactly the `try_submit_with`/`submit_within` semantics
//! documented here, and every [`SubmitError`] arm has a typed wire
//! error. See `cavc serve` and the module docs of
//! [`crate::solver::server`].

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::degree::{DegElem, Dtype};
use crate::graph::Graph;
use crate::prep::{self, PrepConfig};

use super::autotune::{self, AutotuneStats, JobTune, TuneShared, Tuner};
use super::engine::{self, EngineStats, JobCfg, JobCtl, JobView, NodePayload, NodeRepr, WorkerCtx};
use super::memo::{self, JobMemo, MemoCache, MemoLedger, MemoStats};
use super::occupancy::OccupancyModel;
use super::sched::{
    IdleOutcome, LaneHint, PopSource, Scheduler, SchedulerKind, ShardedScheduler,
    WorkStealScheduler, WorkerCounters, WorkerHandle,
};
use super::witness::{self, CoverLift};
use super::{greedy, sequential, PrepSummary, SolverConfig};

/// A problem submitted to the service. Graphs are `Arc`-shared so a
/// batch driver can submit the same graph under several parameters
/// without copying it.
#[derive(Debug, Clone)]
pub enum Problem {
    /// Minimum vertex cover.
    Mvc {
        /// The input graph.
        g: Arc<Graph>,
    },
    /// Parameterized vertex cover: is there a cover of size ≤ `k`?
    Pvc {
        /// The input graph.
        g: Arc<Graph>,
        /// The cover-size budget.
        k: u32,
    },
    /// Maximum independent set (solved as `|V| − MVC`).
    Mis {
        /// The input graph.
        g: Arc<Graph>,
    },
}

impl Problem {
    /// A minimum-vertex-cover problem.
    pub fn mvc(g: impl Into<Arc<Graph>>) -> Problem {
        Problem::Mvc { g: g.into() }
    }

    /// A parameterized-vertex-cover problem (`∃ cover ≤ k?`).
    pub fn pvc(g: impl Into<Arc<Graph>>, k: u32) -> Problem {
        Problem::Pvc { g: g.into(), k }
    }

    /// A maximum-independent-set problem.
    pub fn mis(g: impl Into<Arc<Graph>>) -> Problem {
        Problem::Mis { g: g.into() }
    }

    /// The input graph.
    pub fn graph(&self) -> &Arc<Graph> {
        match self {
            Problem::Mvc { g } | Problem::Pvc { g, .. } | Problem::Mis { g } => g,
        }
    }

    /// The problem kind tag.
    pub fn kind(&self) -> ProblemKind {
        match self {
            Problem::Mvc { .. } => ProblemKind::Mvc,
            Problem::Pvc { .. } => ProblemKind::Pvc,
            Problem::Mis { .. } => ProblemKind::Mis,
        }
    }
}

/// Which problem a [`Solution`] answers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProblemKind {
    /// Minimum vertex cover.
    Mvc,
    /// Parameterized vertex cover.
    Pvc,
    /// Maximum independent set.
    Mis,
}

/// Why a job stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Termination {
    /// The search ran to completion (for PVC this includes stopping at
    /// the first cover ≤ k, which answers the decision problem).
    Complete,
    /// The per-job deadline fired; the reported objective is only a
    /// bound (upper for MVC, lower for MIS; PVC may report infeasible
    /// without proof).
    DeadlineExpired,
    /// [`JobHandle::cancel`] was called before the search finished.
    Cancelled,
    /// A worker panicked while running this job (internal error). The
    /// panic is contained — the pool and other jobs are unaffected, and
    /// `wait` still returns — but this job's objective/stats are not
    /// trustworthy ([`Solution::failure`] carries the captured panic
    /// message). The one-shot shims turn this back into a panic to
    /// preserve the old loud-failure contract.
    Failed,
    /// The parallel run failed, but a [`RetryPolicy`] was set and the
    /// sequential fallback recomputed the answer: the objective and
    /// witness are trusted (degraded throughput, not degraded truth);
    /// [`Solution::failure`] still carries the original panic message.
    Recovered,
}

/// Unified result of any [`Problem`] — replaces the old
/// `SolveResult`/`PvcResult`/`MisResult` triplet at the service layer
/// (the one-shot shims still expose the legacy structs).
#[derive(Debug, Clone)]
pub struct Solution {
    /// Which problem this answers.
    pub problem: ProblemKind,
    /// MVC: cover size (an upper bound if not [`Termination::Complete`]).
    /// MIS: independence number (lower bound if not complete).
    /// PVC: size of the found cover when `feasible`, else `k + 1`.
    pub objective: u32,
    /// PVC: whether a cover of size ≤ k was found (`false` under
    /// deadline/cancel means "unknown", mirroring `PvcResult::found`).
    /// Always `true` for MVC/MIS.
    pub feasible: bool,
    /// Witness vertex set, populated when the job was submitted with
    /// [`JobOptions::extract_witness`]: the cover (MVC/PVC) or
    /// independent set (MIS) in *original* vertex ids, assembled from
    /// the engine's per-node choice logs and lifted through the
    /// induction renumbering and root-reduction unwind. `|witness| ==
    /// objective` for MVC/MIS; for PVC it is a cover with `|witness| ≤
    /// k` (equal to `objective` except when an est-propagated bound
    /// beat the assembled cover to the early stop).
    pub witness: Option<Vec<u32>>,
    /// Whether the extracted witness verified edge-by-edge against the
    /// original graph (`solver::witness`); `None` when no witness was
    /// requested or produced.
    pub witness_verified: Option<bool>,
    /// Engine counters for this job only.
    pub stats: EngineStats,
    /// Preparation summary (root reduction, dtype, occupancy).
    pub prep: PrepSummary,
    /// Wall-clock time from submission to finalization.
    pub elapsed: Duration,
    /// Why the job stopped.
    pub termination: Termination,
    /// The captured panic payload, for [`Termination::Failed`] and
    /// [`Termination::Recovered`] jobs (today's `catch_unwind` no longer
    /// swallows the message). `None` on every healthy path.
    pub failure: Option<String>,
}

impl Solution {
    /// True if the job stopped because its per-job deadline fired —
    /// shorthand for `termination == Termination::DeadlineExpired`,
    /// kept under the old one-shot API's `timed_out` name so callers
    /// ported from `SolveResult`/`PvcResult` read the same way.
    pub fn timed_out(&self) -> bool {
        self.termination == Termination::DeadlineExpired
    }
}

/// QoS lane of a job: which admission queue it waits in and how eagerly
/// the pool's fairness poll picks its items up.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// Small jobs that want low queueing delay. Dispatched with a 4×
    /// deficit-round-robin weight, and their setup/root items are
    /// injected *urgent*: every worker polls the shared queue on every
    /// pop (instead of every 64th) until the items are picked up.
    Latency,
    /// Large jobs where total throughput matters and queueing delay
    /// does not.
    Throughput,
}

impl Lane {
    /// Index into the admission layer's lane arrays.
    fn index(self) -> usize {
        match self {
            Lane::Latency => 0,
            Lane::Throughput => 1,
        }
    }

    /// Short display name (`latency` / `throughput`).
    pub fn name(self) -> &'static str {
        match self {
            Lane::Latency => "latency",
            Lane::Throughput => "throughput",
        }
    }

    /// Parse a CLI spelling (`latency`/`lat`, `throughput`/`tput`).
    pub fn parse(s: &str) -> Option<Lane> {
        match s {
            "latency" | "lat" => Some(Lane::Latency),
            "throughput" | "tput" => Some(Lane::Throughput),
            _ => None,
        }
    }
}

/// Why a submission was not admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded admission queue is at capacity (backpressure): retry
    /// later, or use a blocking submit.
    QueueFull,
    /// The job's tenant is at its concurrent-jobs or live-nodes quota
    /// ([`TenantQuota`]).
    QuotaExceeded,
    /// The memory watchdog's hard limit is exceeded: the pool sheds
    /// load until live bytes drop back under the limit. Non-blocking
    /// submits get this immediately; blocking submits wait for the
    /// pressure to clear (bounded waits report it on expiry).
    MemoryPressure,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "admission queue full"),
            SubmitError::QuotaExceeded => write!(f, "tenant quota exceeded"),
            SubmitError::MemoryPressure => write!(f, "memory watchdog hard limit exceeded"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Per-tenant admission quotas ([`VcServiceBuilder::tenant_quota`]),
/// enforced at admission for jobs submitted with [`JobOptions::tenant`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TenantQuota {
    /// Maximum jobs of one tenant queued or running at once.
    pub max_jobs: usize,
    /// Maximum outstanding work items (queued + executing search nodes)
    /// across one tenant's jobs. Checked at admission: a tenant whose
    /// running jobs hold this many live nodes cannot admit more work
    /// until some retire.
    pub max_live_nodes: u64,
}

/// Failure-recovery policy for a job whose parallel run panicked: rerun
/// it on the *sequential* solver (same prep pipeline, no shared-state
/// machinery — the degraded-but-trusted rung of the degradation ladder)
/// up to `attempts` times before surfacing [`Termination::Failed`].
/// Jobs that exhaust every attempt are quarantined and counted in
/// [`AdmissionStats::quarantined`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Sequential rescue attempts before giving up (min 1).
    pub attempts: u32,
    /// Pause before each rescue attempt (lets transient pressure —
    /// memory, a poisoned scratch — clear before recomputing).
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { attempts: 1, backoff: Duration::ZERO }
    }
}

/// A point-in-time progress snapshot of a running job
/// ([`JobHandle::progress`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct JobProgress {
    /// Best objective bound so far, in the problem's own terms (cover
    /// size for MVC/PVC — an upper bound; independence number for MIS —
    /// a lower bound). `None` until the job's setup published an
    /// initial bound.
    pub best_bound: Option<u32>,
    /// Search-tree nodes expanded so far (published on the engine's
    /// 64-node poll cadence, so it can trail the true count slightly).
    pub nodes_expanded: u64,
    /// Wall-clock time since submission.
    pub elapsed: Duration,
    /// Whether the job has finalized (its [`Solution`] is available).
    pub done: bool,
}

/// Per-job submission options.
#[derive(Debug, Clone, Default)]
pub struct JobOptions {
    /// Per-job wall-clock budget (falls back to the service config's
    /// timeout when `None`). The clock starts at submission, so time
    /// blocked in admission counts against it.
    pub timeout: Option<Duration>,
    /// Per-job solver knobs (component awareness, root reduction,
    /// bounds, dtypes, induce threshold) overriding the service
    /// defaults. The pool-shape fields (`variant`, `workers`,
    /// `scheduler`) are ignored — the resident pool is fixed at build.
    pub config: Option<SolverConfig>,
    /// Return an actual witness in [`Solution::witness`]: the engine
    /// carries per-node choice logs for this job and reassembles the
    /// winning cover at last-descendant aggregation. Costs one extra
    /// pooled buffer per node plus a lock per leaf report; off by
    /// default. A `config` with `extract_cover` set requests the same
    /// thing.
    pub extract_witness: bool,
    /// Pin the job to a QoS [`Lane`]. `None` (default) classifies by
    /// size: input |V| at admission, refined by the reduced-graph size
    /// at prep (the builder's `latency_threshold`).
    pub priority: Option<Lane>,
    /// Tenant id for quota accounting. Jobs without a tenant are never
    /// quota-limited.
    pub tenant: Option<String>,
    /// Failure recovery: rerun a panicked job on the sequential solver
    /// under this policy before surfacing [`Termination::Failed`].
    /// `None` falls back to the builder's [`VcServiceBuilder::retry`]
    /// default (itself `None` = fail fast).
    pub retry: Option<RetryPolicy>,
    /// Deterministic fault plan for chaos testing (see
    /// [`crate::solver::faults`]); also settable process-wide via
    /// `CAVC_FAULT_SEED`. `None` (the default) injects nothing.
    pub fault: Option<super::faults::FaultPlan>,
    /// Per-job opt-in/out of the cross-job component memo cache
    /// ([`crate::solver::memo`]). `None` falls back to the job config's
    /// `memo`, then the service default. Ignored (always off) when the
    /// service was built without a cache.
    pub memo: Option<bool>,
    /// Test hook: panic inside the job's setup stage, exercising the
    /// panic-containment path end to end.
    #[cfg(test)]
    pub(crate) panic_in_setup: bool,
}

/// A submitted job: await it, poll it, or cancel it. Cloning the handle
/// is cheap; all clones observe the same job.
#[derive(Clone)]
pub struct JobHandle {
    job: Arc<JobInner>,
}

impl JobHandle {
    /// The service-unique job id.
    pub fn id(&self) -> u64 {
        self.job.id
    }

    /// Block until the job finalizes and return its solution.
    pub fn wait(&self) -> Solution {
        let mut out = self.job.outcome.lock().unwrap();
        loop {
            if let Some(sol) = out.as_ref() {
                return sol.clone();
            }
            out = self.job.done_cv.wait(out).unwrap();
        }
    }

    /// Non-blocking poll: the solution if the job already finalized.
    pub fn try_result(&self) -> Option<Solution> {
        self.job.outcome.lock().unwrap().as_ref().cloned()
    }

    /// A point-in-time progress snapshot: best objective bound so far,
    /// search-tree nodes expanded, and elapsed wall-clock. Lock-free on
    /// the engine side (the bound and node count are published
    /// atomically by the workers); safe to poll at any rate.
    pub fn progress(&self) -> JobProgress {
        let job = &self.job;
        let done = job.outcome.lock().unwrap().is_some();
        let best_bound = job.prepared.get().map(|p| {
            // Mirror finalization's objective arithmetic on the live
            // residual bound, so the snapshot converges to the final
            // objective as the search tightens it.
            let b = job.ctl.best.load(Ordering::SeqCst);
            let total = p.forced + b.min(p.initial);
            let mvc = total.min(p.greedy_ub);
            match job.problem.kind() {
                ProblemKind::Mis => job.problem.graph().num_vertices() as u32 - mvc,
                ProblemKind::Mvc | ProblemKind::Pvc => mvc,
            }
        });
        JobProgress {
            best_bound,
            nodes_expanded: job.ctl.nodes_expanded.load(Ordering::Relaxed),
            elapsed: job.started.elapsed(),
            done,
        }
    }

    /// Request cancellation. Queued nodes of the job are dropped as they
    /// surface; `wait` then returns with [`Termination::Cancelled`].
    /// Cancelling a finished job is a no-op.
    pub fn cancel(&self) {
        // Order matters: the memo poison and the flag that *labels* the
        // stop must both be set before the flag that *causes* it, so
        // truncated folds can't publish to the cache and finalization
        // can't read a stop with no recorded reason.
        if let Some(m) = &self.job.ctl.cfg.memo {
            m.poison();
        }
        self.job.cancelled.store(true, Ordering::SeqCst);
        self.job.ctl.stop.store(true, Ordering::SeqCst);
    }
}

impl std::fmt::Debug for JobHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobHandle")
            .field("id", &self.job.id)
            .field("done", &self.try_result().is_some())
            .finish()
    }
}

/// Job-prep results published by the `Setup` work item (read by every
/// subsequent node of the job).
struct JobPrep {
    /// The residual (root-reduced, induced) graph the search runs on.
    graph: Arc<Graph>,
    /// Residual-relative initial upper bound handed to the engine.
    initial: u32,
    /// Vertices forced into the cover at the root.
    forced: u32,
    /// Greedy upper bound on the original graph.
    greedy_ub: u32,
    /// PVC: residual budget `k − forced` (when the search ran).
    k_resid: Option<u32>,
    /// Witness lift (induction map + reduction unwind), kept only for
    /// extracting jobs so finalization can translate the assembled
    /// residual cover back to original vertex ids.
    lift: Option<CoverLift>,
    /// Prep summary for the solution.
    summary: PrepSummary,
    /// Payload bytes of the root node (charged at finalization, like the
    /// one-shot runner charges its out-of-worker root).
    root_bytes: u64,
    /// Whether a root node entered the worklist (false for jobs decided
    /// at prep: trivial PVC answers, pre-expired deadlines, cancels).
    root_pushed: bool,
    /// PVC decided during prep, before any search node existed.
    decided: Option<PvcDecided>,
}

/// PVC answers that fall out of the preparation stage.
enum PvcDecided {
    /// The greedy bound already satisfies k.
    FoundGreedy(u32),
    /// More than k vertices are forced at the root: no cover ≤ k.
    Infeasible,
}

/// Shared state of one job. Nodes in the worklist hold an `Arc` to this
/// — that Arc *is* the job id the issue's registry scoping refers to:
/// each job owns a private registry (inside `ctl`), so context ids in a
/// node are meaningful only together with the job pointer riding next to
/// them.
struct JobInner {
    id: u64,
    problem: Problem,
    /// Registry, global best, stop/improved/timed-out flags, stats sink.
    ctl: JobCtl,
    prep_cfg: PrepConfig,
    /// Outstanding work items (setup + queued/executing nodes). The
    /// decrement-to-zero owner finalizes the job.
    live_nodes: AtomicU64,
    cancelled: AtomicBool,
    /// A worker panicked while running this job's setup or a node.
    failed: AtomicBool,
    /// First captured panic payload (the message behind
    /// [`Solution::failure`]); later panics of the same job only count.
    failure: Mutex<Option<String>>,
    /// Failure-recovery policy (job option, else the builder default).
    retry: Option<RetryPolicy>,
    /// `Occupancy::pinned_bytes` charged to the memory ledger at setup,
    /// released exactly once at outcome publication.
    pinned_charge: AtomicU64,
    prepared: OnceLock<JobPrep>,
    outcome: Mutex<Option<Solution>>,
    done_cv: Condvar,
    started: Instant,
    pool_workers: usize,
    /// The service's shared stats accumulators — finalization folds this
    /// job's engine counters into its class slot.
    counters: Arc<ServiceCounters>,
    /// QoS lane index ([`Lane::index`]): estimated at admission from the
    /// input size, refined at prep from the reduced-graph size unless
    /// the submitter pinned it.
    lane: AtomicU8,
    /// `true` when [`JobOptions::priority`] was set — prep-time
    /// refinement then leaves the lane alone.
    explicit_lane: bool,
    /// Reduced-size threshold at or below which prep classifies the job
    /// into the latency lane (copied from the builder).
    latency_threshold: usize,
    /// Tenant quota bookkeeping (jobs submitted with a tenant only).
    tenant: Option<TenantRef>,
    /// The service's admission layer: lane hint for urgent injections,
    /// and the exactly-once job-slot release at outcome publication.
    admission: Arc<Admission>,
    /// Test hook mirrored from [`JobOptions`].
    #[cfg(test)]
    panic_in_setup: bool,
}

impl JobInner {
    /// The job's current QoS lane.
    fn lane(&self) -> Lane {
        if self.lane.load(Ordering::Relaxed) == Lane::Latency.index() as u8 {
            Lane::Latency
        } else {
            Lane::Throughput
        }
    }
}

/// A job's share of its tenant's quota accounting: the live-node counter
/// is shared by every job of the tenant and mirrors each job's
/// `live_nodes` (+1 on every enqueue, −1 on every retire).
struct TenantRef {
    name: String,
    nodes: Arc<AtomicU64>,
}

/// One unit of service work: either a job's setup stage or one search
/// node (dtype-erased so jobs of different degree dtypes share queues).
struct WorkItem {
    job: Arc<JobInner>,
    work: Work,
    /// Latency-lane item injected through the shared queue with the
    /// lane hint raised; the popping worker lowers the hint again.
    urgent: bool,
    /// Payload bytes charged to the memory-watchdog ledger while this
    /// item is queued (released when the item retires).
    bytes: u64,
}

enum Work {
    Setup,
    Node(AnyNode),
}

/// Dtype-erased search node (§IV-D: each job picks the smallest dtype
/// that fits its max degree; the shared worklist must carry them all).
/// Each variant is a [`NodePayload`] — an owned payload or a delta
/// right child, per the job's `node_repr`.
enum AnyNode {
    U8(NodePayload<u8>),
    U16(NodePayload<u16>),
    U32(NodePayload<u32>),
}

impl From<NodePayload<u8>> for AnyNode {
    fn from(n: NodePayload<u8>) -> AnyNode {
        AnyNode::U8(n)
    }
}
impl From<NodePayload<u16>> for AnyNode {
    fn from(n: NodePayload<u16>) -> AnyNode {
        AnyNode::U16(n)
    }
}
impl From<NodePayload<u32>> for AnyNode {
    fn from(n: NodePayload<u32>) -> AnyNode {
        AnyNode::U32(n)
    }
}

/// The resident scheduler, selected at build time.
enum ResidentSched {
    Steal(WorkStealScheduler<WorkItem>),
    Sharded(ShardedScheduler<WorkItem>),
}

impl ResidentSched {
    fn inject(&self, item: WorkItem) {
        match self {
            ResidentSched::Steal(s) => s.inject(item),
            ResidentSched::Sharded(s) => s.inject(item),
        }
    }

    fn request_shutdown(&self) {
        match self {
            ResidentSched::Steal(s) => s.request_shutdown(),
            ResidentSched::Sharded(s) => s.request_shutdown(),
        }
    }

    fn parks(&self) -> u64 {
        match self {
            ResidentSched::Steal(s) => s.parks(),
            ResidentSched::Sharded(s) => s.parks(),
        }
    }

    fn backlog(&self) -> usize {
        match self {
            ResidentSched::Steal(s) => s.backlog(),
            ResidentSched::Sharded(s) => s.backlog(),
        }
    }

    fn lane_hint(&self) -> Arc<LaneHint> {
        match self {
            ResidentSched::Steal(s) => s.lane_hint(),
            ResidentSched::Sharded(s) => s.lane_hint(),
        }
    }
}

/// Pool-level scheduler counters surfaced by [`VcService::stats`]:
/// queue traffic and park events aggregated over every resident worker.
/// Nodes of all job classes share the same deques, so these are
/// pool-wide; the per-class breakdown lives in [`ClassStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Children enqueued by the pool's workers.
    pub pushes: u64,
    /// Service-side injections into the shared queue (dispatched setups
    /// + urgent latency roots) — the non-worker half of the push/pop
    /// conservation ledger: once drained,
    /// `pops + shared_pops + steals == pushes + injected`.
    pub injected: u64,
    /// Nodes taken from a worker's own queue.
    pub pops: u64,
    /// Nodes taken from the shared entry queue.
    pub shared_pops: u64,
    /// Nodes taken from another worker (cross-worker steals).
    pub steals: u64,
    /// Steal attempts that lost a race and retried.
    pub steal_retries: u64,
    /// Worker park events (an idle pool parks; a saturated one never
    /// does — the service QoS "is the pool starved or drowning" signal).
    pub parks: u64,
    /// Queued-node backlog snapshot at the time `stats()` was called
    /// (racy; exact only on a quiescent pool).
    pub backlog: usize,
}

/// Admission-layer telemetry surfaced by [`VcService::stats`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    /// Jobs currently waiting in the admission queue (both lanes).
    pub queued: usize,
    /// Jobs dispatched into the pool and not yet finalized.
    pub live_jobs: usize,
    /// Submissions rejected because the queue was full (`try_submit`,
    /// or a bounded `submit_within` wait that expired).
    pub rejected: u64,
    /// Submissions rejected by a tenant quota.
    pub quota_rejected: u64,
    /// Cumulative wall-clock time submitters spent blocked waiting for
    /// queue space or quota headroom.
    pub blocked: Duration,
    /// Jobs dispatched from the latency lane.
    pub dispatched_latency: u64,
    /// Jobs dispatched from the throughput lane.
    pub dispatched_throughput: u64,
    /// Live bytes on the memory-watchdog ledger right now (queued node
    /// payloads + pinned occupancy charges of live jobs).
    pub live_bytes: u64,
    /// Submissions shed by the watchdog's hard limit
    /// ([`SubmitError::MemoryPressure`]).
    pub mem_rejected: u64,
    /// Sequential rescue attempts started for panicked jobs.
    pub retries: u64,
    /// Panicked jobs whose sequential rescue produced a trusted answer
    /// ([`Termination::Recovered`]).
    pub recovered: u64,
    /// Panicked jobs that exhausted every rescue attempt and surfaced
    /// [`Termination::Failed`].
    pub quarantined: u64,
}

/// Per-job-class counters surfaced by [`VcService::stats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClassStats {
    /// Jobs of this class finalized.
    pub jobs: u64,
    /// Work items of this class acquired via cross-worker steals.
    pub steals: u64,
    /// Search-tree nodes visited for this class.
    pub tree_nodes: u64,
    /// Delta right children pushed for this class (delta node
    /// representation only).
    pub delta_children: u64,
    /// Delta nodes consumed on the in-place undo fast path.
    pub undo_pops: u64,
    /// Delta nodes materialized into owned payloads (stolen/foreign).
    pub materializations: u64,
    /// Component dispatches of this class that consulted the cross-job
    /// memo cache.
    pub memo_lookups: u64,
    /// Memo lookups of this class that skipped the subtree.
    pub memo_hits: u64,
}

/// Aggregate scheduler/engine telemetry of a running service (the
/// ROADMAP "Service QoS" counters endpoint).
#[derive(Debug, Clone, Default)]
pub struct ServiceStats {
    /// Pool-wide queue traffic and park events.
    pub pool: PoolStats,
    /// Admission-layer counters (queue depth, rejections, blocked time,
    /// per-lane dispatches).
    pub admission: AdmissionStats,
    /// MVC-class jobs.
    pub mvc: ClassStats,
    /// PVC-class jobs.
    pub pvc: ClassStats,
    /// MIS-class jobs.
    pub mis: ClassStats,
    /// Cross-job component memo cache counters (all zero when the
    /// service runs with the cache disabled).
    pub memo: MemoStats,
    /// Self-tuning controller counters (decisions, flips, convergence;
    /// `enabled == false` and all-zero when the controller is off).
    pub autotune: AutotuneStats,
}

impl ServiceStats {
    /// The per-class counters for `kind`.
    pub fn class(&self, kind: ProblemKind) -> &ClassStats {
        match kind {
            ProblemKind::Mvc => &self.mvc,
            ProblemKind::Pvc => &self.pvc,
            ProblemKind::Mis => &self.mis,
        }
    }
}

/// Internal atomic accumulators behind [`ServiceStats`].
#[derive(Default)]
struct ClassAgg {
    jobs: AtomicU64,
    steals: AtomicU64,
    tree_nodes: AtomicU64,
    delta_children: AtomicU64,
    undo_pops: AtomicU64,
    materializations: AtomicU64,
    memo_lookups: AtomicU64,
    memo_hits: AtomicU64,
}

impl ClassAgg {
    fn snapshot(&self) -> ClassStats {
        ClassStats {
            jobs: self.jobs.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            tree_nodes: self.tree_nodes.load(Ordering::Relaxed),
            delta_children: self.delta_children.load(Ordering::Relaxed),
            undo_pops: self.undo_pops.load(Ordering::Relaxed),
            materializations: self.materializations.load(Ordering::Relaxed),
            memo_lookups: self.memo_lookups.load(Ordering::Relaxed),
            memo_hits: self.memo_hits.load(Ordering::Relaxed),
        }
    }
}

/// Per-worker queue-traffic publication slot: cumulative totals stored
/// by exactly one worker (single-writer relaxed stores), summed by
/// [`VcService::stats`]. Publishing totals instead of batched deltas
/// closes the old flush gap, where a worker's counters were missing
/// from a snapshot unless that worker happened to hit a 256-item flush
/// or an idle transition.
#[derive(Default)]
struct WorkerSlot {
    pushes: AtomicU64,
    pops: AtomicU64,
    shared_pops: AtomicU64,
    steals: AtomicU64,
    steal_retries: AtomicU64,
}

/// Shared counter block: workers publish queue-traffic totals into
/// their slot, finalization folds each job's engine stats into its
/// class half. `Arc`-shared between the service and every job so
/// finalize (which only sees the job) can attribute per-class counts.
struct ServiceCounters {
    /// One publication slot per resident worker.
    slots: Vec<WorkerSlot>,
    /// Service-side injections into the shared queue (see
    /// [`PoolStats::injected`]).
    injected: AtomicU64,
    classes: [ClassAgg; 3],
}

impl ServiceCounters {
    fn new(workers: usize) -> ServiceCounters {
        ServiceCounters {
            slots: (0..workers).map(|_| WorkerSlot::default()).collect(),
            injected: AtomicU64::new(0),
            classes: Default::default(),
        }
    }

    fn class(&self, kind: ProblemKind) -> &ClassAgg {
        match kind {
            ProblemKind::Mvc => &self.classes[0],
            ProblemKind::Pvc => &self.classes[1],
            ProblemKind::Mis => &self.classes[2],
        }
    }

    /// Publish a worker's cumulative counters into its slot (called
    /// after every processed item and on every idle transition).
    fn publish(&self, worker: usize, c: &WorkerCounters) {
        let s = &self.slots[worker];
        s.pushes.store(c.pushes, Ordering::Relaxed);
        s.pops.store(c.pops, Ordering::Relaxed);
        s.shared_pops.store(c.shared_pops, Ordering::Relaxed);
        s.steals.store(c.steals, Ordering::Relaxed);
        s.steal_retries.store(c.steal_retries, Ordering::Relaxed);
    }
}

/// DRR dispatch weights per lane (latency : throughput). A latency job
/// costs one deficit unit, so the latency lane drains up to 4 jobs per
/// throughput job while both are backlogged.
const LANE_WEIGHT: [u64; 2] = [4, 1];

/// Blocking admission re-checks quota headroom on this cadence: tenant
/// live-node counts drop as workers retire items, with no condvar to
/// signal space.
const ADMIT_WAIT_SLICE: Duration = Duration::from_millis(5);

/// How long a submitter is willing to wait for admission.
#[derive(Clone, Copy)]
enum Wait {
    /// Never block (`try_submit`).
    No,
    /// Block until space, up to the deadline (`None` = forever).
    Until(Option<Instant>),
}

/// Per-tenant admission accounting (lives as long as the service; the
/// tenant map is bounded by the number of distinct tenant ids seen).
#[derive(Default)]
struct TenantEntry {
    /// Jobs queued or running (admission → outcome publication).
    jobs: usize,
    /// Outstanding work items across the tenant's jobs, shared with
    /// each job as a [`TenantRef`].
    nodes: Arc<AtomicU64>,
}

/// Mutable admission state, guarded by one mutex: touched by
/// submitters (enqueue), the dispatcher (dequeue), and outcome
/// publication (release) — each a few queue operations, keeping
/// admission cheap.
#[derive(Default)]
struct AdmissionState {
    /// FIFO per lane, drained by weighted deficit round robin.
    lanes: [VecDeque<Arc<JobInner>>; 2],
    /// DRR deficits (replenished by [`LANE_WEIGHT`], capped at 4×).
    deficit: [u64; 2],
    /// DRR lane cursor.
    cursor: usize,
    /// Total queued jobs (both lanes).
    queued: usize,
    /// Dispatched, not-yet-finalized jobs.
    live_jobs: usize,
    tenants: HashMap<String, TenantEntry>,
}

impl AdmissionState {
    /// Pick the next lane to dispatch from by weighted deficit round
    /// robin. Caller guarantees `queued > 0`, so some lane is
    /// non-empty and the replenish loop terminates.
    fn pick_lane(&mut self) -> usize {
        loop {
            for _ in 0..2 {
                let l = self.cursor;
                if self.lanes[l].is_empty() {
                    // an empty lane forfeits its backlog credit
                    self.deficit[l] = 0;
                    self.cursor = (l + 1) % 2;
                    continue;
                }
                if self.deficit[l] > 0 {
                    self.deficit[l] -= 1;
                    return l;
                }
                self.cursor = (l + 1) % 2;
            }
            for l in 0..2 {
                if !self.lanes[l].is_empty() {
                    self.deficit[l] = (self.deficit[l] + LANE_WEIGHT[l]).min(4 * LANE_WEIGHT[l]);
                }
            }
        }
    }
}

/// The admission layer: a bounded two-lane submit queue with per-tenant
/// quotas, drained into the pool's injector by one dispatcher thread
/// (see the module docs, "Admission & QoS").
struct Admission {
    state: Mutex<AdmissionState>,
    /// Wakes the dispatcher: new work, a live-job release, or shutdown.
    work_cv: Condvar,
    /// Wakes blocked submitters: queue space or quota headroom freed.
    space_cv: Condvar,
    /// Latency-lane hint shared with the scheduler's fairness poll.
    lane_hint: Arc<LaneHint>,
    /// Admission queue bound (backpressure past it). Atomic so the
    /// self-tuning controller can re-plan it from live ledger bytes;
    /// an explicit [`VcServiceBuilder::max_queued`] pins it.
    max_queued: AtomicUsize,
    /// Dispatched-jobs bound; the dispatcher holds jobs back beyond it.
    max_live_jobs: usize,
    /// Lane classification threshold (reduced |V| ≤ it ⇒ latency).
    latency_threshold: usize,
    /// Per-tenant quotas (`None` = unlimited).
    quota: Option<TenantQuota>,
    shutdown: AtomicBool,
    rejected: AtomicU64,
    quota_rejected: AtomicU64,
    blocked_nanos: AtomicU64,
    dispatched: [AtomicU64; 2],
    /// Memory-watchdog ledger: live bytes across queued node payloads
    /// and live jobs' pinned occupancy charges.
    mem_live: AtomicU64,
    /// Soft limit: past it the dispatcher holds throughput-lane jobs
    /// back and new jobs are forced onto the delta node representation.
    mem_soft: u64,
    /// Hard limit: past it submissions are shed with
    /// [`SubmitError::MemoryPressure`].
    mem_hard: u64,
    mem_rejected: AtomicU64,
    /// Failed jobs awaiting sequential rescue, drained by the
    /// `cavc-svc-retry` thread (separate shutdown flag: the retry
    /// thread must outlive the workers, which can enqueue during their
    /// own shutdown drain).
    retry_queue: Mutex<VecDeque<Arc<JobInner>>>,
    retry_cv: Condvar,
    retry_shutdown: AtomicBool,
    retries: AtomicU64,
    recovered: AtomicU64,
    quarantined: AtomicU64,
}

impl Admission {
    /// Release a finalized job's admission accounting — the live-job
    /// slot and (tenanted jobs) the concurrent-jobs quota unit. Called
    /// exactly once per job, from the first-writer branch of
    /// [`store_outcome`].
    fn on_job_finalized(&self, tenant: Option<&TenantRef>) {
        let mut st = self.state.lock().unwrap();
        st.live_jobs = st.live_jobs.saturating_sub(1);
        if let Some(t) = tenant {
            if let Some(e) = st.tenants.get_mut(&t.name) {
                e.jobs = e.jobs.saturating_sub(1);
            }
        }
        drop(st);
        self.work_cv.notify_all();
        self.space_cv.notify_all();
    }

    fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Take the lock so a dispatcher between its check and its wait
        // cannot miss the wakeup.
        drop(self.state.lock().unwrap());
        self.work_cv.notify_all();
        self.space_cv.notify_all();
    }

    /// Charge bytes to the memory-watchdog ledger (queued payloads,
    /// pinned occupancy charges).
    fn mem_charge(&self, bytes: u64) {
        if bytes > 0 {
            self.mem_live.fetch_add(bytes, Ordering::Relaxed);
        }
    }

    /// Release bytes from the ledger (item retired, job finalized).
    fn mem_release(&self, bytes: u64) {
        if bytes > 0 {
            self.mem_live.fetch_sub(bytes, Ordering::Relaxed);
        }
    }

    /// Past the soft limit: hold throughput dispatch, force delta repr.
    fn mem_over_soft(&self) -> bool {
        self.mem_live.load(Ordering::Relaxed) > self.mem_soft
    }

    /// Past the hard limit: shed load at admission.
    fn mem_over_hard(&self) -> bool {
        self.mem_live.load(Ordering::Relaxed) > self.mem_hard
    }

    /// Hand a failed job to the recovery thread (true), or report that
    /// the job has no retry budget and must surface `Failed` (false).
    fn enqueue_retry(&self, job: &Arc<JobInner>) -> bool {
        if job.retry.is_none() {
            return false;
        }
        self.retry_queue.lock().unwrap().push_back(Arc::clone(job));
        self.retry_cv.notify_one();
        true
    }

    fn snapshot(&self) -> AdmissionStats {
        let st = self.state.lock().unwrap();
        AdmissionStats {
            queued: st.queued,
            live_jobs: st.live_jobs,
            rejected: self.rejected.load(Ordering::Relaxed),
            quota_rejected: self.quota_rejected.load(Ordering::Relaxed),
            blocked: Duration::from_nanos(self.blocked_nanos.load(Ordering::Relaxed)),
            dispatched_latency: self.dispatched[0].load(Ordering::Relaxed),
            dispatched_throughput: self.dispatched[1].load(Ordering::Relaxed),
            live_bytes: self.mem_live.load(Ordering::Relaxed),
            mem_rejected: self.mem_rejected.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            recovered: self.recovered.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
        }
    }
}

/// The memo cache charges its resident bytes to the same ledger the
/// memory watchdog reads, so a full cache shows up as pressure — and is
/// shed first when pressure arrives (module docs, degradation ladder).
impl MemoLedger for Admission {
    fn charge(&self, bytes: u64) {
        self.mem_charge(bytes);
    }
    fn release(&self, bytes: u64) {
        self.mem_release(bytes);
    }
}

/// The single-consumer dispatcher: drains the admission queue into the
/// pool's injector by DRR, gated on the live-jobs bound. Runs on its
/// own thread (`cavc-svc-admit`); exits once shutdown is requested and
/// the queue is drained, so held handles' `wait` calls still return.
fn dispatcher_loop(inner: &ServiceInner) {
    let adm = &inner.admission;
    loop {
        let (job, lane) = {
            let mut st = adm.state.lock().unwrap();
            loop {
                let draining = adm.shutdown.load(Ordering::SeqCst);
                // Memory watchdog, soft limit: stop feeding the pool
                // throughput-lane jobs (their node fan-out is what grows
                // the ledger); latency jobs still dispatch, and the
                // shutdown drain ignores the gate so `Drop` always
                // completes.
                let mut throttled = adm.mem_over_soft() && !draining;
                if throttled {
                    // First degradation rung: shed the memo cache — its
                    // bytes are pure speedup, never live search state —
                    // and re-check before holding throughput dispatch.
                    if let Some(m) = &inner.memo {
                        if m.shed() > 0 {
                            throttled = adm.mem_over_soft();
                        }
                    }
                }
                if st.queued > 0 && (st.live_jobs < adm.max_live_jobs || draining) {
                    let latency = Lane::Latency.index();
                    let lane = if throttled {
                        if st.lanes[latency].is_empty() {
                            // only throughput work queued: hold it until
                            // the ledger drops back under the soft limit
                            st = adm.work_cv.wait_timeout(st, ADMIT_WAIT_SLICE).unwrap().0;
                            continue;
                        }
                        latency
                    } else {
                        st.pick_lane()
                    };
                    let job = st.lanes[lane].pop_front().expect("picked lane is non-empty");
                    st.queued -= 1;
                    st.live_jobs += 1;
                    break (job, lane);
                }
                if draining && st.queued == 0 {
                    return;
                }
                st = adm.work_cv.wait(st).unwrap();
            }
        };
        adm.space_cv.notify_all();
        adm.dispatched[lane].fetch_add(1, Ordering::Relaxed);
        let urgent = lane == Lane::Latency.index();
        if urgent {
            // Raise the hint before the item is visible: every worker
            // then polls the shared queue on its next pop.
            adm.lane_hint.pending.fetch_add(1, Ordering::Relaxed);
        }
        inner.counters.injected.fetch_add(1, Ordering::Relaxed);
        inner.sched.inject(WorkItem { job, work: Work::Setup, urgent, bytes: 0 });
    }
}

struct ServiceInner {
    sched: ResidentSched,
    defaults: SolverConfig,
    /// Builder-level failure-recovery default ([`VcServiceBuilder::retry`]).
    default_retry: Option<RetryPolicy>,
    workers: usize,
    next_job: AtomicU64,
    counters: Arc<ServiceCounters>,
    admission: Arc<Admission>,
    /// Cross-job component memo cache ([`crate::solver::memo`]); `None`
    /// when the service was built with memoization disabled.
    memo: Option<Arc<MemoCache>>,
    /// Self-tuning controller state ([`crate::solver::autotune`]);
    /// `None` when the service runs with the controller off.
    tune: Option<Arc<TuneCtl>>,
}

/// Shared state between the service and its `cavc-svc-tune` thread.
struct TuneCtl {
    /// The controller blackboard jobs consult per dispatch.
    shared: Arc<TuneShared>,
    /// An explicit builder/env queue bound pins the admission re-plan.
    admission_pinned: bool,
    /// An explicit builder/env memo budget pins the budget re-plan.
    memo_pinned: bool,
    /// Shutdown flag + wakeup for the tuner thread's tick sleep.
    stop: Mutex<bool>,
    cv: Condvar,
}

/// Tick cadence of the controller thread: frequent enough to converge
/// within a short batch, cheap enough to be noise (each tick is a few
/// dozen relaxed loads and one decision pass).
const TUNE_TICK: Duration = Duration::from_millis(25);

/// Nominal queued-frame charge used for the queue-capacity re-plan: a
/// 1024-vertex u32 degree array, the latency-threshold-sized frame the
/// occupancy model's seed-time plan also assumes for mixed workloads.
const TUNE_NOMINAL_FRAME_BYTES: u64 = 4096;

/// The controller thread: every tick, fold the worker publication
/// slots into a pool-wide steal rate, re-plan admission/queue/memo
/// capacity from live ledger bytes through the occupancy model, apply
/// what is applicable live (admission bound, memo budget), and let the
/// [`Tuner`] decision pass move the per-width knobs.
fn tuner_loop(
    ctl: &TuneCtl,
    counters: &ServiceCounters,
    admission: &Admission,
    memo: Option<&Arc<MemoCache>>,
    occ: &OccupancyModel,
    workers: usize,
) {
    let mut tuner = Tuner::new(Arc::clone(&ctl.shared));
    loop {
        {
            let stop = ctl.stop.lock().unwrap();
            if *stop {
                return;
            }
            let (stop, _) = ctl.cv.wait_timeout(stop, TUNE_TICK).unwrap();
            if *stop {
                return;
            }
        }
        let mut steals = 0u64;
        let mut acquired = 0u64;
        for s in &counters.slots {
            let st = s.steals.load(Ordering::Relaxed);
            steals += st;
            acquired += s.pops.load(Ordering::Relaxed)
                + s.shared_pops.load(Ordering::Relaxed)
                + st;
        }
        let live = admission.mem_live.load(Ordering::Relaxed);
        let adm_cap = occ.replan_admission(live);
        let q_cap = occ.replan_queue_capacity(live, TUNE_NOMINAL_FRAME_BYTES, workers);
        if !ctl.admission_pinned {
            admission.max_queued.store(adm_cap, Ordering::Relaxed);
        }
        if !ctl.memo_pinned {
            if let Some(m) = memo {
                m.set_budget(occ.replan_memo_budget(live));
            }
        }
        tuner.tick(steals, acquired, adm_cap as u64, q_cap as u64);
    }
}

/// Builder for [`VcService`].
pub struct VcServiceBuilder {
    workers: Option<usize>,
    scheduler: SchedulerKind,
    queue_capacity: usize,
    defaults: SolverConfig,
    max_queued: Option<usize>,
    max_live_jobs: Option<usize>,
    latency_threshold: usize,
    quota: Option<TenantQuota>,
    retry: Option<RetryPolicy>,
    mem_soft: Option<u64>,
    mem_hard: Option<u64>,
    memo: Option<bool>,
    memo_bytes: Option<u64>,
    autotune: Option<bool>,
}

/// Default reduced-size cutoff for the latency lane: graphs this small
/// prep and solve in a latency-class time frame.
pub const DEFAULT_LATENCY_THRESHOLD: usize = 1024;

impl VcServiceBuilder {
    /// Number of resident worker threads (default: hardware threads).
    pub fn workers(mut self, n: usize) -> VcServiceBuilder {
        self.workers = Some(n.max(1));
        self
    }

    /// Scheduling runtime for the shared pool (default: work stealing).
    pub fn scheduler(mut self, kind: SchedulerKind) -> VcServiceBuilder {
        self.scheduler = kind;
        self
    }

    /// Initial per-worker queue capacity.
    pub fn queue_capacity(mut self, cap: usize) -> VcServiceBuilder {
        self.queue_capacity = cap.max(8);
        self
    }

    /// Default solver knobs applied to every job (component awareness,
    /// root reduction, bounds, dtypes, induce threshold, default
    /// timeout). The `variant`/`workers`/`scheduler` fields of the
    /// config are ignored — the pool shape is the builder's business.
    pub fn config(mut self, cfg: SolverConfig) -> VcServiceBuilder {
        self.defaults = cfg;
        self
    }

    /// Bound on the admission queue (default: the occupancy model's
    /// `admission_capacity`, charging queued jobs against the stack
    /// memory budget). A full queue rejects [`VcService::try_submit`]
    /// and blocks [`VcService::submit`].
    pub fn max_queued(mut self, n: usize) -> VcServiceBuilder {
        self.max_queued = Some(n.max(1));
        self
    }

    /// Bound on concurrently dispatched (not yet finalized) jobs;
    /// default `max(8 × workers, 32)`. The dispatcher holds further
    /// jobs in the admission queue beyond it — this is what lets the
    /// queue bound fill and exert backpressure.
    pub fn max_live_jobs(mut self, n: usize) -> VcServiceBuilder {
        self.max_live_jobs = Some(n.max(1));
        self
    }

    /// Reduced-graph size at or below which a job without an explicit
    /// [`JobOptions::priority`] is classified into the latency lane
    /// (default [`DEFAULT_LATENCY_THRESHOLD`]).
    pub fn latency_threshold(mut self, n: usize) -> VcServiceBuilder {
        self.latency_threshold = n;
        self
    }

    /// Enforce per-tenant quotas at admission for jobs submitted with
    /// [`JobOptions::tenant`] (default: no quotas).
    pub fn tenant_quota(mut self, q: TenantQuota) -> VcServiceBuilder {
        self.quota = Some(q);
        self
    }

    /// Default failure-recovery policy for every job (overridable per
    /// job via [`JobOptions::retry`]; default: none — a panicked job
    /// surfaces [`Termination::Failed`] without a sequential rescue).
    pub fn retry(mut self, policy: RetryPolicy) -> VcServiceBuilder {
        self.retry = Some(policy);
        self
    }

    /// Memory-watchdog soft limit in bytes (default: the occupancy
    /// model's `watchdog_soft_bytes`). Past it, the dispatcher stops
    /// feeding throughput-lane jobs into the pool and new jobs are
    /// forced onto the delta node representation.
    pub fn mem_soft(mut self, bytes: u64) -> VcServiceBuilder {
        self.mem_soft = Some(bytes);
        self
    }

    /// Memory-watchdog hard limit in bytes (default: the occupancy
    /// model's `watchdog_hard_bytes`). Past it, submissions are shed
    /// with [`SubmitError::MemoryPressure`].
    pub fn mem_hard(mut self, bytes: u64) -> VcServiceBuilder {
        self.mem_hard = Some(bytes);
        self
    }

    /// Enable or disable the cross-job component memo cache
    /// ([`crate::solver::memo`]) for this service (`--memo {on,off}` on
    /// the CLI). Default: the config's `memo`, then the `CAVC_MEMO`
    /// environment default, then on. `off` builds no cache at all — the
    /// ablation baseline with every memo path inert.
    pub fn memo(mut self, on: bool) -> VcServiceBuilder {
        self.memo = Some(on);
        self
    }

    /// Byte budget for the memo cache (`--memo-bytes N`; default:
    /// `CAVC_MEMO_BYTES`, then the occupancy model's
    /// `memo_budget_bytes`). Cache bytes are charged to the memory-
    /// watchdog ledger and evicted CLOCK-wise at the budget.
    pub fn memo_bytes(mut self, bytes: u64) -> VcServiceBuilder {
        self.memo_bytes = Some(bytes);
        self
    }

    /// Enable or disable the self-tuning controller
    /// ([`crate::solver::autotune`], `--autotune {on,off}` on the CLI).
    /// Default: the config's `autotune`, then the `CAVC_AUTOTUNE`
    /// environment default, then on. `off` spawns no tuner thread and
    /// attaches no tune handle to jobs — every knob runs at its static
    /// configured value, the ablation baseline. Explicit static knobs
    /// pin their own dimension even with the controller on (see the
    /// module docs, "Self-tuning controller").
    pub fn autotune(mut self, on: bool) -> VcServiceBuilder {
        self.autotune = Some(on);
        self
    }

    /// Spawn the worker pool and return the service.
    pub fn build(self) -> VcService {
        let workers = self.workers.unwrap_or_else(|| {
            std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4)
        });
        let sched = match self.scheduler {
            SchedulerKind::WorkSteal => {
                ResidentSched::Steal(WorkStealScheduler::new_resident(workers, self.queue_capacity))
            }
            SchedulerKind::Sharded => ResidentSched::Sharded(ShardedScheduler::new_resident(
                workers,
                self.queue_capacity,
            )),
        };
        let occ = OccupancyModel::default();
        let admission = Arc::new(Admission {
            state: Mutex::new(AdmissionState::default()),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            lane_hint: sched.lane_hint(),
            max_queued: AtomicUsize::new(
                self.max_queued.unwrap_or_else(|| occ.admission_capacity()),
            ),
            max_live_jobs: self.max_live_jobs.unwrap_or((workers * 8).max(32)),
            latency_threshold: self.latency_threshold,
            quota: self.quota,
            shutdown: AtomicBool::new(false),
            rejected: AtomicU64::new(0),
            quota_rejected: AtomicU64::new(0),
            blocked_nanos: AtomicU64::new(0),
            dispatched: [AtomicU64::new(0), AtomicU64::new(0)],
            mem_live: AtomicU64::new(0),
            mem_soft: self.mem_soft.unwrap_or_else(|| occ.watchdog_soft_bytes()),
            mem_hard: self.mem_hard.unwrap_or_else(|| occ.watchdog_hard_bytes()),
            mem_rejected: AtomicU64::new(0),
            retry_queue: Mutex::new(VecDeque::new()),
            retry_cv: Condvar::new(),
            retry_shutdown: AtomicBool::new(false),
            retries: AtomicU64::new(0),
            recovered: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
        });
        // Memo cache: builder override → config → CAVC_MEMO env → on.
        let memo_on = self
            .memo
            .or(self.defaults.memo)
            .or_else(memo::env_memo_default)
            .unwrap_or(true);
        let memo = memo_on.then(|| {
            let budget = self
                .memo_bytes
                .or_else(memo::env_memo_bytes)
                .unwrap_or_else(|| occ.memo_budget_bytes());
            Arc::new(MemoCache::new(budget, Some(Arc::clone(&admission) as Arc<dyn MemoLedger>)))
        });
        // Self-tuning controller: builder override → config →
        // CAVC_AUTOTUNE env → on.
        let tune_on = self
            .autotune
            .or(self.defaults.autotune)
            .or_else(autotune::env_autotune_default)
            .unwrap_or(true);
        // An explicit queue bound or memo budget (builder or env) pins
        // that dimension: the controller re-plans only defaults.
        let tune = tune_on.then(|| {
            Arc::new(TuneCtl {
                shared: Arc::new(TuneShared::new()),
                admission_pinned: self.max_queued.is_some(),
                memo_pinned: self.memo_bytes.is_some() || memo::env_memo_bytes().is_some(),
                stop: Mutex::new(false),
                cv: Condvar::new(),
            })
        });
        let inner = Arc::new(ServiceInner {
            sched,
            defaults: self.defaults,
            default_retry: self.retry,
            workers,
            next_job: AtomicU64::new(0),
            counters: Arc::new(ServiceCounters::new(workers)),
            admission,
            memo,
            tune,
        });
        let threads = (0..workers)
            .map(|w| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("cavc-svc-{w}"))
                    .spawn(move || match &inner.sched {
                        ResidentSched::Steal(s) => resident_loop(s, w, &inner.counters),
                        ResidentSched::Sharded(s) => resident_loop(s, w, &inner.counters),
                    })
                    .expect("spawn service worker")
            })
            .collect();
        let dispatcher = {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name("cavc-svc-admit".into())
                .spawn(move || dispatcher_loop(&inner))
                .expect("spawn admission dispatcher")
        };
        let recovery = {
            let adm = Arc::clone(&inner.admission);
            std::thread::Builder::new()
                .name("cavc-svc-retry".into())
                .spawn(move || recovery_loop(&adm))
                .expect("spawn recovery thread")
        };
        let tuner = inner.tune.as_ref().map(|_| {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name("cavc-svc-tune".into())
                .spawn(move || {
                    let ctl = inner.tune.as_ref().expect("tuner spawned with tune state");
                    tuner_loop(
                        ctl,
                        &inner.counters,
                        &inner.admission,
                        inner.memo.as_ref(),
                        &OccupancyModel::default(),
                        inner.workers,
                    )
                })
                .expect("spawn tuner thread")
        });
        VcService { inner, threads, dispatcher: Some(dispatcher), recovery: Some(recovery), tuner }
    }
}

/// A resident vertex-cover solver service (see the module docs).
///
/// Dropping the service requests shutdown and joins the workers after
/// they drain every outstanding job — held [`JobHandle`]s stay valid and
/// their `wait` calls return.
pub struct VcService {
    inner: Arc<ServiceInner>,
    threads: Vec<JoinHandle<()>>,
    dispatcher: Option<JoinHandle<()>>,
    recovery: Option<JoinHandle<()>>,
    tuner: Option<JoinHandle<()>>,
}

impl VcService {
    /// Start building a service.
    pub fn builder() -> VcServiceBuilder {
        VcServiceBuilder {
            workers: None,
            scheduler: SchedulerKind::default(),
            queue_capacity: engine::DEFAULT_QUEUE_CAPACITY,
            defaults: SolverConfig::proposed(),
            max_queued: None,
            max_live_jobs: None,
            latency_threshold: DEFAULT_LATENCY_THRESHOLD,
            quota: None,
            retry: None,
            mem_soft: None,
            mem_hard: None,
            memo: None,
            memo_bytes: None,
            autotune: None,
        }
    }

    /// Number of resident worker threads.
    pub fn workers(&self) -> usize {
        self.inner.workers
    }

    /// Submit a problem with the service's default options, blocking
    /// while the admission queue is full (bounded variants:
    /// [`VcService::try_submit`], [`VcService::submit_within`]).
    pub fn submit(&self, problem: Problem) -> JobHandle {
        self.submit_with(problem, JobOptions::default())
    }

    /// Submit a problem with per-job options, blocking while the
    /// admission queue is full or the tenant is over quota.
    pub fn submit_with(&self, problem: Problem, opts: JobOptions) -> JobHandle {
        match self.admit(problem, opts, Wait::Until(None)) {
            Ok(h) => h,
            Err(_) => unreachable!("unbounded admission wait cannot be rejected"),
        }
    }

    /// Non-blocking submit with default options: [`SubmitError`] when
    /// the admission queue is full or the tenant is over quota.
    pub fn try_submit(&self, problem: Problem) -> Result<JobHandle, SubmitError> {
        self.try_submit_with(problem, JobOptions::default())
    }

    /// Non-blocking submit with per-job options — the backpressure
    /// primitive: never waits, never grows the queue past its bound.
    pub fn try_submit_with(
        &self,
        problem: Problem,
        opts: JobOptions,
    ) -> Result<JobHandle, SubmitError> {
        self.admit(problem, opts, Wait::No)
    }

    /// Blocking submit that gives up after `wait`: the deadline-bounded
    /// middle ground between [`VcService::submit`] (waits forever) and
    /// [`VcService::try_submit_with`] (never waits).
    pub fn submit_within(
        &self,
        problem: Problem,
        opts: JobOptions,
        wait: Duration,
    ) -> Result<JobHandle, SubmitError> {
        self.admit(problem, opts, Wait::Until(Some(Instant::now() + wait)))
    }

    /// The admission gate: classify the job's lane, wait for (or bounce
    /// off) queue space and tenant quota, charge the quota, and enqueue
    /// for the dispatcher.
    fn admit(
        &self,
        problem: Problem,
        opts: JobOptions,
        wait: Wait,
    ) -> Result<JobHandle, SubmitError> {
        let adm = &self.inner.admission;
        let started = Instant::now();
        let cfg = opts.config.as_ref().unwrap_or(&self.inner.defaults);
        let lane = opts.priority.unwrap_or_else(|| {
            // Admission-time estimate from the raw input size; prep
            // refines it from the reduced size (see `setup_job`).
            if problem.graph().num_vertices() <= adm.latency_threshold {
                Lane::Latency
            } else {
                Lane::Throughput
            }
        });
        // Memo participation: per-job override → job config → on (the
        // service-level gate is whether a cache exists at all). PVC
        // jobs consume the cache but never publish — their bound-pruned
        // subtrees are not exact component solutions.
        let job_id = self.inner.next_job.fetch_add(1, Ordering::SeqCst);
        let memo_on = opts.memo.or(cfg.memo).unwrap_or(true);
        let job_memo = match (&self.inner.memo, memo_on) {
            (Some(cache), true) => Some(Arc::new(JobMemo::new(
                job_id,
                Arc::clone(cache),
                !matches!(problem, Problem::Pvc { .. }),
            ))),
            _ => None,
        };
        // Controller participation is per *knob*: an explicitly static
        // knob (non-default config value, or CAVC_NODE_REPR) pins its
        // dimension and the controller never overrides it — ablation
        // baselines stay exact. The watchdog's forced-delta override is
        // checked upstream of the tune handle (`JobCtl::repr_for`). A
        // job whose config says `autotune: Some(false)` opts out of
        // consultation entirely, even on a tuner-enabled service (the
        // one-shot shims route ablation configs through the shared
        // default service).
        let job_tune = if cfg.autotune == Some(false) {
            None
        } else {
            self.inner.tune.as_ref().map(|t| {
                Arc::new(JobTune {
                    shared: Arc::clone(&t.shared),
                    tune_repr: cfg.node_repr == NodeRepr::Owned
                        && std::env::var_os("CAVC_NODE_REPR").is_none(),
                    tune_pin: cfg.max_pin_depth == engine::DEFAULT_MAX_PIN_DEPTH,
                    tune_induce: cfg.induce_threshold == engine::DEFAULT_INDUCE_THRESHOLD,
                })
            })
        };
        let job_cfg = JobCfg {
            component_aware: cfg.component_aware,
            use_bounds: cfg.use_bounds,
            stop_on_improvement: matches!(problem, Problem::Pvc { .. }),
            // The clock starts now: time blocked in admission counts
            // against the job's deadline.
            deadline: opts.timeout.or(cfg.timeout).map(|t| started + t),
            // Per-activity timers are per-worker, not per-job; resident
            // jobs track counters (incl. byte accounting) only.
            instrument: false,
            induce_threshold: cfg.induce_threshold,
            extract_witness: opts.extract_witness || cfg.extract_cover,
            node_repr: cfg.node_repr,
            max_pin_depth: cfg.max_pin_depth,
            fault: opts
                .fault
                .clone()
                .or_else(super::faults::FaultPlan::from_env)
                .map(|plan| Arc::new(super::faults::FaultInjector::new(plan))),
            memo: job_memo,
            tune: job_tune,
        };
        let prep_cfg = cfg.prep_cfg();

        let mut st = adm.state.lock().unwrap();
        loop {
            // Memory watchdog, hard limit: shed load. Non-blocking
            // submits bounce immediately; blocking ones wait for the
            // ledger to drop (it frees as queued items retire). The memo
            // cache goes first — dropping pure-speedup bytes beats
            // refusing a submit (degradation ladder, module docs).
            let mut over_mem = adm.mem_over_hard();
            if over_mem {
                if let Some(m) = &self.inner.memo {
                    if m.shed() > 0 {
                        over_mem = adm.mem_over_hard();
                    }
                }
            }
            let full = st.queued >= adm.max_queued.load(Ordering::Relaxed);
            let over_quota = match (&opts.tenant, &adm.quota) {
                (Some(name), Some(q)) => match st.tenants.get(name) {
                    Some(e) => {
                        e.jobs >= q.max_jobs
                            || e.nodes.load(Ordering::Relaxed) >= q.max_live_nodes
                    }
                    None => false,
                },
                _ => false,
            };
            if !over_mem && !full && !over_quota {
                break;
            }
            let now = Instant::now();
            let expired = match wait {
                Wait::No => true,
                Wait::Until(None) => false,
                Wait::Until(Some(d)) => now >= d,
            };
            if expired {
                return Err(if over_mem {
                    adm.mem_rejected.fetch_add(1, Ordering::Relaxed);
                    SubmitError::MemoryPressure
                } else if over_quota {
                    // Documented shed order (module docs): quota beats
                    // queue-full — a tenant at quota is told so even
                    // when the queue is also at capacity, so its
                    // backoff targets the right resource.
                    adm.quota_rejected.fetch_add(1, Ordering::Relaxed);
                    SubmitError::QuotaExceeded
                } else {
                    adm.rejected.fetch_add(1, Ordering::Relaxed);
                    SubmitError::QueueFull
                });
            }
            // Quota headroom (live-node counts) frees without a
            // notifier, so cap each wait slice and re-check.
            let slice = match wait {
                Wait::Until(Some(d)) => (d - now).min(ADMIT_WAIT_SLICE),
                _ => ADMIT_WAIT_SLICE,
            };
            st = adm.space_cv.wait_timeout(st, slice).unwrap().0;
            adm.blocked_nanos.fetch_add(now.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        // Admitted: charge the tenant (jobs slot + the Setup item's
        // node) and enqueue under the same lock, so concurrent admits
        // can never overshoot the quota between check and charge.
        let tenant = opts.tenant.as_ref().map(|name| {
            let e = st.tenants.entry(name.clone()).or_default();
            e.jobs += 1;
            e.nodes.fetch_add(1, Ordering::Relaxed);
            TenantRef { name: name.clone(), nodes: Arc::clone(&e.nodes) }
        });
        let job = Arc::new(JobInner {
            id: job_id,
            ctl: JobCtl::new(job_cfg, u32::MAX),
            prep_cfg,
            live_nodes: AtomicU64::new(1), // the Setup item
            cancelled: AtomicBool::new(false),
            failed: AtomicBool::new(false),
            failure: Mutex::new(None),
            retry: opts.retry.or(self.inner.default_retry),
            pinned_charge: AtomicU64::new(0),
            prepared: OnceLock::new(),
            outcome: Mutex::new(None),
            done_cv: Condvar::new(),
            started,
            pool_workers: self.inner.workers,
            counters: Arc::clone(&self.inner.counters),
            lane: AtomicU8::new(lane.index() as u8),
            explicit_lane: opts.priority.is_some(),
            latency_threshold: adm.latency_threshold,
            tenant,
            admission: Arc::clone(adm),
            #[cfg(test)]
            panic_in_setup: opts.panic_in_setup,
            problem,
        });
        st.lanes[lane.index()].push_back(Arc::clone(&job));
        st.queued += 1;
        drop(st);
        adm.work_cv.notify_one();
        Ok(JobHandle { job })
    }

    /// Submit-and-wait convenience for one problem.
    pub fn solve(&self, problem: Problem) -> Solution {
        self.submit(problem).wait()
    }

    /// Snapshot the pool-level scheduler counters, the admission-layer
    /// counters, and the per-job-class breakdown (steals / parks /
    /// materializations…): the ROADMAP "Service QoS" telemetry
    /// endpoint. Every worker publishes its cumulative queue-traffic
    /// counters after each processed item, so a snapshot folds all
    /// residual deltas at read time — it can trail the true totals only
    /// by the items currently being processed; class counters for
    /// *finalized* jobs are exact.
    pub fn stats(&self) -> ServiceStats {
        let c = &self.inner.counters;
        let mut pool = PoolStats {
            injected: c.injected.load(Ordering::Relaxed),
            parks: self.inner.sched.parks(),
            backlog: self.inner.sched.backlog(),
            ..PoolStats::default()
        };
        for s in &c.slots {
            pool.pushes += s.pushes.load(Ordering::Relaxed);
            pool.pops += s.pops.load(Ordering::Relaxed);
            pool.shared_pops += s.shared_pops.load(Ordering::Relaxed);
            pool.steals += s.steals.load(Ordering::Relaxed);
            pool.steal_retries += s.steal_retries.load(Ordering::Relaxed);
        }
        ServiceStats {
            pool,
            admission: self.inner.admission.snapshot(),
            mvc: c.classes[0].snapshot(),
            pvc: c.classes[1].snapshot(),
            mis: c.classes[2].snapshot(),
            memo: self.inner.memo.as_ref().map(|m| m.stats()).unwrap_or_default(),
            autotune: self.inner.tune.as_ref().map(|t| t.shared.stats(true)).unwrap_or_default(),
        }
    }
}

impl Drop for VcService {
    fn drop(&mut self) {
        // Order matters: the tuner goes first (it only reads counters
        // and re-plans capacities — stopping it early just freezes the
        // knobs at their last decision), then the admission queue
        // drains into the scheduler (the dispatcher exits only once it
        // is empty), then the pool drains and exits — held handles'
        // `wait` calls return (the drop-drains contract). The recovery
        // thread goes last: draining workers can still hand it failed
        // jobs, and every one of those must publish an outcome before
        // the service is gone.
        if let Some(t) = &self.inner.tune {
            *t.stop.lock().unwrap() = true;
            t.cv.notify_all();
        }
        if let Some(t) = self.tuner.take() {
            let _ = t.join();
        }
        self.inner.admission.request_shutdown();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        self.inner.sched.request_shutdown();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        let adm = &self.inner.admission;
        adm.retry_shutdown.store(true, Ordering::SeqCst);
        // Same lock-then-notify shape as `request_shutdown`: a recovery
        // thread between its check and its wait cannot miss the wakeup.
        drop(adm.retry_queue.lock().unwrap());
        adm.retry_cv.notify_all();
        if let Some(r) = self.recovery.take() {
            let _ = r.join();
        }
    }
}

impl std::fmt::Debug for VcService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("VcService").field("workers", &self.inner.workers).finish()
    }
}

/// The process-wide default service used by the `solve_mvc`/`solve_pvc`
/// one-shot shims for service-compatible configurations. Built lazily on
/// first use with hardware-thread workers; lives for the process (idle
/// cost is a few parked-timeout wakeups per second).
pub fn default_service() -> &'static VcService {
    static DEFAULT: OnceLock<VcService> = OnceLock::new();
    DEFAULT.get_or_init(|| VcService::builder().build())
}

// ---------------------------------------------------------------------
// Resident worker loop
// ---------------------------------------------------------------------

/// Per-worker, per-dtype engine scratch, persistent across jobs.
struct Scratch {
    u8: WorkerCtx<u8>,
    u16: WorkerCtx<u16>,
    u32: WorkerCtx<u32>,
}

impl Scratch {
    fn new(worker: usize) -> Scratch {
        Scratch {
            u8: WorkerCtx::new(worker, 0, false),
            u16: WorkerCtx::new(worker, 0, false),
            u32: WorkerCtx::new(worker, 0, false),
        }
    }
}

fn resident_loop<S: Scheduler<WorkItem>>(sched: &S, worker: usize, counters: &ServiceCounters) {
    let mut scratch = Scratch::new(worker);
    let mut handle = sched.handle(worker);
    loop {
        match handle.pop_traced() {
            Some((item, src)) => {
                if src == PopSource::Stolen {
                    // Steals *are* attributable to a class: the stolen
                    // item carries its job.
                    counters
                        .class(item.job.problem.kind())
                        .steals
                        .fetch_add(1, Ordering::Relaxed);
                }
                process_item(item, &mut scratch, &mut handle, sched, src);
                handle.on_node_done();
                counters.publish(worker, &handle.counters());
            }
            None => {
                counters.publish(worker, &handle.counters());
                // An idle worker's suspended delta frames are
                // unreachable (no queued item can match them anymore);
                // recycle them so a finished big job's frames don't
                // stay resident across unrelated later jobs.
                scratch.u8.drain_descents();
                scratch.u16.drain_descents();
                scratch.u32.drain_descents();
                if let IdleOutcome::Finished = handle.idle_step() {
                    return;
                }
            }
        }
    }
}

fn process_item<S: Scheduler<WorkItem>, H: WorkerHandle<WorkItem>>(
    item: WorkItem,
    scratch: &mut Scratch,
    handle: &mut H,
    sched: &S,
    src: PopSource,
) {
    let WorkItem { job, work, urgent, bytes } = item;
    if urgent {
        // Pairs with the pre-inject bump: the urgent item has left the
        // shared queue, so the every-pop fairness poll can relax again.
        job.admission.lane_hint.pending.fetch_sub(1, Ordering::Relaxed);
    }
    // Contain panics (debug assertions, engine bugs): the one-shot
    // engine propagates them through `thread::scope`, but a resident
    // worker must survive — an escaped panic here would kill the thread
    // with the live-count decrement below unexecuted, hanging every
    // `wait` on the job. The scratch stays structurally valid across an
    // unwind (plain buffers and counters), so it may keep serving other
    // jobs.
    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match work {
        Work::Setup => setup_job(&job, handle, sched),
        Work::Node(node) => {
            job.ctl.check_deadline();
            // A stopped job (cancelled, past-deadline, or PVC already
            // answered) drops its node here; the decrement below still
            // counts it, so the job drains to finalization at pop speed.
            if !job.ctl.stop.load(Ordering::SeqCst) {
                let p = job.prepared.get().expect("node processed before its job's setup");
                match node {
                    AnyNode::U8(n) => run_node(&job, p, n, &mut scratch.u8, handle, src),
                    AnyNode::U16(n) => run_node(&job, p, n, &mut scratch.u16, handle, src),
                    AnyNode::U32(n) => run_node(&job, p, n, &mut scratch.u32, handle, src),
                }
            }
        }
    }));
    if let Err(payload) = run {
        record_failure(&job, &payload);
        // Poison, then label, then stop (same ordering argument as
        // `cancel`): a failed job's truncated folds must not publish to
        // the memo cache, and the job's remaining nodes drain as drops
        // so the normal completion count finalizes it with
        // `Termination::Failed`.
        if let Some(m) = &job.ctl.cfg.memo {
            m.poison();
        }
        job.failed.store(true, Ordering::SeqCst);
        job.ctl.stop.store(true, Ordering::SeqCst);
    }
    // Release the retired item's memory-ledger and tenant-quota charges
    // (each mirrors every `live_nodes` increment) — this is the
    // admission layer's release point on the node axis.
    job.admission.mem_release(bytes);
    if let Some(t) = &job.tenant {
        t.nodes.fetch_sub(1, Ordering::Relaxed);
    }
    if job.live_nodes.fetch_sub(1, Ordering::SeqCst) == 1 {
        // `finalize` itself can assert (debug registry invariants) or
        // carry an injected fault; a panic there must not leave waiters
        // hanging either.
        if let Err(payload) =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| finalize(&job)))
        {
            record_failure(&job, &payload);
            if let Some(m) = &job.ctl.cfg.memo {
                m.poison();
                m.retract();
            }
            job.failed.store(true, Ordering::SeqCst);
            // A finalize panic still gets the degradation ladder's
            // sequential-rescue rung before surfacing `Failed`.
            if !job.admission.enqueue_retry(&job) {
                store_outcome(&job, failed_solution(&job));
            }
        }
    }
}

/// Capture a contained panic's payload: store the first message on the
/// job (the others only count), log it once, and bump the job's panic
/// counter in its stats sink.
fn record_failure(job: &Arc<JobInner>, payload: &(dyn std::any::Any + Send)) {
    let msg = panic_message(payload);
    {
        let mut slot = job.failure.lock().unwrap();
        if slot.is_none() {
            // One log line per job, through the same sink `stats()`
            // reads — repeated panics of one job would otherwise spam.
            eprintln!("cavc-svc: job {} worker panic: {msg}", job.id);
            *slot = Some(msg);
        }
    }
    job.ctl.stats_sink.lock().unwrap().panics += 1;
}

/// Best-effort extraction of a panic payload's message (`panic!` with a
/// literal yields `&str`, with formatting yields `String`).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic payload of unknown type".to_string()
    }
}

/// Run one search node of a job through the engine's node processor,
/// wrapping the pool handle so children are re-tagged with the job.
fn run_node<T: DegElem, H: WorkerHandle<WorkItem>>(
    job: &Arc<JobInner>,
    p: &JobPrep,
    node: NodePayload<T>,
    ctx: &mut WorkerCtx<T>,
    handle: &mut H,
    src: PopSource,
) where
    AnyNode: From<NodePayload<T>>,
{
    ctx.ensure_graph(p.graph.num_vertices());
    let view = JobView { g: p.graph.as_ref(), ctl: &job.ctl };
    let mut push = JobPush { job, inner: handle };
    engine::process(&view, ctx, &mut push, node, src);
    // Flush per item, not per job-switch: any decrement of the job's
    // live count may be the final one, and the finalizing worker must
    // observe complete stats in the sink. The lock is per *descent*
    // (one pop may expand a whole left spine), so it amortizes over
    // many tree nodes — cheaper than the sharded runtime's two RMWs
    // per node, which the benches accept as the baseline.
    ctx.flush_stats_into(&job.ctl);
}

/// Push-only [`WorkerHandle`] adapter: the engine's node processor sees
/// a typed handle, the pool sees job-tagged [`WorkItem`]s.
struct JobPush<'a, H> {
    job: &'a Arc<JobInner>,
    inner: &'a mut H,
}

impl<T: DegElem, H: WorkerHandle<WorkItem>> WorkerHandle<NodePayload<T>> for JobPush<'_, H>
where
    AnyNode: From<NodePayload<T>>,
{
    fn push(&mut self, item: NodePayload<T>) {
        // Increment before the item becomes visible so the job's live
        // count can never reach zero while a node sits in a queue.
        self.job.live_nodes.fetch_add(1, Ordering::SeqCst);
        if let Some(t) = &self.job.tenant {
            t.nodes.fetch_add(1, Ordering::Relaxed);
        }
        // Charge the queued payload to the pool-level memory ledger
        // (released when the item retires in `process_item`).
        let bytes = item.payload_bytes();
        self.job.admission.mem_charge(bytes);
        self.inner.push(WorkItem {
            job: Arc::clone(self.job),
            work: Work::Node(AnyNode::from(item)),
            urgent: false,
            bytes,
        });
    }

    fn pop_traced(&mut self) -> Option<(NodePayload<T>, PopSource)> {
        unreachable!("job adapter is push-only; the resident loop owns pops")
    }

    fn on_node_done(&mut self) {
        unreachable!("job adapter is push-only; the resident loop owns node accounting")
    }

    fn idle_step(&mut self) -> IdleOutcome {
        unreachable!("job adapter is push-only; the resident loop owns idling")
    }

    fn counters(&self) -> WorkerCounters {
        WorkerCounters::default()
    }
}

/// The job-setup stage, run on a worker: preparation pipeline, initial
/// bound, lane refinement, trivial answers, and the root-node push.
fn setup_job<S: Scheduler<WorkItem>, H: WorkerHandle<WorkItem>>(
    job: &Arc<JobInner>,
    handle: &mut H,
    sched: &S,
) {
    #[cfg(test)]
    if job.panic_in_setup {
        panic!("injected setup panic (test hook)");
    }
    if let Some(f) = &job.ctl.cfg.fault {
        f.on_setup();
    }
    // Memory watchdog, soft limit: new jobs branch under the compact
    // delta representation regardless of their configured repr, so
    // their queued right children cost O(delta) instead of O(view).
    if job.admission.mem_over_soft() {
        job.ctl.forced_delta.store(true, Ordering::Relaxed);
    }
    let g: &Graph = job.problem.graph();
    let (p, k) = match &job.problem {
        // ub = k+1 keeps the high-degree rule sound for covers ≤ k.
        Problem::Pvc { k, .. } => {
            (prep::prepare(g, &job.prep_cfg, Some(k.saturating_add(1))), Some(*k))
        }
        _ => (prep::prepare(g, &job.prep_cfg, None), None),
    };
    let forced = p.forced_cover.len() as u32;
    let n_resid = p.residual.graph.num_vertices();
    // Prep-time QoS classification (the issue's "classified cheaply at
    // prep time by reduced-graph size"): the admission-time estimate
    // used the raw input size, which over-classifies reducible graphs
    // into the throughput lane. Explicit priorities are never touched.
    if !job.explicit_lane {
        let lane = if n_resid <= job.latency_threshold { Lane::Latency } else { Lane::Throughput };
        job.lane.store(lane.index() as u8, Ordering::Relaxed);
    }
    let summary = PrepSummary {
        n_original: g.num_vertices(),
        n_residual: n_resid,
        forced: forced as usize,
        greedy_ub: p.greedy_ub,
        dtype: p.dtype,
        blocks: p.occupancy.blocks,
        fits_shared_mem: p.occupancy.fits_shared_mem,
        workers: job.pool_workers,
    };
    // Charge the occupancy plan's pinned bytes (delta-mode base frames)
    // to the memory ledger for the job's lifetime; released exactly
    // once at outcome publication.
    let pinned = p.occupancy.pinned_bytes;
    if pinned > 0 {
        job.admission.mem_charge(pinned);
        job.pinned_charge.store(pinned, Ordering::SeqCst);
    }

    let (initial, k_resid, decided) = match k {
        None => (p.residual_ub, None, None),
        Some(k) => {
            if p.greedy_ub <= k {
                (0, None, Some(PvcDecided::FoundGreedy(p.greedy_ub)))
            } else if forced > k {
                (0, None, Some(PvcDecided::Infeasible))
            } else {
                let k_resid = k - forced;
                ((k_resid + 1).min(n_resid as u32 + 1), Some(k_resid), None)
            }
        }
    };

    // The lift must be captured before the residual graph moves into
    // the job (it clones the induction map + reduction unwind).
    let lift = job.ctl.cfg.extract_witness.then(|| p.cover_lift());
    let graph = Arc::new(p.residual.graph);
    // Publish the bound before any node can observe it (the root is
    // pushed below, after the store). `initial` doubles as the
    // reference for the witnessed-stop gate.
    job.ctl.best.store(initial, Ordering::SeqCst);
    job.ctl.initial.store(initial, Ordering::SeqCst);

    // A job stopped before its search begins (trivial PVC answer,
    // pre-expired deadline, early cancel) pushes no root.
    job.ctl.check_deadline();
    let start_search = decided.is_none() && !job.ctl.stop.load(Ordering::SeqCst);
    let (root, root_bytes) = if start_search {
        let root = match p.dtype {
            Dtype::U8 => AnyNode::U8(NodePayload::Owned(engine::make_root::<u8>(&graph))),
            Dtype::U16 => AnyNode::U16(NodePayload::Owned(engine::make_root::<u16>(&graph))),
            Dtype::U32 => AnyNode::U32(NodePayload::Owned(engine::make_root::<u32>(&graph))),
        };
        let bytes = match &root {
            AnyNode::U8(n) => n.payload_bytes(),
            AnyNode::U16(n) => n.payload_bytes(),
            AnyNode::U32(n) => n.payload_bytes(),
        };
        (Some(root), bytes)
    } else {
        (None, 0)
    };

    let prep_record = JobPrep {
        graph,
        initial,
        forced,
        greedy_ub: p.greedy_ub,
        k_resid,
        lift,
        summary,
        root_bytes,
        root_pushed: root.is_some(),
        decided,
    };
    // Publish prep before the root enters the worklist: any worker that
    // pops a node of this job must see it.
    let _ = job.prepared.set(prep_record);

    if let Some(root) = root {
        job.live_nodes.fetch_add(1, Ordering::SeqCst);
        if let Some(t) = &job.tenant {
            t.nodes.fetch_add(1, Ordering::Relaxed);
        }
        job.admission.mem_charge(root_bytes);
        let urgent = job.lane() == Lane::Latency;
        let item =
            WorkItem { job: Arc::clone(job), work: Work::Node(root), urgent, bytes: root_bytes };
        if urgent {
            // Inject latency roots through the shared queue with the
            // lane hint raised: a handle.push would land the root on
            // this worker's private stack (or a FIFO shard) behind
            // whatever big job's nodes are already queued — exactly the
            // delay the latency lane exists to avoid.
            job.admission.lane_hint.pending.fetch_add(1, Ordering::Relaxed);
            job.counters.injected.fetch_add(1, Ordering::Relaxed);
            sched.inject(item);
        } else {
            handle.push(item);
        }
    }
}

/// Publish a finished job's solution (first writer wins) and wake the
/// waiters. The first writer also releases the job's admission
/// accounting (live-job slot + tenant jobs quota) — exactly once per
/// job, on every exit path (complete, cancelled, deadline, panic).
fn store_outcome(job: &Arc<JobInner>, solution: Solution) {
    let first = {
        let mut out = job.outcome.lock().unwrap();
        let first = out.is_none();
        if first {
            *out = Some(solution);
        }
        job.done_cv.notify_all();
        first
    };
    if first {
        // Release the setup-time pinned-bytes charge exactly once,
        // alongside the admission accounting.
        job.admission.mem_release(job.pinned_charge.swap(0, Ordering::SeqCst));
        job.admission.on_job_finalized(job.tenant.as_ref());
    }
}

/// Degenerate outcome for a job whose setup or finalization panicked:
/// no trustworthy objective, but `wait` must still return.
fn failed_solution(job: &Arc<JobInner>) -> Solution {
    let g = job.problem.graph();
    let prep = match job.prepared.get() {
        Some(p) => p.summary.clone(),
        None => PrepSummary {
            n_original: g.num_vertices(),
            n_residual: 0,
            forced: 0,
            greedy_ub: 0,
            dtype: Dtype::U32,
            blocks: 0,
            fits_shared_mem: false,
            workers: job.pool_workers,
        },
    };
    Solution {
        problem: job.problem.kind(),
        objective: 0,
        feasible: false,
        witness: None,
        witness_verified: None,
        stats: job.ctl.stats_sink.lock().unwrap().clone(),
        prep,
        elapsed: job.started.elapsed(),
        termination: Termination::Failed,
        failure: job.failure.lock().unwrap().clone(),
    }
}

/// Assemble the [`Solution`] once the job's last work item retired; the
/// caller observed `live_nodes` hit zero, so it owns the continuation.
fn finalize(job: &Arc<JobInner>) {
    if let Some(f) = &job.ctl.cfg.fault {
        f.on_finalize();
    }
    let termination = if job.failed.load(Ordering::SeqCst) {
        Termination::Failed
    } else if job.cancelled.load(Ordering::SeqCst) {
        Termination::Cancelled
    } else if job.ctl.timed_out.load(Ordering::SeqCst) {
        Termination::DeadlineExpired
    } else {
        Termination::Complete
    };
    if termination == Termination::Failed {
        // A failed job's folds were poisoned at the failure site;
        // retract anything it published before that as belt-and-
        // suspenders (entries are versioned by job id).
        if let Some(m) = &job.ctl.cfg.memo {
            m.retract();
        }
        if job.admission.enqueue_retry(job) {
            // Degradation ladder, rung 3: the parallel run panicked but
            // a retry policy is set — the recovery thread reruns the
            // job on the sequential solver and publishes the outcome
            // instead.
            return;
        }
    }
    let Some(p) = job.prepared.get() else {
        // Setup panicked before publishing prep: degenerate outcome.
        store_outcome(job, failed_solution(job));
        return;
    };

    #[cfg(debug_assertions)]
    {
        // A fully-explored search must have drained its registry (PVC
        // early stop and cancelled/timed-out jobs legitimately leave
        // live entries behind).
        if termination == Termination::Complete && !job.ctl.stop.load(Ordering::SeqCst) {
            job.ctl.registry.assert_drained();
        }
    }

    let mut stats = job.ctl.stats_sink.lock().unwrap().clone();
    stats.registry_entries = job.ctl.registry.len() as u64;
    if p.root_pushed {
        // The root payload was created in setup, outside any descent.
        stats.payload_nodes += 1;
        stats.payload_bytes += p.root_bytes;
    }
    // Fold this job's engine counters into the service's per-class
    // telemetry ([`VcService::stats`]).
    let agg = job.counters.class(job.problem.kind());
    agg.jobs.fetch_add(1, Ordering::Relaxed);
    agg.tree_nodes.fetch_add(stats.tree_nodes, Ordering::Relaxed);
    agg.delta_children.fetch_add(stats.delta_children, Ordering::Relaxed);
    agg.undo_pops.fetch_add(stats.undo_pops, Ordering::Relaxed);
    agg.materializations.fetch_add(stats.materializations, Ordering::Relaxed);
    agg.memo_lookups.fetch_add(stats.memo_lookups, Ordering::Relaxed);
    agg.memo_hits.fetch_add(stats.memo_hits, Ordering::Relaxed);

    let best_resid = job.ctl.best.load(Ordering::SeqCst);
    let improved = job.ctl.improved.load(Ordering::SeqCst);
    // The engine's assembled residual witness, lifted to original ids
    // (extracting jobs only; decided-at-prep jobs never searched).
    let extract = job.ctl.cfg.extract_witness;
    let lifted: Option<Vec<u32>> = job
        .ctl
        .registry
        .take_root_witness()
        .and_then(|w| p.lift.as_ref().map(|lift| lift.lift(&w)));
    let g_orig = job.problem.graph();
    let (objective, feasible, witness) = match (&job.problem, &p.decided) {
        (Problem::Pvc { .. }, Some(PvcDecided::FoundGreedy(s))) => {
            let w = extract.then(|| greedy::greedy_cover(g_orig));
            (*s, true, w)
        }
        (Problem::Pvc { k, .. }, Some(PvcDecided::Infeasible)) => {
            (k.saturating_add(1), false, None)
        }
        (Problem::Pvc { k, .. }, None) => {
            let k_resid = p.k_resid.expect("searched PVC has a residual budget");
            let found = improved && best_resid <= k_resid;
            if found {
                // The assembled cover always respects k (extraction
                // gates early stop on assembled witnesses); it may be
                // longer than the est-propagated objective.
                let w = lifted.filter(|c| c.len() as u32 <= *k);
                (p.forced + best_resid, true, w)
            } else {
                (k.saturating_add(1), false, None)
            }
        }
        (Problem::Mvc { .. }, _) | (Problem::Mis { .. }, _) => {
            let total = p.forced + best_resid.min(p.initial);
            let anytime = matches!(
                termination,
                Termination::DeadlineExpired | Termination::Cancelled
            );
            let (mvc, cover) = if extract && anytime {
                // Anytime results: a deadline/cancel must not discard
                // the best cover already assembled. The registry's
                // shortest-wins root slot (threaded here as `lifted`)
                // is the best *witnessed* cover; est-propagation can
                // tighten `best` below it without a cover, so under an
                // early stop the reported objective is re-anchored to
                // the witness length — the returned bound and cover
                // always agree (`|witness| == objective`), falling back
                // to the greedy cover when no witness was assembled.
                let c = match lifted {
                    Some(c) if (c.len() as u32) < p.greedy_ub => c,
                    _ => greedy::greedy_cover(g_orig),
                };
                (c.len() as u32, Some(c))
            } else {
                let mvc = total.min(p.greedy_ub);
                let cover = if extract {
                    witness::cover_of_record(lifted, mvc, p.greedy_ub, g_orig)
                } else {
                    None
                };
                (mvc, cover)
            };
            if matches!(job.problem, Problem::Mis { .. }) {
                let set = cover.map(|c| witness::complement(g_orig, &c));
                (g_orig.num_vertices() as u32 - mvc, true, set)
            } else {
                (mvc, true, cover)
            }
        }
    };
    // Canonical witness order: assembly order depends on scheduling, so
    // sort before reporting — cold and warm (memo-hit) runs of the same
    // job then return bit-identical witnesses.
    let witness = witness.map(|mut w| {
        w.sort_unstable();
        w
    });
    let witness_verified = witness.as_ref().map(|w| match job.problem.kind() {
        ProblemKind::Mis => witness::verify_independent_set(g_orig, w).is_ok(),
        ProblemKind::Mvc | ProblemKind::Pvc => witness::verify_cover(g_orig, w).is_ok(),
    });

    store_outcome(
        job,
        Solution {
            problem: job.problem.kind(),
            objective,
            feasible,
            witness,
            witness_verified,
            stats,
            prep: p.summary.clone(),
            elapsed: job.started.elapsed(),
            termination,
            failure: job.failure.lock().unwrap().clone(),
        },
    );
}

// ---------------------------------------------------------------------
// Failure recovery: the sequential-rescue thread
// ---------------------------------------------------------------------

/// The recovery thread (`cavc-svc-retry`): reruns panicked jobs on the
/// sequential solver under their [`RetryPolicy`] — the degraded-but-
/// trusted rung of the degradation ladder. Jobs that exhaust every
/// attempt are quarantined (counted) and surface [`Termination::Failed`].
fn recovery_loop(adm: &Arc<Admission>) {
    loop {
        let job = {
            let mut q = adm.retry_queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if adm.retry_shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = adm.retry_cv.wait(q).unwrap();
            }
        };
        let policy = job.retry.unwrap_or_default();
        let mut rescued = None;
        for _ in 0..policy.attempts.max(1) {
            if !policy.backoff.is_zero() {
                std::thread::sleep(policy.backoff);
            }
            adm.retries.fetch_add(1, Ordering::Relaxed);
            // The sequential solver shares none of the parallel run's
            // state (fresh prep, no registry, no shared queues), but a
            // rescue must stay contained too — e.g. a fault plan that
            // panics in a shared reduction path.
            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                sequential_rescue(&job)
            })) {
                Ok(sol) => {
                    rescued = Some(sol);
                    break;
                }
                Err(payload) => record_failure(&job, &payload),
            }
        }
        match rescued {
            Some(sol) => {
                adm.recovered.fetch_add(1, Ordering::Relaxed);
                store_outcome(&job, sol);
            }
            None => {
                adm.quarantined.fetch_add(1, Ordering::Relaxed);
                store_outcome(&job, failed_solution(&job));
            }
        }
    }
}

/// Recompute a panicked job's answer on the sequential solver, from
/// scratch — fresh preparation, trusting nothing the failed parallel
/// run left behind. Mirrors the one-shot `Variant::Sequential` recipes.
fn sequential_rescue(job: &Arc<JobInner>) -> Solution {
    let g: &Graph = job.problem.graph();
    let extract = job.ctl.cfg.extract_witness;
    let component_aware = job.ctl.cfg.component_aware;
    let deadline = job.ctl.cfg.deadline;
    // Stats: keep the failed attempt's counters (incl. its contained
    // panics) and add the rescue's tree on top.
    let mut stats = job.ctl.stats_sink.lock().unwrap().clone();

    let (objective, feasible, witness, summary) = match &job.problem {
        Problem::Mvc { .. } | Problem::Mis { .. } => {
            let p = prep::prepare(g, &job.prep_cfg, None);
            let initial = p.residual_ub;
            let out =
                sequential::solve(&p.residual.graph, initial, component_aware, extract, deadline);
            stats.tree_nodes += out.tree_nodes;
            stats.component_branches += out.component_branches;
            let cover = out.cover.map(|c| p.lift_residual_cover(&c));
            let best = p.total_size(out.best.min(initial)).min(p.greedy_ub);
            let cover =
                if extract { witness::cover_of_record(cover, best, p.greedy_ub, g) } else { None };
            let summary = rescue_summary(g, &p);
            if matches!(job.problem, Problem::Mis { .. }) {
                let set = cover.map(|c| witness::complement(g, &c));
                (g.num_vertices() as u32 - best, true, set, summary)
            } else {
                (best, true, cover, summary)
            }
        }
        Problem::Pvc { k, .. } => {
            let p = prep::prepare(g, &job.prep_cfg, Some(k.saturating_add(1)));
            let forced = p.forced_cover.len() as u32;
            let summary = rescue_summary(g, &p);
            if p.greedy_ub <= *k {
                (p.greedy_ub, true, extract.then(|| greedy::greedy_cover(g)), summary)
            } else if forced > *k {
                (k.saturating_add(1), false, None, summary)
            } else {
                let k_resid = k - forced;
                let initial = (k_resid + 1).min(p.residual.graph.num_vertices() as u32 + 1);
                let out = sequential::solve(
                    &p.residual.graph,
                    initial,
                    component_aware,
                    extract,
                    deadline,
                );
                stats.tree_nodes += out.tree_nodes;
                stats.component_branches += out.component_branches;
                let found = out.best < initial && out.best <= k_resid;
                if found {
                    let cover = out
                        .cover
                        .map(|c| p.lift_residual_cover(&c))
                        .filter(|c| c.len() as u32 <= *k);
                    (forced + out.best, true, cover, summary)
                } else {
                    (k.saturating_add(1), false, None, summary)
                }
            }
        }
    };
    // Same canonical order as the parallel path (see `finalize`).
    let witness = witness.map(|mut w: Vec<u32>| {
        w.sort_unstable();
        w
    });
    let witness_verified = witness.as_ref().map(|w| match job.problem.kind() {
        ProblemKind::Mis => witness::verify_independent_set(g, w).is_ok(),
        ProblemKind::Mvc | ProblemKind::Pvc => witness::verify_cover(g, w).is_ok(),
    });
    Solution {
        problem: job.problem.kind(),
        objective,
        feasible,
        witness,
        witness_verified,
        stats,
        prep: summary,
        elapsed: job.started.elapsed(),
        termination: Termination::Recovered,
        failure: job.failure.lock().unwrap().clone(),
    }
}

/// Prep summary for a sequential rescue (one logical worker).
fn rescue_summary(g: &Graph, p: &prep::Prepared) -> PrepSummary {
    PrepSummary {
        n_original: g.num_vertices(),
        n_residual: p.residual.graph.num_vertices(),
        forced: p.forced_cover.len(),
        greedy_ub: p.greedy_ub,
        dtype: p.dtype,
        blocks: p.occupancy.blocks,
        fits_shared_mem: p.occupancy.fits_shared_mem,
        workers: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::solver::oracle;

    #[test]
    fn single_mvc_job_matches_oracle() {
        let svc = VcService::builder().workers(2).build();
        for seed in 0..6 {
            let g = generators::erdos_renyi(18, 0.2, seed);
            let opt = oracle::mvc_size(&g);
            let sol = svc.solve(Problem::mvc(g));
            assert_eq!(sol.objective, opt, "seed {seed}");
            assert_eq!(sol.termination, Termination::Complete);
            assert!(sol.feasible);
            assert!(sol.stats.tree_nodes > 0 || sol.prep.n_residual == 0, "seed {seed}");
        }
    }

    #[test]
    fn pvc_jobs_answer_both_sides() {
        let svc = VcService::builder().workers(3).build();
        for seed in 0..6 {
            let g = generators::erdos_renyi(16, 0.22, seed);
            let opt = oracle::mvc_size(&g);
            let yes = svc.solve(Problem::pvc(g.clone(), opt));
            assert!(yes.feasible, "seed {seed} k=opt");
            assert!(yes.objective <= opt, "seed {seed}");
            let no = svc.solve(Problem::pvc(g, opt.saturating_sub(1)));
            assert!(!no.feasible, "seed {seed} k=opt-1");
            assert_eq!(no.objective, opt, "infeasible reports k+1");
        }
    }

    #[test]
    fn mis_job_complements_mvc() {
        let svc = VcService::builder().workers(2).build();
        let g = generators::petersen();
        let sol = svc.solve(Problem::mis(g));
        assert_eq!(sol.objective, 4); // α(Petersen) = 4
        assert_eq!(sol.problem, ProblemKind::Mis);
    }

    fn extract_opts() -> JobOptions {
        JobOptions { extract_witness: true, ..JobOptions::default() }
    }

    #[test]
    fn extracting_jobs_return_verified_witnesses() {
        let svc = VcService::builder().workers(3).build();
        for seed in 0..6 {
            let g = generators::union_of_random(3, 3, 6, 0.3, seed);
            let opt = oracle::mvc_size(&g);
            let sol = svc.submit_with(Problem::mvc(g.clone()), extract_opts()).wait();
            assert_eq!(sol.objective, opt, "seed {seed}");
            let w = sol.witness.as_ref().expect("MVC witness");
            assert_eq!(w.len() as u32, opt, "seed {seed}");
            assert!(g.is_vertex_cover(w), "seed {seed}");
            assert_eq!(sol.witness_verified, Some(true), "seed {seed}");
        }
    }

    #[test]
    fn extracting_pvc_and_mis_jobs() {
        let svc = VcService::builder().workers(2).build();
        for seed in 0..5 {
            let g = generators::erdos_renyi(15, 0.22, seed);
            let opt = oracle::mvc_size(&g);
            let pvc = svc.submit_with(Problem::pvc(g.clone(), opt), extract_opts()).wait();
            assert!(pvc.feasible, "seed {seed}");
            let w = pvc.witness.as_ref().expect("PVC witness");
            assert!(w.len() as u32 <= opt, "seed {seed}");
            assert!(g.is_vertex_cover(w), "seed {seed}");
            assert_eq!(pvc.witness_verified, Some(true), "seed {seed}");

            let mis = svc.submit_with(Problem::mis(g.clone()), extract_opts()).wait();
            let n = g.num_vertices() as u32;
            assert_eq!(mis.objective, n - opt, "seed {seed}");
            let set = mis.witness.as_ref().expect("MIS witness");
            assert_eq!(set.len() as u32, mis.objective, "seed {seed}");
            assert_eq!(mis.witness_verified, Some(true), "seed {seed}");
        }
    }

    #[test]
    fn config_extract_cover_requests_witness() {
        // a per-job SolverConfig with extract_cover set is equivalent to
        // JobOptions::extract_witness (the one-shot shims rely on it)
        let svc = VcService::builder().workers(1).build();
        let mut cfg = SolverConfig::proposed();
        cfg.extract_cover = true;
        let g = generators::petersen();
        let opts = JobOptions { config: Some(cfg), ..JobOptions::default() };
        let sol = svc.submit_with(Problem::mvc(g.clone()), opts).wait();
        assert_eq!(sol.objective, 6);
        let w = sol.witness.expect("config.extract_cover requests a witness");
        assert_eq!(w.len(), 6);
        assert!(g.is_vertex_cover(&w));
        assert_eq!(sol.witness_verified, Some(true));
    }

    #[test]
    fn non_extracting_jobs_have_no_witness() {
        let svc = VcService::builder().workers(1).build();
        let sol = svc.solve(Problem::mvc(generators::petersen()));
        assert_eq!(sol.objective, 6);
        assert!(sol.witness.is_none());
        assert_eq!(sol.witness_verified, None);
        assert_eq!(sol.stats.witness_log_bytes, 0);
    }

    #[test]
    fn many_concurrent_jobs_all_resolve() {
        let svc = VcService::builder().workers(4).build();
        let handles: Vec<(JobHandle, u32)> = (0..24u64)
            .map(|seed| {
                let g = generators::erdos_renyi(14 + (seed as usize % 6), 0.2, seed);
                let opt = oracle::mvc_size(&g);
                (svc.submit(Problem::mvc(g)), opt)
            })
            .collect();
        for (i, (h, opt)) in handles.iter().enumerate() {
            let sol = h.wait();
            assert_eq!(sol.objective, *opt, "job {i}");
            assert_eq!(sol.termination, Termination::Complete, "job {i}");
        }
    }

    #[test]
    fn service_drop_drains_outstanding_jobs() {
        let svc = VcService::builder().workers(2).build();
        let pairs: Vec<(JobHandle, u32)> = (0..8u64)
            .map(|seed| {
                let g = generators::union_of_random(3, 3, 6, 0.3, seed);
                let opt = oracle::mvc_size(&g);
                (svc.submit(Problem::mvc(g)), opt)
            })
            .collect();
        drop(svc); // graceful shutdown must drain, not abandon
        for (h, opt) in pairs {
            let sol = h.wait();
            assert_eq!(sol.objective, opt);
        }
    }

    #[test]
    fn empty_and_trivial_graphs_through_service() {
        let svc = VcService::builder().workers(1).build();
        let empty = Graph::from_edges(5, &[]);
        assert_eq!(svc.solve(Problem::mvc(empty)).objective, 0);
        let single = Graph::from_edges(2, &[(0, 1)]);
        assert_eq!(svc.solve(Problem::mvc(single.clone())).objective, 1);
        assert!(svc.solve(Problem::pvc(single.clone(), 1)).feasible);
        assert!(!svc.solve(Problem::pvc(single, 0)).feasible);
    }

    #[test]
    fn sharded_resident_pool_agrees() {
        let svc =
            VcService::builder().workers(3).scheduler(SchedulerKind::Sharded).build();
        for seed in 0..5 {
            let g = generators::union_of_random(3, 3, 7, 0.3, seed);
            let opt = oracle::mvc_size(&g);
            assert_eq!(svc.solve(Problem::mvc(g)).objective, opt, "seed {seed}");
        }
    }

    #[test]
    fn stats_endpoint_counts_classes_and_parks() {
        let svc = VcService::builder().workers(2).build();
        for seed in 0..3 {
            let g = generators::erdos_renyi(14, 0.2, seed);
            let opt = oracle::mvc_size(&g);
            assert_eq!(svc.solve(Problem::mvc(g.clone())).objective, opt);
            assert!(svc.solve(Problem::pvc(g, opt)).feasible);
        }
        let stats = svc.stats();
        assert_eq!(stats.mvc.jobs, 3);
        assert_eq!(stats.pvc.jobs, 3);
        assert_eq!(stats.mis.jobs, 0);
        assert!(stats.mvc.tree_nodes > 0);
        assert_eq!(stats.class(ProblemKind::Pvc).jobs, 3);
        // an idle resident pool parks its workers; give it a beat
        let mut parks = svc.stats().pool.parks;
        for _ in 0..400 {
            if parks > 0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
            parks = svc.stats().pool.parks;
        }
        assert!(parks > 0, "idle pool must park");
    }

    #[test]
    fn job_ids_are_unique_and_monotonic() {
        let svc = VcService::builder().workers(1).build();
        let a = svc.submit(Problem::mvc(generators::path(4)));
        let b = svc.submit(Problem::mvc(generators::path(5)));
        assert!(b.id() > a.id());
        a.wait();
        b.wait();
    }

    #[test]
    fn wait_returns_under_injected_setup_panic() {
        // Satellite: every exit path must wake the waiters. The injected
        // panic unwinds out of setup before prep is published — the
        // containment path must still finalize with `Failed`, and the
        // pool must keep serving other jobs afterwards.
        let svc = VcService::builder().workers(2).build();
        let opts = JobOptions { panic_in_setup: true, ..JobOptions::default() };
        let sol = svc.submit_with(Problem::mvc(generators::petersen()), opts).wait();
        assert_eq!(sol.termination, Termination::Failed);
        assert!(!sol.feasible);
        // the panicking job released its admission slot and the workers
        // survived: a normal job still runs to completion
        let ok = svc.solve(Problem::mvc(generators::petersen()));
        assert_eq!(ok.objective, 6);
        assert_eq!(ok.termination, Termination::Complete);
    }

    #[test]
    fn pre_expired_deadline_still_wakes_waiters() {
        let svc = VcService::builder().workers(1).build();
        let opts = JobOptions { timeout: Some(Duration::ZERO), ..JobOptions::default() };
        let g = generators::erdos_renyi(30, 0.2, 7);
        let sol = svc.submit_with(Problem::mvc(g), opts).wait();
        assert_eq!(sol.termination, Termination::DeadlineExpired);
        assert!(sol.timed_out());
    }

    #[test]
    fn stats_reconcile_exactly_across_16_workers() {
        // Satellite: the old 256-item flush cadence left per-worker
        // deltas invisible to `stats()` until a worker happened to flush.
        // With read-time folding the push/pop conservation ledger must
        // reconcile exactly once the pool drains.
        let svc = VcService::builder().workers(16).build();
        let handles: Vec<JobHandle> = (0..32u64)
            .map(|seed| svc.submit(Problem::mvc(generators::erdos_renyi(16, 0.25, seed))))
            .collect();
        for h in &handles {
            h.wait();
        }
        // Workers publish cumulative totals after each item; the last
        // publications can trail `wait` by an instant, so poll briefly.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let s = svc.stats();
            let consumed = s.pool.pops + s.pool.shared_pops + s.pool.steals;
            let produced = s.pool.pushes + s.pool.injected;
            if produced > 0
                && consumed == produced
                && s.admission.queued == 0
                && s.admission.live_jobs == 0
            {
                assert_eq!(
                    s.admission.dispatched_latency + s.admission.dispatched_throughput,
                    32
                );
                assert_eq!(s.mvc.jobs, 32);
                break;
            }
            assert!(
                Instant::now() < deadline,
                "ledger failed to reconcile: consumed={consumed} produced={produced} \
                 queued={} live_jobs={}",
                s.admission.queued,
                s.admission.live_jobs
            );
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}
